//! Weight-rotation analysis (paper section 3.4 / Figure 3): how much of the
//! weight change produced by QAT or SpinQuant is explainable as a pure
//! matrix rotation (orthogonal Procrustes distance) vs not.

use anyhow::Result;
use std::collections::BTreeMap;

use crate::config::ModelCfg;
use crate::linalg::{rotation_decomposition, Mat, RotationSplit};
use crate::model::ParamStore;

/// Per-layer-type averages of the rotational / non-rotational split.
pub fn analyze_rotation(
    before: &ParamStore,
    after: &ParamStore,
    _mc: &ModelCfg,
) -> Result<BTreeMap<String, RotationSplit>> {
    let mut grouped: BTreeMap<String, Vec<RotationSplit>> = BTreeMap::new();
    // the paper plots per linear-layer type; q/k/g/u/d/o are single-side
    // rotated in our SpinQuant-analog so all are comparable.
    for wn in ["wq", "wk", "wv", "wo", "wg", "wu", "wd"] {
        let shape = before.shape(wn)?.to_vec();
        let (l, k, n) = (shape[0], shape[1], shape[2]);
        for li in 0..l {
            let a = Mat::from_vec(k, n, before.get(wn)?[li * k * n..(li + 1) * k * n].to_vec());
            let b = Mat::from_vec(k, n, after.get(wn)?[li * k * n..(li + 1) * k * n].to_vec());
            grouped.entry(wn.to_string()).or_default().push(rotation_decomposition(&a, &b));
        }
    }
    Ok(grouped
        .into_iter()
        .map(|(k, v)| {
            let n = v.len() as f64;
            (
                k,
                RotationSplit {
                    total: v.iter().map(|s| s.total).sum::<f64>() / n,
                    non_rotational: v.iter().map(|s| s.non_rotational).sum::<f64>() / n,
                    rotational: v.iter().map(|s| s.rotational).sum::<f64>() / n,
                },
            )
        })
        .collect())
}

/// Fraction of the total weight change explained by rotation, aggregated
/// over all layer types (the paper's headline 90% vs 43% numbers).
pub fn rotation_fraction(splits: &BTreeMap<String, RotationSplit>) -> f64 {
    let total: f64 = splits.values().map(|s| s.total).sum();
    let rot: f64 = splits.values().map(|s| s.rotational).sum();
    if total <= 0.0 {
        0.0
    } else {
        rot / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::random_rotation;
    use crate::util::Rng;

    fn fake_store(seed: u64) -> (ParamStore, ModelCfg) {
        use crate::config::TensorSpec;
        let mc = ModelCfg {
            name: "t".into(), vocab: 32, d_model: 8, n_layers: 2, n_heads: 2,
            d_ff: 8, seq_len: 8, train_batch: 1, fwd_batch: 1, use_pallas: false,
        };
        let mut inputs = vec![];
        for wn in ["wq", "wk", "wv", "wo", "wg", "wu", "wd"] {
            inputs.push(TensorSpec { name: format!("params.{wn}"), dtype: "f32".into(), dims: vec![2, 8, 8] });
        }
        let spec = crate::config::ArtifactSpec {
            name: "x".into(), file: "x".into(), model: "t".into(), prec: "p".into(),
            mode: "fwd".into(), inputs, outputs: vec![],
        };
        let mut rng = Rng::new(seed);
        let mut ps = ParamStore::from_spec(&spec);
        for v in ps.values.iter_mut() {
            *v = rng.normal_vec(v.len(), 1.0);
        }
        (ps, mc)
    }

    #[test]
    fn pure_rotation_has_high_fraction() {
        let (before, mc) = fake_store(1);
        let mut after = before.clone();
        let r = random_rotation(8, &mut Rng::new(2));
        // rotate every weight on the left: B = R A
        for i in 0..after.names.len() {
            for li in 0..2 {
                let a = Mat::from_vec(8, 8, before.values[i][li * 64..(li + 1) * 64].to_vec());
                let b = r.matmul(&a);
                after.values[i][li * 64..(li + 1) * 64].copy_from_slice(&b.data);
            }
        }
        let splits = analyze_rotation(&before, &after, &mc).unwrap();
        assert!(rotation_fraction(&splits) > 0.9, "{}", rotation_fraction(&splits));
    }

    #[test]
    fn additive_noise_has_low_fraction() {
        let (before, mc) = fake_store(3);
        let mut after = before.clone();
        let mut rng = Rng::new(4);
        for v in after.values.iter_mut() {
            for x in v.iter_mut() {
                *x += rng.normal() * 0.5;
            }
        }
        let splits = analyze_rotation(&before, &after, &mc).unwrap();
        assert!(rotation_fraction(&splits) < 0.5, "{}", rotation_fraction(&splits));
    }
}
