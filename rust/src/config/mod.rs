//! Configuration: model/precision descriptions parsed from the artifact
//! manifest (the single source of truth shared with the Python compile path)
//! plus training hyper-parameters with `--set key=value` overrides.

pub mod manifest;

pub use manifest::{ArtifactSpec, Manifest, ModelCfg, PrecCfg, TensorSpec};

/// Training hyper-parameters (paper Appendix B defaults).
#[derive(Clone, Debug)]
pub struct TrainCfg {
    /// base learning rate at `ref_steps` (scaled by the inverse-sqrt rule)
    pub base_lr: f32,
    /// number of steps the base LR is quoted at (paper: 8000)
    pub ref_steps: usize,
    pub steps: usize,
    pub weight_decay: f32,
    /// multiplicative LR boost on activation quantizer steps (paper: 50)
    pub act_lrx: f32,
    pub kd_ratio: f32,
    pub kd_temp: f32,
    /// fraction of pre-training (DCLM-analog) data mixed into instruct QAT
    pub dclm_ratio: f32,
    /// cosine schedule floor as a fraction of the initial LR (paper: 0.1)
    pub min_lr_frac: f32,
    pub seed: u64,
    /// evaluate every N steps (0 = only at the end)
    pub eval_every: usize,
    /// calibration batches (paper: 5 x 128 samples; scaled down here)
    pub calib_batches: usize,
    /// activation calibration: "quantile" (paper) or "max" (ablation)
    pub act_calib: String,
    /// weight calibration: "mse" (paper Eq. 2) or "lsq" (LSQ-paper init)
    pub wgt_calib: String,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            base_lr: 5e-3, // scaled up from the paper's 5e-6: tiny models + short runs
            ref_steps: 800,
            steps: 800,
            weight_decay: 0.1,
            act_lrx: 50.0,
            kd_ratio: 1.0,
            kd_temp: 1.0,
            dclm_ratio: 0.25,
            min_lr_frac: 0.1,
            seed: 0,
            eval_every: 0,
            calib_batches: 4,
            act_calib: "quantile".into(),
            wgt_calib: "mse".into(),
        }
    }
}

impl TrainCfg {
    /// Apply a `key=value` override; returns false for unknown keys.
    pub fn set(&mut self, key: &str, value: &str) -> bool {
        match key {
            "base_lr" => self.base_lr = value.parse().unwrap_or(self.base_lr),
            "ref_steps" => self.ref_steps = value.parse().unwrap_or(self.ref_steps),
            "steps" => self.steps = value.parse().unwrap_or(self.steps),
            "weight_decay" => self.weight_decay = value.parse().unwrap_or(self.weight_decay),
            "act_lrx" => self.act_lrx = value.parse().unwrap_or(self.act_lrx),
            "kd_ratio" => self.kd_ratio = value.parse().unwrap_or(self.kd_ratio),
            "kd_temp" => self.kd_temp = value.parse().unwrap_or(self.kd_temp),
            "dclm_ratio" => self.dclm_ratio = value.parse().unwrap_or(self.dclm_ratio),
            "min_lr_frac" => self.min_lr_frac = value.parse().unwrap_or(self.min_lr_frac),
            "seed" => self.seed = value.parse().unwrap_or(self.seed),
            "eval_every" => self.eval_every = value.parse().unwrap_or(self.eval_every),
            "calib_batches" => self.calib_batches = value.parse().unwrap_or(self.calib_batches),
            "act_calib" => self.act_calib = value.into(),
            "wgt_calib" => self.wgt_calib = value.into(),
            _ => return false,
        }
        true
    }

    /// The paper's LR transfer rule (Appendix B / power scheduler): when the
    /// step count changes by a factor k relative to `ref_steps`, the LR is
    /// scaled by 1/sqrt(k).
    pub fn scaled_lr(&self) -> f32 {
        let k = self.steps as f32 / self.ref_steps as f32;
        self.base_lr / k.sqrt()
    }

    /// Cosine schedule with floor (paper: cosine to 10% of initial, no warmup).
    pub fn lr_at(&self, step: usize) -> f32 {
        let lr0 = self.scaled_lr();
        let min_lr = lr0 * self.min_lr_frac;
        if self.steps <= 1 {
            return lr0;
        }
        let t = step as f32 / (self.steps - 1) as f32;
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
        min_lr + (lr0 - min_lr) * cos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_shape() {
        let c = TrainCfg::default();
        assert_eq!(c.act_lrx, 50.0);
        assert_eq!(c.kd_ratio, 1.0);
        assert_eq!(c.dclm_ratio, 0.25);
        assert_eq!(c.weight_decay, 0.1);
    }

    #[test]
    fn set_overrides() {
        let mut c = TrainCfg::default();
        assert!(c.set("steps", "100"));
        assert!(c.set("kd_ratio", "0.5"));
        assert!(!c.set("nope", "1"));
        assert_eq!(c.steps, 100);
        assert_eq!(c.kd_ratio, 0.5);
    }

    #[test]
    fn lr_sqrt_scaling() {
        let mut c = TrainCfg::default();
        c.base_lr = 1e-3;
        c.ref_steps = 100;
        c.steps = 400; // 4x steps -> lr/2
        assert!((c.scaled_lr() - 5e-4).abs() < 1e-9);
        c.steps = 25; // 1/4 steps -> 2x lr
        assert!((c.scaled_lr() - 2e-3).abs() < 1e-9);
    }

    #[test]
    fn cosine_schedule_endpoints() {
        let mut c = TrainCfg::default();
        c.base_lr = 1e-3;
        c.ref_steps = 100;
        c.steps = 100;
        assert!((c.lr_at(0) - 1e-3).abs() < 1e-9);
        let end = c.lr_at(99);
        assert!((end - 1e-4).abs() < 1e-8, "{end}");
        assert!(c.lr_at(50) < c.lr_at(10));
    }
}
