//! Configuration: model/precision descriptions parsed from the artifact
//! manifest (the single source of truth shared with the Python compile path)
//! plus training hyper-parameters with `--set key=value` overrides.

pub mod manifest;

use anyhow::{anyhow, Result};

pub use manifest::{ArtifactSpec, Manifest, ModelCfg, PrecCfg, TensorSpec};

use crate::policy::CalibMethod;

/// Training hyper-parameters (paper Appendix B defaults).
#[derive(Clone, Debug)]
pub struct TrainCfg {
    /// base learning rate at `ref_steps` (scaled by the inverse-sqrt rule)
    pub base_lr: f32,
    /// number of steps the base LR is quoted at (paper: 8000)
    pub ref_steps: usize,
    pub steps: usize,
    pub weight_decay: f32,
    /// multiplicative LR boost on activation quantizer steps (paper: 50)
    pub act_lrx: f32,
    pub kd_ratio: f32,
    pub kd_temp: f32,
    /// fraction of pre-training (DCLM-analog) data mixed into instruct QAT
    pub dclm_ratio: f32,
    /// cosine schedule floor as a fraction of the initial LR (paper: 0.1)
    pub min_lr_frac: f32,
    pub seed: u64,
    /// evaluate every N steps (0 = only at the end)
    pub eval_every: usize,
    /// calibration batches (paper: 5 x 128 samples; scaled down here)
    pub calib_batches: usize,
    /// activation calibration: `Quantile` (paper) or `Max` (ablation)
    pub act_calib: CalibMethod,
    /// weight calibration: `Mse` (paper Eq. 2) or `Lsq` (LSQ-paper init)
    pub wgt_calib: CalibMethod,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            base_lr: 5e-3, // scaled up from the paper's 5e-6: tiny models + short runs
            ref_steps: 800,
            steps: 800,
            weight_decay: 0.1,
            act_lrx: 50.0,
            kd_ratio: 1.0,
            kd_temp: 1.0,
            dclm_ratio: 0.25,
            min_lr_frac: 0.1,
            seed: 0,
            eval_every: 0,
            calib_batches: 4,
            act_calib: CalibMethod::Quantile,
            wgt_calib: CalibMethod::Mse,
        }
    }
}

/// Parse a numeric override value, naming the key in the error.
fn num<T: std::str::FromStr>(key: &str, value: &str) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    value.parse().map_err(|e| anyhow!("{key}={value}: {e}"))
}

impl TrainCfg {
    /// Apply a `key=value` override. `Ok(false)` means the key is not a
    /// training hyper-parameter; a known key with an unparseable value is
    /// a hard error naming the key (never silently kept at its default).
    pub fn set(&mut self, key: &str, value: &str) -> Result<bool> {
        match key {
            "base_lr" => self.base_lr = num(key, value)?,
            "ref_steps" => self.ref_steps = num(key, value)?,
            "steps" => self.steps = num(key, value)?,
            "weight_decay" => self.weight_decay = num(key, value)?,
            "act_lrx" => self.act_lrx = num(key, value)?,
            "kd_ratio" => self.kd_ratio = num(key, value)?,
            "kd_temp" => self.kd_temp = num(key, value)?,
            "dclm_ratio" => self.dclm_ratio = num(key, value)?,
            "min_lr_frac" => self.min_lr_frac = num(key, value)?,
            "seed" => self.seed = num(key, value)?,
            "eval_every" => self.eval_every = num(key, value)?,
            "calib_batches" => self.calib_batches = num(key, value)?,
            "act_calib" => self.act_calib = CalibMethod::parse_act(value)?,
            "wgt_calib" => self.wgt_calib = CalibMethod::parse_weight(value)?,
            _ => return Ok(false),
        }
        Ok(true)
    }

    /// The paper's LR transfer rule (Appendix B / power scheduler): when the
    /// step count changes by a factor k relative to `ref_steps`, the LR is
    /// scaled by 1/sqrt(k).
    pub fn scaled_lr(&self) -> f32 {
        let k = self.steps as f32 / self.ref_steps as f32;
        self.base_lr / k.sqrt()
    }

    /// Cosine schedule with floor (paper: cosine to 10% of initial, no warmup).
    pub fn lr_at(&self, step: usize) -> f32 {
        let lr0 = self.scaled_lr();
        let min_lr = lr0 * self.min_lr_frac;
        if self.steps <= 1 {
            return lr0;
        }
        let t = step as f32 / (self.steps - 1) as f32;
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
        min_lr + (lr0 - min_lr) * cos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_shape() {
        let c = TrainCfg::default();
        assert_eq!(c.act_lrx, 50.0);
        assert_eq!(c.kd_ratio, 1.0);
        assert_eq!(c.dclm_ratio, 0.25);
        assert_eq!(c.weight_decay, 0.1);
    }

    #[test]
    fn set_overrides() {
        let mut c = TrainCfg::default();
        assert!(c.set("steps", "100").unwrap());
        assert!(c.set("kd_ratio", "0.5").unwrap());
        assert!(!c.set("nope", "1").unwrap());
        assert_eq!(c.steps, 100);
        assert_eq!(c.kd_ratio, 0.5);
    }

    #[test]
    fn set_rejects_bad_values_for_known_keys() {
        let mut c = TrainCfg::default();
        let e = c.set("steps", "notanumber").unwrap_err().to_string();
        assert!(e.contains("steps"), "error must name the key: {e}");
        assert_eq!(c.steps, TrainCfg::default().steps, "value must be untouched");
        assert!(c.set("act_calib", "bogus").is_err());
        assert!(c.set("wgt_calib", "quantile").is_err(), "quantile is act-side only");
        assert!(c.set("act_calib", "max").unwrap());
        assert!(c.set("wgt_calib", "lsq").unwrap());
        assert_eq!(c.act_calib, CalibMethod::Max);
        assert_eq!(c.wgt_calib, CalibMethod::Lsq);
    }

    #[test]
    fn lr_sqrt_scaling() {
        let mut c = TrainCfg::default();
        c.base_lr = 1e-3;
        c.ref_steps = 100;
        c.steps = 400; // 4x steps -> lr/2
        assert!((c.scaled_lr() - 5e-4).abs() < 1e-9);
        c.steps = 25; // 1/4 steps -> 2x lr
        assert!((c.scaled_lr() - 2e-3).abs() < 1e-9);
    }

    #[test]
    fn cosine_schedule_endpoints() {
        let mut c = TrainCfg::default();
        c.base_lr = 1e-3;
        c.ref_steps = 100;
        c.steps = 100;
        assert!((c.lr_at(0) - 1e-3).abs() < 1e-9);
        let end = c.lr_at(99);
        assert!((end - 1e-4).abs() < 1e-8, "{end}");
        assert!(c.lr_at(50) < c.lr_at(10));
    }
}
