//! Artifact manifest parser.
//!
//! `python -m compile.aot` writes `artifacts/manifest.txt`, a plain
//! line-based description of every compiled artifact (serde is unavailable
//! offline, and a line format is trivially diffable anyway). This module is
//! the Rust half of that contract.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::kv_pairs;

/// Architecture description mirrored from `python/compile/configs.py`.
#[derive(Clone, Debug)]
pub struct ModelCfg {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub train_batch: usize,
    pub fwd_batch: usize,
    pub use_pallas: bool,
}

/// Quantization placement mirrored from `python/compile/configs.py`.
///
/// This is the wire form only — every in-process precision decision goes
/// through the typed [`crate::policy::QuantPolicy`] this parses into (see
/// [`PrecCfg::policy`]); `Manifest::parse` validates each entry against it.
#[derive(Clone, Debug)]
pub struct PrecCfg {
    pub name: String,
    pub quantized: bool,
    pub act_bits: u32,
    pub act_dynamic: bool,
    pub cache_bits: u32,
    pub weight_bits: u32,
    pub head_bits: u32,
    pub query_bits: u32,
    pub online_rot: bool,
}

impl PrecCfg {
    /// Lift into the typed policy (lossless; see `QuantPolicy::from_prec`).
    pub fn policy(&self) -> Result<crate::policy::QuantPolicy> {
        crate::policy::QuantPolicy::from_prec(self)
    }
}

/// One tensor in an artifact signature.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    /// "f32" | "i32"
    pub dtype: String,
    /// empty for scalars
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One compiled artifact: file + typed I/O signature.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub model: String,
    pub prec: String,
    pub mode: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    /// Names of the `params.*` inputs in order (the parameter contract).
    pub fn param_names(&self) -> Vec<String> {
        self.inputs
            .iter()
            .filter_map(|t| t.name.strip_prefix("params.").map(|s| s.to_string()))
            .collect()
    }

    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|t| t.name == name)
            .ok_or_else(|| anyhow!("artifact {}: no input {name}", self.name))
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|t| t.name == name)
            .ok_or_else(|| anyhow!("artifact {}: no output {name}", self.name))
    }
}

/// The whole parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelCfg>,
    pub precs: BTreeMap<String, PrecCfg>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn get<'a>(kv: &'a [(String, String)], key: &str) -> Result<&'a str> {
    kv.iter()
        .find(|(k, _)| k == key)
        .map(|(_, v)| v.as_str())
        .ok_or_else(|| anyhow!("missing key {key}"))
}

fn parse_dims(tag: &str) -> Result<Vec<usize>> {
    if tag == "scalar" {
        return Ok(vec![]);
    }
    tag.split('x')
        .map(|d| d.parse::<usize>().context("bad dim"))
        .collect()
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let mut m = Manifest { dir, ..Default::default() };
        let mut cur: Option<ArtifactSpec> = None;

        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let tag = it.next().unwrap();
            let rest: Vec<&str> = it.collect();
            let kv = kv_pairs(line);
            match tag {
                "model" => {
                    let name = rest.first().ok_or_else(|| anyhow!("line {lineno}: model name"))?;
                    m.models.insert(
                        name.to_string(),
                        ModelCfg {
                            name: name.to_string(),
                            vocab: get(&kv, "vocab")?.parse()?,
                            d_model: get(&kv, "d_model")?.parse()?,
                            n_layers: get(&kv, "n_layers")?.parse()?,
                            n_heads: get(&kv, "n_heads")?.parse()?,
                            d_ff: get(&kv, "d_ff")?.parse()?,
                            seq_len: get(&kv, "seq_len")?.parse()?,
                            train_batch: get(&kv, "train_batch")?.parse()?,
                            fwd_batch: get(&kv, "fwd_batch")?.parse()?,
                            use_pallas: get(&kv, "use_pallas")? == "1",
                        },
                    );
                }
                "prec" => {
                    let name = rest.first().ok_or_else(|| anyhow!("line {lineno}: prec name"))?;
                    let pc = PrecCfg {
                        name: name.to_string(),
                        quantized: get(&kv, "quantized")? == "1",
                        act_bits: get(&kv, "act_bits")?.parse()?,
                        act_dynamic: get(&kv, "act_dynamic")? == "1",
                        cache_bits: get(&kv, "cache_bits")?.parse()?,
                        weight_bits: get(&kv, "weight_bits")?.parse()?,
                        head_bits: get(&kv, "head_bits")?.parse()?,
                        query_bits: get(&kv, "query_bits")?.parse()?,
                        online_rot: get(&kv, "online_rot")? == "1",
                    };
                    // a manifest precision the typed policy layer rejects
                    // must fail at parse time, not deep inside a run
                    pc.policy().with_context(|| format!("line {lineno}: invalid precision {name}"))?;
                    m.precs.insert(name.to_string(), pc);
                }
                "artifact" => {
                    let name = rest.first().ok_or_else(|| anyhow!("line {lineno}: artifact name"))?;
                    cur = Some(ArtifactSpec {
                        name: name.to_string(),
                        file: get(&kv, "file")?.to_string(),
                        model: get(&kv, "model")?.to_string(),
                        prec: get(&kv, "prec")?.to_string(),
                        mode: get(&kv, "mode")?.to_string(),
                        inputs: vec![],
                        outputs: vec![],
                    });
                }
                "in" | "out" => {
                    let a = cur.as_mut().ok_or_else(|| anyhow!("line {lineno}: io outside artifact"))?;
                    if rest.len() != 3 {
                        bail!("line {lineno}: expected `in name dtype dims`");
                    }
                    let spec = TensorSpec {
                        name: rest[0].to_string(),
                        dtype: rest[1].to_string(),
                        dims: parse_dims(rest[2])?,
                    };
                    if tag == "in" {
                        a.inputs.push(spec);
                    } else {
                        a.outputs.push(spec);
                    }
                }
                "endartifact" => {
                    let a = cur.take().ok_or_else(|| anyhow!("line {lineno}: stray endartifact"))?;
                    m.artifacts.insert(a.name.clone(), a);
                }
                other => bail!("line {lineno}: unknown tag {other}"),
            }
        }
        if cur.is_some() {
            bail!("unterminated artifact block");
        }
        Ok(m)
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).ok_or_else(|| anyhow!("unknown artifact {name}"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelCfg> {
        self.models.get(name).ok_or_else(|| anyhow!("unknown model {name}"))
    }

    pub fn prec(&self, name: &str) -> Result<&PrecCfg> {
        self.precs.get(name).ok_or_else(|| anyhow!("unknown precision {name}"))
    }

    pub fn hlo_path(&self, artifact: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(artifact)?.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# silq artifact manifest v1
model tiny vocab=256 d_model=128 n_layers=4 n_heads=4 d_ff=256 seq_len=64 train_batch=16 fwd_batch=32 use_pallas=0
prec fp16 quantized=0 act_bits=8 act_dynamic=1 cache_bits=8 weight_bits=4 head_bits=8 query_bits=16 online_rot=0
artifact tiny_fp16_fwd file=tiny_fp16_fwd.hlo.txt model=tiny prec=fp16 mode=fwd
in params.embed f32 256x128
in tokens i32 32x64
out logits f32 32x64x256
endartifact
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.models["tiny"].d_model, 128);
        assert!(!m.precs["fp16"].quantized);
        let a = m.artifact("tiny_fp16_fwd").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.outputs[0].dims, vec![32, 64, 256]);
        assert_eq!(a.param_names(), vec!["embed"]);
    }

    #[test]
    fn scalar_dims() {
        assert_eq!(parse_dims("scalar").unwrap(), Vec::<usize>::new());
        assert_eq!(parse_dims("2x3").unwrap(), vec![2, 3]);
        assert!(parse_dims("2xq").is_err());
    }

    #[test]
    fn io_indexing() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        let a = m.artifact("tiny_fp16_fwd").unwrap();
        assert_eq!(a.input_index("tokens").unwrap(), 1);
        assert_eq!(a.output_index("logits").unwrap(), 0);
        assert!(a.input_index("nope").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("bogus line", PathBuf::new()).is_err());
        assert!(Manifest::parse("in x f32 2", PathBuf::new()).is_err());
    }

    #[test]
    fn prec_lines_validate_against_the_policy_layer() {
        // cache bits past the INT8 slab envelope must fail at parse time
        let bad = "prec weird quantized=1 act_bits=8 act_dynamic=1 cache_bits=32 \
                   weight_bits=4 head_bits=8 query_bits=16 online_rot=0";
        assert!(Manifest::parse(bad, PathBuf::new()).is_err());
        // the sample's fp16 precision lifts into a policy and lowers back
        // without loss
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        let pc = &m.precs["fp16"];
        let back = pc.policy().unwrap().to_prec(&pc.name).unwrap();
        assert_eq!(format!("{pc:?}"), format!("{back:?}"));
    }

    #[test]
    fn real_manifest_loads_if_built() {
        if let Ok(m) = Manifest::load("artifacts") {
            assert!(m.artifacts.len() >= 10);
            let a = m.artifact("tiny_a8s-c8-w4_train").unwrap();
            // params/m/v symmetry
            let nparams = a.param_names().len();
            assert_eq!(a.outputs.len(), 3 * nparams + 4);
        }
    }
}
