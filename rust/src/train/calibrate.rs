//! QAT initialization (paper section 3.1): percentile calibration for
//! activation/cache/query step sizes from the calib artifact's statistics,
//! and convex-MSE (or LSQ-init) calibration for weight steps.

use anyhow::Result;
use std::collections::BTreeMap;

use crate::data::{Batcher, DataMix, World};
use crate::model::ParamStore;
use crate::policy::{CalibMethod, QuantPolicy};
use crate::quant::{self, qbounds};
use crate::runtime::{build_inputs, literal_i32, to_f32_vec, Engine};

/// Accumulated calibration statistics: per-site [L,4] quantile rows
/// (q99.91, q99.99, q99.995, max), per-channel maxima, Gram matrices.
#[derive(Clone, Debug, Default)]
pub struct CalibStats {
    pub tensors: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
    pub batches: usize,
}

impl CalibStats {
    pub fn get(&self, name: &str) -> &(Vec<usize>, Vec<f32>) {
        self.tensors.get(name).unwrap_or_else(|| panic!("calib: no stat {name}"))
    }
}

/// Run the fp16 calib artifact over `n_batches` corpus batches and average.
/// (Quantiles/maxima are averaged across batches; Grams are summed, which is
/// exactly what GPTQ's Hessian accumulation wants.)
pub fn collect_stats(
    engine: &Engine,
    calib_artifact: &str,
    fp16: &ParamStore,
    world: &World,
    n_batches: usize,
    seed: u64,
) -> Result<CalibStats> {
    let m = engine.module(calib_artifact)?;
    let mc = engine.manifest.model(&m.spec.model)?.clone();
    let tok_spec = m.spec.inputs[m.spec.input_index("tokens")?].clone();
    let mut batcher = Batcher::new(world, DataMix::Corpus, mc.fwd_batch, mc.seq_len, seed ^ 0xCA11B);

    let mut stats = CalibStats::default();
    for _ in 0..n_batches.max(1) {
        let tokens = batcher.next_batch();
        let inputs =
            build_inputs(&m.spec, fp16, &[("tokens", literal_i32(&tok_spec.dims, &tokens)?)])?;
        let out = m.run(&inputs)?;
        for (o, spec) in out.iter().zip(&m.spec.outputs) {
            if spec.name == "logits" {
                continue;
            }
            let data = to_f32_vec(o)?;
            let e = stats
                .tensors
                .entry(spec.name.clone())
                .or_insert_with(|| (spec.dims.clone(), vec![0.0; data.len()]));
            let sum_not_avg = spec.name.starts_with("gram_");
            for (acc, x) in e.1.iter_mut().zip(&data) {
                if sum_not_avg {
                    *acc += x;
                } else {
                    *acc += x / n_batches.max(1) as f32;
                }
            }
        }
    }
    stats.batches = n_batches.max(1);
    Ok(stats)
}

/// Column index into the [.., 4] quantile rows for a precision, per the
/// paper's rule (99.91 / 99.99 / 99.995 for 4/8/16-bit); 3 = max.
pub fn quantile_col(bits: u32, use_max: bool) -> usize {
    if use_max {
        return 3;
    }
    match bits {
        b if b <= 4 => 0,
        b if b <= 8 => 1,
        _ => 2,
    }
}

/// Set the static activation/cache/query steps of a quantized store from
/// calib statistics, per the policy's activation-side calibration
/// (`Quantile` = paper percentile rule, `Max` = ablation). No-op entries
/// are skipped for dynamic configs (they have no `sa_*`/`sc_*` params).
pub fn calibrate_act_steps(
    qs: &mut ParamStore,
    policy: &QuantPolicy,
    stats: &CalibStats,
) -> Result<()> {
    let use_max = policy.acts.calib == CalibMethod::Max;
    let site_bits: [(&str, &str, u32); 8] = [
        ("sa_x1", "qs_x1", policy.acts.bits),
        ("sa_q", "qs_q", policy.query.bits),
        ("sc_k", "qs_k", policy.cache.bits),
        ("sc_v", "qs_v", policy.cache.bits),
        ("sa_o", "qs_o", policy.acts.bits),
        ("sa_x2", "qs_x2", policy.acts.bits),
        ("sa_d", "qs_d", policy.acts.bits),
        ("sa_head", "qs_head", policy.head.bits),
    ];
    for (param, stat, bits) in site_bits {
        if !qs.has(param) {
            continue;
        }
        let col = quantile_col(bits, use_max);
        let (_, qp) = qbounds(bits);
        let (dims, data) = stats.get(stat);
        let steps: Vec<f32> = if dims.len() == 2 {
            // [L, 4]
            (0..dims[0]).map(|l| (data[l * 4 + col] / qp as f32).max(quant::EPS)).collect()
        } else {
            vec![(data[col] / qp as f32).max(quant::EPS)]
        };
        let want_len = qs.get(param)?.len();
        anyhow::ensure!(steps.len() == want_len, "{param}: {} vs {}", steps.len(), want_len);
        qs.set(param, steps)?;
    }
    Ok(())
}

/// Set per-output-channel weight steps by the policy's weight-side
/// calibration: the paper's convex-MSE rule (`Mse`) or the LSQ-paper rule
/// (`Lsq`). Handles stacked [L, K, N] weights.
pub fn calibrate_weight_steps(qs: &mut ParamStore, policy: &QuantPolicy) -> Result<()> {
    let families: [(&str, &str, u32); 8] = [
        ("wq", "sw_q", policy.weights.bits),
        ("wk", "sw_k", policy.weights.bits),
        ("wv", "sw_v", policy.weights.bits),
        ("wo", "sw_o", policy.weights.bits),
        ("wg", "sw_g", policy.weights.bits),
        ("wu", "sw_u", policy.weights.bits),
        ("wd", "sw_d", policy.weights.bits),
        ("head", "sw_head", policy.head.bits),
    ];
    let per_channel = |slice: &[f32], n: usize, bits: u32| match policy.weights.calib {
        CalibMethod::Lsq => quant::calib::weight_step_lsq_per_channel(slice, n, bits),
        _ => quant::calib::weight_step_mse_per_channel(slice, n, bits),
    };
    for (wname, sname, bits) in families {
        if !qs.has(sname) {
            continue;
        }
        let wshape = qs.shape(wname)?.to_vec();
        let w = qs.get(wname)?.to_vec();
        let steps = if wshape.len() == 3 {
            let (l, k, n) = (wshape[0], wshape[1], wshape[2]);
            let mut all = Vec::with_capacity(l * n);
            for li in 0..l {
                all.extend(per_channel(&w[li * k * n..(li + 1) * k * n], n, bits));
            }
            all
        } else {
            per_channel(&w, wshape[1], bits)
        };
        qs.set(sname, steps)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantile_col_rule() {
        assert_eq!(quantile_col(4, false), 0);
        assert_eq!(quantile_col(8, false), 1);
        assert_eq!(quantile_col(16, false), 2);
        assert_eq!(quantile_col(8, true), 3);
    }
}
