//! QAT initialization (paper section 3.1): percentile calibration for
//! activation/cache/query step sizes from the calib artifact's statistics,
//! and convex-MSE (or LSQ-init) calibration for weight steps.

use anyhow::Result;
use std::collections::BTreeMap;

use crate::config::PrecCfg;
use crate::data::{Batcher, DataMix, World};
use crate::model::ParamStore;
use crate::quant::{self, qbounds};
use crate::runtime::{build_inputs, literal_i32, to_f32_vec, Engine};

/// Accumulated calibration statistics: per-site [L,4] quantile rows
/// (q99.91, q99.99, q99.995, max), per-channel maxima, Gram matrices.
#[derive(Clone, Debug, Default)]
pub struct CalibStats {
    pub tensors: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
    pub batches: usize,
}

impl CalibStats {
    pub fn get(&self, name: &str) -> &(Vec<usize>, Vec<f32>) {
        self.tensors.get(name).unwrap_or_else(|| panic!("calib: no stat {name}"))
    }
}

/// Run the fp16 calib artifact over `n_batches` corpus batches and average.
/// (Quantiles/maxima are averaged across batches; Grams are summed, which is
/// exactly what GPTQ's Hessian accumulation wants.)
pub fn collect_stats(
    engine: &Engine,
    calib_artifact: &str,
    fp16: &ParamStore,
    world: &World,
    n_batches: usize,
    seed: u64,
) -> Result<CalibStats> {
    let m = engine.module(calib_artifact)?;
    let mc = engine.manifest.model(&m.spec.model)?.clone();
    let tok_spec = m.spec.inputs[m.spec.input_index("tokens")?].clone();
    let mut batcher = Batcher::new(world, DataMix::Corpus, mc.fwd_batch, mc.seq_len, seed ^ 0xCA11B);

    let mut stats = CalibStats::default();
    for _ in 0..n_batches.max(1) {
        let tokens = batcher.next_batch();
        let inputs =
            build_inputs(&m.spec, fp16, &[("tokens", literal_i32(&tok_spec.dims, &tokens)?)])?;
        let out = m.run(&inputs)?;
        for (o, spec) in out.iter().zip(&m.spec.outputs) {
            if spec.name == "logits" {
                continue;
            }
            let data = to_f32_vec(o)?;
            let e = stats
                .tensors
                .entry(spec.name.clone())
                .or_insert_with(|| (spec.dims.clone(), vec![0.0; data.len()]));
            let sum_not_avg = spec.name.starts_with("gram_");
            for (acc, x) in e.1.iter_mut().zip(&data) {
                if sum_not_avg {
                    *acc += x;
                } else {
                    *acc += x / n_batches.max(1) as f32;
                }
            }
        }
    }
    stats.batches = n_batches.max(1);
    Ok(stats)
}

/// Column index into the [.., 4] quantile rows for a precision, per the
/// paper's rule (99.91 / 99.99 / 99.995 for 4/8/16-bit); 3 = max.
pub fn quantile_col(bits: u32, use_max: bool) -> usize {
    if use_max {
        return 3;
    }
    match bits {
        b if b <= 4 => 0,
        b if b <= 8 => 1,
        _ => 2,
    }
}

/// Set the static activation/cache/query steps of a quantized store from
/// calib statistics. No-op entries are skipped for dynamic configs (they
/// have no `sa_*`/`sc_*` params).
pub fn calibrate_act_steps(
    qs: &mut ParamStore,
    prec: &PrecCfg,
    stats: &CalibStats,
    use_max: bool,
) -> Result<()> {
    let site_bits: [(&str, &str, u32); 8] = [
        ("sa_x1", "qs_x1", prec.act_bits),
        ("sa_q", "qs_q", prec.query_bits),
        ("sc_k", "qs_k", prec.cache_bits),
        ("sc_v", "qs_v", prec.cache_bits),
        ("sa_o", "qs_o", prec.act_bits),
        ("sa_x2", "qs_x2", prec.act_bits),
        ("sa_d", "qs_d", prec.act_bits),
        ("sa_head", "qs_head", prec.head_bits),
    ];
    for (param, stat, bits) in site_bits {
        if !qs.has(param) {
            continue;
        }
        let col = quantile_col(bits, use_max);
        let (_, qp) = qbounds(bits);
        let (dims, data) = stats.get(stat);
        let steps: Vec<f32> = if dims.len() == 2 {
            // [L, 4]
            (0..dims[0]).map(|l| (data[l * 4 + col] / qp as f32).max(quant::EPS)).collect()
        } else {
            vec![(data[col] / qp as f32).max(quant::EPS)]
        };
        let want_len = qs.get(param)?.len();
        anyhow::ensure!(steps.len() == want_len, "{param}: {} vs {}", steps.len(), want_len);
        qs.set(param, steps)?;
    }
    Ok(())
}

/// Set per-output-channel weight steps by the paper's convex-MSE rule
/// (`mse`) or the LSQ-paper rule (`lsq`). Handles stacked [L, K, N] weights.
pub fn calibrate_weight_steps(qs: &mut ParamStore, prec: &PrecCfg, method: &str) -> Result<()> {
    let families: [(&str, &str, u32); 8] = [
        ("wq", "sw_q", prec.weight_bits),
        ("wk", "sw_k", prec.weight_bits),
        ("wv", "sw_v", prec.weight_bits),
        ("wo", "sw_o", prec.weight_bits),
        ("wg", "sw_g", prec.weight_bits),
        ("wu", "sw_u", prec.weight_bits),
        ("wd", "sw_d", prec.weight_bits),
        ("head", "sw_head", prec.head_bits),
    ];
    for (wname, sname, bits) in families {
        if !qs.has(sname) {
            continue;
        }
        let wshape = qs.shape(wname)?.to_vec();
        let w = qs.get(wname)?.to_vec();
        let steps = if wshape.len() == 3 {
            let (l, k, n) = (wshape[0], wshape[1], wshape[2]);
            let mut all = Vec::with_capacity(l * n);
            for li in 0..l {
                let slice = &w[li * k * n..(li + 1) * k * n];
                let s = match method {
                    "lsq" => quant::calib::weight_step_lsq_per_channel(slice, n, bits),
                    _ => quant::calib::weight_step_mse_per_channel(slice, n, bits),
                };
                all.extend(s);
            }
            all
        } else {
            let n = wshape[1];
            match method {
                "lsq" => quant::calib::weight_step_lsq_per_channel(&w, n, bits),
                _ => quant::calib::weight_step_mse_per_channel(&w, n, bits),
            }
        };
        qs.set(sname, steps)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantile_col_rule() {
        assert_eq!(quantile_col(4, false), 0);
        assert_eq!(quantile_col(8, false), 1);
        assert_eq!(quantile_col(16, false), 2);
        assert_eq!(quantile_col(8, true), 3);
    }
}
