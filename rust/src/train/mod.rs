//! The SiLQ training pipeline (paper section 3.1): pretrain / SFT at fp16,
//! then QAT with calibrated LSQ quantizers and knowledge distillation.

pub mod calibrate;
pub mod llm_qat;

use anyhow::{Context, Result};
use std::sync::Arc;

use crate::config::{ModelCfg, TrainCfg};
use crate::data::{Batcher, DataMix, World};
use crate::data::vocab::PAD;
use crate::metrics::{RunLog, Table};
use crate::model::ParamStore;
use crate::obs;
use crate::runtime::{literal_f32, literal_i32, literal_scalar, to_f32_scalar, to_f32_vec, Engine, Module};
use crate::util::{Rng, Timer};

/// Optimizer state threaded through the train artifact.
pub struct OptState {
    pub m: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
}

impl OptState {
    pub fn zeros_like(p: &ParamStore) -> OptState {
        OptState {
            m: p.values.iter().map(|v| vec![0.0; v.len()]).collect(),
            v: p.values.iter().map(|v| vec![0.0; v.len()]).collect(),
        }
    }
}

/// Everything one training run needs.
pub struct Trainer<'e> {
    pub engine: &'e Engine,
    pub train_mod: Arc<Module>,
    /// fp16 fwd module used as the KD teacher (None -> NTP-only training)
    pub teacher: Option<(Arc<Module>, ParamStore)>,
    pub mc: ModelCfg,
    pub cfg: TrainCfg,
}

/// Timing breakdown of one run (feeds EXPERIMENTS.md §Perf).
#[derive(Clone, Debug, Default)]
pub struct TrainStats {
    pub steps: usize,
    pub total_secs: f64,
    pub exec_secs: f64,
    pub teacher_secs: f64,
    pub data_secs: f64,
    pub host_secs: f64,
    pub final_loss: f32,
}

impl TrainStats {
    pub fn steps_per_sec(&self) -> f64 {
        self.steps as f64 / self.total_secs.max(1e-9)
    }

    /// Phase attribution of the run as a fixed-width table: data batching,
    /// teacher forwards, host marshalling, artifact execution, and the
    /// unattributed remainder.
    pub fn breakdown(&self) -> String {
        let wall = self.total_secs.max(1e-9);
        let other =
            (self.total_secs - self.data_secs - self.teacher_secs - self.host_secs - self.exec_secs)
                .max(0.0);
        let mut t = Table::new(&["phase", "secs", "% wall"]);
        let mut row = |name: &str, s: f64| {
            t.row(&[name.into(), format!("{s:.3}"), format!("{:.1}", 100.0 * s / wall)]);
        };
        row("data", self.data_secs);
        row("teacher", self.teacher_secs);
        row("host marshal", self.host_secs);
        row("exec", self.exec_secs);
        row("other", other);
        row("total", self.total_secs);
        t.render()
    }
}

impl<'e> Trainer<'e> {
    pub fn new(
        engine: &'e Engine,
        train_artifact: &str,
        teacher: Option<(&str, ParamStore)>,
        cfg: TrainCfg,
    ) -> Result<Self> {
        let train_mod = engine.module(train_artifact)?;
        let mc = engine.manifest.model(&train_mod.spec.model)?.clone();
        let teacher = match teacher {
            Some((art, params)) => Some((engine.module(art)?, params)),
            None => None,
        };
        Ok(Trainer { engine, train_mod, teacher, mc, cfg })
    }

    /// Teacher forward on a train-shaped token batch. The fwd artifact has a
    /// larger batch (fwd_batch >= train_batch); rows are padded and the
    /// first train_batch rows of logits sliced out.
    fn teacher_logits(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let (tm, tp) = self.teacher.as_ref().context("no teacher configured")?;
        let spec = &tm.spec;
        let tok_spec = &spec.inputs[spec.input_index("tokens")?];
        let (fb, s, v) = (self.mc.fwd_batch, self.mc.seq_len, self.mc.vocab);
        let mut padded = vec![PAD; fb * s];
        padded[..tokens.len()].copy_from_slice(tokens);
        let inputs = crate::runtime::build_inputs(
            spec,
            tp,
            &[("tokens", literal_i32(&tok_spec.dims, &padded)?)],
        )?;
        let out = tm.run(&inputs)?;
        let logits = to_f32_vec(&out[0])?;
        Ok(logits[..self.mc.train_batch * s * v].to_vec())
    }

    /// Run `cfg.steps` of training, mutating `params` in place.
    /// `eval_hook(step, params)` fires every `cfg.eval_every` steps.
    pub fn run(
        &self,
        params: &mut ParamStore,
        world: &World,
        mix: DataMix,
        log: &mut RunLog,
        mut eval_hook: Option<&mut dyn FnMut(usize, &ParamStore)>,
    ) -> Result<TrainStats> {
        let spec = self.train_mod.spec.clone();
        let names = spec.param_names();
        let n = names.len();
        anyhow::ensure!(names == params.names, "param order mismatch");

        let mut opt = OptState::zeros_like(params);
        let mut batcher = Batcher::new(
            world,
            mix,
            self.mc.train_batch,
            self.mc.seq_len,
            self.cfg.seed ^ 0xDA7A,
        );
        let mut stats = TrainStats::default();
        let total_t = Timer::start();

        let tok_idx = spec.input_index("tokens")?;
        let tl_idx = spec.input_index("teacher_logits")?;
        let (tb, s, v) = (self.mc.train_batch, self.mc.seq_len, self.mc.vocab);

        for step in 0..self.cfg.steps {
            let _step_span = obs::span("train_step", "train", 0, step as u64);
            let step_t = Timer::start();
            let dt = Timer::start();
            let tokens = batcher.next_batch();
            stats.data_secs += dt.secs();

            let tt = Timer::start();
            let teacher_logits = if self.teacher.is_some() && self.cfg.kd_ratio > 0.0 {
                self.teacher_logits(&tokens)?
            } else {
                vec![0.0; tb * s * v]
            };
            stats.teacher_secs += tt.secs();

            let ht = Timer::start();
            let mut inputs = Vec::with_capacity(spec.inputs.len());
            for (i, t) in spec.inputs.iter().enumerate() {
                if i < n {
                    inputs.push(literal_f32(&t.dims, &params.values[i])?);
                } else if i < 2 * n {
                    inputs.push(literal_f32(&t.dims, &opt.m[i - n])?);
                } else if i < 3 * n {
                    inputs.push(literal_f32(&t.dims, &opt.v[i - 2 * n])?);
                } else if i == tok_idx {
                    inputs.push(literal_i32(&t.dims, &tokens)?);
                } else if i == tl_idx {
                    inputs.push(literal_f32(&t.dims, &teacher_logits)?);
                } else {
                    let val = match t.name.as_str() {
                        "lr" => self.cfg.lr_at(step),
                        "act_lrx" => self.cfg.act_lrx,
                        "kd_ratio" => if self.teacher.is_some() { self.cfg.kd_ratio } else { 0.0 },
                        "kd_temp" => self.cfg.kd_temp,
                        "wd" => self.cfg.weight_decay,
                        "step" => (step + 1) as f32,
                        other => anyhow::bail!("unknown scalar input {other}"),
                    };
                    inputs.push(literal_scalar(val));
                }
            }
            stats.host_secs += ht.secs();

            let et = Timer::start();
            let out = self.train_mod.run(&inputs)?;
            stats.exec_secs += et.secs();

            let ht2 = Timer::start();
            for i in 0..n {
                params.values[i] = to_f32_vec(&out[i])?;
                opt.m[i] = to_f32_vec(&out[n + i])?;
                opt.v[i] = to_f32_vec(&out[2 * n + i])?;
            }
            let loss = to_f32_scalar(&out[spec.output_index("loss")?])?;
            let gnorm = to_f32_scalar(&out[spec.output_index("gnorm")?])?;
            stats.host_secs += ht2.secs();
            anyhow::ensure!(loss.is_finite(), "loss diverged at step {step}");
            obs::add(obs::Counter::QatSteps, 1);
            log.step(
                step,
                loss,
                &format!(
                    "gnorm {gnorm:.4} lr {:.2e} step_ms {:.1}",
                    self.cfg.lr_at(step),
                    step_t.millis()
                ),
            );

            if let Some(hook) = eval_hook.as_deref_mut() {
                if self.cfg.eval_every > 0 && (step + 1) % self.cfg.eval_every == 0 {
                    hook(step + 1, params);
                }
            }
            stats.final_loss = loss;
        }
        stats.steps = self.cfg.steps;
        stats.total_secs = total_t.secs();
        log.note(&format!("phase breakdown:\n{}", stats.breakdown()));
        Ok(stats)
    }
}

/// Initialize a fresh fp16 model for pretraining.
pub fn init_model(engine: &Engine, fwd_artifact: &str, seed: u64) -> Result<ParamStore> {
    let m = engine.module(fwd_artifact)?;
    let mc = engine.manifest.model(&m.spec.model)?.clone();
    let mut rng = Rng::new(seed);
    Ok(ParamStore::init(&m.spec, &mc, &mut rng))
}

/// Build a quantized-model store from fp16 weights: shared tensors copied,
/// quantizer steps left for calibration.
pub fn quantize_store(engine: &Engine, quant_artifact: &str, fp16: &ParamStore) -> Result<ParamStore> {
    let m = engine.module(quant_artifact)?;
    let mut qs = ParamStore::from_spec(&m.spec);
    // steps get a safe placeholder before calibration
    for i in 0..qs.names.len() {
        if qs.names[i].starts_with("sw_") || qs.names[i].starts_with("sa_") || qs.names[i].starts_with("sc_") {
            qs.values[i] = vec![0.05; qs.values[i].len()];
        }
    }
    qs.copy_common_from(fp16);
    Ok(qs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optstate_shapes() {
        use crate::config::TensorSpec;
        let spec = crate::config::ArtifactSpec {
            name: "t".into(), file: "f".into(), model: "m".into(), prec: "p".into(),
            mode: "train".into(),
            inputs: vec![TensorSpec { name: "params.a".into(), dtype: "f32".into(), dims: vec![3] }],
            outputs: vec![],
        };
        let p = ParamStore::from_spec(&spec);
        let o = OptState::zeros_like(&p);
        assert_eq!(o.m[0].len(), 3);
        assert_eq!(o.v.len(), 1);
    }

    #[test]
    fn stats_steps_per_sec() {
        let s = TrainStats { steps: 10, total_secs: 2.0, ..Default::default() };
        assert!((s.steps_per_sec() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn stats_breakdown_attributes_phases() {
        let s = TrainStats {
            steps: 4,
            total_secs: 2.0,
            exec_secs: 1.0,
            teacher_secs: 0.4,
            data_secs: 0.1,
            host_secs: 0.2,
            final_loss: 1.0,
        };
        let b = s.breakdown();
        for phase in ["data", "teacher", "host marshal", "exec", "other", "total"] {
            assert!(b.contains(phase), "breakdown missing {phase}:\n{b}");
        }
        assert!(b.contains("50.0"), "exec should be half the wall:\n{b}");
        assert!(!b.contains("NaN"));
    }
}
