//! LLM-QAT baseline (Liu et al., 2023; paper Table 2): QAT on data
//! *self-generated* from the fp16 model instead of an external corpus.
//!
//! Faithful to the original recipe at our scale: the first tokens after BOS
//! are decoded greedily (top-1), the rest sampled from the full softmax —
//! LLM-QAT's "hybrid" sampling — and generation cost is what makes the
//! method slow, which is exactly the axis Table 2 compares.
//!
//! Generation is generic over [`ForwardBackend`]: on the artifact backend
//! each step recomputes the full sequence through the stateless graph; on
//! the host backend the shared incremental decode driver does one token of
//! work per step over the KV pool, with no artifacts needed at all.

use anyhow::Result;

use crate::data::vocab::BOS;
use crate::evalharness::decode::argmax;
use crate::forward::{decode_with, ForwardBackend};
use crate::util::{Rng, Timer};

/// Generate `n_samples` documents of `gen_len` tokens from the model bound
/// to `backend`. Returns (documents, wall_seconds).
pub fn self_generate<B: ForwardBackend + ?Sized>(
    backend: &mut B,
    n_samples: usize,
    gen_len: usize,
    greedy_prefix: usize,
    temperature: f32,
    seed: u64,
) -> Result<(Vec<Vec<i32>>, f64)> {
    let (fb, s) = (backend.batch(), backend.seq_len());
    let gen_len = gen_len.min(s - 1);
    let mut rng = Rng::new(seed ^ 0x11AA);
    let t = Timer::start();

    let bos = [BOS];
    let mut docs: Vec<Vec<i32>> = vec![];
    let mut remaining = n_samples;
    while remaining > 0 {
        let bsz = remaining.min(fb);
        let prompts: Vec<&[i32]> = vec![&bos[..]; bsz];
        let rows = decode_with(backend, &prompts, gen_len, |_, step, lg| {
            if step < greedy_prefix {
                argmax(lg) as i32
            } else {
                sample(lg, temperature, &mut rng) as i32
            }
        })?;
        docs.extend(rows.into_iter().map(|gen| {
            let mut doc = vec![BOS];
            doc.extend(gen);
            doc
        }));
        remaining -= bsz;
    }
    Ok((docs, t.secs()))
}

fn sample(logits: &[f32], temperature: f32, rng: &mut Rng) -> usize {
    let t = temperature.max(1e-3);
    let m = logits.iter().fold(f32::MIN, |a, &b| a.max(b));
    let ps: Vec<f32> = logits.iter().map(|&x| ((x - m) / t).exp()).collect();
    rng.weighted(&ps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forward::HostForward;
    use crate::hostmodel::{host_test_params, tiny_host_cfg, CacheStore};

    #[test]
    fn sample_prefers_high_logits() {
        let mut rng = Rng::new(0);
        let logits = [0.0f32, 5.0, 0.0, 0.0];
        let mut hits = 0;
        for _ in 0..200 {
            if sample(&logits, 1.0, &mut rng) == 1 {
                hits += 1;
            }
        }
        assert!(hits > 150);
    }

    #[test]
    fn sample_temperature_flattens() {
        let mut rng = Rng::new(1);
        let logits = [0.0f32, 3.0];
        let hot: usize = (0..500).filter(|_| sample(&logits, 0.1, &mut rng) == 1).count();
        let cold: usize = (0..500).filter(|_| sample(&logits, 10.0, &mut rng) == 1).count();
        assert!(hot > cold);
    }

    #[test]
    fn self_generate_runs_artifact_free_on_the_host_backend() {
        let cfg = tiny_host_cfg(true, true);
        let params = host_test_params(&cfg, 27);
        let mut fwd = HostForward::new(cfg, 4, &params, CacheStore::Int8).unwrap();
        let (docs, secs) = self_generate(&mut fwd, 6, 5, 2, 1.0, 0).unwrap();
        assert_eq!(docs.len(), 6);
        assert!(secs >= 0.0);
        for d in &docs {
            assert_eq!(d[0], BOS);
            assert_eq!(d.len(), 6); // BOS + gen_len
        }
        // hybrid sampling: greedy prefix must be deterministic across docs
        // in the same batch (same BOS prompt, same model)
        assert_eq!(docs[0][1..3], docs[1][1..3]);
    }
}
