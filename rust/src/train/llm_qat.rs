//! LLM-QAT baseline (Liu et al., 2023; paper Table 2): QAT on data
//! *self-generated* from the fp16 model instead of an external corpus.
//!
//! Faithful to the original recipe at our scale: the first tokens after BOS
//! are decoded greedily (top-1), the rest sampled from the full softmax —
//! LLM-QAT's "hybrid" sampling — and generation cost is what makes the
//! method slow, which is exactly the axis Table 2 compares.

use anyhow::Result;

use crate::data::vocab::{BOS, PAD};
use crate::evalharness::decode::argmax;
use crate::model::ParamStore;
use crate::runtime::{build_inputs, literal_i32, to_f32_vec, Engine};
use crate::util::{Rng, Timer};

/// Generate `n_samples` documents of `gen_len` tokens from the model.
/// Returns (documents, wall_seconds).
pub fn self_generate(
    engine: &Engine,
    fwd_artifact: &str,
    fp16: &ParamStore,
    n_samples: usize,
    gen_len: usize,
    greedy_prefix: usize,
    temperature: f32,
    seed: u64,
) -> Result<(Vec<Vec<i32>>, f64)> {
    let m = engine.module(fwd_artifact)?;
    let mc = engine.manifest.model(&m.spec.model)?.clone();
    let tok_spec = m.spec.inputs[m.spec.input_index("tokens")?].clone();
    let (fb, s, v) = (mc.fwd_batch, mc.seq_len, mc.vocab);
    let gen_len = gen_len.min(s - 1);
    let mut rng = Rng::new(seed ^ 0x11AA);
    let t = Timer::start();

    let mut docs: Vec<Vec<i32>> = vec![];
    let mut remaining = n_samples;
    while remaining > 0 {
        let bsz = remaining.min(fb);
        let mut rows: Vec<Vec<i32>> = vec![vec![BOS]; bsz];
        for step in 0..gen_len {
            let mut tokens = vec![PAD; fb * s];
            for (r, row) in rows.iter().enumerate() {
                tokens[r * s..r * s + row.len()].copy_from_slice(row);
            }
            let inputs = build_inputs(
                &m.spec,
                fp16,
                &[("tokens", literal_i32(&tok_spec.dims, &tokens)?)],
            )?;
            let out = m.run(&inputs)?;
            let logits = to_f32_vec(&out[0])?;
            for (r, row) in rows.iter_mut().enumerate() {
                let base = (r * s + row.len() - 1) * v;
                let lg = &logits[base..base + v];
                let next = if step < greedy_prefix {
                    argmax(lg) as i32
                } else {
                    sample(lg, temperature, &mut rng) as i32
                };
                row.push(next);
            }
        }
        docs.extend(rows);
        remaining -= bsz;
    }
    Ok((docs, t.secs()))
}

fn sample(logits: &[f32], temperature: f32, rng: &mut Rng) -> usize {
    let t = temperature.max(1e-3);
    let m = logits.iter().fold(f32::MIN, |a, &b| a.max(b));
    let ps: Vec<f32> = logits.iter().map(|&x| ((x - m) / t).exp()).collect();
    rng.weighted(&ps)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_prefers_high_logits() {
        let mut rng = Rng::new(0);
        let logits = [0.0f32, 5.0, 0.0, 0.0];
        let mut hits = 0;
        for _ in 0..200 {
            if sample(&logits, 1.0, &mut rng) == 1 {
                hits += 1;
            }
        }
        assert!(hits > 150);
    }

    #[test]
    fn sample_temperature_flattens() {
        let mut rng = Rng::new(1);
        let logits = [0.0f32, 3.0];
        let hot: usize = (0..500).filter(|_| sample(&logits, 0.1, &mut rng) == 1).count();
        let cold: usize = (0..500).filter(|_| sample(&logits, 10.0, &mut rng) == 1).count();
        assert!(hot > cold);
    }
}
