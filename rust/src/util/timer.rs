//! Wall-clock phase timing for the metrics/EXPERIMENTS reports.

use std::time::Instant;

pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// One `bench_ms` measurement: per-iteration minimum and mean wall ms.
///
/// The min is the noise-robust statistic — scheduler preemptions and
/// cache-cold iterations only ever push a sample *up*, so the fastest
/// iteration is the best estimate of the code's intrinsic cost and is
/// what the `BENCH_*.json` speedup ratios use. The mean rides along for
/// console reports where jitter context is useful.
#[derive(Clone, Copy, Debug)]
pub struct BenchMs {
    pub min_ms: f64,
    pub mean_ms: f64,
}

/// Measure `f` over `iters` timed runs after `warmup` untimed ones,
/// timing each iteration separately (min and mean, see [`BenchMs`]).
pub fn bench_ms<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> BenchMs {
    for _ in 0..warmup {
        f();
    }
    let iters = iters.max(1);
    let mut min = f64::INFINITY;
    let mut sum = 0.0;
    for _ in 0..iters {
        let t = Timer::start();
        f();
        let ms = t.millis();
        min = min.min(ms);
        sum += ms;
    }
    BenchMs { min_ms: min, mean_ms: sum / iters as f64 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.millis() >= 4.0);
    }

    #[test]
    fn bench_runs() {
        let mut n = 0;
        let b = bench_ms(1, 3, || n += 1);
        assert_eq!(n, 4);
        assert!(b.min_ms <= b.mean_ms, "the min iteration cannot exceed the mean");
        assert!(b.min_ms >= 0.0 && b.mean_ms.is_finite());
    }
}
