//! Wall-clock phase timing for the metrics/EXPERIMENTS reports.

use std::time::Instant;

pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Measure the average milliseconds of `f` over `iters` runs after `warmup`.
pub fn bench_ms<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let t = Timer::start();
    for _ in 0..iters {
        f();
    }
    t.millis() / iters as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.millis() >= 4.0);
    }

    #[test]
    fn bench_runs() {
        let mut n = 0;
        let _ = bench_ms(1, 3, || n += 1);
        assert_eq!(n, 4);
    }
}
