//! Small shared utilities: deterministic PRNG, timers, and text helpers.
//!
//! The `rand`/`proptest` crates are unavailable in this offline environment,
//! so the repository carries its own SplitMix64/xoshiro-style generator; the
//! property tests in `rust/tests/proptests.rs` drive it.

pub mod rng;
pub mod timer;

pub use rng::Rng;
pub use timer::Timer;

/// Parse `key=value` tokens out of a whitespace-separated line.
pub fn kv_pairs(line: &str) -> Vec<(String, String)> {
    line.split_whitespace()
        .filter_map(|tok| tok.split_once('=').map(|(k, v)| (k.to_string(), v.to_string())))
        .collect()
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f32>() / xs.len() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_pairs_parses() {
        let kv = kv_pairs("model tiny vocab=256 d_model=128");
        assert_eq!(kv.len(), 2);
        assert_eq!(kv[0], ("vocab".into(), "256".into()));
    }

    #[test]
    fn kv_pairs_empty() {
        assert!(kv_pairs("no pairs here").is_empty());
    }

    #[test]
    fn mean_works() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }
}
