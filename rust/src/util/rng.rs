//! Deterministic SplitMix64-seeded xoshiro256** PRNG.
//!
//! Every stochastic component in the coordinator (data generation, parameter
//! init, shuffling, LLM-QAT sampling) takes an explicit `Rng`, making every
//! experiment bit-reproducible from its seed.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller sample
    spare: Option<f32>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)], spare: None }
    }

    /// Derive an independent stream (for parallel/persistent substreams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        let (mut u1, u2) = (self.uniform(), self.uniform());
        if u1 < 1e-12 {
            u1 = 1e-12;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f32::consts::PI * u2;
        self.spare = Some(r * th.sin());
        r * th.cos()
    }

    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Pick a random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, w: &[f32]) -> usize {
        let total: f32 = w.iter().sum();
        let mut t = self.uniform() * total;
        for (i, &wi) in w.iter().enumerate() {
            t -= wi;
            if t <= 0.0 {
                return i;
            }
        }
        w.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(3);
        let m: f32 = (0..50_000).map(|_| r.uniform()).sum::<f32>() / 50_000.0;
        assert!((m - 0.5).abs() < 0.01, "{m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f32> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.below(3) < 3);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(13);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac = counts[2] as f32 / 30_000.0;
        assert!((frac - 0.7).abs() < 0.03, "{frac}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(1);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
