//! `hostmodel` — the host quantized transformer.
//!
//! SiLQ's pitch is that quantization adds *no new operations* to the model,
//! so the repo keeps exactly one artifact-free quantized forward and every
//! workload (eval scoring, greedy generation, LLM-QAT self-generation,
//! `silq serve`) runs on top of it. [`HostModel`] holds the folded weights
//! (per-output-channel fake quant applied once at construction), the
//! learned static activation steps, and the RoPE tables, and exposes two
//! forwards that are bit-identical where they overlap:
//!
//! * [`HostModel::forward_token`] — incremental per-token decode with the
//!   K/V cache resident in a [`KvPool`] (O(1) work per new token).
//! * [`HostModel::forward_seq`] — batched full-sequence forward returning
//!   logits at every position (continuation log-likelihood scoring).
//!
//! Both mirror `python/compile/model.py::forward` site for site (sans the
//! online-rotation ablation). `proptests.rs` pins the incremental ==
//! batched identity down; the serve integration suite pins INT8 == f32
//! cache storage.
//!
//! [`builtin_model`] / [`builtin_prec`] mirror `python/compile/configs.py`
//! so host-backend workloads run in a bare checkout, no manifest needed.

pub mod kvpool;

pub use kvpool::{CacheStore, KvPool, QuantRule};

use anyhow::{ensure, Context, Result};

use crate::config::{ArtifactSpec, ModelCfg, PrecCfg, TensorSpec};
use crate::model::ParamStore;
use crate::policy::{QuantMode, QuantPolicy};
use crate::quant::{dynamic_quant_rows, fake_quant, fake_quant_per_channel};

/// Model shape + typed precision policy of the host forward, decoupled
/// from the artifact manifest so tests, benches and `--backend host` runs
/// work without built artifacts. Every quantization decision in the host
/// stack (fold widths, activation quantizers, the KV pool's `QuantRule`)
/// derives from `policy`.
#[derive(Clone, Debug)]
pub struct HostCfg {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    /// the typed precision policy (see [`crate::policy`])
    pub policy: QuantPolicy,
    /// `rope_theta` from `python/compile/configs.py` (all current models
    /// use the default; the manifest does not carry it)
    pub rope_theta: f32,
}

impl HostCfg {
    /// Combine an architecture with a typed precision policy — the one
    /// constructor every host entry point funnels through.
    pub fn from_policy(mc: &ModelCfg, policy: &QuantPolicy) -> Result<HostCfg> {
        ensure!(
            !policy.online_rot,
            "host forward does not implement the online-rotation ablation"
        );
        policy.validate()?;
        Ok(HostCfg {
            vocab: mc.vocab,
            d_model: mc.d_model,
            n_layers: mc.n_layers,
            n_heads: mc.n_heads,
            d_ff: mc.d_ff,
            seq_len: mc.seq_len,
            policy: policy.clone(),
            rope_theta: 10000.0,
        })
    }

    /// Combine an architecture and a manifest precision placement (from
    /// the manifest, or from [`builtin_model`]/[`builtin_prec`]).
    pub fn from_cfgs(mc: &ModelCfg, pc: &PrecCfg) -> Result<HostCfg> {
        Self::from_policy(mc, &pc.policy()?)
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn quantized(&self) -> bool {
        self.policy.quantized
    }

    /// Whether the runtime-quantized slots use dynamic per-token steps.
    pub fn act_dynamic(&self) -> bool {
        self.policy.acts.mode == QuantMode::Dynamic
    }
}

/// Built-in mirror of `python/compile/configs.py::MODELS` — lets the host
/// backend describe a model with no artifact manifest on disk.
pub fn builtin_model(name: &str) -> Option<ModelCfg> {
    let mut mc = match name {
        "tiny" | "tiny-pallas" => ModelCfg {
            name: name.into(),
            vocab: 256,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            d_ff: 256,
            seq_len: 64,
            train_batch: 16,
            fwd_batch: 32,
            use_pallas: false,
        },
        "small" => ModelCfg {
            name: name.into(),
            vocab: 512,
            d_model: 256,
            n_layers: 8,
            n_heads: 8,
            d_ff: 512,
            seq_len: 128,
            train_batch: 8,
            fwd_batch: 16,
            use_pallas: false,
        },
        _ => return None,
    };
    if name == "tiny-pallas" {
        mc.n_layers = 2;
        mc.use_pallas = true;
    }
    Some(mc)
}

/// Built-in mirror of `python/compile/configs.py::PRECISIONS`, now a thin
/// veneer over the typed policy grammar: the legacy manifest names
/// (`a8d-c8-w4`, ...), the policy presets and inline spec strings all
/// resolve; anything else is `None`. The cache storage rule that used to
/// live here is [`CacheStore::for_policy`].
pub fn builtin_prec(name: &str) -> Option<PrecCfg> {
    let p = QuantPolicy::resolve(name).ok()?;
    p.to_prec(name).ok()
}

/// Build the `ArtifactSpec` a host-served model's `ParamStore` follows —
/// the same ordered contract as `python/compile/model.py::param_spec`.
pub fn host_param_spec(cfg: &HostCfg) -> ArtifactSpec {
    let (l, d, f, v) = (cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab);
    let mut inputs: Vec<(String, Vec<usize>)> = vec![
        ("embed".into(), vec![v, d]),
        ("ln1".into(), vec![l, d]),
        ("wq".into(), vec![l, d, d]),
        ("wk".into(), vec![l, d, d]),
        ("wv".into(), vec![l, d, d]),
        ("wo".into(), vec![l, d, d]),
        ("ln2".into(), vec![l, d]),
        ("wg".into(), vec![l, d, f]),
        ("wu".into(), vec![l, d, f]),
        ("wd".into(), vec![l, f, d]),
        ("ln_f".into(), vec![d]),
        ("head".into(), vec![d, v]),
    ];
    if cfg.quantized() {
        for (n, dims) in [
            ("sw_q", vec![l, d]),
            ("sw_k", vec![l, d]),
            ("sw_v", vec![l, d]),
            ("sw_o", vec![l, d]),
            ("sw_g", vec![l, f]),
            ("sw_u", vec![l, f]),
            ("sw_d", vec![l, d]),
            ("sw_head", vec![v]),
        ] {
            inputs.push((n.into(), dims));
        }
        if !cfg.act_dynamic() {
            for (n, dims) in [
                ("sa_x1", vec![l]),
                ("sa_q", vec![l]),
                ("sc_k", vec![l]),
                ("sc_v", vec![l]),
                ("sa_o", vec![l]),
                ("sa_x2", vec![l]),
                ("sa_d", vec![l]),
                ("sa_head", vec![]),
            ] {
                inputs.push((n.into(), dims));
            }
        }
    }
    ArtifactSpec {
        name: "host_fwd".into(),
        file: String::new(),
        model: "host".into(),
        prec: if cfg.quantized() { "quantized" } else { "fp16" }.into(),
        mode: "fwd".into(),
        inputs: inputs
            .into_iter()
            .map(|(n, dims)| TensorSpec { name: format!("params.{n}"), dtype: "f32".into(), dims })
            .collect(),
        outputs: vec![],
    }
}

/// Deterministic randomly-initialized parameters following
/// [`host_param_spec`] — the bootstrap the tests and benches share (an
/// untrained model generates noise, but latency/identity properties
/// don't care).
pub fn host_test_params(cfg: &HostCfg, seed: u64) -> ParamStore {
    let spec = host_param_spec(cfg);
    // ParamStore::init keys its rules off parameter names alone; the
    // ModelCfg is only part of the signature
    let mc = ModelCfg {
        name: "host".into(),
        vocab: cfg.vocab,
        d_model: cfg.d_model,
        n_layers: cfg.n_layers,
        n_heads: cfg.n_heads,
        d_ff: cfg.d_ff,
        seq_len: cfg.seq_len,
        train_batch: 1,
        fwd_batch: 1,
        use_pallas: false,
    };
    let mut rng = crate::util::Rng::new(seed);
    ParamStore::init(&spec, &mc, &mut rng)
}

/// Admission-time prompt validation shared by every host/artifact entry
/// point (serve admit, decode prefill, batched scoring).
pub fn check_tokens(prompt: &[i32], vocab: usize) -> Result<()> {
    for &t in prompt {
        ensure!(t >= 0 && (t as usize) < vocab, "prompt token {t} outside the vocab (0..{vocab})");
    }
    Ok(())
}

/// Static (learned-scalar) activation steps per layer, when `act_dynamic`
/// is off.
struct StaticSteps {
    sa_x1: Vec<f32>,
    sa_q: Vec<f32>,
    sa_o: Vec<f32>,
    sa_x2: Vec<f32>,
    sa_d: Vec<f32>,
    sa_head: f32,
}

/// Per-layer weights with weight quantization folded in at construction
/// (weights are static; per-output-channel fake quant is applied once).
struct LayerWeights {
    ln1: Vec<f32>,
    wq: Vec<f32>,
    wk: Vec<f32>,
    wv: Vec<f32>,
    wo: Vec<f32>,
    ln2: Vec<f32>,
    wg: Vec<f32>,
    wu: Vec<f32>,
    wd: Vec<f32>,
}

/// The host quantized transformer: folded weights + activation quantizers +
/// RoPE tables. Pure host math over a `ParamStore`; the K/V cache lives in
/// a caller-owned [`KvPool`] so one model instance can serve any number of
/// concurrent sessions.
pub struct HostModel {
    pub cfg: HostCfg,
    embed: Vec<f32>,
    layers: Vec<LayerWeights>,
    ln_f: Vec<f32>,
    head: Vec<f32>,
    sa: Option<StaticSteps>,
    rule: QuantRule,
    /// RoPE tables [seq, d_head/2]
    cos: Vec<f32>,
    sin: Vec<f32>,
}

impl HostModel {
    pub fn new(cfg: HostCfg, params: &ParamStore) -> Result<HostModel> {
        let (l, d, f, v) = (cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab);
        ensure!(d % cfg.n_heads == 0, "d_model must divide into heads");

        let slice = |name: &str, layer: usize, per: usize| -> Result<Vec<f32>> {
            let t = params.get(name)?;
            ensure!(t.len() == l * per, "{name}: expected {} values, got {}", l * per, t.len());
            Ok(t[layer * per..(layer + 1) * per].to_vec())
        };

        let mut layers = Vec::with_capacity(l);
        for li in 0..l {
            let mut w = LayerWeights {
                ln1: slice("ln1", li, d)?,
                wq: slice("wq", li, d * d)?,
                wk: slice("wk", li, d * d)?,
                wv: slice("wv", li, d * d)?,
                wo: slice("wo", li, d * d)?,
                ln2: slice("ln2", li, d)?,
                wg: slice("wg", li, d * f)?,
                wu: slice("wu", li, d * f)?,
                wd: slice("wd", li, f * d)?,
            };
            if cfg.quantized() {
                let wb = cfg.policy.weights.bits;
                fake_quant_per_channel(&mut w.wq, d, &slice("sw_q", li, d)?, wb);
                fake_quant_per_channel(&mut w.wk, d, &slice("sw_k", li, d)?, wb);
                fake_quant_per_channel(&mut w.wv, d, &slice("sw_v", li, d)?, wb);
                fake_quant_per_channel(&mut w.wo, d, &slice("sw_o", li, d)?, wb);
                fake_quant_per_channel(&mut w.wg, f, &slice("sw_g", li, f)?, wb);
                fake_quant_per_channel(&mut w.wu, f, &slice("sw_u", li, f)?, wb);
                fake_quant_per_channel(&mut w.wd, d, &slice("sw_d", li, d)?, wb);
            }
            layers.push(w);
        }

        let mut head = params.get("head")?.to_vec();
        if cfg.quantized() {
            fake_quant_per_channel(&mut head, v, params.get("sw_head")?, cfg.policy.head.bits);
        }

        let sa = if cfg.quantized() && !cfg.act_dynamic() {
            Some(StaticSteps {
                sa_x1: params.get("sa_x1")?.to_vec(),
                sa_q: params.get("sa_q")?.to_vec(),
                sa_o: params.get("sa_o")?.to_vec(),
                sa_x2: params.get("sa_x2")?.to_vec(),
                sa_d: params.get("sa_d")?.to_vec(),
                sa_head: params.get("sa_head")?[0],
            })
        } else {
            None
        };

        // cache quantization rule, derived from the policy's cache slot:
        // static steps come from the trained sc_k/sc_v scalars broadcast
        // across channels; dynamic recomputes per head row on write
        // (ste_dynamic_quantize's last-axis rule)
        let rule = if !cfg.quantized() {
            QuantRule::None
        } else {
            match cfg.policy.cache.mode {
                QuantMode::Dynamic => {
                    QuantRule::Dynamic { bits: cfg.policy.cache.bits, rows: cfg.n_heads }
                }
                QuantMode::Static => {
                    let bc = |name: &str| -> Result<Vec<f32>> {
                        let s = params.get(name)?;
                        ensure!(s.len() == l, "{name} must be one step per layer");
                        Ok(s.iter().flat_map(|&x| std::iter::repeat(x).take(d)).collect())
                    };
                    QuantRule::Static {
                        bits: cfg.policy.cache.bits,
                        k_steps: bc("sc_k")?,
                        v_steps: bc("sc_v")?,
                    }
                }
            }
        };

        // RoPE tables, as in model.py::rope_tables
        let dh = cfg.d_head();
        let half = dh / 2;
        let mut cos = Vec::with_capacity(cfg.seq_len * half);
        let mut sin = Vec::with_capacity(cfg.seq_len * half);
        for p in 0..cfg.seq_len {
            for i in 0..half {
                let inv = 1.0 / cfg.rope_theta.powf(2.0 * i as f32 / dh as f32);
                let ang = p as f32 * inv;
                cos.push(ang.cos());
                sin.push(ang.sin());
            }
        }

        Ok(HostModel {
            embed: params.get("embed")?.to_vec(),
            ln_f: params.get("ln_f")?.to_vec(),
            head,
            layers,
            sa,
            rule,
            cos,
            sin,
            cfg,
        })
    }

    /// A KV pool sized for this model with `slots` concurrent sessions,
    /// quantizing under this model's cache rule.
    pub fn make_pool(&self, slots: usize, store: CacheStore) -> Result<KvPool> {
        KvPool::new(
            slots,
            self.cfg.n_layers,
            self.cfg.seq_len,
            self.cfg.d_model,
            store,
            self.rule.clone(),
        )
        .context("building KV pool")
    }

    /// Quantize one activation vector at a site (mirrors `act_quant`):
    /// dynamic per-`rows` sub-row (`ste_dynamic_quantize`'s last-axis
    /// rule), or a static learned step, or identity.
    fn act_quant(&self, x: &mut [f32], bits: u32, static_step: Option<f32>, rows: usize) {
        if !self.cfg.quantized() {
            return;
        }
        match static_step {
            Some(s) => fake_quant(x, s, bits),
            None => dynamic_quant_rows(x, x.len() / rows, bits),
        }
    }

    /// Apply RoPE at `pos` to one position's q and k rows (head-major
    /// channel layout).
    fn rope(&self, pos: usize, q: &mut [f32], k: &mut [f32]) {
        let (h, dh) = (self.cfg.n_heads, self.cfg.d_head());
        let half = dh / 2;
        for head_i in 0..h {
            for i in 0..half {
                let (c, s) = (self.cos[pos * half + i], self.sin[pos * half + i]);
                for t in [&mut *q, &mut *k] {
                    let (a, b) = (t[head_i * dh + 2 * i], t[head_i * dh + 2 * i + 1]);
                    t[head_i * dh + 2 * i] = a * c - b * s;
                    t[head_i * dh + 2 * i + 1] = a * s + b * c;
                }
            }
        }
    }

    /// Causal attention for one query position over `pos + 1` cached K/V
    /// rows ([pos+1, d_model], head-major). Returns the context vector.
    fn attend(&self, q: &[f32], k_cache: &[f32], v_cache: &[f32], pos: usize) -> Vec<f32> {
        let (d, h, dh) = (self.cfg.d_model, self.cfg.n_heads, self.cfg.d_head());
        let scale = 1.0 / (dh as f32).sqrt();
        let mut ctx = vec![0f32; d];
        let mut scores = vec![0f32; pos + 1];
        for head_i in 0..h {
            let qh = &q[head_i * dh..(head_i + 1) * dh];
            for (j, sc) in scores.iter_mut().enumerate() {
                let kh = &k_cache[j * d + head_i * dh..j * d + (head_i + 1) * dh];
                *sc = qh.iter().zip(kh).map(|(a, b)| a * b).sum::<f32>() * scale;
            }
            softmax_inplace(&mut scores);
            let ch = &mut ctx[head_i * dh..(head_i + 1) * dh];
            for (j, &p_j) in scores.iter().enumerate() {
                let vh = &v_cache[j * d + head_i * dh..j * d + (head_i + 1) * dh];
                for (cv, &vv) in ch.iter_mut().zip(vh) {
                    *cv += p_j * vv;
                }
            }
        }
        ctx
    }

    /// Static activation steps of layer `li` (None at every site when the
    /// precision is dynamic or unquantized).
    fn steps(&self, li: usize) -> LayerSteps {
        match &self.sa {
            Some(s) => LayerSteps {
                sa_x1: Some(s.sa_x1[li]),
                sa_q: Some(s.sa_q[li]),
                sa_o: Some(s.sa_o[li]),
                sa_x2: Some(s.sa_x2[li]),
                sa_d: Some(s.sa_d[li]),
            },
            None => LayerSteps::default(),
        }
    }

    /// Run one token through the stack at position `pos` of session `slot`,
    /// reading and extending the K/V cache in `pool`; returns logits only
    /// when asked (prefill positions skip the head matmul).
    pub fn forward_token(
        &self,
        pool: &mut KvPool,
        slot: usize,
        tok: i32,
        pos: usize,
        want_logits: bool,
    ) -> Result<Option<Vec<f32>>> {
        let cfg = &self.cfg;
        let (d, f, h) = (cfg.d_model, cfg.d_ff, cfg.n_heads);
        ensure!(pos < cfg.seq_len, "position {pos} outside the context window");
        ensure!(tok >= 0 && (tok as usize) < cfg.vocab, "token {tok} outside the vocab");

        let mut x = self.embed[tok as usize * d..(tok as usize + 1) * d].to_vec();
        let mut k_cache = vec![0f32; (pos + 1) * d];
        let mut v_cache = vec![0f32; (pos + 1) * d];

        for li in 0..cfg.n_layers {
            let st = self.steps(li);
            let lw = &self.layers[li];
            let mut hnorm = rmsnorm(&x, &lw.ln1);
            self.act_quant(&mut hnorm, cfg.policy.acts.bits, st.sa_x1, 1);
            let mut q = matvec(&hnorm, &lw.wq, d);
            let mut k = matvec(&hnorm, &lw.wk, d);
            let v = matvec(&hnorm, &lw.wv, d);

            self.rope(pos, &mut q, &mut k);

            // INT16 query; K/V are quantized by the pool on write
            self.act_quant(&mut q, cfg.policy.query.bits, st.sa_q, h);
            pool.write(slot, li, pos, &k, &v);
            pool.read_into(slot, li, pos + 1, &mut k_cache, &mut v_cache)?;

            // causal attention over the cached prefix
            let mut ctx = self.attend(&q, &k_cache, &v_cache, pos);

            self.act_quant(&mut ctx, cfg.policy.acts.bits, st.sa_o, 1);
            let o = matvec(&ctx, &lw.wo, d);
            for (xv, ov) in x.iter_mut().zip(&o) {
                *xv += ov;
            }

            let mut h2 = rmsnorm(&x, &lw.ln2);
            self.act_quant(&mut h2, cfg.policy.acts.bits, st.sa_x2, 1);
            let g = matvec(&h2, &lw.wg, f);
            let u = matvec(&h2, &lw.wu, f);
            let mut a: Vec<f32> = g.iter().zip(&u).map(|(&gv, &uv)| silu(gv) * uv).collect();
            self.act_quant(&mut a, cfg.policy.acts.bits, st.sa_d, 1);
            let dn = matvec(&a, &lw.wd, d);
            for (xv, dv) in x.iter_mut().zip(&dn) {
                *xv += dv;
            }
        }

        if !want_logits {
            return Ok(None);
        }
        let mut hf = rmsnorm(&x, &self.ln_f);
        self.act_quant(&mut hf, cfg.policy.head.bits, self.sa.as_ref().map(|s| s.sa_head), 1);
        Ok(Some(matvec(&hf, &self.head, cfg.vocab)))
    }

    /// Batched full-sequence forward of one row: logits at **every**
    /// position, `[len * vocab]` row-major (rows longer than the context
    /// window are truncated, matching `pack_rows`). Independent math from
    /// [`HostModel::forward_token`] — whole-sequence attention with K/V
    /// fake-quantized through the shared [`QuantRule`] — and bit-identical
    /// to it position for position (the property test's subject).
    pub fn forward_seq(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        let (d, f, h, v) = (cfg.d_model, cfg.d_ff, cfg.n_heads, cfg.vocab);
        let n = tokens.len().min(cfg.seq_len);
        ensure!(n > 0, "empty sequence");
        check_tokens(&tokens[..n], v)?;

        let mut x = vec![0f32; n * d];
        for (p, &t) in tokens[..n].iter().enumerate() {
            x[p * d..(p + 1) * d].copy_from_slice(&self.embed[t as usize * d..(t as usize + 1) * d]);
        }

        for li in 0..cfg.n_layers {
            let st = self.steps(li);
            let lw = &self.layers[li];

            // attention inputs for every position (the "prefill" that the
            // incremental path amortizes across steps)
            let mut q_all = vec![0f32; n * d];
            let mut k_all = vec![0f32; n * d];
            let mut v_all = vec![0f32; n * d];
            for p in 0..n {
                let mut hnorm = rmsnorm(&x[p * d..(p + 1) * d], &lw.ln1);
                self.act_quant(&mut hnorm, cfg.policy.acts.bits, st.sa_x1, 1);
                let mut q = matvec(&hnorm, &lw.wq, d);
                let mut k = matvec(&hnorm, &lw.wk, d);
                let mut vv = matvec(&hnorm, &lw.wv, d);
                self.rope(p, &mut q, &mut k);
                self.act_quant(&mut q, cfg.policy.query.bits, st.sa_q, h);
                // cache quantization, same rule as the pool's write path
                self.rule.quantize_f32(li, &mut k, &mut vv);
                q_all[p * d..(p + 1) * d].copy_from_slice(&q);
                k_all[p * d..(p + 1) * d].copy_from_slice(&k);
                v_all[p * d..(p + 1) * d].copy_from_slice(&vv);
            }

            // causal attention + output projection per position (attention
            // reads only q/k/v, so updating x in place is safe)
            for p in 0..n {
                let mut ctx = self.attend(&q_all[p * d..(p + 1) * d], &k_all, &v_all, p);
                self.act_quant(&mut ctx, cfg.policy.acts.bits, st.sa_o, 1);
                let o = matvec(&ctx, &lw.wo, d);
                for (xv, ov) in x[p * d..(p + 1) * d].iter_mut().zip(&o) {
                    *xv += ov;
                }
            }

            // FFN per position
            for p in 0..n {
                let mut h2 = rmsnorm(&x[p * d..(p + 1) * d], &lw.ln2);
                self.act_quant(&mut h2, cfg.policy.acts.bits, st.sa_x2, 1);
                let g = matvec(&h2, &lw.wg, f);
                let u = matvec(&h2, &lw.wu, f);
                let mut a: Vec<f32> = g.iter().zip(&u).map(|(&gv, &uv)| silu(gv) * uv).collect();
                self.act_quant(&mut a, cfg.policy.acts.bits, st.sa_d, 1);
                let dn = matvec(&a, &lw.wd, d);
                for (xv, dv) in x[p * d..(p + 1) * d].iter_mut().zip(&dn) {
                    *xv += dv;
                }
            }
        }

        let mut logits = vec![0f32; n * v];
        for p in 0..n {
            let mut hf = rmsnorm(&x[p * d..(p + 1) * d], &self.ln_f);
            self.act_quant(&mut hf, cfg.policy.head.bits, self.sa.as_ref().map(|s| s.sa_head), 1);
            logits[p * v..(p + 1) * v].copy_from_slice(&matvec(&hf, &self.head, v));
        }
        Ok(logits)
    }
}

/// One layer's static activation steps, or all-None for dynamic precisions.
#[derive(Clone, Copy, Default)]
struct LayerSteps {
    sa_x1: Option<f32>,
    sa_q: Option<f32>,
    sa_o: Option<f32>,
    sa_x2: Option<f32>,
    sa_d: Option<f32>,
}

fn rmsnorm(x: &[f32], g: &[f32]) -> Vec<f32> {
    // model.py uses EPS=1e-6 inside rmsnorm (quant EPS is 1e-9)
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let r = 1.0 / (ms + 1e-6).sqrt();
    x.iter().zip(g).map(|(&v, &gv)| v * gv * r).collect()
}

/// `out[o] = sum_i x[i] * w[i * out_dim + o]` — the `x @ W` layout of the
/// row-major `[in, out]` weight matrices in the param contract.
fn matvec(x: &[f32], w: &[f32], out_dim: usize) -> Vec<f32> {
    debug_assert_eq!(x.len() * out_dim, w.len());
    let mut out = vec![0f32; out_dim];
    for (i, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let row = &w[i * out_dim..(i + 1) * out_dim];
        for (o, &wv) in out.iter_mut().zip(row) {
            *o += xv * wv;
        }
    }
    out
}

fn softmax_inplace(xs: &mut [f32]) {
    let m = xs.iter().fold(f32::MIN, |a, &b| a.max(b));
    let mut sum = 0f32;
    for v in xs.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    for v in xs.iter_mut() {
        *v /= sum;
    }
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Small host config the unit tests across modules share.
#[cfg(test)]
pub(crate) fn tiny_host_cfg(quantized: bool, act_dynamic: bool) -> HostCfg {
    let policy = match (quantized, act_dynamic) {
        (false, _) => QuantPolicy::fp16(),
        (true, true) => QuantPolicy::w4a8kv8(),
        (true, false) => QuantPolicy::w4a8kv8().with_static_acts(),
    };
    HostCfg {
        vocab: 256,
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        d_ff: 64,
        seq_len: 16,
        policy,
        rope_theta: 10000.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::evalharness::decode::argmax;

    #[test]
    fn host_spec_matches_python_param_spec() {
        let spec = host_param_spec(&tiny_host_cfg(true, false));
        let names = spec.param_names();
        assert_eq!(names.len(), 12 + 8 + 8);
        assert_eq!(names[0], "embed");
        assert!(names.contains(&"sc_k".to_string()));
        let spec_dyn = host_param_spec(&tiny_host_cfg(true, true));
        assert_eq!(spec_dyn.param_names().len(), 12 + 8);
    }

    #[test]
    fn builtin_cfgs_mirror_configs_py() {
        let tiny = builtin_model("tiny").unwrap();
        assert_eq!((tiny.d_model, tiny.n_layers, tiny.seq_len, tiny.fwd_batch), (128, 4, 64, 32));
        let tp = builtin_model("tiny-pallas").unwrap();
        assert!(tp.use_pallas);
        assert_eq!(tp.n_layers, 2);
        assert_eq!(builtin_model("small").unwrap().vocab, 512);
        assert!(builtin_model("huge").is_none());

        assert!(!builtin_prec("fp16").unwrap().quantized);
        assert!(!builtin_prec("a8s-c8-w4").unwrap().act_dynamic);
        assert_eq!(builtin_prec("a8d-c4-w4").unwrap().cache_bits, 4);
        assert!(builtin_prec("a8d-c8-w4-rot").unwrap().online_rot);
        assert!(builtin_prec("a8d-c8-w4").is_some());
        assert!(builtin_prec("int1").is_none());
        // the typed grammar means inline specs and presets resolve too
        let spec = builtin_prec("w4a8kv8").unwrap();
        assert!(spec.act_dynamic && spec.cache_bits == 8 && spec.weight_bits == 4);
        assert!(!builtin_prec("w4a8kv8:statacts").unwrap().act_dynamic);
        // the rotation ablation has no host forward
        let mc = builtin_model("tiny").unwrap();
        assert!(HostCfg::from_cfgs(&mc, &builtin_prec("a8d-c8-w4-rot").unwrap()).is_err());
    }

    #[test]
    fn incremental_and_seq_forwards_agree_exactly() {
        // the core identity forward_seq is built to satisfy; swept more
        // broadly by proptests.rs
        for (quantized, act_dynamic) in [(true, true), (true, false), (false, true)] {
            let cfg = tiny_host_cfg(quantized, act_dynamic);
            let params = host_test_params(&cfg, 41);
            let model = HostModel::new(cfg.clone(), &params).unwrap();
            let mut pool = model.make_pool(1, CacheStore::F32).unwrap();
            let slot = pool.alloc().unwrap();
            let prompt = [1i32, 7, 130, 22, 4];
            let batched = model.forward_seq(&prompt).unwrap();
            for (pos, &tok) in prompt.iter().enumerate() {
                let inc = model.forward_token(&mut pool, slot, tok, pos, true).unwrap().unwrap();
                assert_eq!(
                    &batched[pos * cfg.vocab..(pos + 1) * cfg.vocab],
                    &inc[..],
                    "quantized={quantized} act_dynamic={act_dynamic} pos={pos}"
                );
            }
        }
    }

    #[test]
    fn forward_seq_truncates_at_the_window() {
        let cfg = tiny_host_cfg(true, true);
        let params = host_test_params(&cfg, 5);
        let model = HostModel::new(cfg.clone(), &params).unwrap();
        let long: Vec<i32> = (0..cfg.seq_len as i32 + 4).map(|i| i % 200).collect();
        let logits = model.forward_seq(&long).unwrap();
        assert_eq!(logits.len(), cfg.seq_len * cfg.vocab);
        assert!(logits.iter().all(|l| l.is_finite()));
        assert!(model.forward_seq(&[]).is_err());
        assert!(model.forward_seq(&[9999]).is_err());
    }

    #[test]
    fn greedy_continuations_agree_between_paths() {
        let cfg = tiny_host_cfg(true, true);
        let params = host_test_params(&cfg, 9);
        let model = HostModel::new(cfg.clone(), &params).unwrap();
        let v = cfg.vocab;

        // batched: full recompute per emitted token
        let mut row_b = vec![1i32, 3, 22, 10];
        for _ in 0..4 {
            let lg = model.forward_seq(&row_b).unwrap();
            let last = &lg[(row_b.len() - 1) * v..row_b.len() * v];
            row_b.push(argmax(last) as i32);
        }

        // incremental: one token per step over the pool
        let mut pool = model.make_pool(1, CacheStore::F32).unwrap();
        let slot = pool.alloc().unwrap();
        let mut row_i = vec![1i32, 3, 22, 10];
        for (pos, &tok) in row_i.clone().iter().enumerate().take(row_i.len() - 1) {
            model.forward_token(&mut pool, slot, tok, pos, false).unwrap();
        }
        for _ in 0..4 {
            let pos = row_i.len() - 1;
            let lg = model.forward_token(&mut pool, slot, row_i[pos], pos, true).unwrap().unwrap();
            row_i.push(argmax(&lg) as i32);
        }
        assert_eq!(row_b, row_i);
    }
}
