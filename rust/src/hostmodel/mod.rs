//! `hostmodel` — the host quantized transformer.
//!
//! SiLQ's pitch is that quantization adds *no new operations* to the model,
//! so the repo keeps exactly one artifact-free quantized forward and every
//! workload (eval scoring, greedy generation, LLM-QAT self-generation,
//! `silq serve`) runs on top of it. [`HostModel`] holds the weights in the
//! representation the policy earns — **packed `i8` integers + per-output-
//! channel steps** for quantized policies (a quarter of the f32 memory
//! traffic), fake-quantized f32 otherwise — plus the learned static
//! activation steps and the RoPE tables, and exposes two forwards that are
//! bit-identical where they overlap:
//!
//! * [`HostModel::forward_token_into`] — incremental per-token decode with
//!   the K/V cache resident in a [`KvPool`] (O(1) work per new token) and
//!   every intermediate in a caller-owned
//!   [`DecodeScratch`](crate::kernels::DecodeScratch), so the steady-state
//!   loop performs **no heap allocation**. On the integer path the linear
//!   layers run the fused `i8` GEMV and attention reads the pool's raw
//!   int8 slab zero-copy (`q·k` in `i32` — see [`crate::kernels`]).
//! * [`HostModel::forward_tokens_batch`] — **cross-lane batched decode**:
//!   several independent [`KvPool`] sessions (serve lanes at ragged
//!   positions) advance one token each through one fused blocked GEMM per
//!   weight matrix, bit-identical per lane to `forward_token_into` (exact
//!   `i32` accumulation makes GEMV ≡ GEMM; attention stays per lane over
//!   each lane's own slab rows).
//! * [`HostModel::forward_seq`] — batched full-sequence forward returning
//!   logits at every position (continuation log-likelihood scoring),
//!   running the same kernels in blocked multi-row GEMM form — one pass
//!   over each weight matrix instead of n independent matvecs.
//!
//! All mirror `python/compile/model.py::forward` site for site (sans the
//! online-rotation ablation). `proptests.rs` and
//! `tests/kernels_integration.rs` pin the incremental == batched identity
//! bit-exactly on the deployment store, and pin the integer path against
//! the f32 fake-quant reference ([`HostModel::new_reference`]) at the
//! greedy-token and 1e-4-relative-logit level; the batched≡sequential
//! cross-lane identity is swept through the real serve scheduler in
//! `proptests.rs`.
//!
//! [`builtin_model`] / [`builtin_prec`] mirror `python/compile/configs.py`
//! so host-backend workloads run in a bare checkout, no manifest needed.

pub mod kvpool;

pub use kvpool::{
    AdmitErr, CacheStore, KvLayout, KvPool, KvSlabRef, PageLedger, QuantRule, DEFAULT_PAGE_SIZE,
};

use anyhow::{ensure, Context, Result};

use crate::config::{ArtifactSpec, ModelCfg, PrecCfg, TensorSpec};
use crate::kernels::pool as wpool;
use crate::kernels::{
    attend_f32, attend_i8, attend_i8_runs, matvec_into, quant_rows_i32, quant_rows_i8,
    rmsnorm_into, silu, ActRow, BatchScratch, DecodeScratch, KvRun, Linear, QLinear, GEMM_BLOCK,
};
use crate::model::ParamStore;
use crate::obs;
use crate::policy::{QuantMode, QuantPolicy};
use crate::quant::{dynamic_quant_rows, fake_quant, fake_quant_per_channel, EPS};

/// Model shape + typed precision policy of the host forward, decoupled
/// from the artifact manifest so tests, benches and `--backend host` runs
/// work without built artifacts. Every quantization decision in the host
/// stack (fold widths, activation quantizers, the KV pool's `QuantRule`)
/// derives from `policy`.
#[derive(Clone, Debug)]
pub struct HostCfg {
    /// vocabulary size
    pub vocab: usize,
    /// residual width
    pub d_model: usize,
    /// transformer layers
    pub n_layers: usize,
    /// attention heads
    pub n_heads: usize,
    /// FFN width
    pub d_ff: usize,
    /// context window
    pub seq_len: usize,
    /// the typed precision policy (see [`crate::policy`])
    pub policy: QuantPolicy,
    /// `rope_theta` from `python/compile/configs.py` (all current models
    /// use the default; the manifest does not carry it)
    pub rope_theta: f32,
}

impl HostCfg {
    /// Combine an architecture with a typed precision policy — the one
    /// constructor every host entry point funnels through.
    pub fn from_policy(mc: &ModelCfg, policy: &QuantPolicy) -> Result<HostCfg> {
        ensure!(
            !policy.online_rot,
            "host forward does not implement the online-rotation ablation"
        );
        policy.validate()?;
        Ok(HostCfg {
            vocab: mc.vocab,
            d_model: mc.d_model,
            n_layers: mc.n_layers,
            n_heads: mc.n_heads,
            d_ff: mc.d_ff,
            seq_len: mc.seq_len,
            policy: policy.clone(),
            rope_theta: 10000.0,
        })
    }

    /// Combine an architecture and a manifest precision placement (from
    /// the manifest, or from [`builtin_model`]/[`builtin_prec`]).
    pub fn from_cfgs(mc: &ModelCfg, pc: &PrecCfg) -> Result<HostCfg> {
        Self::from_policy(mc, &pc.policy()?)
    }

    /// Channels per attention head.
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Whether the policy quantizes at all.
    pub fn quantized(&self) -> bool {
        self.policy.quantized
    }

    /// Whether the runtime-quantized slots use dynamic per-token steps.
    pub fn act_dynamic(&self) -> bool {
        self.policy.acts.mode == QuantMode::Dynamic
    }
}

/// Built-in mirror of `python/compile/configs.py::MODELS` — lets the host
/// backend describe a model with no artifact manifest on disk.
pub fn builtin_model(name: &str) -> Option<ModelCfg> {
    let mut mc = match name {
        "tiny" | "tiny-pallas" => ModelCfg {
            name: name.into(),
            vocab: 256,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            d_ff: 256,
            seq_len: 64,
            train_batch: 16,
            fwd_batch: 32,
            use_pallas: false,
        },
        "small" => ModelCfg {
            name: name.into(),
            vocab: 512,
            d_model: 256,
            n_layers: 8,
            n_heads: 8,
            d_ff: 512,
            seq_len: 128,
            train_batch: 8,
            fwd_batch: 16,
            use_pallas: false,
        },
        _ => return None,
    };
    if name == "tiny-pallas" {
        mc.n_layers = 2;
        mc.use_pallas = true;
    }
    Some(mc)
}

/// Built-in mirror of `python/compile/configs.py::PRECISIONS`, now a thin
/// veneer over the typed policy grammar: the legacy manifest names
/// (`a8d-c8-w4`, ...), the policy presets and inline spec strings all
/// resolve; anything else is `None`. The cache storage rule that used to
/// live here is [`CacheStore::for_policy`].
pub fn builtin_prec(name: &str) -> Option<PrecCfg> {
    let p = QuantPolicy::resolve(name).ok()?;
    p.to_prec(name).ok()
}

/// Build the `ArtifactSpec` a host-served model's `ParamStore` follows —
/// the same ordered contract as `python/compile/model.py::param_spec`.
pub fn host_param_spec(cfg: &HostCfg) -> ArtifactSpec {
    let (l, d, f, v) = (cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab);
    let mut inputs: Vec<(String, Vec<usize>)> = vec![
        ("embed".into(), vec![v, d]),
        ("ln1".into(), vec![l, d]),
        ("wq".into(), vec![l, d, d]),
        ("wk".into(), vec![l, d, d]),
        ("wv".into(), vec![l, d, d]),
        ("wo".into(), vec![l, d, d]),
        ("ln2".into(), vec![l, d]),
        ("wg".into(), vec![l, d, f]),
        ("wu".into(), vec![l, d, f]),
        ("wd".into(), vec![l, f, d]),
        ("ln_f".into(), vec![d]),
        ("head".into(), vec![d, v]),
    ];
    if cfg.quantized() {
        for (n, dims) in [
            ("sw_q", vec![l, d]),
            ("sw_k", vec![l, d]),
            ("sw_v", vec![l, d]),
            ("sw_o", vec![l, d]),
            ("sw_g", vec![l, f]),
            ("sw_u", vec![l, f]),
            ("sw_d", vec![l, d]),
            ("sw_head", vec![v]),
        ] {
            inputs.push((n.into(), dims));
        }
        if !cfg.act_dynamic() {
            for (n, dims) in [
                ("sa_x1", vec![l]),
                ("sa_q", vec![l]),
                ("sc_k", vec![l]),
                ("sc_v", vec![l]),
                ("sa_o", vec![l]),
                ("sa_x2", vec![l]),
                ("sa_d", vec![l]),
                ("sa_head", vec![]),
            ] {
                inputs.push((n.into(), dims));
            }
        }
    }
    ArtifactSpec {
        name: "host_fwd".into(),
        file: String::new(),
        model: "host".into(),
        prec: if cfg.quantized() { "quantized" } else { "fp16" }.into(),
        mode: "fwd".into(),
        inputs: inputs
            .into_iter()
            .map(|(n, dims)| TensorSpec { name: format!("params.{n}"), dtype: "f32".into(), dims })
            .collect(),
        outputs: vec![],
    }
}

/// Deterministic randomly-initialized parameters following
/// [`host_param_spec`] — the bootstrap the tests and benches share (an
/// untrained model generates noise, but latency/identity properties
/// don't care).
pub fn host_test_params(cfg: &HostCfg, seed: u64) -> ParamStore {
    let spec = host_param_spec(cfg);
    // ParamStore::init keys its rules off parameter names alone; the
    // ModelCfg is only part of the signature
    let mc = ModelCfg {
        name: "host".into(),
        vocab: cfg.vocab,
        d_model: cfg.d_model,
        n_layers: cfg.n_layers,
        n_heads: cfg.n_heads,
        d_ff: cfg.d_ff,
        seq_len: cfg.seq_len,
        train_batch: 1,
        fwd_batch: 1,
        use_pallas: false,
    };
    let mut rng = crate::util::Rng::new(seed);
    ParamStore::init(&spec, &mc, &mut rng)
}

/// Admission-time prompt validation shared by every host/artifact entry
/// point (serve admit, decode prefill, batched scoring).
pub fn check_tokens(prompt: &[i32], vocab: usize) -> Result<()> {
    for &t in prompt {
        ensure!(t >= 0 && (t as usize) < vocab, "prompt token {t} outside the vocab (0..{vocab})");
    }
    Ok(())
}

/// Static (learned-scalar) activation steps per layer, when `act_dynamic`
/// is off. Floored at `quant::EPS` on load so the integer quantizers use
/// them directly (the fake-quant floor is idempotent).
struct StaticSteps {
    sa_x1: Vec<f32>,
    sa_q: Vec<f32>,
    sa_o: Vec<f32>,
    sa_x2: Vec<f32>,
    sa_d: Vec<f32>,
    sa_head: f32,
}

/// Per-layer weights in the representation the policy earned (packed
/// integers or fake-quantized f32 — see [`crate::kernels::Linear`]).
struct LayerWeights {
    ln1: Vec<f32>,
    wq: Linear,
    wk: Linear,
    wv: Linear,
    wo: Linear,
    ln2: Vec<f32>,
    wg: Linear,
    wu: Linear,
    wd: Linear,
}

/// One lane of a cross-lane batched decode step: pool session `slot`
/// advances by token `tok` at position `pos`. Positions may be ragged
/// across lanes — staggered admissions are the normal serve state.
#[derive(Clone, Copy, Debug)]
pub struct BatchLane {
    /// the lane's [`KvPool`] session slot
    pub slot: usize,
    /// the token to fold into the cache this step
    pub tok: i32,
    /// the position `tok` lands at (== tokens already cached)
    pub pos: usize,
}

/// The host quantized transformer: folded weights + activation quantizers +
/// RoPE tables. Pure host math over a `ParamStore`; the K/V cache lives in
/// a caller-owned [`KvPool`] so one model instance can serve any number of
/// concurrent sessions.
pub struct HostModel {
    /// shape + precision policy
    pub cfg: HostCfg,
    embed: Vec<f32>,
    layers: Vec<LayerWeights>,
    ln_f: Vec<f32>,
    head: Linear,
    sa: Option<StaticSteps>,
    rule: QuantRule,
    /// RoPE tables [seq, d_head/2]
    cos: Vec<f32>,
    sin: Vec<f32>,
    /// per-(layer, head) K attention steps for the static int8 cache (the
    /// per-layer broadcast scalar repeated per head; empty otherwise)
    k_attn: Vec<f32>,
    /// per-(layer, head) V attention steps (static int8 cache only)
    v_attn: Vec<f32>,
    /// linear layers run the packed `i8` GEMV/GEMM path
    int_linear: bool,
    /// the head projection runs the packed path
    int_head: bool,
    /// attention runs `i32` q·k over int8 K/V rows
    int_attn: bool,
}

/// Worst-case `|Σ xq·wq|` of an integer contraction must stay an exact
/// `i32` — the bound that makes integer accumulation *exact* rather than
/// approximately right.
fn int_dot_fits(in_dim: usize, a_bits: u32, b_bits: u32) -> bool {
    (in_dim as i64) * (1i64 << (a_bits - 1)) * (1i64 << (b_bits - 1)) <= i32::MAX as i64
}

impl HostModel {
    /// Build the model in the best representation the policy allows:
    /// quantized linear weights fold to packed `i8` + per-channel steps,
    /// attention reads int8 K/V slabs, and fp16 (or out-of-envelope
    /// policies, e.g. >8-bit weights) falls back to f32 site by site.
    pub fn new(cfg: HostCfg, params: &ParamStore) -> Result<HostModel> {
        Self::build(cfg, params, false)
    }

    /// Build the f32 fake-quant **reference**: every weight fake-quantized
    /// but stored as f32, activations fake-quantized in place, attention
    /// over dequantized rows — the pre-kernels host path. Benches measure
    /// the integer path's speedup against it and the identity tests pin
    /// greedy-token equality to it.
    pub fn new_reference(cfg: HostCfg, params: &ParamStore) -> Result<HostModel> {
        Self::build(cfg, params, true)
    }

    fn build(cfg: HostCfg, params: &ParamStore, reference: bool) -> Result<HostModel> {
        let (l, d, f, v) = (cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab);
        ensure!(d % cfg.n_heads == 0, "d_model must divide into heads");

        let p = &cfg.policy;
        let int_linear = !reference
            && cfg.quantized()
            && p.weights.bits <= 8
            && p.acts.bits <= 8
            && int_dot_fits(d.max(f), p.acts.bits, p.weights.bits);
        let int_head = !reference
            && cfg.quantized()
            && p.head.bits <= 8
            && int_dot_fits(d, p.head.bits, p.head.bits);
        let int_attn = !reference
            && cfg.quantized()
            && p.query.bits <= 16
            && p.cache.bits <= 8
            && int_dot_fits(cfg.d_head(), p.query.bits, p.cache.bits);

        let slice = |name: &str, layer: usize, per: usize| -> Result<Vec<f32>> {
            let t = params.get(name)?;
            ensure!(t.len() == l * per, "{name}: expected {} values, got {}", l * per, t.len());
            Ok(t[layer * per..(layer + 1) * per].to_vec())
        };

        // fold one matrix into the representation its `int` flag earned
        let fold = |mut w: Vec<f32>, steps: Option<Vec<f32>>, out_dim: usize, bits: u32, int: bool| {
            match steps {
                Some(st) if int => Linear::Int8(QLinear::pack(&w, out_dim, &st, bits)),
                Some(st) => {
                    fake_quant_per_channel(&mut w, out_dim, &st, bits);
                    Linear::F32 { w, out_dim }
                }
                None => Linear::F32 { w, out_dim },
            }
        };

        let wb = p.weights.bits;
        let mut layers = Vec::with_capacity(l);
        for li in 0..l {
            let st = |name: &str, per: usize| -> Result<Option<Vec<f32>>> {
                if cfg.quantized() {
                    Ok(Some(slice(name, li, per)?))
                } else {
                    Ok(None)
                }
            };
            layers.push(LayerWeights {
                ln1: slice("ln1", li, d)?,
                wq: fold(slice("wq", li, d * d)?, st("sw_q", d)?, d, wb, int_linear),
                wk: fold(slice("wk", li, d * d)?, st("sw_k", d)?, d, wb, int_linear),
                wv: fold(slice("wv", li, d * d)?, st("sw_v", d)?, d, wb, int_linear),
                wo: fold(slice("wo", li, d * d)?, st("sw_o", d)?, d, wb, int_linear),
                ln2: slice("ln2", li, d)?,
                wg: fold(slice("wg", li, d * f)?, st("sw_g", f)?, f, wb, int_linear),
                wu: fold(slice("wu", li, d * f)?, st("sw_u", f)?, f, wb, int_linear),
                wd: fold(slice("wd", li, f * d)?, st("sw_d", d)?, d, wb, int_linear),
            });
        }

        let head_steps =
            if cfg.quantized() { Some(params.get("sw_head")?.to_vec()) } else { None };
        let head = fold(params.get("head")?.to_vec(), head_steps, v, p.head.bits, int_head);

        let sa = if cfg.quantized() && !cfg.act_dynamic() {
            let floored = |name: &str| -> Result<Vec<f32>> {
                Ok(params.get(name)?.iter().map(|&s| s.max(EPS)).collect())
            };
            Some(StaticSteps {
                sa_x1: floored("sa_x1")?,
                sa_q: floored("sa_q")?,
                sa_o: floored("sa_o")?,
                sa_x2: floored("sa_x2")?,
                sa_d: floored("sa_d")?,
                sa_head: params.get("sa_head")?[0].max(EPS),
            })
        } else {
            None
        };

        // cache quantization rule, derived from the policy's cache slot:
        // static steps come from the trained sc_k/sc_v scalars broadcast
        // across channels; dynamic recomputes per head row on write
        // (ste_dynamic_quantize's last-axis rule)
        let rule = if !cfg.quantized() {
            QuantRule::None
        } else {
            match p.cache.mode {
                QuantMode::Dynamic => {
                    QuantRule::Dynamic { bits: p.cache.bits, rows: cfg.n_heads }
                }
                QuantMode::Static => {
                    let bc = |name: &str| -> Result<Vec<f32>> {
                        let s = params.get(name)?;
                        ensure!(s.len() == l, "{name} must be one step per layer");
                        Ok(s.iter().flat_map(|&x| std::iter::repeat(x).take(d)).collect())
                    };
                    QuantRule::Static {
                        bits: p.cache.bits,
                        k_steps: bc("sc_k")?,
                        v_steps: bc("sc_v")?,
                    }
                }
            }
        }
        .floored();

        // per-(layer, head) attention steps for the static int8 cache: the
        // rule's steps are the per-layer scalar broadcast across channels,
        // so one value per head row is exact
        let h = cfg.n_heads;
        let (k_attn, v_attn) = match (&rule, int_attn) {
            (QuantRule::Static { k_steps, v_steps, .. }, true) => {
                let per_head = |steps: &[f32]| -> Vec<f32> {
                    (0..l).flat_map(|li| std::iter::repeat(steps[li * d]).take(h)).collect()
                };
                (per_head(k_steps), per_head(v_steps))
            }
            _ => (vec![], vec![]),
        };

        // RoPE tables, as in model.py::rope_tables
        let dh = cfg.d_head();
        let half = dh / 2;
        let mut cos = Vec::with_capacity(cfg.seq_len * half);
        let mut sin = Vec::with_capacity(cfg.seq_len * half);
        for pos in 0..cfg.seq_len {
            for i in 0..half {
                let inv = 1.0 / cfg.rope_theta.powf(2.0 * i as f32 / dh as f32);
                let ang = pos as f32 * inv;
                cos.push(ang.cos());
                sin.push(ang.sin());
            }
        }

        Ok(HostModel {
            embed: params.get("embed")?.to_vec(),
            ln_f: params.get("ln_f")?.to_vec(),
            head,
            layers,
            sa,
            rule,
            cos,
            sin,
            k_attn,
            v_attn,
            int_linear,
            int_head,
            int_attn,
            cfg,
        })
    }

    /// Whether this build runs the full integer path (packed linears +
    /// int8 slab attention) — false for [`HostModel::new_reference`] and
    /// out-of-envelope policies.
    pub fn integer_path(&self) -> bool {
        self.int_linear && self.int_head && self.int_attn
    }

    /// Resident weight bytes in this build's representation (packed
    /// integers + scales, or 4-byte floats), including the always-f32
    /// tensors (embed, norm gains).
    pub fn weight_bytes(&self) -> usize {
        let lin = |w: &Linear| w.resident_bytes();
        self.layers
            .iter()
            .map(|lw| {
                lin(&lw.wq)
                    + lin(&lw.wk)
                    + lin(&lw.wv)
                    + lin(&lw.wo)
                    + lin(&lw.wg)
                    + lin(&lw.wu)
                    + lin(&lw.wd)
                    + (lw.ln1.len() + lw.ln2.len()) * 4
            })
            .sum::<usize>()
            + lin(&self.head)
            + (self.embed.len() + self.ln_f.len()) * 4
    }

    /// A KV pool sized for this model with `slots` concurrent sessions,
    /// quantizing under this model's cache rule.
    pub fn make_pool(&self, slots: usize, store: CacheStore) -> Result<KvPool> {
        self.make_pool_with(slots, store, KvLayout::Slab)
    }

    /// [`HostModel::make_pool`] with an explicit [`KvLayout`] — the paged
    /// geometry (`--kv paged`) shares prompt-prefix pages across sessions
    /// and admits in pages, token-identical to the slab by construction.
    pub fn make_pool_with(
        &self,
        slots: usize,
        store: CacheStore,
        layout: KvLayout,
    ) -> Result<KvPool> {
        KvPool::new_with_layout(
            slots,
            self.cfg.n_layers,
            self.cfg.seq_len,
            self.cfg.d_model,
            store,
            self.rule.clone(),
            layout,
        )
        .context("building KV pool")
    }

    /// Quantize one activation vector at a site (mirrors `act_quant`):
    /// dynamic per-`rows` sub-row (`ste_dynamic_quantize`'s last-axis
    /// rule), or a static learned step, or identity — the f32 fake-quant
    /// form the fallback/reference path uses in place.
    fn act_quant(&self, x: &mut [f32], bits: u32, static_step: Option<f32>, rows: usize) {
        if !self.cfg.quantized() {
            return;
        }
        match static_step {
            Some(s) => fake_quant(x, s, bits),
            None => dynamic_quant_rows(x, x.len() / rows, bits),
        }
    }

    /// Prepare one activation row for a [`Linear`] in the representation
    /// `int` selects: quantized `i8` + step for the packed path (into the
    /// caller's scratch), fake-quantized f32 in place otherwise.
    fn prep_act<'a>(
        &self,
        int: bool,
        x: &'a mut [f32],
        bits: u32,
        step: Option<f32>,
        q: &'a mut [i8],
        s: &'a mut [f32],
    ) -> ActRow<'a> {
        if int {
            let n = x.len();
            quant_rows_i8(x, n, bits, step, &mut q[..n], &mut s[..1]);
            ActRow::I8 { q: &q[..n], scale: s[0] }
        } else {
            self.act_quant(x, bits, step, 1);
            ActRow::F32(x)
        }
    }

    /// Apply RoPE at `pos` to one position's q and k rows (head-major
    /// channel layout).
    fn rope(&self, pos: usize, q: &mut [f32], k: &mut [f32]) {
        let (h, dh) = (self.cfg.n_heads, self.cfg.d_head());
        let half = dh / 2;
        for head_i in 0..h {
            for i in 0..half {
                let (c, s) = (self.cos[pos * half + i], self.sin[pos * half + i]);
                for t in [&mut *q, &mut *k] {
                    let (a, b) = (t[head_i * dh + 2 * i], t[head_i * dh + 2 * i + 1]);
                    t[head_i * dh + 2 * i] = a * c - b * s;
                    t[head_i * dh + 2 * i + 1] = a * s + b * c;
                }
            }
        }
    }

    /// Static activation steps of layer `li` (None at every site when the
    /// precision is dynamic or unquantized).
    fn steps(&self, li: usize) -> LayerSteps {
        match &self.sa {
            Some(s) => LayerSteps {
                sa_x1: Some(s.sa_x1[li]),
                sa_q: Some(s.sa_q[li]),
                sa_o: Some(s.sa_o[li]),
                sa_x2: Some(s.sa_x2[li]),
                sa_d: Some(s.sa_d[li]),
            },
            None => LayerSteps::default(),
        }
    }

    /// Run one token through the stack at position `pos` of session `slot`,
    /// reading and extending the K/V cache in `pool`; logits (borrowed
    /// from `scratch`) only when asked — prefill positions skip the head
    /// matmul. Steady state allocates nothing: every intermediate lives in
    /// `scratch` (`tests/kernels_zero_alloc.rs` pins this), and on the
    /// integer path attention runs directly over the pool's int8 slab.
    pub fn forward_token_into<'s>(
        &self,
        pool: &mut KvPool,
        slot: usize,
        tok: i32,
        pos: usize,
        want_logits: bool,
        scratch: &'s mut DecodeScratch,
    ) -> Result<Option<&'s [f32]>> {
        let cfg = &self.cfg;
        let (d, f, h) = (cfg.d_model, cfg.d_ff, cfg.n_heads);
        ensure!(pos < cfg.seq_len, "position {pos} outside the context window");
        ensure!(tok >= 0 && (tok as usize) < cfg.vocab, "token {tok} outside the vocab");
        scratch.check(cfg);
        // phase telemetry: prefill folds the token without logits, decode
        // pays the head matmul; the guard lives for the whole forward
        let _span = if want_logits {
            obs::add(obs::Counter::DecodeTokens, 1);
            obs::span("decode_token", "hostmodel", slot as u32 + 1, pos as u64)
        } else {
            obs::add(obs::Counter::PrefillTokens, 1);
            obs::span("prefill_token", "hostmodel", slot as u32 + 1, pos as u64)
        };
        // attention can only read integers the pool actually stores
        let int_attn = self.int_attn && pool.store == CacheStore::Int8;

        let s = &mut *scratch;
        s.x[..d].copy_from_slice(&self.embed[tok as usize * d..(tok as usize + 1) * d]);

        for li in 0..cfg.n_layers {
            let st = self.steps(li);
            let lw = &self.layers[li];

            // attention-input projections off one quantization of hnorm
            rmsnorm_into(&s.x[..d], &lw.ln1, &mut s.hnorm[..d]);
            let act1 = self.prep_act(
                self.int_linear,
                &mut s.hnorm[..d],
                cfg.policy.acts.bits,
                st.sa_x1,
                &mut s.xq,
                &mut s.xs,
            );
            lw.wq.forward(act1, &mut s.acc, &mut s.q[..d]);
            lw.wk.forward(act1, &mut s.acc, &mut s.k[..d]);
            lw.wv.forward(act1, &mut s.acc, &mut s.v[..d]);

            self.rope(pos, &mut s.q[..d], &mut s.k[..d]);

            // INT16 query; K/V are quantized by the pool on write
            if int_attn {
                quant_rows_i32(
                    &s.q[..d],
                    cfg.d_head(),
                    cfg.policy.query.bits,
                    st.sa_q,
                    &mut s.qq[..d],
                    &mut s.qs[..h],
                );
            } else {
                self.act_quant(&mut s.q[..d], cfg.policy.query.bits, st.sa_q, h);
            }
            pool.write(slot, li, pos, &s.k[..d], &s.v[..d]);

            // causal attention over the cached prefix — walking the pool's
            // resident page runs in position order (one run on the slab
            // geometry; bit-identical at any split, see
            // `attend_i8_runs_is_bit_identical_at_any_split`)
            let len = pos + 1;
            if int_attn {
                let stride = pool.scale_rows();
                if stride > 0 {
                    attend_i8_runs(
                        &s.qq[..d],
                        &s.qs[..h],
                        pool.runs(slot, li, len),
                        stride,
                        h,
                        d,
                        len,
                        &mut s.scores[..len],
                        &mut s.ctx[..d],
                    );
                } else {
                    // static rule: per-layer steps live in the model, not
                    // the pages — substitute them into every run at stride 0
                    let (ksc, vsc) =
                        (&self.k_attn[li * h..(li + 1) * h], &self.v_attn[li * h..(li + 1) * h]);
                    let runs = pool
                        .runs(slot, li, len)
                        .map(|r| KvRun { k_scales: ksc, v_scales: vsc, ..r });
                    attend_i8_runs(
                        &s.qq[..d],
                        &s.qs[..h],
                        runs,
                        0,
                        h,
                        d,
                        len,
                        &mut s.scores[..len],
                        &mut s.ctx[..d],
                    );
                }
            } else {
                pool.read_into(slot, li, len, &mut s.kc[..len * d], &mut s.vc[..len * d])?;
                attend_f32(
                    &s.q[..d],
                    &s.kc[..len * d],
                    &s.vc[..len * d],
                    h,
                    d,
                    len,
                    &mut s.scores[..len],
                    &mut s.ctx[..d],
                );
            }

            let act_o = self.prep_act(
                self.int_linear,
                &mut s.ctx[..d],
                cfg.policy.acts.bits,
                st.sa_o,
                &mut s.xq,
                &mut s.xs,
            );
            lw.wo.forward(act_o, &mut s.acc, &mut s.o[..d]);
            for (xv, ov) in s.x[..d].iter_mut().zip(&s.o[..d]) {
                *xv += *ov;
            }

            // FFN
            rmsnorm_into(&s.x[..d], &lw.ln2, &mut s.hnorm[..d]);
            let act2 = self.prep_act(
                self.int_linear,
                &mut s.hnorm[..d],
                cfg.policy.acts.bits,
                st.sa_x2,
                &mut s.xq,
                &mut s.xs,
            );
            lw.wg.forward(act2, &mut s.acc, &mut s.g[..f]);
            lw.wu.forward(act2, &mut s.acc, &mut s.u[..f]);
            for (gv, uv) in s.g[..f].iter_mut().zip(&s.u[..f]) {
                *gv = silu(*gv) * *uv;
            }
            let act3 = self.prep_act(
                self.int_linear,
                &mut s.g[..f],
                cfg.policy.acts.bits,
                st.sa_d,
                &mut s.xq,
                &mut s.xs,
            );
            lw.wd.forward(act3, &mut s.acc, &mut s.o[..d]);
            for (xv, dv) in s.x[..d].iter_mut().zip(&s.o[..d]) {
                *xv += *dv;
            }
        }

        if !want_logits {
            return Ok(None);
        }
        rmsnorm_into(&s.x[..d], &self.ln_f, &mut s.hnorm[..d]);
        let act_h = self.prep_act(
            self.int_head,
            &mut s.hnorm[..d],
            cfg.policy.head.bits,
            self.sa.as_ref().map(|st| st.sa_head),
            &mut s.xq,
            &mut s.xs,
        );
        self.head.forward(act_h, &mut s.acc, &mut s.logits[..cfg.vocab]);
        Ok(Some(&scratch.logits[..cfg.vocab]))
    }

    /// [`HostModel::forward_token_into`] with a throwaway scratch —
    /// convenience for tests and one-off calls; hot loops (serve lanes,
    /// eval decode) hold a persistent [`DecodeScratch`] instead.
    pub fn forward_token(
        &self,
        pool: &mut KvPool,
        slot: usize,
        tok: i32,
        pos: usize,
        want_logits: bool,
    ) -> Result<Option<Vec<f32>>> {
        let mut scratch = DecodeScratch::for_cfg(&self.cfg);
        Ok(self
            .forward_token_into(pool, slot, tok, pos, want_logits, &mut scratch)?
            .map(|lg| lg.to_vec()))
    }

    /// **Cross-lane batched decode**: advance several independent [`KvPool`]
    /// sessions by one token each through **one fused pass per weight
    /// matrix**. The B lanes' activation rows are stacked `[B, dim]` and
    /// run through the blocked `i8` GEMM ([`QLinear::gemm_into`]) instead
    /// of B sequential GEMVs, so at batch width B every weight matrix is
    /// streamed once per [`GEMM_BLOCK`] lanes per step instead of B times —
    /// the memory-bound lever `silq serve` rides. Attention stays per lane
    /// (each lane owns its own slab rows at its own — possibly ragged —
    /// position), exactly as in [`HostModel::forward_token_into`] — and on
    /// the integer path the lanes fan out across the kernels worker pool
    /// (`kernels::pool`), each into its own score/context windows, while
    /// the fused GEMMs shard by output channel inside the kernel; both
    /// fan-outs are bit-exact at any thread count.
    ///
    /// Bit-exactness: per lane this computes *exactly* what
    /// `forward_token_into` computes — row quantization is per lane row
    /// (same steps), the GEMM's `i32` contraction is exact so blocking
    /// cannot change any row's result (GEMV ≡ GEMM, pinned in
    /// `kernels::tests`), and RoPE/norms/residuals/attention are per-lane
    /// scalar loops. The batched≡sequential proptest and the serve
    /// identity suite pin this end to end.
    ///
    /// Logits land in `scratch.logits` as `[B, vocab]` row-major, ordered
    /// as `lanes`; `None` when `want_logits` is off (prefill). Lanes must
    /// target distinct pool slots.
    pub fn forward_tokens_batch<'s>(
        &self,
        pool: &mut KvPool,
        lanes: &[BatchLane],
        want_logits: bool,
        scratch: &'s mut BatchScratch,
    ) -> Result<Option<&'s [f32]>> {
        let cfg = &self.cfg;
        let (d, f, h, v) = (cfg.d_model, cfg.d_ff, cfg.n_heads, cfg.vocab);
        let b = lanes.len();
        ensure!(b > 0, "batched step over zero lanes");
        scratch.check(cfg, b);
        for (i, ln) in lanes.iter().enumerate() {
            ensure!(
                ln.pos < cfg.seq_len,
                "lane {i}: position {} outside the context window",
                ln.pos
            );
            ensure!(
                ln.tok >= 0 && (ln.tok as usize) < v,
                "lane {i}: token {} outside the vocab",
                ln.tok
            );
            ensure!(
                !lanes[..i].iter().any(|o| o.slot == ln.slot),
                "lane {i}: slot {} stepped twice in one batch",
                ln.slot
            );
        }
        let _span = obs::span("batch_decode", "hostmodel", 0, b as u64);
        obs::add(obs::Counter::BatchSteps, 1);
        obs::add(
            if want_logits { obs::Counter::DecodeTokens } else { obs::Counter::PrefillTokens },
            b as u64,
        );
        // attention can only read integers the pool actually stores
        let int_attn = self.int_attn && pool.store == CacheStore::Int8;

        let s = &mut *scratch;
        for (l, ln) in lanes.iter().enumerate() {
            let t = ln.tok as usize;
            s.x[l * d..(l + 1) * d].copy_from_slice(&self.embed[t * d..(t + 1) * d]);
        }

        for li in 0..cfg.n_layers {
            let st = self.steps(li);
            let lw = &self.layers[li];

            // attention-input projections: one fused GEMM per matrix over
            // the B stacked (normed, quantized) lane rows
            for l in 0..b {
                rmsnorm_into(&s.x[l * d..(l + 1) * d], &lw.ln1, &mut s.hnorm[l * d..(l + 1) * d]);
            }
            self.seq_linear(
                self.int_linear,
                &mut s.hnorm[..b * d],
                b,
                d,
                cfg.policy.acts.bits,
                st.sa_x1,
                &mut s.xq,
                &mut s.sx,
                &mut s.acc,
                &mut [
                    (&lw.wq, &mut s.q[..b * d]),
                    (&lw.wk, &mut s.k[..b * d]),
                    (&lw.wv, &mut s.v[..b * d]),
                ],
            );

            // per-lane prologue (sequential — the cache write needs the
            // pool mutably): RoPE at the lane's own position, query
            // quantization, cache write
            for (l, ln) in lanes.iter().enumerate() {
                let qr = l * d;
                self.rope(ln.pos, &mut s.q[qr..qr + d], &mut s.k[qr..qr + d]);
                if int_attn {
                    quant_rows_i32(
                        &s.q[qr..qr + d],
                        cfg.d_head(),
                        cfg.policy.query.bits,
                        st.sa_q,
                        &mut s.qq[l * d..(l + 1) * d],
                        &mut s.qs[l * h..(l + 1) * h],
                    );
                } else {
                    self.act_quant(&mut s.q[qr..qr + d], cfg.policy.query.bits, st.sa_q, h);
                }
                pool.write(ln.slot, li, ln.pos, &s.k[qr..qr + d], &s.v[qr..qr + d]);
            }

            if int_attn {
                // integer attention fans whole lanes across the worker
                // pool: every lane reads its own (now written) slab rows
                // through `&KvPool` and owns disjoint score/context
                // windows, and each lane's math is exactly the sequential
                // loop's — per-lane order is untouched, so parallel ≡
                // sequential bit-for-bit at any thread count.
                let seq = cfg.seq_len;
                let attn_work: usize = lanes.iter().map(|ln| 2 * (ln.pos + 1) * d).sum();
                let shards = wpool::shard_count(attn_work, b);
                let qq = &s.qq[..b * d];
                let qs = &s.qs[..b * h];
                let scoresp = wpool::SendPtr(s.scores.as_mut_ptr());
                let ctxp = wpool::SendPtr(s.ctx.as_mut_ptr());
                let kv: &KvPool = pool;
                wpool::run(shards, &|sh| {
                    let (l0, l1) = wpool::shard_range(b, shards, sh);
                    for (l, ln) in lanes.iter().enumerate().take(l1).skip(l0) {
                        let len = ln.pos + 1;
                        // SAFETY: lane l's score row `[l·seq, l·seq+len)`
                        // and context row `[l·d, (l+1)·d)` — shards own
                        // disjoint lane ranges and the pool joins every
                        // shard before `run` returns.
                        let scores = unsafe {
                            std::slice::from_raw_parts_mut(scoresp.0.add(l * seq), len)
                        };
                        let ctx = unsafe {
                            std::slice::from_raw_parts_mut(ctxp.0.add(l * d), d)
                        };
                        let stride = kv.scale_rows();
                        if stride > 0 {
                            attend_i8_runs(
                                &qq[l * d..(l + 1) * d],
                                &qs[l * h..(l + 1) * h],
                                kv.runs(ln.slot, li, len),
                                stride,
                                h,
                                d,
                                len,
                                scores,
                                ctx,
                            );
                        } else {
                            let (ksc, vsc) = (
                                &self.k_attn[li * h..(li + 1) * h],
                                &self.v_attn[li * h..(li + 1) * h],
                            );
                            let runs = kv
                                .runs(ln.slot, li, len)
                                .map(|r| KvRun { k_scales: ksc, v_scales: vsc, ..r });
                            attend_i8_runs(
                                &qq[l * d..(l + 1) * d],
                                &qs[l * h..(l + 1) * h],
                                runs,
                                0,
                                h,
                                d,
                                len,
                                scores,
                                ctx,
                            );
                        }
                    }
                });
            } else {
                // f32 fallback: shares the single-lane dequant buffers, so
                // it stays sequential (same order as the reference path)
                for (l, ln) in lanes.iter().enumerate() {
                    let qr = l * d;
                    let len = ln.pos + 1;
                    pool.read_into(ln.slot, li, len, &mut s.kc[..len * d], &mut s.vc[..len * d])?;
                    attend_f32(
                        &s.q[qr..qr + d],
                        &s.kc[..len * d],
                        &s.vc[..len * d],
                        h,
                        d,
                        len,
                        &mut s.scores[..len],
                        &mut s.ctx[l * d..(l + 1) * d],
                    );
                }
            }

            // output projection + residual, fused across lanes
            self.seq_linear(
                self.int_linear,
                &mut s.ctx[..b * d],
                b,
                d,
                cfg.policy.acts.bits,
                st.sa_o,
                &mut s.xq,
                &mut s.sx,
                &mut s.acc,
                &mut [(&lw.wo, &mut s.o[..b * d])],
            );
            for (xv, ov) in s.x[..b * d].iter_mut().zip(&s.o[..b * d]) {
                *xv += *ov;
            }

            // FFN, fused across lanes
            for l in 0..b {
                rmsnorm_into(&s.x[l * d..(l + 1) * d], &lw.ln2, &mut s.hnorm[l * d..(l + 1) * d]);
            }
            self.seq_linear(
                self.int_linear,
                &mut s.hnorm[..b * d],
                b,
                d,
                cfg.policy.acts.bits,
                st.sa_x2,
                &mut s.xq,
                &mut s.sx,
                &mut s.acc,
                &mut [(&lw.wg, &mut s.g[..b * f]), (&lw.wu, &mut s.u[..b * f])],
            );
            for (gv, uv) in s.g[..b * f].iter_mut().zip(&s.u[..b * f]) {
                *gv = silu(*gv) * *uv;
            }
            self.seq_linear(
                self.int_linear,
                &mut s.g[..b * f],
                b,
                f,
                cfg.policy.acts.bits,
                st.sa_d,
                &mut s.xq,
                &mut s.sx,
                &mut s.acc,
                &mut [(&lw.wd, &mut s.o[..b * d])],
            );
            for (xv, dv) in s.x[..b * d].iter_mut().zip(&s.o[..b * d]) {
                *xv += *dv;
            }
        }

        if !want_logits {
            return Ok(None);
        }
        for l in 0..b {
            rmsnorm_into(&s.x[l * d..(l + 1) * d], &self.ln_f, &mut s.hnorm[l * d..(l + 1) * d]);
        }
        self.seq_linear(
            self.int_head,
            &mut s.hnorm[..b * d],
            b,
            d,
            cfg.policy.head.bits,
            self.sa.as_ref().map(|st| st.sa_head),
            &mut s.xq,
            &mut s.sx,
            &mut s.acc,
            &mut [(&self.head, &mut s.logits[..b * v])],
        );
        Ok(Some(&scratch.logits[..b * v]))
    }

    /// Batched full-sequence forward of one row: logits at **every**
    /// position, `[len * vocab]` row-major (rows longer than the context
    /// window are truncated, matching `pack_rows`). Independent math from
    /// [`HostModel::forward_token_into`] — whole-sequence attention with
    /// K/V quantized through the shared [`QuantRule`], linear layers in
    /// blocked multi-row GEMM form — and bit-identical to the incremental
    /// path position for position on the deployment store (the property
    /// tests' subject).
    pub fn forward_seq(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        let (d, f, h, v) = (cfg.d_model, cfg.d_ff, cfg.n_heads, cfg.vocab);
        let dh = cfg.d_head();
        let n = tokens.len().min(cfg.seq_len);
        ensure!(n > 0, "empty sequence");
        check_tokens(&tokens[..n], v)?;

        let mut x = vec![0f32; n * d];
        for (pos, &t) in tokens[..n].iter().enumerate() {
            x[pos * d..(pos + 1) * d]
                .copy_from_slice(&self.embed[t as usize * d..(t as usize + 1) * d]);
        }

        let mut hn = vec![0f32; n * d];
        let mut q_all = vec![0f32; n * d];
        let mut k_all = vec![0f32; n * d];
        let mut v_all = vec![0f32; n * d];
        let mut ctx_all = vec![0f32; n * d];
        let mut o_all = vec![0f32; n * d];
        let mut g_all = vec![0f32; n * f];
        let mut u_all = vec![0f32; n * f];
        let mut scores = vec![0f32; n];
        // integer-path row buffers (empty when the path is off)
        let int_rows = self.int_linear || self.int_head;
        let mut xq = vec![0i8; if int_rows { n * d.max(f) } else { 0 }];
        let mut sx = vec![0f32; if int_rows { n } else { 0 }];
        let mut acc = vec![0i32; if int_rows { GEMM_BLOCK * d.max(f).max(v) } else { 0 }];
        let attn_n = if self.int_attn { n } else { 0 };
        let mut qq = vec![0i32; attn_n * d];
        let mut qs = vec![0f32; attn_n * h];
        let mut kq = vec![0i8; attn_n * d];
        let mut vq = vec![0i8; attn_n * d];
        let mut ksc = vec![0f32; attn_n * h];
        let mut vsc = vec![0f32; attn_n * h];

        for li in 0..cfg.n_layers {
            let st = self.steps(li);
            let lw = &self.layers[li];

            // attention inputs for every position: one blocked GEMM per
            // matrix off a single quantization of the normed rows (the
            // "prefill" the incremental path amortizes across steps)
            for p in 0..n {
                rmsnorm_into(&x[p * d..(p + 1) * d], &lw.ln1, &mut hn[p * d..(p + 1) * d]);
            }
            self.seq_linear(
                self.int_linear,
                &mut hn,
                n,
                d,
                cfg.policy.acts.bits,
                st.sa_x1,
                &mut xq,
                &mut sx,
                &mut acc,
                &mut [
                    (&lw.wq, &mut q_all[..n * d]),
                    (&lw.wk, &mut k_all[..n * d]),
                    (&lw.wv, &mut v_all[..n * d]),
                ],
            );
            for p in 0..n {
                self.rope(p, &mut q_all[p * d..(p + 1) * d], &mut k_all[p * d..(p + 1) * d]);
            }

            // query + cache quantization, same rules as the pool's write
            // path (the shared code is what keeps incremental == batched)
            if cfg.quantized() {
                for p in 0..n {
                    if self.int_attn {
                        quant_rows_i32(
                            &q_all[p * d..(p + 1) * d],
                            dh,
                            cfg.policy.query.bits,
                            st.sa_q,
                            &mut qq[p * d..(p + 1) * d],
                            &mut qs[p * h..(p + 1) * h],
                        );
                        self.rule.quantize_i8(
                            li,
                            &k_all[p * d..(p + 1) * d],
                            &v_all[p * d..(p + 1) * d],
                            &mut kq[p * d..(p + 1) * d],
                            &mut vq[p * d..(p + 1) * d],
                            &mut ksc[p * h..(p + 1) * h],
                            &mut vsc[p * h..(p + 1) * h],
                        );
                    } else {
                        self.act_quant(&mut q_all[p * d..(p + 1) * d], cfg.policy.query.bits, st.sa_q, h);
                        self.rule.quantize_f32(
                            li,
                            &mut k_all[p * d..(p + 1) * d],
                            &mut v_all[p * d..(p + 1) * d],
                        );
                    }
                }
            }

            // causal attention per position (reads only q/k/v rows)
            if self.int_attn {
                let (ksrc, vsrc, stride): (&[f32], &[f32], usize) = match &self.rule {
                    QuantRule::Dynamic { rows, .. } => (&ksc[..], &vsc[..], *rows),
                    QuantRule::Static { .. } => {
                        (&self.k_attn[li * h..(li + 1) * h], &self.v_attn[li * h..(li + 1) * h], 0)
                    }
                    QuantRule::None => unreachable!("int_attn requires a quantized cache"),
                };
                for p in 0..n {
                    attend_i8(
                        &qq[p * d..(p + 1) * d],
                        &qs[p * h..(p + 1) * h],
                        &kq[..(p + 1) * d],
                        &vq[..(p + 1) * d],
                        ksrc,
                        vsrc,
                        stride,
                        h,
                        d,
                        p + 1,
                        &mut scores[..p + 1],
                        &mut ctx_all[p * d..(p + 1) * d],
                    );
                }
            } else {
                for p in 0..n {
                    attend_f32(
                        &q_all[p * d..(p + 1) * d],
                        &k_all[..(p + 1) * d],
                        &v_all[..(p + 1) * d],
                        h,
                        d,
                        p + 1,
                        &mut scores[..p + 1],
                        &mut ctx_all[p * d..(p + 1) * d],
                    );
                }
            }

            // output projection + residual
            self.seq_linear(
                self.int_linear,
                &mut ctx_all,
                n,
                d,
                cfg.policy.acts.bits,
                st.sa_o,
                &mut xq,
                &mut sx,
                &mut acc,
                &mut [(&lw.wo, &mut o_all[..n * d])],
            );
            for (xv, ov) in x.iter_mut().zip(&o_all) {
                *xv += *ov;
            }

            // FFN
            for p in 0..n {
                rmsnorm_into(&x[p * d..(p + 1) * d], &lw.ln2, &mut hn[p * d..(p + 1) * d]);
            }
            self.seq_linear(
                self.int_linear,
                &mut hn,
                n,
                d,
                cfg.policy.acts.bits,
                st.sa_x2,
                &mut xq,
                &mut sx,
                &mut acc,
                &mut [(&lw.wg, &mut g_all[..n * f]), (&lw.wu, &mut u_all[..n * f])],
            );
            for (gv, uv) in g_all.iter_mut().zip(&u_all) {
                *gv = silu(*gv) * *uv;
            }
            self.seq_linear(
                self.int_linear,
                &mut g_all,
                n,
                f,
                cfg.policy.acts.bits,
                st.sa_d,
                &mut xq,
                &mut sx,
                &mut acc,
                &mut [(&lw.wd, &mut o_all[..n * d])],
            );
            for (xv, dv) in x.iter_mut().zip(&o_all) {
                *xv += *dv;
            }
        }

        let mut logits = vec![0f32; n * v];
        for p in 0..n {
            rmsnorm_into(&x[p * d..(p + 1) * d], &self.ln_f, &mut hn[p * d..(p + 1) * d]);
        }
        self.seq_linear(
            self.int_head,
            &mut hn,
            n,
            d,
            cfg.policy.head.bits,
            self.sa.as_ref().map(|st| st.sa_head),
            &mut xq,
            &mut sx,
            &mut acc,
            &mut [(&self.head, &mut logits[..n * v])],
        );
        Ok(logits)
    }

    /// Quantize `n` activation rows (`[n, in_dim]`, in place on the f32
    /// path) once, then run them through each `(weight, out)` pair —
    /// blocked GEMM on the packed path (`acc` is `i32` scratch, at least
    /// `GEMM_BLOCK · out_dim`), per-row matvec on the f32 path. Shared by
    /// the full-sequence forward and the cross-lane batched decode step:
    /// per row it quantizes exactly as `prep_act` and contracts exactly as
    /// the GEMV, which is what makes batched ≡ sequential bit-exact.
    fn seq_linear(
        &self,
        int: bool,
        acts: &mut [f32],
        n: usize,
        in_dim: usize,
        bits: u32,
        step: Option<f32>,
        xq: &mut [i8],
        sx: &mut [f32],
        acc: &mut [i32],
        outs: &mut [(&Linear, &mut [f32])],
    ) {
        if int {
            for p in 0..n {
                quant_rows_i8(
                    &acts[p * in_dim..(p + 1) * in_dim],
                    in_dim,
                    bits,
                    step,
                    &mut xq[p * in_dim..(p + 1) * in_dim],
                    &mut sx[p..p + 1],
                );
            }
            for (lin, out) in outs.iter_mut() {
                match lin {
                    Linear::Int8(ql) => ql.gemm_into(&xq[..n * in_dim], &sx[..n], acc, out),
                    Linear::F32 { .. } => unreachable!("packed path with an f32 weight"),
                }
            }
        } else {
            for p in 0..n {
                self.act_quant(&mut acts[p * in_dim..(p + 1) * in_dim], bits, step, 1);
            }
            for (lin, out) in outs.iter_mut() {
                let od = lin.out_dim();
                match lin {
                    Linear::F32 { w, .. } => {
                        for p in 0..n {
                            matvec_into(
                                &acts[p * in_dim..(p + 1) * in_dim],
                                w,
                                &mut out[p * od..(p + 1) * od],
                            );
                        }
                    }
                    Linear::Int8(_) => unreachable!("f32 path with a packed weight"),
                }
            }
        }
    }
}

/// One layer's static activation steps, or all-None for dynamic precisions.
#[derive(Clone, Copy, Default)]
struct LayerSteps {
    sa_x1: Option<f32>,
    sa_q: Option<f32>,
    sa_o: Option<f32>,
    sa_x2: Option<f32>,
    sa_d: Option<f32>,
}

/// Small host config the unit tests across modules share.
#[cfg(test)]
pub(crate) fn tiny_host_cfg(quantized: bool, act_dynamic: bool) -> HostCfg {
    let policy = match (quantized, act_dynamic) {
        (false, _) => QuantPolicy::fp16(),
        (true, true) => QuantPolicy::w4a8kv8(),
        (true, false) => QuantPolicy::w4a8kv8().with_static_acts(),
    };
    HostCfg {
        vocab: 256,
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        d_ff: 64,
        seq_len: 16,
        policy,
        rope_theta: 10000.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::evalharness::decode::argmax;

    #[test]
    fn host_spec_matches_python_param_spec() {
        let spec = host_param_spec(&tiny_host_cfg(true, false));
        let names = spec.param_names();
        assert_eq!(names.len(), 12 + 8 + 8);
        assert_eq!(names[0], "embed");
        assert!(names.contains(&"sc_k".to_string()));
        let spec_dyn = host_param_spec(&tiny_host_cfg(true, true));
        assert_eq!(spec_dyn.param_names().len(), 12 + 8);
    }

    #[test]
    fn builtin_cfgs_mirror_configs_py() {
        let tiny = builtin_model("tiny").unwrap();
        assert_eq!((tiny.d_model, tiny.n_layers, tiny.seq_len, tiny.fwd_batch), (128, 4, 64, 32));
        let tp = builtin_model("tiny-pallas").unwrap();
        assert!(tp.use_pallas);
        assert_eq!(tp.n_layers, 2);
        assert_eq!(builtin_model("small").unwrap().vocab, 512);
        assert!(builtin_model("huge").is_none());

        assert!(!builtin_prec("fp16").unwrap().quantized);
        assert!(!builtin_prec("a8s-c8-w4").unwrap().act_dynamic);
        assert_eq!(builtin_prec("a8d-c4-w4").unwrap().cache_bits, 4);
        assert!(builtin_prec("a8d-c8-w4-rot").unwrap().online_rot);
        assert!(builtin_prec("a8d-c8-w4").is_some());
        assert!(builtin_prec("int1").is_none());
        // the typed grammar means inline specs and presets resolve too
        let spec = builtin_prec("w4a8kv8").unwrap();
        assert!(spec.act_dynamic && spec.cache_bits == 8 && spec.weight_bits == 4);
        assert!(!builtin_prec("w4a8kv8:statacts").unwrap().act_dynamic);
        // the rotation ablation has no host forward
        let mc = builtin_model("tiny").unwrap();
        assert!(HostCfg::from_cfgs(&mc, &builtin_prec("a8d-c8-w4-rot").unwrap()).is_err());
    }

    #[test]
    fn quantized_builds_take_the_integer_path() {
        let cfg = tiny_host_cfg(true, true);
        let params = host_test_params(&cfg, 3);
        let int = HostModel::new(cfg.clone(), &params).unwrap();
        assert!(int.integer_path());
        let rf = HostModel::new_reference(cfg.clone(), &params).unwrap();
        assert!(!rf.integer_path());
        // packed weights shrink the resident footprint (embed stays f32,
        // so the tiny-model ratio lands above 2x rather than the full 4x)
        assert!(rf.weight_bytes() > 2 * int.weight_bytes());
        // fp16 has no integers to pack
        let fp = tiny_host_cfg(false, true);
        let fp_params = host_test_params(&fp, 3);
        assert!(!HostModel::new(fp, &fp_params).unwrap().integer_path());
    }

    #[test]
    fn incremental_and_seq_forwards_agree_exactly() {
        // the core identity forward_seq is built to satisfy, on the store
        // that matches each policy's deployment representation; swept more
        // broadly by proptests.rs and tests/kernels_integration.rs
        for (quantized, act_dynamic) in [(true, true), (true, false), (false, true)] {
            let cfg = tiny_host_cfg(quantized, act_dynamic);
            let params = host_test_params(&cfg, 41);
            let model = HostModel::new(cfg.clone(), &params).unwrap();
            let store = CacheStore::for_policy(&cfg.policy);
            let mut pool = model.make_pool(1, store).unwrap();
            let slot = pool.alloc().unwrap();
            let prompt = [1i32, 7, 130, 22, 4];
            let batched = model.forward_seq(&prompt).unwrap();
            for (pos, &tok) in prompt.iter().enumerate() {
                let inc = model.forward_token(&mut pool, slot, tok, pos, true).unwrap().unwrap();
                assert_eq!(
                    &batched[pos * cfg.vocab..(pos + 1) * cfg.vocab],
                    &inc[..],
                    "quantized={quantized} act_dynamic={act_dynamic} pos={pos}"
                );
            }
        }
    }

    #[test]
    fn paged_pool_decode_is_bit_identical_to_slab() {
        // the paged-KV tentpole identity at unit scale: the same decode
        // through a paged pool (windows spanning several pages, prefix
        // pages attached shared) produces *bit-identical* logits to the
        // slab pool, on every policy family. Swept through the real
        // scheduler by proptests.rs.
        for (quantized, act_dynamic) in [(true, true), (true, false), (false, true)] {
            let cfg = tiny_host_cfg(quantized, act_dynamic);
            let params = host_test_params(&cfg, 61);
            let model = HostModel::new(cfg.clone(), &params).unwrap();
            let store = CacheStore::for_policy(&cfg.policy);
            let mut slab = model.make_pool(2, store).unwrap();
            let layout = KvLayout::Paged { page_size: 4, total_pages: None, sharing: true };
            let mut paged = model.make_pool_with(2, store, layout).unwrap();
            let prompt = [1i32, 7, 130, 22, 4, 9, 2, 66]; // 2 full pages
            let ss = slab.alloc().unwrap();
            let (sp, shared) = paged.alloc_with_prompt(&prompt).unwrap();
            assert_eq!(shared, 0, "nothing sealed yet");
            let mut scratch = DecodeScratch::for_cfg(&cfg);
            let mut toks = prompt.to_vec();
            for (p, &t) in prompt[..prompt.len() - 1].iter().enumerate() {
                model.forward_token_into(&mut slab, ss, t, p, false, &mut scratch).unwrap();
                model.forward_token_into(&mut paged, sp, t, p, false, &mut scratch).unwrap();
            }
            for step in 0..6 {
                let (pos, &tok) = (toks.len() - 1, toks.last().unwrap());
                let a = model
                    .forward_token_into(&mut slab, ss, tok, pos, true, &mut scratch)
                    .unwrap()
                    .unwrap()
                    .to_vec();
                let b = model
                    .forward_token_into(&mut paged, sp, tok, pos, true, &mut scratch)
                    .unwrap()
                    .unwrap();
                assert_eq!(
                    a, b,
                    "quantized={quantized} act_dynamic={act_dynamic} step={step}: \
                     paged logits diverged from slab"
                );
                toks.push(argmax(b) as i32);
            }
            // a second paged session with the same prompt attaches the two
            // sealed prefix pages and still decodes bit-identically: the
            // shared positions are skipped at prefill, and its first write
            // (the prompt-tail fold below) COW-forks out of the shared page
            let (sp2, shared2) = paged.alloc_with_prompt(&prompt).unwrap();
            assert_eq!(shared2, 8, "both full prompt pages must attach");
            assert!(paged.ledger().shared >= 2);
            let (pos, tok) = (prompt.len() - 1, prompt[prompt.len() - 1]);
            let b2 = model
                .forward_token_into(&mut paged, sp2, tok, pos, true, &mut scratch)
                .unwrap()
                .unwrap();
            // same prompt → same first decode logits as the slab run's
            let sref = slab.alloc().unwrap();
            for (p, &t) in prompt[..pos].iter().enumerate() {
                model.forward_token_into(&mut slab, sref, t, p, false, &mut scratch).unwrap();
            }
            let b2 = b2.to_vec();
            let aref = model
                .forward_token_into(&mut slab, sref, tok, pos, true, &mut scratch)
                .unwrap()
                .unwrap();
            assert_eq!(
                aref, &b2[..],
                "quantized={quantized} act_dynamic={act_dynamic}: shared-prefix lane diverged"
            );
            paged.free(sp);
            paged.free(sp2);
            assert!(paged.all_pages_free());
        }
    }

    #[test]
    fn batched_cross_lane_step_is_bit_identical_to_sequential() {
        // the PR-5 tentpole identity at unit scale: three lanes at ragged
        // positions advanced through forward_tokens_batch (one fused GEMM
        // per matrix) produce *bit-identical* logits to three sequential
        // forward_token_into calls, on every policy family. Swept through
        // the real scheduler by proptests.rs.
        use crate::kernels::BatchScratch;
        for (quantized, act_dynamic) in [(true, true), (true, false), (false, true)] {
            let cfg = tiny_host_cfg(quantized, act_dynamic);
            let params = host_test_params(&cfg, 51);
            let model = HostModel::new(cfg.clone(), &params).unwrap();
            let store = CacheStore::for_policy(&cfg.policy);
            let mut pool_s = model.make_pool(3, store).unwrap();
            let mut pool_b = model.make_pool(3, store).unwrap();
            let mut scratch = DecodeScratch::for_cfg(&cfg);
            let mut bscratch = BatchScratch::for_cfg(&cfg, 3);
            // ragged prefixes — staggered admissions are the normal state
            let prompts: [&[i32]; 3] = [&[1, 7, 130], &[2, 9], &[3, 5, 22, 10, 4]];
            let mut slots_s = vec![];
            let mut slots_b = vec![];
            for p in prompts.iter() {
                let (ss, sb) = (pool_s.alloc().unwrap(), pool_b.alloc().unwrap());
                for (pos, &t) in p[..p.len() - 1].iter().enumerate() {
                    model
                        .forward_token_into(&mut pool_s, ss, t, pos, false, &mut scratch)
                        .unwrap();
                    model
                        .forward_token_into(&mut pool_b, sb, t, pos, false, &mut scratch)
                        .unwrap();
                }
                slots_s.push(ss);
                slots_b.push(sb);
            }
            let v = cfg.vocab;
            let mut rows: Vec<Vec<i32>> = prompts.iter().map(|p| p.to_vec()).collect();
            for step in 0..4 {
                let lanes: Vec<BatchLane> = rows
                    .iter()
                    .zip(&slots_b)
                    .map(|(r, &slot)| BatchLane {
                        slot,
                        tok: *r.last().unwrap(),
                        pos: r.len() - 1,
                    })
                    .collect();
                let blg = model
                    .forward_tokens_batch(&mut pool_b, &lanes, true, &mut bscratch)
                    .unwrap()
                    .unwrap()
                    .to_vec();
                for (l, row) in rows.iter_mut().enumerate() {
                    let (tok, pos) = (*row.last().unwrap(), row.len() - 1);
                    let slg = model
                        .forward_token_into(&mut pool_s, slots_s[l], tok, pos, true, &mut scratch)
                        .unwrap()
                        .unwrap();
                    assert_eq!(
                        &blg[l * v..(l + 1) * v],
                        slg,
                        "quantized={quantized} act_dynamic={act_dynamic} step={step} lane={l}: \
                         batched logits diverged from sequential"
                    );
                    row.push(argmax(slg) as i32);
                }
            }
        }
    }

    #[test]
    fn batched_step_rejects_bad_lanes() {
        use crate::kernels::BatchScratch;
        let cfg = tiny_host_cfg(true, true);
        let params = host_test_params(&cfg, 53);
        let model = HostModel::new(cfg.clone(), &params).unwrap();
        let mut pool = model.make_pool(2, CacheStore::Int8).unwrap();
        let (a, b) = (pool.alloc().unwrap(), pool.alloc().unwrap());
        let mut s = BatchScratch::for_cfg(&cfg, 2);
        let lane = |slot, tok, pos| BatchLane { slot, tok, pos };
        // empty batch, out-of-window position, out-of-vocab token, and a
        // slot stepped twice in one batch are all hard errors
        assert!(model.forward_tokens_batch(&mut pool, &[], true, &mut s).is_err());
        assert!(model
            .forward_tokens_batch(&mut pool, &[lane(a, 1, cfg.seq_len)], true, &mut s)
            .is_err());
        assert!(model
            .forward_tokens_batch(&mut pool, &[lane(a, 9999, 0)], true, &mut s)
            .is_err());
        assert!(model
            .forward_tokens_batch(&mut pool, &[lane(a, 1, 0), lane(a, 2, 1)], true, &mut s)
            .is_err());
        // a well-formed two-lane batch still works after the rejections
        let lg = model
            .forward_tokens_batch(&mut pool, &[lane(a, 1, 0), lane(b, 2, 0)], true, &mut s)
            .unwrap()
            .unwrap();
        assert_eq!(lg.len(), 2 * cfg.vocab);
        assert!(lg.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn forward_seq_truncates_at_the_window() {
        let cfg = tiny_host_cfg(true, true);
        let params = host_test_params(&cfg, 5);
        let model = HostModel::new(cfg.clone(), &params).unwrap();
        let long: Vec<i32> = (0..cfg.seq_len as i32 + 4).map(|i| i % 200).collect();
        let logits = model.forward_seq(&long).unwrap();
        assert_eq!(logits.len(), cfg.seq_len * cfg.vocab);
        assert!(logits.iter().all(|l| l.is_finite()));
        assert!(model.forward_seq(&[]).is_err());
        assert!(model.forward_seq(&[9999]).is_err());
    }

    #[test]
    fn greedy_continuations_agree_between_paths() {
        let cfg = tiny_host_cfg(true, true);
        let params = host_test_params(&cfg, 9);
        let model = HostModel::new(cfg.clone(), &params).unwrap();
        let v = cfg.vocab;

        // batched: full recompute per emitted token
        let mut row_b = vec![1i32, 3, 22, 10];
        for _ in 0..4 {
            let lg = model.forward_seq(&row_b).unwrap();
            let last = &lg[(row_b.len() - 1) * v..row_b.len() * v];
            row_b.push(argmax(last) as i32);
        }

        // incremental: one token per step over the deployment-store pool
        let mut pool = model.make_pool(1, CacheStore::Int8).unwrap();
        let slot = pool.alloc().unwrap();
        let mut row_i = vec![1i32, 3, 22, 10];
        for (pos, &tok) in row_i.clone().iter().enumerate().take(row_i.len() - 1) {
            model.forward_token(&mut pool, slot, tok, pos, false).unwrap();
        }
        for _ in 0..4 {
            let pos = row_i.len() - 1;
            let lg = model.forward_token(&mut pool, slot, row_i[pos], pos, true).unwrap().unwrap();
            row_i.push(argmax(&lg) as i32);
        }
        assert_eq!(row_b, row_i);
    }

    #[test]
    fn integer_and_reference_builds_agree_on_greedy_tokens() {
        // the deployability identity at unit scale (tests/
        // kernels_integration.rs sweeps it over the builtin models): the
        // integer kernels and the f32 fake-quant reference pick the same
        // greedy tokens, and their logits track within 1e-4 relative
        for act_dynamic in [true, false] {
            let cfg = tiny_host_cfg(true, act_dynamic);
            let params = host_test_params(&cfg, 17);
            let int = HostModel::new(cfg.clone(), &params).unwrap();
            let rf = HostModel::new_reference(cfg.clone(), &params).unwrap();
            let prompt = [1i32, 9, 77, 4];
            let li = int.forward_seq(&prompt).unwrap();
            let lr = rf.forward_seq(&prompt).unwrap();
            for (pos, (a, b)) in li
                .chunks(cfg.vocab)
                .zip(lr.chunks(cfg.vocab))
                .enumerate()
            {
                assert_eq!(
                    argmax(a),
                    argmax(b),
                    "act_dynamic={act_dynamic} pos {pos}: greedy choice diverged"
                );
                for (x, y) in a.iter().zip(b) {
                    let tol = 1e-4 * x.abs().max(y.abs()).max(1.0);
                    assert!((x - y).abs() <= tol, "pos {pos}: {x} vs {y}");
                }
            }
        }
    }
}
