//! Slab-allocated KV-cache pool with quantized storage.
//!
//! Each decode session (a serve lane, or an eval/self-generation row) owns
//! one *slot*: a contiguous per-layer slab of K and V rows, one row of
//! `dim` channels per generated position. The pool applies the paper's
//! cache quantization **on write** (Figure 2: C-bit K/V tensors) and
//! dequantizes **on read**, so the decode path only ever sees f32 rows
//! while the resident representation is the one a NorthPole-class
//! deployment would hold.
//!
//! Two storage modes share one quantization rule:
//! * [`CacheStore::F32`] — the QAT "fake quant" view: quantized values kept
//!   as f32 (round(clip(x/s))*s).
//! * [`CacheStore::Int8`] — the deployment view: the integers themselves
//!   plus their steps. By the pack/unpack losslessness invariant (see
//!   `quant::pack` and `prop_pack_unpack_exactly_lossless_2_to_8_bits`) both
//!   modes dequantize to bit-identical f32, which is exactly the paper's
//!   deployability claim — the serve integration test asserts greedy decode
//!   is token-identical across the two.

use anyhow::{bail, ensure, Result};

use crate::quant::{fake_quant_scalar, qbounds, round_half_even, EPS};

/// How cache rows are quantized on write.
#[derive(Clone, Debug)]
pub enum QuantRule {
    /// No cache quantization (fp16-precision serving).
    None,
    /// Fixed calibrated steps, one per (layer, channel); `k_steps` and
    /// `v_steps` are `[layers * dim]` row-major. This is the static ('s')
    /// cache mode: steps come from the trained `sc_k`/`sc_v` parameters or
    /// from offline calibration.
    Static { bits: u32, k_steps: Vec<f32>, v_steps: Vec<f32> },
    /// Per-write dynamic steps over `rows` equal sub-rows of each cache row
    /// (one per attention head, matching `ste_dynamic_quantize`'s last-axis
    /// reduction on `[B, H, S, d_head]`). This is the dynamic ('d') mode.
    Dynamic { bits: u32, rows: usize },
}

impl QuantRule {
    /// Apply this rule's fake quantization to one position's K and V rows
    /// in place — the F32-store view of a cache write. Shared by
    /// [`KvPool::write`] and `HostModel::forward_seq` so the pooled
    /// incremental path and the batched full-sequence path quantize the
    /// cache bit-identically.
    pub fn quantize_f32(&self, layer: usize, k: &mut [f32], v: &mut [f32]) {
        debug_assert_eq!(k.len(), v.len());
        match self {
            QuantRule::None => {}
            QuantRule::Static { bits, k_steps, v_steps } => {
                let sb = layer * k.len();
                for c in 0..k.len() {
                    k[c] = fake_quant_scalar(k[c], k_steps[sb + c], *bits);
                    v[c] = fake_quant_scalar(v[c], v_steps[sb + c], *bits);
                }
            }
            QuantRule::Dynamic { bits, rows } => {
                let (_, qp) = qbounds(*bits);
                let sub = k.len() / rows;
                for r in 0..*rows {
                    let ks = dyn_step(&k[r * sub..(r + 1) * sub], qp);
                    let vs = dyn_step(&v[r * sub..(r + 1) * sub], qp);
                    for c in r * sub..(r + 1) * sub {
                        k[c] = fake_quant_scalar(k[c], ks, *bits);
                        v[c] = fake_quant_scalar(v[c], vs, *bits);
                    }
                }
            }
        }
    }
}

/// Resident representation of the quantized values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheStore {
    F32,
    Int8,
}

impl CacheStore {
    /// The store a policy serves with: quantized policies keep the K/V
    /// cache in the deployment INT8 representation, fp16 keeps f32. One
    /// rule shared by every host entry point (pipeline eval, `silq eval
    /// --backend host`, `silq serve`) so their outputs stay comparable.
    pub fn for_policy(policy: &crate::policy::QuantPolicy) -> CacheStore {
        if policy.quantized {
            CacheStore::Int8
        } else {
            CacheStore::F32
        }
    }

    /// Parse a `--cache` flag value; unknown values are a hard error
    /// naming the accepted set (never silently coerced to a store).
    pub fn parse(s: &str) -> Result<CacheStore> {
        match s {
            "int8" => Ok(CacheStore::Int8),
            "f32" => Ok(CacheStore::F32),
            other => bail!("unknown cache store {other:?} (accepted: int8|f32)"),
        }
    }
}

/// Slab pool: `slots` sessions x `layers` x `seq` positions x `dim` channels
/// for K and V each.
pub struct KvPool {
    pub slots: usize,
    pub layers: usize,
    pub seq: usize,
    pub dim: usize,
    pub store: CacheStore,
    rule: QuantRule,
    // F32 storage (quantized values kept as floats)
    kf: Vec<f32>,
    vf: Vec<f32>,
    // Int8 storage (integers + per-write dynamic scales)
    ki: Vec<i8>,
    vi: Vec<i8>,
    k_scales: Vec<f32>,
    v_scales: Vec<f32>,
    free: Vec<usize>,
    in_use: usize,
}

impl KvPool {
    pub fn new(
        slots: usize,
        layers: usize,
        seq: usize,
        dim: usize,
        store: CacheStore,
        rule: QuantRule,
    ) -> Result<KvPool> {
        let n = slots * layers * seq * dim;
        match &rule {
            QuantRule::None => {
                ensure!(store == CacheStore::F32, "integer storage needs a quantization rule");
            }
            QuantRule::Static { bits, k_steps, v_steps } => {
                ensure!((2..=8).contains(bits), "cache bits must be 2..=8, got {bits}");
                ensure!(
                    k_steps.len() == layers * dim && v_steps.len() == layers * dim,
                    "static steps must be [layers*dim]"
                );
            }
            QuantRule::Dynamic { bits, rows } => {
                ensure!((2..=8).contains(bits), "cache bits must be 2..=8, got {bits}");
                ensure!(*rows > 0 && dim % rows == 0, "dim {dim} not divisible into {rows} rows");
            }
        }
        let int8 = store == CacheStore::Int8;
        let n_scales = match &rule {
            QuantRule::Dynamic { rows, .. } if int8 => slots * layers * seq * rows,
            _ => 0,
        };
        Ok(KvPool {
            slots,
            layers,
            seq,
            dim,
            store,
            rule,
            kf: if int8 { vec![] } else { vec![0.0; n] },
            vf: if int8 { vec![] } else { vec![0.0; n] },
            ki: if int8 { vec![0; n] } else { vec![] },
            vi: if int8 { vec![0; n] } else { vec![] },
            k_scales: vec![0.0; n_scales],
            v_scales: vec![0.0; n_scales],
            free: (0..slots).rev().collect(),
            in_use: 0,
        })
    }

    /// Claim a session slot; `None` when the pool is exhausted.
    pub fn alloc(&mut self) -> Option<usize> {
        let s = self.free.pop()?;
        self.in_use += 1;
        Some(s)
    }

    /// Return a slot to the free list. Contents need no zeroing: positions
    /// are only ever read up to the owning session's length.
    pub fn free(&mut self, slot: usize) {
        debug_assert!(!self.free.contains(&slot), "double free of slot {slot}");
        self.free.push(slot);
        self.in_use -= 1;
    }

    pub fn slots_in_use(&self) -> usize {
        self.in_use
    }

    /// Deployment storage footprint in bytes (bit-packed integers + scales,
    /// matching `PackedTensor::storage_bytes` accounting).
    pub fn storage_bytes(&self) -> usize {
        let n = 2 * self.slots * self.layers * self.seq * self.dim; // K and V
        match (&self.rule, self.store) {
            (QuantRule::None, _) => n * 4,
            (_, CacheStore::F32) => n * 4,
            (QuantRule::Static { bits, k_steps, v_steps }, CacheStore::Int8) => {
                (n * *bits as usize + 7) / 8 + (k_steps.len() + v_steps.len()) * 4
            }
            (QuantRule::Dynamic { bits, .. }, CacheStore::Int8) => {
                (n * *bits as usize + 7) / 8 + (self.k_scales.len() + self.v_scales.len()) * 4
            }
        }
    }

    #[inline]
    fn base(&self, slot: usize, layer: usize, pos: usize) -> usize {
        debug_assert!(slot < self.slots && layer < self.layers && pos < self.seq);
        ((slot * self.layers + layer) * self.seq + pos) * self.dim
    }

    /// Quantize-on-write one position's K and V rows (`dim` channels each).
    pub fn write(&mut self, slot: usize, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        assert_eq!(k.len(), self.dim);
        assert_eq!(v.len(), self.dim);
        let base = self.base(slot, layer, pos);
        match (&self.rule, self.store) {
            (_, CacheStore::F32) => {
                self.kf[base..base + self.dim].copy_from_slice(k);
                self.vf[base..base + self.dim].copy_from_slice(v);
                self.rule.quantize_f32(
                    layer,
                    &mut self.kf[base..base + self.dim],
                    &mut self.vf[base..base + self.dim],
                );
            }
            (QuantRule::Static { bits, k_steps, v_steps }, CacheStore::Int8) => {
                let sb = layer * self.dim;
                for c in 0..self.dim {
                    self.ki[base + c] = qi(k[c], k_steps[sb + c], *bits);
                    self.vi[base + c] = qi(v[c], v_steps[sb + c], *bits);
                }
            }
            (QuantRule::Dynamic { bits, rows }, CacheStore::Int8) => {
                let (_, qp) = qbounds(*bits);
                let sub = self.dim / rows;
                let scale_base = ((slot * self.layers + layer) * self.seq + pos) * rows;
                for r in 0..*rows {
                    let ks = dyn_step(&k[r * sub..(r + 1) * sub], qp);
                    let vs = dyn_step(&v[r * sub..(r + 1) * sub], qp);
                    self.k_scales[scale_base + r] = ks;
                    self.v_scales[scale_base + r] = vs;
                    for c in r * sub..(r + 1) * sub {
                        self.ki[base + c] = qi(k[c], ks, *bits);
                        self.vi[base + c] = qi(v[c], vs, *bits);
                    }
                }
            }
            (QuantRule::None, CacheStore::Int8) => unreachable!("rejected by KvPool::new"),
        }
    }

    /// Dequantize-on-read positions `0..len` into `k_out`/`v_out`
    /// (`len * dim` f32 each, row-major by position).
    pub fn read_into(
        &self,
        slot: usize,
        layer: usize,
        len: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<()> {
        ensure!(len <= self.seq, "read past slab end: {len} > {}", self.seq);
        ensure!(k_out.len() == len * self.dim && v_out.len() == len * self.dim, "bad read buffer");
        let base = self.base(slot, layer, 0);
        match (&self.rule, self.store) {
            (_, CacheStore::F32) => {
                k_out.copy_from_slice(&self.kf[base..base + len * self.dim]);
                v_out.copy_from_slice(&self.vf[base..base + len * self.dim]);
            }
            (QuantRule::Static { k_steps, v_steps, .. }, CacheStore::Int8) => {
                let sb = layer * self.dim;
                for p in 0..len {
                    for c in 0..self.dim {
                        let i = p * self.dim + c;
                        k_out[i] = self.ki[base + i] as f32 * k_steps[sb + c].max(EPS);
                        v_out[i] = self.vi[base + i] as f32 * v_steps[sb + c].max(EPS);
                    }
                }
            }
            (QuantRule::Dynamic { rows, .. }, CacheStore::Int8) => {
                let sub = self.dim / rows;
                for p in 0..len {
                    let scale_base = ((slot * self.layers + layer) * self.seq + p) * rows;
                    for r in 0..*rows {
                        let (ks, vs) = (self.k_scales[scale_base + r], self.v_scales[scale_base + r]);
                        for c in r * sub..(r + 1) * sub {
                            let i = p * self.dim + c;
                            k_out[i] = self.ki[base + i] as f32 * ks;
                            v_out[i] = self.vi[base + i] as f32 * vs;
                        }
                    }
                }
            }
            (QuantRule::None, CacheStore::Int8) => bail!("unreachable: int8 without rule"),
        }
        Ok(())
    }
}

/// The integer half of `fake_quant_scalar` (same EPS floor, clamp and
/// round, minus the final multiply) — what the deployment target stores.
/// Kept next to the dequant paths so the pair stays bit-consistent with
/// `quant::fake_quant_scalar`.
#[inline]
fn qi(x: f32, s: f32, bits: u32) -> i8 {
    let (qn, qp) = qbounds(bits);
    let s = s.max(EPS);
    round_half_even((x / s).clamp(qn as f32, qp as f32)) as i8
}

/// Dynamic per-row step: max|x| / q_p, floored at EPS (the 'd' mode rule).
#[inline]
fn dyn_step(row: &[f32], qp: i64) -> f32 {
    let maxabs = row.iter().fold(0f32, |a, &b| a.max(b.abs()));
    (maxabs / qp as f32).max(EPS)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::fake_quant_scalar;
    use crate::util::Rng;

    fn rand_row(rng: &mut Rng, n: usize) -> Vec<f32> {
        rng.normal_vec(n, 0.3)
    }

    #[test]
    fn alloc_free_slab_cycle() {
        let mut p =
            KvPool::new(2, 1, 4, 8, CacheStore::F32, QuantRule::None).unwrap();
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert!(p.alloc().is_none());
        assert_eq!(p.slots_in_use(), 2);
        p.free(a);
        assert_eq!(p.alloc(), Some(a));
    }

    #[test]
    fn raw_roundtrip() {
        let mut rng = Rng::new(0);
        let mut p = KvPool::new(1, 2, 4, 8, CacheStore::F32, QuantRule::None).unwrap();
        let s = p.alloc().unwrap();
        let (k, v) = (rand_row(&mut rng, 8), rand_row(&mut rng, 8));
        p.write(s, 1, 2, &k, &v);
        let mut ko = vec![0.0; 3 * 8];
        let mut vo = vec![0.0; 3 * 8];
        p.read_into(s, 1, 3, &mut ko, &mut vo).unwrap();
        assert_eq!(&ko[16..24], &k[..]);
        assert_eq!(&vo[16..24], &v[..]);
    }

    #[test]
    fn static_int8_matches_fake_quant() {
        let mut rng = Rng::new(1);
        let dim = 8;
        let steps: Vec<f32> = (0..dim).map(|i| 0.01 + 0.003 * i as f32).collect();
        let rule = QuantRule::Static { bits: 8, k_steps: steps.clone(), v_steps: steps.clone() };
        let mut p = KvPool::new(1, 1, 2, dim, CacheStore::Int8, rule).unwrap();
        let s = p.alloc().unwrap();
        let (k, v) = (rand_row(&mut rng, dim), rand_row(&mut rng, dim));
        p.write(s, 0, 0, &k, &v);
        let mut ko = vec![0.0; dim];
        let mut vo = vec![0.0; dim];
        p.read_into(s, 0, 1, &mut ko, &mut vo).unwrap();
        for c in 0..dim {
            assert_eq!(ko[c], fake_quant_scalar(k[c], steps[c], 8));
            assert_eq!(vo[c], fake_quant_scalar(v[c], steps[c], 8));
        }
    }

    #[test]
    fn quantize_f32_matches_pool_write() {
        // the shared rule helper and the pooled write path must agree
        // bit-for-bit — forward_seq leans on this
        let mut rng = Rng::new(3);
        let (dim, layers) = (16, 2);
        for rule in [
            QuantRule::None,
            QuantRule::Dynamic { bits: 8, rows: 4 },
            QuantRule::Static {
                bits: 8,
                k_steps: (0..layers * dim).map(|_| rng.uniform() * 0.05 + 1e-3).collect(),
                v_steps: (0..layers * dim).map(|_| rng.uniform() * 0.05 + 1e-3).collect(),
            },
        ] {
            let mut p = KvPool::new(1, layers, 2, dim, CacheStore::F32, rule.clone()).unwrap();
            let s = p.alloc().unwrap();
            for layer in 0..layers {
                let (k, v) = (rand_row(&mut rng, dim), rand_row(&mut rng, dim));
                p.write(s, layer, 0, &k, &v);
                let (mut kq, mut vq) = (k.clone(), v.clone());
                rule.quantize_f32(layer, &mut kq, &mut vq);
                let mut ko = vec![0.0; dim];
                let mut vo = vec![0.0; dim];
                p.read_into(s, layer, 1, &mut ko, &mut vo).unwrap();
                assert_eq!(ko, kq);
                assert_eq!(vo, vq);
            }
        }
    }

    #[test]
    fn int8_and_f32_stores_dequantize_identically() {
        // the pool-level statement of the serve-path deployability invariant
        let mut rng = Rng::new(2);
        let (dim, rows) = (16, 4);
        for rule in [
            QuantRule::Dynamic { bits: 8, rows },
            QuantRule::Static {
                bits: 8,
                k_steps: (0..dim).map(|_| rng.uniform() * 0.05 + 1e-3).collect(),
                v_steps: (0..dim).map(|_| rng.uniform() * 0.05 + 1e-3).collect(),
            },
        ] {
            let mut pf = KvPool::new(1, 1, 4, dim, CacheStore::F32, rule.clone()).unwrap();
            let mut pi = KvPool::new(1, 1, 4, dim, CacheStore::Int8, rule).unwrap();
            let (sf, si) = (pf.alloc().unwrap(), pi.alloc().unwrap());
            for pos in 0..4 {
                let (k, v) = (rand_row(&mut rng, dim), rand_row(&mut rng, dim));
                pf.write(sf, 0, pos, &k, &v);
                pi.write(si, 0, pos, &k, &v);
            }
            let mut a = (vec![0.0; 4 * dim], vec![0.0; 4 * dim]);
            let mut b = (vec![0.0; 4 * dim], vec![0.0; 4 * dim]);
            pf.read_into(sf, 0, 4, &mut a.0, &mut a.1).unwrap();
            pi.read_into(si, 0, 4, &mut b.0, &mut b.1).unwrap();
            assert_eq!(a, b, "f32 and int8 stores must dequantize bit-identically");
        }
    }

    #[test]
    fn int8_storage_is_smaller() {
        let rule = QuantRule::Dynamic { bits: 8, rows: 4 };
        let pf = KvPool::new(4, 2, 8, 16, CacheStore::F32, rule.clone()).unwrap();
        let pi = KvPool::new(4, 2, 8, 16, CacheStore::Int8, rule).unwrap();
        assert!(pi.storage_bytes() * 2 < pf.storage_bytes());
    }

    #[test]
    fn cache_store_parse_and_policy_rule() {
        use crate::policy::QuantPolicy;
        assert_eq!(CacheStore::parse("int8").unwrap(), CacheStore::Int8);
        assert_eq!(CacheStore::parse("f32").unwrap(), CacheStore::F32);
        let e = CacheStore::parse("fp8").unwrap_err().to_string();
        assert!(e.contains("int8|f32"), "error must list the accepted set: {e}");
        assert_eq!(CacheStore::for_policy(&QuantPolicy::w4a8kv8()), CacheStore::Int8);
        assert_eq!(CacheStore::for_policy(&QuantPolicy::fp16()), CacheStore::F32);
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(KvPool::new(1, 1, 2, 8, CacheStore::Int8, QuantRule::None).is_err());
        assert!(KvPool::new(1, 1, 2, 8, CacheStore::Int8, QuantRule::Dynamic { bits: 16, rows: 2 })
            .is_err());
        assert!(KvPool::new(1, 1, 2, 8, CacheStore::Int8, QuantRule::Dynamic { bits: 8, rows: 3 })
            .is_err());
        let bad = QuantRule::Static { bits: 8, k_steps: vec![0.1; 4], v_steps: vec![0.1; 8] };
        assert!(KvPool::new(1, 1, 2, 8, CacheStore::Int8, bad).is_err());
    }
}
