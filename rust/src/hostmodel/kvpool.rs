//! Slab-allocated KV-cache pool with quantized storage.
//!
//! Each decode session (a serve lane, or an eval/self-generation row) owns
//! one *slot*: a contiguous per-layer slab of K and V rows, one row of
//! `dim` channels per generated position. The pool applies the paper's
//! cache quantization **on write** (Figure 2: C-bit K/V tensors). Readers
//! have two views:
//!
//! * [`KvPool::read_into`] — dequantize positions `0..len` into f32
//!   buffers (the fake-quant view; the f32 fallback decode path).
//! * [`KvPool::slab`] — the raw `i8` rows + their write steps, borrowed
//!   straight out of the slab with **no copy and no dequantization**; the
//!   integer attention kernel (`kernels::attend_i8`) computes `q·k` in
//!   `i32` directly over this view.
//!
//! Two storage modes share one quantization rule:
//! * [`CacheStore::F32`] — the QAT "fake quant" view: quantized values kept
//!   as f32 (round(clip(x/s))*s).
//! * [`CacheStore::Int8`] — the deployment view: the integers themselves
//!   plus their steps. By the pack/unpack losslessness invariant (see
//!   `quant::pack` and `prop_pack_unpack_exactly_lossless_2_to_8_bits`) both
//!   modes **dequantize** to bit-identical f32 — the paper's deployability
//!   claim at the value level, pinned by the unit tests below. Since the
//!   integer-kernel PR, *decode* over the Int8 store runs exact `i32` q·k
//!   over the slab while the F32 store attends over the fake-quant floats,
//!   so end-to-end logits agree to float-rounding (~1e-5 relative) rather
//!   than bit-for-bit; the serve integration test pins greedy decode
//!   token-identical across the two on the builtin models, where top-logit
//!   margins dwarf that rounding.

use anyhow::{bail, ensure, Result};

use crate::kernels::{dyn_step, qint};
use crate::quant::{fake_quant_prefloored, qbounds, EPS};

/// How cache rows are quantized on write.
#[derive(Clone, Debug)]
pub enum QuantRule {
    /// No cache quantization (fp16-precision serving).
    None,
    /// Fixed calibrated steps, one per (layer, channel); `k_steps` and
    /// `v_steps` are `[layers * dim]` row-major. This is the static ('s')
    /// cache mode: steps come from the trained `sc_k`/`sc_v` parameters or
    /// from offline calibration. Steps must be pre-floored at `quant::EPS`
    /// — build through [`QuantRule::floored`] (the floor is hoisted out of
    /// the per-channel write/read loops).
    Static {
        /// cache bit width
        bits: u32,
        /// per-(layer, channel) K steps, `[layers * dim]`
        k_steps: Vec<f32>,
        /// per-(layer, channel) V steps, `[layers * dim]`
        v_steps: Vec<f32>,
    },
    /// Per-write dynamic steps over `rows` equal sub-rows of each cache row
    /// (one per attention head, matching `ste_dynamic_quantize`'s last-axis
    /// reduction on `[B, H, S, d_head]`). This is the dynamic ('d') mode.
    Dynamic {
        /// cache bit width
        bits: u32,
        /// sub-rows per cache row (attention heads)
        rows: usize,
    },
}

impl QuantRule {
    /// Floor the static step vectors at `quant::EPS` once, so the write,
    /// read and attention inner loops can use them directly. Bit-identical
    /// (`s.max(EPS)` is idempotent and the dynamic step is floored at
    /// computation); both [`KvPool::new`] and `HostModel::new` apply this.
    pub fn floored(mut self) -> QuantRule {
        if let QuantRule::Static { k_steps, v_steps, .. } = &mut self {
            for s in k_steps.iter_mut().chain(v_steps.iter_mut()) {
                *s = s.max(EPS);
            }
        }
        self
    }

    /// Apply this rule's fake quantization to one position's K and V rows
    /// in place — the F32-store view of a cache write. Shared by
    /// [`KvPool::write`] and `HostModel::forward_seq` so the pooled
    /// incremental path and the batched full-sequence path quantize the
    /// cache bit-identically. Static steps must be pre-floored
    /// ([`QuantRule::floored`]).
    pub fn quantize_f32(&self, layer: usize, k: &mut [f32], v: &mut [f32]) {
        debug_assert_eq!(k.len(), v.len());
        match self {
            QuantRule::None => {}
            QuantRule::Static { bits, k_steps, v_steps } => {
                let sb = layer * k.len();
                for c in 0..k.len() {
                    k[c] = fake_quant_prefloored(k[c], k_steps[sb + c], *bits);
                    v[c] = fake_quant_prefloored(v[c], v_steps[sb + c], *bits);
                }
            }
            QuantRule::Dynamic { bits, rows } => {
                let (_, qp) = qbounds(*bits);
                let sub = k.len() / rows;
                for r in 0..*rows {
                    let ks = dyn_step(&k[r * sub..(r + 1) * sub], qp);
                    let vs = dyn_step(&v[r * sub..(r + 1) * sub], qp);
                    for c in r * sub..(r + 1) * sub {
                        k[c] = fake_quant_prefloored(k[c], ks, *bits);
                        v[c] = fake_quant_prefloored(v[c], vs, *bits);
                    }
                }
            }
        }
    }

    /// Integer twin of [`QuantRule::quantize_f32`]: quantize one position's
    /// K and V rows into `i8` buffers — the representation the Int8 store
    /// keeps and `kernels::attend_i8` consumes. For the dynamic rule the
    /// per-sub-row steps land in `k_sc`/`v_sc` (`rows` values each); the
    /// static rule reads its pre-floored step vectors and leaves the scale
    /// slices untouched (its attention steps are per layer — see
    /// `HostModel`). Shared by [`KvPool::write`] and
    /// `HostModel::forward_seq`, which is what makes the incremental and
    /// batched integer paths bit-identical. No-op for [`QuantRule::None`].
    pub fn quantize_i8(
        &self,
        layer: usize,
        k: &[f32],
        v: &[f32],
        kq: &mut [i8],
        vq: &mut [i8],
        k_sc: &mut [f32],
        v_sc: &mut [f32],
    ) {
        debug_assert_eq!(k.len(), v.len());
        match self {
            QuantRule::None => {}
            QuantRule::Static { bits, k_steps, v_steps } => {
                let sb = layer * k.len();
                for c in 0..k.len() {
                    kq[c] = qint(k[c], k_steps[sb + c], *bits) as i8;
                    vq[c] = qint(v[c], v_steps[sb + c], *bits) as i8;
                }
            }
            QuantRule::Dynamic { bits, rows } => {
                let (_, qp) = qbounds(*bits);
                let sub = k.len() / rows;
                for r in 0..*rows {
                    let ks = dyn_step(&k[r * sub..(r + 1) * sub], qp);
                    let vs = dyn_step(&v[r * sub..(r + 1) * sub], qp);
                    k_sc[r] = ks;
                    v_sc[r] = vs;
                    for c in r * sub..(r + 1) * sub {
                        kq[c] = qint(k[c], ks, *bits) as i8;
                        vq[c] = qint(v[c], vs, *bits) as i8;
                    }
                }
            }
        }
    }
}

/// Resident representation of the quantized values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheStore {
    /// fake-quant view: quantized values kept as f32
    F32,
    /// deployment view: the integers + their steps
    Int8,
}

impl CacheStore {
    /// The store a policy serves with: quantized policies keep the K/V
    /// cache in the deployment INT8 representation, fp16 keeps f32. One
    /// rule shared by every host entry point (pipeline eval, `silq eval
    /// --backend host`, `silq serve`) so their outputs stay comparable.
    pub fn for_policy(policy: &crate::policy::QuantPolicy) -> CacheStore {
        if policy.quantized {
            CacheStore::Int8
        } else {
            CacheStore::F32
        }
    }

    /// Parse a `--cache` flag value; unknown values are a hard error
    /// naming the accepted set (never silently coerced to a store).
    pub fn parse(s: &str) -> Result<CacheStore> {
        match s {
            "int8" => Ok(CacheStore::Int8),
            "f32" => Ok(CacheStore::F32),
            other => bail!("unknown cache store {other:?} (accepted: int8|f32)"),
        }
    }
}

/// Borrowed view of one (slot, layer)'s raw quantized K/V rows — what
/// [`KvPool::slab`] hands the integer attention kernel. No copy is made:
/// the slices alias the resident slab.
pub struct KvSlabRef<'a> {
    /// `i8` K rows, `[len * dim]` row-major by position
    pub k: &'a [i8],
    /// `i8` V rows, `[len * dim]` row-major by position
    pub v: &'a [i8],
    /// per-(position, head) K write steps `[len * rows]` — empty for the
    /// static rule (whose steps live in the `QuantRule` / the model)
    pub k_scales: &'a [f32],
    /// per-(position, head) V write steps `[len * rows]` — empty for the
    /// static rule
    pub v_scales: &'a [f32],
    /// sub-rows (heads) per position for the dynamic rule; 0 for static
    pub rows: usize,
}

/// Slab pool: `slots` sessions x `layers` x `seq` positions x `dim` channels
/// for K and V each.
pub struct KvPool {
    /// concurrent sessions
    pub slots: usize,
    /// model layers
    pub layers: usize,
    /// context window (positions per slot)
    pub seq: usize,
    /// channels per row (`d_model`)
    pub dim: usize,
    /// resident representation
    pub store: CacheStore,
    rule: QuantRule,
    // F32 storage (quantized values kept as floats)
    kf: Vec<f32>,
    vf: Vec<f32>,
    // Int8 storage (integers + per-write dynamic scales)
    ki: Vec<i8>,
    vi: Vec<i8>,
    k_scales: Vec<f32>,
    v_scales: Vec<f32>,
    free: Vec<usize>,
    in_use: usize,
}

impl KvPool {
    /// Build a pool; the rule's static steps are floored here once
    /// ([`QuantRule::floored`]).
    pub fn new(
        slots: usize,
        layers: usize,
        seq: usize,
        dim: usize,
        store: CacheStore,
        rule: QuantRule,
    ) -> Result<KvPool> {
        let n = slots * layers * seq * dim;
        match &rule {
            QuantRule::None => {
                ensure!(store == CacheStore::F32, "integer storage needs a quantization rule");
            }
            QuantRule::Static { bits, k_steps, v_steps } => {
                ensure!((2..=8).contains(bits), "cache bits must be 2..=8, got {bits}");
                ensure!(
                    k_steps.len() == layers * dim && v_steps.len() == layers * dim,
                    "static steps must be [layers*dim]"
                );
            }
            QuantRule::Dynamic { bits, rows } => {
                ensure!((2..=8).contains(bits), "cache bits must be 2..=8, got {bits}");
                ensure!(*rows > 0 && dim % rows == 0, "dim {dim} not divisible into {rows} rows");
            }
        }
        let int8 = store == CacheStore::Int8;
        let n_scales = match &rule {
            QuantRule::Dynamic { rows, .. } if int8 => slots * layers * seq * rows,
            _ => 0,
        };
        Ok(KvPool {
            slots,
            layers,
            seq,
            dim,
            store,
            rule: rule.floored(),
            kf: if int8 { vec![] } else { vec![0.0; n] },
            vf: if int8 { vec![] } else { vec![0.0; n] },
            ki: if int8 { vec![0; n] } else { vec![] },
            vi: if int8 { vec![0; n] } else { vec![] },
            k_scales: vec![0.0; n_scales],
            v_scales: vec![0.0; n_scales],
            free: (0..slots).rev().collect(),
            in_use: 0,
        })
    }

    /// The (floored) quantization rule this pool writes with.
    pub fn rule(&self) -> &QuantRule {
        &self.rule
    }

    /// Claim a session slot; `None` when the pool is exhausted. An armed
    /// `kv@N` fault plan ([`crate::faults`]) forces exhaustion on planned
    /// attempts — exercising the same typed-reject path a genuinely full
    /// pool takes, never a distinct failure mode.
    pub fn alloc(&mut self) -> Option<usize> {
        if crate::faults::should_inject(crate::faults::Site::KvAlloc) {
            return None;
        }
        let s = self.free.pop()?;
        self.in_use += 1;
        Some(s)
    }

    /// Return a slot to the free list. Contents need no zeroing: positions
    /// are only ever read up to the owning session's length.
    ///
    /// Out-of-range slots and double frees are hard errors (release
    /// asserts, not `debug_assert!`): in release either would silently
    /// corrupt the free list and surface as a confusing panic far from the
    /// bug — a lane double-freeing under load must fail *here*. The
    /// double-free scan is O(free slots), noise next to a decode step.
    pub fn free(&mut self, slot: usize) {
        assert!(slot < self.slots, "free of out-of-range slot {slot} (pool has {})", self.slots);
        assert!(!self.free.contains(&slot), "double free of slot {slot}");
        self.free.push(slot);
        self.in_use -= 1;
    }

    /// Sessions currently holding a slot.
    pub fn slots_in_use(&self) -> usize {
        self.in_use
    }

    /// Whether every session slot has been returned — the shutdown
    /// invariant the serve soak test pins (a lane leak shows up here long
    /// before it shows up as pool exhaustion under load).
    pub fn all_slots_free(&self) -> bool {
        self.in_use == 0 && self.free.len() == self.slots
    }

    /// Deployment storage footprint in bytes (bit-packed integers + scales,
    /// matching `PackedTensor::storage_bytes` accounting).
    pub fn storage_bytes(&self) -> usize {
        let n = 2 * self.slots * self.layers * self.seq * self.dim; // K and V
        match (&self.rule, self.store) {
            (QuantRule::None, _) => n * 4,
            (_, CacheStore::F32) => n * 4,
            (QuantRule::Static { bits, k_steps, v_steps }, CacheStore::Int8) => {
                (n * *bits as usize + 7) / 8 + (k_steps.len() + v_steps.len()) * 4
            }
            (QuantRule::Dynamic { bits, .. }, CacheStore::Int8) => {
                (n * *bits as usize + 7) / 8 + (self.k_scales.len() + self.v_scales.len()) * 4
            }
        }
    }

    /// Bytes the attention read path touches per decoded token when the
    /// prefix holds `len` positions: K and V rows across every layer, plus
    /// the dynamic write steps on the Int8 store. The integer slab reads
    /// one byte per channel where the f32 path reads four — the bench
    /// harness reports this next to decode tok/s.
    pub fn read_bytes_per_token(&self, len: usize) -> usize {
        let rows = match (&self.rule, self.store) {
            (QuantRule::Dynamic { rows, .. }, CacheStore::Int8) => *rows,
            _ => 0,
        };
        let elem = if self.store == CacheStore::Int8 { 1 } else { 4 };
        self.layers * (2 * len * self.dim * elem + 2 * len * rows * 4)
    }

    #[inline]
    fn base(&self, slot: usize, layer: usize, pos: usize) -> usize {
        debug_assert!(slot < self.slots && layer < self.layers && pos < self.seq);
        ((slot * self.layers + layer) * self.seq + pos) * self.dim
    }

    /// Quantize-on-write one position's K and V rows (`dim` channels each).
    pub fn write(&mut self, slot: usize, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        assert_eq!(k.len(), self.dim);
        assert_eq!(v.len(), self.dim);
        let base = self.base(slot, layer, pos);
        if self.store == CacheStore::F32 {
            self.kf[base..base + self.dim].copy_from_slice(k);
            self.vf[base..base + self.dim].copy_from_slice(v);
            self.rule.quantize_f32(
                layer,
                &mut self.kf[base..base + self.dim],
                &mut self.vf[base..base + self.dim],
            );
            return;
        }
        // Int8 store: quantize straight into the slab. The static rule has
        // no per-write scales (`rows == 0` slices an empty range).
        let rows = match &self.rule {
            QuantRule::Dynamic { rows, .. } => *rows,
            _ => 0,
        };
        let sb = ((slot * self.layers + layer) * self.seq + pos) * rows;
        self.rule.quantize_i8(
            layer,
            k,
            v,
            &mut self.ki[base..base + self.dim],
            &mut self.vi[base..base + self.dim],
            &mut self.k_scales[sb..sb + rows],
            &mut self.v_scales[sb..sb + rows],
        );
    }

    /// Borrow the raw `i8` K/V rows (and dynamic write steps) of positions
    /// `0..len` — zero-copy input for `kernels::attend_i8`. `None` on the
    /// F32 store, which keeps no integers. `len` past the window is a hard
    /// error (like [`KvPool::free`]): the slab is contiguous across layers,
    /// so a release over-read would silently attend over the next layer's
    /// rows.
    pub fn slab(&self, slot: usize, layer: usize, len: usize) -> Option<KvSlabRef<'_>> {
        if self.store != CacheStore::Int8 {
            return None;
        }
        assert!(len <= self.seq, "slab read past the window: {len} > {}", self.seq);
        let base = self.base(slot, layer, 0);
        let rows = match &self.rule {
            QuantRule::Dynamic { rows, .. } => *rows,
            _ => 0,
        };
        let (k_scales, v_scales) = if rows > 0 {
            let sb = (slot * self.layers + layer) * self.seq * rows;
            (&self.k_scales[sb..sb + len * rows], &self.v_scales[sb..sb + len * rows])
        } else {
            (&[][..], &[][..])
        };
        Some(KvSlabRef {
            k: &self.ki[base..base + len * self.dim],
            v: &self.vi[base..base + len * self.dim],
            k_scales,
            v_scales,
            rows,
        })
    }

    /// Dequantize-on-read positions `0..len` into `k_out`/`v_out`
    /// (`len * dim` f32 each, row-major by position).
    pub fn read_into(
        &self,
        slot: usize,
        layer: usize,
        len: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<()> {
        ensure!(len <= self.seq, "read past slab end: {len} > {}", self.seq);
        ensure!(k_out.len() == len * self.dim && v_out.len() == len * self.dim, "bad read buffer");
        let base = self.base(slot, layer, 0);
        match (&self.rule, self.store) {
            (_, CacheStore::F32) => {
                k_out.copy_from_slice(&self.kf[base..base + len * self.dim]);
                v_out.copy_from_slice(&self.vf[base..base + len * self.dim]);
            }
            (QuantRule::Static { k_steps, v_steps, .. }, CacheStore::Int8) => {
                let sb = layer * self.dim;
                for p in 0..len {
                    for c in 0..self.dim {
                        let i = p * self.dim + c;
                        k_out[i] = self.ki[base + i] as f32 * k_steps[sb + c];
                        v_out[i] = self.vi[base + i] as f32 * v_steps[sb + c];
                    }
                }
            }
            (QuantRule::Dynamic { rows, .. }, CacheStore::Int8) => {
                let sub = self.dim / rows;
                for p in 0..len {
                    let scale_base = ((slot * self.layers + layer) * self.seq + p) * rows;
                    for r in 0..*rows {
                        let (ks, vs) = (self.k_scales[scale_base + r], self.v_scales[scale_base + r]);
                        for c in r * sub..(r + 1) * sub {
                            let i = p * self.dim + c;
                            k_out[i] = self.ki[base + i] as f32 * ks;
                            v_out[i] = self.vi[base + i] as f32 * vs;
                        }
                    }
                }
            }
            (QuantRule::None, CacheStore::Int8) => bail!("unreachable: int8 without rule"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::fake_quant_scalar;
    use crate::util::Rng;

    fn rand_row(rng: &mut Rng, n: usize) -> Vec<f32> {
        rng.normal_vec(n, 0.3)
    }

    #[test]
    fn alloc_free_slab_cycle() {
        let mut p = KvPool::new(2, 1, 4, 8, CacheStore::F32, QuantRule::None).unwrap();
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert!(p.alloc().is_none());
        assert_eq!(p.slots_in_use(), 2);
        p.free(a);
        assert_eq!(p.alloc(), Some(a));
    }

    #[test]
    #[should_panic(expected = "double free of slot")]
    fn double_free_is_a_hard_error() {
        // regression: a debug_assert! let release builds corrupt the free
        // list (the slot handed to two sessions) and panic far away
        let mut p = KvPool::new(2, 1, 4, 8, CacheStore::F32, QuantRule::None).unwrap();
        let a = p.alloc().unwrap();
        p.free(a);
        p.free(a);
    }

    #[test]
    #[should_panic(expected = "out-of-range slot")]
    fn out_of_range_free_is_a_hard_error() {
        let mut p = KvPool::new(2, 1, 4, 8, CacheStore::F32, QuantRule::None).unwrap();
        p.free(7);
    }

    #[test]
    fn raw_roundtrip() {
        let mut rng = Rng::new(0);
        let mut p = KvPool::new(1, 2, 4, 8, CacheStore::F32, QuantRule::None).unwrap();
        let s = p.alloc().unwrap();
        let (k, v) = (rand_row(&mut rng, 8), rand_row(&mut rng, 8));
        p.write(s, 1, 2, &k, &v);
        let mut ko = vec![0.0; 3 * 8];
        let mut vo = vec![0.0; 3 * 8];
        p.read_into(s, 1, 3, &mut ko, &mut vo).unwrap();
        assert_eq!(&ko[16..24], &k[..]);
        assert_eq!(&vo[16..24], &v[..]);
    }

    #[test]
    fn static_int8_matches_fake_quant() {
        let mut rng = Rng::new(1);
        let dim = 8;
        let steps: Vec<f32> = (0..dim).map(|i| 0.01 + 0.003 * i as f32).collect();
        let rule = QuantRule::Static { bits: 8, k_steps: steps.clone(), v_steps: steps.clone() };
        let mut p = KvPool::new(1, 1, 2, dim, CacheStore::Int8, rule).unwrap();
        let s = p.alloc().unwrap();
        let (k, v) = (rand_row(&mut rng, dim), rand_row(&mut rng, dim));
        p.write(s, 0, 0, &k, &v);
        let mut ko = vec![0.0; dim];
        let mut vo = vec![0.0; dim];
        p.read_into(s, 0, 1, &mut ko, &mut vo).unwrap();
        for c in 0..dim {
            assert_eq!(ko[c], fake_quant_scalar(k[c], steps[c], 8));
            assert_eq!(vo[c], fake_quant_scalar(v[c], steps[c], 8));
        }
    }

    #[test]
    fn quantize_f32_matches_pool_write() {
        // the shared rule helper and the pooled write path must agree
        // bit-for-bit — forward_seq leans on this
        let mut rng = Rng::new(3);
        let (dim, layers) = (16, 2);
        for rule in [
            QuantRule::None,
            QuantRule::Dynamic { bits: 8, rows: 4 },
            QuantRule::Static {
                bits: 8,
                k_steps: (0..layers * dim).map(|_| rng.uniform() * 0.05 + 1e-3).collect(),
                v_steps: (0..layers * dim).map(|_| rng.uniform() * 0.05 + 1e-3).collect(),
            },
        ] {
            let mut p = KvPool::new(1, layers, 2, dim, CacheStore::F32, rule.clone()).unwrap();
            let s = p.alloc().unwrap();
            let rule = rule.floored();
            for layer in 0..layers {
                let (k, v) = (rand_row(&mut rng, dim), rand_row(&mut rng, dim));
                p.write(s, layer, 0, &k, &v);
                let (mut kq, mut vq) = (k.clone(), v.clone());
                rule.quantize_f32(layer, &mut kq, &mut vq);
                let mut ko = vec![0.0; dim];
                let mut vo = vec![0.0; dim];
                p.read_into(s, layer, 1, &mut ko, &mut vo).unwrap();
                assert_eq!(ko, kq);
                assert_eq!(vo, vq);
            }
        }
    }

    #[test]
    fn slab_exposes_the_resident_integers() {
        // the zero-copy view must agree exactly with the dequantizing read
        let mut rng = Rng::new(7);
        let (dim, rows, layers, seq) = (16usize, 4usize, 2usize, 4usize);
        for rule in [
            QuantRule::Dynamic { bits: 8, rows },
            QuantRule::Static {
                bits: 8,
                k_steps: (0..layers * dim).map(|_| rng.uniform() * 0.05 + 1e-3).collect(),
                v_steps: (0..layers * dim).map(|_| rng.uniform() * 0.05 + 1e-3).collect(),
            },
        ] {
            let mut p = KvPool::new(1, layers, seq, dim, CacheStore::Int8, rule).unwrap();
            let s = p.alloc().unwrap();
            for layer in 0..layers {
                for pos in 0..3 {
                    let (k, v) = (rand_row(&mut rng, dim), rand_row(&mut rng, dim));
                    p.write(s, layer, pos, &k, &v);
                }
            }
            for layer in 0..layers {
                let slab = p.slab(s, layer, 3).unwrap();
                assert_eq!(slab.k.len(), 3 * dim);
                let mut ko = vec![0.0; 3 * dim];
                let mut vo = vec![0.0; 3 * dim];
                p.read_into(s, layer, 3, &mut ko, &mut vo).unwrap();
                for (i, &kq) in slab.k.iter().enumerate() {
                    let scale = match p.rule() {
                        QuantRule::Dynamic { .. } => slab.k_scales[(i / dim) * slab.rows
                            + (i % dim) / (dim / slab.rows)],
                        QuantRule::Static { k_steps, .. } => k_steps[layer * dim + i % dim],
                        QuantRule::None => unreachable!(),
                    };
                    assert_eq!(kq as f32 * scale, ko[i], "rule {:?} idx {i}", p.rule());
                }
            }
        }
        // the f32 store keeps no integers
        let p = KvPool::new(1, 1, 2, 8, CacheStore::F32, QuantRule::None).unwrap();
        assert!(p.slab(0, 0, 1).is_none());
    }

    #[test]
    fn int8_and_f32_stores_dequantize_identically() {
        // the pool-level statement of the serve-path deployability invariant
        let mut rng = Rng::new(2);
        let (dim, rows) = (16, 4);
        for rule in [
            QuantRule::Dynamic { bits: 8, rows },
            QuantRule::Static {
                bits: 8,
                k_steps: (0..dim).map(|_| rng.uniform() * 0.05 + 1e-3).collect(),
                v_steps: (0..dim).map(|_| rng.uniform() * 0.05 + 1e-3).collect(),
            },
        ] {
            let mut pf = KvPool::new(1, 1, 4, dim, CacheStore::F32, rule.clone()).unwrap();
            let mut pi = KvPool::new(1, 1, 4, dim, CacheStore::Int8, rule).unwrap();
            let (sf, si) = (pf.alloc().unwrap(), pi.alloc().unwrap());
            for pos in 0..4 {
                let (k, v) = (rand_row(&mut rng, dim), rand_row(&mut rng, dim));
                pf.write(sf, 0, pos, &k, &v);
                pi.write(si, 0, pos, &k, &v);
            }
            let mut a = (vec![0.0; 4 * dim], vec![0.0; 4 * dim]);
            let mut b = (vec![0.0; 4 * dim], vec![0.0; 4 * dim]);
            pf.read_into(sf, 0, 4, &mut a.0, &mut a.1).unwrap();
            pi.read_into(si, 0, 4, &mut b.0, &mut b.1).unwrap();
            assert_eq!(a, b, "f32 and int8 stores must dequantize bit-identically");
        }
    }

    #[test]
    fn int8_storage_is_smaller() {
        let rule = QuantRule::Dynamic { bits: 8, rows: 4 };
        let pf = KvPool::new(4, 2, 8, 16, CacheStore::F32, rule.clone()).unwrap();
        let pi = KvPool::new(4, 2, 8, 16, CacheStore::Int8, rule).unwrap();
        assert!(pi.storage_bytes() * 2 < pf.storage_bytes());
        // the integer slab reads 4x fewer row bytes; at this tiny dim/rows
        // ratio the dynamic per-(position, head) scales claw half of that
        // back, so the end-to-end ratio lands at exactly 2x (realistic
        // shapes with dim >> rows approach 4x)
        assert!(pf.read_bytes_per_token(8) >= 2 * pi.read_bytes_per_token(8));
    }

    #[test]
    fn cache_store_parse_and_policy_rule() {
        use crate::policy::QuantPolicy;
        assert_eq!(CacheStore::parse("int8").unwrap(), CacheStore::Int8);
        assert_eq!(CacheStore::parse("f32").unwrap(), CacheStore::F32);
        let e = CacheStore::parse("fp8").unwrap_err().to_string();
        assert!(e.contains("int8|f32"), "error must list the accepted set: {e}");
        assert_eq!(CacheStore::for_policy(&QuantPolicy::w4a8kv8()), CacheStore::Int8);
        assert_eq!(CacheStore::for_policy(&QuantPolicy::fp16()), CacheStore::F32);
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(KvPool::new(1, 1, 2, 8, CacheStore::Int8, QuantRule::None).is_err());
        assert!(KvPool::new(1, 1, 2, 8, CacheStore::Int8, QuantRule::Dynamic { bits: 16, rows: 2 })
            .is_err());
        assert!(KvPool::new(1, 1, 2, 8, CacheStore::Int8, QuantRule::Dynamic { bits: 8, rows: 3 })
            .is_err());
        let bad = QuantRule::Static { bits: 8, k_steps: vec![0.1; 4], v_steps: vec![0.1; 8] };
        assert!(KvPool::new(1, 1, 2, 8, CacheStore::Int8, bad).is_err());
    }
}
