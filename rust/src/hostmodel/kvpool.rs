//! Paged KV-cache pool with quantized storage and prefix sharing.
//!
//! Each decode session (a serve lane, or an eval/self-generation row) owns
//! one *slot*: a page table mapping logical positions to fixed-size
//! physical **pages** (`page_size` positions × `dim` channels, K, V and
//! the dynamic write steps co-resident). The pool applies the paper's
//! cache quantization **on write** (Figure 2: C-bit K/V tensors). Readers
//! have two views:
//!
//! * [`KvPool::read_into`] — dequantize positions `0..len` into f32
//!   buffers, gathering across pages (the fake-quant view; the f32
//!   fallback decode path).
//! * [`KvPool::runs`] — the raw `i8` rows + their write steps, borrowed
//!   page by page straight out of the resident storage with **no copy and
//!   no dequantization**; the integer attention kernel
//!   (`kernels::attend_i8_runs`) walks the runs in position order and
//!   computes `q·k` in `i32` directly over them. [`KvPool::slab`] remains
//!   as the single-run view for windows that fit one page (every window,
//!   under the slab-equivalent geometry).
//!
//! **Paging.** [`KvPool::new`] builds the slab-equivalent geometry — one
//! page of `seq` positions per slot, sharing off — so every pre-paging
//! caller keeps its exact semantics. [`KvPool::new_paged`] (or
//! [`KvLayout::Paged`]) turns on real paging: pages are bound lazily on
//! first write, admission commits the worst-case page budget up front
//! (`pages_per_slot` minus any shared prefix), and a typed
//! [`AdmitErr::Pages`] reject fires when the uncommitted pool can't cover
//! a new session — mid-decode writes can then never run out (the commit
//! invariant; `alloc_page` panics rather than corrupt if it is ever
//! broken).
//!
//! **Prefix sharing.** [`KvPool::alloc_with_prompt`] chain-hashes the
//! prompt in `page_size`-token chunks and attaches any already-resident
//! pages whose full token prefix matches exactly (the hash is a hint;
//! equality is verified token-for-token). Attached pages are refcounted;
//! position-determinism (a position's K/V depends only on the tokens at or
//! before it) makes the skip-prefill bit-exact. A writer landing inside a
//! page shared `rc > 1` triggers a **copy-on-write fork**; pages whose
//! last reference drops while still indexed park in an **LRU** list —
//! revivable by a later matching admit, reclaimed oldest-first when the
//! free list runs dry.
//!
//! Two storage modes share one quantization rule:
//! * [`CacheStore::F32`] — the QAT "fake quant" view: quantized values kept
//!   as f32 (round(clip(x/s))*s).
//! * [`CacheStore::Int8`] — the deployment view: the integers themselves
//!   plus their steps. By the pack/unpack losslessness invariant (see
//!   `quant::pack` and `prop_pack_unpack_exactly_lossless_2_to_8_bits`) both
//!   modes **dequantize** to bit-identical f32 — the paper's deployability
//!   claim at the value level, pinned by the unit tests below. Since the
//!   integer-kernel PR, *decode* over the Int8 store runs exact `i32` q·k
//!   over the resident pages while the F32 store attends over the
//!   fake-quant floats, so end-to-end logits agree to float-rounding
//!   (~1e-5 relative) rather than bit-for-bit; the serve integration test
//!   pins greedy decode token-identical across the two on the builtin
//!   models, where top-logit margins dwarf that rounding.

use std::collections::HashMap;
use std::fmt;

use anyhow::{bail, ensure, Result};

use crate::kernels::{dyn_step, qint, KvRun};
use crate::obs;
use crate::quant::{fake_quant_prefloored, qbounds, EPS};

/// How cache rows are quantized on write.
#[derive(Clone, Debug)]
pub enum QuantRule {
    /// No cache quantization (fp16-precision serving).
    None,
    /// Fixed calibrated steps, one per (layer, channel); `k_steps` and
    /// `v_steps` are `[layers * dim]` row-major. This is the static ('s')
    /// cache mode: steps come from the trained `sc_k`/`sc_v` parameters or
    /// from offline calibration. Steps must be pre-floored at `quant::EPS`
    /// — build through [`QuantRule::floored`] (the floor is hoisted out of
    /// the per-channel write/read loops).
    Static {
        /// cache bit width
        bits: u32,
        /// per-(layer, channel) K steps, `[layers * dim]`
        k_steps: Vec<f32>,
        /// per-(layer, channel) V steps, `[layers * dim]`
        v_steps: Vec<f32>,
    },
    /// Per-write dynamic steps over `rows` equal sub-rows of each cache row
    /// (one per attention head, matching `ste_dynamic_quantize`'s last-axis
    /// reduction on `[B, H, S, d_head]`). This is the dynamic ('d') mode.
    Dynamic {
        /// cache bit width
        bits: u32,
        /// sub-rows per cache row (attention heads)
        rows: usize,
    },
}

impl QuantRule {
    /// Floor the static step vectors at `quant::EPS` once, so the write,
    /// read and attention inner loops can use them directly. Bit-identical
    /// (`s.max(EPS)` is idempotent and the dynamic step is floored at
    /// computation); both [`KvPool::new`] and `HostModel::new` apply this.
    pub fn floored(mut self) -> QuantRule {
        if let QuantRule::Static { k_steps, v_steps, .. } = &mut self {
            for s in k_steps.iter_mut().chain(v_steps.iter_mut()) {
                *s = s.max(EPS);
            }
        }
        self
    }

    /// Apply this rule's fake quantization to one position's K and V rows
    /// in place — the F32-store view of a cache write. Shared by
    /// [`KvPool::write`] and `HostModel::forward_seq` so the pooled
    /// incremental path and the batched full-sequence path quantize the
    /// cache bit-identically. Static steps must be pre-floored
    /// ([`QuantRule::floored`]).
    pub fn quantize_f32(&self, layer: usize, k: &mut [f32], v: &mut [f32]) {
        debug_assert_eq!(k.len(), v.len());
        match self {
            QuantRule::None => {}
            QuantRule::Static { bits, k_steps, v_steps } => {
                let sb = layer * k.len();
                for c in 0..k.len() {
                    k[c] = fake_quant_prefloored(k[c], k_steps[sb + c], *bits);
                    v[c] = fake_quant_prefloored(v[c], v_steps[sb + c], *bits);
                }
            }
            QuantRule::Dynamic { bits, rows } => {
                let (_, qp) = qbounds(*bits);
                let sub = k.len() / rows;
                for r in 0..*rows {
                    let ks = dyn_step(&k[r * sub..(r + 1) * sub], qp);
                    let vs = dyn_step(&v[r * sub..(r + 1) * sub], qp);
                    for c in r * sub..(r + 1) * sub {
                        k[c] = fake_quant_prefloored(k[c], ks, *bits);
                        v[c] = fake_quant_prefloored(v[c], vs, *bits);
                    }
                }
            }
        }
    }

    /// Integer twin of [`QuantRule::quantize_f32`]: quantize one position's
    /// K and V rows into `i8` buffers — the representation the Int8 store
    /// keeps and `kernels::attend_i8` consumes. For the dynamic rule the
    /// per-sub-row steps land in `k_sc`/`v_sc` (`rows` values each); the
    /// static rule reads its pre-floored step vectors and leaves the scale
    /// slices untouched (its attention steps are per layer — see
    /// `HostModel`). Shared by [`KvPool::write`] and
    /// `HostModel::forward_seq`, which is what makes the incremental and
    /// batched integer paths bit-identical. No-op for [`QuantRule::None`].
    pub fn quantize_i8(
        &self,
        layer: usize,
        k: &[f32],
        v: &[f32],
        kq: &mut [i8],
        vq: &mut [i8],
        k_sc: &mut [f32],
        v_sc: &mut [f32],
    ) {
        debug_assert_eq!(k.len(), v.len());
        match self {
            QuantRule::None => {}
            QuantRule::Static { bits, k_steps, v_steps } => {
                let sb = layer * k.len();
                for c in 0..k.len() {
                    kq[c] = qint(k[c], k_steps[sb + c], *bits) as i8;
                    vq[c] = qint(v[c], v_steps[sb + c], *bits) as i8;
                }
            }
            QuantRule::Dynamic { bits, rows } => {
                let (_, qp) = qbounds(*bits);
                let sub = k.len() / rows;
                for r in 0..*rows {
                    let ks = dyn_step(&k[r * sub..(r + 1) * sub], qp);
                    let vs = dyn_step(&v[r * sub..(r + 1) * sub], qp);
                    k_sc[r] = ks;
                    v_sc[r] = vs;
                    for c in r * sub..(r + 1) * sub {
                        kq[c] = qint(k[c], ks, *bits) as i8;
                        vq[c] = qint(v[c], vs, *bits) as i8;
                    }
                }
            }
        }
    }
}

/// Resident representation of the quantized values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheStore {
    /// fake-quant view: quantized values kept as f32
    F32,
    /// deployment view: the integers + their steps
    Int8,
}

impl CacheStore {
    /// The store a policy serves with: quantized policies keep the K/V
    /// cache in the deployment INT8 representation, fp16 keeps f32. One
    /// rule shared by every host entry point (pipeline eval, `silq eval
    /// --backend host`, `silq serve`) so their outputs stay comparable.
    pub fn for_policy(policy: &crate::policy::QuantPolicy) -> CacheStore {
        if policy.quantized {
            CacheStore::Int8
        } else {
            CacheStore::F32
        }
    }

    /// Parse a `--cache` flag value; unknown values are a hard error
    /// naming the accepted set (never silently coerced to a store).
    pub fn parse(s: &str) -> Result<CacheStore> {
        match s {
            "int8" => Ok(CacheStore::Int8),
            "f32" => Ok(CacheStore::F32),
            other => bail!("unknown cache store {other:?} (accepted: int8|f32)"),
        }
    }
}

/// Default positions per page for the paged geometry (`--kv paged`).
pub const DEFAULT_PAGE_SIZE: usize = 16;

/// Pool geometry selector — slab-equivalent (one `seq`-sized page per
/// slot, no sharing: the pre-paging behavior) or truly paged.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum KvLayout {
    /// one contiguous page per slot, prefix sharing off
    #[default]
    Slab,
    /// fixed-size pages, lazy binding, refcounted prefix sharing
    Paged {
        /// positions per page
        page_size: usize,
        /// physical pages in the pool; `None` = `slots * pages_per_slot`
        /// (capacity-equivalent to the slab)
        total_pages: Option<usize>,
        /// hash-match common prompt prefixes at admit
        sharing: bool,
    },
}

impl KvLayout {
    /// The default paged geometry: [`DEFAULT_PAGE_SIZE`], slab-equivalent
    /// capacity, sharing on.
    pub fn paged() -> KvLayout {
        KvLayout::Paged { page_size: DEFAULT_PAGE_SIZE, total_pages: None, sharing: true }
    }

    /// Parse a `--kv` flag value; unknown values are a hard error naming
    /// the accepted set.
    pub fn parse(s: &str) -> Result<KvLayout> {
        match s {
            "slab" => Ok(KvLayout::Slab),
            "paged" => Ok(KvLayout::paged()),
            other => bail!("unknown kv layout {other:?} (accepted: slab|paged)"),
        }
    }
}

/// Why [`KvPool::alloc_with_prompt`] refused a session — the typed
/// admission reject the scheduler surfaces as a rejected finish, and the
/// HTTP front-end maps onto a 429 body naming the exhausted resource.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmitErr {
    /// every session slot is taken
    Slots {
        /// pool slot count
        slots: usize,
    },
    /// the uncommitted page pool can't cover this session's worst case
    Pages {
        /// pages this session would commit
        needed: usize,
        /// uncommitted pages actually available
        available: usize,
    },
    /// an armed `kv@N` fault plan forced exhaustion on this attempt
    Injected,
}

impl fmt::Display for AdmitErr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AdmitErr::Slots { slots } => write!(f, "no free session slot (pool has {slots})"),
            AdmitErr::Pages { needed, available } => {
                write!(f, "out of pages (need {needed}, {available} uncommitted)")
            }
            AdmitErr::Injected => write!(f, "forced exhaustion (fault injection)"),
        }
    }
}

/// Running page-event totals — the exact-balance ledger the paged-pool
/// torture test audits: `allocated + revived == released + resident` at
/// every point, and `resident == 0` at clean shutdown.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PageLedger {
    /// pages bound to a session off the free path (incl. COW copies)
    pub allocated: u64,
    /// shared-prefix attaches (refcount bumps + LRU revivals)
    pub shared: u64,
    /// copy-on-write forks (a writer landed in a page shared `rc > 1`)
    pub forked: u64,
    /// sealed LRU pages unsealed and stolen when the free list ran dry
    pub reclaimed: u64,
    /// pages whose last reference dropped (to the free list or the LRU)
    pub released: u64,
    /// LRU-parked pages re-attached by a later matching admit
    pub revived: u64,
}

/// Borrowed view of one (slot, layer)'s raw quantized K/V rows — what
/// [`KvPool::slab`] hands the integer attention kernel. No copy is made:
/// the slices alias the resident page.
pub struct KvSlabRef<'a> {
    /// `i8` K rows, `[len * dim]` row-major by position
    pub k: &'a [i8],
    /// `i8` V rows, `[len * dim]` row-major by position
    pub v: &'a [i8],
    /// per-(position, head) K write steps `[len * rows]` — empty for the
    /// static rule (whose steps live in the `QuantRule` / the model)
    pub k_scales: &'a [f32],
    /// per-(position, head) V write steps `[len * rows]` — empty for the
    /// static rule
    pub v_scales: &'a [f32],
    /// sub-rows (heads) per position for the dynamic rule; 0 for static
    pub rows: usize,
}

/// Physical page lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum PageState {
    /// on the free stack
    Free,
    /// referenced by >= 1 session (`rc` live references)
    Live,
    /// `rc == 0` but still sealed in the share index — revivable until
    /// reclaimed
    Lru,
}

/// Linked-list sentinel for the intrusive LRU.
const NIL: usize = usize::MAX;
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

#[inline]
fn fnv_i32(mut h: u64, t: i32) -> u64 {
    for b in t.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Paged pool: `total_pages` physical pages of `layers` x `page_size`
/// positions x `dim` channels for K and V each, shared by `slots`
/// concurrent sessions through per-slot page tables.
pub struct KvPool {
    /// concurrent sessions
    pub slots: usize,
    /// model layers
    pub layers: usize,
    /// context window (positions per slot)
    pub seq: usize,
    /// channels per row (`d_model`)
    pub dim: usize,
    /// resident representation
    pub store: CacheStore,
    rule: QuantRule,
    // --- geometry ---
    page_size: usize,
    pages_per_slot: usize,
    total_pages: usize,
    sharing: bool,
    // --- physical storage, indexed by page ---
    // F32 storage (quantized values kept as floats)
    kf: Vec<f32>,
    vf: Vec<f32>,
    // Int8 storage (integers + per-write dynamic scales)
    ki: Vec<i8>,
    vi: Vec<i8>,
    k_scales: Vec<f32>,
    v_scales: Vec<f32>,
    // --- page metadata (state flag replaces the old O(n) free-list scan) ---
    state: Vec<PageState>,
    rc: Vec<u32>,
    sealed: Vec<bool>,
    seal_key: Vec<u64>,
    seal_tokens: Vec<Vec<i32>>,
    free_pages: Vec<usize>,
    lru_prev: Vec<usize>,
    lru_next: Vec<usize>,
    lru_head: usize,
    lru_tail: usize,
    lru_len: usize,
    index: HashMap<u64, usize>,
    // --- per-slot state (tables preallocated: steady state never allocs) ---
    slot_live: Vec<bool>,
    free_slots: Vec<usize>,
    tables: Vec<Vec<usize>>,
    growth_left: Vec<usize>,
    pending: usize,
    seal_from: Vec<usize>,
    seal_until: Vec<usize>,
    seal_keys: Vec<Vec<u64>>,
    prompt_copy: Vec<Vec<i32>>,
    in_use: usize,
    resident: usize,
    ledger: PageLedger,
}

impl KvPool {
    /// Build a slab-equivalent pool (one `seq`-sized page per slot, prefix
    /// sharing off — the pre-paging semantics); the rule's static steps
    /// are floored here once ([`QuantRule::floored`]).
    pub fn new(
        slots: usize,
        layers: usize,
        seq: usize,
        dim: usize,
        store: CacheStore,
        rule: QuantRule,
    ) -> Result<KvPool> {
        KvPool::new_paged(slots, layers, seq, dim, store, rule, seq.max(1), Some(slots), false)
    }

    /// Build a pool with the layout `layout` selects.
    pub fn new_with_layout(
        slots: usize,
        layers: usize,
        seq: usize,
        dim: usize,
        store: CacheStore,
        rule: QuantRule,
        layout: KvLayout,
    ) -> Result<KvPool> {
        match layout {
            KvLayout::Slab => KvPool::new(slots, layers, seq, dim, store, rule),
            KvLayout::Paged { page_size, total_pages, sharing } => KvPool::new_paged(
                slots,
                layers,
                seq,
                dim,
                store,
                rule,
                page_size,
                total_pages,
                sharing,
            ),
        }
    }

    /// Build a paged pool: `page_size` positions per page, `total_pages`
    /// physical pages (`None` = `slots * ceil(seq/page_size)`, the
    /// slab-equivalent capacity), optional prompt-prefix sharing.
    #[allow(clippy::too_many_arguments)]
    pub fn new_paged(
        slots: usize,
        layers: usize,
        seq: usize,
        dim: usize,
        store: CacheStore,
        rule: QuantRule,
        page_size: usize,
        total_pages: Option<usize>,
        sharing: bool,
    ) -> Result<KvPool> {
        match &rule {
            QuantRule::None => {
                ensure!(store == CacheStore::F32, "integer storage needs a quantization rule");
            }
            QuantRule::Static { bits, k_steps, v_steps } => {
                ensure!((2..=8).contains(bits), "cache bits must be 2..=8, got {bits}");
                ensure!(
                    k_steps.len() == layers * dim && v_steps.len() == layers * dim,
                    "static steps must be [layers*dim]"
                );
            }
            QuantRule::Dynamic { bits, rows } => {
                ensure!((2..=8).contains(bits), "cache bits must be 2..=8, got {bits}");
                ensure!(*rows > 0 && dim % rows == 0, "dim {dim} not divisible into {rows} rows");
            }
        }
        ensure!(page_size >= 1, "page size must be >= 1");
        let pages_per_slot = seq.div_ceil(page_size).max(1);
        let total = total_pages.unwrap_or(slots * pages_per_slot);
        ensure!(
            slots == 0 || total >= pages_per_slot,
            "pool of {total} pages cannot hold even one session ({pages_per_slot} pages)"
        );
        let int8 = store == CacheStore::Int8;
        let n = total * layers * page_size * dim;
        let n_scales = match &rule {
            QuantRule::Dynamic { rows, .. } if int8 => total * layers * page_size * rows,
            _ => 0,
        };
        Ok(KvPool {
            slots,
            layers,
            seq,
            dim,
            store,
            rule: rule.floored(),
            page_size,
            pages_per_slot,
            total_pages: total,
            sharing,
            kf: if int8 { vec![] } else { vec![0.0; n] },
            vf: if int8 { vec![] } else { vec![0.0; n] },
            ki: if int8 { vec![0; n] } else { vec![] },
            vi: if int8 { vec![0; n] } else { vec![] },
            k_scales: vec![0.0; n_scales],
            v_scales: vec![0.0; n_scales],
            state: vec![PageState::Free; total],
            rc: vec![0; total],
            sealed: vec![false; total],
            seal_key: vec![0; total],
            seal_tokens: vec![Vec::new(); total],
            free_pages: (0..total).rev().collect(),
            lru_prev: vec![NIL; total],
            lru_next: vec![NIL; total],
            lru_head: NIL,
            lru_tail: NIL,
            lru_len: 0,
            index: HashMap::new(),
            slot_live: vec![false; slots],
            free_slots: (0..slots).rev().collect(),
            tables: (0..slots).map(|_| Vec::with_capacity(pages_per_slot)).collect(),
            growth_left: vec![0; slots],
            pending: 0,
            seal_from: vec![0; slots],
            seal_until: vec![0; slots],
            seal_keys: (0..slots).map(|_| Vec::with_capacity(pages_per_slot)).collect(),
            prompt_copy: vec![Vec::new(); slots],
            in_use: 0,
            resident: 0,
            ledger: PageLedger::default(),
        })
    }

    /// The (floored) quantization rule this pool writes with.
    pub fn rule(&self) -> &QuantRule {
        &self.rule
    }

    /// Dynamic per-(position, head) scale rows kept per cache row on the
    /// Int8 store; 0 for the static rule / the F32 store (whose attention
    /// steps live in the model, indexed at stride 0).
    #[inline]
    pub fn scale_rows(&self) -> usize {
        match (&self.rule, self.store) {
            (QuantRule::Dynamic { rows, .. }, CacheStore::Int8) => *rows,
            _ => 0,
        }
    }

    // -----------------------------------------------------------------
    // session admission
    // -----------------------------------------------------------------

    /// Claim a session slot; `None` when the pool is exhausted. An armed
    /// `kv@N` fault plan ([`crate::faults`]) forces exhaustion on planned
    /// attempts — exercising the same typed-reject path a genuinely full
    /// pool takes, never a distinct failure mode.
    pub fn alloc(&mut self) -> Option<usize> {
        self.alloc_with_prompt(&[]).ok().map(|(slot, _)| slot)
    }

    /// Claim a session slot for `prompt`, attaching any already-sealed
    /// pages whose token prefix matches exactly. Returns `(slot,
    /// shared_positions)`: positions `0..shared_positions` are resident
    /// already (their K/V is determined by the matched tokens alone), so
    /// the caller skips prefilling them. Commits the session's worst-case
    /// page budget — `pages_per_slot` minus the shared prefix, plus one
    /// fork allowance when the prompt exactly fills its shared pages (the
    /// last-token fold then lands inside a shared page and must COW) — and
    /// rejects typed ([`AdmitErr`]) when slots or uncommitted pages run
    /// out.
    pub fn alloc_with_prompt(&mut self, prompt: &[i32]) -> Result<(usize, usize), AdmitErr> {
        if crate::faults::should_inject(crate::faults::Site::KvAlloc) {
            return Err(AdmitErr::Injected);
        }
        if self.free_slots.is_empty() {
            return Err(AdmitErr::Slots { slots: self.slots });
        }
        let ps = self.page_size;
        // chain keys over whole-page prompt chunks: key i covers tokens
        // 0..(i+1)*ps, so a hash match is a candidate for the *entire*
        // prefix through page i (verified by exact token comparison)
        let full = if self.sharing { prompt.len() / ps } else { 0 };
        let mut keys: Vec<u64> = Vec::with_capacity(full);
        let mut h = FNV_OFFSET;
        for i in 0..full {
            for &t in &prompt[i * ps..(i + 1) * ps] {
                h = fnv_i32(h, t);
            }
            keys.push(h);
        }
        let mut matched: Vec<usize> = Vec::with_capacity(full);
        for (i, key) in keys.iter().enumerate() {
            match self.index.get(key) {
                Some(&pg)
                    if self.seal_tokens[pg].len() == (i + 1) * ps
                        && self.seal_tokens[pg] == prompt[..(i + 1) * ps] =>
                {
                    matched.push(pg)
                }
                _ => break,
            }
        }
        let shared = matched.len();
        let needed =
            self.pages_per_slot - shared + usize::from(shared > 0 && shared * ps == prompt.len());
        let revivals = matched.iter().filter(|&&pg| self.state[pg] == PageState::Lru).count();
        let uncommitted =
            (self.free_pages.len() + self.lru_len - revivals).saturating_sub(self.pending);
        if uncommitted < needed {
            return Err(AdmitErr::Pages { needed, available: uncommitted });
        }
        let slot = self.free_slots.pop().expect("checked non-empty");
        self.slot_live[slot] = true;
        self.in_use += 1;
        for &pg in &matched {
            match self.state[pg] {
                PageState::Live => self.rc[pg] += 1,
                PageState::Lru => {
                    self.lru_remove(pg);
                    self.state[pg] = PageState::Live;
                    self.rc[pg] = 1;
                    self.resident += 1;
                    self.ledger.revived += 1;
                }
                PageState::Free => unreachable!("indexed page on the free list"),
            }
            self.tables[slot].push(pg);
        }
        if shared > 0 {
            self.ledger.shared += shared as u64;
            obs::add(obs::Counter::KvPagesShared, shared as u64);
        }
        self.growth_left[slot] = needed;
        self.pending += needed;
        self.seal_from[slot] = shared;
        self.seal_until[slot] = full;
        self.seal_keys[slot].clear();
        self.seal_keys[slot].extend_from_slice(&keys);
        self.prompt_copy[slot].clear();
        self.prompt_copy[slot].extend_from_slice(&prompt[..full * ps]);
        Ok((slot, shared * ps))
    }

    /// Return a slot and drop its page references. Contents need no
    /// zeroing: positions are only ever read up to the owning session's
    /// length, and reused pages are rewritten before they are read.
    ///
    /// Out-of-range slots and double frees are hard errors (release
    /// asserts, not `debug_assert!`): in release either would silently
    /// corrupt the allocator and surface as a confusing panic far from the
    /// bug — a lane double-freeing under load must fail *here*. The guard
    /// is an O(1) per-slot state flag (the old linear free-list scan was
    /// O(slots) per free, and would be O(pages) on the hot eviction path
    /// here).
    pub fn free(&mut self, slot: usize) {
        assert!(slot < self.slots, "free of out-of-range slot {slot} (pool has {})", self.slots);
        assert!(self.slot_live[slot], "double free of slot {slot}");
        self.slot_live[slot] = false;
        let mut table = std::mem::take(&mut self.tables[slot]);
        for &pg in &table {
            self.decref(pg);
        }
        table.clear();
        self.tables[slot] = table; // keep the preallocated capacity
        self.pending -= self.growth_left[slot];
        self.growth_left[slot] = 0;
        self.seal_from[slot] = 0;
        self.seal_until[slot] = 0;
        self.free_slots.push(slot);
        self.in_use -= 1;
    }

    /// Drop one reference; on the last one the page parks in the LRU while
    /// still sealed (revivable until reclaimed) or returns to the free
    /// stack.
    fn decref(&mut self, pg: usize) {
        debug_assert_eq!(self.state[pg], PageState::Live, "decref of a non-live page");
        self.rc[pg] -= 1;
        if self.rc[pg] > 0 {
            return;
        }
        self.resident -= 1;
        self.ledger.released += 1;
        if self.sealed[pg] {
            self.state[pg] = PageState::Lru;
            self.lru_push_tail(pg);
        } else {
            self.state[pg] = PageState::Free;
            self.free_pages.push(pg);
        }
    }

    /// Bind a fresh physical page against `slot`'s committed growth
    /// budget: free stack first, then the oldest LRU page (unsealed +
    /// reclaimed). The admission commit invariant guarantees one is
    /// available — running dry here is allocator corruption, not load.
    fn alloc_page(&mut self, slot: usize) -> usize {
        let pg = if let Some(pg) = self.free_pages.pop() {
            pg
        } else {
            let pg = self
                .lru_pop_head()
                .expect("KV pool commit invariant violated: no page for a committed write");
            self.index.remove(&self.seal_key[pg]);
            self.sealed[pg] = false;
            self.seal_tokens[pg].clear();
            self.ledger.reclaimed += 1;
            obs::add(obs::Counter::KvPagesReclaimed, 1);
            pg
        };
        self.state[pg] = PageState::Live;
        self.rc[pg] = 1;
        self.resident += 1;
        self.ledger.allocated += 1;
        obs::add(obs::Counter::KvPagesAllocated, 1);
        debug_assert!(self.growth_left[slot] > 0, "slot {slot} exceeded its committed budget");
        self.growth_left[slot] -= 1;
        self.pending -= 1;
        pg
    }

    // -----------------------------------------------------------------
    // intrusive LRU (prealloc'd prev/next arrays — O(1), alloc-free)
    // -----------------------------------------------------------------

    fn lru_push_tail(&mut self, pg: usize) {
        self.lru_prev[pg] = self.lru_tail;
        self.lru_next[pg] = NIL;
        if self.lru_tail != NIL {
            self.lru_next[self.lru_tail] = pg;
        } else {
            self.lru_head = pg;
        }
        self.lru_tail = pg;
        self.lru_len += 1;
    }

    fn lru_remove(&mut self, pg: usize) {
        let (p, n) = (self.lru_prev[pg], self.lru_next[pg]);
        if p != NIL {
            self.lru_next[p] = n;
        } else {
            self.lru_head = n;
        }
        if n != NIL {
            self.lru_prev[n] = p;
        } else {
            self.lru_tail = p;
        }
        self.lru_len -= 1;
    }

    fn lru_pop_head(&mut self) -> Option<usize> {
        if self.lru_head == NIL {
            return None;
        }
        let pg = self.lru_head;
        self.lru_remove(pg);
        Some(pg)
    }

    // -----------------------------------------------------------------
    // accounting
    // -----------------------------------------------------------------

    /// Sessions currently holding a slot.
    pub fn slots_in_use(&self) -> usize {
        self.in_use
    }

    /// Whether every session slot has been returned (the slot half of the
    /// shutdown invariant; see [`KvPool::all_pages_free`]).
    pub fn all_slots_free(&self) -> bool {
        self.in_use == 0 && self.free_slots.len() == self.slots
    }

    /// Whether every session *and every page* has been returned — the
    /// shutdown invariant the serve soak/chaos suites pin (a leaked page
    /// shows up here long before it shows up as pool exhaustion under
    /// load). LRU-parked pages count as free: they hold no session and are
    /// reclaimable on demand.
    pub fn all_pages_free(&self) -> bool {
        self.all_slots_free()
            && self.resident == 0
            && self.pending == 0
            && self.free_pages.len() + self.lru_len == self.total_pages
    }

    /// Positions per page.
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Worst-case pages one session can hold.
    pub fn pages_per_slot(&self) -> usize {
        self.pages_per_slot
    }

    /// Physical pages in the pool.
    pub fn total_pages(&self) -> usize {
        self.total_pages
    }

    /// Distinct physical pages currently referenced by >= 1 session.
    pub fn pages_in_use(&self) -> usize {
        self.resident
    }

    /// Running page-event totals (see [`PageLedger`]).
    pub fn ledger(&self) -> PageLedger {
        self.ledger
    }

    /// Deployment storage footprint in bytes of the whole pool
    /// (bit-packed integers + scales, matching `PackedTensor::storage_bytes`
    /// accounting).
    pub fn storage_bytes(&self) -> usize {
        let n = 2 * self.total_pages * self.layers * self.page_size * self.dim; // K and V
        match (&self.rule, self.store) {
            (QuantRule::None, _) => n * 4,
            (_, CacheStore::F32) => n * 4,
            (QuantRule::Static { bits, k_steps, v_steps }, CacheStore::Int8) => {
                (n * *bits as usize + 7) / 8 + (k_steps.len() + v_steps.len()) * 4
            }
            (QuantRule::Dynamic { bits, .. }, CacheStore::Int8) => {
                (n * *bits as usize + 7) / 8 + (self.k_scales.len() + self.v_scales.len()) * 4
            }
        }
    }

    /// Deployment bytes of one page (K + V values + co-resident dynamic
    /// scales; the static rule's steps are global, not per page).
    fn page_bytes(&self) -> usize {
        let n = 2 * self.layers * self.page_size * self.dim;
        match (&self.rule, self.store) {
            (QuantRule::None, _) | (_, CacheStore::F32) => n * 4,
            (QuantRule::Static { bits, .. }, CacheStore::Int8) => (n * *bits as usize + 7) / 8,
            (QuantRule::Dynamic { bits, rows }, CacheStore::Int8) => {
                (n * *bits as usize + 7) / 8 + 2 * self.layers * self.page_size * rows * 4
            }
        }
    }

    /// Deployment bytes of the pages sessions currently hold — what
    /// `kv_bytes` reports over the wire: resident pages, not reserved
    /// worst-case slabs.
    pub fn resident_bytes(&self) -> usize {
        self.resident * self.page_bytes()
    }

    /// Bytes the attention read path touches per decoded token when the
    /// prefix holds `len` positions: K and V rows across every layer, the
    /// dynamic write steps on the Int8 store, plus the static rule's
    /// per-channel step vectors (one K and one V vector per layer — reads
    /// the earlier accounting omitted, flattering the int8-vs-f32 traffic
    /// ratio under static cache policies). The integer pages read one byte
    /// per channel where the f32 path reads four — the bench harness
    /// reports this next to decode tok/s.
    pub fn read_bytes_per_token(&self, len: usize) -> usize {
        let rows = self.scale_rows();
        let elem = if self.store == CacheStore::Int8 { 1 } else { 4 };
        let step_bytes = match (&self.rule, self.store) {
            (QuantRule::Static { .. }, CacheStore::Int8) => self.layers * 2 * self.dim * 4,
            _ => 0,
        };
        self.layers * (2 * len * self.dim * elem + 2 * len * rows * 4) + step_bytes
    }

    /// Base index of `(page, layer, local position)` in the value storage.
    #[inline]
    fn page_base(&self, pg: usize, layer: usize, q: usize) -> usize {
        debug_assert!(pg < self.total_pages && layer < self.layers && q < self.page_size);
        ((pg * self.layers + layer) * self.page_size + q) * self.dim
    }

    // -----------------------------------------------------------------
    // write / read
    // -----------------------------------------------------------------

    /// Quantize-on-write one position's K and V rows (`dim` channels
    /// each). Binds pages lazily (first write into a logical page pops a
    /// free page — covered by the admission commit, so it cannot fail
    /// mid-decode) and forks a private copy first when the target page is
    /// shared `rc > 1` (copy-on-write); a sole owner writing into a
    /// still-indexed page just unseals it in place.
    pub fn write(&mut self, slot: usize, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        assert_eq!(k.len(), self.dim);
        assert_eq!(v.len(), self.dim);
        debug_assert!(slot < self.slots && layer < self.layers && pos < self.seq);
        let (lp, q) = (pos / self.page_size, pos % self.page_size);
        while self.tables[slot].len() <= lp {
            let pg = self.alloc_page(slot);
            self.tables[slot].push(pg);
        }
        let mut pg = self.tables[slot][lp];
        if self.rc[pg] > 1 {
            pg = self.cow_fork(slot, lp, pg);
        } else if self.sealed[pg] {
            self.index.remove(&self.seal_key[pg]);
            self.sealed[pg] = false;
            self.seal_tokens[pg].clear();
        }
        let base = self.page_base(pg, layer, q);
        if self.store == CacheStore::F32 {
            self.kf[base..base + self.dim].copy_from_slice(k);
            self.vf[base..base + self.dim].copy_from_slice(v);
            self.rule.quantize_f32(
                layer,
                &mut self.kf[base..base + self.dim],
                &mut self.vf[base..base + self.dim],
            );
        } else {
            // Int8 store: quantize straight into the page. The static rule
            // has no per-write scales (`rows == 0` slices an empty range).
            let rows = self.scale_rows();
            let sb = ((pg * self.layers + layer) * self.page_size + q) * rows;
            self.rule.quantize_i8(
                layer,
                k,
                v,
                &mut self.ki[base..base + self.dim],
                &mut self.vi[base..base + self.dim],
                &mut self.k_scales[sb..sb + rows],
                &mut self.v_scales[sb..sb + rows],
            );
        }
        // a prompt-determined page is complete once its last position's
        // last layer lands — register it for prefix matching
        if self.sharing && layer + 1 == self.layers {
            self.maybe_seal(slot, lp, pos);
        }
    }

    /// Copy-on-write fork: bind a fresh page, copy every layer's K/V rows
    /// (+ co-resident dynamic scales), swap it into the table and drop the
    /// shared original's reference.
    fn cow_fork(&mut self, slot: usize, lp: usize, old: usize) -> usize {
        let np = self.alloc_page(slot);
        let n = self.layers * self.page_size * self.dim;
        if self.store == CacheStore::Int8 {
            self.ki.copy_within(old * n..(old + 1) * n, np * n);
            self.vi.copy_within(old * n..(old + 1) * n, np * n);
        } else {
            self.kf.copy_within(old * n..(old + 1) * n, np * n);
            self.vf.copy_within(old * n..(old + 1) * n, np * n);
        }
        let rows = self.scale_rows();
        if rows > 0 {
            let m = self.layers * self.page_size * rows;
            self.k_scales.copy_within(old * m..(old + 1) * m, np * m);
            self.v_scales.copy_within(old * m..(old + 1) * m, np * m);
        }
        self.tables[slot][lp] = np;
        self.decref(old);
        self.ledger.forked += 1;
        obs::add(obs::Counter::KvCowForks, 1);
        np
    }

    /// Seal slot `slot`'s next pending prompt page if this write completed
    /// it (its last position, last layer). First identical page wins the
    /// index entry; later twins stay private.
    fn maybe_seal(&mut self, slot: usize, lp: usize, pos: usize) {
        let i = self.seal_from[slot];
        if i >= self.seal_until[slot] || lp != i || pos + 1 != (i + 1) * self.page_size {
            return;
        }
        self.seal_from[slot] = i + 1;
        let key = self.seal_keys[slot][i];
        if self.index.contains_key(&key) {
            return;
        }
        let pg = self.tables[slot][i];
        debug_assert_eq!(self.rc[pg], 1, "sealing a page that is already shared");
        self.sealed[pg] = true;
        self.seal_key[pg] = key;
        self.seal_tokens[pg].clear();
        self.seal_tokens[pg].extend_from_slice(&self.prompt_copy[slot][..(i + 1) * self.page_size]);
        self.index.insert(key, pg);
    }

    /// Borrow the raw `i8` K/V rows (and dynamic write steps) of positions
    /// `0..len` as one contiguous run — zero-copy input for
    /// `kernels::attend_i8` when the window fits one page (every window,
    /// under the slab-equivalent geometry). `None` on the F32 store, which
    /// keeps no integers. `len` past the window is a hard error (like
    /// [`KvPool::free`]): pages are contiguous across layers, so a release
    /// over-read would silently attend over the next layer's rows. Windows
    /// that span pages must use [`KvPool::runs`].
    pub fn slab(&self, slot: usize, layer: usize, len: usize) -> Option<KvSlabRef<'_>> {
        if self.store != CacheStore::Int8 {
            return None;
        }
        assert!(len <= self.seq, "slab read past the window: {len} > {}", self.seq);
        let rows = self.scale_rows();
        if len == 0 {
            return Some(KvSlabRef { k: &[], v: &[], k_scales: &[], v_scales: &[], rows });
        }
        assert!(
            len <= self.page_size,
            "slab read spans pages: {len} > page size {} (use runs())",
            self.page_size
        );
        let pg = self.tables[slot][0];
        let base = self.page_base(pg, layer, 0);
        let (k_scales, v_scales) = if rows > 0 {
            let sb = (pg * self.layers + layer) * self.page_size * rows;
            (&self.k_scales[sb..sb + len * rows], &self.v_scales[sb..sb + len * rows])
        } else {
            (&[][..], &[][..])
        };
        Some(KvSlabRef {
            k: &self.ki[base..base + len * self.dim],
            v: &self.vi[base..base + len * self.dim],
            k_scales,
            v_scales,
            rows,
        })
    }

    /// Iterate positions `0..len` of `(slot, layer)` as page runs — the
    /// zero-copy, zero-alloc input for `kernels::attend_i8_runs`. The
    /// iterator is `Clone` (the kernel walks it twice: scores, then
    /// softmax·V) and yields runs in position order, so paged attention is
    /// bit-identical to the contiguous slab. Int8 store only.
    pub fn runs(&self, slot: usize, layer: usize, len: usize) -> PageRuns<'_> {
        debug_assert_eq!(self.store, CacheStore::Int8, "runs() reads the integer store");
        assert!(len <= self.seq, "slab read past the window: {len} > {}", self.seq);
        debug_assert!(len == 0 || len.div_ceil(self.page_size) <= self.tables[slot].len());
        PageRuns { pool: self, table: &self.tables[slot], layer, idx: 0, remaining: len }
    }

    /// Dequantize-on-read positions `0..len` into `k_out`/`v_out`
    /// (`len * dim` f32 each, row-major by position), gathering across
    /// pages.
    pub fn read_into(
        &self,
        slot: usize,
        layer: usize,
        len: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<()> {
        ensure!(len <= self.seq, "read past slab end: {len} > {}", self.seq);
        ensure!(k_out.len() == len * self.dim && v_out.len() == len * self.dim, "bad read buffer");
        let ps = self.page_size;
        let mut done = 0usize;
        while done < len {
            let pg = self.tables[slot][done / ps];
            let n = (len - done).min(ps);
            let base = self.page_base(pg, layer, 0);
            let ob = done * self.dim;
            match (&self.rule, self.store) {
                (_, CacheStore::F32) => {
                    k_out[ob..ob + n * self.dim]
                        .copy_from_slice(&self.kf[base..base + n * self.dim]);
                    v_out[ob..ob + n * self.dim]
                        .copy_from_slice(&self.vf[base..base + n * self.dim]);
                }
                (QuantRule::Static { k_steps, v_steps, .. }, CacheStore::Int8) => {
                    let sb = layer * self.dim;
                    for p in 0..n {
                        for c in 0..self.dim {
                            let i = p * self.dim + c;
                            k_out[ob + i] = self.ki[base + i] as f32 * k_steps[sb + c];
                            v_out[ob + i] = self.vi[base + i] as f32 * v_steps[sb + c];
                        }
                    }
                }
                (QuantRule::Dynamic { rows, .. }, CacheStore::Int8) => {
                    let sub = self.dim / rows;
                    for p in 0..n {
                        let scale_base = ((pg * self.layers + layer) * self.page_size + p) * rows;
                        for r in 0..*rows {
                            let (ks, vs) =
                                (self.k_scales[scale_base + r], self.v_scales[scale_base + r]);
                            for c in r * sub..(r + 1) * sub {
                                let i = p * self.dim + c;
                                k_out[ob + i] = self.ki[base + i] as f32 * ks;
                                v_out[ob + i] = self.vi[base + i] as f32 * vs;
                            }
                        }
                    }
                }
                (QuantRule::None, CacheStore::Int8) => bail!("unreachable: int8 without rule"),
            }
            done += n;
        }
        Ok(())
    }
}

/// Clone-able iterator over one (slot, layer)'s resident page runs — see
/// [`KvPool::runs`]. Plain index arithmetic over borrowed storage: no
/// allocation, so the steady-state zero-alloc decode pins hold on the
/// paged path.
#[derive(Clone)]
pub struct PageRuns<'a> {
    pool: &'a KvPool,
    table: &'a [usize],
    layer: usize,
    idx: usize,
    remaining: usize,
}

impl<'a> Iterator for PageRuns<'a> {
    type Item = KvRun<'a>;

    fn next(&mut self) -> Option<KvRun<'a>> {
        if self.remaining == 0 {
            return None;
        }
        let pg = self.table[self.idx];
        let n = self.remaining.min(self.pool.page_size);
        let base = self.pool.page_base(pg, self.layer, 0);
        let rows = self.pool.scale_rows();
        let (k_scales, v_scales) = if rows > 0 {
            let sb = (pg * self.pool.layers + self.layer) * self.pool.page_size * rows;
            (&self.pool.k_scales[sb..sb + n * rows], &self.pool.v_scales[sb..sb + n * rows])
        } else {
            (&[][..], &[][..])
        };
        self.idx += 1;
        self.remaining -= n;
        Some(KvRun {
            k: &self.pool.ki[base..base + n * self.pool.dim],
            v: &self.pool.vi[base..base + n * self.pool.dim],
            k_scales,
            v_scales,
            len: n,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::fake_quant_scalar;
    use crate::util::Rng;

    fn rand_row(rng: &mut Rng, n: usize) -> Vec<f32> {
        rng.normal_vec(n, 0.3)
    }

    #[test]
    fn alloc_free_slab_cycle() {
        let mut p = KvPool::new(2, 1, 4, 8, CacheStore::F32, QuantRule::None).unwrap();
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert!(p.alloc().is_none());
        assert_eq!(p.slots_in_use(), 2);
        p.free(a);
        assert_eq!(p.alloc(), Some(a));
    }

    #[test]
    #[should_panic(expected = "double free of slot")]
    fn double_free_is_a_hard_error() {
        // regression: a debug_assert! let release builds corrupt the free
        // list (the slot handed to two sessions) and panic far away
        let mut p = KvPool::new(2, 1, 4, 8, CacheStore::F32, QuantRule::None).unwrap();
        let a = p.alloc().unwrap();
        p.free(a);
        p.free(a);
    }

    #[test]
    #[should_panic(expected = "out-of-range slot")]
    fn out_of_range_free_is_a_hard_error() {
        let mut p = KvPool::new(2, 1, 4, 8, CacheStore::F32, QuantRule::None).unwrap();
        p.free(7);
    }

    #[test]
    fn raw_roundtrip() {
        let mut rng = Rng::new(0);
        let mut p = KvPool::new(1, 2, 4, 8, CacheStore::F32, QuantRule::None).unwrap();
        let s = p.alloc().unwrap();
        for pos in 0..2 {
            let (k0, v0) = (rand_row(&mut rng, 8), rand_row(&mut rng, 8));
            p.write(s, 1, pos, &k0, &v0);
        }
        let (k, v) = (rand_row(&mut rng, 8), rand_row(&mut rng, 8));
        p.write(s, 1, 2, &k, &v);
        let mut ko = vec![0.0; 3 * 8];
        let mut vo = vec![0.0; 3 * 8];
        p.read_into(s, 1, 3, &mut ko, &mut vo).unwrap();
        assert_eq!(&ko[16..24], &k[..]);
        assert_eq!(&vo[16..24], &v[..]);
    }

    #[test]
    fn static_int8_matches_fake_quant() {
        let mut rng = Rng::new(1);
        let dim = 8;
        let steps: Vec<f32> = (0..dim).map(|i| 0.01 + 0.003 * i as f32).collect();
        let rule = QuantRule::Static { bits: 8, k_steps: steps.clone(), v_steps: steps.clone() };
        let mut p = KvPool::new(1, 1, 2, dim, CacheStore::Int8, rule).unwrap();
        let s = p.alloc().unwrap();
        let (k, v) = (rand_row(&mut rng, dim), rand_row(&mut rng, dim));
        p.write(s, 0, 0, &k, &v);
        let mut ko = vec![0.0; dim];
        let mut vo = vec![0.0; dim];
        p.read_into(s, 0, 1, &mut ko, &mut vo).unwrap();
        for c in 0..dim {
            assert_eq!(ko[c], fake_quant_scalar(k[c], steps[c], 8));
            assert_eq!(vo[c], fake_quant_scalar(v[c], steps[c], 8));
        }
    }

    #[test]
    fn quantize_f32_matches_pool_write() {
        // the shared rule helper and the pooled write path must agree
        // bit-for-bit — forward_seq leans on this
        let mut rng = Rng::new(3);
        let (dim, layers) = (16, 2);
        for rule in [
            QuantRule::None,
            QuantRule::Dynamic { bits: 8, rows: 4 },
            QuantRule::Static {
                bits: 8,
                k_steps: (0..layers * dim).map(|_| rng.uniform() * 0.05 + 1e-3).collect(),
                v_steps: (0..layers * dim).map(|_| rng.uniform() * 0.05 + 1e-3).collect(),
            },
        ] {
            let mut p = KvPool::new(1, layers, 2, dim, CacheStore::F32, rule.clone()).unwrap();
            let s = p.alloc().unwrap();
            let rule = rule.floored();
            for layer in 0..layers {
                let (k, v) = (rand_row(&mut rng, dim), rand_row(&mut rng, dim));
                p.write(s, layer, 0, &k, &v);
                let (mut kq, mut vq) = (k.clone(), v.clone());
                rule.quantize_f32(layer, &mut kq, &mut vq);
                let mut ko = vec![0.0; dim];
                let mut vo = vec![0.0; dim];
                p.read_into(s, layer, 1, &mut ko, &mut vo).unwrap();
                assert_eq!(ko, kq);
                assert_eq!(vo, vq);
            }
        }
    }

    #[test]
    fn slab_exposes_the_resident_integers() {
        // the zero-copy view must agree exactly with the dequantizing read
        let mut rng = Rng::new(7);
        let (dim, rows, layers, seq) = (16usize, 4usize, 2usize, 4usize);
        for rule in [
            QuantRule::Dynamic { bits: 8, rows },
            QuantRule::Static {
                bits: 8,
                k_steps: (0..layers * dim).map(|_| rng.uniform() * 0.05 + 1e-3).collect(),
                v_steps: (0..layers * dim).map(|_| rng.uniform() * 0.05 + 1e-3).collect(),
            },
        ] {
            let mut p = KvPool::new(1, layers, seq, dim, CacheStore::Int8, rule).unwrap();
            let s = p.alloc().unwrap();
            for pos in 0..3 {
                for layer in 0..layers {
                    let (k, v) = (rand_row(&mut rng, dim), rand_row(&mut rng, dim));
                    p.write(s, layer, pos, &k, &v);
                }
            }
            for layer in 0..layers {
                let slab = p.slab(s, layer, 3).unwrap();
                assert_eq!(slab.k.len(), 3 * dim);
                let mut ko = vec![0.0; 3 * dim];
                let mut vo = vec![0.0; 3 * dim];
                p.read_into(s, layer, 3, &mut ko, &mut vo).unwrap();
                for (i, &kq) in slab.k.iter().enumerate() {
                    let scale = match p.rule() {
                        QuantRule::Dynamic { .. } => {
                            slab.k_scales[(i / dim) * slab.rows + (i % dim) / (dim / slab.rows)]
                        }
                        QuantRule::Static { k_steps, .. } => k_steps[layer * dim + i % dim],
                        QuantRule::None => unreachable!(),
                    };
                    assert_eq!(kq as f32 * scale, ko[i], "rule {:?} idx {i}", p.rule());
                }
                // the page-run view exposes the same bytes, page by page
                let total: usize = p.runs(s, layer, 3).map(|r| r.len).sum();
                assert_eq!(total, 3);
                let gathered: Vec<i8> =
                    p.runs(s, layer, 3).flat_map(|r| r.k.to_vec()).collect();
                assert_eq!(gathered, slab.k);
            }
        }
        // the f32 store keeps no integers
        let p = KvPool::new(1, 1, 2, 8, CacheStore::F32, QuantRule::None).unwrap();
        assert!(p.slab(0, 0, 1).is_none());
    }

    #[test]
    fn int8_and_f32_stores_dequantize_identically() {
        // the pool-level statement of the serve-path deployability invariant
        let mut rng = Rng::new(2);
        let (dim, rows) = (16, 4);
        for rule in [
            QuantRule::Dynamic { bits: 8, rows },
            QuantRule::Static {
                bits: 8,
                k_steps: (0..dim).map(|_| rng.uniform() * 0.05 + 1e-3).collect(),
                v_steps: (0..dim).map(|_| rng.uniform() * 0.05 + 1e-3).collect(),
            },
        ] {
            let mut pf = KvPool::new(1, 1, 4, dim, CacheStore::F32, rule.clone()).unwrap();
            let mut pi = KvPool::new(1, 1, 4, dim, CacheStore::Int8, rule).unwrap();
            let (sf, si) = (pf.alloc().unwrap(), pi.alloc().unwrap());
            for pos in 0..4 {
                let (k, v) = (rand_row(&mut rng, dim), rand_row(&mut rng, dim));
                pf.write(sf, 0, pos, &k, &v);
                pi.write(si, 0, pos, &k, &v);
            }
            let mut a = (vec![0.0; 4 * dim], vec![0.0; 4 * dim]);
            let mut b = (vec![0.0; 4 * dim], vec![0.0; 4 * dim]);
            pf.read_into(sf, 0, 4, &mut a.0, &mut a.1).unwrap();
            pi.read_into(si, 0, 4, &mut b.0, &mut b.1).unwrap();
            assert_eq!(a, b, "f32 and int8 stores must dequantize bit-identically");
        }
    }

    #[test]
    fn int8_storage_is_smaller() {
        let rule = QuantRule::Dynamic { bits: 8, rows: 4 };
        let pf = KvPool::new(4, 2, 8, 16, CacheStore::F32, rule.clone()).unwrap();
        let pi = KvPool::new(4, 2, 8, 16, CacheStore::Int8, rule).unwrap();
        assert!(pi.storage_bytes() * 2 < pf.storage_bytes());
        // the integer slab reads 4x fewer row bytes; at this tiny dim/rows
        // ratio the dynamic per-(position, head) scales claw half of that
        // back, so the end-to-end ratio lands at exactly 2x (realistic
        // shapes with dim >> rows approach 4x)
        assert!(pf.read_bytes_per_token(8) >= 2 * pi.read_bytes_per_token(8));
        // static rule: the per-channel step vectors the attention path
        // actually reads (layers * 2 * dim * 4 bytes) now count on the
        // int8 side — previously omitted, which flattered the ratio
        let srule =
            QuantRule::Static { bits: 8, k_steps: vec![0.1; 2 * 16], v_steps: vec![0.1; 2 * 16] };
        let sf = KvPool::new(4, 2, 8, 16, CacheStore::F32, srule.clone()).unwrap();
        let si = KvPool::new(4, 2, 8, 16, CacheStore::Int8, srule).unwrap();
        let steps = 2 * 2 * 16 * 4; // layers * (K+V) * dim * 4 bytes
        assert_eq!(si.read_bytes_per_token(8), 2 * (2 * 8 * 16) + steps);
        assert_eq!(sf.read_bytes_per_token(8), 2 * (2 * 8 * 16 * 4));
        assert!(sf.read_bytes_per_token(8) > 2 * (si.read_bytes_per_token(8) - steps));
    }

    #[test]
    fn cache_store_parse_and_policy_rule() {
        use crate::policy::QuantPolicy;
        assert_eq!(CacheStore::parse("int8").unwrap(), CacheStore::Int8);
        assert_eq!(CacheStore::parse("f32").unwrap(), CacheStore::F32);
        let e = CacheStore::parse("fp8").unwrap_err().to_string();
        assert!(e.contains("int8|f32"), "error must list the accepted set: {e}");
        assert_eq!(CacheStore::for_policy(&QuantPolicy::w4a8kv8()), CacheStore::Int8);
        assert_eq!(CacheStore::for_policy(&QuantPolicy::fp16()), CacheStore::F32);
        assert_eq!(KvLayout::parse("slab").unwrap(), KvLayout::Slab);
        assert_eq!(KvLayout::parse("paged").unwrap(), KvLayout::paged());
        let e = KvLayout::parse("heap").unwrap_err().to_string();
        assert!(e.contains("slab|paged"), "error must list the accepted set: {e}");
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(KvPool::new(1, 1, 2, 8, CacheStore::Int8, QuantRule::None).is_err());
        assert!(KvPool::new(1, 1, 2, 8, CacheStore::Int8, QuantRule::Dynamic { bits: 16, rows: 2 })
            .is_err());
        assert!(KvPool::new(1, 1, 2, 8, CacheStore::Int8, QuantRule::Dynamic { bits: 8, rows: 3 })
            .is_err());
        let bad = QuantRule::Static { bits: 8, k_steps: vec![0.1; 4], v_steps: vec![0.1; 8] };
        assert!(KvPool::new(1, 1, 2, 8, CacheStore::Int8, bad).is_err());
        // a paged pool must hold at least one whole session
        assert!(
            KvPool::new_paged(2, 1, 8, 8, CacheStore::F32, QuantRule::None, 2, Some(3), true)
                .is_err()
        );
    }

    /// Write positions `from..upto` of every layer (a sharing admit's
    /// prefill skips the shared positions, like the host forward does).
    fn fill(p: &mut KvPool, rng: &mut Rng, slot: usize, from: usize, upto: usize) {
        for pos in from..upto {
            for layer in 0..p.layers {
                let (k, v) = (rand_row(rng, p.dim), rand_row(rng, p.dim));
                p.write(slot, layer, pos, &k, &v);
            }
        }
    }

    #[test]
    fn prefix_sharing_holds_p_plus_suffix_pages() {
        // N lanes sharing a P-page prefix hold exactly P + sum-of-suffix
        // pages, not N * (P + suffix)
        let mut rng = Rng::new(11);
        let rule = QuantRule::Dynamic { bits: 8, rows: 2 };
        let mut p = KvPool::new_paged(4, 2, 8, 4, CacheStore::Int8, rule, 2, None, true).unwrap();
        let prompt = [5i32, 6, 7, 8, 9]; // 2 full pages (P=2) + 1 spill token
        let (s0, shared0) = p.alloc_with_prompt(&prompt).unwrap();
        assert_eq!(shared0, 0, "nothing sealed yet");
        fill(&mut p, &mut rng, s0, 0, prompt.len());
        assert_eq!(p.pages_in_use(), 3); // P=2 + 1 suffix page
        for n in 2..4usize {
            let (s, shared) = p.alloc_with_prompt(&prompt).unwrap();
            assert_eq!(shared, 4, "both full-prompt pages must match");
            fill(&mut p, &mut rng, s, shared, prompt.len());
            assert_eq!(p.pages_in_use(), 2 + n, "P + suffix-per-lane");
        }
        assert_eq!(p.ledger().shared, 4); // 2 pages x 2 attaching lanes
        assert_eq!(p.ledger().forked, 0, "no writer landed inside the shared pages");
        // a different prompt shares nothing
        let (_s3, shared3) = p.alloc_with_prompt(&[9, 9, 9, 9, 9]).unwrap();
        assert_eq!(shared3, 0);
    }

    #[test]
    fn exact_fill_write_cow_forks_the_shared_page() {
        let mut rng = Rng::new(13);
        let rule = QuantRule::Dynamic { bits: 8, rows: 2 };
        let mut p = KvPool::new_paged(3, 1, 8, 4, CacheStore::Int8, rule, 2, None, true).unwrap();
        let prompt = [3i32, 1, 4, 1]; // exactly 2 pages
        let (s0, _) = p.alloc_with_prompt(&prompt).unwrap();
        fill(&mut p, &mut rng, s0, 0, prompt.len());
        let (s1, shared) = p.alloc_with_prompt(&prompt).unwrap();
        assert_eq!(shared, 4, "exact-fill prompt matches whole");
        assert_eq!(p.pages_in_use(), 2);
        // re-folding the last prompt token writes position 3 — inside the
        // shared page — and must fork, leaving s0's copy untouched
        let mut before = (vec![0.0; 4 * 4], vec![0.0; 4 * 4]);
        p.read_into(s0, 0, 4, &mut before.0, &mut before.1).unwrap();
        let (k, v) = (rand_row(&mut rng, 4), rand_row(&mut rng, 4));
        p.write(s1, 0, 3, &k, &v);
        assert_eq!(p.ledger().forked, 1);
        assert_eq!(p.pages_in_use(), 3);
        let mut after = (vec![0.0; 4 * 4], vec![0.0; 4 * 4]);
        p.read_into(s0, 0, 4, &mut after.0, &mut after.1).unwrap();
        assert_eq!(before, after, "COW must not disturb the original lane");
        // and s1's fork kept the shared positions 0..3
        let mut forked = (vec![0.0; 4 * 4], vec![0.0; 4 * 4]);
        p.read_into(s1, 0, 4, &mut forked.0, &mut forked.1).unwrap();
        assert_eq!(&forked.0[..3 * 4], &after.0[..3 * 4]);
        p.free(s0);
        p.free(s1);
        assert!(p.all_pages_free());
    }

    #[test]
    fn lru_parks_sealed_pages_then_revives_or_reclaims() {
        let mut rng = Rng::new(17);
        let rule = QuantRule::Dynamic { bits: 8, rows: 2 };
        // 4 pages total, 2 per session
        let mut p =
            KvPool::new_paged(4, 1, 4, 4, CacheStore::Int8, rule, 2, Some(4), true).unwrap();
        let prompt = [7i32, 7, 7, 7];
        let (s0, _) = p.alloc_with_prompt(&prompt).unwrap();
        fill(&mut p, &mut rng, s0, 0, 4);
        p.free(s0); // both pages sealed -> LRU, revivable
        assert!(p.all_pages_free(), "LRU pages count as free capacity");
        assert_eq!(p.pages_in_use(), 0);
        // a matching admit revives them from the LRU — zero fresh pages
        let allocated = p.ledger().allocated;
        let (s1, shared) = p.alloc_with_prompt(&prompt).unwrap();
        assert_eq!(shared, 4);
        assert_eq!(p.ledger().revived, 2);
        assert_eq!(p.ledger().allocated, allocated, "revival binds no fresh page");
        p.free(s1);
        // a non-matching admit reclaims the oldest LRU pages once the free
        // list is dry
        let (s2, shared2) = p.alloc_with_prompt(&[1, 2, 3, 4]).unwrap();
        assert_eq!(shared2, 0);
        fill(&mut p, &mut rng, s2, 0, 4);
        let (s3, _) = p.alloc_with_prompt(&[5, 6, 7, 8]).unwrap();
        fill(&mut p, &mut rng, s3, 0, 4);
        assert_eq!(p.ledger().reclaimed, 2, "the two parked pages were stolen");
        // the pool is now fully committed: a fifth session rejects typed
        let err = p.alloc_with_prompt(&[8, 8, 8, 8]).unwrap_err();
        assert!(matches!(err, AdmitErr::Pages { needed: 2, .. }), "{err}");
        assert!(err.to_string().contains("out of pages"), "{err}");
        p.free(s2);
        p.free(s3);
        assert!(p.all_pages_free());
        // ledger balance: every bound page was released (resident == 0)
        let l = p.ledger();
        assert_eq!(l.allocated + l.revived, l.released);
    }
}
