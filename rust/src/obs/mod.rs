//! `obs` — end-to-end telemetry: a global, runtime-toggleable registry of
//! preregistered atomic counters and fixed-bucket latency histograms, plus
//! scoped span timers feeding a preallocated trace-event ring.
//!
//! Design constraints (they explain every choice below):
//!
//! * **Allocation-free recording.** The decode hot loop is pinned to zero
//!   heap allocations (`tests/kernels_zero_alloc.rs`) *with telemetry
//!   enabled*, so nothing on the record path may allocate: counters are a
//!   fixed static array of `AtomicU64` indexed by the [`Counter`] enum,
//!   histogram buckets are fixed at compile time, span names are
//!   `&'static str`, and trace events land in a ring whose capacity is
//!   reserved once at [`enable_tracing`] — a full ring drops new events
//!   (counted in [`Counter::TraceDropped`]) rather than growing.
//! * **Near-zero disabled cost.** Every record call starts with one
//!   relaxed atomic load and a branch; when disabled that is the whole
//!   cost, so instrumentation can stay unconditionally compiled into the
//!   kernels.
//! * **No dependencies.** `obs` sits below every instrumented layer
//!   (kernels, hostmodel, serve, train) and uses only `std`, so nothing
//!   can cycle back into it.
//!
//! Exporters live in [`export`]: Chrome `trace_event` JSON for
//! Perfetto / `chrome://tracing` (`silq serve --trace out.trace.json`).
//! The per-step serve time series is owned by `serve::ServeStats` (it is
//! per-run state, not global) and exported by `--metrics-out`.

pub mod export;

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

// ---------------------------------------------------------------------------
// counter registry
// ---------------------------------------------------------------------------

/// Every counter the system records, preregistered so recording is one
/// array index — no map lookups, no string hashing, no allocation.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
#[repr(usize)]
pub enum Counter {
    /// span scopes entered ([`span`]); balanced against [`Counter::SpanExit`]
    SpanEnter,
    /// span scopes exited (guard drops)
    SpanExit,
    /// trace events dropped because the ring was full
    TraceDropped,
    /// requests accepted into the admission queue
    ServeEnqueued,
    /// requests admitted into a scheduler lane
    ServeAdmitted,
    /// requests completed (includes zero-budget completions)
    ServeCompleted,
    /// requests rejected at admission
    ServeRejected,
    /// lane evictions (one per completion by construction)
    ServeEvicted,
    /// scheduler decode steps
    ServeSteps,
    /// tokens generated across all serve lanes
    ServeNewTokens,
    /// prompt tokens folded into a KV cache without logits (prefill)
    PrefillTokens,
    /// single-lane decode forwards (`forward_token_into` with logits)
    DecodeTokens,
    /// cross-lane batched decode forwards (`forward_tokens_batch` calls)
    BatchSteps,
    /// fused quantized GEMV calls (`QLinear::gemv`)
    GemvCalls,
    /// blocked quantized GEMM calls (`QLinear::gemm_into`)
    GemmCalls,
    /// zero-copy int8 attention calls (`attend_i8`)
    AttendI8Calls,
    /// `i8×i8` multiply-accumulates issued by GEMV/GEMM (dense count;
    /// the zero-activation skip is an optimization, not fewer MACs owed)
    I8Macs,
    /// K/V cache bytes read by `attend_i8` (the memory-bound decode metric)
    KvBytesRead,
    /// QAT/PTQ optimizer steps executed
    QatSteps,
    /// kernel jobs actually fanned out across the worker pool (serial
    /// fast-path calls are not jobs)
    PoolJobs,
    /// shards claimed across all pool jobs (`pool_shards / pool_jobs` =
    /// mean fan-out width)
    PoolShards,
    /// requests cancelled mid-flight (client disconnect evicted the lane)
    ServeCancelled,
    /// TCP connections accepted by the HTTP front-end
    NetConnections,
    /// HTTP requests parsed and routed (all endpoints)
    NetRequests,
    /// streaming completions started (SSE/chunked responses opened)
    NetStreams,
    /// client disconnects detected mid-stream (write failures that
    /// triggered a lane cancellation)
    NetDisconnects,
    /// requests rejected with 429 because the admission queue was full
    Net429,
    /// queued requests shed because their TTFT deadline passed before a
    /// lane freed up (answered 503 + `Retry-After` on the wire)
    DeadlineShed,
    /// in-flight lanes evicted because their decode deadline passed
    DeadlineEvicted,
    /// scheduler steps the watchdog flagged as slow (over
    /// `serve::health::SLOW_STEP_MS`)
    WatchdogSlowSteps,
    /// scheduler steps the watchdog flagged as stuck (over
    /// `serve::health::STUCK_STEP_MS`)
    WatchdogStuckSteps,
    /// faults actually fired by an armed [`crate::faults`] plan
    FaultsInjected,
    /// malformed or hostile wire requests refused by the slowloris guard
    /// (408 read timeout, 431 oversized headers, 413 oversized body)
    NetGuardRejects,
    /// wire requests answered 503 because they were deadline-shed
    Net503Shed,
    /// KV pages bound to a session (free-list pops, including COW copies)
    KvPagesAllocated,
    /// shared-prefix page attaches (refcount bumps + LRU revivals at admit)
    KvPagesShared,
    /// copy-on-write forks (a writer landed inside a page shared rc > 1)
    KvCowForks,
    /// sealed LRU pages stolen for reuse when the free list ran dry
    KvPagesReclaimed,
}

/// Number of registered counters (the registry array size).
pub const N_COUNTERS: usize = 38;

impl Counter {
    /// Every counter, in declaration order — drives [`snapshot`].
    pub const ALL: [Counter; N_COUNTERS] = [
        Counter::SpanEnter,
        Counter::SpanExit,
        Counter::TraceDropped,
        Counter::ServeEnqueued,
        Counter::ServeAdmitted,
        Counter::ServeCompleted,
        Counter::ServeRejected,
        Counter::ServeEvicted,
        Counter::ServeSteps,
        Counter::ServeNewTokens,
        Counter::PrefillTokens,
        Counter::DecodeTokens,
        Counter::BatchSteps,
        Counter::GemvCalls,
        Counter::GemmCalls,
        Counter::AttendI8Calls,
        Counter::I8Macs,
        Counter::KvBytesRead,
        Counter::QatSteps,
        Counter::PoolJobs,
        Counter::PoolShards,
        Counter::ServeCancelled,
        Counter::NetConnections,
        Counter::NetRequests,
        Counter::NetStreams,
        Counter::NetDisconnects,
        Counter::Net429,
        Counter::DeadlineShed,
        Counter::DeadlineEvicted,
        Counter::WatchdogSlowSteps,
        Counter::WatchdogStuckSteps,
        Counter::FaultsInjected,
        Counter::NetGuardRejects,
        Counter::Net503Shed,
        Counter::KvPagesAllocated,
        Counter::KvPagesShared,
        Counter::KvCowForks,
        Counter::KvPagesReclaimed,
    ];

    /// Stable snake_case name (report keys, JSON fields).
    pub fn name(self) -> &'static str {
        match self {
            Counter::SpanEnter => "span_enter",
            Counter::SpanExit => "span_exit",
            Counter::TraceDropped => "trace_dropped",
            Counter::ServeEnqueued => "serve_enqueued",
            Counter::ServeAdmitted => "serve_admitted",
            Counter::ServeCompleted => "serve_completed",
            Counter::ServeRejected => "serve_rejected",
            Counter::ServeEvicted => "serve_evicted",
            Counter::ServeSteps => "serve_steps",
            Counter::ServeNewTokens => "serve_new_tokens",
            Counter::PrefillTokens => "prefill_tokens",
            Counter::DecodeTokens => "decode_tokens",
            Counter::BatchSteps => "batch_steps",
            Counter::GemvCalls => "gemv_calls",
            Counter::GemmCalls => "gemm_calls",
            Counter::AttendI8Calls => "attend_i8_calls",
            Counter::I8Macs => "i8_macs",
            Counter::KvBytesRead => "kv_bytes_read",
            Counter::QatSteps => "qat_steps",
            Counter::PoolJobs => "pool_jobs",
            Counter::PoolShards => "pool_shards",
            Counter::ServeCancelled => "serve_cancelled",
            Counter::NetConnections => "net_connections",
            Counter::NetRequests => "net_requests",
            Counter::NetStreams => "net_streams",
            Counter::NetDisconnects => "net_disconnects",
            Counter::Net429 => "net_429",
            Counter::DeadlineShed => "deadline_shed",
            Counter::DeadlineEvicted => "deadline_evicted",
            Counter::WatchdogSlowSteps => "watchdog_slow_steps",
            Counter::WatchdogStuckSteps => "watchdog_stuck_steps",
            Counter::FaultsInjected => "faults_injected",
            Counter::NetGuardRejects => "net_guard_rejects",
            Counter::Net503Shed => "net_503_shed",
            Counter::KvPagesAllocated => "kv_pages_allocated",
            Counter::KvPagesShared => "kv_pages_shared",
            Counter::KvCowForks => "kv_cow_forks",
            Counter::KvPagesReclaimed => "kv_pages_reclaimed",
        }
    }
}

const ZERO: AtomicU64 = AtomicU64::new(0);
static COUNTERS: [AtomicU64; N_COUNTERS] = [ZERO; N_COUNTERS];
static ENABLED: AtomicBool = AtomicBool::new(false);
/// Trace-ring capacity; 0 means tracing is off (events are not recorded).
static TRACE_CAP: AtomicUsize = AtomicUsize::new(0);
static EVENTS: Mutex<Vec<TraceEvent>> = Mutex::new(Vec::new());
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Timestamps are microseconds since this process-wide epoch (first
/// telemetry activation).
pub fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Turn counter/span recording on or off at runtime. Disabled recording
/// costs one relaxed atomic load + branch per call site.
pub fn set_enabled(on: bool) {
    if on {
        epoch(); // pin the timebase before the first record
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether telemetry recording is on.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Enable span tracing with a ring of `capacity` events (also enables
/// telemetry). The ring is reserved here, once — recording never grows
/// it, so the record path stays allocation-free; when full, new events
/// are dropped and counted in [`Counter::TraceDropped`].
pub fn enable_tracing(capacity: usize) {
    let capacity = capacity.max(16);
    {
        let mut ev = EVENTS.lock().unwrap();
        let have = ev.capacity();
        if have < capacity {
            ev.reserve_exact(capacity - have);
        }
    }
    TRACE_CAP.store(capacity, Ordering::Relaxed);
    set_enabled(true);
}

/// Whether span tracing (the event ring) is active.
#[inline]
pub fn tracing_enabled() -> bool {
    TRACE_CAP.load(Ordering::Relaxed) > 0
}

/// Add `n` to a counter (no-op while telemetry is disabled).
#[inline]
pub fn add(c: Counter, n: u64) {
    if enabled() {
        COUNTERS[c as usize].fetch_add(n, Ordering::Relaxed);
    }
}

/// Current value of a counter.
pub fn get(c: Counter) -> u64 {
    COUNTERS[c as usize].load(Ordering::Relaxed)
}

/// Every counter with its stable name, in declaration order.
pub fn snapshot() -> Vec<(&'static str, u64)> {
    Counter::ALL.iter().map(|&c| (c.name(), get(c))).collect()
}

/// Reset all counters and clear the event ring (tests and fresh runs;
/// the ring keeps its reserved capacity).
pub fn reset() {
    for c in &COUNTERS {
        c.store(0, Ordering::Relaxed);
    }
    EVENTS.lock().unwrap().clear();
    wire_ttft().reset();
}

// ---------------------------------------------------------------------------
// spans + trace events
// ---------------------------------------------------------------------------

/// One completed span in Chrome `trace_event` terms: a `ph: "X"` complete
/// event. Fixed-size on purpose — names are `&'static str` so recording
/// one never allocates.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    /// event name (the trace row label)
    pub name: &'static str,
    /// category (`serve`, `hostmodel`, `train`, ...)
    pub cat: &'static str,
    /// track id — serve lanes map to distinct tids so ragged multi-lane
    /// steps render as separate tracks
    pub tid: u32,
    /// microseconds since [`epoch`]
    pub ts_us: u64,
    /// duration in microseconds
    pub dur_us: u64,
    /// one free integer argument (request id, token count, ...)
    pub arg0: u64,
}

fn push_event(ev: TraceEvent) {
    let cap = TRACE_CAP.load(Ordering::Relaxed);
    if cap == 0 {
        return;
    }
    let mut events = EVENTS.lock().unwrap();
    if events.len() < cap {
        events.push(ev);
    } else {
        drop(events);
        add(Counter::TraceDropped, 1);
    }
}

fn us_since_epoch(t: Instant) -> u64 {
    t.checked_duration_since(epoch()).unwrap_or_default().as_micros() as u64
}

/// Record a complete event retroactively from instants the caller already
/// holds (e.g. a request's queued→admitted interval at completion time).
pub fn event_at(name: &'static str, cat: &'static str, tid: u32, start: Instant, dur_us: u64, arg0: u64) {
    if !enabled() {
        return;
    }
    push_event(TraceEvent { name, cat, tid, ts_us: us_since_epoch(start), dur_us, arg0 });
}

/// Scoped span timer: construction stamps the start (and counts
/// [`Counter::SpanEnter`]); dropping records the duration as a trace
/// event and counts [`Counter::SpanExit`]. The enabled decision is
/// latched at entry so a mid-span toggle can never unbalance the
/// enter/exit counters.
pub struct SpanGuard {
    name: &'static str,
    cat: &'static str,
    tid: u32,
    arg0: u64,
    start: Instant,
    armed: bool,
}

/// Open a span (see [`SpanGuard`]). When telemetry is disabled this is a
/// branch and a cheap `Instant` read; nothing is recorded.
#[inline]
pub fn span(name: &'static str, cat: &'static str, tid: u32, arg0: u64) -> SpanGuard {
    let armed = enabled();
    if armed {
        add(Counter::SpanEnter, 1);
    }
    SpanGuard { name, cat, tid, arg0, start: Instant::now(), armed }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        COUNTERS[Counter::SpanExit as usize].fetch_add(1, Ordering::Relaxed);
        let dur_us = self.start.elapsed().as_micros() as u64;
        push_event(TraceEvent {
            name: self.name,
            cat: self.cat,
            tid: self.tid,
            ts_us: us_since_epoch(self.start),
            dur_us,
            arg0: self.arg0,
        });
    }
}

/// Copy the recorded events out of the ring (export-time only; the hot
/// path never calls this).
pub fn events() -> Vec<TraceEvent> {
    EVENTS.lock().unwrap().clone()
}

// ---------------------------------------------------------------------------
// fixed-bucket latency histogram
// ---------------------------------------------------------------------------

/// Histogram bucket count: power-of-two µs buckets, bucket `b` holding
/// values in `[2^(b-1), 2^b)` µs (bucket 0 holds 0), covering sub-µs up
/// to ~2^39 µs (≈ 6 days) — every latency this system can produce.
pub const HIST_BUCKETS: usize = 40;

/// Fixed-bucket latency histogram with atomic cells: recording is a
/// couple of relaxed atomic adds — no allocation, no sorting, usable
/// through `&self` from any thread. Quantiles are read from the bucket
/// boundaries (upper edge, clamped to the observed min/max), so a
/// percentile costs one bucket walk instead of the clone-and-sort of a
/// raw sample vector; the bound is exact-to-the-bucket (≤ 2× relative,
/// and never outside `[min, max]` actually recorded). Means are exact
/// (sum/count in integer µs).
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
    min_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub const fn new() -> Histogram {
        Histogram {
            buckets: [ZERO; HIST_BUCKETS],
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            min_us: AtomicU64::new(u64::MAX),
            max_us: AtomicU64::new(0),
        }
    }

    #[inline]
    fn bucket_of(us: u64) -> usize {
        (64 - us.leading_zeros() as usize).min(HIST_BUCKETS - 1)
    }

    /// Record one latency in milliseconds. Non-finite and negative inputs
    /// record as 0 (the caller-side contract already filters NaN TTFTs;
    /// this is the don't-poison-the-aggregate backstop).
    pub fn record_ms(&self, ms: f64) {
        let us = if ms.is_finite() && ms > 0.0 { (ms * 1e3) as u64 } else { 0 };
        self.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.min_us.fetch_min(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Exact sum of all recorded samples in integer microseconds — the
    /// quantity `record_ms` actually accumulates (`(ms * 1e3) as u64` per
    /// sample), exposed so tests can pin histogram totals bit-for-bit
    /// against an independently computed sum.
    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    /// Exact mean in ms (0 for an empty histogram — the serve gauges'
    /// degenerate-run contract).
    pub fn mean_ms(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / n as f64 / 1e3
        }
    }

    pub fn min_ms(&self) -> f64 {
        if self.is_empty() {
            0.0
        } else {
            self.min_us.load(Ordering::Relaxed) as f64 / 1e3
        }
    }

    pub fn max_ms(&self) -> f64 {
        if self.is_empty() {
            0.0
        } else {
            self.max_us.load(Ordering::Relaxed) as f64 / 1e3
        }
    }

    /// Zero every cell (tests and fresh runs; used by the global
    /// [`reset`] for the wire-TTFT histogram).
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_us.store(0, Ordering::Relaxed);
        self.min_us.store(u64::MAX, Ordering::Relaxed);
        self.max_us.store(0, Ordering::Relaxed);
    }

    /// Nearest-rank percentile over the buckets: the upper edge of the
    /// bucket holding the target rank, clamped to the observed `[min,
    /// max]`. 0 for an empty histogram.
    pub fn percentile_ms(&self, p: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let target = ((p / 100.0 * n as f64).ceil() as u64).clamp(1, n);
        let mut cum = 0u64;
        for b in 0..HIST_BUCKETS {
            cum += self.buckets[b].load(Ordering::Relaxed);
            if cum >= target {
                // bucket b holds [2^(b-1), 2^b) µs; report the upper edge
                let upper = if b == 0 { 0 } else { 1u64 << b };
                let lo = self.min_us.load(Ordering::Relaxed);
                let hi = self.max_us.load(Ordering::Relaxed);
                return upper.clamp(lo, hi) as f64 / 1e3;
            }
        }
        self.max_ms()
    }
}

// ---------------------------------------------------------------------------
// global wire-latency histogram
// ---------------------------------------------------------------------------

/// Wire-level time-to-first-token: stamped by the HTTP front-end when the
/// first token *frame hits the socket*, so it includes queueing, HTTP
/// parsing, and scheduler latency — the number a client actually feels.
/// Global (like the counters) because connections outlive any one serve
/// run; exported by `GET /metrics` and reset with [`reset`].
static WIRE_TTFT: Histogram = Histogram::new();

/// The global wire-TTFT histogram (see [`WIRE_TTFT`] docs). Recording
/// respects the [`enabled`] flag at the call site in `net`, not here —
/// the histogram itself is always writable.
pub fn wire_ttft() -> &'static Histogram {
    &WIRE_TTFT
}

/// Serialize unit tests that toggle the global enable flag or trace ring
/// (lib tests run on parallel threads; without this, one test's flood can
/// break another's capacity assertion). Poisoning is ignored — a failed
/// sibling test must not cascade.
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global and lib tests run in parallel, so
    // these assertions are monotone (deltas, balance-or-better) rather
    // than exact — and every test that toggles the enable flag or the
    // trace ring holds `test_guard`; the serve soak and obs integration
    // binaries own the exact-accounting assertions in isolation.

    #[test]
    fn counters_record_only_when_enabled() {
        let _g = test_guard();
        set_enabled(false);
        let before = get(Counter::QatSteps);
        add(Counter::QatSteps, 5);
        assert_eq!(get(Counter::QatSteps), before, "disabled add must be a no-op");
        set_enabled(true);
        add(Counter::QatSteps, 5);
        assert!(get(Counter::QatSteps) >= before + 5);
        set_enabled(false);
    }

    #[test]
    fn span_guard_counts_enter_and_exit() {
        let _g = test_guard();
        set_enabled(true);
        let e0 = get(Counter::SpanEnter);
        let x0 = get(Counter::SpanExit);
        {
            let _g = span("test", "obs", 0, 7);
            assert!(get(Counter::SpanEnter) >= e0 + 1);
        }
        assert!(get(Counter::SpanExit) >= x0 + 1);
        set_enabled(false);
    }

    #[test]
    fn tracing_ring_caps_and_counts_drops() {
        let _g = test_guard();
        enable_tracing(16);
        let base = events().len();
        for i in 0..64u64 {
            event_at("flood", "obs", 0, Instant::now(), 1, i);
        }
        let ev = events();
        assert!(ev.len() <= 16, "ring exceeded its capacity: {}", ev.len());
        assert!(ev.len() >= base.min(16));
        assert!(get(Counter::TraceDropped) > 0, "a full ring must count drops");
        set_enabled(false);
    }

    #[test]
    fn histogram_mean_and_percentiles() {
        let h = Histogram::new();
        assert_eq!(h.mean_ms(), 0.0);
        assert_eq!(h.percentile_ms(95.0), 0.0);
        for ms in [1.0f64, 2.0, 3.0, 4.0] {
            h.record_ms(ms);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean_ms() - 2.5).abs() < 1e-6);
        let p95 = h.percentile_ms(95.0);
        assert!(p95.is_finite() && p95 >= h.min_ms() && p95 <= h.max_ms());
        // NaN / negative inputs are clamped into bucket 0, never poisoning
        h.record_ms(f64::NAN);
        h.record_ms(-3.0);
        assert_eq!(h.count(), 6);
        assert!(h.mean_ms().is_finite());
        assert!(h.percentile_ms(50.0).is_finite());
    }

    #[test]
    fn histogram_percentile_stays_within_observed_range() {
        let h = Histogram::new();
        for i in 1..=100u64 {
            h.record_ms(i as f64);
        }
        for p in [10.0, 50.0, 90.0, 99.0, 100.0] {
            let v = h.percentile_ms(p);
            assert!(
                (h.min_ms()..=h.max_ms()).contains(&v),
                "p{p} = {v} outside [{}, {}]",
                h.min_ms(),
                h.max_ms()
            );
        }
        // bucket resolution: p100 lands in the top bucket, clamped to max
        assert!(h.percentile_ms(100.0) <= 100.0 + 1e-9);
    }

    #[test]
    fn counter_names_are_unique_and_total() {
        let names: Vec<&str> = Counter::ALL.iter().map(|c| c.name()).collect();
        assert_eq!(names.len(), N_COUNTERS);
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), N_COUNTERS, "duplicate counter names");
    }
}
