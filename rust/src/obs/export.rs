//! Trace export: serialize the recorded span ring as Chrome `trace_event`
//! JSON, loadable in Perfetto (<https://ui.perfetto.dev>) or
//! `chrome://tracing`.
//!
//! Format: one complete event (`"ph": "X"`) per recorded span, timestamps
//! and durations in microseconds since the telemetry [`epoch`](super::epoch).
//! All spans share `pid` 1; the `tid` separates tracks — serve lanes map
//! to `tid = lane + 1` so ragged multi-lane steps render as parallel
//! rows, and scheduler-wide spans sit on `tid` 0.

use std::io::Write;

use super::{events, snapshot, wire_ttft, TraceEvent};

fn push_event_json(out: &mut String, ev: &TraceEvent) {
    // names/cats are static identifiers (no quotes or escapes by
    // construction), so plain formatting is valid JSON here
    out.push_str(&format!(
        "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"pid\":1,\"tid\":{},\"ts\":{},\"dur\":{},\"args\":{{\"v\":{}}}}}",
        ev.name, ev.cat, ev.tid, ev.ts_us, ev.dur_us, ev.arg0
    ));
}

/// Render the current event ring as a Chrome trace JSON document. The
/// counter snapshot rides along under `"counters"` so a trace file is
/// self-describing about the run that produced it.
pub fn chrome_trace_json() -> String {
    let evs = events();
    let mut out = String::with_capacity(128 + evs.len() * 120);
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    for (i, ev) in evs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        push_event_json(&mut out, ev);
    }
    out.push_str("],\"counters\":{");
    for (i, (name, v)) in snapshot().iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("\"{name}\":{v}"));
    }
    out.push_str("}}");
    out
}

/// Render the live counter registry plus the wire-TTFT summary as a
/// `silq.metrics.v1` JSON document — what `GET /metrics` serves, so a
/// running server is scrapeable without `--metrics-out` (which instead
/// exports the per-run `ServeStats` time series under the same schema
/// tag).
pub fn metrics_live_json() -> String {
    let mut out = String::with_capacity(512);
    out.push_str("{\"schema\":\"silq.metrics.v1\",\"counters\":{");
    for (i, (name, v)) in snapshot().iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("\"{name}\":{v}"));
    }
    let h = wire_ttft();
    out.push_str(&format!(
        "}},\"wire_ttft\":{{\"count\":{},\"mean_ms\":{:.3},\"p50_ms\":{:.3},\
         \"p95_ms\":{:.3},\"max_ms\":{:.3}}}}}",
        h.count(),
        h.mean_ms(),
        h.percentile_ms(50.0),
        h.percentile_ms(95.0),
        h.max_ms(),
    ));
    out
}

/// Write the Chrome trace document to `path`.
pub fn write_chrome_trace(path: &str) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(chrome_trace_json().as_bytes())?;
    f.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_json_has_chrome_shape() {
        let doc = chrome_trace_json();
        assert!(doc.starts_with('{') && doc.ends_with('}'));
        assert!(doc.contains("\"traceEvents\":["));
        assert!(doc.contains("\"counters\":{"));
        assert!(doc.contains("\"gemv_calls\":"));
    }

    #[test]
    fn live_metrics_json_has_schema_counters_and_wire_ttft() {
        let doc = metrics_live_json();
        assert!(doc.contains("\"schema\":\"silq.metrics.v1\""));
        assert!(doc.contains("\"net_requests\":"));
        assert!(doc.contains("\"serve_cancelled\":"));
        assert!(doc.contains("\"wire_ttft\":{\"count\":"));
        assert!(!doc.contains("NaN"), "live metrics leaked a NaN:\n{doc}");
    }

    #[test]
    fn events_render_as_complete_events() {
        let _g = crate::obs::test_guard();
        crate::obs::enable_tracing(64);
        crate::obs::event_at("unit_test_event", "obs", 3, std::time::Instant::now(), 42, 7);
        let doc = chrome_trace_json();
        assert!(doc.contains("\"name\":\"unit_test_event\""));
        assert!(doc.contains("\"ph\":\"X\""));
        crate::obs::set_enabled(false);
    }
}
