//! Evaluation harness — the lm-evaluation-harness analog.
//!
//! Multiple choice: length-normalized continuation log-likelihood over the
//! candidate answers (exactly the mechanics of ARC/HellaSwag/MMLU scoring).
//! Generation: greedy decoding + exact match (GSM8K/IFEval mechanics).

pub mod decode;

use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::Arc;

use crate::config::ModelCfg;
use crate::data::{EvalItem, Suite, TaskKind, World};
use crate::model::ParamStore;
use crate::runtime::{build_inputs, literal_i32, to_f32_vec, Engine, Module};

use decode::{argmax, log_softmax_at, pack_rows};

/// Scores one model (params + fwd artifact) on the benchmark registry.
pub struct Evaluator<'e> {
    pub engine: &'e Engine,
    pub module: Arc<Module>,
    pub mc: ModelCfg,
    /// apply the instruct chat template (paper's --apply_chat_template)
    pub chat: bool,
    /// items per task
    pub n_items: usize,
}

/// Per-suite averages plus per-task accuracies.
#[derive(Clone, Debug, Default)]
pub struct EvalReport {
    pub per_task: Vec<(String, Suite, f32)>,
}

impl EvalReport {
    pub fn suite_avg(&self, suite: Suite) -> f32 {
        let v: Vec<f32> =
            self.per_task.iter().filter(|(_, s, _)| *s == suite).map(|(_, _, a)| *a).collect();
        if v.is_empty() {
            0.0
        } else {
            v.iter().sum::<f32>() / v.len() as f32
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "CSR {:.2}  OLLMv1 {:.2}  OLLMv2 {:.2}",
            100.0 * self.suite_avg(Suite::Csr),
            100.0 * self.suite_avg(Suite::OllmV1),
            100.0 * self.suite_avg(Suite::OllmV2)
        )
    }
}

impl<'e> Evaluator<'e> {
    pub fn new(engine: &'e Engine, artifact: &str, chat: bool, n_items: usize) -> Result<Self> {
        let module = engine.module(artifact)?;
        let mc = engine.manifest.model(&module.spec.model)?.clone();
        Ok(Evaluator { engine, module, mc, chat, n_items })
    }

    /// Run one [fwd_batch, seq_len] token batch -> logits (row-major).
    fn logits(&self, params: &ParamStore, tokens: &[i32]) -> Result<Vec<f32>> {
        let spec = &self.module.spec;
        let tok_spec = &spec.inputs[spec.input_index("tokens")?];
        let inputs =
            build_inputs(spec, params, &[("tokens", literal_i32(&tok_spec.dims, tokens)?)])?;
        let out = self.module.run(&inputs)?;
        to_f32_vec(&out[0])
    }

    /// Length-normalized log-likelihood of `cont` following `prompt` for a
    /// set of rows, evaluated in packed batches.
    fn continuation_scores(
        &self,
        params: &ParamStore,
        rows: &[(Vec<i32>, Vec<i32>)], // (prompt, continuation)
    ) -> Result<Vec<f32>> {
        let (bsz, s, v) = (self.mc.fwd_batch, self.mc.seq_len, self.mc.vocab);
        let mut scores = vec![0f32; rows.len()];
        for (chunk_idx, chunk) in rows.chunks(bsz).enumerate() {
            let joined: Vec<Vec<i32>> =
                chunk.iter().map(|(p, c)| p.iter().chain(c.iter()).cloned().collect()).collect();
            let views: Vec<&[i32]> = joined.iter().map(|r| r.as_slice()).collect();
            let tokens = pack_rows(&views, bsz, s);
            let logits = self.logits(params, &tokens)?;
            for (r, (p, c)) in chunk.iter().enumerate() {
                let mut total = 0f32;
                let mut n = 0usize;
                for (k, &tok) in c.iter().enumerate() {
                    let pos = p.len() + k; // predicted from pos-1
                    if pos >= s {
                        break;
                    }
                    let base = (r * s + pos - 1) * v;
                    let row_logits = &logits[base..base + v];
                    total += log_softmax_at(row_logits, tok as usize);
                    n += 1;
                }
                scores[chunk_idx * bsz + r] = if n > 0 { total / n as f32 } else { f32::MIN };
            }
        }
        Ok(scores)
    }

    /// Greedy-decode `max_new` tokens for each prompt.
    pub fn generate(
        &self,
        params: &ParamStore,
        prompts: &[Vec<i32>],
        max_new: usize,
    ) -> Result<Vec<Vec<i32>>> {
        let (bsz, s, v) = (self.mc.fwd_batch, self.mc.seq_len, self.mc.vocab);
        let mut outs: Vec<Vec<i32>> = vec![vec![]; prompts.len()];
        for (chunk_idx, chunk) in prompts.chunks(bsz).enumerate() {
            let mut rows: Vec<Vec<i32>> = chunk.to_vec();
            for _ in 0..max_new {
                let views: Vec<&[i32]> = rows.iter().map(|r| r.as_slice()).collect();
                let tokens = pack_rows(&views, bsz, s);
                let logits = self.logits(params, &tokens)?;
                for (r, row) in rows.iter_mut().enumerate() {
                    if row.len() >= s {
                        continue;
                    }
                    let base = (r * s + row.len() - 1) * v;
                    let next = argmax(&logits[base..base + v]) as i32;
                    row.push(next);
                    outs[chunk_idx * bsz + r].push(next);
                }
            }
        }
        Ok(outs)
    }

    /// Score one task's items.
    pub fn score_task(
        &self,
        params: &ParamStore,
        kind: TaskKind,
        items: &[EvalItem],
    ) -> Result<f32> {
        match kind {
            TaskKind::MultipleChoice => {
                let mut rows = vec![];
                let mut spans = vec![];
                for it in items {
                    spans.push((rows.len(), it.choices.len()));
                    for c in &it.choices {
                        rows.push((it.prompt.clone(), c.clone()));
                    }
                }
                let scores = self.continuation_scores(params, &rows)?;
                let mut correct = 0usize;
                for (it, (start, n)) in items.iter().zip(&spans) {
                    let best = (0..*n)
                        .max_by(|&a, &b| {
                            scores[start + a].partial_cmp(&scores[start + b]).unwrap()
                        })
                        .unwrap();
                    if best == it.correct {
                        correct += 1;
                    }
                }
                Ok(correct as f32 / items.len() as f32)
            }
            TaskKind::Generate => {
                let prompts: Vec<Vec<i32>> = items.iter().map(|i| i.prompt.clone()).collect();
                let max_new = items.iter().map(|i| i.answer.len()).max().unwrap_or(1);
                let gens = self.generate(params, &prompts, max_new)?;
                let mut correct = 0usize;
                for (it, g) in items.iter().zip(&gens) {
                    if g.len() >= it.answer.len() && g[..it.answer.len()] == it.answer[..] {
                        correct += 1;
                    }
                }
                Ok(correct as f32 / items.len() as f32)
            }
        }
    }

    /// Evaluate the full registry on a world.
    pub fn eval_all(&self, params: &ParamStore, world: &World, seed: u64) -> Result<EvalReport> {
        let mut report = EvalReport::default();
        for task in crate::data::tasks::registry(self.n_items) {
            let items = task.items(world, self.chat, seed);
            let acc = self.score_task(params, task.kind, &items)?;
            report.per_task.push((task.name.to_string(), task.suite, acc));
        }
        Ok(report)
    }

    /// Evaluate only the named suites (faster loops, e.g. Figure 1 sweeps).
    pub fn eval_suites(
        &self,
        params: &ParamStore,
        world: &World,
        suites: &[Suite],
        seed: u64,
    ) -> Result<EvalReport> {
        let mut report = EvalReport::default();
        for task in crate::data::tasks::registry(self.n_items) {
            if !suites.contains(&task.suite) {
                continue;
            }
            let items = task.items(world, self.chat, seed);
            let acc = self.score_task(params, task.kind, &items)?;
            report.per_task.push((task.name.to_string(), task.suite, acc));
        }
        Ok(report)
    }
}

/// Aggregate multiple reports (e.g. across model seeds) by task name.
pub fn average_reports(reports: &[EvalReport]) -> EvalReport {
    let mut acc: BTreeMap<(String, u8), (Suite, f32, usize)> = BTreeMap::new();
    for r in reports {
        for (name, suite, a) in &r.per_task {
            let k = (name.clone(), *suite as u8);
            let e = acc.entry(k).or_insert((*suite, 0.0, 0));
            e.1 += a;
            e.2 += 1;
        }
    }
    EvalReport {
        per_task: acc
            .into_iter()
            .map(|((name, _), (suite, total, n))| (name, suite, total / n as f32))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_suite_average() {
        let r = EvalReport {
            per_task: vec![
                ("a".into(), Suite::Csr, 0.5),
                ("b".into(), Suite::Csr, 0.7),
                ("c".into(), Suite::OllmV1, 0.2),
            ],
        };
        assert!((r.suite_avg(Suite::Csr) - 0.6).abs() < 1e-6);
        assert!((r.suite_avg(Suite::OllmV1) - 0.2).abs() < 1e-6);
        assert_eq!(r.suite_avg(Suite::OllmV2), 0.0);
    }

    #[test]
    fn average_reports_merges() {
        let a = EvalReport { per_task: vec![("t".into(), Suite::Csr, 0.4)] };
        let b = EvalReport { per_task: vec![("t".into(), Suite::Csr, 0.6)] };
        let avg = average_reports(&[a, b]);
        assert!((avg.per_task[0].2 - 0.5).abs() < 1e-6);
    }
}
