//! Evaluation harness — the lm-evaluation-harness analog.
//!
//! Multiple choice: length-normalized continuation log-likelihood over the
//! candidate answers (exactly the mechanics of ARC/HellaSwag/MMLU scoring).
//! Generation: greedy decoding + exact match (GSM8K/IFEval mechanics).
//!
//! The harness is generic over [`ForwardBackend`], so the same scoring
//! machinery runs against the compiled PJRT graph (`ArtifactForward`) or
//! the artifact-free host transformer (`HostForward`) — `silq eval
//! --backend host` needs nothing built. Generation goes through the shared
//! incremental decode driver: one token of work per step on the host
//! backend, early-exiting as soon as every row in a chunk is finished.

pub mod decode;

use anyhow::Result;
use std::collections::BTreeMap;

use crate::data::{EvalItem, Suite, TaskKind, World};
use crate::forward::{decode_greedy, ForwardBackend};

use decode::log_softmax_at;

/// Salt mixed into the world seed for eval item sampling — one constant so
/// every eval entry point (`Pipeline::eval`, `silq eval --backend host`)
/// scores the exact same items for a given world.
pub const EVAL_SEED_SALT: u64 = 0xE7A1;

/// Scores one bound model (a [`ForwardBackend`] with its parameters fixed
/// at construction) on the benchmark registry.
pub struct Evaluator<B: ForwardBackend> {
    pub backend: B,
    /// apply the instruct chat template (paper's --apply_chat_template)
    pub chat: bool,
    /// items per task
    pub n_items: usize,
}

/// Per-suite averages plus per-task accuracies.
#[derive(Clone, Debug, Default)]
pub struct EvalReport {
    pub per_task: Vec<(String, Suite, f32)>,
}

impl EvalReport {
    pub fn suite_avg(&self, suite: Suite) -> f32 {
        let v: Vec<f32> =
            self.per_task.iter().filter(|(_, s, _)| *s == suite).map(|(_, _, a)| *a).collect();
        if v.is_empty() {
            0.0
        } else {
            v.iter().sum::<f32>() / v.len() as f32
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "CSR {:.2}  OLLMv1 {:.2}  OLLMv2 {:.2}",
            100.0 * self.suite_avg(Suite::Csr),
            100.0 * self.suite_avg(Suite::OllmV1),
            100.0 * self.suite_avg(Suite::OllmV2)
        )
    }
}

impl<B: ForwardBackend> Evaluator<B> {
    pub fn new(backend: B, chat: bool, n_items: usize) -> Self {
        Evaluator { backend, chat, n_items }
    }

    /// Length-normalized log-likelihood of `cont` following `prompt` for a
    /// set of rows, evaluated in packed batches.
    fn continuation_scores(
        &mut self,
        rows: &[(Vec<i32>, Vec<i32>)], // (prompt, continuation)
    ) -> Result<Vec<f32>> {
        let (bsz, s, v) =
            (self.backend.batch(), self.backend.seq_len(), self.backend.vocab());
        let mut scores = vec![0f32; rows.len()];
        for (chunk_idx, chunk) in rows.chunks(bsz).enumerate() {
            let joined: Vec<Vec<i32>> =
                chunk.iter().map(|(p, c)| p.iter().chain(c.iter()).cloned().collect()).collect();
            let views: Vec<&[i32]> = joined.iter().map(|r| r.as_slice()).collect();
            let logits = self.backend.batch_logits(&views)?;
            for (r, (p, c)) in chunk.iter().enumerate() {
                let mut total = 0f32;
                let mut n = 0usize;
                for (k, &tok) in c.iter().enumerate() {
                    let pos = p.len() + k; // predicted from pos-1
                    if pos == 0 {
                        // empty prompt: no position predicts the first
                        // continuation token — skip it instead of wrapping
                        // the index below zero
                        continue;
                    }
                    if pos >= s {
                        break;
                    }
                    let base = (r * s + pos - 1) * v;
                    let row_logits = &logits[base..base + v];
                    total += log_softmax_at(row_logits, tok as usize);
                    n += 1;
                }
                scores[chunk_idx * bsz + r] = if n > 0 { total / n as f32 } else { f32::MIN };
            }
        }
        Ok(scores)
    }

    /// Greedy-decode up to `max_new` tokens for each prompt through the
    /// backend's incremental decode session (early-exits per chunk once
    /// every row is finished or hit the context window).
    pub fn generate(&mut self, prompts: &[Vec<i32>], max_new: usize) -> Result<Vec<Vec<i32>>> {
        let bsz = self.backend.batch();
        let mut outs = Vec::with_capacity(prompts.len());
        for chunk in prompts.chunks(bsz) {
            let views: Vec<&[i32]> = chunk.iter().map(|p| p.as_slice()).collect();
            outs.extend(decode_greedy(&mut self.backend, &views, max_new)?);
        }
        Ok(outs)
    }

    /// Score one task's items.
    pub fn score_task(&mut self, kind: TaskKind, items: &[EvalItem]) -> Result<f32> {
        match kind {
            TaskKind::MultipleChoice => {
                let mut rows = vec![];
                let mut spans = vec![];
                for it in items {
                    spans.push((rows.len(), it.choices.len()));
                    for c in &it.choices {
                        rows.push((it.prompt.clone(), c.clone()));
                    }
                }
                let scores = self.continuation_scores(&rows)?;
                let mut correct = 0usize;
                for (it, (start, n)) in items.iter().zip(&spans) {
                    let best = (0..*n)
                        .max_by(|&a, &b| {
                            scores[start + a].partial_cmp(&scores[start + b]).unwrap()
                        })
                        .unwrap();
                    if best == it.correct {
                        correct += 1;
                    }
                }
                Ok(correct as f32 / items.len() as f32)
            }
            TaskKind::Generate => {
                let prompts: Vec<Vec<i32>> = items.iter().map(|i| i.prompt.clone()).collect();
                let max_new = items.iter().map(|i| i.answer.len()).max().unwrap_or(1);
                let gens = self.generate(&prompts, max_new)?;
                let mut correct = 0usize;
                for (it, g) in items.iter().zip(&gens) {
                    if g.len() >= it.answer.len() && g[..it.answer.len()] == it.answer[..] {
                        correct += 1;
                    }
                }
                Ok(correct as f32 / items.len() as f32)
            }
        }
    }

    /// Evaluate the full registry on a world.
    pub fn eval_all(&mut self, world: &World, seed: u64) -> Result<EvalReport> {
        let mut report = EvalReport::default();
        for task in crate::data::tasks::registry(self.n_items) {
            let items = task.items(world, self.chat, seed);
            let acc = self.score_task(task.kind, &items)?;
            report.per_task.push((task.name.to_string(), task.suite, acc));
        }
        Ok(report)
    }

    /// Evaluate only the named suites (faster loops, e.g. Figure 1 sweeps).
    pub fn eval_suites(
        &mut self,
        world: &World,
        suites: &[Suite],
        seed: u64,
    ) -> Result<EvalReport> {
        let mut report = EvalReport::default();
        for task in crate::data::tasks::registry(self.n_items) {
            if !suites.contains(&task.suite) {
                continue;
            }
            let items = task.items(world, self.chat, seed);
            let acc = self.score_task(task.kind, &items)?;
            report.per_task.push((task.name.to_string(), task.suite, acc));
        }
        Ok(report)
    }
}

/// Aggregate multiple reports (e.g. across model seeds) by task name.
pub fn average_reports(reports: &[EvalReport]) -> EvalReport {
    let mut acc: BTreeMap<(String, u8), (Suite, f32, usize)> = BTreeMap::new();
    for r in reports {
        for (name, suite, a) in &r.per_task {
            let k = (name.clone(), *suite as u8);
            let e = acc.entry(k).or_insert((*suite, 0.0, 0));
            e.1 += a;
            e.2 += 1;
        }
    }
    EvalReport {
        per_task: acc
            .into_iter()
            .map(|((name, _), (suite, total, n))| (name, suite, total / n as f32))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_suite_average() {
        let r = EvalReport {
            per_task: vec![
                ("a".into(), Suite::Csr, 0.5),
                ("b".into(), Suite::Csr, 0.7),
                ("c".into(), Suite::OllmV1, 0.2),
            ],
        };
        assert!((r.suite_avg(Suite::Csr) - 0.6).abs() < 1e-6);
        assert!((r.suite_avg(Suite::OllmV1) - 0.2).abs() < 1e-6);
        assert_eq!(r.suite_avg(Suite::OllmV2), 0.0);
    }

    #[test]
    fn average_reports_merges() {
        let a = EvalReport { per_task: vec![("t".into(), Suite::Csr, 0.4)] };
        let b = EvalReport { per_task: vec![("t".into(), Suite::Csr, 0.6)] };
        let avg = average_reports(&[a, b]);
        assert!((avg.per_task[0].2 - 0.5).abs() < 1e-6);
    }

    #[test]
    fn empty_prompt_continuation_does_not_underflow() {
        // regression: an empty prompt made `pos == 0` and
        // `(r*s + pos - 1) * v` wrapped the usize into a huge slice index
        use crate::forward::HostForward;
        use crate::hostmodel::{host_test_params, tiny_host_cfg, CacheStore};
        let cfg = tiny_host_cfg(true, true);
        let params = host_test_params(&cfg, 19);
        let fwd = HostForward::new(cfg, 2, &params, CacheStore::F32).unwrap();
        let mut ev = Evaluator::new(fwd, false, 2);
        let rows = vec![
            (vec![], vec![1i32, 3]),      // empty prompt: first token skipped
            (vec![1i32], vec![3i32, 4]),  // normal row
        ];
        let scores = ev.continuation_scores(&rows).unwrap();
        assert_eq!(scores.len(), 2);
        assert!(scores.iter().all(|s| s.is_finite()));
    }
}
