//! Greedy-decode primitives shared by the eval harness, the forward
//! backends and the serve engine, so `silq eval` and `silq serve` score
//! and sample identically.

use crate::data::vocab::PAD;

/// Index of the maximum logit (greedy next-token choice).
pub fn argmax(xs: &[f32]) -> usize {
    xs.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).map(|(i, _)| i).unwrap()
}

/// [`argmax`] over each `width`-sized row of a flat `[rows * width]`
/// buffer — the greedy pick for a batched decode step's stacked logits
/// (`HostForward::step_greedy` reads its scratch through this, so serve's
/// batched hot path and the single-row path share one tie-break rule).
pub fn argmax_rows(flat: &[f32], width: usize) -> Vec<usize> {
    debug_assert!(width > 0 && flat.len() % width == 0);
    flat.chunks(width).map(argmax).collect()
}

/// Log-probability of token `idx` under a softmax over `logits`. The max
/// fold seeds with `f32::NEG_INFINITY` (the identity element of `max`),
/// matching `kernels::softmax_inplace`.
pub fn log_softmax_at(logits: &[f32], idx: usize) -> f32 {
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let lse = logits.iter().map(|&x| (x - m).exp()).sum::<f32>().ln() + m;
    logits[idx] - lse
}

/// Pack variable-length rows into the fixed `[bsz, s]` token shape a fwd
/// artifact expects: PAD-filled, rows truncated at the context window,
/// missing rows all-PAD.
pub fn pack_rows(rows: &[&[i32]], bsz: usize, s: usize) -> Vec<i32> {
    let mut tokens = vec![PAD; bsz * s];
    for (r, row) in rows.iter().enumerate().take(bsz) {
        let l = row.len().min(s);
        tokens[r * s..r * s + l].copy_from_slice(&row[..l]);
    }
    tokens
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_softmax_normalizes() {
        let l = [1.0f32, 2.0, 3.0];
        let p: f32 = (0..3).map(|i| log_softmax_at(&l, i).exp()).sum();
        assert!((p - 1.0).abs() < 1e-5);
        assert!(log_softmax_at(&l, 2) > log_softmax_at(&l, 0));
    }

    #[test]
    fn argmax_works() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
    }

    #[test]
    fn argmax_rows_matches_per_row_argmax() {
        let flat = [0.1f32, 0.9, 0.5, 2.0, -1.0, 0.0];
        assert_eq!(argmax_rows(&flat, 3), vec![1, 0]);
        assert!(argmax_rows(&[], 4).is_empty());
    }

    #[test]
    fn pack_rows_pads_and_truncates() {
        let rows: Vec<&[i32]> = vec![&[1, 2, 3], &[4, 5, 6, 7, 8, 9]];
        let t = pack_rows(&rows, 3, 4);
        assert_eq!(t, vec![1, 2, 3, PAD, 4, 5, 6, 7, PAD, PAD, PAD, PAD]);
    }
}
