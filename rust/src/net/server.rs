//! The HTTP front-end: a dependency-light HTTP/1.1 server on std
//! `TcpListener` in front of the continuous-batching engine.
//!
//! Shape: a non-blocking accept loop (so the shutdown flag and SIGINT are
//! polled between accepts) hands each connection to its own handler
//! thread, bounded by `max_conns` slots — beyond that, connections queue
//! in the OS backlog, which is backpressure a load balancer understands.
//! Handlers submit into the shared [`AdmissionQueue`] with
//! [`AdmissionQueue::try_submit`], so a full queue becomes `429 Too Many
//! Requests` on the wire instead of a stalled socket.
//!
//! Endpoints:
//!
//! * `POST /v1/completions` — body `{"prompt":[...], "max_tokens":N,
//!   "ignore_eos":bool, "stream":bool, "id":N, "priority":"interactive"|
//!   "batch", "deadline_ms":N, "ttft_deadline_ms":N}` (all but `prompt`
//!   optional). Buffered mode answers one JSON result; streaming mode
//!   answers SSE-over-chunked, one `data: {"token":T}` frame per decoded
//!   token and a terminal `data: {"done":true, ...}` frame. A failed
//!   frame write (client disconnect) sets the request's cancel flag: the
//!   scheduler evicts the lane and frees its KV slot at the next step
//!   boundary — mid-decode, not at drain. A full admission queue answers
//!   `429` and a TTFT-deadline shed answers `503`, both with a
//!   `Retry-After` header derived from queue depth × recent step time.
//! * `GET /healthz` — health state machine: `ok`, `degraded` (recent
//!   deadline misses / slow steps, with evidence fields), or `draining`.
//! * `GET /metrics` — live `silq.metrics.v1` counters + wire-TTFT summary
//!   ([`crate::obs::export::metrics_live_json`]).
//! * `POST /shutdown` — graceful drain: stop accepting, finish in-flight
//!   lanes, return. SIGINT triggers the same path when
//!   [`install_sigint_drain`] was called.

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::net::http;
use crate::net::json::{escape, Json};
use crate::obs::{add, Counter};
use crate::serve::{
    health, AdmissionQueue, DecodeBackend, FinishReason, GenRequest, GenResult, Priority,
    ServeHandle, ServeOutcome, StreamEvent, SubmitError,
};

const JSON_TYPE: &str = "application/json";
const SSE_TYPE: &str = "text/event-stream";
/// Accept-loop poll interval: how fast drain/SIGINT are noticed.
const POLL: Duration = Duration::from_millis(5);
/// Per-socket read/write timeout — a dead peer must not pin a handler
/// slot forever (one blocked write of a token frame times out and takes
/// the disconnect path).
const SOCKET_TIMEOUT: Duration = Duration::from_secs(30);

/// Server construction parameters.
pub struct ServerCfg {
    /// listen address (`host:port`; port 0 binds an ephemeral port —
    /// read it back from [`Server::local_addr`])
    pub addr: String,
    /// scheduler batch lanes
    pub lanes: usize,
    /// admission-queue capacity (beyond it: 429)
    pub queue_cap: usize,
    /// concurrent connection-handler cap (beyond it: OS backlog)
    pub max_conns: usize,
    /// `max_tokens` when the request body does not set one
    pub default_max_new: usize,
    /// slowloris guard: how long a connection may take to deliver its
    /// full request (start-line, headers, body) before it is answered
    /// `408` and dropped. The generous [`SOCKET_TIMEOUT`] is restored
    /// for the response/stream phase.
    pub header_timeout_ms: u64,
}

/// Wire-side totals for one server run, tallied locally (always on,
/// independent of the global telemetry toggle) and mirrored into the
/// [`Counter`] registry when telemetry is enabled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NetReport {
    pub connections: u64,
    pub requests: u64,
    /// streaming completions opened
    pub streams: u64,
    /// mid-stream client disconnects that triggered a cancellation
    pub disconnects: u64,
    /// requests answered 429 (admission queue full)
    pub rejected_429: u64,
    /// requests answered 503 after a TTFT-deadline shed in the queue
    pub shed_503: u64,
    /// connections refused by the request-head guards (408/413/431)
    pub guard_rejects: u64,
}

#[derive(Default)]
struct Tallies {
    connections: AtomicU64,
    requests: AtomicU64,
    streams: AtomicU64,
    disconnects: AtomicU64,
    rejected_429: AtomicU64,
    shed_503: AtomicU64,
    guard_rejects: AtomicU64,
}

impl Tallies {
    fn bump(&self, local: &AtomicU64, counter: Counter) {
        local.fetch_add(1, Ordering::Relaxed);
        add(counter, 1);
    }

    fn report(&self) -> NetReport {
        NetReport {
            connections: self.connections.load(Ordering::Relaxed),
            requests: self.requests.load(Ordering::Relaxed),
            streams: self.streams.load(Ordering::Relaxed),
            disconnects: self.disconnects.load(Ordering::Relaxed),
            rejected_429: self.rejected_429.load(Ordering::Relaxed),
            shed_503: self.shed_503.load(Ordering::Relaxed),
            guard_rejects: self.guard_rejects.load(Ordering::Relaxed),
        }
    }
}

/// Everything a connection handler needs, behind one `Arc`.
struct Ctx {
    queue: Arc<AdmissionQueue>,
    tallies: Tallies,
    shutdown: Arc<AtomicBool>,
    /// ids for bodies that do not pick their own
    next_id: AtomicU64,
    default_max_new: usize,
    header_timeout: Duration,
}

/// A bound listener, ready to [`Server::run`].
pub struct Server {
    listener: TcpListener,
    addr: SocketAddr,
    cfg: ServerCfg,
    shutdown: Arc<AtomicBool>,
}

impl Server {
    /// Bind `cfg.addr` (non-blocking, so the accept loop can poll the
    /// drain flags).
    pub fn bind(cfg: ServerCfg) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        listener.set_nonblocking(true).context("non-blocking listener")?;
        let addr = listener.local_addr().context("listener address")?;
        Ok(Server { listener, addr, cfg, shutdown: Arc::new(AtomicBool::new(false)) })
    }

    /// The bound address (resolves port 0 to the actual ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The drain flag: set it (from any thread) to stop accepting and
    /// finish in-flight work — what `POST /shutdown` does internally.
    pub fn shutdown_flag(&self) -> Arc<AtomicBool> {
        self.shutdown.clone()
    }

    /// Serve until drained (`/shutdown`, the shutdown flag, or SIGINT):
    /// spawns the scheduler worker, accepts connections into bounded
    /// handler threads, then joins every handler, closes the queue, and
    /// hands back the scheduler outcome (results, stats, backend — for
    /// the shutdown invariants) plus the wire-side [`NetReport`].
    pub fn run<B: DecodeBackend + Send + 'static>(
        self,
        backend: B,
    ) -> Result<(ServeOutcome<B>, NetReport)> {
        // reset health before the accept loop opens: a handler must never
        // read stale pressure/draining left by a previous server in the
        // same process (the scheduler thread also resets, but it races
        // the first accept)
        health::reset();
        let handle = ServeHandle::spawn(backend, self.cfg.lanes, self.cfg.queue_cap)?;
        let ctx = Arc::new(Ctx {
            queue: handle.queue(),
            tallies: Tallies::default(),
            shutdown: self.shutdown.clone(),
            next_id: AtomicU64::new(1),
            default_max_new: self.cfg.default_max_new.max(1),
            header_timeout: Duration::from_millis(self.cfg.header_timeout_ms.max(1)),
        });

        // handler-slot accounting: slot acquired before spawn, released by
        // the guard when the handler thread exits (however it exits)
        let slots = Arc::new((Mutex::new(0usize), Condvar::new()));
        struct SlotGuard(Arc<(Mutex<usize>, Condvar)>);
        impl Drop for SlotGuard {
            fn drop(&mut self) {
                *self.0 .0.lock().unwrap() -= 1;
                self.0 .1.notify_one();
            }
        }

        let mut handlers: Vec<std::thread::JoinHandle<()>> = Vec::new();
        while !self.shutdown.load(Ordering::SeqCst) && !drain_requested() {
            {
                let (lock, cv) = &*slots;
                let n = lock.lock().unwrap();
                if *n >= self.cfg.max_conns {
                    // all slots busy: wait for one, re-checking the drain
                    // flags on a bounded cadence
                    let _ = cv.wait_timeout(n, Duration::from_millis(50)).unwrap();
                    continue;
                }
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    *slots.0.lock().unwrap() += 1;
                    let guard = SlotGuard(slots.clone());
                    let ctx = ctx.clone();
                    ctx.tallies.bump(&ctx.tallies.connections, Counter::NetConnections);
                    handlers.push(std::thread::spawn(move || {
                        let _slot = guard;
                        handle_conn(stream, &ctx);
                    }));
                    if handlers.len() >= 2 * self.cfg.max_conns.max(8) {
                        handlers.retain(|h| !h.is_finished());
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => std::thread::sleep(POLL),
                // transient accept failures (e.g. ECONNABORTED): keep serving
                Err(_) => std::thread::sleep(POLL),
            }
        }

        // drain: refuse new connections, let every in-flight handler run
        // to its Done (the scheduler is still stepping), then stop the
        // scheduler and collect the outcome
        drop(self.listener);
        for h in handlers {
            let _ = h.join();
        }
        let outcome = handle.finish_all()?;
        Ok((outcome, ctx.tallies.report()))
    }
}

// ---------------------------------------------------------------------------
// connection handling
// ---------------------------------------------------------------------------

fn handle_conn(stream: TcpStream, ctx: &Ctx) {
    // slowloris guard: the request head gets the short header timeout; a
    // peer that dribbles bytes (or stalls outright) is answered 408 and
    // dropped instead of pinning a handler slot for SOCKET_TIMEOUT.
    let _ = stream.set_read_timeout(Some(ctx.header_timeout));
    let _ = stream.set_write_timeout(Some(SOCKET_TIMEOUT));
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut w = stream;
    let req = match http::read_request(&mut reader) {
        Ok(Some(r)) => r,
        Ok(None) => return, // peer connected and left
        Err(e) => {
            let status = http::guard_status(&e);
            if status != 400 {
                ctx.tallies.bump(&ctx.tallies.guard_rejects, Counter::NetGuardRejects);
            }
            let body = format!("{{\"error\":\"{}\"}}", guard_reason(status));
            let _ = http::write_response(&mut w, status, JSON_TYPE, body.as_bytes());
            return;
        }
    };
    // head arrived in time: restore the generous per-socket timeout for
    // the response/stream phase (slow decode is not a slow client)
    let _ = reader.get_ref().set_read_timeout(Some(SOCKET_TIMEOUT));
    ctx.tallies.bump(&ctx.tallies.requests, Counter::NetRequests);
    match (req.method.as_str(), req.target.as_str()) {
        ("GET", "/healthz") => {
            let body = health::healthz_json();
            let _ = http::write_response(&mut w, 200, JSON_TYPE, body.as_bytes());
        }
        ("GET", "/metrics") => {
            let body = crate::obs::export::metrics_live_json();
            let _ = http::write_response(&mut w, 200, JSON_TYPE, body.as_bytes());
        }
        ("POST", "/shutdown") => {
            ctx.shutdown.store(true, Ordering::SeqCst);
            health::set_draining();
            let _ = http::write_response(&mut w, 200, JSON_TYPE, br#"{"draining":true}"#);
        }
        ("POST", "/v1/completions") => completions(&mut w, &req, ctx),
        _ => {
            let _ = http::write_response(&mut w, 404, JSON_TYPE, br#"{"error":"no such endpoint"}"#);
        }
    }
}

/// Stable body text for a request-head guard rejection.
fn guard_reason(status: u16) -> &'static str {
    match status {
        408 => "request head timed out",
        413 => "body too large",
        431 => "request head too large",
        _ => "malformed request",
    }
}

/// Render `retry_after_ms` as the whole-seconds `Retry-After` header
/// (rounded up, at least 1 — zero tells the client nothing).
fn retry_after_header(ms: u64) -> (&'static str, String) {
    ("Retry-After", ms.div_ceil(1000).max(1).to_string())
}

/// Parse, submit, and answer one completion request (buffered or
/// streaming).
fn completions(w: &mut TcpStream, req: &http::Request, ctx: &Ctx) {
    let parsed = std::str::from_utf8(&req.body)
        .map_err(|_| "body is not utf-8".to_string())
        .and_then(Json::parse);
    let doc = match parsed {
        Ok(d) => d,
        Err(e) => {
            let body = format!("{{\"error\":\"bad json: {}\"}}", escape(&e));
            let _ = http::write_response(w, 400, JSON_TYPE, body.as_bytes());
            return;
        }
    };
    let Some(prompt) = doc.get("prompt").and_then(Json::as_i32_arr) else {
        let _ = http::write_response(
            w,
            400,
            JSON_TYPE,
            br#"{"error":"'prompt' must be an array of integer token ids"}"#,
        );
        return;
    };
    let id = doc
        .get("id")
        .and_then(Json::as_u64)
        .unwrap_or_else(|| ctx.next_id.fetch_add(1, Ordering::Relaxed));
    let max_new = doc
        .get("max_tokens")
        .and_then(Json::as_u64)
        .map(|n| n as usize)
        .unwrap_or(ctx.default_max_new);
    let ignore_eos = doc.get("ignore_eos").and_then(Json::as_bool).unwrap_or(false);
    let stream_mode = doc.get("stream").and_then(Json::as_bool).unwrap_or(false);
    let priority = match doc.get("priority").and_then(Json::as_str) {
        None => Priority::default(),
        Some(p) => match Priority::parse(p) {
            Ok(p) => p,
            Err(reason) => {
                let body = format!("{{\"error\":\"{}\"}}", escape(&reason));
                let _ = http::write_response(w, 400, JSON_TYPE, body.as_bytes());
                return;
            }
        },
    };
    let deadline_ms = doc.get("deadline_ms").and_then(Json::as_u64);
    let ttft_deadline_ms = doc.get("ttft_deadline_ms").and_then(Json::as_u64);

    let received = Instant::now();
    let (tx, rx) = std::sync::mpsc::channel();
    let cancel = Arc::new(AtomicBool::new(false));
    let mut gr = GenRequest::new(id, prompt, max_new)
        .with_sink(tx)
        .with_cancel(cancel.clone())
        .with_priority(priority);
    if ignore_eos {
        gr = gr.ignore_eos();
    }
    if let Some(ms) = deadline_ms {
        gr = gr.with_deadline_ms(ms);
    }
    if let Some(ms) = ttft_deadline_ms {
        gr = gr.with_ttft_deadline_ms(ms);
    }
    match ctx.queue.try_submit(gr) {
        Err(SubmitError::Full { retry_after_ms, .. }) => {
            ctx.tallies.bump(&ctx.tallies.rejected_429, Counter::Net429);
            let body = format!(
                "{{\"error\":\"admission queue is full, retry later\",\
                 \"reason\":\"queue_full\",\
                 \"retry_after_ms\":{retry_after_ms}}}"
            );
            let _ = http::write_response_with(
                w,
                429,
                JSON_TYPE,
                &[retry_after_header(retry_after_ms)],
                body.as_bytes(),
            );
        }
        Err(SubmitError::Closed(_)) => {
            let _ = http::write_response(
                w,
                503,
                JSON_TYPE,
                br#"{"error":"server is draining"}"#,
            );
        }
        Err(SubmitError::Invalid { reason, .. }) => {
            let body = format!("{{\"error\":\"{}\"}}", escape(&reason));
            let _ = http::write_response(w, 400, JSON_TYPE, body.as_bytes());
        }
        Ok(()) => {
            if stream_mode {
                stream_response(w, &rx, &cancel, received, ctx);
            } else {
                buffered_response(w, &rx, ctx);
            }
        }
    }
}

/// Whether a scheduler-side admission reject was a transient KV
/// pages-exhausted condition (paged pool at capacity) rather than a
/// permanently-bad request — the former deserves a retryable `429`, the
/// latter the historical 200 + terminal error frame.
fn pages_exhausted(r: &GenResult) -> bool {
    r.reason == FinishReason::Rejected
        && r.error.as_deref().is_some_and(|e| e.contains("out of pages"))
}

/// Answer a pages-exhausted admission reject: `429` + `Retry-After`, with
/// a `reason` distinguishing it from the submit-time queue-full `429`
/// (`"pages_exhausted"` vs `"queue_full"`) — capacity pressure in the KV
/// pool, not the admission queue.
fn pages_exhausted_response(w: &mut TcpStream, r: &GenResult, ctx: &Ctx) {
    ctx.tallies.bump(&ctx.tallies.rejected_429, Counter::Net429);
    let retry_after_ms = health::retry_after_ms(ctx.queue.depth());
    let body = format!(
        "{{\"error\":\"kv pool out of pages, retry later\",\
         \"reason\":\"pages_exhausted\",\"id\":{},\"retry_after_ms\":{retry_after_ms}}}",
        r.id,
    );
    let _ = http::write_response_with(
        w,
        429,
        JSON_TYPE,
        &[retry_after_header(retry_after_ms)],
        body.as_bytes(),
    );
}

/// Answer a queue-side TTFT-deadline shed: plain `503` with `Retry-After`
/// (sheds happen before any token, so the response is always atomic —
/// never a torn stream).
fn shed_response(w: &mut TcpStream, r: &GenResult, ctx: &Ctx) {
    ctx.tallies.bump(&ctx.tallies.shed_503, Counter::Net503Shed);
    let retry_after_ms = health::retry_after_ms(ctx.queue.depth());
    let body = format!(
        "{{\"error\":\"shed: ttft deadline exceeded while queued\",\
         \"reason\":\"{}\",\"id\":{},\"retry_after_ms\":{retry_after_ms}}}",
        FinishReason::DeadlineShed.name(),
        r.id,
    );
    let _ = http::write_response_with(
        w,
        503,
        JSON_TYPE,
        &[retry_after_header(retry_after_ms)],
        body.as_bytes(),
    );
}

/// Buffered mode: wait for the terminal event, answer one JSON document.
/// (Token events are drained and dropped; the terminal result carries the
/// full token vector.) A TTFT-deadline shed answers `503 Retry-After`
/// instead of a 200 body.
fn buffered_response(w: &mut TcpStream, rx: &Receiver<StreamEvent>, ctx: &Ctx) {
    match drain_to_done(rx) {
        Some(r) if r.reason == FinishReason::DeadlineShed => shed_response(w, &r, ctx),
        Some(r) if pages_exhausted(&r) => pages_exhausted_response(w, &r, ctx),
        Some(r) => {
            let _ = http::write_response(w, 200, JSON_TYPE, result_json(&r, false).as_bytes());
        }
        None => {
            // the scheduler died without a terminal event (worker panic)
            let _ = http::write_response(w, 500, JSON_TYPE, br#"{"error":"scheduler died"}"#);
        }
    }
}

/// Streaming mode: one SSE frame per token as it decodes, a terminal
/// `done` frame with the full result. The first event is peeked before
/// the chunked 200 is committed, so a queue-side TTFT shed still answers
/// a plain `503 Retry-After` (admission rejects keep their historical
/// 200 + terminal-frame shape). A failed frame write is the client
/// disconnecting: set the cancel flag (the scheduler evicts the lane and
/// frees its KV slot at the next step boundary) and drain the channel to
/// its terminal event so teardown is deterministic.
fn stream_response(
    w: &mut TcpStream,
    rx: &Receiver<StreamEvent>,
    cancel: &AtomicBool,
    received: Instant,
    ctx: &Ctx,
) {
    // Peek the first event before committing to a chunked stream: a
    // queue-side shed arrives as an immediate terminal event and must
    // answer a plain 503 (with Retry-After) — once `start_chunked` has
    // written a 200 status line there is no honest way to say "retry".
    let mut event = rx.recv();
    if let Ok(StreamEvent::Done(r)) = &event {
        if r.reason == FinishReason::DeadlineShed {
            shed_response(w, r, ctx);
            return;
        }
        if pages_exhausted(r) {
            pages_exhausted_response(w, r, ctx);
            return;
        }
    }
    ctx.tallies.bump(&ctx.tallies.streams, Counter::NetStreams);
    if http::start_chunked(w, 200, SSE_TYPE).is_err() {
        disconnected(rx, cancel, ctx);
        return;
    }
    let mut first = true;
    loop {
        match event {
            Ok(StreamEvent::Token(t)) => {
                let frame = http::sse_frame(&format!("{{\"token\":{t}}}"));
                if http::write_chunk(w, &frame).is_err() {
                    disconnected(rx, cancel, ctx);
                    return;
                }
                if first {
                    first = false;
                    // wire TTFT: request received -> first frame on the
                    // socket (includes queueing + scheduling + decode)
                    if crate::obs::enabled() {
                        crate::obs::wire_ttft()
                            .record_ms(received.elapsed().as_secs_f64() * 1e3);
                    }
                }
            }
            Ok(StreamEvent::Done(r)) => {
                let frame = http::sse_frame(&result_json(&r, true));
                if http::write_chunk(w, &frame).is_err() {
                    // disconnect raced the terminal frame: the request is
                    // already off its lane, nothing to cancel
                    return;
                }
                let _ = http::end_chunked(w);
                return;
            }
            Err(_) => {
                // scheduler died without a terminal event
                let _ = http::end_chunked(w);
                return;
            }
        }
        event = rx.recv();
    }
}

/// Client-disconnect path: request the eviction and wait for the
/// scheduler's terminal event so the lane/slot handoff is observable.
fn disconnected(rx: &Receiver<StreamEvent>, cancel: &AtomicBool, ctx: &Ctx) {
    cancel.store(true, Ordering::SeqCst);
    ctx.tallies.bump(&ctx.tallies.disconnects, Counter::NetDisconnects);
    drain_to_done(rx);
}

/// Pull events until the terminal one; `None` if the channel closed
/// without it (scheduler worker death).
fn drain_to_done(rx: &Receiver<StreamEvent>) -> Option<GenResult> {
    loop {
        match rx.recv() {
            Ok(StreamEvent::Done(r)) => return Some(r),
            Ok(_) => {}
            Err(_) => return None,
        }
    }
}

/// Render one result as the response/terminal-frame JSON. Non-finite
/// latencies (zero-budget or cancelled-before-first-token requests)
/// render as `null` — JSON has no NaN.
fn result_json(r: &GenResult, done: bool) -> String {
    let ms = |x: f64| if x.is_finite() { format!("{x:.3}") } else { "null".to_string() };
    let join = |ts: &[i32]| {
        ts.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(",")
    };
    format!(
        "{{{}\"id\":{},\"reason\":\"{}\",\"prompt_len\":{},\"tokens\":[{}],\"generated\":[{}],\
         \"queued_ms\":{},\"ttft_ms\":{},\"total_ms\":{},\"error\":{}}}",
        if done { "\"done\":true," } else { "" },
        r.id,
        r.reason.name(),
        r.prompt_len,
        join(&r.tokens),
        join(r.generated()),
        ms(r.queued_ms),
        ms(r.ttft_ms),
        ms(r.total_ms),
        match &r.error {
            Some(e) => format!("\"{}\"", escape(e)),
            None => "null".to_string(),
        },
    )
}

// ---------------------------------------------------------------------------
// SIGINT -> graceful drain
// ---------------------------------------------------------------------------

#[cfg(unix)]
mod sigint {
    use std::os::raw::c_int;
    use std::sync::atomic::{AtomicBool, Ordering};

    static DRAIN: AtomicBool = AtomicBool::new(false);

    type SigHandler = extern "C" fn(c_int);
    extern "C" {
        fn signal(signum: c_int, handler: SigHandler) -> usize;
    }

    extern "C" fn on_sigint(_: c_int) {
        // only an atomic store: async-signal-safe by construction
        DRAIN.store(true, Ordering::SeqCst);
    }

    pub fn install() {
        unsafe {
            signal(2 /* SIGINT */, on_sigint);
        }
    }

    pub fn requested() -> bool {
        DRAIN.load(Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod sigint {
    pub fn install() {}

    pub fn requested() -> bool {
        false
    }
}

/// Route SIGINT into the graceful-drain path (`silq serve --listen` calls
/// this; ^C then finishes in-flight lanes instead of killing the
/// process). No-op on non-unix targets.
pub fn install_sigint_drain() {
    sigint::install();
}

/// Whether a SIGINT drain was requested (always false before
/// [`install_sigint_drain`] and on non-unix targets).
pub fn drain_requested() -> bool {
    sigint::requested()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(err: Option<&str>) -> GenResult {
        GenResult {
            id: 7,
            prompt_len: 2,
            tokens: vec![1, 2, 9, 10],
            queued_ms: 0.5,
            ttft_ms: f64::NAN,
            total_ms: 3.25,
            decode_tok_per_sec: f64::NAN,
            admitted_step: 0,
            finished_step: 2,
            error: err.map(|e| e.to_string()),
            reason: FinishReason::Completed,
        }
    }

    #[test]
    fn result_json_renders_nan_as_null_and_escapes_errors() {
        let doc = result_json(&result(None), false);
        let parsed = Json::parse(&doc).expect("result json must parse");
        assert_eq!(parsed.get("id").unwrap().as_u64(), Some(7));
        assert_eq!(parsed.get("reason").unwrap().as_str(), Some("ok"));
        assert_eq!(parsed.get("generated").unwrap().as_i32_arr(), Some(vec![9, 10]));
        assert_eq!(parsed.get("ttft_ms").unwrap(), &Json::Null);
        assert_eq!(parsed.get("total_ms").unwrap().as_f64(), Some(3.25));
        assert!(parsed.get("done").is_none());
        let doc = result_json(&result(Some("bad \"quote\"")), true);
        let parsed = Json::parse(&doc).expect("escaped error must still parse");
        assert_eq!(parsed.get("done").unwrap().as_bool(), Some(true));
        assert_eq!(parsed.get("error").unwrap().as_str(), Some("bad \"quote\""));
    }

    #[test]
    fn result_json_carries_the_deadline_reason() {
        let mut r = result(Some("completion deadline exceeded mid-decode"));
        r.reason = FinishReason::DeadlineEvicted;
        let parsed = Json::parse(&result_json(&r, true)).expect("deadline json must parse");
        assert_eq!(parsed.get("reason").unwrap().as_str(), Some("deadline"));
    }

    #[test]
    fn tallies_mirror_into_the_report() {
        let t = Tallies::default();
        t.bump(&t.connections, Counter::NetConnections);
        t.bump(&t.requests, Counter::NetRequests);
        t.bump(&t.requests, Counter::NetRequests);
        t.bump(&t.shed_503, Counter::Net503Shed);
        t.bump(&t.guard_rejects, Counter::NetGuardRejects);
        let r = t.report();
        assert_eq!((r.connections, r.requests), (1, 2));
        assert_eq!((r.streams, r.disconnects, r.rejected_429), (0, 0, 0));
        assert_eq!((r.shed_503, r.guard_rejects), (1, 1));
    }

    #[test]
    fn retry_after_header_rounds_up_to_whole_seconds() {
        assert_eq!(retry_after_header(0).1, "1");
        assert_eq!(retry_after_header(1).1, "1");
        assert_eq!(retry_after_header(1000).1, "1");
        assert_eq!(retry_after_header(1001).1, "2");
        assert_eq!(retry_after_header(59_500).1, "60");
    }
}
