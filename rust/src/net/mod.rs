//! `net` — serving over the wire: a dependency-light HTTP/1.1 front-end
//! for the continuous-batching engine, plus the client the wire bench and
//! tests drive it with.
//!
//! ```text
//!   TcpListener (non-blocking accept, drain flags polled)     (server.rs)
//!        |  bounded handler threads (max_conns slots)
//!   HTTP/1.1 parse / respond / chunked+SSE framing            (http.rs)
//!        |  POST /v1/completions -> GenRequest{sink, cancel}
//!   AdmissionQueue::try_submit  (full -> 429, closed -> 503)  (serve)
//!        |
//!   Scheduler lanes: StreamEvent::Token per decode step back
//!   through the sink; a failed frame write sets the cancel
//!   flag -> lane + KV slot freed mid-decode
//! ```
//!
//! Everything is std: `TcpListener`/`TcpStream`, thread-per-connection
//! over a bounded slot count, hand-rolled HTTP and JSON ([`json`] is the
//! one real parser in the repo — the wire is where untrusted bytes come
//! in). The serving semantics (queueing, scheduling, cancellation,
//! accounting) all live in [`crate::serve`]; this layer only maps them
//! onto sockets: backpressure to `429 Retry-After`, TTFT-deadline sheds
//! to `503 Retry-After`, disconnect to cancellation, slow/stalled
//! request delivery to `408` (the slowloris guard), drain (`/shutdown`
//! or SIGINT) to finish-in-flight-then-exit. `GET /healthz` exposes the
//! [`crate::serve::health`] state machine (`ok`/`degraded`/`draining`)
//! with its queue-depth and deadline-miss evidence.
//!
//! Request/response schemas and the streaming frame format are documented
//! in README §Serving over HTTP.

pub mod client;
pub mod http;
pub mod json;
pub mod server;

pub use json::Json;
pub use server::{drain_requested, install_sigint_drain, NetReport, Server, ServerCfg};
