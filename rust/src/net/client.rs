//! Minimal blocking HTTP/1.1 client for the wire bench (`silq
//! bench-serve`), the integration tests, and the soak — std `TcpStream`
//! only, one request per connection, chunked/SSE decoding via
//! [`http::SseAssembler`].
//!
//! Latency here is measured **client-side**: [`WireOutcome::ttft_ms`] is
//! request-written → first token frame parsed, i.e. the full wire round
//! trip a user feels, independent of the server's own accounting.

use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::net::http;
use crate::net::json::Json;

/// What one completion request produced, as observed on the wire.
#[derive(Debug)]
pub struct WireOutcome {
    pub status: u16,
    /// token frames in arrival order (streaming) or the `generated` field
    /// of the buffered response
    pub tokens: Vec<i32>,
    /// the terminal document: the buffered response body, or the
    /// streaming `done` frame (`None` when the client disconnected early
    /// or the request was refused)
    pub done: Option<Json>,
    /// client-measured time-to-first-token in ms (`NaN` when no token
    /// frame arrived)
    pub ttft_ms: f64,
    /// the client hung up on purpose before the stream finished
    pub disconnected: bool,
}

/// Build a `/v1/completions` request body.
pub fn completion_body(
    id: u64,
    prompt: &[i32],
    max_tokens: usize,
    ignore_eos: bool,
    stream: bool,
) -> String {
    let p = prompt.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(",");
    format!(
        "{{\"id\":{id},\"prompt\":[{p}],\"max_tokens\":{max_tokens},\
         \"ignore_eos\":{ignore_eos},\"stream\":{stream}}}"
    )
}

fn connect(addr: &str) -> Result<TcpStream> {
    TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))
}

fn send_request(stream: &mut TcpStream, method: &str, path: &str, body: &str) -> Result<()> {
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: silq\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()?;
    Ok(())
}

/// One non-streaming request; returns status + body text.
pub fn request(addr: &str, method: &str, path: &str, body: &str) -> Result<(u16, String)> {
    let mut stream = connect(addr)?;
    send_request(&mut stream, method, path, body)?;
    let mut r = BufReader::new(stream);
    let (status, headers) = http::read_response_head(&mut r).context("response head")?;
    let body = http::read_response_body(&mut r, &headers).context("response body")?;
    Ok((status, String::from_utf8_lossy(&body).into_owned()))
}

/// `GET` convenience (healthz, metrics).
pub fn get(addr: &str, path: &str) -> Result<(u16, String)> {
    request(addr, "GET", path, "")
}

/// Buffered completion: POST, parse the one-document answer.
pub fn complete_buffered(addr: &str, body: &str) -> Result<WireOutcome> {
    let (status, text) = request(addr, "POST", "/v1/completions", body)?;
    let done = Json::parse(&text).ok();
    let tokens = done
        .as_ref()
        .and_then(|d| d.get("generated"))
        .and_then(Json::as_i32_arr)
        .unwrap_or_default();
    Ok(WireOutcome { status, tokens, done, ttft_ms: f64::NAN, disconnected: false })
}

/// Streaming completion: POST with `"stream":true`, consume SSE frames as
/// they arrive. `disconnect_after: Some(k)` hangs up after `k` token
/// frames (the forced-disconnect path the cancellation tests drive);
/// `None` consumes through the terminal `done` frame.
pub fn complete_streaming(
    addr: &str,
    body: &str,
    disconnect_after: Option<usize>,
) -> Result<WireOutcome> {
    let mut stream = connect(addr)?;
    let t0 = Instant::now();
    send_request(&mut stream, "POST", "/v1/completions", body)?;
    let mut r = BufReader::new(stream);
    let (status, headers) = http::read_response_head(&mut r).context("response head")?;
    if status != 200 {
        let text = http::read_response_body(&mut r, &headers).unwrap_or_default();
        return Ok(WireOutcome {
            status,
            tokens: Vec::new(),
            done: Json::parse(&String::from_utf8_lossy(&text)).ok(),
            ttft_ms: f64::NAN,
            disconnected: false,
        });
    }
    if !http::header(&headers, "Transfer-Encoding")
        .is_some_and(|v| v.eq_ignore_ascii_case("chunked"))
    {
        bail!("streaming response is not chunked");
    }
    let mut sse = http::SseAssembler::new();
    let mut out = WireOutcome {
        status,
        tokens: Vec::new(),
        done: None,
        ttft_ms: f64::NAN,
        disconnected: false,
    };
    while let Some(chunk) = http::read_chunk(&mut r).context("reading chunk")? {
        for payload in sse.push(&chunk) {
            let Ok(doc) = Json::parse(&payload) else { continue };
            if let Some(t) = doc.get("token").and_then(Json::as_f64) {
                if out.tokens.is_empty() {
                    out.ttft_ms = t0.elapsed().as_secs_f64() * 1e3;
                }
                out.tokens.push(t as i32);
            } else if doc.get("done").and_then(Json::as_bool) == Some(true) {
                out.done = Some(doc);
                return Ok(out);
            }
        }
        if let Some(k) = disconnect_after {
            if out.tokens.len() >= k {
                // drop the socket mid-stream: the server's next frame
                // write fails and cancels the lane
                out.disconnected = true;
                return Ok(out);
            }
        }
    }
    Ok(out)
}

/// Ask a live server to drain and exit.
pub fn shutdown(addr: &str) -> Result<u16> {
    Ok(request(addr, "POST", "/shutdown", "")?.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn completion_body_is_valid_json() {
        let body = completion_body(7, &[1, 2, 3], 8, true, false);
        let doc = Json::parse(&body).unwrap();
        assert_eq!(doc.get("id").unwrap().as_u64(), Some(7));
        assert_eq!(doc.get("prompt").unwrap().as_i32_arr(), Some(vec![1, 2, 3]));
        assert_eq!(doc.get("max_tokens").unwrap().as_u64(), Some(8));
        assert_eq!(doc.get("ignore_eos").unwrap().as_bool(), Some(true));
        assert_eq!(doc.get("stream").unwrap().as_bool(), Some(false));
    }
}
