//! Minimal blocking HTTP/1.1 client for the wire bench (`silq
//! bench-serve`), the integration tests, and the soak — std `TcpStream`
//! only, one request per connection, chunked/SSE decoding via
//! [`http::SseAssembler`].
//!
//! Latency here is measured **client-side**: [`WireOutcome::ttft_ms`] is
//! request-written → first token frame parsed, i.e. the full wire round
//! trip a user feels, independent of the server's own accounting.

use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::net::http;
use crate::net::json::Json;

/// What one completion request produced, as observed on the wire.
#[derive(Debug)]
pub struct WireOutcome {
    pub status: u16,
    /// token frames in arrival order (streaming) or the `generated` field
    /// of the buffered response
    pub tokens: Vec<i32>,
    /// the terminal document: the buffered response body, or the
    /// streaming `done` frame (`None` when the client disconnected early
    /// or the request was refused)
    pub done: Option<Json>,
    /// client-measured time-to-first-token in ms (`NaN` when no token
    /// frame arrived)
    pub ttft_ms: f64,
    /// the client hung up on purpose before the stream finished
    pub disconnected: bool,
    /// backoff hint from a 429/503 answer (`retry_after_ms` body field),
    /// `None` on any other outcome
    pub retry_after_ms: Option<u64>,
}

/// Pull the `retry_after_ms` backoff hint out of a parsed error body.
fn retry_hint(done: &Option<Json>) -> Option<u64> {
    done.as_ref().and_then(|d| d.get("retry_after_ms")).and_then(Json::as_u64)
}

/// Build a `/v1/completions` request body.
pub fn completion_body(
    id: u64,
    prompt: &[i32],
    max_tokens: usize,
    ignore_eos: bool,
    stream: bool,
) -> String {
    completion_body_ext(id, prompt, max_tokens, ignore_eos, stream, None, None, None)
}

/// [`completion_body`] with the resilience fields: scheduling class and
/// the two deadlines (all optional, omitted when `None`).
#[allow(clippy::too_many_arguments)]
pub fn completion_body_ext(
    id: u64,
    prompt: &[i32],
    max_tokens: usize,
    ignore_eos: bool,
    stream: bool,
    priority: Option<&str>,
    deadline_ms: Option<u64>,
    ttft_deadline_ms: Option<u64>,
) -> String {
    let p = prompt.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(",");
    let mut body = format!(
        "{{\"id\":{id},\"prompt\":[{p}],\"max_tokens\":{max_tokens},\
         \"ignore_eos\":{ignore_eos},\"stream\":{stream}"
    );
    if let Some(p) = priority {
        body.push_str(&format!(",\"priority\":\"{p}\""));
    }
    if let Some(ms) = deadline_ms {
        body.push_str(&format!(",\"deadline_ms\":{ms}"));
    }
    if let Some(ms) = ttft_deadline_ms {
        body.push_str(&format!(",\"ttft_deadline_ms\":{ms}"));
    }
    body.push('}');
    body
}

fn connect(addr: &str) -> Result<TcpStream> {
    TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))
}

fn send_request(stream: &mut TcpStream, method: &str, path: &str, body: &str) -> Result<()> {
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: silq\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )?;
    // fault site `stall`: flush the head, then sit on the body — a
    // deterministic slowloris. With the guard in place the server answers
    // 408 instead of letting this pin a handler slot.
    if crate::faults::should_inject(crate::faults::Site::ClientStall) {
        stream.flush()?;
        std::thread::sleep(Duration::from_millis(crate::faults::latency_ms(
            crate::faults::Site::ClientStall,
        )));
    }
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// One non-streaming request; returns status + body text.
pub fn request(addr: &str, method: &str, path: &str, body: &str) -> Result<(u16, String)> {
    let mut stream = connect(addr)?;
    send_request(&mut stream, method, path, body)?;
    let mut r = BufReader::new(stream);
    let (status, headers) = http::read_response_head(&mut r).context("response head")?;
    let body = http::read_response_body(&mut r, &headers).context("response body")?;
    Ok((status, String::from_utf8_lossy(&body).into_owned()))
}

/// `GET` convenience (healthz, metrics).
pub fn get(addr: &str, path: &str) -> Result<(u16, String)> {
    request(addr, "GET", path, "")
}

/// Buffered completion: POST, parse the one-document answer.
pub fn complete_buffered(addr: &str, body: &str) -> Result<WireOutcome> {
    let (status, text) = request(addr, "POST", "/v1/completions", body)?;
    let done = Json::parse(&text).ok();
    let tokens = done
        .as_ref()
        .and_then(|d| d.get("generated"))
        .and_then(Json::as_i32_arr)
        .unwrap_or_default();
    let retry_after_ms = retry_hint(&done);
    Ok(WireOutcome { status, tokens, done, ttft_ms: f64::NAN, disconnected: false, retry_after_ms })
}

/// Streaming completion: POST with `"stream":true`, consume SSE frames as
/// they arrive. `disconnect_after: Some(k)` hangs up after `k` token
/// frames (the forced-disconnect path the cancellation tests drive);
/// `None` consumes through the terminal `done` frame.
pub fn complete_streaming(
    addr: &str,
    body: &str,
    disconnect_after: Option<usize>,
) -> Result<WireOutcome> {
    let mut stream = connect(addr)?;
    let t0 = Instant::now();
    send_request(&mut stream, "POST", "/v1/completions", body)?;
    let mut r = BufReader::new(stream);
    let (status, headers) = http::read_response_head(&mut r).context("response head")?;
    if status != 200 {
        let text = http::read_response_body(&mut r, &headers).unwrap_or_default();
        let done = Json::parse(&String::from_utf8_lossy(&text)).ok();
        let retry_after_ms = retry_hint(&done).or_else(|| {
            // fall back to the whole-seconds header if the body had no hint
            http::header(&headers, "Retry-After").and_then(|v| v.parse::<u64>().ok()).map(|s| s * 1000)
        });
        return Ok(WireOutcome {
            status,
            tokens: Vec::new(),
            done,
            ttft_ms: f64::NAN,
            disconnected: false,
            retry_after_ms,
        });
    }
    if !http::header(&headers, "Transfer-Encoding")
        .is_some_and(|v| v.eq_ignore_ascii_case("chunked"))
    {
        bail!("streaming response is not chunked");
    }
    let mut sse = http::SseAssembler::new();
    let mut out = WireOutcome {
        status,
        tokens: Vec::new(),
        done: None,
        ttft_ms: f64::NAN,
        disconnected: false,
        retry_after_ms: None,
    };
    while let Some(chunk) = http::read_chunk(&mut r).context("reading chunk")? {
        for payload in sse.push(&chunk) {
            let Ok(doc) = Json::parse(&payload) else { continue };
            if let Some(t) = doc.get("token").and_then(Json::as_f64) {
                if out.tokens.is_empty() {
                    out.ttft_ms = t0.elapsed().as_secs_f64() * 1e3;
                }
                out.tokens.push(t as i32);
            } else if doc.get("done").and_then(Json::as_bool) == Some(true) {
                out.done = Some(doc);
                return Ok(out);
            }
        }
        if let Some(k) = disconnect_after {
            if out.tokens.len() >= k {
                // drop the socket mid-stream: the server's next frame
                // write fails and cancels the lane
                out.disconnected = true;
                return Ok(out);
            }
        }
    }
    Ok(out)
}

/// Ask a live server to drain and exit.
pub fn shutdown(addr: &str) -> Result<u16> {
    Ok(request(addr, "POST", "/shutdown", "")?.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn completion_body_is_valid_json() {
        let body = completion_body(7, &[1, 2, 3], 8, true, false);
        let doc = Json::parse(&body).unwrap();
        assert_eq!(doc.get("id").unwrap().as_u64(), Some(7));
        assert_eq!(doc.get("prompt").unwrap().as_i32_arr(), Some(vec![1, 2, 3]));
        assert_eq!(doc.get("max_tokens").unwrap().as_u64(), Some(8));
        assert_eq!(doc.get("ignore_eos").unwrap().as_bool(), Some(true));
        assert_eq!(doc.get("stream").unwrap().as_bool(), Some(false));
        assert!(doc.get("priority").is_none());
        assert!(doc.get("deadline_ms").is_none());
    }

    #[test]
    fn extended_body_carries_priority_and_deadlines() {
        let body =
            completion_body_ext(9, &[4], 2, false, true, Some("batch"), Some(250), Some(40));
        let doc = Json::parse(&body).unwrap();
        assert_eq!(doc.get("priority").unwrap().as_str(), Some("batch"));
        assert_eq!(doc.get("deadline_ms").unwrap().as_u64(), Some(250));
        assert_eq!(doc.get("ttft_deadline_ms").unwrap().as_u64(), Some(40));
    }

    #[test]
    fn retry_hint_reads_the_body_field() {
        let doc = Json::parse(r#"{"error":"full","retry_after_ms":125}"#).ok();
        assert_eq!(retry_hint(&doc), Some(125));
        assert_eq!(retry_hint(&None), None);
    }
}
