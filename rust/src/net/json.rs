//! Minimal JSON for the HTTP boundary: a recursive-descent parser for
//! request bodies and client-side response/frame decoding, plus the
//! string-escape helper the response writers use.
//!
//! This repo takes no serializer dependency — every JSON *writer*
//! (metrics, traces, bench rows) hand-rolls its document. The wire
//! front-end is the first place untrusted JSON comes *in*, so it gets a
//! real parser: depth-limited, whole-input (no trailing garbage), with
//! standard escape handling. It parses into a small [`Json`] tree —
//! convenient accessors beat zero-copy here; request bodies are tiny.

/// One parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// All JSON numbers parse as f64 (request ids and token ids fit
    /// exactly: they are well inside the 2^53 integer range).
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered key/value pairs (no map: bodies have ~5 keys).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document; trailing non-whitespace is an error.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object field lookup (`None` on non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Non-negative integer view of a number (request ids, budgets).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Array-of-integers view (the `prompt`/`tokens` fields); `None` if
    /// any element is not an exact i32.
    pub fn as_i32_arr(&self) -> Option<Vec<i32>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            let n = v.as_f64()?;
            if n.fract() != 0.0 || n < i32::MIN as f64 || n > i32::MAX as f64 {
                return None;
            }
            out.push(n as i32);
        }
        Some(out)
    }
}

/// Escape a string for embedding in a JSON document (quotes not included).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Nesting limit: request bodies are flat; 32 is far above anything
/// legitimate and keeps adversarial input off the recursion stack.
const MAX_DEPTH: usize = 32;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        if depth > MAX_DEPTH {
            return Err("nesting too deep".into());
        }
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected '{}' at byte {}", c as char, self.i)),
            None => Err("unexpected end of input".into()),
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap_or("");
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => out.push(self.unicode_escape()?),
                        c => return Err(format!("bad escape '\\{}'", c as char)),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar (input is a &str, so the
                    // bytes are valid; find the char boundary)
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    /// `\uXXXX`, including surrogate pairs; lone surrogates map to the
    /// replacement character rather than failing the whole body.
    fn unicode_escape(&mut self) -> Result<char, String> {
        let hi = self.hex4()?;
        if (0xd800..0xdc00).contains(&hi) {
            if self.b[self.i..].starts_with(b"\\u") {
                self.i += 2;
                let lo = self.hex4()?;
                if (0xdc00..0xe000).contains(&lo) {
                    let c = 0x10000 + ((hi - 0xd800) << 10) + (lo - 0xdc00);
                    return Ok(char::from_u32(c).unwrap_or('\u{fffd}'));
                }
            }
            return Ok('\u{fffd}');
        }
        Ok(char::from_u32(hi).unwrap_or('\u{fffd}'))
    }

    fn hex4(&mut self) -> Result<u32, String> {
        if self.i + 4 > self.b.len() {
            return Err("truncated \\u escape".into());
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| "bad \\u escape".to_string())?;
        let v = u32::from_str_radix(s, 16).map_err(|_| "bad \\u escape".to_string())?;
        self.i += 4;
        Ok(v)
    }

    fn array(&mut self, depth: usize) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value(depth + 1)?;
            out.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_completion_body() {
        let doc = Json::parse(
            r#"{"id": 7, "prompt": [1, 2, 3], "max_tokens": 8, "ignore_eos": true, "stream": false}"#,
        )
        .unwrap();
        assert_eq!(doc.get("id").unwrap().as_u64(), Some(7));
        assert_eq!(doc.get("prompt").unwrap().as_i32_arr(), Some(vec![1, 2, 3]));
        assert_eq!(doc.get("max_tokens").unwrap().as_u64(), Some(8));
        assert_eq!(doc.get("ignore_eos").unwrap().as_bool(), Some(true));
        assert_eq!(doc.get("stream").unwrap().as_bool(), Some(false));
        assert!(doc.get("missing").is_none());
    }

    #[test]
    fn parses_nested_values_strings_and_numbers() {
        let doc = Json::parse(r#"{"a": [null, -1.5e2, "x\nyA"], "b": {"c": 0}}"#).unwrap();
        let a = doc.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0], Json::Null);
        assert_eq!(a[1].as_f64(), Some(-150.0));
        assert_eq!(a[2].as_str(), Some("x\nyA"));
        assert_eq!(doc.get("b").unwrap().get("c").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "", "{", "[1,]", "{\"a\":}", "nul", "{\"a\":1} trailing", "\"unterminated",
            "{\"a\" 1}",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted malformed input: {bad:?}");
        }
        // depth bomb stays off the stack
        let deep = "[".repeat(1000) + &"]".repeat(1000);
        assert!(Json::parse(&deep).is_err());
    }

    #[test]
    fn i32_array_rejects_fractions_and_overflow() {
        assert!(Json::parse("[1.5]").unwrap().as_i32_arr().is_none());
        assert!(Json::parse("[3000000000]").unwrap().as_i32_arr().is_none());
        assert_eq!(Json::parse("[-4, 0]").unwrap().as_i32_arr(), Some(vec![-4, 0]));
    }

    #[test]
    fn escape_round_trips_through_the_parser() {
        let raw = "a\"b\\c\nd\te\u{1}";
        let doc = Json::parse(&format!("\"{}\"", escape(raw))).unwrap();
        assert_eq!(doc.as_str(), Some(raw));
    }
}
