//! HTTP/1.1 wire framing: request parsing, response writing, chunked
//! transfer encoding, and SSE event framing — generic over `Read`/`Write`
//! so every parser unit-tests on byte slices without a socket.
//!
//! Scope is deliberately narrow (this is a model server, not a web
//! framework): one request per connection (`Connection: close`),
//! `Content-Length` bodies in, `Content-Length` or chunked bodies out.
//! Streaming completions go out as Server-Sent Events where **one chunk
//! is one complete `data:` frame** — a reader that just de-chunks gets
//! whole events; the client-side [`SseAssembler`] additionally tolerates
//! frames split across chunk boundaries.

use std::io::{self, BufRead, Read, Write};

/// Parsed-input hard limits: a malformed or hostile peer must cost a
/// bounded read, never an unbounded allocation. Overrunning a cap is a
/// typed guard error ([`guard_status`]) so the server answers the
/// honest status — 431 for oversized request line/headers, 413 for an
/// oversized body — instead of a generic 400.
const MAX_HEADER_LINE: usize = 8 * 1024;
const MAX_HEADERS: usize = 64;
const MAX_BODY: usize = 1 << 20;

/// A parse failure that maps to a specific HTTP status (the slowloris /
/// resource-cap guard). Carried as the inner error of an
/// [`io::ErrorKind::InvalidData`] error so the `io::Result` plumbing is
/// undisturbed; [`guard_status`] recovers the status at the answer site.
#[derive(Debug)]
struct GuardError {
    status: u16,
    msg: &'static str,
}

impl std::fmt::Display for GuardError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} ({})", self.msg, self.status)
    }
}

impl std::error::Error for GuardError {}

fn guard(status: u16, msg: &'static str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, GuardError { status, msg })
}

/// The status a request-parse error deserves: 408 for a read timeout (a
/// stalled peer held the connection past the grace period), the guard's
/// own status for a cap overrun (431/413), 400 for everything else
/// malformed.
pub fn guard_status(e: &io::Error) -> u16 {
    match e.kind() {
        io::ErrorKind::TimedOut | io::ErrorKind::WouldBlock => 408,
        _ => e
            .get_ref()
            .and_then(|inner| inner.downcast_ref::<GuardError>())
            .map_or(400, |g| g.status),
    }
}

/// One parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// request target as sent (path only; this server ignores queries)
    pub target: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        header(&self.headers, name)
    }
}

/// Case-insensitive lookup in a header list.
pub fn header<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case(name))
        .map(|(_, v)| v.as_str())
}

fn bad(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

/// Read one bounded CRLF-terminated line (without the terminator).
fn read_line<R: BufRead>(r: &mut R) -> io::Result<String> {
    let mut line = String::new();
    let n = r.take(MAX_HEADER_LINE as u64 + 2).read_line(&mut line)?;
    if n > MAX_HEADER_LINE {
        return Err(guard(431, "header line too long"));
    }
    while line.ends_with('\n') || line.ends_with('\r') {
        line.pop();
    }
    Ok(line)
}

fn read_headers<R: BufRead>(r: &mut R) -> io::Result<Vec<(String, String)>> {
    let mut headers = Vec::new();
    loop {
        let line = read_line(r)?;
        if line.is_empty() {
            return Ok(headers);
        }
        if headers.len() >= MAX_HEADERS {
            return Err(guard(431, "too many headers"));
        }
        let (k, v) = line.split_once(':').ok_or_else(|| bad("malformed header"))?;
        headers.push((k.trim().to_string(), v.trim().to_string()));
    }
}

fn read_body<R: BufRead>(r: &mut R, headers: &[(String, String)]) -> io::Result<Vec<u8>> {
    let len = match header(headers, "Content-Length") {
        Some(v) => v.trim().parse::<usize>().map_err(|_| bad("bad Content-Length"))?,
        None => 0,
    };
    if len > MAX_BODY {
        return Err(guard(413, "body too large"));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(body)
}

/// Parse one request. `Ok(None)` is the clean end of the connection (EOF
/// before any request line); malformed input is `InvalidData` (the server
/// answers 400).
pub fn read_request<R: BufRead>(r: &mut R) -> io::Result<Option<Request>> {
    let line = read_line(r)?;
    if line.is_empty() {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let (method, target, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v)) => (m, t, v),
        _ => return Err(bad("malformed request line")),
    };
    if !version.starts_with("HTTP/1.") {
        return Err(bad("unsupported HTTP version"));
    }
    let headers = read_headers(r)?;
    let body = read_body(r, &headers)?;
    Ok(Some(Request {
        method: method.to_string(),
        target: target.to_string(),
        headers,
        body,
    }))
}

/// Canonical reason phrases for the statuses this server emits.
pub fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        408 => "Request Timeout",
        413 => "Content Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write one complete `Content-Length` response and flush.
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    body: &[u8],
) -> io::Result<()> {
    write_response_with(w, status, content_type, &[], body)
}

/// [`write_response`] with extra headers (e.g. `Retry-After` on 429/503).
pub fn write_response_with<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    extra_headers: &[(&str, String)],
    body: &[u8],
) -> io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n",
        status,
        status_reason(status),
        content_type,
        body.len()
    )?;
    for (k, v) in extra_headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    w.write_all(b"Connection: close\r\n\r\n")?;
    w.write_all(body)?;
    w.flush()
}

/// Open a chunked response (status line + headers); the body follows as
/// [`write_chunk`] calls terminated by [`end_chunked`].
pub fn start_chunked<W: Write>(w: &mut W, status: u16, content_type: &str) -> io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nTransfer-Encoding: chunked\r\n\
         Connection: close\r\n\r\n",
        status,
        status_reason(status),
        content_type,
    )?;
    w.flush()
}

/// Write one chunk and flush — each token frame must hit the socket the
/// step it decodes, not sit in a buffer until the run ends.
///
/// An armed `torn@N` fault plan ([`crate::faults`]) tears planned
/// writes: half the payload goes out, then the write fails as a broken
/// pipe — exactly what a peer vanishing mid-frame looks like, so the
/// server's disconnect-as-cancellation path gets exercised on demand.
pub fn write_chunk<W: Write>(w: &mut W, payload: &[u8]) -> io::Result<()> {
    if crate::faults::should_inject(crate::faults::Site::NetWrite) {
        write!(w, "{:x}\r\n", payload.len())?;
        w.write_all(&payload[..payload.len() / 2])?;
        let _ = w.flush();
        return Err(io::Error::new(io::ErrorKind::BrokenPipe, "torn write (fault injected)"));
    }
    write!(w, "{:x}\r\n", payload.len())?;
    w.write_all(payload)?;
    w.write_all(b"\r\n")?;
    w.flush()
}

/// Terminate a chunked response.
pub fn end_chunked<W: Write>(w: &mut W) -> io::Result<()> {
    w.write_all(b"0\r\n\r\n")?;
    w.flush()
}

/// Frame a JSON payload as one SSE event (`data: {...}\n\n`).
pub fn sse_frame(json: &str) -> Vec<u8> {
    let mut out = Vec::with_capacity(json.len() + 8);
    out.extend_from_slice(b"data: ");
    out.extend_from_slice(json.as_bytes());
    out.extend_from_slice(b"\n\n");
    out
}

// ---------------------------------------------------------------------------
// client-side response reading
// ---------------------------------------------------------------------------

/// Read a response status line + headers (the body framing differs by
/// endpoint, so it stays with the caller).
pub fn read_response_head<R: BufRead>(r: &mut R) -> io::Result<(u16, Vec<(String, String)>)> {
    let line = read_line(r)?;
    let status = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| bad("malformed status line"))?;
    Ok((status, read_headers(r)?))
}

/// Read one chunk of a chunked body; `Ok(None)` at the terminator.
pub fn read_chunk<R: BufRead>(r: &mut R) -> io::Result<Option<Vec<u8>>> {
    let line = read_line(r)?;
    let len = usize::from_str_radix(line.trim(), 16).map_err(|_| bad("bad chunk size"))?;
    if len > MAX_BODY {
        return Err(bad("chunk too large"));
    }
    let mut data = vec![0u8; len + 2];
    r.read_exact(&mut data)?;
    if &data[len..] != b"\r\n" {
        return Err(bad("missing chunk terminator"));
    }
    data.truncate(len);
    if len == 0 {
        // the zero chunk's trailing CRLF was the two bytes just consumed
        return Ok(None);
    }
    Ok(Some(data))
}

/// Read a whole response body: `Content-Length` or chunked (assembled).
pub fn read_response_body<R: BufRead>(
    r: &mut R,
    headers: &[(String, String)],
) -> io::Result<Vec<u8>> {
    if header(headers, "Transfer-Encoding").is_some_and(|v| v.eq_ignore_ascii_case("chunked")) {
        let mut body = Vec::new();
        while let Some(chunk) = read_chunk(r)? {
            if body.len() + chunk.len() > MAX_BODY {
                return Err(bad("chunked body too large"));
            }
            body.extend_from_slice(&chunk);
        }
        return Ok(body);
    }
    read_body(r, headers)
}

/// Reassemble SSE `data:` payloads from an arbitrary byte stream — the
/// server sends one frame per chunk, but a correct client must not rely
/// on that alignment.
#[derive(Default)]
pub struct SseAssembler {
    buf: Vec<u8>,
}

impl SseAssembler {
    pub fn new() -> SseAssembler {
        SseAssembler::default()
    }

    /// Feed bytes; returns every complete event payload they finish.
    pub fn push(&mut self, bytes: &[u8]) -> Vec<String> {
        self.buf.extend_from_slice(bytes);
        let mut out = Vec::new();
        while let Some(pos) = self.buf.windows(2).position(|w| w == b"\n\n") {
            let event: Vec<u8> = self.buf.drain(..pos + 2).collect();
            let text = String::from_utf8_lossy(&event[..pos]);
            for line in text.lines() {
                if let Some(payload) = line.strip_prefix("data: ") {
                    out.push(payload.to_string());
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_a_post_with_body() {
        let raw = b"POST /v1/completions HTTP/1.1\r\nHost: x\r\ncontent-length: 4\r\n\r\nabcd";
        let req = read_request(&mut Cursor::new(&raw[..])).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.target, "/v1/completions");
        assert_eq!(req.header("Content-Length"), Some("4"));
        assert_eq!(req.body, b"abcd");
    }

    #[test]
    fn eof_before_a_request_is_a_clean_none() {
        assert!(read_request(&mut Cursor::new(&b""[..])).unwrap().is_none());
    }

    #[test]
    fn malformed_requests_error() {
        for raw in [
            &b"GARBAGE\r\n\r\n"[..],
            &b"GET /x SPDY/3\r\n\r\n"[..],
            &b"GET /x HTTP/1.1\r\nno-colon-here\r\n\r\n"[..],
            &b"GET /x HTTP/1.1\r\nContent-Length: nope\r\n\r\n"[..],
        ] {
            assert!(read_request(&mut Cursor::new(raw)).is_err());
        }
        // a body larger than the cap is refused before allocation
        let raw = format!("POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY + 1);
        assert!(read_request(&mut Cursor::new(raw.as_bytes())).is_err());
    }

    #[test]
    fn guard_errors_carry_their_status() {
        // oversized body -> 413
        let raw = format!("POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY + 1);
        let e = read_request(&mut Cursor::new(raw.as_bytes())).unwrap_err();
        assert_eq!(guard_status(&e), 413);
        // unbounded request line (no CRLF in sight) -> 431, after a
        // bounded read — the guard, not the allocator, stops it
        let raw = vec![b'A'; MAX_HEADER_LINE + 64];
        let e = read_request(&mut Cursor::new(&raw[..])).unwrap_err();
        assert_eq!(guard_status(&e), 431);
        // header flood -> 431
        let mut raw = b"GET /x HTTP/1.1\r\n".to_vec();
        for i in 0..MAX_HEADERS + 1 {
            raw.extend_from_slice(format!("h{i}: v\r\n").as_bytes());
        }
        raw.extend_from_slice(b"\r\n");
        let e = read_request(&mut Cursor::new(&raw[..])).unwrap_err();
        assert_eq!(guard_status(&e), 431);
        // a stalled read (SO_RCVTIMEO surfaces WouldBlock/TimedOut) -> 408
        assert_eq!(guard_status(&io::Error::from(io::ErrorKind::WouldBlock)), 408);
        assert_eq!(guard_status(&io::Error::from(io::ErrorKind::TimedOut)), 408);
        // plain malformed input stays 400
        let e = read_request(&mut Cursor::new(&b"GARBAGE\r\n\r\n"[..])).unwrap_err();
        assert_eq!(guard_status(&e), 400);
    }

    #[test]
    fn extra_headers_ride_along_and_parse_back() {
        let mut wire = Vec::new();
        write_response_with(
            &mut wire,
            429,
            "application/json",
            &[("Retry-After", "2".to_string())],
            b"{}",
        )
        .unwrap();
        let mut r = Cursor::new(&wire[..]);
        let (status, headers) = read_response_head(&mut r).unwrap();
        assert_eq!(status, 429);
        assert_eq!(header(&headers, "retry-after"), Some("2"));
        assert_eq!(header(&headers, "connection"), Some("close"));
        assert_eq!(read_response_body(&mut r, &headers).unwrap(), b"{}");
    }

    #[test]
    fn response_writer_round_trips_through_the_reader() {
        let mut wire = Vec::new();
        write_response(&mut wire, 429, "application/json", b"{\"error\":\"full\"}").unwrap();
        let mut r = Cursor::new(&wire[..]);
        let (status, headers) = read_response_head(&mut r).unwrap();
        assert_eq!(status, 429);
        let body = read_response_body(&mut r, &headers).unwrap();
        assert_eq!(body, b"{\"error\":\"full\"}");
    }

    #[test]
    fn chunked_stream_round_trips() {
        let mut wire = Vec::new();
        start_chunked(&mut wire, 200, "text/event-stream").unwrap();
        write_chunk(&mut wire, &sse_frame("{\"token\":5}")).unwrap();
        write_chunk(&mut wire, &sse_frame("{\"done\":true}")).unwrap();
        end_chunked(&mut wire).unwrap();
        let mut r = Cursor::new(&wire[..]);
        let (status, headers) = read_response_head(&mut r).unwrap();
        assert_eq!(status, 200);
        assert_eq!(header(&headers, "transfer-encoding"), Some("chunked"));
        let mut sse = SseAssembler::new();
        let mut events = Vec::new();
        while let Some(chunk) = read_chunk(&mut r).unwrap() {
            events.extend(sse.push(&chunk));
        }
        assert_eq!(events, vec!["{\"token\":5}", "{\"done\":true}"]);
    }

    #[test]
    fn sse_assembler_survives_split_frames() {
        let mut sse = SseAssembler::new();
        let frame = sse_frame("{\"token\":12}");
        let (a, b) = frame.split_at(7);
        assert!(sse.push(a).is_empty(), "half a frame must not emit");
        assert_eq!(sse.push(b), vec!["{\"token\":12}"]);
        // two frames in one push both come out, in order
        let mut two = sse_frame("{\"token\":1}");
        two.extend_from_slice(&sse_frame("{\"token\":2}"));
        assert_eq!(sse.push(&two), vec!["{\"token\":1}", "{\"token\":2}"]);
    }
}
