//! Run logging and report formatting (EXPERIMENTS.md rows come from here).

use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

/// Append-only run log: step metrics + free-form notes, flushed to
/// `runs/<name>/log.txt`.
pub struct RunLog {
    pub dir: PathBuf,
    file: Option<BufWriter<std::fs::File>>,
    /// first failed write already warned (later failures stay quiet — a
    /// dead disk must not turn a training run into a warning firehose)
    write_failed: bool,
    pub losses: Vec<(usize, f32)>,
}

impl RunLog {
    pub fn new(dir: impl AsRef<Path>) -> RunLog {
        let dir = dir.as_ref().to_path_buf();
        let file = std::fs::create_dir_all(&dir)
            .and_then(|_| std::fs::File::create(dir.join("log.txt")))
            .map(BufWriter::new)
            .map_err(|e| {
                eprintln!(
                    "warning: RunLog: cannot create {}/log.txt ({e}); \
                     this run will not be logged to disk",
                    dir.display()
                )
            })
            .ok();
        RunLog { dir, file, write_failed: false, losses: vec![] }
    }

    /// In-memory only (tests, throwaway runs).
    pub fn ephemeral() -> RunLog {
        RunLog { dir: PathBuf::new(), file: None, write_failed: false, losses: vec![] }
    }

    /// Write one log line, warning on the *first* failure instead of
    /// silently dropping every write forever.
    fn write_line(&mut self, line: std::fmt::Arguments<'_>) {
        if let Some(f) = &mut self.file {
            if let Err(e) = f.write_fmt(line).and_then(|()| f.write_all(b"\n")) {
                if !self.write_failed {
                    self.write_failed = true;
                    eprintln!(
                        "warning: RunLog: write to {}/log.txt failed ({e}); \
                         further log lines may be lost",
                        self.dir.display()
                    );
                }
            }
        }
    }

    pub fn note(&mut self, msg: &str) {
        println!("{msg}");
        self.write_line(format_args!("{msg}"));
    }

    pub fn step(&mut self, step: usize, loss: f32, extra: &str) {
        self.losses.push((step, loss));
        self.write_line(format_args!("step {step} loss {loss:.5} {extra}"));
    }

    /// Mean loss over the last `n` recorded steps.
    pub fn recent_loss(&self, n: usize) -> f32 {
        let tail = &self.losses[self.losses.len().saturating_sub(n)..];
        if tail.is_empty() {
            return f32::NAN;
        }
        tail.iter().map(|(_, l)| l).sum::<f32>() / tail.len() as f32
    }
}

impl Drop for RunLog {
    /// Flush the buffered tail — a short run that exits right after its
    /// last `note` must not lose the end of `log.txt`.
    fn drop(&mut self) {
        if let Some(f) = &mut self.file {
            let _ = f.flush();
        }
    }
}

/// Fixed-width table printer for experiment reports.
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .take(ncol)
                .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.header) + "\n";
        out += &widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  ");
        out += "\n";
        for r in &self.rows {
            out += &fmt_row(r);
            out += "\n";
        }
        out
    }
}

pub fn pct(x: f32) -> String {
    format!("{:.2}", 100.0 * x)
}

/// Nearest-rank percentile of an unsorted sample (NaN for empty input).
///
/// Selection instead of a full sort (`select_nth_unstable_by`, expected
/// O(n) vs the old clone-and-sort's O(n log n)), ordered by `total_cmp`
/// so NaN samples order deterministically (after +inf) instead of
/// panicking in `partial_cmp().unwrap()`.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    let idx = ((p / 100.0 * v.len() as f64).ceil() as usize).clamp(1, v.len()) - 1;
    let (_, x, _) = v.select_nth_unstable_by(idx, f64::total_cmp);
    *x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "acc"]);
        t.row(&["baseline".into(), "62.65".into()]);
        t.row(&["siq".into(), "61.1".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].contains("baseline"));
    }

    #[test]
    fn runlog_tracks_losses() {
        let mut l = RunLog::ephemeral();
        l.step(1, 2.0, "");
        l.step(2, 1.0, "");
        assert_eq!(l.recent_loss(1), 1.0);
        assert_eq!(l.recent_loss(10), 1.5);
    }

    #[test]
    fn pct_format() {
        assert_eq!(pct(0.6265), "62.65");
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 95.0), 95.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&[3.0], 99.0), 3.0);
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn percentile_survives_nan_input() {
        // regression: partial_cmp().unwrap() panicked on any NaN sample.
        // total_cmp orders NaN after +inf, so finite percentiles of a
        // mostly-finite sample stay finite and correct.
        let xs = [5.0, f64::NAN, 1.0, 3.0];
        let p50 = percentile(&xs, 50.0);
        assert_eq!(p50, 3.0);
        assert!(percentile(&xs, 100.0).is_nan(), "NaN sorts last under total_cmp");
        assert!(percentile(&[f64::NAN], 50.0).is_nan());
    }

    #[test]
    fn runlog_warns_once_and_flushes_on_drop() {
        // a RunLog pointed at a real directory must land its buffered tail
        // on disk by Drop (short runs exit right after the last note)
        let dir = std::env::temp_dir().join(format!("silq_runlog_{}", std::process::id()));
        {
            let mut l = RunLog::new(&dir);
            l.note("tail line");
            l.step(1, 0.5, "extra");
        } // drop flushes
        let text = std::fs::read_to_string(dir.join("log.txt")).unwrap();
        assert!(text.contains("tail line"));
        assert!(text.contains("step 1 loss 0.50000 extra"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
