//! Run logging and report formatting (EXPERIMENTS.md rows come from here).

use std::io::Write;
use std::path::{Path, PathBuf};

/// Append-only run log: step metrics + free-form notes, flushed to
/// `runs/<name>/log.txt`.
pub struct RunLog {
    pub dir: PathBuf,
    file: Option<std::fs::File>,
    pub losses: Vec<(usize, f32)>,
}

impl RunLog {
    pub fn new(dir: impl AsRef<Path>) -> RunLog {
        let dir = dir.as_ref().to_path_buf();
        let file = std::fs::create_dir_all(&dir)
            .and_then(|_| std::fs::File::create(dir.join("log.txt")))
            .map_err(|e| {
                eprintln!(
                    "warning: RunLog: cannot create {}/log.txt ({e}); \
                     this run will not be logged to disk",
                    dir.display()
                )
            })
            .ok();
        RunLog { dir, file, losses: vec![] }
    }

    /// In-memory only (tests, throwaway runs).
    pub fn ephemeral() -> RunLog {
        RunLog { dir: PathBuf::new(), file: None, losses: vec![] }
    }

    pub fn note(&mut self, msg: &str) {
        println!("{msg}");
        if let Some(f) = &mut self.file {
            let _ = writeln!(f, "{msg}");
        }
    }

    pub fn step(&mut self, step: usize, loss: f32, extra: &str) {
        self.losses.push((step, loss));
        if let Some(f) = &mut self.file {
            let _ = writeln!(f, "step {step} loss {loss:.5} {extra}");
        }
    }

    /// Mean loss over the last `n` recorded steps.
    pub fn recent_loss(&self, n: usize) -> f32 {
        let tail = &self.losses[self.losses.len().saturating_sub(n)..];
        if tail.is_empty() {
            return f32::NAN;
        }
        tail.iter().map(|(_, l)| l).sum::<f32>() / tail.len() as f32
    }
}

/// Fixed-width table printer for experiment reports.
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .take(ncol)
                .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.header) + "\n";
        out += &widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  ");
        out += "\n";
        for r in &self.rows {
            out += &fmt_row(r);
            out += "\n";
        }
        out
    }
}

pub fn pct(x: f32) -> String {
    format!("{:.2}", 100.0 * x)
}

/// Nearest-rank percentile of an unsorted sample (NaN for empty input).
/// Used by the serve stats for TTFT/latency tails.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0 * v.len() as f64).ceil() as usize).clamp(1, v.len()) - 1;
    v[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "acc"]);
        t.row(&["baseline".into(), "62.65".into()]);
        t.row(&["siq".into(), "61.1".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].contains("baseline"));
    }

    #[test]
    fn runlog_tracks_losses() {
        let mut l = RunLog::ephemeral();
        l.step(1, 2.0, "");
        l.step(2, 1.0, "");
        assert_eq!(l.recent_loss(1), 1.0);
        assert_eq!(l.recent_loss(10), 1.5);
    }

    #[test]
    fn pct_format() {
        assert_eq!(pct(0.6265), "62.65");
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 95.0), 95.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&[3.0], 99.0), 3.0);
        assert!(percentile(&[], 50.0).is_nan());
    }
}
