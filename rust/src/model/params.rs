//! Parameter store: the ordered, named f32 tensors of one model instance,
//! matching the artifact manifest's `params.*` contract.

use anyhow::{anyhow, Result};
use std::path::Path;

use crate::config::{ArtifactSpec, ModelCfg};
use crate::model::bundle::{Tensor, TensorBundle};
use crate::util::Rng;

/// Ordered parameter collection. Order always matches the artifact manifest
/// so the flat literal list fed to PJRT lines up.
#[derive(Clone, Debug)]
pub struct ParamStore {
    pub names: Vec<String>,
    pub shapes: Vec<Vec<usize>>,
    pub values: Vec<Vec<f32>>,
}

impl ParamStore {
    /// Build an empty store with the shapes an artifact expects.
    pub fn from_spec(spec: &ArtifactSpec) -> Self {
        let mut names = vec![];
        let mut shapes = vec![];
        let mut values = vec![];
        for t in &spec.inputs {
            if let Some(n) = t.name.strip_prefix("params.") {
                names.push(n.to_string());
                shapes.push(t.dims.clone());
                values.push(vec![0.0; t.numel().max(1)]);
            }
        }
        ParamStore { names, shapes, values }
    }

    /// Random initialization (same scheme as `model.init_params` on the
    /// Python side: ones for norms, small constant for quantizer steps,
    /// scaled normals for weights).
    pub fn init(spec: &ArtifactSpec, _mc: &ModelCfg, rng: &mut Rng) -> Self {
        let mut ps = Self::from_spec(spec);
        for i in 0..ps.names.len() {
            let name = ps.names[i].clone();
            let shape = ps.shapes[i].clone();
            let n = ps.values[i].len();
            ps.values[i] = if name.starts_with("ln") {
                vec![1.0; n]
            } else if name.starts_with("sw_") || name.starts_with("sa_") || name.starts_with("sc_") {
                vec![0.05; n]
            } else {
                let std = if name == "embed" || name == "head" {
                    0.02
                } else {
                    // fan-in init: second-to-last dim
                    let fan_in = shape[shape.len() - 2] as f32;
                    1.0 / fan_in.sqrt()
                };
                rng.normal_vec(n, std)
            };
        }
        ps
    }

    pub fn index(&self, name: &str) -> Result<usize> {
        self.names
            .iter()
            .position(|n| n == name)
            .ok_or_else(|| anyhow!("param store: no param {name}"))
    }

    pub fn has(&self, name: &str) -> bool {
        self.names.iter().any(|n| n == name)
    }

    pub fn get(&self, name: &str) -> Result<&[f32]> {
        Ok(&self.values[self.index(name)?])
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Vec<f32>> {
        let i = self.index(name)?;
        Ok(&mut self.values[i])
    }

    pub fn shape(&self, name: &str) -> Result<&[usize]> {
        Ok(&self.shapes[self.index(name)?])
    }

    pub fn set(&mut self, name: &str, data: Vec<f32>) -> Result<()> {
        let i = self.index(name)?;
        anyhow::ensure!(data.len() == self.values[i].len(), "shape mismatch for {name}");
        self.values[i] = data;
        Ok(())
    }

    /// Total parameter count.
    pub fn numel(&self) -> usize {
        self.values.iter().map(|v| v.len()).sum()
    }

    /// Copy shared tensors from another store (e.g. fp16 weights into a
    /// quantized store whose extra `sw_*`/`sa_*` entries stay untouched).
    pub fn copy_common_from(&mut self, other: &ParamStore) {
        for i in 0..self.names.len() {
            if let Ok(j) = other.index(&self.names[i]) {
                if other.values[j].len() == self.values[i].len() {
                    self.values[i] = other.values[j].clone();
                }
            }
        }
    }

    pub fn to_bundle(&self) -> TensorBundle {
        let mut b = TensorBundle::new();
        for i in 0..self.names.len() {
            b.insert(
                format!("params.{}", self.names[i]),
                Tensor::f32(self.shapes[i].clone(), self.values[i].clone()),
            );
        }
        b
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        self.to_bundle().save(path)
    }

    /// Load values from a bundle (names must cover this store's params).
    pub fn load_from_bundle(spec: &ArtifactSpec, b: &TensorBundle) -> Result<Self> {
        let mut ps = Self::from_spec(spec);
        for i in 0..ps.names.len() {
            let t = b.get(&format!("params.{}", ps.names[i]))?;
            let data = t.as_f32()?.to_vec();
            anyhow::ensure!(
                data.len() == ps.values[i].len(),
                "bundle shape mismatch for {}",
                ps.names[i]
            );
            ps.values[i] = data;
        }
        Ok(ps)
    }

    pub fn load(spec: &ArtifactSpec, path: impl AsRef<Path>) -> Result<Self> {
        Self::load_from_bundle(spec, &TensorBundle::load(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Manifest, TensorSpec};
    use std::path::PathBuf;

    fn fake_spec() -> ArtifactSpec {
        ArtifactSpec {
            name: "t".into(),
            file: "t.hlo.txt".into(),
            model: "tiny".into(),
            prec: "fp16".into(),
            mode: "fwd".into(),
            inputs: vec![
                TensorSpec { name: "params.embed".into(), dtype: "f32".into(), dims: vec![8, 4] },
                TensorSpec { name: "params.ln1".into(), dtype: "f32".into(), dims: vec![2, 4] },
                TensorSpec { name: "params.sw_q".into(), dtype: "f32".into(), dims: vec![2, 4] },
                TensorSpec { name: "tokens".into(), dtype: "i32".into(), dims: vec![1, 4] },
            ],
            outputs: vec![],
        }
    }

    fn fake_mc() -> ModelCfg {
        ModelCfg {
            name: "tiny".into(), vocab: 8, d_model: 4, n_layers: 2, n_heads: 1,
            d_ff: 8, seq_len: 4, train_batch: 1, fwd_batch: 1, use_pallas: false,
        }
    }

    #[test]
    fn from_spec_skips_non_params() {
        let ps = ParamStore::from_spec(&fake_spec());
        assert_eq!(ps.names, vec!["embed", "ln1", "sw_q"]);
        assert_eq!(ps.numel(), 32 + 8 + 8);
    }

    #[test]
    fn init_rules() {
        let mut rng = Rng::new(0);
        let ps = ParamStore::init(&fake_spec(), &fake_mc(), &mut rng);
        assert!(ps.get("ln1").unwrap().iter().all(|&v| v == 1.0));
        assert!(ps.get("sw_q").unwrap().iter().all(|&v| v == 0.05));
        assert!(ps.get("embed").unwrap().iter().any(|&v| v != 0.0));
    }

    #[test]
    fn set_get_roundtrip() {
        let mut ps = ParamStore::from_spec(&fake_spec());
        ps.set("ln1", vec![2.0; 8]).unwrap();
        assert_eq!(ps.get("ln1").unwrap()[0], 2.0);
        assert!(ps.set("ln1", vec![1.0; 3]).is_err());
        assert!(ps.get("nope").is_err());
    }

    #[test]
    fn bundle_roundtrip() {
        let mut rng = Rng::new(1);
        let ps = ParamStore::init(&fake_spec(), &fake_mc(), &mut rng);
        let path = std::env::temp_dir().join("silq_params_test.bin");
        ps.save(&path).unwrap();
        let ps2 = ParamStore::load(&fake_spec(), &path).unwrap();
        assert_eq!(ps.values, ps2.values);
    }

    #[test]
    fn loads_python_fixture_params_if_built() {
        if let Ok(m) = Manifest::load("artifacts") {
            let spec = m.artifact("tiny_fp16_fwd").unwrap();
            let p = PathBuf::from("artifacts/fixtures/fwd_tiny_fp16.bin");
            if p.exists() {
                let ps = ParamStore::load(spec, &p).unwrap();
                assert_eq!(ps.names.len(), 12);
                assert!(ps.numel() > 500_000);
            }
        }
    }
}
