//! Parameter storage, initialization, and checkpoint IO.

pub mod bundle;
pub mod params;

pub use bundle::{Tensor, TensorBundle};
pub use params::ParamStore;
