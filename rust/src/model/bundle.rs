//! "Tensor bundle" binary format — the checkpoint format of this repo and
//! the fixture interchange with the Python compile path
//! (see `python/compile/fixtures.py` for the layout).

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"SILQTNSR";

/// A named tensor: f32 or i32 payload plus shape.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    I32 { dims: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn f32(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor::F32 { dims, data }
    }

    pub fn i32(dims: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        Tensor::I32 { dims, data }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor::F32 { dims: vec![], data: vec![v] }
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            Tensor::F32 { dims, .. } | Tensor::I32 { dims, .. } => dims,
        }
    }

    pub fn numel(&self) -> usize {
        self.dims().iter().product::<usize>().max(1)
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }
}

/// An ordered map of named tensors with binary (de)serialization.
#[derive(Clone, Debug, Default)]
pub struct TensorBundle {
    pub tensors: BTreeMap<String, Tensor>,
}

impl TensorBundle {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: impl Into<String>, t: Tensor) {
        self.tensors.insert(name.into(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).ok_or_else(|| anyhow!("bundle: no tensor {name}"))
    }

    pub fn f32s(&self, name: &str) -> Result<&[f32]> {
        self.get(name)?.as_f32()
    }

    pub fn scalar(&self, name: &str) -> Result<f32> {
        Ok(self.f32s(name)?[0])
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut w = std::io::BufWriter::new(std::fs::File::create(path.as_ref())?);
        w.write_all(MAGIC)?;
        w.write_all(&1u32.to_le_bytes())?;
        w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, t) in &self.tensors {
            let nb = name.as_bytes();
            w.write_all(&(nb.len() as u32).to_le_bytes())?;
            w.write_all(nb)?;
            let (dt, dims): (u8, &[usize]) = match t {
                Tensor::F32 { dims, .. } => (0, dims),
                Tensor::I32 { dims, .. } => (1, dims),
            };
            w.write_all(&[dt])?;
            w.write_all(&(dims.len() as u32).to_le_bytes())?;
            for d in dims {
                w.write_all(&(*d as u32).to_le_bytes())?;
            }
            match t {
                Tensor::F32 { data, .. } => {
                    for v in data {
                        w.write_all(&v.to_le_bytes())?;
                    }
                }
                Tensor::I32 { data, .. } => {
                    for v in data {
                        w.write_all(&v.to_le_bytes())?;
                    }
                }
            }
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<TensorBundle> {
        let bytes = std::fs::read(path.as_ref())
            .with_context(|| format!("reading bundle {:?}", path.as_ref()))?;
        Self::from_bytes(&bytes)
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<TensorBundle> {
        let mut r = bytes;
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("bad bundle magic");
        }
        let version = read_u32(&mut r)?;
        if version != 1 {
            bail!("unsupported bundle version {version}");
        }
        let count = read_u32(&mut r)? as usize;
        let mut bundle = TensorBundle::new();
        for _ in 0..count {
            let nlen = read_u32(&mut r)? as usize;
            let mut nb = vec![0u8; nlen];
            r.read_exact(&mut nb)?;
            let name = String::from_utf8(nb)?;
            let mut dt = [0u8; 1];
            r.read_exact(&mut dt)?;
            let ndim = read_u32(&mut r)? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(read_u32(&mut r)? as usize);
            }
            let numel: usize = dims.iter().product::<usize>().max(if ndim == 0 { 1 } else { 0 });
            // note: 0-dim tensors carry exactly one element
            let numel = if ndim == 0 { 1 } else { numel };
            let t = match dt[0] {
                0 => {
                    let mut data = vec![0f32; numel];
                    for v in data.iter_mut() {
                        let mut b = [0u8; 4];
                        r.read_exact(&mut b)?;
                        *v = f32::from_le_bytes(b);
                    }
                    Tensor::F32 { dims, data }
                }
                1 => {
                    let mut data = vec![0i32; numel];
                    for v in data.iter_mut() {
                        let mut b = [0u8; 4];
                        r.read_exact(&mut b)?;
                        *v = i32::from_le_bytes(b);
                    }
                    Tensor::I32 { dims, data }
                }
                other => bail!("unknown dtype tag {other}"),
            };
            bundle.insert(name, t);
        }
        Ok(bundle)
    }
}

fn read_u32(r: &mut &[u8]) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut b = TensorBundle::new();
        b.insert("a", Tensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]));
        b.insert("b", Tensor::i32(vec![4], vec![7, 8, 9, 10]));
        b.insert("s", Tensor::scalar(3.5));
        let dir = std::env::temp_dir().join("silq_bundle_test.bin");
        b.save(&dir).unwrap();
        let c = TensorBundle::load(&dir).unwrap();
        assert_eq!(b.tensors, c.tensors);
        assert_eq!(c.scalar("s").unwrap(), 3.5);
        assert_eq!(c.get("b").unwrap().as_i32().unwrap(), &[7, 8, 9, 10]);
    }

    #[test]
    fn missing_tensor_errors() {
        let b = TensorBundle::new();
        assert!(b.get("nope").is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(TensorBundle::from_bytes(b"NOTMAGIC\x01\x00\x00\x00").is_err());
    }

    #[test]
    fn python_fixtures_load_if_built() {
        let p = std::path::Path::new("artifacts/fixtures/quant_cases.bin");
        if p.exists() {
            let b = TensorBundle::load(p).unwrap();
            assert!(b.tensors.len() > 10);
            // quantized outputs land on the step grid
            let x = b.f32s("fq0.x").unwrap();
            let y = b.f32s("fq0.y").unwrap();
            let s = b.scalar("fq0.s").unwrap();
            assert_eq!(x.len(), y.len());
            for v in y {
                let r = v / s;
                assert!((r - r.round()).abs() < 1e-3);
            }
        }
    }
}
