//! Decode backends: how one scheduler step turns token prefixes into next
//! tokens.
//!
//! The transformer forwards themselves live elsewhere — the host quantized
//! model in [`crate::hostmodel`], the PJRT graph plumbing in
//! [`crate::forward`] — and both backends here are thin [`DecodeBackend`]
//! adapters over the shared [`crate::forward::ForwardBackend`]
//! implementations, so `silq eval`, LLM-QAT self-generation and
//! `silq serve` run the exact same forward:
//!
//! * [`ArtifactBackend`] — over [`ArtifactForward`]: packs the active lanes
//!   into the compiled `*_fwd` artifact's fixed `[fwd_batch, seq_len]`
//!   shape and recomputes the full sequence on PJRT each step (the graph
//!   holds its cache internally). The throughput path when artifacts are
//!   built.
//! * [`HostBackend`] — over [`HostForward`]: incremental decode with an
//!   explicit [`crate::hostmodel::KvPool`], the host mirror of the
//!   deployment loop where the K/V cache is resident in the paper's
//!   integer representation. One scheduler step is **one cross-lane
//!   batched forward**: every live lane's activation row stacks into one
//!   fused `i8` GEMM per weight matrix
//!   (`ForwardBackend::step_greedy` → `HostModel::forward_tokens_batch`),
//!   so at batch width B each matrix streams once per GEMM block per step
//!   instead of B times. [`HostBackend::new_sequential`] keeps the
//!   per-lane GEMV loop as the bit-identical reference the
//!   batched≡sequential identity suite and the bench baseline run
//!   against. Runs with no artifacts at all, which is what lets the serve
//!   integration tests execute everywhere.

use anyhow::{ensure, Result};

use crate::evalharness::decode::argmax;
use crate::forward::{ArtifactForward, ForwardBackend, HostForward};
use crate::hostmodel::{CacheStore, HostCfg, KvLayout, PageLedger};
use crate::model::ParamStore;
use crate::runtime::Engine;

/// One decode step over a fixed set of lanes.
pub trait DecodeBackend {
    /// Fixed number of batch lanes the backend can serve concurrently.
    fn lanes(&self) -> usize;
    /// Model context window.
    fn seq_len(&self) -> usize;
    /// Bind a prompt to lane `lane` (prefill). Called once per admission.
    fn admit(&mut self, lane: usize, prompt: &[i32]) -> Result<()>;
    /// Release lane `lane`'s cache resources.
    fn evict(&mut self, lane: usize);
    /// Advance every active lane by one greedy token. `lanes[l]` is the
    /// full token prefix of lane `l`, or `None` for an idle lane; the
    /// return vector mirrors that layout.
    fn step(&mut self, lanes: &[Option<&[i32]>]) -> Result<Vec<Option<i32>>>;
    /// Resident KV bytes in deployment format (0 when the cache lives
    /// inside the compiled graph).
    fn kv_bytes(&self) -> usize {
        0
    }
    /// Physical KV pages bound to live lanes (0 when the backend has no
    /// explicit pool).
    fn kv_pages(&self) -> usize {
        0
    }
    /// Lifetime page-flow counters of the backend's pool (all-zero when
    /// the backend has no explicit pool).
    fn kv_ledger(&self) -> PageLedger {
        PageLedger::default()
    }
}

// ---------------------------------------------------------------------------
// ArtifactBackend — full-sequence recompute through the compiled graph
// ---------------------------------------------------------------------------

/// Serves through a compiled `*_fwd` artifact (a [`ArtifactForward`] in
/// lane clothing).
pub struct ArtifactBackend {
    inner: ArtifactForward,
}

impl ArtifactBackend {
    pub fn new(engine: &Engine, artifact: &str, params: &ParamStore) -> Result<ArtifactBackend> {
        Ok(ArtifactBackend { inner: ArtifactForward::new(engine, artifact, params)? })
    }
}

impl DecodeBackend for ArtifactBackend {
    fn lanes(&self) -> usize {
        self.inner.batch()
    }

    fn seq_len(&self) -> usize {
        self.inner.seq_len()
    }

    fn admit(&mut self, _lane: usize, prompt: &[i32]) -> Result<()> {
        // stateless graph: the prefix is recomputed every step, so
        // admission is pure validation
        self.inner.begin_decode(&[prompt])
    }

    fn evict(&mut self, _lane: usize) {}

    fn step(&mut self, lanes: &[Option<&[i32]>]) -> Result<Vec<Option<i32>>> {
        ensure!(lanes.len() <= self.inner.batch(), "more lanes than the artifact batch");
        let logits = self.inner.step_logits(lanes)?;
        Ok(logits.into_iter().map(|l| l.map(|lg| argmax(&lg) as i32)).collect())
    }
}

// ---------------------------------------------------------------------------
// HostBackend — incremental decode with an explicit quantized KV pool
// ---------------------------------------------------------------------------

/// Incremental greedy decoder over a `ParamStore` (a [`HostForward`] in
/// lane clothing): scheduler lanes map one-to-one onto the forward's cache
/// rows, and one scheduler step is one cross-lane batched forward. When
/// the [`crate::kernels::pool`] worker pool is configured wider than one
/// thread, that fused forward additionally shards its GEMMs by output
/// channel and its integer attention by lane — still token-exact against
/// [`HostBackend::new_sequential`] at any width.
pub struct HostBackend {
    inner: HostForward,
    /// step lanes one at a time through the per-lane GEMV path instead of
    /// the fused cross-lane GEMM — the bit-identical sequential reference
    sequential: bool,
}

impl HostBackend {
    /// The production backend: every scheduler step advances all live
    /// lanes through one fused batched forward.
    pub fn new(
        cfg: HostCfg,
        n_lanes: usize,
        params: &ParamStore,
        store: CacheStore,
    ) -> Result<HostBackend> {
        Self::new_with_layout(cfg, n_lanes, params, store, KvLayout::Slab)
    }

    /// [`HostBackend::new`] with an explicit KV cache layout — `--kv
    /// paged` selects [`KvLayout::Paged`] here and the scheduler above is
    /// layout-oblivious.
    pub fn new_with_layout(
        cfg: HostCfg,
        n_lanes: usize,
        params: &ParamStore,
        store: CacheStore,
        layout: KvLayout,
    ) -> Result<HostBackend> {
        Ok(HostBackend {
            inner: HostForward::new_with_layout(cfg, n_lanes, params, store, layout)?,
            sequential: false,
        })
    }

    /// The **sequential reference**: lanes step one at a time through
    /// [`HostForward::step_row_greedy`] (the pre-batching serve loop).
    /// Bit-identical to [`HostBackend::new`] by the exact-integer GEMV ≡
    /// GEMM invariant — the batched≡sequential proptest runs both through
    /// the real scheduler and requires token-exact agreement, and the
    /// bench harness measures the batched speedup against this.
    pub fn new_sequential(
        cfg: HostCfg,
        n_lanes: usize,
        params: &ParamStore,
        store: CacheStore,
    ) -> Result<HostBackend> {
        Ok(HostBackend {
            inner: HostForward::new(cfg, n_lanes, params, store)?,
            sequential: true,
        })
    }

    /// Whether every KV slot is back in the pool (serve-soak shutdown
    /// invariant).
    pub fn all_slots_free(&self) -> bool {
        self.inner.all_slots_free()
    }

    /// [`HostBackend::all_slots_free`] generalized to the paged pool: no
    /// page resident and every physical page back on the free list or the
    /// LRU — the shutdown invariant the paged torture test pins.
    pub fn all_pages_free(&self) -> bool {
        self.inner.all_pages_free()
    }
}

impl DecodeBackend for HostBackend {
    fn lanes(&self) -> usize {
        self.inner.batch()
    }

    fn seq_len(&self) -> usize {
        self.inner.seq_len()
    }

    fn admit(&mut self, lane: usize, prompt: &[i32]) -> Result<()> {
        self.inner.admit_row(lane, prompt)
    }

    fn evict(&mut self, lane: usize) {
        self.inner.evict_row(lane);
    }

    fn step(&mut self, lanes: &[Option<&[i32]>]) -> Result<Vec<Option<i32>>> {
        ensure!(lanes.len() <= self.inner.batch(), "more lanes than configured");
        if !self.sequential {
            // the hot path: gather every live lane into ONE batched
            // forward — one fused GEMM per weight matrix per step across
            // the whole batch, greedy picks straight off the stacked
            // scratch logits
            return self.inner.step_greedy(lanes);
        }
        // sequential reference: B independent GEMV passes, one per lane
        let mut next = Vec::with_capacity(lanes.len());
        for (lane, toks) in lanes.iter().enumerate() {
            next.push(match toks {
                Some(toks) if !toks.is_empty() && toks.len() < self.inner.seq_len() => {
                    Some(self.inner.step_row_greedy(lane, toks)?)
                }
                _ => None,
            });
        }
        Ok(next)
    }

    fn kv_bytes(&self) -> usize {
        self.inner.kv_bytes()
    }

    fn kv_pages(&self) -> usize {
        self.inner.kv_pages()
    }

    fn kv_ledger(&self) -> PageLedger {
        self.inner.kv_ledger()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostmodel::{host_param_spec, host_test_params, tiny_host_cfg};

    fn backend(cfg: &HostCfg, lanes: usize, store: CacheStore, seed: u64) -> HostBackend {
        let params = host_test_params(cfg, seed);
        HostBackend::new(cfg.clone(), lanes, &params, store).unwrap()
    }

    #[test]
    fn host_spec_matches_python_param_spec() {
        let spec = host_param_spec(&tiny_host_cfg(true, false));
        let names = spec.param_names();
        assert_eq!(names.len(), 12 + 8 + 8);
        assert_eq!(names[0], "embed");
        assert!(names.contains(&"sc_k".to_string()));
        let spec_dyn = host_param_spec(&tiny_host_cfg(true, true));
        assert_eq!(spec_dyn.param_names().len(), 12 + 8);
    }

    #[test]
    fn decode_is_deterministic_and_finite() {
        let cfg = tiny_host_cfg(true, true);
        let mut b1 = backend(&cfg, 2, CacheStore::Int8, 3);
        let mut b2 = backend(&cfg, 2, CacheStore::Int8, 3);
        let prompt = [1i32, 3, 22, 10, 130, 4];
        b1.admit(0, &prompt).unwrap();
        b2.admit(0, &prompt).unwrap();
        let mut toks = prompt.to_vec();
        for _ in 0..4 {
            let n1 = b1.step(&[Some(&toks), None]).unwrap()[0].unwrap();
            let n2 = b2.step(&[Some(&toks), None]).unwrap()[0].unwrap();
            assert_eq!(n1, n2);
            toks.push(n1);
        }
    }

    #[test]
    fn batched_step_matches_sequential_reference_token_for_token() {
        // two lanes at ragged positions: one fused cross-lane step must
        // pick exactly the tokens two per-lane GEMV steps pick
        let cfg = tiny_host_cfg(true, true);
        let params = host_test_params(&cfg, 3);
        let mut bat = HostBackend::new(cfg.clone(), 2, &params, CacheStore::Int8).unwrap();
        let mut seq =
            HostBackend::new_sequential(cfg.clone(), 2, &params, CacheStore::Int8).unwrap();
        let mut rows: Vec<Vec<i32>> = vec![vec![1, 3, 22], vec![4, 130, 9, 17, 2]];
        for (lane, row) in rows.iter().enumerate() {
            bat.admit(lane, row).unwrap();
            seq.admit(lane, row).unwrap();
        }
        for _ in 0..4 {
            let views: Vec<Option<&[i32]>> = rows.iter().map(|r| Some(r.as_slice())).collect();
            let nb = bat.step(&views).unwrap();
            let ns = seq.step(&views).unwrap();
            assert_eq!(nb, ns, "batched step diverged from the sequential reference");
            for (row, tok) in rows.iter_mut().zip(nb) {
                row.push(tok.unwrap());
            }
        }
        bat.evict(0);
        bat.evict(1);
        assert!(bat.all_slots_free());
    }

    #[test]
    fn eviction_frees_the_slot() {
        let cfg = tiny_host_cfg(true, true);
        let mut b = backend(&cfg, 1, CacheStore::Int8, 5);
        b.admit(0, &[1, 3, 4]).unwrap();
        assert!(b.kv_bytes() > 0);
        b.evict(0);
        assert_eq!(b.kv_bytes(), 0);
        b.admit(0, &[1, 5, 4]).unwrap(); // slot is reusable
    }

    #[test]
    fn fp16_cfg_runs_unquantized() {
        let cfg = tiny_host_cfg(false, true);
        let mut b = backend(&cfg, 1, CacheStore::F32, 7);
        b.admit(0, &[1, 3, 4]).unwrap();
        let n = b.step(&[Some(&[1, 3, 4])]).unwrap();
        assert!(n[0].is_some());
    }

    #[test]
    fn bad_prompt_is_rejected_at_admission() {
        let cfg = tiny_host_cfg(true, true);
        let mut b = backend(&cfg, 1, CacheStore::Int8, 9);
        assert!(b.admit(0, &[]).is_err());
        assert!(b.admit(0, &[1, 9999]).is_err());
        // rejection leaves the lane free
        b.admit(0, &[1, 3]).unwrap();
    }
}
