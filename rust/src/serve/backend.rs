//! Decode backends: how one scheduler step turns token prefixes into next
//! tokens.
//!
//! * [`ArtifactBackend`] — packs the active lanes into the compiled
//!   `*_fwd` artifact's fixed `[fwd_batch, seq_len]` shape and recomputes
//!   the full sequence on PJRT each step (the graph holds its cache
//!   internally). This is the throughput path when artifacts are built.
//! * [`HostBackend`] — incremental single-token decode with an explicit
//!   [`KvPool`]: the host mirror of the deployment loop, where the K/V
//!   cache is resident in the paper's integer representation. Runs with no
//!   artifacts at all, which is what lets the serve integration tests
//!   execute everywhere.
//!
//! Both backends share the greedy-decode helpers extracted from the eval
//! harness so `silq eval` and `silq serve` argmax identically.

use anyhow::{ensure, Context, Result};
use std::sync::Arc;

use crate::config::{ArtifactSpec, ModelCfg, PrecCfg, TensorSpec};
use crate::evalharness::decode::{argmax, pack_rows};
use crate::model::ParamStore;
use crate::quant::{dynamic_quant_rows, fake_quant, fake_quant_per_channel};
use crate::runtime::{build_inputs, literal_i32, to_f32_vec, Engine, Module};
use crate::serve::kvpool::{CacheStore, KvPool, QuantRule};

/// One decode step over a fixed set of lanes.
pub trait DecodeBackend {
    /// Fixed number of batch lanes the backend can serve concurrently.
    fn lanes(&self) -> usize;
    /// Model context window.
    fn seq_len(&self) -> usize;
    /// Bind a prompt to lane `lane` (prefill). Called once per admission.
    fn admit(&mut self, lane: usize, prompt: &[i32]) -> Result<()>;
    /// Release lane `lane`'s cache resources.
    fn evict(&mut self, lane: usize);
    /// Advance every active lane by one greedy token. `lanes[l]` is the
    /// full token prefix of lane `l`, or `None` for an idle lane; the
    /// return vector mirrors that layout.
    fn step(&mut self, lanes: &[Option<&[i32]>]) -> Result<Vec<Option<i32>>>;
    /// Resident KV bytes in deployment format (0 when the cache lives
    /// inside the compiled graph).
    fn kv_bytes(&self) -> usize {
        0
    }
}

// ---------------------------------------------------------------------------
// ArtifactBackend — full-sequence recompute through the compiled graph
// ---------------------------------------------------------------------------

/// Serves through a compiled `*_fwd` artifact. Parameter literals are built
/// once; only the token literal changes per step.
pub struct ArtifactBackend {
    module: Arc<Module>,
    inputs: Vec<xla::Literal>,
    tok_idx: usize,
    batch: usize,
    seq: usize,
    vocab: usize,
}

impl ArtifactBackend {
    pub fn new(engine: &Engine, artifact: &str, params: &ParamStore) -> Result<ArtifactBackend> {
        let module = engine.module(artifact)?;
        let spec = module.spec.clone();
        let mc = engine.manifest.model(&spec.model)?;
        let (batch, seq, vocab) = (mc.fwd_batch, mc.seq_len, mc.vocab);
        let tok_idx = spec.input_index("tokens")?;
        let zeros = vec![0i32; batch * seq];
        let inputs = build_inputs(
            &spec,
            params,
            &[("tokens", literal_i32(&spec.inputs[tok_idx].dims, &zeros)?)],
        )?;
        Ok(ArtifactBackend { module, inputs, tok_idx, batch, seq, vocab })
    }
}

impl DecodeBackend for ArtifactBackend {
    fn lanes(&self) -> usize {
        self.batch
    }

    fn seq_len(&self) -> usize {
        self.seq
    }

    fn admit(&mut self, _lane: usize, prompt: &[i32]) -> Result<()> {
        ensure!(prompt.len() < self.seq, "prompt does not fit the context window");
        check_tokens(prompt, self.vocab)?;
        Ok(()) // stateless graph: the prefix is recomputed every step
    }

    fn evict(&mut self, _lane: usize) {}

    fn step(&mut self, lanes: &[Option<&[i32]>]) -> Result<Vec<Option<i32>>> {
        ensure!(lanes.len() <= self.batch, "more lanes than the artifact batch");
        let rows: Vec<&[i32]> = lanes.iter().map(|l| l.unwrap_or(&[])).collect();
        let tokens = pack_rows(&rows, self.batch, self.seq);
        let tok_spec = &self.module.spec.inputs[self.tok_idx];
        self.inputs[self.tok_idx] = literal_i32(&tok_spec.dims, &tokens)?;
        let out = self.module.run(&self.inputs)?;
        let logits = to_f32_vec(&out[0])?;
        let mut next = Vec::with_capacity(lanes.len());
        for (r, lane) in lanes.iter().enumerate() {
            next.push(match lane {
                Some(toks) if !toks.is_empty() && toks.len() < self.seq => {
                    let base = (r * self.seq + toks.len() - 1) * self.vocab;
                    Some(argmax(&logits[base..base + self.vocab]) as i32)
                }
                _ => None,
            });
        }
        Ok(next)
    }
}

// ---------------------------------------------------------------------------
// HostBackend — incremental decode with an explicit quantized KV pool
// ---------------------------------------------------------------------------

/// Model + precision shape of the host decode path, decoupled from the
/// artifact manifest so tests and benches run without built artifacts.
#[derive(Clone, Debug)]
pub struct HostCfg {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub quantized: bool,
    pub act_bits: u32,
    pub act_dynamic: bool,
    pub cache_bits: u32,
    pub weight_bits: u32,
    pub head_bits: u32,
    pub query_bits: u32,
    /// `rope_theta` from `python/compile/configs.py` (all current models
    /// use the default; the manifest does not carry it)
    pub rope_theta: f32,
}

impl HostCfg {
    pub fn from_manifest(mc: &ModelCfg, pc: &PrecCfg) -> Result<HostCfg> {
        ensure!(!pc.online_rot, "host decode does not implement the online-rotation ablation");
        Ok(HostCfg {
            vocab: mc.vocab,
            d_model: mc.d_model,
            n_layers: mc.n_layers,
            n_heads: mc.n_heads,
            d_ff: mc.d_ff,
            seq_len: mc.seq_len,
            quantized: pc.quantized,
            act_bits: pc.act_bits,
            act_dynamic: pc.act_dynamic,
            cache_bits: pc.cache_bits,
            weight_bits: pc.weight_bits,
            head_bits: pc.head_bits,
            query_bits: pc.query_bits,
            rope_theta: 10000.0,
        })
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }
}

/// Build the `ArtifactSpec` a host-served model's `ParamStore` follows —
/// the same ordered contract as `python/compile/model.py::param_spec`.
pub fn host_param_spec(cfg: &HostCfg) -> ArtifactSpec {
    let (l, d, f, v) = (cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab);
    let mut inputs: Vec<(String, Vec<usize>)> = vec![
        ("embed".into(), vec![v, d]),
        ("ln1".into(), vec![l, d]),
        ("wq".into(), vec![l, d, d]),
        ("wk".into(), vec![l, d, d]),
        ("wv".into(), vec![l, d, d]),
        ("wo".into(), vec![l, d, d]),
        ("ln2".into(), vec![l, d]),
        ("wg".into(), vec![l, d, f]),
        ("wu".into(), vec![l, d, f]),
        ("wd".into(), vec![l, f, d]),
        ("ln_f".into(), vec![d]),
        ("head".into(), vec![d, v]),
    ];
    if cfg.quantized {
        for (n, dims) in [
            ("sw_q", vec![l, d]),
            ("sw_k", vec![l, d]),
            ("sw_v", vec![l, d]),
            ("sw_o", vec![l, d]),
            ("sw_g", vec![l, f]),
            ("sw_u", vec![l, f]),
            ("sw_d", vec![l, d]),
            ("sw_head", vec![v]),
        ] {
            inputs.push((n.into(), dims));
        }
        if !cfg.act_dynamic {
            for (n, dims) in [
                ("sa_x1", vec![l]),
                ("sa_q", vec![l]),
                ("sc_k", vec![l]),
                ("sc_v", vec![l]),
                ("sa_o", vec![l]),
                ("sa_x2", vec![l]),
                ("sa_d", vec![l]),
                ("sa_head", vec![]),
            ] {
                inputs.push((n.into(), dims));
            }
        }
    }
    ArtifactSpec {
        name: "host_fwd".into(),
        file: String::new(),
        model: "host".into(),
        prec: if cfg.quantized { "quantized" } else { "fp16" }.into(),
        mode: "fwd".into(),
        inputs: inputs
            .into_iter()
            .map(|(n, dims)| TensorSpec { name: format!("params.{n}"), dtype: "f32".into(), dims })
            .collect(),
        outputs: vec![],
    }
}

/// Deterministic randomly-initialized parameters following
/// [`host_param_spec`] — the bootstrap the serve tests and benches share
/// (an untrained model generates noise, but latency/identity properties
/// don't care).
pub fn host_test_params(cfg: &HostCfg, seed: u64) -> ParamStore {
    let spec = host_param_spec(cfg);
    // ParamStore::init keys its rules off parameter names alone; the
    // ModelCfg is only part of the signature
    let mc = ModelCfg {
        name: "host".into(),
        vocab: cfg.vocab,
        d_model: cfg.d_model,
        n_layers: cfg.n_layers,
        n_heads: cfg.n_heads,
        d_ff: cfg.d_ff,
        seq_len: cfg.seq_len,
        train_batch: 1,
        fwd_batch: 1,
        use_pallas: false,
    };
    let mut rng = crate::util::Rng::new(seed);
    ParamStore::init(&spec, &mc, &mut rng)
}

/// Static (learned-scalar) activation steps per layer, when `act_dynamic`
/// is off.
struct StaticSteps {
    sa_x1: Vec<f32>,
    sa_q: Vec<f32>,
    sa_o: Vec<f32>,
    sa_x2: Vec<f32>,
    sa_d: Vec<f32>,
    sa_head: f32,
}

/// Per-layer weights with weight quantization folded in at construction
/// (weights are static; per-output-channel fake quant is applied once).
struct LayerWeights {
    ln1: Vec<f32>,
    wq: Vec<f32>,
    wk: Vec<f32>,
    wv: Vec<f32>,
    wo: Vec<f32>,
    ln2: Vec<f32>,
    wg: Vec<f32>,
    wu: Vec<f32>,
    wd: Vec<f32>,
}

/// Incremental greedy decoder over a `ParamStore`, with the K/V cache
/// resident in a [`KvPool`]. Pure host math — mirrors
/// `python/compile/model.py::forward` site for site (sans online rotation).
pub struct HostBackend {
    pub cfg: HostCfg,
    n_lanes: usize,
    embed: Vec<f32>,
    layers: Vec<LayerWeights>,
    ln_f: Vec<f32>,
    head: Vec<f32>,
    sa: Option<StaticSteps>,
    /// RoPE tables [seq, d_head/2]
    cos: Vec<f32>,
    sin: Vec<f32>,
    pool: KvPool,
    slot_of_lane: Vec<Option<usize>>,
    /// tokens already folded into the cache, per lane
    processed: Vec<usize>,
}

impl HostBackend {
    pub fn new(
        cfg: HostCfg,
        n_lanes: usize,
        params: &ParamStore,
        store: CacheStore,
    ) -> Result<HostBackend> {
        let (l, d, f, v) = (cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab);
        ensure!(d % cfg.n_heads == 0, "d_model must divide into heads");

        let slice = |name: &str, layer: usize, per: usize| -> Result<Vec<f32>> {
            let t = params.get(name)?;
            ensure!(t.len() == l * per, "{name}: expected {} values, got {}", l * per, t.len());
            Ok(t[layer * per..(layer + 1) * per].to_vec())
        };

        let mut layers = Vec::with_capacity(l);
        for li in 0..l {
            let mut w = LayerWeights {
                ln1: slice("ln1", li, d)?,
                wq: slice("wq", li, d * d)?,
                wk: slice("wk", li, d * d)?,
                wv: slice("wv", li, d * d)?,
                wo: slice("wo", li, d * d)?,
                ln2: slice("ln2", li, d)?,
                wg: slice("wg", li, d * f)?,
                wu: slice("wu", li, d * f)?,
                wd: slice("wd", li, f * d)?,
            };
            if cfg.quantized {
                let wb = cfg.weight_bits;
                fake_quant_per_channel(&mut w.wq, d, &slice("sw_q", li, d)?, wb);
                fake_quant_per_channel(&mut w.wk, d, &slice("sw_k", li, d)?, wb);
                fake_quant_per_channel(&mut w.wv, d, &slice("sw_v", li, d)?, wb);
                fake_quant_per_channel(&mut w.wo, d, &slice("sw_o", li, d)?, wb);
                fake_quant_per_channel(&mut w.wg, f, &slice("sw_g", li, f)?, wb);
                fake_quant_per_channel(&mut w.wu, f, &slice("sw_u", li, f)?, wb);
                fake_quant_per_channel(&mut w.wd, d, &slice("sw_d", li, d)?, wb);
            }
            layers.push(w);
        }

        let mut head = params.get("head")?.to_vec();
        if cfg.quantized {
            fake_quant_per_channel(&mut head, v, params.get("sw_head")?, cfg.head_bits);
        }

        let sa = if cfg.quantized && !cfg.act_dynamic {
            Some(StaticSteps {
                sa_x1: params.get("sa_x1")?.to_vec(),
                sa_q: params.get("sa_q")?.to_vec(),
                sa_o: params.get("sa_o")?.to_vec(),
                sa_x2: params.get("sa_x2")?.to_vec(),
                sa_d: params.get("sa_d")?.to_vec(),
                sa_head: params.get("sa_head")?[0],
            })
        } else {
            None
        };

        // cache quantization rule: static steps come from the trained
        // sc_k/sc_v scalars broadcast across channels; dynamic recomputes
        // per head row on write (ste_dynamic_quantize's last-axis rule)
        let rule = if !cfg.quantized {
            QuantRule::None
        } else if cfg.act_dynamic {
            QuantRule::Dynamic { bits: cfg.cache_bits, rows: cfg.n_heads }
        } else {
            let bc = |name: &str| -> Result<Vec<f32>> {
                let s = params.get(name)?;
                ensure!(s.len() == l, "{name} must be one step per layer");
                Ok(s.iter().flat_map(|&x| std::iter::repeat(x).take(d)).collect())
            };
            QuantRule::Static { bits: cfg.cache_bits, k_steps: bc("sc_k")?, v_steps: bc("sc_v")? }
        };
        let pool = KvPool::new(n_lanes, l, cfg.seq_len, d, store, rule)
            .context("building serve KV pool")?;

        // RoPE tables, as in model.py::rope_tables
        let dh = cfg.d_head();
        let half = dh / 2;
        let mut cos = Vec::with_capacity(cfg.seq_len * half);
        let mut sin = Vec::with_capacity(cfg.seq_len * half);
        for p in 0..cfg.seq_len {
            for i in 0..half {
                let inv = 1.0 / cfg.rope_theta.powf(2.0 * i as f32 / dh as f32);
                let ang = p as f32 * inv;
                cos.push(ang.cos());
                sin.push(ang.sin());
            }
        }

        Ok(HostBackend {
            embed: params.get("embed")?.to_vec(),
            ln_f: params.get("ln_f")?.to_vec(),
            head,
            layers,
            sa,
            cos,
            sin,
            pool,
            slot_of_lane: vec![None; n_lanes],
            processed: vec![0; n_lanes],
            n_lanes,
            cfg,
        })
    }

    /// Quantize one activation vector at a site (mirrors `act_quant`):
    /// dynamic per-`rows` sub-row (`ste_dynamic_quantize`'s last-axis
    /// rule), or a static learned step, or identity.
    fn act_quant(&self, x: &mut [f32], bits: u32, static_step: Option<f32>, rows: usize) {
        if !self.cfg.quantized {
            return;
        }
        match static_step {
            Some(s) => fake_quant(x, s, bits),
            None => dynamic_quant_rows(x, x.len() / rows, bits),
        }
    }

    /// Run one token through the stack; returns logits only when asked
    /// (prefill positions skip the head matmul).
    fn forward_token(&mut self, lane: usize, tok: i32, pos: usize, want_logits: bool) -> Result<Option<Vec<f32>>> {
        let cfg = self.cfg.clone();
        let (d, f, h, dh) = (cfg.d_model, cfg.d_ff, cfg.n_heads, cfg.d_head());
        let half = dh / 2;
        let slot = self.slot_of_lane[lane].context("lane has no cache slot")?;
        ensure!(pos < cfg.seq_len, "position {pos} outside the context window");
        ensure!((tok as usize) < cfg.vocab, "token {tok} outside the vocab");

        let mut x = self.embed[tok as usize * d..(tok as usize + 1) * d].to_vec();
        let mut k_cache = vec![0f32; (pos + 1) * d];
        let mut v_cache = vec![0f32; (pos + 1) * d];

        for li in 0..cfg.n_layers {
            // copy this layer's static activation steps out so no borrow of
            // `self.sa` is live across the mutable pool accesses below
            let (sa_x1, sa_q, sa_o, sa_x2, sa_d) = match &self.sa {
                Some(s) => (
                    Some(s.sa_x1[li]),
                    Some(s.sa_q[li]),
                    Some(s.sa_o[li]),
                    Some(s.sa_x2[li]),
                    Some(s.sa_d[li]),
                ),
                None => (None, None, None, None, None),
            };
            let mut hnorm = rmsnorm(&x, &self.layers[li].ln1);
            self.act_quant(&mut hnorm, cfg.act_bits, sa_x1, 1);
            let lw = &self.layers[li];
            let mut q = matvec(&hnorm, &lw.wq, d);
            let mut k = matvec(&hnorm, &lw.wk, d);
            let v = matvec(&hnorm, &lw.wv, d);

            // RoPE at this position, per head (channel layout is head-major)
            for head_i in 0..h {
                for i in 0..half {
                    let (c, s) = (self.cos[pos * half + i], self.sin[pos * half + i]);
                    for t in [&mut q, &mut k] {
                        let (a, b) = (t[head_i * dh + 2 * i], t[head_i * dh + 2 * i + 1]);
                        t[head_i * dh + 2 * i] = a * c - b * s;
                        t[head_i * dh + 2 * i + 1] = a * s + b * c;
                    }
                }
            }

            // INT16 query; K/V are quantized by the pool on write
            self.act_quant(&mut q, cfg.query_bits, sa_q, h);
            self.pool.write(slot, li, pos, &k, &v);
            self.pool.read_into(slot, li, pos + 1, &mut k_cache, &mut v_cache)?;

            // causal attention over the cached prefix
            let mut ctx = vec![0f32; d];
            let scale = 1.0 / (dh as f32).sqrt();
            let mut scores = vec![0f32; pos + 1];
            for head_i in 0..h {
                let qh = &q[head_i * dh..(head_i + 1) * dh];
                for (j, sc) in scores.iter_mut().enumerate() {
                    let kh = &k_cache[j * d + head_i * dh..j * d + (head_i + 1) * dh];
                    *sc = qh.iter().zip(kh).map(|(a, b)| a * b).sum::<f32>() * scale;
                }
                softmax_inplace(&mut scores);
                let ch = &mut ctx[head_i * dh..(head_i + 1) * dh];
                for (j, &p_j) in scores.iter().enumerate() {
                    let vh = &v_cache[j * d + head_i * dh..j * d + (head_i + 1) * dh];
                    for (cv, &vv) in ch.iter_mut().zip(vh) {
                        *cv += p_j * vv;
                    }
                }
            }

            self.act_quant(&mut ctx, cfg.act_bits, sa_o, 1);
            let o = matvec(&ctx, &self.layers[li].wo, d);
            for (xv, ov) in x.iter_mut().zip(&o) {
                *xv += ov;
            }

            let mut h2 = rmsnorm(&x, &self.layers[li].ln2);
            self.act_quant(&mut h2, cfg.act_bits, sa_x2, 1);
            let lw = &self.layers[li];
            let g = matvec(&h2, &lw.wg, f);
            let u = matvec(&h2, &lw.wu, f);
            let mut a: Vec<f32> = g.iter().zip(&u).map(|(&gv, &uv)| silu(gv) * uv).collect();
            self.act_quant(&mut a, cfg.act_bits, sa_d, 1);
            let dn = matvec(&a, &self.layers[li].wd, d);
            for (xv, dv) in x.iter_mut().zip(&dn) {
                *xv += dv;
            }
        }

        if !want_logits {
            return Ok(None);
        }
        let mut hf = rmsnorm(&x, &self.ln_f);
        self.act_quant(&mut hf, cfg.head_bits, self.sa.as_ref().map(|s| s.sa_head), 1);
        Ok(Some(matvec(&hf, &self.head, cfg.vocab)))
    }
}

impl DecodeBackend for HostBackend {
    fn lanes(&self) -> usize {
        self.n_lanes
    }

    fn seq_len(&self) -> usize {
        self.cfg.seq_len
    }

    fn admit(&mut self, lane: usize, prompt: &[i32]) -> Result<()> {
        ensure!(self.slot_of_lane[lane].is_none(), "lane {lane} already occupied");
        ensure!(!prompt.is_empty() && prompt.len() < self.cfg.seq_len, "bad prompt length");
        // validate the WHOLE prompt here — a bad final token must be a
        // per-request rejection, not an error out of the first step()
        check_tokens(prompt, self.cfg.vocab)?;
        let slot = self.pool.alloc().context("KV pool exhausted")?;
        self.slot_of_lane[lane] = Some(slot);
        // prefill everything but the last prompt token; the first step()
        // folds that one in and emits the first generated token
        for (pos, &tok) in prompt[..prompt.len() - 1].iter().enumerate() {
            self.forward_token(lane, tok, pos, false)?;
        }
        self.processed[lane] = prompt.len() - 1;
        Ok(())
    }

    fn evict(&mut self, lane: usize) {
        if let Some(slot) = self.slot_of_lane[lane].take() {
            self.pool.free(slot);
        }
        self.processed[lane] = 0;
    }

    fn step(&mut self, lanes: &[Option<&[i32]>]) -> Result<Vec<Option<i32>>> {
        ensure!(lanes.len() <= self.n_lanes, "more lanes than configured");
        let mut next = Vec::with_capacity(lanes.len());
        for (lane, toks) in lanes.iter().enumerate() {
            let Some(toks) = toks else {
                next.push(None);
                continue;
            };
            let pos = self.processed[lane];
            ensure!(pos + 1 == toks.len(), "lane {lane}: cache holds {pos} tokens, lane has {}", toks.len());
            if toks.len() >= self.cfg.seq_len {
                next.push(None);
                continue;
            }
            let logits = self
                .forward_token(lane, toks[pos], pos, true)?
                .expect("logits requested");
            self.processed[lane] = pos + 1;
            next.push(Some(argmax(&logits) as i32));
        }
        Ok(next)
    }

    fn kv_bytes(&self) -> usize {
        // resident bytes of the in-use slots, in deployment format
        if self.pool.slots == 0 {
            return 0;
        }
        self.pool.storage_bytes() * self.pool.slots_in_use() / self.pool.slots
    }
}

/// Admission-time validation shared by both backends.
fn check_tokens(prompt: &[i32], vocab: usize) -> Result<()> {
    for &t in prompt {
        ensure!((t as usize) < vocab, "prompt token {t} outside the vocab (0..{vocab})");
    }
    Ok(())
}

fn rmsnorm(x: &[f32], g: &[f32]) -> Vec<f32> {
    // model.py uses EPS=1e-6 inside rmsnorm (quant EPS is 1e-9)
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let r = 1.0 / (ms + 1e-6).sqrt();
    x.iter().zip(g).map(|(&v, &gv)| v * gv * r).collect()
}

/// `out[o] = sum_i x[i] * w[i * out_dim + o]` — the `x @ W` layout of the
/// row-major `[in, out]` weight matrices in the param contract.
fn matvec(x: &[f32], w: &[f32], out_dim: usize) -> Vec<f32> {
    debug_assert_eq!(x.len() * out_dim, w.len());
    let mut out = vec![0f32; out_dim];
    for (i, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let row = &w[i * out_dim..(i + 1) * out_dim];
        for (o, &wv) in out.iter_mut().zip(row) {
            *o += xv * wv;
        }
    }
    out
}

fn softmax_inplace(xs: &mut [f32]) {
    let m = xs.iter().fold(f32::MIN, |a, &b| a.max(b));
    let mut sum = 0f32;
    for v in xs.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    for v in xs.iter_mut() {
        *v /= sum;
    }
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    pub fn tiny_host_cfg(quantized: bool, act_dynamic: bool) -> HostCfg {
        HostCfg {
            vocab: 256,
            d_model: 32,
            n_layers: 2,
            n_heads: 4,
            d_ff: 64,
            seq_len: 16,
            quantized,
            act_bits: 8,
            act_dynamic,
            cache_bits: 8,
            weight_bits: 4,
            head_bits: 8,
            query_bits: 16,
            rope_theta: 10000.0,
        }
    }

    fn backend(cfg: &HostCfg, lanes: usize, store: CacheStore, seed: u64) -> HostBackend {
        let params = host_test_params(cfg, seed);
        HostBackend::new(cfg.clone(), lanes, &params, store).unwrap()
    }

    #[test]
    fn host_spec_matches_python_param_spec() {
        let spec = host_param_spec(&tiny_host_cfg(true, false));
        let names = spec.param_names();
        assert_eq!(names.len(), 12 + 8 + 8);
        assert_eq!(names[0], "embed");
        assert!(names.contains(&"sc_k".to_string()));
        let spec_dyn = host_param_spec(&tiny_host_cfg(true, true));
        assert_eq!(spec_dyn.param_names().len(), 12 + 8);
    }

    #[test]
    fn decode_is_deterministic_and_finite() {
        let cfg = tiny_host_cfg(true, true);
        let mut b1 = backend(&cfg, 2, CacheStore::Int8, 3);
        let mut b2 = backend(&cfg, 2, CacheStore::Int8, 3);
        let prompt = [1i32, 3, 22, 10, 130, 4];
        b1.admit(0, &prompt).unwrap();
        b2.admit(0, &prompt).unwrap();
        let mut toks = prompt.to_vec();
        for _ in 0..4 {
            let n1 = b1.step(&[Some(&toks), None]).unwrap()[0].unwrap();
            let n2 = b2.step(&[Some(&toks), None]).unwrap()[0].unwrap();
            assert_eq!(n1, n2);
            toks.push(n1);
        }
    }

    #[test]
    fn eviction_frees_the_slot() {
        let cfg = tiny_host_cfg(true, true);
        let mut b = backend(&cfg, 1, CacheStore::Int8, 5);
        b.admit(0, &[1, 3, 4]).unwrap();
        assert!(b.kv_bytes() > 0);
        b.evict(0);
        assert_eq!(b.kv_bytes(), 0);
        b.admit(0, &[1, 5, 4]).unwrap(); // slot is reusable
    }

    #[test]
    fn fp16_cfg_runs_unquantized() {
        let cfg = tiny_host_cfg(false, true);
        let mut b = backend(&cfg, 1, CacheStore::F32, 7);
        b.admit(0, &[1, 3, 4]).unwrap();
        let n = b.step(&[Some(&[1, 3, 4])]).unwrap();
        assert!(n[0].is_some());
    }
}
