//! `serve` — the continuous-batching inference engine.
//!
//! Pipeline shape (see README §serving):
//!
//! ```text
//!   producers -> AdmissionQueue (bounded, blocking)
//!                   |
//!             Scheduler: evict finished / admit queued / step   (scheduler.rs)
//!                   |
//!             DecodeBackend: ArtifactBackend (PJRT full-sequence)  (backend.rs)
//!                            HostBackend (cross-lane batched decode
//!                            over the KvPool — one fused GEMM per
//!                            weight matrix per step across all lanes)
//!                   |
//!             hostmodel::KvPool: slab K/V cache, INT8 quantize-on-write
//!                   |
//!             ServeStats: TTFT / tok/s / queue depth / occupancy  (stats.rs)
//! ```
//!
//! The transformer forwards behind both backends live in
//! [`crate::hostmodel`] (host quantized model + KV pool) and
//! [`crate::forward`] (the shared `ForwardBackend` abstraction); this
//! module only owns the serving mechanics — queueing, lane scheduling and
//! latency accounting.
//!
//! The engine is deliberately network-free: in this offline environment the
//! "clients" are load-generator threads (`silq serve` drives itself), but
//! the queue/scheduler/pool layering is the one a socket frontend would sit
//! on top of.

pub mod backend;
pub mod scheduler;
pub mod session;
pub mod stats;

pub use backend::{ArtifactBackend, DecodeBackend, HostBackend};
pub use scheduler::Scheduler;
pub use stats::ServeStats;

// the pool and host config moved to `hostmodel`; re-exported here because
// they are part of the serve construction surface
pub use crate::hostmodel::{CacheStore, HostCfg, KvPool, QuantRule};

use anyhow::{bail, ensure, Result};
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One generation request as submitted by a client.
#[derive(Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    /// token budget for the completion
    pub max_new: usize,
    /// stop at EOS (default); load generators and latency tests turn this
    /// off so every request decodes its full budget deterministically
    pub stop_on_eos: bool,
    pub submitted: Instant,
}

impl GenRequest {
    pub fn new(id: u64, prompt: Vec<i32>, max_new: usize) -> GenRequest {
        GenRequest { id, prompt, max_new, stop_on_eos: true, submitted: Instant::now() }
    }

    /// Decode the full `max_new` budget even if the model emits EOS.
    pub fn ignore_eos(mut self) -> GenRequest {
        self.stop_on_eos = false;
        self
    }
}

/// One finished request with its latency breakdown.
#[derive(Debug)]
pub struct GenResult {
    pub id: u64,
    pub prompt_len: usize,
    /// prompt followed by the generated completion
    pub tokens: Vec<i32>,
    /// submit -> admission (time spent in the queue)
    pub queued_ms: f64,
    /// submit -> first generated token
    pub ttft_ms: f64,
    /// submit -> completion
    pub total_ms: f64,
    /// steady-state decode rate after the first token (NaN for 1-token runs)
    pub decode_tok_per_sec: f64,
    /// scheduler step at which the request entered a lane / left it
    pub admitted_step: u64,
    pub finished_step: u64,
    /// set when the request was rejected at admission (bad prompt, cache
    /// exhaustion); the run itself survives and serves everything else
    pub error: Option<String>,
}

impl GenResult {
    pub fn generated(&self) -> &[i32] {
        &self.tokens[self.prompt_len..]
    }
}

/// Bounded MPSC admission queue: producers block when the queue is full
/// (backpressure), the scheduler polls it every step.
pub struct AdmissionQueue {
    cap: usize,
    inner: Mutex<QueueInner>,
    space: Condvar,
    avail: Condvar,
}

struct QueueInner {
    q: VecDeque<GenRequest>,
    closed: bool,
}

impl AdmissionQueue {
    pub fn new(cap: usize) -> AdmissionQueue {
        AdmissionQueue {
            cap: cap.max(1),
            inner: Mutex::new(QueueInner { q: VecDeque::new(), closed: false }),
            space: Condvar::new(),
            avail: Condvar::new(),
        }
    }

    /// Enqueue a request, blocking while the queue is at capacity.
    /// Fails once the queue is closed.
    pub fn submit(&self, req: GenRequest) -> Result<()> {
        ensure!(!req.prompt.is_empty(), "empty prompt");
        let mut g = self.inner.lock().unwrap();
        while g.q.len() >= self.cap && !g.closed {
            g = self.space.wait(g).unwrap();
        }
        if g.closed {
            bail!("admission queue is closed");
        }
        g.q.push_back(req);
        crate::obs::add(crate::obs::Counter::ServeEnqueued, 1);
        self.avail.notify_one();
        Ok(())
    }

    pub fn try_pop(&self) -> Option<GenRequest> {
        let mut g = self.inner.lock().unwrap();
        let r = g.q.pop_front();
        if r.is_some() {
            self.space.notify_one();
        }
        r
    }

    /// No more submissions; the scheduler drains what is left and stops.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.space.notify_all();
        self.avail.notify_all();
    }

    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn is_drained(&self) -> bool {
        let g = self.inner.lock().unwrap();
        g.closed && g.q.is_empty()
    }

    /// Park until a request is available or the queue closes (bounded by
    /// `timeout` so the scheduler can re-check its own state).
    pub fn wait_nonempty(&self, timeout: Duration) {
        let g = self.inner.lock().unwrap();
        if g.q.is_empty() && !g.closed {
            let _ = self.avail.wait_timeout(g, timeout).unwrap();
        }
    }
}

/// A scheduler running on its own worker thread, sharing the admission
/// queue with any number of producer threads — the multi-threaded shape of
/// the engine (and the proof the serve types are `Send`-sound).
pub struct ServeHandle {
    queue: Arc<AdmissionQueue>,
    worker: std::thread::JoinHandle<Result<(Vec<GenResult>, ServeStats)>>,
}

impl ServeHandle {
    /// Spawn a scheduler over `backend` with `lanes` batch lanes and an
    /// admission queue of `queue_cap` entries.
    pub fn spawn<B>(backend: B, lanes: usize, queue_cap: usize) -> Result<ServeHandle>
    where
        B: DecodeBackend + Send + 'static,
    {
        /// Closes the queue when the worker exits — by return, error or
        /// panic — so producers blocked in `submit` always wake up and get
        /// an error instead of deadlocking on a dead scheduler.
        struct CloseOnExit(Arc<AdmissionQueue>);
        impl Drop for CloseOnExit {
            fn drop(&mut self) {
                self.0.close();
            }
        }

        let mut sched = Scheduler::new(backend, lanes)?;
        let queue = Arc::new(AdmissionQueue::new(queue_cap));
        let q = queue.clone();
        let worker = std::thread::spawn(move || {
            let _guard = CloseOnExit(q.clone());
            let mut stats = ServeStats::new(lanes);
            let results = sched.run(&q, &mut stats)?;
            Ok((results, stats))
        });
        Ok(ServeHandle { queue, worker })
    }

    /// The shared queue — clone the `Arc` into producer threads.
    pub fn queue(&self) -> Arc<AdmissionQueue> {
        self.queue.clone()
    }

    /// Close the queue, wait for the drain, and return results + stats.
    pub fn finish(self) -> Result<(Vec<GenResult>, ServeStats)> {
        self.queue.close();
        match self.worker.join() {
            Ok(r) => r,
            Err(_) => bail!("serve worker panicked"),
        }
    }
}

/// Run a scheduler to completion on the current thread (single-threaded
/// callers: examples, benches, the artifact backend whose literals are not
/// `Send`).
pub fn serve_inline<B: DecodeBackend>(
    backend: B,
    lanes: usize,
    requests: Vec<GenRequest>,
) -> Result<(Vec<GenResult>, ServeStats)> {
    let queue = AdmissionQueue::new(requests.len().max(1));
    for r in requests {
        queue.submit(r)?;
    }
    queue.close();
    let mut sched = Scheduler::new(backend, lanes)?;
    let mut stats = ServeStats::new(lanes);
    let results = sched.run(&queue, &mut stats)?;
    Ok((results, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_blocks_and_closes() {
        let q = AdmissionQueue::new(1);
        q.submit(GenRequest::new(1, vec![1], 1)).unwrap();
        assert_eq!(q.depth(), 1);
        assert!(!q.is_drained());
        q.close();
        assert!(q.submit(GenRequest::new(2, vec![1], 1)).is_err());
        assert!(q.try_pop().is_some());
        assert!(q.is_drained());
    }

    #[test]
    fn queue_rejects_empty_prompt() {
        let q = AdmissionQueue::new(4);
        assert!(q.submit(GenRequest::new(1, vec![], 1)).is_err());
    }

    #[test]
    fn backpressure_unblocks_on_pop() {
        let q = Arc::new(AdmissionQueue::new(1));
        q.submit(GenRequest::new(1, vec![1], 1)).unwrap();
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.submit(GenRequest::new(2, vec![1], 1)));
        std::thread::sleep(Duration::from_millis(20));
        assert!(q.try_pop().is_some()); // frees space, unblocks the producer
        t.join().unwrap().unwrap();
        assert_eq!(q.depth(), 1);
    }
}
