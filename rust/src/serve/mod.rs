//! `serve` — the continuous-batching inference engine.
//!
//! Pipeline shape (see README §serving):
//!
//! ```text
//!   producers -> AdmissionQueue (bounded, blocking)
//!                   |
//!             Scheduler: evict finished / admit queued / step   (scheduler.rs)
//!                   |
//!             DecodeBackend: ArtifactBackend (PJRT full-sequence)  (backend.rs)
//!                            HostBackend (cross-lane batched decode
//!                            over the KvPool — one fused GEMM per
//!                            weight matrix per step across all lanes)
//!                   |
//!             hostmodel::KvPool: slab K/V cache, INT8 quantize-on-write
//!                   |
//!             ServeStats: TTFT / tok/s / queue depth / occupancy  (stats.rs)
//! ```
//!
//! The transformer forwards behind both backends live in
//! [`crate::hostmodel`] (host quantized model + KV pool) and
//! [`crate::forward`] (the shared `ForwardBackend` abstraction); this
//! module only owns the serving mechanics — queueing, lane scheduling and
//! latency accounting.
//!
//! Clients reach the engine two ways: in-process load-generator threads
//! (`silq serve` drives itself), or over real sockets through the
//! [`crate::net`] HTTP front-end (`silq serve --listen ADDR`). Both sit on
//! the same queue/scheduler/pool layering; the wire path additionally
//! threads a per-token [`TokenSink`] and a cancellation flag through
//! [`GenRequest`] so tokens stream out as they decode and a client
//! disconnect frees the lane (and its KV slot) mid-decode.

pub mod backend;
pub mod health;
pub mod scheduler;
pub mod session;
pub mod stats;

pub use backend::{ArtifactBackend, DecodeBackend, HostBackend};
pub use health::HealthState;
pub use scheduler::Scheduler;
pub use stats::ServeStats;

// the pool and host config moved to `hostmodel`; re-exported here because
// they are part of the serve construction surface
pub use crate::hostmodel::{CacheStore, HostCfg, KvPool, QuantRule};

use anyhow::{bail, ensure, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Per-token delivery from the scheduler to a streaming client. The
/// scheduler pushes one [`StreamEvent::Token`] per generated token and
/// exactly one [`StreamEvent::Done`] when the request leaves its lane —
/// completed, rejected at admission, or cancelled. Senders never block
/// (the channel is unbounded) and a hung or vanished receiver never stalls
/// the decode loop: send failures are ignored.
#[derive(Debug, Clone)]
pub enum StreamEvent {
    /// One generated token, in decode order.
    Token(i32),
    /// Terminal event: the request's full result (also returned from the
    /// scheduler's result vector; `error` distinguishes reject/cancel).
    Done(GenResult),
}

/// The sending half a streaming client attaches via
/// [`GenRequest::with_sink`].
pub type TokenSink = std::sync::mpsc::Sender<StreamEvent>;

/// Scheduling class of a request. The admission queue serves
/// [`Priority::Interactive`] strictly before [`Priority::Batch`], FIFO
/// within each class — latency-sensitive traffic never queues behind
/// bulk work, while bulk work keeps draining whenever no interactive
/// request is waiting.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Priority {
    /// latency-sensitive (the default): served first
    #[default]
    Interactive,
    /// throughput traffic: served when no interactive request waits
    Batch,
}

impl Priority {
    /// Stable wire name (`priority` field of `POST /v1/completions`).
    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }

    /// Parse a wire name.
    pub fn parse(s: &str) -> std::result::Result<Priority, String> {
        match s {
            "interactive" => Ok(Priority::Interactive),
            "batch" => Ok(Priority::Batch),
            other => Err(format!("unknown priority `{other}` (interactive|batch)")),
        }
    }
}

/// One generation request as submitted by a client.
#[derive(Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    /// token budget for the completion
    pub max_new: usize,
    /// stop at EOS (default); load generators and latency tests turn this
    /// off so every request decodes its full budget deterministically
    pub stop_on_eos: bool,
    pub submitted: Instant,
    /// scheduling class (see [`Priority`]; default interactive)
    pub priority: Priority,
    /// shed the request if it is still queued at this instant — it will
    /// never be admitted, and the client gets a typed timeout (503 +
    /// `Retry-After` on the wire) instead of a first token that arrives
    /// too late to matter
    pub ttft_deadline: Option<Instant>,
    /// evict the request if it is still decoding at this instant; the
    /// partial completion is delivered with `reason: "deadline"`
    pub deadline: Option<Instant>,
    /// streaming delivery: every generated token (and the terminal result)
    /// is sent here as it happens; `None` for buffered requests
    pub sink: Option<TokenSink>,
    /// cooperative cancellation: when set to `true` (client disconnect),
    /// the scheduler evicts the session at the next step boundary, freeing
    /// the lane and its KV slot mid-decode
    pub cancel: Option<Arc<AtomicBool>>,
}

impl GenRequest {
    pub fn new(id: u64, prompt: Vec<i32>, max_new: usize) -> GenRequest {
        GenRequest {
            id,
            prompt,
            max_new,
            stop_on_eos: true,
            submitted: Instant::now(),
            priority: Priority::Interactive,
            ttft_deadline: None,
            deadline: None,
            sink: None,
            cancel: None,
        }
    }

    /// Decode the full `max_new` budget even if the model emits EOS.
    pub fn ignore_eos(mut self) -> GenRequest {
        self.stop_on_eos = false;
        self
    }

    /// Stream tokens (and the terminal result) into `sink` as they decode.
    pub fn with_sink(mut self, sink: TokenSink) -> GenRequest {
        self.sink = Some(sink);
        self
    }

    /// Attach a cancellation flag; setting it evicts the session at the
    /// next scheduler step.
    pub fn with_cancel(mut self, flag: Arc<AtomicBool>) -> GenRequest {
        self.cancel = Some(flag);
        self
    }

    /// Set the scheduling class.
    pub fn with_priority(mut self, p: Priority) -> GenRequest {
        self.priority = p;
        self
    }

    /// Shed the request unless it is admitted within `ms` of submission.
    pub fn with_ttft_deadline_ms(mut self, ms: u64) -> GenRequest {
        self.ttft_deadline = Some(self.submitted + Duration::from_millis(ms));
        self
    }

    /// Evict the request unless it finishes within `ms` of submission.
    pub fn with_deadline_ms(mut self, ms: u64) -> GenRequest {
        self.deadline = Some(self.submitted + Duration::from_millis(ms));
        self
    }

    /// Has the TTFT deadline already passed (shed instead of admit)?
    pub fn ttft_deadline_expired(&self) -> bool {
        self.ttft_deadline.is_some_and(|d| Instant::now() >= d)
    }
}

/// How a request left the engine — the typed terminal outcome behind
/// [`GenResult::error`]. Every request gets exactly one.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FinishReason {
    /// decoded to its budget / EOS / context window
    #[default]
    Completed,
    /// refused at admission (bad prompt, KV exhaustion)
    Rejected,
    /// evicted mid-decode by the client's cancellation flag
    Cancelled,
    /// shed while queued: its TTFT deadline passed before a lane freed
    DeadlineShed,
    /// evicted mid-decode: its completion deadline passed
    DeadlineEvicted,
}

impl FinishReason {
    /// Stable wire name (the `reason` field of a terminal frame).
    pub fn name(self) -> &'static str {
        match self {
            FinishReason::Completed => "ok",
            FinishReason::Rejected => "rejected",
            FinishReason::Cancelled => "cancelled",
            FinishReason::DeadlineShed => "deadline_shed",
            FinishReason::DeadlineEvicted => "deadline",
        }
    }
}

/// One finished request with its latency breakdown.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub id: u64,
    pub prompt_len: usize,
    /// prompt followed by the generated completion
    pub tokens: Vec<i32>,
    /// submit -> admission (time spent in the queue)
    pub queued_ms: f64,
    /// submit -> first generated token
    pub ttft_ms: f64,
    /// submit -> completion
    pub total_ms: f64,
    /// steady-state decode rate after the first token (NaN for 1-token runs)
    pub decode_tok_per_sec: f64,
    /// scheduler step at which the request entered a lane / left it
    pub admitted_step: u64,
    pub finished_step: u64,
    /// set when the request was rejected at admission (bad prompt, cache
    /// exhaustion) or cancelled mid-decode (client disconnect); the run
    /// itself survives and serves everything else
    pub error: Option<String>,
    /// typed terminal outcome (`error` carries the human-readable detail)
    pub reason: FinishReason,
}

impl GenResult {
    pub fn generated(&self) -> &[i32] {
        &self.tokens[self.prompt_len..]
    }
}

/// Why a non-blocking [`AdmissionQueue::try_submit`] did not enqueue. The
/// `Full`/`Closed` variants hand the request back so the caller can retry
/// or answer the client without rebuilding it — the HTTP layer maps them
/// to `429 Too Many Requests` and `503 Service Unavailable`.
#[derive(Debug)]
pub enum SubmitError {
    /// The queue is at capacity right now (transient: retry later).
    Full {
        /// the request, handed back intact
        req: GenRequest,
        /// how long the caller should wait before retrying — current
        /// queue depth × recent mean step time (the wire layer turns
        /// this into a `Retry-After` header)
        retry_after_ms: u64,
    },
    /// The queue is closed — the server is draining; no retry will succeed.
    Closed(GenRequest),
    /// The request can never be accepted (empty prompt).
    Invalid {
        /// id of the rejected request
        id: u64,
        /// what was wrong with it
        reason: String,
    },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Full { req, retry_after_ms } => write!(
                f,
                "admission queue is full (request {}, retry in {retry_after_ms} ms)",
                req.id
            ),
            SubmitError::Closed(r) => write!(f, "admission queue is closed (request {})", r.id),
            SubmitError::Invalid { id, reason } => write!(f, "invalid request {id}: {reason}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Bounded MPSC admission queue: producers block when the queue is full
/// (backpressure), the scheduler polls it every step.
pub struct AdmissionQueue {
    cap: usize,
    inner: Mutex<QueueInner>,
    space: Condvar,
    avail: Condvar,
}

struct QueueInner {
    /// one FIFO per scheduling class, so `try_pop` is O(1): the old single
    /// deque paid an O(n) priority `position` scan per pop under the queue
    /// lock — quadratic across the drain of a deep batch backlog
    interactive: VecDeque<GenRequest>,
    batch: VecDeque<GenRequest>,
    closed: bool,
}

impl QueueInner {
    fn len(&self) -> usize {
        self.interactive.len() + self.batch.len()
    }

    fn push(&mut self, req: GenRequest) {
        match req.priority {
            Priority::Interactive => self.interactive.push_back(req),
            Priority::Batch => self.batch.push_back(req),
        }
    }
}

impl AdmissionQueue {
    pub fn new(cap: usize) -> AdmissionQueue {
        AdmissionQueue {
            cap: cap.max(1),
            inner: Mutex::new(QueueInner {
                interactive: VecDeque::new(),
                batch: VecDeque::new(),
                closed: false,
            }),
            space: Condvar::new(),
            avail: Condvar::new(),
        }
    }

    /// Enqueue a request, blocking while the queue is at capacity.
    /// Fails once the queue is closed.
    pub fn submit(&self, req: GenRequest) -> Result<()> {
        ensure!(!req.prompt.is_empty(), "empty prompt");
        let mut g = self.inner.lock().unwrap();
        while g.len() >= self.cap && !g.closed {
            g = self.space.wait(g).unwrap();
        }
        if g.closed {
            bail!("admission queue is closed");
        }
        g.push(req);
        crate::obs::add(crate::obs::Counter::ServeEnqueued, 1);
        self.avail.notify_one();
        Ok(())
    }

    /// Non-blocking submit: enqueue if there is space, otherwise return a
    /// typed error **with the request inside** instead of blocking the
    /// producer. This is the socket-facing entry point — a full queue must
    /// become backpressure on the wire (429), not a stalled connection
    /// handler.
    pub fn try_submit(&self, req: GenRequest) -> std::result::Result<(), SubmitError> {
        if req.prompt.is_empty() {
            return Err(SubmitError::Invalid { id: req.id, reason: "empty prompt".into() });
        }
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(SubmitError::Closed(req));
        }
        if g.len() >= self.cap || crate::faults::should_inject(crate::faults::Site::Submit) {
            let retry_after_ms = health::retry_after_ms(g.len());
            return Err(SubmitError::Full { req, retry_after_ms });
        }
        g.push(req);
        crate::obs::add(crate::obs::Counter::ServeEnqueued, 1);
        self.avail.notify_one();
        Ok(())
    }

    /// Dequeue the next request by scheduling class: the earliest
    /// interactive request if any is waiting, else the earliest batch
    /// request — strict priority, FIFO within a class.
    pub fn try_pop(&self) -> Option<GenRequest> {
        let mut g = self.inner.lock().unwrap();
        let r = g.interactive.pop_front().or_else(|| g.batch.pop_front());
        if r.is_some() {
            self.space.notify_one();
        }
        r
    }

    /// No more submissions; the scheduler drains what is left and stops.
    /// From here `/healthz` reports `draining`.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        health::set_draining();
        self.space.notify_all();
        self.avail.notify_all();
    }

    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_drained(&self) -> bool {
        let g = self.inner.lock().unwrap();
        g.closed && g.len() == 0
    }

    /// Park until a request is available or the queue closes (bounded by
    /// `timeout` so the scheduler can re-check its own state).
    pub fn wait_nonempty(&self, timeout: Duration) {
        let g = self.inner.lock().unwrap();
        if g.len() == 0 && !g.closed {
            let _ = self.avail.wait_timeout(g, timeout).unwrap();
        }
    }
}

/// Everything a drained scheduler worker hands back: completion-ordered
/// results, the run's stats, and the backend itself (so callers can assert
/// the shutdown invariants — every KV slot free, nothing resident).
pub type ServeOutcome<B> = (Vec<GenResult>, ServeStats, B);

/// A scheduler running on its own worker thread, sharing the admission
/// queue with any number of producer threads — the multi-threaded shape of
/// the engine (and the proof the serve types are `Send`-sound).
pub struct ServeHandle<B: DecodeBackend + Send + 'static> {
    queue: Arc<AdmissionQueue>,
    worker: std::thread::JoinHandle<Result<ServeOutcome<B>>>,
}

impl<B: DecodeBackend + Send + 'static> ServeHandle<B> {
    /// Spawn a scheduler over `backend` with `lanes` batch lanes and an
    /// admission queue of `queue_cap` entries.
    pub fn spawn(backend: B, lanes: usize, queue_cap: usize) -> Result<ServeHandle<B>> {
        /// Closes the queue when the worker exits — by return, error or
        /// panic — so producers blocked in `submit` always wake up and get
        /// an error instead of deadlocking on a dead scheduler.
        struct CloseOnExit(Arc<AdmissionQueue>);
        impl Drop for CloseOnExit {
            fn drop(&mut self) {
                self.0.close();
            }
        }

        let mut sched = Scheduler::new(backend, lanes)?;
        let queue = Arc::new(AdmissionQueue::new(queue_cap));
        let q = queue.clone();
        let worker = std::thread::spawn(move || {
            let _guard = CloseOnExit(q.clone());
            let mut stats = ServeStats::new(lanes);
            let results = sched.run(&q, &mut stats)?;
            Ok((results, stats, sched.into_backend()))
        });
        Ok(ServeHandle { queue, worker })
    }

    /// The shared queue — clone the `Arc` into producer threads.
    pub fn queue(&self) -> Arc<AdmissionQueue> {
        self.queue.clone()
    }

    /// Close the queue, wait for the drain, and return results + stats.
    pub fn finish(self) -> Result<(Vec<GenResult>, ServeStats)> {
        self.finish_all().map(|(results, stats, _)| (results, stats))
    }

    /// Like [`ServeHandle::finish`], but also hand back the drained
    /// backend so shutdown invariants (`all_slots_free`, zero resident KV
    /// bytes) can be asserted after the run.
    pub fn finish_all(self) -> Result<ServeOutcome<B>> {
        self.queue.close();
        match self.worker.join() {
            Ok(r) => r,
            Err(_) => bail!("serve worker panicked"),
        }
    }
}

/// Run a scheduler to completion on the current thread (single-threaded
/// callers: examples, benches, the artifact backend whose literals are not
/// `Send`).
pub fn serve_inline<B: DecodeBackend>(
    backend: B,
    lanes: usize,
    requests: Vec<GenRequest>,
) -> Result<(Vec<GenResult>, ServeStats)> {
    let queue = AdmissionQueue::new(requests.len().max(1));
    for r in requests {
        queue.submit(r)?;
    }
    queue.close();
    let mut sched = Scheduler::new(backend, lanes)?;
    let mut stats = ServeStats::new(lanes);
    let results = sched.run(&queue, &mut stats)?;
    Ok((results, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_blocks_and_closes() {
        let q = AdmissionQueue::new(1);
        q.submit(GenRequest::new(1, vec![1], 1)).unwrap();
        assert_eq!(q.depth(), 1);
        assert!(!q.is_drained());
        q.close();
        assert!(q.submit(GenRequest::new(2, vec![1], 1)).is_err());
        assert!(q.try_pop().is_some());
        assert!(q.is_drained());
    }

    #[test]
    fn queue_rejects_empty_prompt() {
        let q = AdmissionQueue::new(4);
        assert!(q.submit(GenRequest::new(1, vec![], 1)).is_err());
    }

    #[test]
    fn try_submit_maps_full_closed_and_invalid() {
        let q = AdmissionQueue::new(1);
        q.try_submit(GenRequest::new(1, vec![1], 1)).unwrap();
        // full: the request comes back intact for a retry / 429 answer,
        // with a positive retry estimate riding along
        match q.try_submit(GenRequest::new(2, vec![7, 8], 3)) {
            Err(SubmitError::Full { req, retry_after_ms }) => {
                assert_eq!((req.id, req.max_new), (2, 3));
                assert_eq!(req.prompt, vec![7, 8]);
                assert!(retry_after_ms >= 1, "retry estimate must be positive");
            }
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(q.depth(), 1, "a failed try_submit must not enqueue");
        // space frees -> accepted again
        assert!(q.try_pop().is_some());
        q.try_submit(GenRequest::new(3, vec![1], 1)).unwrap();
        // closed wins over full and over space alike
        q.close();
        match q.try_submit(GenRequest::new(4, vec![1], 1)) {
            Err(SubmitError::Closed(r)) => assert_eq!(r.id, 4),
            other => panic!("expected Closed, got {other:?}"),
        }
        // invalid is terminal: no request to hand back, just the reason
        let q2 = AdmissionQueue::new(1);
        match q2.try_submit(GenRequest::new(5, vec![], 1)) {
            Err(SubmitError::Invalid { id, reason }) => {
                assert_eq!(id, 5);
                assert!(reason.contains("empty"));
            }
            other => panic!("expected Invalid, got {other:?}"),
        }
    }

    #[test]
    fn try_submit_race_never_overfills_or_loses() {
        // several threads hammer try_submit against a tiny queue while a
        // consumer drains it: the cap must hold at every instant and every
        // accepted request must come out exactly once
        let cap = 3;
        let q = Arc::new(AdmissionQueue::new(cap));
        let accepted = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let submitters: Vec<_> = (0..4u64)
            .map(|t| {
                let q = q.clone();
                let accepted = accepted.clone();
                std::thread::spawn(move || {
                    for i in 0..200u64 {
                        match q.try_submit(GenRequest::new(t * 1000 + i, vec![1], 1)) {
                            Ok(()) => {
                                accepted.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(SubmitError::Full { .. }) => std::thread::yield_now(),
                            Err(e) => panic!("unexpected submit error: {e}"),
                        }
                    }
                })
            })
            .collect();
        let consumer = {
            let q = q.clone();
            std::thread::spawn(move || {
                let mut drained = 0u64;
                loop {
                    assert!(q.depth() <= cap, "queue overfilled under racing try_submit");
                    match q.try_pop() {
                        Some(_) => drained += 1,
                        None if q.is_drained() => break drained,
                        None => std::thread::yield_now(),
                    }
                }
            })
        };
        for t in submitters {
            t.join().unwrap();
        }
        q.close();
        let drained = consumer.join().unwrap();
        assert_eq!(drained, accepted.load(Ordering::Relaxed), "accepted != drained");
    }

    #[test]
    fn pop_serves_interactive_before_batch_fifo_within_class() {
        let q = AdmissionQueue::new(8);
        // submit order: batch 1, batch 2, interactive 3, interactive 4
        q.submit(GenRequest::new(1, vec![1], 1).with_priority(Priority::Batch)).unwrap();
        q.submit(GenRequest::new(2, vec![1], 1).with_priority(Priority::Batch)).unwrap();
        q.submit(GenRequest::new(3, vec![1], 1)).unwrap();
        q.submit(GenRequest::new(4, vec![1], 1).with_priority(Priority::Interactive)).unwrap();
        let order: Vec<u64> = std::iter::from_fn(|| q.try_pop()).map(|r| r.id).collect();
        assert_eq!(order, [3, 4, 1, 2], "interactive first, FIFO within class");
    }

    #[test]
    fn deadline_builders_and_expiry() {
        let r = GenRequest::new(1, vec![1], 4);
        assert!(!r.ttft_deadline_expired(), "no deadline never expires");
        assert_eq!(r.priority, Priority::Interactive, "interactive is the default");
        let r = GenRequest::new(2, vec![1], 4).with_ttft_deadline_ms(0).with_deadline_ms(0);
        assert!(r.ttft_deadline_expired(), "0 ms TTFT deadline is already over");
        assert!(r.deadline.is_some());
        let r = GenRequest::new(3, vec![1], 4).with_ttft_deadline_ms(60_000);
        assert!(!r.ttft_deadline_expired(), "a generous deadline has not passed");
        assert_eq!(Priority::parse("batch"), Ok(Priority::Batch));
        assert!(Priority::parse("urgent").is_err());
    }

    #[test]
    fn backpressure_unblocks_on_pop() {
        let q = Arc::new(AdmissionQueue::new(1));
        q.submit(GenRequest::new(1, vec![1], 1)).unwrap();
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.submit(GenRequest::new(2, vec![1], 1)));
        std::thread::sleep(Duration::from_millis(20));
        assert!(q.try_pop().is_some()); // frees space, unblocks the producer
        t.join().unwrap().unwrap();
        assert_eq!(q.depth(), 1);
    }
}
