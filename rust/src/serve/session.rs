//! One in-flight generation session: a request bound to a scheduler lane.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::data::vocab::EOS;
use crate::serve::{FinishReason, GenRequest, GenResult, StreamEvent, TokenSink};

/// State of one admitted request while it occupies a lane.
#[derive(Debug)]
pub struct Session {
    pub id: u64,
    pub prompt_len: usize,
    /// prompt followed by generated tokens
    pub tokens: Vec<i32>,
    pub max_new: usize,
    pub stop_on_eos: bool,
    pub submitted: Instant,
    pub admitted: Instant,
    pub admitted_step: u64,
    pub first_token: Option<Instant>,
    /// time-to-first-token, stamped **at the first emitted token** (not
    /// retroactively at completion) so streaming latency is honest; `None`
    /// until then (and forever, for zero-budget/rejected requests)
    pub ttft_ms: Option<f64>,
    /// evict at this instant if still decoding, carried from the request
    pub deadline: Option<Instant>,
    /// streaming delivery target (client sink), carried from the request
    pub sink: Option<TokenSink>,
    /// cooperative cancellation flag, carried from the request
    pub cancel: Option<Arc<AtomicBool>>,
}

impl Session {
    pub fn admit(req: GenRequest, step: u64) -> Session {
        Session {
            id: req.id,
            prompt_len: req.prompt.len(),
            tokens: req.prompt,
            max_new: req.max_new,
            stop_on_eos: req.stop_on_eos,
            submitted: req.submitted,
            admitted: Instant::now(),
            admitted_step: step,
            first_token: None,
            ttft_ms: None,
            deadline: req.deadline,
            sink: req.sink,
            cancel: req.cancel,
        }
    }

    pub fn generated(&self) -> &[i32] {
        &self.tokens[self.prompt_len..]
    }

    /// Record one generated token: stamps time-to-first-token once (at
    /// emission time, from the same instant stored in `first_token`, so
    /// the value is bit-identical to the old compute-at-completion
    /// accounting) and streams the token to the sink when one is attached.
    pub fn push(&mut self, tok: i32) {
        if self.first_token.is_none() {
            let now = Instant::now();
            self.first_token = Some(now);
            self.ttft_ms = Some(now.duration_since(self.submitted).as_secs_f64() * 1e3);
        }
        self.tokens.push(tok);
        if let Some(sink) = &self.sink {
            // a vanished receiver must never stall the decode loop
            let _ = sink.send(StreamEvent::Token(tok));
        }
    }

    /// Whether the client asked for this session to be torn down (socket
    /// disconnect); the scheduler checks this every step boundary.
    pub fn cancelled(&self) -> bool {
        self.cancel.as_ref().is_some_and(|c| c.load(Ordering::Relaxed))
    }

    /// Whether the session's completion deadline has passed — the
    /// scheduler evicts it at the next step boundary with
    /// `reason: "deadline"`, delivering whatever decoded so far.
    pub fn deadline_exceeded(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }

    /// A session is done when it hit its token budget, emitted EOS, or
    /// filled the model's context window.
    pub fn done(&self, seq_len: usize) -> bool {
        self.generated().len() >= self.max_new
            || (self.stop_on_eos && self.generated().last() == Some(&EOS))
            || self.tokens.len() >= seq_len
    }

    pub fn into_result(self, finished_step: u64) -> GenResult {
        let now = Instant::now();
        let new_tokens = self.tokens.len() - self.prompt_len;
        let decode_secs = self
            .first_token
            .map(|t| now.duration_since(t).as_secs_f64())
            .unwrap_or(0.0);
        GenResult {
            id: self.id,
            prompt_len: self.prompt_len,
            tokens: self.tokens,
            queued_ms: self.admitted.duration_since(self.submitted).as_secs_f64() * 1e3,
            ttft_ms: self.ttft_ms.unwrap_or(f64::NAN),
            total_ms: now.duration_since(self.submitted).as_secs_f64() * 1e3,
            decode_tok_per_sec: if decode_secs > 0.0 && new_tokens > 1 {
                (new_tokens - 1) as f64 / decode_secs
            } else {
                f64::NAN
            },
            admitted_step: self.admitted_step,
            finished_step,
            error: None,
            reason: FinishReason::Completed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt: Vec<i32>, max_new: usize) -> GenRequest {
        GenRequest::new(id, prompt, max_new)
    }

    #[test]
    fn done_conditions() {
        let mut s = Session::admit(req(1, vec![1, 3], 2), 0);
        assert!(!s.done(64));
        s.push(40);
        assert!(!s.done(64));
        s.push(41);
        assert!(s.done(64)); // budget
        let mut s = Session::admit(req(2, vec![1], 8), 0);
        s.push(EOS);
        assert!(s.done(64)); // eos
        let mut s = Session::admit(req(2, vec![1], 8).ignore_eos(), 0);
        s.push(EOS);
        assert!(!s.done(64)); // load-generator mode decodes through EOS
        let mut s = Session::admit(req(3, vec![1, 2, 3], 8), 0);
        s.push(9);
        assert!(s.done(4)); // context window
    }

    #[test]
    fn result_accounting() {
        let mut s = Session::admit(req(7, vec![1, 3, 5], 4), 2);
        s.push(10);
        s.push(11);
        let r = s.into_result(9);
        assert_eq!(r.id, 7);
        assert_eq!(r.generated(), &[10, 11]);
        assert_eq!((r.admitted_step, r.finished_step), (2, 9));
        assert!(r.ttft_ms >= 0.0 && r.total_ms >= r.ttft_ms);
    }

    #[test]
    fn ttft_is_stamped_at_first_push_and_survives_into_the_result() {
        let mut s = Session::admit(req(1, vec![1, 2], 4), 0);
        assert!(s.ttft_ms.is_none());
        s.push(9);
        let at_first = s.ttft_ms.expect("first push must stamp ttft");
        s.push(10);
        assert_eq!(s.ttft_ms, Some(at_first), "later pushes must not restamp");
        // bit-equal to the first_token-instant accounting by construction
        let from_instant =
            s.first_token.unwrap().duration_since(s.submitted).as_secs_f64() * 1e3;
        assert_eq!(at_first.to_bits(), from_instant.to_bits());
        let r = s.into_result(1);
        assert_eq!(r.ttft_ms.to_bits(), at_first.to_bits());
    }

    #[test]
    fn sink_receives_every_token_in_order() {
        let (tx, rx) = std::sync::mpsc::channel();
        let mut s = Session::admit(req(2, vec![1], 3).with_sink(tx), 0);
        s.push(5);
        s.push(6);
        s.push(7);
        let got: Vec<i32> = rx
            .try_iter()
            .map(|ev| match ev {
                StreamEvent::Token(t) => t,
                other => panic!("unexpected event {other:?}"),
            })
            .collect();
        assert_eq!(got, vec![5, 6, 7]);
        // a dropped receiver must not panic later pushes
        let (tx, rx) = std::sync::mpsc::channel();
        let mut s = Session::admit(req(3, vec![1], 2).with_sink(tx), 0);
        drop(rx);
        s.push(9);
        assert_eq!(s.generated(), &[9]);
    }

    #[test]
    fn cancel_flag_reads_through() {
        let flag = Arc::new(AtomicBool::new(false));
        let s = Session::admit(req(4, vec![1], 2).with_cancel(flag.clone()), 0);
        assert!(!s.cancelled());
        flag.store(true, Ordering::Relaxed);
        assert!(s.cancelled());
        // no flag attached -> never cancelled
        assert!(!Session::admit(req(5, vec![1], 2), 0).cancelled());
    }

    #[test]
    fn deadline_reads_through() {
        let s = Session::admit(req(6, vec![1], 2).with_deadline_ms(0), 0);
        assert!(s.deadline_exceeded(), "0 ms deadline is already over");
        let s = Session::admit(req(7, vec![1], 2).with_deadline_ms(60_000), 0);
        assert!(!s.deadline_exceeded());
        // no deadline attached -> never exceeded
        assert!(!Session::admit(req(8, vec![1], 2), 0).deadline_exceeded());
    }
}
