//! One in-flight generation session: a request bound to a scheduler lane.

use std::time::Instant;

use crate::data::vocab::EOS;
use crate::serve::{GenRequest, GenResult};

/// State of one admitted request while it occupies a lane.
#[derive(Debug)]
pub struct Session {
    pub id: u64,
    pub prompt_len: usize,
    /// prompt followed by generated tokens
    pub tokens: Vec<i32>,
    pub max_new: usize,
    pub stop_on_eos: bool,
    pub submitted: Instant,
    pub admitted: Instant,
    pub admitted_step: u64,
    pub first_token: Option<Instant>,
}

impl Session {
    pub fn admit(req: GenRequest, step: u64) -> Session {
        Session {
            id: req.id,
            prompt_len: req.prompt.len(),
            tokens: req.prompt,
            max_new: req.max_new,
            stop_on_eos: req.stop_on_eos,
            submitted: req.submitted,
            admitted: Instant::now(),
            admitted_step: step,
            first_token: None,
        }
    }

    pub fn generated(&self) -> &[i32] {
        &self.tokens[self.prompt_len..]
    }

    /// Record one generated token (stamps time-to-first-token once).
    pub fn push(&mut self, tok: i32) {
        if self.first_token.is_none() {
            self.first_token = Some(Instant::now());
        }
        self.tokens.push(tok);
    }

    /// A session is done when it hit its token budget, emitted EOS, or
    /// filled the model's context window.
    pub fn done(&self, seq_len: usize) -> bool {
        self.generated().len() >= self.max_new
            || (self.stop_on_eos && self.generated().last() == Some(&EOS))
            || self.tokens.len() >= seq_len
    }

    pub fn into_result(self, finished_step: u64) -> GenResult {
        let now = Instant::now();
        let new_tokens = self.tokens.len() - self.prompt_len;
        let ttft_ms = self
            .first_token
            .map(|t| t.duration_since(self.submitted).as_secs_f64() * 1e3)
            .unwrap_or(f64::NAN);
        let decode_secs = self
            .first_token
            .map(|t| now.duration_since(t).as_secs_f64())
            .unwrap_or(0.0);
        GenResult {
            id: self.id,
            prompt_len: self.prompt_len,
            tokens: self.tokens,
            queued_ms: self.admitted.duration_since(self.submitted).as_secs_f64() * 1e3,
            ttft_ms,
            total_ms: now.duration_since(self.submitted).as_secs_f64() * 1e3,
            decode_tok_per_sec: if decode_secs > 0.0 && new_tokens > 1 {
                (new_tokens - 1) as f64 / decode_secs
            } else {
                f64::NAN
            },
            admitted_step: self.admitted_step,
            finished_step,
            error: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt: Vec<i32>, max_new: usize) -> GenRequest {
        GenRequest::new(id, prompt, max_new)
    }

    #[test]
    fn done_conditions() {
        let mut s = Session::admit(req(1, vec![1, 3], 2), 0);
        assert!(!s.done(64));
        s.push(40);
        assert!(!s.done(64));
        s.push(41);
        assert!(s.done(64)); // budget
        let mut s = Session::admit(req(2, vec![1], 8), 0);
        s.push(EOS);
        assert!(s.done(64)); // eos
        let mut s = Session::admit(req(2, vec![1], 8).ignore_eos(), 0);
        s.push(EOS);
        assert!(!s.done(64)); // load-generator mode decodes through EOS
        let mut s = Session::admit(req(3, vec![1, 2, 3], 8), 0);
        s.push(9);
        assert!(s.done(4)); // context window
    }

    #[test]
    fn result_accounting() {
        let mut s = Session::admit(req(7, vec![1, 3, 5], 4), 2);
        s.push(10);
        s.push(11);
        let r = s.into_result(9);
        assert_eq!(r.id, 7);
        assert_eq!(r.generated(), &[10, 11]);
        assert_eq!((r.admitted_step, r.finished_step), (2, 9));
        assert!(r.ttft_ms >= 0.0 && r.total_ms >= r.ttft_ms);
    }
}
