//! Continuous-batching scheduler.
//!
//! The scheduler owns a fixed set of batch lanes over one decode backend.
//! Every step it (1) evicts finished sessions, (2) admits queued requests
//! into the freed lanes, and (3) advances all live lanes by one token — so
//! a queued request starts decoding as soon as *any* lane frees, instead of
//! waiting for the whole batch to drain (the property the serve
//! integration test pins down).

use anyhow::{ensure, Result};
use std::time::Duration;

use crate::obs::{self, Counter};
use crate::serve::backend::DecodeBackend;
use crate::serve::session::Session;
use crate::serve::stats::ServeStats;
use crate::serve::{health, AdmissionQueue, FinishReason, GenRequest, GenResult, StreamEvent, TokenSink};
use crate::util::Timer;

pub struct Scheduler<B: DecodeBackend> {
    backend: B,
    lanes: Vec<Option<Session>>,
    /// monotone step counter (one backend step per increment)
    step_no: u64,
}

impl<B: DecodeBackend> Scheduler<B> {
    /// `lanes` may be smaller than the backend's native batch (the unused
    /// rows ride along as padding); it can never exceed it.
    pub fn new(backend: B, lanes: usize) -> Result<Scheduler<B>> {
        ensure!(lanes >= 1, "need at least one lane");
        ensure!(
            lanes <= backend.lanes(),
            "requested {lanes} lanes but the backend serves {}",
            backend.lanes()
        );
        Ok(Scheduler { backend, lanes: (0..lanes).map(|_| None).collect(), step_no: 0 })
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Tear the scheduler down and hand the backend back, so drained runs
    /// can assert the shutdown invariants (`all_slots_free`, zero resident
    /// KV bytes) on the very backend that served them.
    pub fn into_backend(self) -> B {
        self.backend
    }

    fn active(&self) -> usize {
        self.lanes.iter().filter(|l| l.is_some()).count()
    }

    /// Deliver the terminal event to a streaming client (no-op for
    /// buffered requests; a vanished receiver is ignored).
    fn deliver(sink: Option<TokenSink>, r: &GenResult) {
        if let Some(sink) = sink {
            let _ = sink.send(StreamEvent::Done(r.clone()));
        }
    }

    /// Complete session `s` out of `lane`: evict, convert, record — and
    /// emit the request's retroactive lifecycle trace events ("queued" =
    /// submit→admit on the lane track, "request" = admit→now) now that the
    /// whole timeline is known. (The "ttft" event is emitted live, at the
    /// first generated token.)
    fn complete(
        &mut self,
        lane: usize,
        mut s: Session,
        stats: &mut ServeStats,
        results: &mut Vec<GenResult>,
    ) {
        self.backend.evict(lane);
        obs::add(Counter::ServeCompleted, 1);
        obs::add(Counter::ServeEvicted, 1);
        if obs::enabled() {
            let tid = lane as u32 + 1;
            let queued_us =
                s.admitted.checked_duration_since(s.submitted).unwrap_or_default().as_micros()
                    as u64;
            obs::event_at("queued", "serve", tid, s.submitted, queued_us, s.id);
            let active_us = s.admitted.elapsed().as_micros() as u64;
            obs::event_at("request", "serve", tid, s.admitted, active_us, s.id);
        }
        let sink = s.sink.take();
        let r = s.into_result(self.step_no);
        stats.on_complete(&r);
        Self::deliver(sink, &r);
        results.push(r);
    }

    /// Evict a cancelled session out of `lane` mid-decode: the client went
    /// away, so the lane and its KV slot free immediately and a queued
    /// request can take them this very step. Counts toward
    /// [`Counter::ServeEvicted`] like a completion (one evict per lane
    /// departure), plus [`Counter::ServeCancelled`].
    fn cancel(
        &mut self,
        lane: usize,
        mut s: Session,
        stats: &mut ServeStats,
        results: &mut Vec<GenResult>,
    ) {
        self.backend.evict(lane);
        obs::add(Counter::ServeCancelled, 1);
        obs::add(Counter::ServeEvicted, 1);
        if obs::enabled() {
            let active_us = s.admitted.elapsed().as_micros() as u64;
            obs::event_at("cancelled", "serve", lane as u32 + 1, s.admitted, active_us, s.id);
        }
        let sink = s.sink.take();
        let mut r = s.into_result(self.step_no);
        r.error = Some("cancelled by client disconnect".into());
        r.reason = FinishReason::Cancelled;
        stats.on_cancel(&r);
        Self::deliver(sink, &r);
        results.push(r);
    }

    /// Shed a queued request whose TTFT deadline passed before a lane
    /// freed: it never touches the backend — no prefill is paid for a
    /// first token that would arrive too late — and the client gets a
    /// typed timeout (the wire layer answers `503` + `Retry-After`). Its
    /// queue wait still lands in the `queued` histogram via
    /// [`ServeStats::on_shed`].
    fn shed(&mut self, req: GenRequest, stats: &mut ServeStats, results: &mut Vec<GenResult>) {
        obs::add(Counter::DeadlineShed, 1);
        health::note_deadline_miss();
        let mut sess = Session::admit(req, self.step_no);
        if obs::enabled() {
            let queued_us =
                sess.admitted.checked_duration_since(sess.submitted).unwrap_or_default().as_micros()
                    as u64;
            obs::event_at("shed", "serve", 0, sess.submitted, queued_us, sess.id);
        }
        let sink = sess.sink.take();
        let mut r = sess.into_result(self.step_no);
        r.error = Some("ttft deadline exceeded while queued".into());
        r.reason = FinishReason::DeadlineShed;
        stats.on_shed(&r);
        Self::deliver(sink, &r);
        results.push(r);
    }

    /// Evict a session whose completion deadline passed mid-decode: the
    /// lane and KV slot free immediately and the partial completion is
    /// delivered with `reason: "deadline"`. Counts toward
    /// [`Counter::ServeEvicted`] like every lane departure, plus
    /// [`Counter::DeadlineEvicted`].
    fn deadline_evict(
        &mut self,
        lane: usize,
        mut s: Session,
        stats: &mut ServeStats,
        results: &mut Vec<GenResult>,
    ) {
        self.backend.evict(lane);
        obs::add(Counter::DeadlineEvicted, 1);
        obs::add(Counter::ServeEvicted, 1);
        health::note_deadline_miss();
        if obs::enabled() {
            let active_us = s.admitted.elapsed().as_micros() as u64;
            obs::event_at("deadline", "serve", lane as u32 + 1, s.admitted, active_us, s.id);
        }
        let sink = s.sink.take();
        let mut r = s.into_result(self.step_no);
        r.error = Some("completion deadline exceeded mid-decode".into());
        r.reason = FinishReason::DeadlineEvicted;
        stats.on_deadline_evict(&r);
        Self::deliver(sink, &r);
        results.push(r);
    }

    /// Drain the queue to completion: runs until the queue is closed and
    /// every admitted session has finished. Returns results in completion
    /// order.
    pub fn run(&mut self, queue: &AdmissionQueue, stats: &mut ServeStats) -> Result<Vec<GenResult>> {
        health::reset();
        let mut results = vec![];
        let seq_len = self.backend.seq_len();
        loop {
            let admit_timer = Timer::start();
            // 1. evict finished, cancelled and deadline-blown sessions,
            //    freeing their lane + cache slot (a cancelled or evicted
            //    lane frees mid-decode: nothing useful waits on its
            //    remaining budget). The deadline check runs before the
            //    done check so a lane past its deadline can never leave
            //    as a normal completion.
            for lane in 0..self.lanes.len() {
                let Some(s) = &self.lanes[lane] else { continue };
                if s.cancelled() {
                    let s = self.lanes[lane].take().unwrap();
                    self.cancel(lane, s, stats, &mut results);
                } else if s.deadline_exceeded() {
                    let s = self.lanes[lane].take().unwrap();
                    self.deadline_evict(lane, s, stats, &mut results);
                } else if s.done(seq_len) {
                    let s = self.lanes[lane].take().unwrap();
                    self.complete(lane, s, stats, &mut results);
                }
            }

            // 2. admit queued requests into free lanes (continuous batching:
            //    this happens every step, not once per batch); requests
            //    whose TTFT deadline already passed are shed instead of
            //    admitted, so an expired head-of-line never wastes the
            //    lane a live request could take this step
            'admit: for lane in 0..self.lanes.len() {
                // keep pulling from the queue until this lane actually
                // holds a session: a shed, rejected, or zero-budget
                // inline-completed request leaves the lane free, and the
                // next queued request must take it in the SAME pass — a
                // premature break here used to park the freed lane for a
                // full decode step while live lanes stepped, one batched
                // forward of dead TTFT for the head of the queue
                while self.lanes[lane].is_none() {
                    let Some(req) = queue.try_pop() else { break 'admit };
                    if req.ttft_deadline_expired() {
                        self.shed(req, stats, &mut results);
                        continue; // the lane is still free — try the next request
                    }
                    match self.backend.admit(lane, &req.prompt) {
                        Ok(()) => {
                            obs::add(Counter::ServeAdmitted, 1);
                            let sess = Session::admit(req, self.step_no);
                            if sess.done(seq_len) {
                                // zero-budget request: complete without a
                                // step — the lane frees again, keep pulling
                                self.complete(lane, sess, stats, &mut results);
                            } else {
                                self.lanes[lane] = Some(sess);
                            }
                        }
                        Err(e) => {
                            // reject just this request — one bad prompt must not
                            // take down the run (or lose the other sessions);
                            // the lane frees again, keep pulling
                            self.backend.evict(lane); // release any partial admit
                            obs::add(Counter::ServeRejected, 1);
                            let mut sess = Session::admit(req, self.step_no);
                            let sink = sess.sink.take();
                            let mut r = sess.into_result(self.step_no);
                            // full context chain, not just the outermost
                            // message: the wire layer keys the retryable
                            // pages-exhausted 429 off the typed cause
                            r.error = Some(format!("{e:#}"));
                            r.reason = FinishReason::Rejected;
                            stats.on_reject();
                            Self::deliver(sink, &r);
                            results.push(r);
                        }
                    }
                }
            }
            stats.add_admit_secs(admit_timer.secs());

            if self.active() == 0 {
                if queue.is_drained() {
                    break;
                }
                // idle: block until a request arrives or the queue closes
                let idle_timer = Timer::start();
                queue.wait_nonempty(Duration::from_millis(50));
                stats.add_idle_secs(idle_timer.secs());
                continue;
            }

            // 3. one decode step across all live lanes
            let active = self.active();
            let views: Vec<Option<&[i32]>> =
                self.lanes.iter().map(|l| l.as_ref().map(|s| s.tokens.as_slice())).collect();
            let step_timer = Timer::start();
            let next = {
                let _span = obs::span("step", "serve", 0, active as u64);
                self.backend.step(&views)?
            };
            let step_ms = step_timer.millis();
            self.step_no += 1;
            let mut new_tokens = 0usize;
            for (lane, tok) in next.into_iter().enumerate() {
                if let (Some(s), Some(t)) = (self.lanes[lane].as_mut(), tok) {
                    let first = s.generated().is_empty();
                    s.push(t);
                    if first {
                        // TTFT lands in the stats the moment the first
                        // token exists — streaming clients see it then,
                        // so the accounting must too (bit-equal to the old
                        // record-at-completion value: same instant, same
                        // conversion — pinned by obs_integration)
                        let ttft_ms = s.ttft_ms.unwrap_or(f64::NAN);
                        stats.on_first_token(ttft_ms);
                        if obs::enabled() {
                            let ttft_us = s
                                .first_token
                                .and_then(|ft| ft.checked_duration_since(s.submitted))
                                .unwrap_or_default()
                                .as_micros() as u64;
                            obs::event_at(
                                "ttft", "serve", lane as u32 + 1, s.submitted, ttft_us, s.id,
                            );
                        }
                    }
                    new_tokens += 1;
                }
            }
            obs::add(Counter::ServeSteps, 1);
            obs::add(Counter::ServeNewTokens, new_tokens as u64);
            let depth = queue.depth();
            stats.on_step(
                depth,
                active,
                self.backend.kv_bytes(),
                self.backend.kv_pages(),
                step_ms,
                new_tokens,
            );
            // watchdog: classify the step's wall time (slow/stuck flags)
            // and feed the health state machine its evidence
            health::note_step(depth, step_ms);
        }
        stats.record_kv_ledger(self.backend.kv_ledger());
        stats.finish();
        Ok(results)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::GenRequest;

    /// Deterministic model-free backend: lane l always emits token 100+l.
    /// Mirrors the artifact backend's statelessness.
    struct MockBackend {
        lanes: usize,
        seq: usize,
        admitted: Vec<u32>,
        evicted: Vec<u32>,
    }

    impl MockBackend {
        fn new(lanes: usize, seq: usize) -> MockBackend {
            MockBackend { lanes, seq, admitted: vec![0; lanes], evicted: vec![0; lanes] }
        }
    }

    impl DecodeBackend for MockBackend {
        fn lanes(&self) -> usize {
            self.lanes
        }
        fn seq_len(&self) -> usize {
            self.seq
        }
        fn admit(&mut self, lane: usize, prompt: &[i32]) -> Result<()> {
            anyhow::ensure!(prompt.first() != Some(&99), "marker prompt rejected");
            self.admitted[lane] += 1;
            Ok(())
        }
        fn evict(&mut self, lane: usize) {
            self.evicted[lane] += 1;
        }
        fn step(&mut self, lanes: &[Option<&[i32]>]) -> Result<Vec<Option<i32>>> {
            Ok(lanes
                .iter()
                .enumerate()
                .map(|(l, t)| t.map(|_| 100 + l as i32))
                .collect())
        }
    }

    fn run_reqs(lanes: usize, reqs: Vec<GenRequest>) -> (Vec<GenResult>, ServeStats) {
        let queue = AdmissionQueue::new(reqs.len().max(1));
        for r in reqs {
            queue.submit(r).unwrap();
        }
        queue.close();
        let mut sched = Scheduler::new(MockBackend::new(lanes, 64), lanes).unwrap();
        let mut stats = ServeStats::new(lanes);
        let results = sched.run(&queue, &mut stats).unwrap();
        (results, stats)
    }

    fn by_id(results: &[GenResult], id: u64) -> &GenResult {
        results.iter().find(|r| r.id == id).unwrap()
    }

    #[test]
    fn admits_queued_request_before_batch_drains() {
        // 2 lanes, 3 requests: the short one frees a lane while the long
        // one is still decoding — the queued request must start then.
        let (results, stats) = run_reqs(
            2,
            vec![
                GenRequest::new(1, vec![1, 3], 6),
                GenRequest::new(2, vec![1, 4], 2),
                GenRequest::new(3, vec![1, 5], 2),
            ],
        );
        assert_eq!(results.len(), 3);
        let (r1, r2, r3) = (by_id(&results, 1), by_id(&results, 2), by_id(&results, 3));
        assert!(
            r3.admitted_step < r1.finished_step,
            "continuous batching must admit ({}) before the batch drains ({})",
            r3.admitted_step,
            r1.finished_step
        );
        assert!(r3.admitted_step >= r2.finished_step, "no free lane before the short request ended");
        assert_eq!(r1.generated().len(), 6);
        assert!(stats.mean_queue_depth() > 0.0, "request 3 must have waited in the queue");
        assert!(stats.batch_occupancy() > 0.5);
    }

    #[test]
    fn all_lanes_used_and_released() {
        let reqs = (0..8).map(|i| GenRequest::new(i, vec![1, 2], 3)).collect();
        let (results, stats) = run_reqs(4, reqs);
        assert_eq!(results.len(), 8);
        assert_eq!(stats.completed, 8);
        assert_eq!(stats.total_new_tokens, 8 * 3);
        let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 8);
    }

    #[test]
    fn context_window_bounds_generation() {
        // seq 8, prompt 5 -> at most 3 generated tokens regardless of budget
        let queue = AdmissionQueue::new(1);
        queue.submit(GenRequest::new(9, vec![1, 2, 3, 4, 5], 100)).unwrap();
        queue.close();
        let mut sched = Scheduler::new(MockBackend::new(1, 8), 1).unwrap();
        let mut stats = ServeStats::new(1);
        let results = sched.run(&queue, &mut stats).unwrap();
        assert_eq!(results[0].generated().len(), 3);
    }

    #[test]
    fn bad_request_is_rejected_without_killing_the_run() {
        let (results, stats) = run_reqs(
            2,
            vec![
                GenRequest::new(1, vec![1, 2], 3),
                GenRequest::new(2, vec![99, 2], 3), // admit fails on marker
                GenRequest::new(3, vec![1, 4], 3),
            ],
        );
        assert_eq!(results.len(), 3);
        let bad = by_id(&results, 2);
        assert!(bad.error.as_deref().unwrap().contains("marker"));
        assert!(bad.generated().is_empty());
        assert_eq!(stats.rejected, 1);
        assert!(by_id(&results, 1).error.is_none());
        assert_eq!(by_id(&results, 3).generated().len(), 3);
    }

    #[test]
    fn freed_lane_is_refilled_in_the_same_admit_pass() {
        // regression: the admit loop used to `break` out of a lane after a
        // rejected or zero-budget inline-completed request, stranding the
        // just-freed lane for one full decode step while lane 0 stepped.
        // Queue: r1 keeps lane 0 busy; lane 1 pulls r2 (marker reject),
        // then r3 (zero budget, completes inline), then r4 — all in the
        // SAME admit pass, so r4 must be admitted at step 0, not step 1+.
        let (results, stats) = run_reqs(
            2,
            vec![
                GenRequest::new(1, vec![1, 2], 4),
                GenRequest::new(2, vec![99, 2], 3), // admit fails on marker
                GenRequest::new(3, vec![1, 3], 0),  // inline-completes
                GenRequest::new(4, vec![1, 4], 2),
            ],
        );
        assert_eq!(results.len(), 4);
        assert_eq!(
            by_id(&results, 4).admitted_step,
            by_id(&results, 1).admitted_step,
            "the follow-up request must take the freed lane in the same pass"
        );
        assert_eq!(by_id(&results, 4).admitted_step, 0);
        assert!(by_id(&results, 3).generated().is_empty());
        assert_eq!(by_id(&results, 4).generated().len(), 2);
        assert_eq!((stats.rejected, stats.completed), (1, 3));
    }

    #[test]
    fn zero_budget_request_generates_nothing() {
        let (results, stats) = run_reqs(
            1,
            vec![GenRequest::new(1, vec![1, 2], 0), GenRequest::new(2, vec![1, 3], 2)],
        );
        assert_eq!(results.len(), 2);
        assert!(by_id(&results, 1).generated().is_empty());
        assert_eq!(by_id(&results, 2).generated().len(), 2);
        assert_eq!(stats.total_new_tokens, 2);
    }

    #[test]
    fn rejects_more_lanes_than_backend() {
        assert!(Scheduler::new(MockBackend::new(2, 8), 3).is_err());
        assert!(Scheduler::new(MockBackend::new(2, 8), 0).is_err());
    }

    #[test]
    fn cancelled_session_frees_the_lane_for_the_next_request() {
        use std::sync::atomic::AtomicBool;
        use std::sync::Arc;
        // one lane; request 1 has a huge budget but its client is already
        // gone — the scheduler must evict it after at most one step and
        // serve request 2 to completion instead of decoding 500 tokens
        let flag = Arc::new(AtomicBool::new(true));
        let queue = AdmissionQueue::new(2);
        queue
            .submit(GenRequest::new(1, vec![1, 2], 500).with_cancel(flag))
            .unwrap();
        queue.submit(GenRequest::new(2, vec![1, 3], 3)).unwrap();
        queue.close();
        let mut sched = Scheduler::new(MockBackend::new(1, 1024), 1).unwrap();
        let mut stats = ServeStats::new(1);
        let results = sched.run(&queue, &mut stats).unwrap();
        assert_eq!(results.len(), 2);
        let r1 = by_id(&results, 1);
        assert!(r1.error.as_deref().unwrap().contains("cancel"), "{:?}", r1.error);
        assert!(r1.generated().len() <= 1, "cancelled lane kept decoding");
        let r2 = by_id(&results, 2);
        assert!(r2.error.is_none());
        assert_eq!(r2.generated().len(), 3);
        assert_eq!((stats.completed, stats.cancelled), (1, 1));
        // the cancelled request's generated tokens still count: the token
        // counter invariant (stats == per-step series sum) must hold
        let generated: usize = results.iter().map(|r| r.generated().len()).sum();
        assert_eq!(stats.total_new_tokens, generated);
        // the backend saw exactly one evict per lane departure
        assert_eq!(sched.backend().evicted[0], 2);
    }

    #[test]
    fn expired_ttft_deadline_sheds_instead_of_admitting() {
        use crate::serve::FinishReason;
        // request 1's TTFT deadline is already over when the scheduler
        // first looks at it: it must be shed without touching the backend,
        // and request 2 (behind it in the queue) takes the lane this step
        let queue = AdmissionQueue::new(2);
        queue
            .submit(GenRequest::new(1, vec![1, 2], 50).with_ttft_deadline_ms(0))
            .unwrap();
        queue.submit(GenRequest::new(2, vec![1, 3], 3)).unwrap();
        queue.close();
        let mut sched = Scheduler::new(MockBackend::new(1, 64), 1).unwrap();
        let mut stats = ServeStats::new(1);
        let results = sched.run(&queue, &mut stats).unwrap();
        assert_eq!(results.len(), 2);
        let shed = by_id(&results, 1);
        assert_eq!(shed.reason, FinishReason::DeadlineShed);
        assert!(shed.error.as_deref().unwrap().contains("ttft deadline"), "{:?}", shed.error);
        assert!(shed.generated().is_empty(), "a shed request must not decode");
        let ok = by_id(&results, 2);
        assert_eq!((ok.reason, ok.generated().len()), (FinishReason::Completed, 3));
        assert_eq!((stats.completed, stats.deadline_shed), (1, 1));
        // shed without ever admitting: the backend saw exactly one session
        assert_eq!(sched.backend().admitted[0], 1);
        assert_eq!(sched.backend().evicted[0], 1);
    }

    #[test]
    fn blown_decode_deadline_evicts_the_lane_mid_flight() {
        use crate::serve::FinishReason;
        // request 1 has a huge budget but a deadline that is already over
        // by its first step boundary: exactly one token decodes (admit ->
        // step -> boundary sees the deadline), then the lane frees for
        // request 2 — deterministic at any worker-pool width, which the
        // proptests pin across SILQ_THREADS
        let queue = AdmissionQueue::new(2);
        queue
            .submit(GenRequest::new(1, vec![1, 2], 500).with_deadline_ms(0))
            .unwrap();
        queue.submit(GenRequest::new(2, vec![1, 3], 3)).unwrap();
        queue.close();
        let mut sched = Scheduler::new(MockBackend::new(1, 1024), 1).unwrap();
        let mut stats = ServeStats::new(1);
        let results = sched.run(&queue, &mut stats).unwrap();
        assert_eq!(results.len(), 2);
        let evicted = by_id(&results, 1);
        assert_eq!(evicted.reason, FinishReason::DeadlineEvicted);
        assert!(evicted.error.as_deref().unwrap().contains("deadline"), "{:?}", evicted.error);
        assert_eq!(evicted.generated().len(), 1, "evicted at the first step boundary");
        let ok = by_id(&results, 2);
        assert_eq!(ok.generated().len(), 3);
        assert_eq!((stats.completed, stats.deadline_evicted), (1, 1));
        // evicted tokens still count toward the exact token ledger
        let generated: usize = results.iter().map(|r| r.generated().len()).sum();
        assert_eq!(stats.total_new_tokens, generated);
        assert_eq!(sched.backend().evicted[0], 2, "one evict per lane departure");
    }

    #[test]
    fn sink_streams_tokens_then_done() {
        let (tx, rx) = std::sync::mpsc::channel();
        let queue = AdmissionQueue::new(1);
        queue
            .submit(GenRequest::new(5, vec![1, 2], 3).with_sink(tx))
            .unwrap();
        queue.close();
        let mut sched = Scheduler::new(MockBackend::new(2, 64), 2).unwrap();
        let mut stats = ServeStats::new(2);
        let results = sched.run(&queue, &mut stats).unwrap();
        assert_eq!(results.len(), 1);
        let events: Vec<_> = rx.try_iter().collect();
        assert_eq!(events.len(), 4, "3 tokens + 1 done, got {events:?}");
        for (i, ev) in events.iter().take(3).enumerate() {
            match ev {
                crate::serve::StreamEvent::Token(t) => assert_eq!(*t, 100, "token {i}"),
                other => panic!("expected token, got {other:?}"),
            }
        }
        match &events[3] {
            crate::serve::StreamEvent::Done(r) => {
                assert_eq!(r.id, 5);
                assert_eq!(r.generated(), results[0].generated());
                assert!(r.error.is_none());
            }
            other => panic!("expected done, got {other:?}"),
        }
    }

    #[test]
    fn rejected_request_still_gets_its_done_event() {
        let (tx, rx) = std::sync::mpsc::channel();
        let queue = AdmissionQueue::new(1);
        queue
            .submit(GenRequest::new(9, vec![99, 2], 3).with_sink(tx)) // marker: admit fails
            .unwrap();
        queue.close();
        let mut sched = Scheduler::new(MockBackend::new(1, 64), 1).unwrap();
        let mut stats = ServeStats::new(1);
        let results = sched.run(&queue, &mut stats).unwrap();
        assert_eq!(stats.rejected, 1);
        assert!(results[0].error.is_some());
        let events: Vec<_> = rx.try_iter().collect();
        assert_eq!(events.len(), 1);
        match &events[0] {
            crate::serve::StreamEvent::Done(r) => {
                assert!(r.error.as_deref().unwrap().contains("marker"))
            }
            other => panic!("expected done, got {other:?}"),
        }
    }
}
