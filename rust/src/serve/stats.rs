//! Serving telemetry: per-request latency records plus per-step scheduler
//! gauges, aggregated into the throughput report `silq serve` prints.

use crate::metrics::percentile;
use crate::serve::GenResult;
use crate::util::Timer;

/// Aggregate statistics over one serve run.
pub struct ServeStats {
    /// wall-clock seconds of the run (stamped by `finish`)
    pub wall_secs: f64,
    pub steps: u64,
    pub completed: usize,
    /// requests rejected at admission (bad prompt, cache exhaustion)
    pub rejected: usize,
    pub total_new_tokens: usize,
    /// per-step gauges (summed; divide by steps for means)
    queue_depth_sum: f64,
    active_lane_sum: f64,
    lanes: usize,
    /// peak deployment-format KV bytes resident in the pool
    pub kv_bytes_peak: usize,
    /// per-request records
    pub ttft_ms: Vec<f64>,
    pub queued_ms: Vec<f64>,
    pub total_ms: Vec<f64>,
    timer: Timer,
}

impl ServeStats {
    pub fn new(lanes: usize) -> ServeStats {
        ServeStats {
            wall_secs: 0.0,
            steps: 0,
            completed: 0,
            rejected: 0,
            total_new_tokens: 0,
            queue_depth_sum: 0.0,
            active_lane_sum: 0.0,
            lanes: lanes.max(1),
            kv_bytes_peak: 0,
            ttft_ms: vec![],
            queued_ms: vec![],
            total_ms: vec![],
            timer: Timer::start(),
        }
    }

    /// Record one scheduler step's gauges.
    pub fn on_step(&mut self, queue_depth: usize, active_lanes: usize, kv_bytes: usize) {
        self.steps += 1;
        self.queue_depth_sum += queue_depth as f64;
        self.active_lane_sum += active_lanes as f64;
        self.kv_bytes_peak = self.kv_bytes_peak.max(kv_bytes);
    }

    /// Record one finished request.
    pub fn on_complete(&mut self, r: &GenResult) {
        self.completed += 1;
        self.total_new_tokens += r.generated().len();
        if r.ttft_ms.is_finite() {
            self.ttft_ms.push(r.ttft_ms);
        }
        self.queued_ms.push(r.queued_ms);
        self.total_ms.push(r.total_ms);
    }

    /// Record one request rejected at admission.
    pub fn on_reject(&mut self) {
        self.rejected += 1;
    }

    pub fn finish(&mut self) {
        self.wall_secs = self.timer.secs();
    }

    /// Mean admission-queue depth sampled once per scheduler step.
    pub fn mean_queue_depth(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.queue_depth_sum / self.steps as f64
        }
    }

    /// Mean fraction of batch lanes holding a live session.
    pub fn batch_occupancy(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.active_lane_sum / (self.steps as f64 * self.lanes as f64)
        }
    }

    /// Aggregate generated-token throughput over the whole run.
    pub fn tokens_per_sec(&self) -> f64 {
        let secs = if self.wall_secs > 0.0 { self.wall_secs } else { self.timer.secs() };
        self.total_new_tokens as f64 / secs.max(1e-9)
    }

    /// Mean TTFT over requests that produced a first token. Degenerate
    /// runs (nothing completed, or only zero-budget/rejected requests)
    /// report 0, not NaN — a dashboard averaging these must not poison
    /// every downstream aggregate.
    pub fn ttft_mean_ms(&self) -> f64 {
        if self.ttft_ms.is_empty() {
            0.0
        } else {
            self.ttft_ms.iter().sum::<f64>() / self.ttft_ms.len() as f64
        }
    }

    /// p95 TTFT. `metrics::percentile` is NaN on an empty sample by
    /// contract; this guards the degenerate serve run to 0 like the mean
    /// (`empty_run_report_has_no_nans` pins all three zero-sample gauges).
    pub fn ttft_p95_ms(&self) -> f64 {
        if self.ttft_ms.is_empty() {
            0.0
        } else {
            percentile(&self.ttft_ms, 95.0)
        }
    }

    /// Mean queue wait across completed requests (0 when none completed).
    pub fn queued_mean_ms(&self) -> f64 {
        if self.queued_ms.is_empty() {
            0.0
        } else {
            self.queued_ms.iter().sum::<f64>() / self.queued_ms.len() as f64
        }
    }

    /// The report `silq serve` prints.
    pub fn report(&self) -> String {
        format!(
            "served {} requests ({} rejected) / {} tokens in {:.2}s over {} steps\n\
             throughput     {:>9.1} tok/s\n\
             ttft           {:>9.2} ms mean   {:>8.2} ms p95\n\
             queued         {:>9.2} ms mean\n\
             queue depth    {:>9.2} mean\n\
             batch occupancy{:>9.1} %\n\
             kv pool peak   {:>9.1} KiB (deployment format)",
            self.completed,
            self.rejected,
            self.total_new_tokens,
            self.wall_secs,
            self.steps,
            self.tokens_per_sec(),
            self.ttft_mean_ms(),
            self.ttft_p95_ms(),
            self.queued_mean_ms(),
            self.mean_queue_depth(),
            100.0 * self.batch_occupancy(),
            self.kv_bytes_peak as f64 / 1024.0,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::GenRequest;
    use crate::serve::session::Session;

    #[test]
    fn gauges_average_per_step() {
        let mut st = ServeStats::new(4);
        st.on_step(2, 4, 100);
        st.on_step(0, 2, 50);
        assert!((st.mean_queue_depth() - 1.0).abs() < 1e-9);
        assert!((st.batch_occupancy() - 0.75).abs() < 1e-9);
        assert_eq!(st.kv_bytes_peak, 100);
    }

    #[test]
    fn completion_accounting() {
        let mut st = ServeStats::new(2);
        let mut s = Session::admit(GenRequest::new(1, vec![1, 2], 3), 0);
        s.push(5);
        s.push(6);
        st.on_complete(&s.into_result(2));
        st.finish();
        assert_eq!(st.completed, 1);
        assert_eq!(st.total_new_tokens, 2);
        assert!(st.tokens_per_sec() > 0.0);
        assert!(st.report().contains("served 1 requests"));
    }

    #[test]
    fn empty_run_report_has_no_nans() {
        // degenerate run: zero completed requests, zero scheduler steps.
        // Every gauge must report 0 — the step-normalized means guard
        // steps == 0, and the TTFT mean/p95 guard the empty sample that
        // metrics::percentile maps to NaN by contract.
        let mut st = ServeStats::new(1);
        st.finish();
        assert_eq!(st.mean_queue_depth(), 0.0);
        assert_eq!(st.batch_occupancy(), 0.0);
        assert_eq!(st.ttft_mean_ms(), 0.0);
        assert_eq!(st.ttft_p95_ms(), 0.0);
        assert_eq!(st.queued_mean_ms(), 0.0);
        assert!(st.tokens_per_sec().is_finite());
        let report = st.report();
        assert!(!report.contains("NaN"), "degenerate report leaked a NaN:\n{report}");
    }

    #[test]
    fn zero_budget_completions_leave_ttft_at_zero_not_nan() {
        // a request that completes without ever emitting a token records
        // no TTFT sample (its per-request ttft_ms is NaN by contract);
        // the aggregates over the empty sample must still be 0
        let mut st = ServeStats::new(1);
        let s = Session::admit(GenRequest::new(1, vec![1, 2], 0), 0);
        let r = s.into_result(0);
        assert!(r.ttft_ms.is_nan());
        st.on_complete(&r);
        st.on_reject();
        st.finish();
        assert_eq!(st.completed, 1);
        assert_eq!(st.rejected, 1);
        assert_eq!(st.total_new_tokens, 0);
        assert_eq!(st.ttft_mean_ms(), 0.0);
        assert_eq!(st.ttft_p95_ms(), 0.0);
        assert!(!st.report().contains("NaN"));
    }
}
