//! Serving telemetry: per-request latency histograms plus a per-step
//! scheduler time series, aggregated into the throughput report, phase
//! breakdown, and `--metrics-out` JSON `silq serve` emits.
//!
//! Latency aggregates sit on [`obs::Histogram`] — fixed power-of-two
//! buckets, so recording is O(1) without retaining samples and a
//! percentile is one bucket walk instead of the clone-and-sort per query
//! the old `Vec<f64>` records paid. Quantiles are bucket-resolution
//! (upper edge, clamped to the observed min/max); means stay exact.

use crate::hostmodel::PageLedger;
use crate::metrics::Table;
use crate::obs::Histogram;
use crate::serve::GenResult;
use crate::util::Timer;

/// One scheduler step of the `--metrics-out` time series.
#[derive(Clone, Copy, Debug)]
pub struct StepRow {
    /// scheduler step number (0-based)
    pub step: u64,
    /// admission-queue depth after this step's admissions
    pub queue_depth: usize,
    /// lanes holding a live session during the step
    pub active_lanes: usize,
    /// deployment-format KV bytes resident after the step
    pub kv_bytes: usize,
    /// physical KV pages resident after the step (page occupancy; 0 for
    /// backends without an explicit pool)
    pub kv_pages: usize,
    /// wall milliseconds of the backend step call
    pub step_ms: f64,
    /// tokens emitted by this step across all lanes
    pub new_tokens: usize,
}

impl StepRow {
    /// Instantaneous throughput of this step.
    pub fn tok_per_s(&self) -> f64 {
        self.new_tokens as f64 / (self.step_ms / 1e3).max(1e-9)
    }
}

/// Aggregate statistics over one serve run.
pub struct ServeStats {
    /// wall-clock seconds of the run (stamped by `finish`)
    pub wall_secs: f64,
    pub steps: u64,
    pub completed: usize,
    /// requests rejected at admission (bad prompt, cache exhaustion)
    pub rejected: usize,
    /// requests cancelled mid-flight (client disconnect evicted the lane)
    pub cancelled: usize,
    /// requests shed while queued (TTFT deadline passed before admission)
    pub deadline_shed: usize,
    /// requests evicted mid-decode (completion deadline passed)
    pub deadline_evicted: usize,
    pub total_new_tokens: usize,
    /// per-step gauges (summed; divide by steps for means)
    queue_depth_sum: f64,
    active_lane_sum: f64,
    lanes: usize,
    /// peak deployment-format KV bytes resident in the pool
    pub kv_bytes_peak: usize,
    /// peak physical KV pages resident in the pool
    pub kv_pages_peak: usize,
    /// lifetime page-flow counters snapshotted from the backend's pool at
    /// the end of the run (all-zero for poolless backends)
    pub kv_ledger: PageLedger,
    /// per-request latency histograms (TTFT records only finite samples —
    /// zero-budget completions never produce a first token)
    pub ttft: Histogram,
    pub queued: Histogram,
    pub total: Histogram,
    /// per-step time series for `--metrics-out`
    pub series: Vec<StepRow>,
    /// phase wall-time sums for the breakdown report (seconds)
    admit_secs: f64,
    step_secs: f64,
    idle_secs: f64,
    timer: Timer,
}

impl ServeStats {
    pub fn new(lanes: usize) -> ServeStats {
        ServeStats {
            wall_secs: 0.0,
            steps: 0,
            completed: 0,
            rejected: 0,
            cancelled: 0,
            deadline_shed: 0,
            deadline_evicted: 0,
            total_new_tokens: 0,
            queue_depth_sum: 0.0,
            active_lane_sum: 0.0,
            lanes: lanes.max(1),
            kv_bytes_peak: 0,
            kv_pages_peak: 0,
            kv_ledger: PageLedger::default(),
            ttft: Histogram::new(),
            queued: Histogram::new(),
            total: Histogram::new(),
            series: Vec::new(),
            admit_secs: 0.0,
            step_secs: 0.0,
            idle_secs: 0.0,
            timer: Timer::start(),
        }
    }

    /// Record one scheduler step: gauges plus the step's wall time and
    /// token yield for the time series.
    pub fn on_step(
        &mut self,
        queue_depth: usize,
        active_lanes: usize,
        kv_bytes: usize,
        kv_pages: usize,
        step_ms: f64,
        new_tokens: usize,
    ) {
        self.series.push(StepRow {
            step: self.steps,
            queue_depth,
            active_lanes,
            kv_bytes,
            kv_pages,
            step_ms,
            new_tokens,
        });
        self.steps += 1;
        self.queue_depth_sum += queue_depth as f64;
        self.active_lane_sum += active_lanes as f64;
        self.kv_bytes_peak = self.kv_bytes_peak.max(kv_bytes);
        self.kv_pages_peak = self.kv_pages_peak.max(kv_pages);
        self.step_secs += step_ms / 1e3;
    }

    /// Snapshot the backend pool's lifetime page-flow counters into the
    /// run's aggregates (the scheduler calls this once, at drain).
    pub fn record_kv_ledger(&mut self, ledger: PageLedger) {
        self.kv_ledger = ledger;
    }

    /// Record a request's time-to-first-token **at first-token time** (the
    /// scheduler calls this the step the token is emitted, so streaming
    /// clients and the histogram see the same latency at the same moment;
    /// non-finite samples are skipped, matching the old completion-time
    /// filter bit for bit).
    pub fn on_first_token(&mut self, ttft_ms: f64) {
        if ttft_ms.is_finite() {
            self.ttft.record_ms(ttft_ms);
        }
    }

    /// Record one finished request. (TTFT was already recorded at
    /// first-token time by [`ServeStats::on_first_token`].)
    pub fn on_complete(&mut self, r: &GenResult) {
        self.completed += 1;
        self.total_new_tokens += r.generated().len();
        self.queued.record_ms(r.queued_ms);
        self.total.record_ms(r.total_ms);
    }

    /// Record one request rejected at admission.
    pub fn on_reject(&mut self) {
        self.rejected += 1;
    }

    /// Record one request cancelled mid-flight. The tokens it generated
    /// before the disconnect still count toward `total_new_tokens` — the
    /// per-step series already counted them, and the two accountings must
    /// stay exactly equal (the soak pins this).
    pub fn on_cancel(&mut self, r: &GenResult) {
        self.cancelled += 1;
        self.total_new_tokens += r.generated().len();
    }

    /// Record one request shed from the queue because its TTFT deadline
    /// passed before a lane freed. Its queue wait still lands in the
    /// `queued` histogram — shed requests are precisely the ones whose
    /// wait mattered most, so dropping them from the wait accounting
    /// would bias `queued_ms_mean` optimistic under overload.
    pub fn on_shed(&mut self, r: &GenResult) {
        self.deadline_shed += 1;
        self.queued.record_ms(r.queued_ms);
    }

    /// Record one request evicted mid-decode because its completion
    /// deadline passed. Like a cancel, its partial tokens stay in the
    /// exact token ledger; like a shed, its queue wait stays in the
    /// `queued` histogram.
    pub fn on_deadline_evict(&mut self, r: &GenResult) {
        self.deadline_evicted += 1;
        self.total_new_tokens += r.generated().len();
        self.queued.record_ms(r.queued_ms);
    }

    /// Attribute wall time spent admitting/evicting (includes prefill).
    pub fn add_admit_secs(&mut self, secs: f64) {
        self.admit_secs += secs;
    }

    /// Attribute wall time spent parked on an empty queue.
    pub fn add_idle_secs(&mut self, secs: f64) {
        self.idle_secs += secs;
    }

    pub fn finish(&mut self) {
        self.wall_secs = self.timer.secs();
    }

    /// Mean admission-queue depth sampled once per scheduler step.
    pub fn mean_queue_depth(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.queue_depth_sum / self.steps as f64
        }
    }

    /// Mean fraction of batch lanes holding a live session.
    pub fn batch_occupancy(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.active_lane_sum / (self.steps as f64 * self.lanes as f64)
        }
    }

    /// Aggregate generated-token throughput over the whole run.
    pub fn tokens_per_sec(&self) -> f64 {
        let secs = if self.wall_secs > 0.0 { self.wall_secs } else { self.timer.secs() };
        self.total_new_tokens as f64 / secs.max(1e-9)
    }

    /// Mean TTFT over requests that produced a first token. Degenerate
    /// runs (nothing completed, or only zero-budget/rejected requests)
    /// report 0, not NaN — a dashboard averaging these must not poison
    /// every downstream aggregate. Exact (histogram means do not bucket).
    pub fn ttft_mean_ms(&self) -> f64 {
        self.ttft.mean_ms()
    }

    /// p95 TTFT at histogram-bucket resolution (0 on the degenerate
    /// empty-sample run; `empty_run_report_has_no_nans` pins all the
    /// zero-sample gauges).
    pub fn ttft_p95_ms(&self) -> f64 {
        self.ttft.percentile_ms(95.0)
    }

    /// Mean queue wait across completed requests (0 when none completed).
    pub fn queued_mean_ms(&self) -> f64 {
        self.queued.mean_ms()
    }

    /// Fraction of page binds served by attaching to an already-resident
    /// page instead of allocating a fresh one (shared attaches over
    /// allocated + shared); 0 when no pages moved at all.
    pub fn kv_sharing_ratio(&self) -> f64 {
        let total = self.kv_ledger.allocated + self.kv_ledger.shared;
        if total == 0 {
            0.0
        } else {
            self.kv_ledger.shared as f64 / total as f64
        }
    }

    /// The report `silq serve` prints.
    pub fn report(&self) -> String {
        format!(
            "served {} requests ({} rejected, {} cancelled, {} deadline-shed, \
             {} deadline-evicted) / {} tokens in {:.2}s over {} steps\n\
             throughput     {:>9.1} tok/s\n\
             ttft           {:>9.2} ms mean   {:>8.2} ms p95\n\
             queued         {:>9.2} ms mean\n\
             queue depth    {:>9.2} mean\n\
             batch occupancy{:>9.1} %\n\
             kv pool peak   {:>9.1} KiB (deployment format)\n\
             kv pages peak  {:>9} resident ({} shared attaches, {} cow forks, {} reclaimed)",
            self.completed,
            self.rejected,
            self.cancelled,
            self.deadline_shed,
            self.deadline_evicted,
            self.total_new_tokens,
            self.wall_secs,
            self.steps,
            self.tokens_per_sec(),
            self.ttft_mean_ms(),
            self.ttft_p95_ms(),
            self.queued_mean_ms(),
            self.mean_queue_depth(),
            100.0 * self.batch_occupancy(),
            self.kv_bytes_peak as f64 / 1024.0,
            self.kv_pages_peak,
            self.kv_ledger.shared,
            self.kv_ledger.forked,
            self.kv_ledger.reclaimed,
        )
    }

    /// Phase attribution of the run's wall time, as a fixed-width table:
    /// admit/evict (incl. prefill), backend decode steps, idle queue
    /// waits, and the unattributed remainder (result plumbing, gauges).
    pub fn breakdown(&self) -> String {
        let wall = if self.wall_secs > 0.0 { self.wall_secs } else { self.timer.secs() };
        let other = (wall - self.admit_secs - self.step_secs - self.idle_secs).max(0.0);
        let mut t = Table::new(&["phase", "secs", "% wall"]);
        let pct = |s: f64| format!("{:.1}", 100.0 * s / wall.max(1e-9));
        t.row(&[
            "admit+prefill".into(),
            format!("{:.3}", self.admit_secs),
            pct(self.admit_secs),
        ]);
        t.row(&["decode steps".into(), format!("{:.3}", self.step_secs), pct(self.step_secs)]);
        t.row(&["idle wait".into(), format!("{:.3}", self.idle_secs), pct(self.idle_secs)]);
        t.row(&["other".into(), format!("{other:.3}"), pct(other)]);
        t.row(&["total".into(), format!("{wall:.3}"), "100.0".into()]);
        t.render()
    }

    /// The `--metrics-out` document: the per-step time series plus the
    /// aggregate totals, hand-rolled JSON (this repo takes no serializer
    /// dependency). Schema is documented in README §Observability.
    pub fn metrics_json(&self) -> String {
        let mut out = String::with_capacity(64 + self.series.len() * 96);
        out.push_str("{\"schema\":\"silq.metrics.v1\",\"steps\":[");
        for (i, r) in self.series.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"step\":{},\"queue_depth\":{},\"active_lanes\":{},\"kv_bytes\":{},\
                 \"kv_pages\":{},\"step_ms\":{:.4},\"new_tokens\":{},\"tok_per_s\":{:.2}}}",
                r.step, r.queue_depth, r.active_lanes, r.kv_bytes, r.kv_pages, r.step_ms,
                r.new_tokens, r.tok_per_s()
            ));
        }
        out.push_str(&format!(
            "],\"totals\":{{\"steps\":{},\"completed\":{},\"rejected\":{},\"cancelled\":{},\
             \"deadline_shed\":{},\"deadline_evicted\":{},\
             \"new_tokens\":{},\
             \"wall_secs\":{:.4},\"tok_per_s\":{:.2},\"ttft_ms_mean\":{:.3},\
             \"ttft_ms_p95\":{:.3},\"queued_ms_mean\":{:.3},\"kv_bytes_peak\":{},\
             \"kv_pages_peak\":{},\"kv_pages_allocated\":{},\"kv_pages_shared\":{},\
             \"kv_cow_forks\":{},\"kv_pages_reclaimed\":{},\"kv_sharing_ratio\":{:.4},\
             \"mean_queue_depth\":{:.3},\"batch_occupancy\":{:.4}}}}}",
            self.steps,
            self.completed,
            self.rejected,
            self.cancelled,
            self.deadline_shed,
            self.deadline_evicted,
            self.total_new_tokens,
            self.wall_secs,
            self.tokens_per_sec(),
            self.ttft_mean_ms(),
            self.ttft_p95_ms(),
            self.queued_mean_ms(),
            self.kv_bytes_peak,
            self.kv_pages_peak,
            self.kv_ledger.allocated,
            self.kv_ledger.shared,
            self.kv_ledger.forked,
            self.kv_ledger.reclaimed,
            self.kv_sharing_ratio(),
            self.mean_queue_depth(),
            self.batch_occupancy(),
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::session::Session;
    use crate::serve::GenRequest;

    #[test]
    fn gauges_average_per_step() {
        let mut st = ServeStats::new(4);
        st.on_step(2, 4, 100, 3, 1.5, 4);
        st.on_step(0, 2, 50, 1, 0.5, 2);
        assert!((st.mean_queue_depth() - 1.0).abs() < 1e-9);
        assert!((st.batch_occupancy() - 0.75).abs() < 1e-9);
        assert_eq!(st.kv_bytes_peak, 100);
        assert_eq!(st.kv_pages_peak, 3);
        // the series mirrors the gauges row for row
        assert_eq!(st.series.len(), 2);
        assert_eq!(st.series[0].step, 0);
        assert_eq!(st.series[1].queue_depth, 0);
        assert_eq!(st.series.iter().map(|r| r.new_tokens).sum::<usize>(), 6);
        assert!(st.series[0].tok_per_s() > 0.0);
    }

    #[test]
    fn completion_accounting() {
        let mut st = ServeStats::new(2);
        let mut s = Session::admit(GenRequest::new(1, vec![1, 2], 3), 0);
        s.push(5);
        // the scheduler records TTFT the step the first token is emitted
        st.on_first_token(s.ttft_ms.unwrap());
        s.push(6);
        st.on_complete(&s.into_result(2));
        st.finish();
        assert_eq!(st.completed, 1);
        assert_eq!(st.total_new_tokens, 2);
        assert!(st.tokens_per_sec() > 0.0);
        assert!(st.report().contains("served 1 requests"));
        assert_eq!(st.ttft.count(), 1);
        assert_eq!(st.total.count(), 1);
    }

    #[test]
    fn cancel_accounting_keeps_token_totals_exact() {
        let mut st = ServeStats::new(2);
        let mut s = Session::admit(GenRequest::new(9, vec![1, 2], 8), 0);
        s.push(5);
        st.on_first_token(s.ttft_ms.unwrap());
        s.push(6);
        st.on_cancel(&s.into_result(3));
        st.finish();
        assert_eq!((st.completed, st.cancelled), (0, 1));
        // partial progress still counts: the per-step series saw these tokens
        assert_eq!(st.total_new_tokens, 2);
        assert_eq!(st.ttft.count(), 1, "TTFT was already live when the cancel landed");
        assert_eq!(st.total.count(), 0, "total-latency histogram is completed-only");
        assert!(st.report().contains("1 cancelled"));
        assert!(st.metrics_json().contains("\"cancelled\":1"));
        // NaN TTFT on a cancelled-before-first-token request is skipped
        st.on_first_token(f64::NAN);
        assert_eq!(st.ttft.count(), 1);
    }

    #[test]
    fn shed_and_deadline_evictions_are_distinct_outcomes_with_queue_waits() {
        let mut st = ServeStats::new(2);
        // shed: never admitted, no tokens — but its queue wait is recorded
        let s = Session::admit(GenRequest::new(1, vec![1, 2], 4), 0);
        st.on_shed(&s.into_result(0));
        // deadline eviction: partial tokens count, wait is recorded
        let mut s = Session::admit(GenRequest::new(2, vec![1, 2], 50), 0);
        s.push(7);
        st.on_first_token(s.ttft_ms.unwrap());
        st.on_deadline_evict(&s.into_result(1));
        st.finish();
        assert_eq!((st.completed, st.rejected, st.cancelled), (0, 0, 0));
        assert_eq!((st.deadline_shed, st.deadline_evicted), (1, 1));
        assert_eq!(st.total_new_tokens, 1, "evicted partial progress still counts");
        assert_eq!(st.queued.count(), 2, "shed + evicted both stamp the queued histogram");
        assert_eq!(st.total.count(), 0, "total-latency histogram stays completed-only");
        let report = st.report();
        assert!(report.contains("1 deadline-shed"), "{report}");
        assert!(report.contains("1 deadline-evicted"), "{report}");
        let doc = st.metrics_json();
        assert!(doc.contains("\"deadline_shed\":1"), "{doc}");
        assert!(doc.contains("\"deadline_evicted\":1"), "{doc}");
    }

    #[test]
    fn empty_run_report_has_no_nans() {
        // degenerate run: zero completed requests, zero scheduler steps.
        // Every gauge must report 0 — the step-normalized means guard
        // steps == 0, and the TTFT histogram maps the empty sample to 0
        // by contract (metrics::percentile would be NaN on empty).
        let mut st = ServeStats::new(1);
        st.finish();
        assert_eq!(st.mean_queue_depth(), 0.0);
        assert_eq!(st.batch_occupancy(), 0.0);
        assert_eq!(st.ttft_mean_ms(), 0.0);
        assert_eq!(st.ttft_p95_ms(), 0.0);
        assert_eq!(st.queued_mean_ms(), 0.0);
        assert!(st.tokens_per_sec().is_finite());
        let report = st.report();
        assert!(!report.contains("NaN"), "degenerate report leaked a NaN:\n{report}");
        assert!(!st.breakdown().contains("NaN"));
        // the metrics document stays well-formed on the empty run
        let doc = st.metrics_json();
        assert!(doc.contains("\"steps\":[]"));
        assert!(!doc.contains("NaN"));
    }

    #[test]
    fn zero_budget_completions_leave_ttft_at_zero_not_nan() {
        // a request that completes without ever emitting a token records
        // no TTFT sample (its per-request ttft_ms is NaN by contract);
        // the aggregates over the empty sample must still be 0
        let mut st = ServeStats::new(1);
        let s = Session::admit(GenRequest::new(1, vec![1, 2], 0), 0);
        let r = s.into_result(0);
        assert!(r.ttft_ms.is_nan());
        st.on_complete(&r);
        st.on_reject();
        st.finish();
        assert_eq!(st.completed, 1);
        assert_eq!(st.rejected, 1);
        assert_eq!(st.total_new_tokens, 0);
        assert_eq!(st.ttft.count(), 0, "a NaN TTFT must not enter the histogram");
        assert_eq!(st.ttft_mean_ms(), 0.0);
        assert_eq!(st.ttft_p95_ms(), 0.0);
        assert!(!st.report().contains("NaN"));
    }

    #[test]
    fn breakdown_attributes_phases() {
        let mut st = ServeStats::new(2);
        st.add_admit_secs(0.25);
        st.add_idle_secs(0.1);
        st.on_step(0, 2, 10, 1, 100.0, 2);
        st.finish();
        let b = st.breakdown();
        assert!(b.contains("admit+prefill"));
        assert!(b.contains("decode steps"));
        assert!(b.contains("idle wait"));
        assert!(b.contains("total"));
    }

    #[test]
    fn metrics_json_totals_match_fields() {
        let mut st = ServeStats::new(2);
        st.on_step(1, 2, 64, 2, 2.0, 2);
        let mut s = Session::admit(GenRequest::new(7, vec![1], 2), 0);
        s.push(3);
        s.push(4);
        st.on_complete(&s.into_result(1));
        st.record_kv_ledger(PageLedger {
            allocated: 3,
            shared: 1,
            forked: 1,
            reclaimed: 0,
            released: 4,
            revived: 0,
        });
        st.finish();
        let doc = st.metrics_json();
        assert!(doc.contains("\"schema\":\"silq.metrics.v1\""));
        assert!(doc.contains("\"completed\":1"));
        assert!(doc.contains("\"new_tokens\":2"));
        assert!(doc.contains("\"kv_bytes_peak\":64"));
        assert!(doc.contains("\"kv_pages\":2"), "{doc}");
        assert!(doc.contains("\"kv_pages_peak\":2"), "{doc}");
        assert!(doc.contains("\"kv_pages_shared\":1"), "{doc}");
        assert!(doc.contains("\"kv_cow_forks\":1"), "{doc}");
        assert!(doc.contains("\"kv_sharing_ratio\":0.2500"), "{doc}");
        assert!(st.report().contains("kv pages peak"));
    }
}
