//! `health` — the serve watchdog and liveness state machine.
//!
//! The scheduler reports every step here ([`note_step`]) and every
//! deadline miss ([`note_deadline_miss`]); `GET /healthz` reads the
//! derived [`HealthState`] plus its evidence. State is process-global
//! atomics (one scheduler runs at a time; in-crate suites that run
//! several serialize on the serve/traffic locks already), reset at the
//! top of every [`crate::serve::Scheduler::run`].
//!
//! ### The state machine
//!
//! ```text
//!   ok  --pressure > 0-->  degraded  --pressure drains-->  ok
//!    \______________ draining (queue closed / shutdown) ___/
//! ```
//!
//! "Pressure" is a bounded integer score: each deadline miss or
//! slow/stuck step adds to it, each healthy step drains one point. The
//! scheme is deliberately deterministic — a storm of misses flips
//! `/healthz` to `degraded`, a bounded amount of clean traffic
//! (≤ [`PRESSURE_CAP`] steps) is guaranteed to bring it back to `ok` —
//! so the chaos soak can assert the full transition cycle. `draining` is
//! terminal for a run: it is set by shutdown/queue-close and only a new
//! scheduler run clears it.
//!
//! The watchdog itself is post-hoc: a stalled step is detected when it
//! finally ends (its wall time crossed [`SLOW_STEP_MS`] /
//! [`STUCK_STEP_MS`]), bumping the `watchdog_*` obs counters and adding
//! pressure. Everything in this module is lock-free and allocation-free,
//! safe to call from the decode loop.

use crate::obs::{self, Counter};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// A step slower than this is "slow" (watchdog evidence, +pressure).
pub const SLOW_STEP_MS: f64 = 100.0;
/// A step slower than this is "stuck" — the scheduler effectively froze.
pub const STUCK_STEP_MS: f64 = 1000.0;

/// Pressure added per deadline miss or slow step; a stuck step pins the
/// score to the cap.
const PRESSURE_ADD: u64 = 3;
/// Upper bound on the pressure score: recovery needs at most this many
/// healthy steps.
pub const PRESSURE_CAP: u64 = 64;

/// What `GET /healthz` reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthState {
    /// steady state: no recent deadline misses or watchdog flags
    Ok,
    /// serving, but under visible stress (unrecovered pressure)
    Degraded,
    /// shutting down: the admission queue is closed
    Draining,
}

impl HealthState {
    /// Stable wire name (`/healthz` JSON `status` field).
    pub fn name(self) -> &'static str {
        match self {
            HealthState::Ok => "ok",
            HealthState::Degraded => "degraded",
            HealthState::Draining => "draining",
        }
    }
}

/// unrecovered stress score (see module docs)
static PRESSURE: AtomicU64 = AtomicU64::new(0);
/// 1 once the run is draining
static DRAINING: AtomicU64 = AtomicU64::new(0);
/// EWMA of step wall time, microseconds (α = 1/8)
static STEP_EWMA_US: AtomicU64 = AtomicU64::new(0);
/// queue depth observed at the most recent step
static LAST_DEPTH: AtomicUsize = AtomicUsize::new(0);
/// total deadline misses (sheds + evictions) this run
static DEADLINE_MISSES: AtomicU64 = AtomicU64::new(0);
/// total watchdog flags (slow + stuck steps) this run
static WATCHDOG_FLAGS: AtomicU64 = AtomicU64::new(0);

/// Start-of-run reset: back to `ok` with no evidence.
pub fn reset() {
    PRESSURE.store(0, Ordering::Relaxed);
    DRAINING.store(0, Ordering::Relaxed);
    STEP_EWMA_US.store(0, Ordering::Relaxed);
    LAST_DEPTH.store(0, Ordering::Relaxed);
    DEADLINE_MISSES.store(0, Ordering::Relaxed);
    WATCHDOG_FLAGS.store(0, Ordering::Relaxed);
}

/// The queue closed / shutdown began: report `draining` from here on.
pub fn set_draining() {
    DRAINING.store(1, Ordering::Relaxed);
}

/// One scheduler step finished: fold its wall time into the EWMA, run
/// the watchdog classification, and drain or add pressure.
pub fn note_step(queue_depth: usize, step_ms: f64) {
    LAST_DEPTH.store(queue_depth, Ordering::Relaxed);
    let us = (step_ms * 1000.0).max(0.0) as u64;
    let old = STEP_EWMA_US.load(Ordering::Relaxed);
    let ewma = if old == 0 { us } else { (7 * old + us) / 8 };
    STEP_EWMA_US.store(ewma.max(1), Ordering::Relaxed);

    if step_ms > STUCK_STEP_MS {
        obs::add(Counter::WatchdogStuckSteps, 1);
        WATCHDOG_FLAGS.fetch_add(1, Ordering::Relaxed);
        PRESSURE.store(PRESSURE_CAP, Ordering::Relaxed);
    } else if step_ms > SLOW_STEP_MS {
        obs::add(Counter::WatchdogSlowSteps, 1);
        WATCHDOG_FLAGS.fetch_add(1, Ordering::Relaxed);
        add_pressure(PRESSURE_ADD);
    } else {
        // a healthy step drains one point of pressure
        let _ = PRESSURE.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |p| {
            (p > 0).then(|| p - 1)
        });
    }
}

/// A request missed a deadline (TTFT shed or mid-decode eviction).
pub fn note_deadline_miss() {
    DEADLINE_MISSES.fetch_add(1, Ordering::Relaxed);
    add_pressure(PRESSURE_ADD);
}

fn add_pressure(n: u64) {
    let _ = PRESSURE.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |p| {
        Some((p + n).min(PRESSURE_CAP))
    });
}

/// Current state: `draining` once shutdown began, else `degraded` while
/// pressure is unrecovered, else `ok`.
pub fn state() -> HealthState {
    if DRAINING.load(Ordering::Relaxed) != 0 {
        HealthState::Draining
    } else if PRESSURE.load(Ordering::Relaxed) > 0 {
        HealthState::Degraded
    } else {
        HealthState::Ok
    }
}

/// EWMA of recent step wall time, in milliseconds (0.0 before any step).
pub fn mean_step_ms() -> f64 {
    STEP_EWMA_US.load(Ordering::Relaxed) as f64 / 1000.0
}

/// How long a client should wait before retrying a full queue: every
/// queued request ahead of it costs roughly one mean step, floored at
/// 25 ms/request while no step has been measured yet and clamped to
/// `[1 ms, 60 s]`.
pub fn retry_after_ms(queue_depth: usize) -> u64 {
    let per_req = match mean_step_ms() {
        m if m > 0.0 => m,
        _ => 25.0,
    };
    (((queue_depth + 1) as f64) * per_req).ceil().clamp(1.0, 60_000.0) as u64
}

/// The `/healthz` body: state plus the evidence behind it.
pub fn healthz_json() -> String {
    format!(
        concat!(
            "{{\"status\":\"{}\",\"queue_depth\":{},\"pressure\":{},",
            "\"deadline_misses\":{},\"watchdog_flags\":{},\"mean_step_ms\":{:.3}}}"
        ),
        state().name(),
        LAST_DEPTH.load(Ordering::Relaxed),
        PRESSURE.load(Ordering::Relaxed),
        DEADLINE_MISSES.load(Ordering::Relaxed),
        WATCHDOG_FLAGS.load(Ordering::Relaxed),
        mean_step_ms(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// State is process-global: serialize tests that drive it.
    fn lock() -> std::sync::MutexGuard<'static, ()> {
        static M: std::sync::Mutex<()> = std::sync::Mutex::new(());
        M.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn pressure_cycle_ok_degraded_ok() {
        let _g = lock();
        reset();
        assert_eq!(state(), HealthState::Ok);
        note_deadline_miss();
        assert_eq!(state(), HealthState::Degraded);
        // PRESSURE_ADD healthy steps drain it back to ok
        for _ in 0..PRESSURE_ADD {
            assert_eq!(state(), HealthState::Degraded);
            note_step(0, 1.0);
        }
        assert_eq!(state(), HealthState::Ok);
        reset();
    }

    #[test]
    fn recovery_is_bounded_by_the_cap() {
        let _g = lock();
        reset();
        for _ in 0..1000 {
            note_deadline_miss();
        }
        note_step(0, STUCK_STEP_MS + 1.0); // stuck step also pins the cap
        for _ in 0..PRESSURE_CAP {
            note_step(0, 1.0);
        }
        assert_eq!(state(), HealthState::Ok, "cap must bound recovery time");
        reset();
    }

    #[test]
    fn draining_wins_and_reset_clears_it() {
        let _g = lock();
        reset();
        set_draining();
        note_step(0, 1.0);
        assert_eq!(state(), HealthState::Draining);
        assert!(healthz_json().contains("\"status\":\"draining\""));
        reset();
        assert_eq!(state(), HealthState::Ok);
    }

    #[test]
    fn retry_after_scales_with_depth_and_step_time() {
        let _g = lock();
        reset();
        // no steps yet: 25 ms per queued request
        assert_eq!(retry_after_ms(0), 25);
        assert_eq!(retry_after_ms(3), 100);
        for _ in 0..64 {
            note_step(0, 8.0); // converge the EWMA near 8 ms
        }
        let est = retry_after_ms(4);
        assert!((30..=60).contains(&est), "estimate {est} out of range");
        reset();
    }

    #[test]
    fn watchdog_classifies_slow_and_stuck() {
        let _g = lock();
        reset();
        let slow0 = obs::get(Counter::WatchdogSlowSteps);
        let stuck0 = obs::get(Counter::WatchdogStuckSteps);
        let on = obs::enabled();
        obs::set_enabled(true);
        note_step(2, SLOW_STEP_MS + 1.0);
        note_step(2, STUCK_STEP_MS + 1.0);
        note_step(2, 1.0);
        obs::set_enabled(on);
        assert_eq!(obs::get(Counter::WatchdogSlowSteps) - slow0, 1);
        assert_eq!(obs::get(Counter::WatchdogStuckSteps) - stuck0, 1);
        let body = healthz_json();
        assert!(body.contains("\"watchdog_flags\":2"), "{body}");
        assert!(body.contains("\"queue_depth\":2"), "{body}");
        reset();
    }
}
