//! `faults` — deterministic fault injection for the serve stack.
//!
//! Chaos testing is only useful if the chaos is reproducible: a failure
//! found under a fault plan must replay bit-for-bit on the next run. So
//! this module injects faults at **planned trigger counts**, not random
//! coin flips. Each injection [`Site`] keeps a process-global hit
//! counter; a plan arms a site with "fire on the Nth hit" (optionally
//! repeating every P hits after that), and the Nth hit fires no matter
//! which thread lands on it. With the same plan and the same request
//! stream, the same hits fire.
//!
//! ### Spec grammar (`--faults SPEC` / `SILQ_FAULTS`)
//!
//! ```text
//!   SPEC   := entry ("," entry)*
//!   entry  := site "@" nth ["+" period] [":" ms]   |  "seed=" u64
//!   site   := "kv" | "lat" | "torn" | "stall" | "full"
//! ```
//!
//! - `kv@N[+P]` — the Nth [`Site::KvAlloc`] attempt fails: the KV pool
//!   reports exhaustion, which the engine must absorb as a typed reject.
//! - `lat@N[+P]:MS` — the Nth kernel-pool job sleeps `MS` ms before
//!   running, simulating a stalled shard (drives the step watchdog).
//! - `torn@N[+P]` — the Nth streamed HTTP chunk write is torn: half the
//!   frame goes out, then the write fails as a broken pipe.
//! - `stall@N[+P]:MS` — the Nth wire-client request pauses `MS` ms
//!   between its header block and its body (a cooperative slowloris,
//!   exercising the server's read-timeout guard from inside the suite).
//! - `full@N[+P]` — the Nth admission-queue `try_submit` is forced to
//!   report `Full` regardless of actual depth (deterministic 429 +
//!   `Retry-After` coverage).
//! - `seed=N` — recorded for harnesses ([`seed`]): the chaos soak derives
//!   its request mix from it so plan + seed fully determine a run. The
//!   trigger counts themselves are exact, never sampled.
//!
//! ### Cost discipline
//!
//! Same rules as [`crate::obs`]: disabled means **one relaxed atomic
//! load** per site hit and nothing else — no allocation, no locks — so
//! the zero-alloc decode pins and the identity suites hold unchanged
//! when no plan is armed. Armed sites stay lock-free (fetch_add + a few
//! loads); only [`configure`]/[`clear`] write the plan.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Where a fault can be injected. Each site owns one global hit counter;
/// the variant order is the storage index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum Site {
    /// [`crate::hostmodel::KvPool::alloc`] — a fired hit allocates nothing
    /// and returns `None` (pool exhausted).
    KvAlloc = 0,
    /// [`crate::kernels::pool::run`] — a fired hit sleeps the armed
    /// latency before the job runs.
    Shard = 1,
    /// `net::http::write_chunk` — a fired hit writes half the chunk and
    /// then fails with `BrokenPipe`.
    NetWrite = 2,
    /// `net::client` request writes — a fired hit flushes the header
    /// block, sleeps the armed latency, then sends the body.
    ClientStall = 3,
    /// [`crate::serve::AdmissionQueue::try_submit`] — a fired hit reports
    /// `Full` without enqueueing.
    Submit = 4,
}

pub const N_SITES: usize = 5;

impl Site {
    pub const ALL: [Site; N_SITES] =
        [Site::KvAlloc, Site::Shard, Site::NetWrite, Site::ClientStall, Site::Submit];

    /// Spec-grammar name of the site.
    pub fn name(self) -> &'static str {
        match self {
            Site::KvAlloc => "kv",
            Site::Shard => "lat",
            Site::NetWrite => "torn",
            Site::ClientStall => "stall",
            Site::Submit => "full",
        }
    }

    fn from_name(s: &str) -> Option<Site> {
        Site::ALL.into_iter().find(|site| site.name() == s)
    }
}

/// Per-site plan + bookkeeping. `trigger == 0` means the site is unarmed
/// (spec counts are 1-based, so 0 is never a valid trigger).
struct SiteState {
    /// fire on this hit number (1-based; 0 = unarmed)
    trigger: AtomicU64,
    /// after `trigger`, fire again every `period` hits (0 = once only)
    period: AtomicU64,
    /// site parameter — latency in ms for `lat` / `stall`
    param_ms: AtomicU64,
    /// total site invocations since the last [`configure`]/[`clear`]
    hits: AtomicU64,
    /// how many of those actually fired
    injected: AtomicU64,
}

#[allow(clippy::declare_interior_mutable_const)] // per-element array init
const SITE_INIT: SiteState = SiteState {
    trigger: AtomicU64::new(0),
    period: AtomicU64::new(0),
    param_ms: AtomicU64::new(0),
    hits: AtomicU64::new(0),
    injected: AtomicU64::new(0),
};

static SITES: [SiteState; N_SITES] = [SITE_INIT; N_SITES];

/// Master switch — the only thing the hot path reads when no plan is
/// armed.
static ACTIVE: AtomicBool = AtomicBool::new(false);

/// Plan seed (`seed=N`), for harnesses that derive their traffic from the
/// fault plan.
static SEED: AtomicU64 = AtomicU64::new(0);

/// Is any fault plan armed? One relaxed load.
#[inline]
pub fn enabled() -> bool {
    ACTIVE.load(Ordering::Relaxed)
}

/// The hot-path hook: count one hit at `site` and report whether the
/// planned fault fires on it. Always `false` (after a single relaxed
/// load) when no plan is armed.
#[inline]
pub fn should_inject(site: Site) -> bool {
    if !enabled() {
        return false;
    }
    fire(&SITES[site as usize])
}

#[cold]
fn fire(s: &SiteState) -> bool {
    let n = s.hits.fetch_add(1, Ordering::Relaxed) + 1;
    let trigger = s.trigger.load(Ordering::Relaxed);
    if trigger == 0 || n < trigger {
        return false;
    }
    let period = s.period.load(Ordering::Relaxed);
    let hit = n == trigger || (period > 0 && (n - trigger) % period == 0);
    if hit {
        s.injected.fetch_add(1, Ordering::Relaxed);
        crate::obs::add(crate::obs::Counter::FaultsInjected, 1);
    }
    hit
}

/// The armed latency (ms) for a site — what a fired `lat`/`stall` hit
/// should sleep.
pub fn latency_ms(site: Site) -> u64 {
    SITES[site as usize].param_ms.load(Ordering::Relaxed)
}

/// The plan seed (`seed=N`, default 0).
pub fn seed() -> u64 {
    SEED.load(Ordering::Relaxed)
}

/// `(site name, hits, injected)` for every site — for logs and soak
/// assertions.
pub fn report() -> Vec<(&'static str, u64, u64)> {
    Site::ALL
        .iter()
        .map(|&site| {
            let s = &SITES[site as usize];
            (site.name(), s.hits.load(Ordering::Relaxed), s.injected.load(Ordering::Relaxed))
        })
        .collect()
}

/// Disarm everything and zero all counters.
pub fn clear() {
    ACTIVE.store(false, Ordering::Relaxed);
    SEED.store(0, Ordering::Relaxed);
    for s in &SITES {
        s.trigger.store(0, Ordering::Relaxed);
        s.period.store(0, Ordering::Relaxed);
        s.param_ms.store(0, Ordering::Relaxed);
        s.hits.store(0, Ordering::Relaxed);
        s.injected.store(0, Ordering::Relaxed);
    }
}

/// Parse and arm a fault plan (see the module docs for the grammar).
/// Replaces any previous plan; an empty spec is an error (use [`clear`]
/// to disarm).
pub fn configure(spec: &str) -> Result<(), String> {
    let spec = spec.trim();
    if spec.is_empty() {
        return Err("empty fault spec".into());
    }
    // parse into a scratch plan first so a bad entry leaves the armed
    // plan untouched
    let mut plan: Vec<(Site, u64, u64, u64)> = Vec::new();
    let mut seed = 0u64;
    for entry in spec.split(',') {
        let entry = entry.trim();
        if let Some(v) = entry.strip_prefix("seed=") {
            seed = v.parse().map_err(|_| format!("bad seed `{v}`"))?;
            continue;
        }
        let (name, rest) =
            entry.split_once('@').ok_or_else(|| format!("`{entry}`: expected site@nth"))?;
        let site = Site::from_name(name)
            .ok_or_else(|| format!("unknown fault site `{name}` (kv|lat|torn|stall|full)"))?;
        let (count, ms) = match rest.split_once(':') {
            Some((c, m)) => (c, m.parse().map_err(|_| format!("`{entry}`: bad ms `{m}`"))?),
            None => (rest, 0u64),
        };
        let (nth, period) = match count.split_once('+') {
            Some((n, p)) => (
                n.parse().map_err(|_| format!("`{entry}`: bad nth `{n}`"))?,
                p.parse().map_err(|_| format!("`{entry}`: bad period `{p}`"))?,
            ),
            None => (count.parse().map_err(|_| format!("`{entry}`: bad nth `{count}`"))?, 0u64),
        };
        if nth == 0 {
            return Err(format!("`{entry}`: trigger counts are 1-based"));
        }
        if matches!(site, Site::Shard | Site::ClientStall) && ms == 0 {
            return Err(format!("`{entry}`: {} needs `:ms`", site.name()));
        }
        plan.push((site, nth, period, ms));
    }
    clear();
    SEED.store(seed, Ordering::Relaxed);
    for (site, nth, period, ms) in plan {
        let s = &SITES[site as usize];
        s.trigger.store(nth, Ordering::Relaxed);
        s.period.store(period, Ordering::Relaxed);
        s.param_ms.store(ms, Ordering::Relaxed);
    }
    ACTIVE.store(true, Ordering::Relaxed);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Plan state is process-global; serialize the tests that touch it.
    fn lock() -> std::sync::MutexGuard<'static, ()> {
        static M: std::sync::Mutex<()> = std::sync::Mutex::new(());
        M.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disabled_sites_never_fire_and_count_nothing() {
        let _g = lock();
        clear();
        for _ in 0..100 {
            assert!(!should_inject(Site::KvAlloc));
        }
        // hits are not even counted while disarmed
        assert!(report().iter().all(|&(_, h, i)| h == 0 && i == 0));
    }

    #[test]
    fn nth_hit_fires_exactly_once_without_period() {
        let _g = lock();
        configure("kv@3").unwrap();
        let fired: Vec<bool> = (0..8).map(|_| should_inject(Site::KvAlloc)).collect();
        assert_eq!(fired, [false, false, true, false, false, false, false, false]);
        let (_, hits, injected) = report()[Site::KvAlloc as usize];
        assert_eq!((hits, injected), (8, 1));
        clear();
    }

    #[test]
    fn periodic_triggers_repeat_and_params_stick() {
        let _g = lock();
        configure("lat@2+3:150, seed=7").unwrap();
        assert_eq!(latency_ms(Site::Shard), 150);
        assert_eq!(seed(), 7);
        let fired: Vec<usize> = (1..=11usize).filter(|_| should_inject(Site::Shard)).collect();
        // fires on hits 2, 5, 8, 11
        assert_eq!(fired.len(), 4);
        // other sites stay silent
        assert!(!should_inject(Site::NetWrite));
        clear();
    }

    #[test]
    fn spec_errors_are_rejected_and_leave_plan_unarmed() {
        let _g = lock();
        clear();
        for bad in ["", "bogus@1", "kv", "kv@0", "kv@x", "lat@3", "stall@2", "kv@1+z", "seed=x"] {
            assert!(configure(bad).is_err(), "spec `{bad}` should be rejected");
        }
        assert!(!enabled());
    }

    #[test]
    fn reconfigure_replaces_the_whole_plan() {
        let _g = lock();
        configure("kv@1").unwrap();
        assert!(should_inject(Site::KvAlloc));
        configure("full@1").unwrap();
        // kv was re-zeroed: hit 1 of the new plan has no kv trigger
        assert!(!should_inject(Site::KvAlloc));
        assert!(should_inject(Site::Submit));
        clear();
    }
}
