//! Dense linear algebra substrate (no external crates available offline):
//! row-major matrices, matmul, Cholesky (GPTQ Hessians), one-sided Jacobi
//! SVD (Procrustes analysis), Hadamard/random rotations (SpinQuant-analog).

pub mod procrustes;
pub mod rotations;

pub use procrustes::{procrustes_distance, rotation_decomposition, RotationSplit};
pub use rotations::{hadamard, random_rotation};

use anyhow::{bail, Result};

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(rows * cols, data.len());
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    /// Cache-friendly ikj matmul.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            for kk in 0..k {
                let a = self.data[i * k + kk];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[kk * n..kk * n + n];
                let orow = &mut out.data[i * n..i * n + n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat::from_vec(
            self.rows,
            self.cols,
            self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        )
    }

    pub fn scale(&self, k: f32) -> Mat {
        Mat::from_vec(self.rows, self.cols, self.data.iter().map(|v| v * k).collect())
    }

    /// Multiply each row r by d[r] (diag(d) * M).
    pub fn scale_rows(&mut self, d: &[f32]) {
        assert_eq!(d.len(), self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                self.data[r * self.cols + c] *= d[r];
            }
        }
    }

    /// Multiply each column c by d[c] (M * diag(d)).
    pub fn scale_cols(&mut self, d: &[f32]) {
        assert_eq!(d.len(), self.cols);
        for r in 0..self.rows {
            for c in 0..self.cols {
                self.data[r * self.cols + c] *= d[c];
            }
        }
    }
}

/// Cholesky decomposition of a symmetric positive-definite matrix: A = L L^T.
/// Returns the lower-triangular L.
pub fn cholesky(a: &Mat) -> Result<Mat> {
    if a.rows != a.cols {
        bail!("cholesky: not square");
    }
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j) as f64;
            for k in 0..j {
                sum -= l.at(i, k) as f64 * l.at(j, k) as f64;
            }
            if i == j {
                if sum <= 0.0 {
                    bail!("cholesky: not positive definite at {i}");
                }
                l.set(i, j, (sum.sqrt()) as f32);
            } else {
                l.set(i, j, (sum / l.at(j, j) as f64) as f32);
            }
        }
    }
    Ok(l)
}

/// Invert an SPD matrix via Cholesky (A^-1 = L^-T L^-1).
pub fn spd_inverse(a: &Mat) -> Result<Mat> {
    let n = a.rows;
    let l = cholesky(a)?;
    // forward-solve L X = I  -> X = L^-1
    let mut linv = Mat::zeros(n, n);
    for col in 0..n {
        for i in 0..n {
            let mut sum = if i == col { 1.0f64 } else { 0.0 };
            for k in 0..i {
                sum -= l.at(i, k) as f64 * linv.at(k, col) as f64;
            }
            linv.set(i, col, (sum / l.at(i, i) as f64) as f32);
        }
    }
    // A^-1 = L^-T L^-1
    Ok(linv.transpose().matmul(&linv))
}

/// Singular values of a square matrix via one-sided Jacobi (on A^T A).
/// Sufficient for the Procrustes trace-norm; tolerances are fine at D<=512.
pub fn singular_values(a: &Mat) -> Vec<f64> {
    let mut u = a.transpose(); // rows = original cols; we orthogonalize rows
    let n = u.rows;
    let cols = u.cols;
    for _sweep in 0..30 {
        let mut off = 0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let (mut app, mut aqq, mut apq) = (0f64, 0f64, 0f64);
                for k in 0..cols {
                    let up = u.data[p * cols + k] as f64;
                    let uq = u.data[q * cols + k] as f64;
                    app += up * up;
                    aqq += uq * uq;
                    apq += up * uq;
                }
                off += apq.abs();
                if apq.abs() < 1e-12 * (app * aqq).sqrt().max(1e-30) {
                    continue;
                }
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for k in 0..cols {
                    let up = u.data[p * cols + k] as f64;
                    let uq = u.data[q * cols + k] as f64;
                    u.data[p * cols + k] = (c * up - s * uq) as f32;
                    u.data[q * cols + k] = (s * up + c * uq) as f32;
                }
            }
        }
        if off < 1e-10 {
            break;
        }
    }
    let mut sv: Vec<f64> = (0..n)
        .map(|r| {
            (0..cols)
                .map(|k| {
                    let v = u.data[r * cols + k] as f64;
                    v * v
                })
                .sum::<f64>()
                .sqrt()
        })
        .collect();
    sv.sort_by(|a, b| b.partial_cmp(a).unwrap());
    sv
}

/// Nuclear norm (sum of singular values).
pub fn nuclear_norm(a: &Mat) -> f64 {
    singular_values(a).iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randmat(rng: &mut Rng, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, rng.normal_vec(r * c, 1.0))
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(0);
        let a = randmat(&mut rng, 5, 5);
        let i = Mat::eye(5);
        assert_eq!(a.matmul(&i).data, a.data);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::from_vec(2, 2, vec![5., 6., 7., 8.]);
        assert_eq!(a.matmul(&b).data, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let a = randmat(&mut rng, 3, 7);
        assert_eq!(a.transpose().transpose().data, a.data);
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(2);
        let b = randmat(&mut rng, 8, 8);
        let mut a = b.matmul(&b.transpose());
        for i in 0..8 {
            a.data[i * 8 + i] += 8.0; // ensure SPD
        }
        let l = cholesky(&a).unwrap();
        let rec = l.matmul(&l.transpose());
        for (x, y) in rec.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigvals 3, -1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn spd_inverse_works() {
        let mut rng = Rng::new(3);
        let b = randmat(&mut rng, 6, 6);
        let mut a = b.matmul(&b.transpose());
        for i in 0..6 {
            a.data[i * 6 + i] += 6.0;
        }
        let ainv = spd_inverse(&a).unwrap();
        let id = a.matmul(&ainv);
        for i in 0..6 {
            for j in 0..6 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((id.at(i, j) - want).abs() < 1e-3, "({i},{j}) {}", id.at(i, j));
            }
        }
    }

    #[test]
    fn singular_values_of_diag() {
        let mut a = Mat::zeros(3, 3);
        a.set(0, 0, 3.0);
        a.set(1, 1, -2.0);
        a.set(2, 2, 1.0);
        let sv = singular_values(&a);
        assert!((sv[0] - 3.0).abs() < 1e-4);
        assert!((sv[1] - 2.0).abs() < 1e-4);
        assert!((sv[2] - 1.0).abs() < 1e-4);
    }

    #[test]
    fn singular_values_rotation_invariant() {
        let mut rng = Rng::new(4);
        let a = randmat(&mut rng, 16, 16);
        let r = rotations::random_rotation(16, &mut rng);
        let sv_a = singular_values(&a);
        let sv_ra = singular_values(&r.matmul(&a));
        for (x, y) in sv_a.iter().zip(&sv_ra) {
            assert!((x - y).abs() < 1e-2 * x.max(1.0), "{x} vs {y}");
        }
    }

    #[test]
    fn nuclear_norm_orthogonal_is_n() {
        let mut rng = Rng::new(5);
        let r = rotations::random_rotation(12, &mut rng);
        assert!((nuclear_norm(&r) - 12.0).abs() < 1e-3);
    }

    #[test]
    fn row_col_scaling() {
        let mut a = Mat::from_vec(2, 2, vec![1., 1., 1., 1.]);
        a.scale_rows(&[2.0, 3.0]);
        assert_eq!(a.data, vec![2., 2., 3., 3.]);
        a.scale_cols(&[1.0, 10.0]);
        assert_eq!(a.data, vec![2., 20., 3., 30.]);
    }
}
