//! Orthogonal rotation constructors for the SpinQuant-analog PTQ baseline
//! and the QuaRot-style online-rotation ablation.

use super::Mat;
use crate::util::Rng;

/// Normalized Walsh-Hadamard matrix (n must be a power of two): H H^T = I.
pub fn hadamard(n: usize) -> Mat {
    assert!(n.is_power_of_two(), "hadamard size must be a power of two");
    let mut h = vec![1.0f32];
    let mut size = 1;
    while size < n {
        let mut next = vec![0.0f32; 4 * size * size];
        let ns = 2 * size;
        for r in 0..size {
            for c in 0..size {
                let v = h[r * size + c];
                next[r * ns + c] = v;
                next[r * ns + c + size] = v;
                next[(r + size) * ns + c] = v;
                next[(r + size) * ns + c + size] = -v;
            }
        }
        h = next;
        size = ns;
    }
    let norm = 1.0 / (n as f32).sqrt();
    Mat::from_vec(n, n, h.into_iter().map(|v| v * norm).collect())
}

/// Random rotation from QR (modified Gram-Schmidt) of a Gaussian matrix,
/// sign-fixed so det-independent columns have positive diagonal R.
pub fn random_rotation(n: usize, rng: &mut Rng) -> Mat {
    let mut a = Mat::from_vec(n, n, rng.normal_vec(n * n, 1.0));
    // modified Gram-Schmidt on columns
    for c in 0..n {
        // normalize column c
        let mut norm = 0f64;
        for r in 0..n {
            norm += (a.at(r, c) as f64).powi(2);
        }
        let norm = norm.sqrt().max(1e-12) as f32;
        for r in 0..n {
            a.set(r, c, a.at(r, c) / norm);
        }
        // orthogonalize the rest
        for c2 in (c + 1)..n {
            let mut dot = 0f64;
            for r in 0..n {
                dot += a.at(r, c) as f64 * a.at(r, c2) as f64;
            }
            for r in 0..n {
                a.set(r, c2, a.at(r, c2) - (dot as f32) * a.at(r, c));
            }
        }
    }
    a
}

/// || R R^T - I ||_max — orthogonality defect, used by tests.
pub fn orthogonality_defect(r: &Mat) -> f32 {
    let g = r.matmul(&r.transpose());
    let n = r.rows;
    let mut worst = 0f32;
    for i in 0..n {
        for j in 0..n {
            let want = if i == j { 1.0 } else { 0.0 };
            worst = worst.max((g.at(i, j) - want).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hadamard_orthogonal() {
        for n in [2usize, 4, 8, 64, 128] {
            assert!(orthogonality_defect(&hadamard(n)) < 1e-4, "n={n}");
        }
    }

    #[test]
    fn hadamard_entries_uniform_magnitude() {
        let h = hadamard(16);
        let want = 1.0 / 4.0;
        assert!(h.data.iter().all(|v| (v.abs() - want).abs() < 1e-6));
    }

    #[test]
    #[should_panic]
    fn hadamard_rejects_non_pow2() {
        hadamard(12);
    }

    #[test]
    fn random_rotation_orthogonal() {
        let mut rng = Rng::new(7);
        for n in [4usize, 16, 64] {
            let r = random_rotation(n, &mut rng);
            assert!(orthogonality_defect(&r) < 1e-3, "n={n}");
        }
    }

    #[test]
    fn random_rotations_differ_by_seed() {
        let r1 = random_rotation(8, &mut Rng::new(1));
        let r2 = random_rotation(8, &mut Rng::new(2));
        assert_ne!(r1.data, r2.data);
    }

    #[test]
    fn rotation_preserves_norm() {
        let mut rng = Rng::new(9);
        let r = random_rotation(32, &mut rng);
        let x = Mat::from_vec(1, 32, rng.normal_vec(32, 1.0));
        let y = x.matmul(&r);
        assert!((x.frobenius() - y.frobenius()).abs() < 1e-3);
    }
}
