//! Orthogonal Procrustes analysis (paper section 3.4 / Figure 3).
//!
//! For weight matrices A (before) and B (after), the Procrustes distance
//! d_p(A, B) = min_R ||R A - B||_F over rotations R measures how much of the
//! change A -> B *cannot* be explained by a rotation; the paper computes it
//! for left- and right-side rotations and keeps the smaller. With
//! M = B A^T (left) or A^T B (right) and SVD M = U S V^T:
//!     d_p^2 = ||A||_F^2 + ||B||_F^2 - 2 * sum(S)  (the nuclear norm of M).

use super::{nuclear_norm, Mat};

/// Procrustes distance for one side. `left=true` solves min_R ||R A - B||.
pub fn procrustes_distance(a: &Mat, b: &Mat, left: bool) -> f64 {
    let m = if left { b.matmul(&a.transpose()) } else { a.transpose().matmul(b) };
    let na = a.frobenius();
    let nb = b.frobenius();
    let d2 = na * na + nb * nb - 2.0 * nuclear_norm(&m);
    d2.max(0.0).sqrt()
}

/// The decomposition Figure 3 plots, normalized by ||A||_F.
#[derive(Clone, Debug)]
pub struct RotationSplit {
    /// total relative change ||B - A||_F / ||A||_F
    pub total: f64,
    /// part not explainable by rotation: min-side Procrustes distance / ||A||_F
    pub non_rotational: f64,
    /// part explainable by rotation: total - non_rotational
    pub rotational: f64,
}

/// Decompose the change A -> B into rotational and non-rotational parts.
pub fn rotation_decomposition(a: &Mat, b: &Mat) -> RotationSplit {
    let na = a.frobenius().max(1e-12);
    let total = b.sub(a).frobenius() / na;
    let dp = procrustes_distance(a, b, true).min(procrustes_distance(a, b, false)) / na;
    let dp = dp.min(total); // numerical guard: rotation can only explain, not add
    RotationSplit { total, non_rotational: dp, rotational: total - dp }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rotations::random_rotation;
    use crate::util::Rng;

    fn randmat(rng: &mut Rng, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, rng.normal_vec(r * c, 1.0))
    }

    #[test]
    fn identical_matrices_zero_distance() {
        let mut rng = Rng::new(0);
        let a = randmat(&mut rng, 12, 12);
        let s = rotation_decomposition(&a, &a);
        assert!(s.total < 1e-6 && s.non_rotational < 1e-3);
    }

    #[test]
    fn pure_rotation_fully_explained() {
        let mut rng = Rng::new(1);
        let a = randmat(&mut rng, 16, 16);
        let r = random_rotation(16, &mut rng);
        let b = r.matmul(&a); // pure left rotation
        let s = rotation_decomposition(&a, &b);
        assert!(s.non_rotational < 0.02 * s.total.max(1.0), "non-rot {}", s.non_rotational);
        assert!(s.rotational > 0.5, "rotation should dominate: {:?}", s);
    }

    #[test]
    fn right_rotation_also_detected() {
        let mut rng = Rng::new(2);
        let a = randmat(&mut rng, 16, 16);
        let r = random_rotation(16, &mut rng);
        let b = a.matmul(&r);
        let s = rotation_decomposition(&a, &b);
        assert!(s.non_rotational < 0.02 * s.total.max(1.0));
    }

    #[test]
    fn random_perturbation_mostly_non_rotational() {
        let mut rng = Rng::new(3);
        let a = randmat(&mut rng, 16, 16);
        let noise = randmat(&mut rng, 16, 16).scale(0.3);
        let mut b = a.clone();
        for (x, n) in b.data.iter_mut().zip(&noise.data) {
            *x += n;
        }
        let s = rotation_decomposition(&a, &b);
        assert!(s.non_rotational > 0.5 * s.total, "{:?}", s);
    }

    #[test]
    fn scaling_is_non_rotational() {
        let mut rng = Rng::new(4);
        let a = randmat(&mut rng, 8, 8);
        let b = a.scale(2.0);
        let s = rotation_decomposition(&a, &b);
        // doubling is not a rotation: non-rotational ~ ||A|| (relative 1.0)
        assert!(s.non_rotational > 0.9, "{:?}", s);
    }

    #[test]
    fn procrustes_symmetric_under_side_choice_for_square() {
        let mut rng = Rng::new(5);
        let a = randmat(&mut rng, 10, 10);
        let b = randmat(&mut rng, 10, 10);
        let l = procrustes_distance(&a, &b, true);
        let r = procrustes_distance(&a, &b, false);
        assert!(l.is_finite() && r.is_finite());
        assert!(l >= 0.0 && r >= 0.0);
    }
}
