//! Post-training quantization baselines, sharing the paper's hardware
//! constraints (per-tensor/static or per-token/dynamic activations, per-
//! channel weights, quantized head):
//!
//! * [`rtn`] — round-to-nearest with MSE-calibrated steps (the substrate
//!   every other method finishes with).
//! * [`smoothquant`] — Xiao et al.: α-migration of activation outliers into
//!   the weights, folded into the preceding RMSNorm gains.
//! * [`gptq`] — Frantar et al.: Hessian-guided sequential rounding using
//!   the calib artifact's Gram matrices.
//! * [`spinquant`] — Liu et al. analog: an orthogonal residual-stream
//!   rotation folded into the weights, then GPTQ. The "learned" rotation is
//!   proxied by candidate search (Hadamard + random QR rotations, pick the
//!   lowest post-rotation weight-quantization MSE — see DESIGN.md §2).

use anyhow::Result;

use crate::config::ModelCfg;
use crate::linalg::{hadamard, random_rotation, Mat};
use crate::model::ParamStore;
use crate::policy::QuantPolicy;
use crate::quant;
use crate::train::calibrate::{calibrate_weight_steps, CalibStats};
use crate::util::Rng;

pub mod gptq;
pub use gptq::gptq_quantize_family;

/// RTN: calibrate per-channel weight steps under the policy's weight
/// calibration. The quantization itself happens inside the model's
/// fake-quant ops at run time.
pub fn rtn(qs: &mut ParamStore, policy: &QuantPolicy) -> Result<()> {
    calibrate_weight_steps(qs, policy)
}

/// SmoothQuant α-migration: for each norm-fed linear family, scale channel
/// j of the input down by s_j and the corresponding weight row up, with
/// s_j = cmax_j^α / wmax_j^(1-α). The input-side scaling folds exactly into
/// the RMSNorm gain, so the fp function is unchanged.
pub fn smoothquant(
    qs: &mut ParamStore,
    mc: &ModelCfg,
    policy: &QuantPolicy,
    stats: &CalibStats,
    alpha: f32,
) -> Result<()> {
    let (l, d) = (mc.n_layers, mc.d_model);
    // family: (norm param, [weights consuming the norm output], stat name)
    let fams: [(&str, Vec<&str>, &str); 2] = [
        ("ln1", vec!["wq", "wk", "wv"], "cmax_x1"),
        ("ln2", vec!["wg", "wu"], "cmax_x2"),
    ];
    for (norm, weights, stat) in fams {
        let (_, cmax) = stats.get(stat).clone();
        for li in 0..l {
            // wmax_j = max |W[j, :]| across the family's weights
            let mut wmax = vec![0f32; d];
            for wn in &weights {
                let shape = qs.shape(wn)?.to_vec();
                let n = shape[2];
                let w = qs.get(wn)?;
                let base = li * d * n;
                for j in 0..d {
                    for c in 0..n {
                        wmax[j] = wmax[j].max(w[base + j * n + c].abs());
                    }
                }
            }
            // migration scales
            let mut s = vec![1f32; d];
            for j in 0..d {
                let a = cmax[li * d + j].max(1e-5);
                let b = wmax[j].max(1e-5);
                s[j] = (a.powf(alpha) / b.powf(1.0 - alpha)).clamp(1e-3, 1e3);
            }
            // fold into the norm gain and the weight rows
            {
                let g = qs.get_mut(norm)?;
                for j in 0..d {
                    g[li * d + j] /= s[j];
                }
            }
            for wn in &weights {
                let shape = qs.shape(wn)?.to_vec();
                let n = shape[2];
                let w = qs.get_mut(wn)?;
                let base = li * d * n;
                for j in 0..d {
                    for c in 0..n {
                        w[base + j * n + c] *= s[j];
                    }
                }
            }
        }
    }
    calibrate_weight_steps(qs, policy)
}

/// GPTQ over every linear family using the calib Gram matrices as Hessians.
pub fn gptq(
    qs: &mut ParamStore,
    _mc: &ModelCfg,
    policy: &QuantPolicy,
    stats: &CalibStats,
) -> Result<()> {
    calibrate_weight_steps(qs, policy)?;
    let fams: [(&str, &str, &str, u32); 8] = [
        ("wq", "sw_q", "gram_x1", policy.weights.bits),
        ("wk", "sw_k", "gram_x1", policy.weights.bits),
        ("wv", "sw_v", "gram_x1", policy.weights.bits),
        ("wo", "sw_o", "gram_o", policy.weights.bits),
        ("wg", "sw_g", "gram_x2", policy.weights.bits),
        ("wu", "sw_u", "gram_x2", policy.weights.bits),
        ("wd", "sw_d", "gram_d", policy.weights.bits),
        ("head", "sw_head", "gram_head", policy.head.bits),
    ];
    for (wn, sn, gn, bits) in fams {
        let (gdims, gdata) = stats.get(gn).clone();
        let wshape = qs.shape(wn)?.to_vec();
        if wshape.len() == 3 {
            let (l, k, n) = (wshape[0], wshape[1], wshape[2]);
            for li in 0..l {
                let gram = Mat::from_vec(k, k, gdata[li * k * k..(li + 1) * k * k].to_vec());
                let steps = qs.get(sn)?[li * n..(li + 1) * n].to_vec();
                let w = qs.get_mut(wn)?;
                gptq_quantize_family(&mut w[li * k * n..(li + 1) * k * n], k, n, &gram, &steps, bits)?;
            }
        } else {
            let (k, n) = (wshape[0], wshape[1]);
            anyhow::ensure!(gdims == vec![k, k], "gram dims");
            let gram = Mat::from_vec(k, k, gdata.clone());
            let steps = qs.get(sn)?.to_vec();
            let w = qs.get_mut(wn)?;
            gptq_quantize_family(w, k, n, &gram, &steps, bits)?;
        }
    }
    Ok(())
}

/// Fold every RMSNorm gain into its consumer weights (γ := 1). Required
/// before rotations (RMSNorm commutes with rotations only when γ = 1).
pub fn fold_norms(qs: &mut ParamStore, mc: &ModelCfg) -> Result<()> {
    let (l, d) = (mc.n_layers, mc.d_model);
    let fams: [(&str, Vec<&str>); 2] = [("ln1", vec!["wq", "wk", "wv"]), ("ln2", vec!["wg", "wu"])];
    for (norm, weights) in fams {
        for li in 0..l {
            let gamma = qs.get(norm)?[li * d..(li + 1) * d].to_vec();
            for wn in &weights {
                let n = qs.shape(wn)?[2];
                let w = qs.get_mut(wn)?;
                let base = li * d * n;
                for j in 0..d {
                    for c in 0..n {
                        w[base + j * n + c] *= gamma[j];
                    }
                }
            }
            let g = qs.get_mut(norm)?;
            for j in 0..d {
                g[li * d + j] = 1.0;
            }
        }
    }
    // final norm -> head
    let gamma = qs.get("ln_f")?.to_vec();
    let n = qs.shape("head")?[1];
    let head = qs.get_mut("head")?;
    for j in 0..d {
        for c in 0..n {
            head[j * n + c] *= gamma[j];
        }
    }
    let g = qs.get_mut("ln_f")?;
    for v in g.iter_mut() {
        *v = 1.0;
    }
    Ok(())
}

/// Apply a residual-stream rotation R to the folded model:
/// embed := embed R;  input-side weights := R^T W;  output-side := W R;
/// head := R^T head. The fp function is exactly preserved (γ = 1).
pub fn apply_rotation(qs: &mut ParamStore, mc: &ModelCfg, r: &Mat) -> Result<()> {
    let (l, d) = (mc.n_layers, mc.d_model);
    anyhow::ensure!(r.rows == d && r.cols == d);
    let rt = r.transpose();

    // embed [V, D] -> embed @ R
    {
        let v = qs.shape("embed")?[0];
        let e = qs.get("embed")?.to_vec();
        let rotated = Mat::from_vec(v, d, e).matmul(r);
        qs.set("embed", rotated.data)?;
    }
    // input-side (R^T W): wq wk wv wg wu ; output-side (W R): wo wd
    for li in 0..l {
        for wn in ["wq", "wk", "wv", "wg", "wu"] {
            let n = qs.shape(wn)?[2];
            let w = qs.get(wn)?[li * d * n..(li + 1) * d * n].to_vec();
            let rotated = rt.matmul(&Mat::from_vec(d, n, w));
            qs.get_mut(wn)?[li * d * n..(li + 1) * d * n].copy_from_slice(&rotated.data);
        }
        for wn in ["wo", "wd"] {
            let k = qs.shape(wn)?[1];
            let w = qs.get(wn)?[li * k * d..(li + 1) * k * d].to_vec();
            let rotated = Mat::from_vec(k, d, w).matmul(r);
            qs.get_mut(wn)?[li * k * d..(li + 1) * k * d].copy_from_slice(&rotated.data);
        }
    }
    // head [D, V] -> R^T head
    {
        let v = qs.shape("head")?[1];
        let h = qs.get("head")?.to_vec();
        let rotated = rt.matmul(&Mat::from_vec(d, v, h));
        qs.set("head", rotated.data)?;
    }
    Ok(())
}

/// Total per-channel weight quantization MSE of the store (rotation
/// candidate selection objective).
pub fn total_weight_mse(qs: &ParamStore, policy: &QuantPolicy) -> Result<f64> {
    let wb = policy.weights.bits;
    let mut total = 0f64;
    for wn in ["wq", "wk", "wv", "wo", "wg", "wu", "wd"] {
        let shape = qs.shape(wn)?.to_vec();
        let n = shape[shape.len() - 1];
        let w = qs.get(wn)?;
        for chunk in w.chunks(shape[shape.len() - 2] * n) {
            let steps = quant::calib::weight_step_mse_per_channel(chunk, n, wb);
            let mut q = chunk.to_vec();
            quant::fake_quant_per_channel(&mut q, n, &steps, wb);
            total += q.iter().zip(chunk).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>();
        }
    }
    Ok(total)
}

/// SpinQuant-analog: fold norms, pick the best rotation among Hadamard and
/// `n_candidates` random rotations (weight-MSE proxy for the paper's Cayley
/// optimization), apply it, then GPTQ with rotated Hessians.
pub fn spinquant(
    qs: &mut ParamStore,
    mc: &ModelCfg,
    policy: &QuantPolicy,
    stats: &CalibStats,
    n_candidates: usize,
    seed: u64,
) -> Result<()> {
    fold_norms(qs, mc)?;

    let d = mc.d_model;
    let mut rng = Rng::new(seed ^ 0x5417);
    let mut cands = vec![hadamard(d)];
    for _ in 0..n_candidates {
        cands.push(random_rotation(d, &mut rng));
    }
    let mut best: Option<(f64, Mat)> = None;
    for r in cands {
        let mut trial = qs.clone();
        apply_rotation(&mut trial, mc, &r)?;
        let mse = total_weight_mse(&trial, policy)?;
        if best.as_ref().map(|(b, _)| mse < *b).unwrap_or(true) {
            best = Some((mse, r));
        }
    }
    let (_, r) = best.unwrap();
    apply_rotation(qs, mc, &r)?;

    // rotate the Hessians of the rotated-input families: G' = R^T G R
    let mut stats2 = stats.clone();
    for gn in ["gram_x1", "gram_x2", "gram_head"] {
        let (dims, data) = stats2.tensors.get(gn).unwrap().clone();
        let rt = r.transpose();
        let mut out = data.clone();
        if dims.len() == 3 {
            for li in 0..dims[0] {
                let g = Mat::from_vec(d, d, data[li * d * d..(li + 1) * d * d].to_vec());
                let rotated = rt.matmul(&g).matmul(&r);
                out[li * d * d..(li + 1) * d * d].copy_from_slice(&rotated.data);
            }
        } else {
            let g = Mat::from_vec(d, d, data.clone());
            out = rt.matmul(&g).matmul(&r).data;
        }
        stats2.tensors.insert(gn.to_string(), (dims, out));
    }
    gptq(qs, mc, policy, &stats2)
}

#[cfg(test)]
mod tests {
    use super::*;

    // host-side reference forward is impractical here; fold/rotation
    // function-preservation is asserted end-to-end in rust/tests/
    // ptq_integration.rs against the PJRT model. Unit tests below cover the
    // pure math.

    #[test]
    fn smoothquant_scale_formula_monotonic() {
        // bigger activation max -> bigger migration scale
        let s1 = (10f32.powf(0.5)) / (1f32.powf(0.5));
        let s2 = (100f32.powf(0.5)) / (1f32.powf(0.5));
        assert!(s2 > s1);
    }
}
