//! GPTQ core (Frantar et al., 2022): sequential per-row rounding with
//! optimal-brain-surgeon error compensation, using the Cholesky factor of
//! the inverse Hessian.

use anyhow::Result;

use crate::linalg::{cholesky, spd_inverse, Mat};
use crate::quant::{fake_quant_scalar, EPS};

/// Quantize a row-major [K, N] weight matrix in place.
///
/// `gram` is X^T X of the layer inputs ([K, K]); `steps[c]` the per-output-
/// channel step; rows are processed in order, each row's rounding error
/// propagated into the not-yet-quantized rows via the upper Cholesky factor
/// of H^-1 (the standard GPTQ update).
pub fn gptq_quantize_family(
    w: &mut [f32],
    k: usize,
    n: usize,
    gram: &Mat,
    steps: &[f32],
    bits: u32,
) -> Result<()> {
    anyhow::ensure!(w.len() == k * n && steps.len() == n && gram.rows == k);

    // damped Hessian: H = G + lambda I
    let mut h = gram.clone();
    let mean_diag: f64 =
        (0..k).map(|i| h.at(i, i) as f64).sum::<f64>() / k as f64;
    let damp = (0.01 * mean_diag).max(1e-6) as f32;
    for i in 0..k {
        h.set(i, i, h.at(i, i) + damp);
    }

    // U = upper Cholesky factor of H^-1  (Hinv = U^T U with U upper... we
    // use L from cholesky(Hinv): Hinv = L L^T, and read U = L^T)
    let hinv = spd_inverse(&h)?;
    let l = cholesky(&hinv)?;

    for r in 0..k {
        let d = l.at(r, r).max(EPS);
        // quantize row r, compensate rows > r
        for c in 0..n {
            let wv = w[r * n + c];
            let q = fake_quant_scalar(wv, steps[c], bits);
            let err = (wv - q) / d;
            w[r * n + c] = q;
            for rr in (r + 1)..k {
                // L[rr, r] is column r of the lower factor == row r of U
                w[rr * n + c] -= err * l.at(rr, r);
            }
        }
    }
    Ok(())
}

/// Reconstruction error ||X(W - Wq)||^2 proxy: tr((W-Wq)^T H (W-Wq)).
pub fn reconstruction_error(w0: &[f32], wq: &[f32], k: usize, n: usize, gram: &Mat) -> f64 {
    let mut delta = Mat::zeros(k, n);
    for i in 0..k * n {
        delta.data[i] = w0[i] - wq[i];
    }
    let hd = gram.matmul(&delta);
    let mut tr = 0f64;
    for i in 0..k * n {
        tr += delta.data[i] as f64 * hd.data[i] as f64;
    }
    tr
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{calib::weight_step_mse_per_channel, fake_quant_per_channel};
    use crate::util::Rng;

    fn random_problem(seed: u64, k: usize, n: usize, nsamples: usize) -> (Vec<f32>, Mat) {
        let mut rng = Rng::new(seed);
        let w = rng.normal_vec(k * n, 0.5);
        // correlated inputs -> non-trivial Hessian
        let mut gram = Mat::zeros(k, k);
        for _ in 0..nsamples {
            let base = rng.normal_vec(k, 1.0);
            let x: Vec<f32> = base
                .iter()
                .enumerate()
                .map(|(i, &b)| b + if i > 0 { 0.7 * base[i - 1] } else { 0.0 })
                .collect();
            for i in 0..k {
                for j in 0..k {
                    gram.data[i * k + j] += x[i] * x[j];
                }
            }
        }
        (w, gram)
    }

    #[test]
    fn gptq_beats_rtn_on_reconstruction_error() {
        let (w0, gram) = random_problem(3, 24, 12, 256);
        let steps = weight_step_mse_per_channel(&w0, 12, 4);

        let mut rtn = w0.clone();
        fake_quant_per_channel(&mut rtn, 12, &steps, 4);
        let e_rtn = reconstruction_error(&w0, &rtn, 24, 12, &gram);

        let mut gq = w0.clone();
        gptq_quantize_family(&mut gq, 24, 12, &gram, &steps, 4).unwrap();
        let e_gptq = reconstruction_error(&w0, &gq, 24, 12, &gram);

        assert!(
            e_gptq < e_rtn,
            "GPTQ must reduce data-aware error: {e_gptq} vs {e_rtn}"
        );
    }

    #[test]
    fn gptq_output_on_quant_grid() {
        let (w0, gram) = random_problem(5, 16, 8, 128);
        let steps = weight_step_mse_per_channel(&w0, 8, 4);
        let mut gq = w0.clone();
        gptq_quantize_family(&mut gq, 16, 8, &gram, &steps, 4).unwrap();
        for r in 0..16 {
            for c in 0..8 {
                let v = gq[r * 8 + c] / steps[c];
                assert!((v - v.round()).abs() < 1e-3, "off grid at ({r},{c})");
                assert!((-8.0..=7.0).contains(&v.round()));
            }
        }
    }

    #[test]
    fn gptq_identity_hessian_equals_rtn() {
        // with H = I there is no correlation to exploit: GPTQ == RTN
        let mut rng = Rng::new(7);
        let w0 = rng.normal_vec(12 * 6, 0.3);
        let steps = weight_step_mse_per_channel(&w0, 6, 4);
        let gram = Mat::eye(12);
        let mut gq = w0.clone();
        gptq_quantize_family(&mut gq, 12, 6, &gram, &steps, 4).unwrap();
        let mut rtn = w0.clone();
        fake_quant_per_channel(&mut rtn, 6, &steps, 4);
        for (a, b) in gq.iter().zip(&rtn) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn rejects_bad_shapes() {
        let gram = Mat::eye(4);
        let mut w = vec![0.0; 12];
        assert!(gptq_quantize_family(&mut w, 4, 3, &gram, &[0.1, 0.1], 4).is_err());
    }
}
