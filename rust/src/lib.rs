//! # SiLQ — Simple Large Language Model Quantization-Aware Training
//!
//! Full-system reproduction of the SiLQ paper as a three-layer stack:
//! Rust coordinator (this crate) + JAX model + Pallas kernels, AOT-compiled
//! to HLO and executed through PJRT. See DESIGN.md for the architecture and
//! EXPERIMENTS.md for paper-vs-measured results.
//!
//! Layer map:
//! * [`runtime`] — PJRT engine loading `artifacts/*.hlo.txt`
//! * [`train`] — the SiLQ QAT pipeline (calibrate -> LSQ + KD end-to-end)
//! * [`ptq`] — baselines: RTN, SmoothQuant, GPTQ, SpinQuant-analog
//! * [`evalharness`] — CSR / OLLMv1 / OLLMv2 synthetic benchmark suites
//! * [`serve`] — continuous-batching inference engine + quantized KV pool
//! * [`data`] — SynthLang corpus + SFT dataset generators
//! * [`coordinator`] — one runner per paper table/figure

pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod evalharness;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod ptq;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod train;
pub mod util;
