//! # SiLQ — Simple Large Language Model Quantization-Aware Training
//!
//! Full-system reproduction of the SiLQ paper as a three-layer stack:
//! Rust coordinator (this crate) + JAX model + Pallas kernels, AOT-compiled
//! to HLO and executed through PJRT. See DESIGN.md for the architecture and
//! EXPERIMENTS.md for paper-vs-measured results.
//!
//! Layer map:
//! * [`runtime`] — PJRT engine loading `artifacts/*.hlo.txt`
//! * [`policy`] — the typed `QuantPolicy` precision API (spec strings,
//!   presets, manifest conversions) every layer below keys off
//! * [`hostmodel`] — the host quantized transformer + slab KV pool
//! * [`kernels`] — integer decode kernels: packed `i8` weights, fused
//!   quantized GEMV/GEMM, zero-copy int8 attention, `DecodeScratch`
//! * [`forward`] — `ForwardBackend`: batched logits + incremental decode,
//!   artifact (PJRT) and host implementations
//! * [`train`] — the SiLQ QAT pipeline (calibrate -> LSQ + KD end-to-end)
//! * [`ptq`] — baselines: RTN, SmoothQuant, GPTQ, SpinQuant-analog
//! * [`evalharness`] — CSR / OLLMv1 / OLLMv2 synthetic benchmark suites
//! * [`serve`] — continuous-batching inference engine over either backend
//! * [`net`] — HTTP/1.1 front-end over `serve` (streaming SSE
//!   completions, disconnect-as-cancellation, 429 backpressure) + the
//!   wire bench client
//! * [`obs`] — end-to-end telemetry: atomic counter registry, zero-alloc
//!   spans + trace ring, latency histograms, Chrome-trace export
//! * [`faults`] — deterministic fault injection (`--faults` plans) for
//!   the chaos suites; one relaxed load when disarmed
//! * [`data`] — SynthLang corpus + SFT dataset generators
//! * [`coordinator`] — one runner per paper table/figure

// Numeric-kernel idioms — explicit index loops over multiple parallel
// buffers, manual ceil-div on bit counts — trip these style lints without
// being clearer rewritten; the clippy gate stays at -D warnings for
// everything else.
#![allow(clippy::needless_range_loop, clippy::manual_div_ceil, clippy::too_many_arguments)]

pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod evalharness;
pub mod faults;
pub mod forward;
pub mod hostmodel;
pub mod kernels;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod net;
pub mod obs;
pub mod policy;
pub mod ptq;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod train;
pub mod util;
