//! Batch assembly with mixture sampling (the paper's 75% SFT / 25% DCLM
//! recipe) and a background prefetch thread so data generation never sits
//! on the training hot path.

use std::collections::VecDeque;
use std::sync::mpsc;

use crate::data::corpus::CorpusGen;
use crate::data::sft::{SftGen, SftStyle};
use crate::data::world::World;
use crate::util::Rng;

/// Which documents a batch draws from.
#[derive(Clone, Debug)]
pub enum DataMix {
    /// pre-training corpus only (base-model QAT / pretraining)
    Corpus,
    /// SFT style mixed with `dclm_ratio` of corpus documents (instruct QAT)
    Instruct { style: SftStyle, dclm_ratio: f32 },
    /// fixed set of pre-generated documents cycled forever (LLM-QAT's
    /// self-generated data)
    Fixed(Vec<Vec<i32>>),
}

/// Synchronous batcher: deterministic, used by tests and as the prefetch
/// thread's inner generator.
pub struct Batcher<'w> {
    mix: DataMix,
    corpus: CorpusGen<'w>,
    sft: SftGen<'w>,
    rng: Rng,
    fixed_pos: usize,
    pub batch: usize,
    pub seq_len: usize,
}

impl<'w> Batcher<'w> {
    pub fn new(world: &'w World, mix: DataMix, batch: usize, seq_len: usize, seed: u64) -> Self {
        let style = match &mix {
            DataMix::Instruct { style, .. } => *style,
            _ => SftStyle::TuluSynth,
        };
        let _ = world; // generators hold their own references
        Batcher {
            mix,
            corpus: CorpusGen::new(world, seed ^ 0xC0),
            sft: SftGen::new(world, style, seed ^ 0x5F),
            rng: Rng::new(seed ^ 0xBA),
            fixed_pos: 0,
            batch,
            seq_len,
        }
    }

    fn document(&mut self) -> Vec<i32> {
        match &self.mix {
            DataMix::Corpus => self.corpus.document(self.seq_len),
            DataMix::Instruct { dclm_ratio, .. } => {
                if self.rng.uniform() < *dclm_ratio {
                    self.corpus.document(self.seq_len)
                } else {
                    self.sft.document(self.seq_len)
                }
            }
            DataMix::Fixed(docs) => {
                let d = docs[self.fixed_pos % docs.len()].clone();
                self.fixed_pos += 1;
                let mut d = d;
                d.resize(self.seq_len, crate::data::vocab::PAD);
                d
            }
        }
    }

    /// Next `[batch * seq_len]` row-major token batch.
    pub fn next_batch(&mut self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.batch * self.seq_len);
        for _ in 0..self.batch {
            out.extend(self.document());
        }
        out
    }
}

/// Background prefetcher: runs a `Batcher` on its own thread with a bounded
/// channel, overlapping data generation with PJRT execution.
pub struct Prefetcher {
    rx: mpsc::Receiver<Vec<i32>>,
    _handle: std::thread::JoinHandle<()>,
}

impl Prefetcher {
    /// `world` is cloned into the thread (worlds are small).
    pub fn spawn(
        world: World,
        mix: DataMix,
        batch: usize,
        seq_len: usize,
        seed: u64,
        depth: usize,
    ) -> Prefetcher {
        let (tx, rx) = mpsc::sync_channel(depth);
        let handle = std::thread::spawn(move || {
            let mut b = Batcher::new(&world, mix, batch, seq_len, seed);
            loop {
                let batch = b.next_batch();
                if tx.send(batch).is_err() {
                    return; // consumer dropped
                }
            }
        });
        Prefetcher { rx, _handle: handle }
    }

    pub fn next(&self) -> Vec<i32> {
        self.rx.recv().expect("prefetch thread died")
    }
}

/// Deterministic eval-batch assembly: pack prompts (right-padded) into
/// fixed-shape [batch, seq_len] with their row indices.
pub fn pad_rows(rows: &[Vec<i32>], batch: usize, seq_len: usize) -> Vec<Vec<i32>> {
    let mut out = vec![];
    let mut cur: Vec<i32> = Vec::with_capacity(batch * seq_len);
    let mut q = VecDeque::from(rows.to_vec());
    while let Some(mut r) = q.pop_front() {
        r.truncate(seq_len);
        r.resize(seq_len, crate::data::vocab::PAD);
        cur.extend(r);
        if cur.len() == batch * seq_len {
            out.push(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        cur.resize(batch * seq_len, crate::data::vocab::PAD);
        out.push(cur);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::vocab::{Vocab, PAD, Q};

    fn setup() -> World {
        World::generate(Vocab::new(256), 41)
    }

    #[test]
    fn batch_shape() {
        let w = setup();
        let mut b = Batcher::new(&w, DataMix::Corpus, 4, 32, 0);
        let batch = b.next_batch();
        assert_eq!(batch.len(), 4 * 32);
    }

    #[test]
    fn mixture_ratio_respected() {
        let w = setup();
        let mut b = Batcher::new(
            &w,
            DataMix::Instruct { style: SftStyle::TuluSynth, dclm_ratio: 0.25 },
            1,
            64,
            7,
        );
        let mut sft_docs = 0;
        let n = 400;
        for _ in 0..n {
            let doc = b.next_batch();
            if doc.contains(&Q) {
                sft_docs += 1;
            }
        }
        let frac = sft_docs as f32 / n as f32;
        assert!((frac - 0.75).abs() < 0.08, "sft fraction {frac}");
    }

    #[test]
    fn fixed_mix_cycles() {
        let w = setup();
        let docs = vec![vec![1, 2, 3], vec![4, 5, 6]];
        let mut b = Batcher::new(&w, DataMix::Fixed(docs), 1, 4, 0);
        assert_eq!(b.next_batch(), vec![1, 2, 3, PAD]);
        assert_eq!(b.next_batch(), vec![4, 5, 6, PAD]);
        assert_eq!(b.next_batch(), vec![1, 2, 3, PAD]);
    }

    #[test]
    fn prefetcher_streams() {
        let w = setup();
        let p = Prefetcher::spawn(w, DataMix::Corpus, 2, 16, 3, 4);
        let a = p.next();
        let b = p.next();
        assert_eq!(a.len(), 32);
        assert_eq!(b.len(), 32);
        assert_ne!(a, b);
    }

    #[test]
    fn prefetcher_matches_sync_batcher() {
        let w = setup();
        let mut sync = Batcher::new(&w, DataMix::Corpus, 2, 16, 5);
        let p = Prefetcher::spawn(w.clone(), DataMix::Corpus, 2, 16, 5, 2);
        for _ in 0..5 {
            assert_eq!(p.next(), sync.next_batch());
        }
    }

    #[test]
    fn pad_rows_shapes() {
        let rows = vec![vec![1, 2], vec![3, 4, 5, 6, 7], vec![8]];
        let batches = pad_rows(&rows, 2, 4);
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0], vec![1, 2, 0, 0, 3, 4, 5, 6]);
        assert_eq!(batches[1], vec![8, 0, 0, 0, 0, 0, 0, 0]);
    }
}
