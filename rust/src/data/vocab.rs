//! Token-id layout of SynthLang. Fixed structural ids below 32, number
//! tokens 32..64, attribute values 64..96, filler words 96..128, entities
//! from 128 up to the model's vocab size.

/// Structural token ids.
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const Q: i32 = 3;
pub const A: i32 = 4;
pub const YES: i32 = 5;
pub const NO: i32 = 6;
pub const SEP: i32 = 7;
pub const IS: i32 = 8;
pub const HAS: i32 = 9;
pub const OF: i32 = 10;
pub const FRIEND: i32 = 11;
pub const PLUS: i32 = 12;
pub const MINUS: i32 = 13;
pub const TIMES: i32 = 14;
pub const EQUALS: i32 = 15;
pub const TRUE_T: i32 = 16;
pub const FALSE_T: i32 = 17;
pub const REPEAT: i32 = 18;

/// Attribute-type tokens.
pub const COLOR: i32 = 22;
pub const SIZE: i32 = 23;
pub const SHAPE: i32 = 24;
pub const PLACE: i32 = 25;
pub const NUMBER: i32 = 26;

pub const NUM_BASE: i32 = 32;
pub const NUM_COUNT: usize = 32;
pub const ATTR_VAL_BASE: i32 = 64; // 4 families x 8 values
pub const ATTR_VALS_PER_FAMILY: usize = 8;
pub const FILLER_BASE: i32 = 96;
pub const FILLER_COUNT: usize = 32;
pub const ENTITY_BASE: i32 = 128;

/// Vocab view for a given model vocabulary size.
#[derive(Clone, Debug)]
pub struct Vocab {
    pub size: usize,
}

impl Vocab {
    pub fn new(size: usize) -> Vocab {
        assert!(size >= 256, "SynthLang needs vocab >= 256");
        Vocab { size }
    }

    pub fn n_entities(&self) -> usize {
        self.size - ENTITY_BASE as usize
    }

    pub fn entity(&self, i: usize) -> i32 {
        assert!(i < self.n_entities());
        ENTITY_BASE + i as i32
    }

    pub fn number(&self, v: usize) -> i32 {
        assert!(v < NUM_COUNT);
        NUM_BASE + v as i32
    }

    /// value token for attribute family f (0=color,1=size,2=shape,3=place)
    pub fn attr_val(&self, family: usize, v: usize) -> i32 {
        assert!(family < 4 && v < ATTR_VAL_PER_FAMILY_CHECK);
        ATTR_VAL_BASE + (family * ATTR_VALS_PER_FAMILY + v) as i32
    }

    pub fn filler(&self, i: usize) -> i32 {
        FILLER_BASE + (i % FILLER_COUNT) as i32
    }

    /// attribute-type token for family index
    pub fn attr_type(family: usize) -> i32 {
        [COLOR, SIZE, SHAPE, PLACE][family]
    }

    /// Human-readable form (debugging / examples output).
    pub fn describe(&self, tok: i32) -> String {
        match tok {
            PAD => "<pad>".into(),
            BOS => "<bos>".into(),
            EOS => "<eos>".into(),
            Q => "Q:".into(),
            A => "A:".into(),
            YES => "yes".into(),
            NO => "no".into(),
            SEP => ".".into(),
            IS => "is".into(),
            HAS => "has".into(),
            OF => "of".into(),
            FRIEND => "friend".into(),
            PLUS => "plus".into(),
            MINUS => "minus".into(),
            TIMES => "times".into(),
            EQUALS => "equals".into(),
            TRUE_T => "true".into(),
            FALSE_T => "false".into(),
            REPEAT => "repeat".into(),
            COLOR => "color".into(),
            SIZE => "size".into(),
            SHAPE => "shape".into(),
            PLACE => "place".into(),
            NUMBER => "number".into(),
            t if (NUM_BASE..NUM_BASE + NUM_COUNT as i32).contains(&t) => format!("{}", t - NUM_BASE),
            t if (ATTR_VAL_BASE..FILLER_BASE).contains(&t) => {
                let idx = (t - ATTR_VAL_BASE) as usize;
                let fam = ["color", "size", "shape", "place"][idx / ATTR_VALS_PER_FAMILY];
                format!("{fam}{}", idx % ATTR_VALS_PER_FAMILY)
            }
            t if (FILLER_BASE..ENTITY_BASE).contains(&t) => format!("w{}", t - FILLER_BASE),
            t if t >= ENTITY_BASE => format!("E{}", t - ENTITY_BASE),
            t => format!("?{t}?"),
        }
    }

    pub fn describe_seq(&self, toks: &[i32]) -> String {
        toks.iter().map(|&t| self.describe(t)).collect::<Vec<_>>().join(" ")
    }
}

const ATTR_VAL_PER_FAMILY_CHECK: usize = ATTR_VALS_PER_FAMILY;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_disjoint() {
        let v = Vocab::new(256);
        // structural < numbers < attr values < filler < entities
        assert!(NUMBER < NUM_BASE);
        assert_eq!(v.number(0), 32);
        assert_eq!(v.number(31), 63);
        assert_eq!(v.attr_val(0, 0), 64);
        assert_eq!(v.attr_val(3, 7), 95);
        assert_eq!(v.filler(0), 96);
        assert_eq!(v.entity(0), 128);
        assert_eq!(v.entity(127), 255);
        assert_eq!(v.n_entities(), 128);
    }

    #[test]
    #[should_panic]
    fn entity_out_of_range_panics() {
        Vocab::new(256).entity(128);
    }

    #[test]
    fn describe_roundtrip_spotcheck() {
        let v = Vocab::new(256);
        assert_eq!(v.describe(v.number(5)), "5");
        assert_eq!(v.describe(v.entity(3)), "E3");
        assert_eq!(v.describe(PLUS), "plus");
        assert_eq!(v.describe(v.attr_val(1, 2)), "size2");
    }

    #[test]
    fn bigger_vocab_more_entities() {
        assert_eq!(Vocab::new(512).n_entities(), 384);
    }
}
