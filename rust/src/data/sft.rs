//! SFT instruct datasets: Q/A-formatted documents over the world.
//!
//! Two styles reproduce the paper's Table 3 comparison:
//! * `Original` — the narrow "model's own SFT data": knowledge-only question
//!   families (attributes, friendships, booleans).
//! * `TuluSynth` — the broad open-source substitute: every question family
//!   including arithmetic, sequences and instruction-following, i.e. better
//!   aligned with the benchmarks (like Tulu3 is for the Open LLM suites).

use crate::data::vocab::{self, Vocab, ATTR_VALS_PER_FAMILY};
use crate::data::world::World;
use crate::util::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SftStyle {
    Original,
    TuluSynth,
}

pub struct SftGen<'w> {
    pub world: &'w World,
    pub style: SftStyle,
    rng: Rng,
}

impl<'w> SftGen<'w> {
    pub fn new(world: &'w World, style: SftStyle, seed: u64) -> Self {
        SftGen { world, style, rng: Rng::new(seed ^ 0x53465447) }
    }

    /// One Q/A pair: (question tokens, answer tokens).
    pub fn qa(&mut self) -> (Vec<i32>, Vec<i32>) {
        let w = self.world;
        let v = &w.vocab;
        let ne = w.n_entities();
        let rng = &mut self.rng;
        let n_kinds = match self.style {
            SftStyle::Original => 4,
            SftStyle::TuluSynth => 10,
        };
        match rng.below(n_kinds) {
            0 => {
                let f = rng.below(4);
                let e = rng.below(ne);
                (
                    vec![Vocab::attr_type(f), vocab::OF, v.entity(e)],
                    vec![v.attr_val(f, w.attr(e, f))],
                )
            }
            1 => {
                let e = rng.below(ne);
                (
                    vec![vocab::FRIEND, vocab::OF, v.entity(e), vocab::IS],
                    vec![v.entity(w.friend(e))],
                )
            }
            2 => {
                let f = rng.below(4);
                let e = rng.below(ne);
                let truth = rng.below(2) == 0;
                let val = if truth {
                    w.attr(e, f)
                } else {
                    (w.attr(e, f) + 1 + rng.below(ATTR_VALS_PER_FAMILY - 1)) % ATTR_VALS_PER_FAMILY
                };
                (
                    vec![v.entity(e), vocab::HAS, Vocab::attr_type(f), v.attr_val(f, val)],
                    vec![if truth { vocab::YES } else { vocab::NO }],
                )
            }
            3 => {
                let f = rng.below(4);
                let e = rng.below(ne);
                (
                    vec![Vocab::attr_type(f), vocab::OF, vocab::FRIEND, vocab::OF, v.entity(e)],
                    vec![v.attr_val(f, w.attr(w.friend(e), f))],
                )
            }
            // ---- TuluSynth-only families ----
            4 => {
                let a = rng.below(16);
                let b = rng.below(16);
                (
                    vec![v.number(a), vocab::PLUS, v.number(b), vocab::EQUALS],
                    vec![v.number(a + b)],
                )
            }
            5 => {
                let a = rng.below(10);
                let b = rng.below(10);
                let c = rng.below(10);
                (
                    vec![v.number(a), vocab::PLUS, v.number(b), vocab::PLUS, v.number(c), vocab::EQUALS],
                    vec![v.number(a + b + c)],
                )
            }
            6 => {
                let a = rng.below(6);
                let b = rng.below(6);
                (
                    vec![v.number(a), vocab::TIMES, v.number(b), vocab::EQUALS],
                    vec![v.number(a * b)],
                )
            }
            7 => {
                let k = rng.range(1, 4);
                let n0 = rng.below(32 - 5 * k);
                (
                    (0..4).map(|i| v.number(n0 + i * k)).collect(),
                    vec![v.number(n0 + 4 * k)],
                )
            }
            8 => {
                let k = rng.range(1, 5);
                (
                    vec![vocab::REPEAT, v.number(k), vocab::YES],
                    vec![vocab::YES; k],
                )
            }
            _ => {
                let e1 = rng.below(ne);
                let e2 = rng.below(ne);
                (
                    vec![
                        vocab::NUMBER, vocab::OF, v.entity(e1), vocab::PLUS,
                        vocab::NUMBER, vocab::OF, v.entity(e2), vocab::EQUALS,
                    ],
                    vec![v.number(w.number(e1) + w.number(e2))],
                )
            }
        }
    }

    /// One packed SFT document: BOS then `Q q A a SEP` groups; PAD tail.
    pub fn document(&mut self, seq_len: usize) -> Vec<i32> {
        let mut doc = vec![vocab::BOS];
        loop {
            let (q, a) = self.qa();
            // stop if the next pair would overflow
            if doc.len() + q.len() + a.len() + 3 > seq_len {
                break;
            }
            doc.push(vocab::Q);
            doc.extend_from_slice(&q);
            doc.push(vocab::A);
            doc.extend_from_slice(&a);
            doc.push(vocab::SEP);
        }
        doc.resize(seq_len, vocab::PAD);
        doc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::vocab::NUM_BASE;

    fn setup() -> World {
        World::generate(Vocab::new(256), 31)
    }

    #[test]
    fn documents_shape() {
        let w = setup();
        let mut g = SftGen::new(&w, SftStyle::TuluSynth, 0);
        for _ in 0..10 {
            let d = g.document(64);
            assert_eq!(d.len(), 64);
            assert_eq!(d[0], vocab::BOS);
            assert!(d.contains(&vocab::Q) && d.contains(&vocab::A));
        }
    }

    #[test]
    fn original_style_has_no_arithmetic() {
        let w = setup();
        let mut g = SftGen::new(&w, SftStyle::Original, 1);
        for _ in 0..500 {
            let (q, _) = g.qa();
            assert!(!q.contains(&vocab::PLUS) && !q.contains(&vocab::TIMES));
        }
    }

    #[test]
    fn tulu_style_covers_arithmetic() {
        let w = setup();
        let mut g = SftGen::new(&w, SftStyle::TuluSynth, 2);
        let mut saw_plus = false;
        let mut saw_repeat = false;
        for _ in 0..500 {
            let (q, _) = g.qa();
            saw_plus |= q.contains(&vocab::PLUS);
            saw_repeat |= q.contains(&vocab::REPEAT);
        }
        assert!(saw_plus && saw_repeat);
    }

    #[test]
    fn answers_are_correct() {
        let w = setup();
        let mut g = SftGen::new(&w, SftStyle::TuluSynth, 3);
        for _ in 0..1000 {
            let (q, a) = g.qa();
            if q.len() == 4 && q[1] == vocab::PLUS && q[3] == vocab::EQUALS {
                assert_eq!(a[0] - NUM_BASE, (q[0] - NUM_BASE) + (q[2] - NUM_BASE));
            }
        }
    }

    #[test]
    fn deterministic() {
        let w = setup();
        let mut g1 = SftGen::new(&w, SftStyle::TuluSynth, 9);
        let mut g2 = SftGen::new(&w, SftStyle::TuluSynth, 9);
        for _ in 0..20 {
            assert_eq!(g1.document(48), g2.document(48));
        }
    }
}
