//! Benchmark task generators: synthetic analogs of the paper's CSR,
//! OLLMv1 and OLLMv2 suites (Tables 1, 5, 6, 7).
//!
//! Mechanics mirror lm-evaluation-harness: multiple-choice tasks are scored
//! by length-normalized continuation log-likelihood; generation tasks by
//! greedy decoding + exact match. Suites are ordered by compositional
//! depth, so quantization damage degrades OLLMv2-analogs first — the same
//! qualitative behaviour the paper reports.

use crate::data::vocab::{self, Vocab, ATTR_VALS_PER_FAMILY};
use crate::data::world::World;
use crate::util::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Suite {
    Csr,
    OllmV1,
    OllmV2,
}

impl Suite {
    pub fn label(&self) -> &'static str {
        match self {
            Suite::Csr => "CSR",
            Suite::OllmV1 => "OLLMv1",
            Suite::OllmV2 => "OLLMv2",
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TaskKind {
    MultipleChoice,
    Generate,
}

/// One benchmark task.
#[derive(Clone, Debug)]
pub struct TaskDef {
    pub name: &'static str,
    pub suite: Suite,
    pub fewshot: usize,
    pub kind: TaskKind,
    pub n_items: usize,
    qtype: QType,
}

/// One evaluation item.
#[derive(Clone, Debug)]
pub struct EvalItem {
    /// full prompt (BOS + few-shot examples + question), unpadded
    pub prompt: Vec<i32>,
    /// candidate continuations (MultipleChoice)
    pub choices: Vec<Vec<i32>>,
    pub correct: usize,
    /// gold continuation (Generate)
    pub answer: Vec<i32>,
}

/// Question archetypes, ordered roughly by difficulty.
#[derive(Clone, Copy, Debug)]
enum QType {
    /// attribute of an entity (family fixed or 4 = random)
    Attr(usize),
    /// attribute of the friend of an entity
    TwoHop,
    /// attribute of the friend of the friend (3 retrieval hops)
    ThreeHop,
    /// does entity have attribute value? yes/no
    BoolAttr,
    /// statement + true/false judgement
    Truth,
    /// who is the friend of E?
    Friend,
    /// a + b = ?
    Add,
    /// a + b + c = ? (two-step arithmetic, GSM8K-analog)
    Add3,
    /// a * b = ?
    Mul,
    /// continue the arithmetic progression
    SeqCont,
    /// number(e1) + number(e2) = ? (two retrievals + arithmetic)
    NumSum,
    /// instruction following: repeat "yes" k times
    RepeatInstr,
    /// in-context friendship graph overriding the world (MUSR-analog)
    ContextHop,
    /// mixture of Attr/Add/Mul (MMLU-analog)
    Mixed,
}

/// A question: tokens, gold answer tokens, distractor answers.
struct Qa {
    q: Vec<i32>,
    ans: Vec<i32>,
    distractors: Vec<Vec<i32>>,
}

fn gen_qa(w: &World, rng: &mut Rng, qt: QType) -> Qa {
    let v = &w.vocab;
    let ne = w.n_entities();
    match qt {
        QType::Attr(fam) => {
            let f = if fam >= 4 { rng.below(4) } else { fam };
            let e = rng.below(ne);
            let correct = w.attr(e, f);
            let distractors = distinct_vals(rng, correct, 3)
                .into_iter()
                .map(|x| vec![v.attr_val(f, x)])
                .collect();
            Qa {
                q: vec![Vocab::attr_type(f), vocab::OF, v.entity(e)],
                ans: vec![v.attr_val(f, correct)],
                distractors,
            }
        }
        QType::TwoHop => {
            let f = rng.below(4);
            let e = rng.below(ne);
            let correct = w.attr(w.friend(e), f);
            Qa {
                q: vec![Vocab::attr_type(f), vocab::OF, vocab::FRIEND, vocab::OF, v.entity(e)],
                ans: vec![v.attr_val(f, correct)],
                distractors: distinct_vals(rng, correct, 3)
                    .into_iter()
                    .map(|x| vec![v.attr_val(f, x)])
                    .collect(),
            }
        }
        QType::ThreeHop => {
            let f = rng.below(4);
            let e = rng.below(ne);
            let correct = w.attr(w.friend_hop(e, 2), f);
            Qa {
                q: vec![
                    Vocab::attr_type(f), vocab::OF, vocab::FRIEND, vocab::OF,
                    vocab::FRIEND, vocab::OF, v.entity(e),
                ],
                ans: vec![v.attr_val(f, correct)],
                distractors: distinct_vals(rng, correct, 3)
                    .into_iter()
                    .map(|x| vec![v.attr_val(f, x)])
                    .collect(),
            }
        }
        QType::BoolAttr => {
            let f = rng.below(4);
            let e = rng.below(ne);
            let truth = rng.below(2) == 0;
            let val = if truth {
                w.attr(e, f)
            } else {
                (w.attr(e, f) + 1 + rng.below(ATTR_VALS_PER_FAMILY - 1)) % ATTR_VALS_PER_FAMILY
            };
            Qa {
                q: vec![v.entity(e), vocab::HAS, Vocab::attr_type(f), v.attr_val(f, val)],
                ans: vec![if truth { vocab::YES } else { vocab::NO }],
                distractors: vec![vec![if truth { vocab::NO } else { vocab::YES }]],
            }
        }
        QType::Truth => {
            let f = rng.below(4);
            let e = rng.below(ne);
            let truth = rng.below(2) == 0;
            let val = if truth {
                w.attr(e, f)
            } else {
                (w.attr(e, f) + 1 + rng.below(ATTR_VALS_PER_FAMILY - 1)) % ATTR_VALS_PER_FAMILY
            };
            Qa {
                q: vec![v.entity(e), vocab::HAS, Vocab::attr_type(f), v.attr_val(f, val), vocab::IS],
                ans: vec![if truth { vocab::TRUE_T } else { vocab::FALSE_T }],
                distractors: vec![vec![if truth { vocab::FALSE_T } else { vocab::TRUE_T }]],
            }
        }
        QType::Friend => {
            let e = rng.below(ne);
            let correct = w.friend(e);
            let mut ds = vec![];
            while ds.len() < 3 {
                let d = rng.below(ne);
                if d != correct {
                    ds.push(vec![v.entity(d)]);
                }
            }
            Qa {
                q: vec![vocab::FRIEND, vocab::OF, v.entity(e), vocab::IS],
                ans: vec![v.entity(correct)],
                distractors: ds,
            }
        }
        QType::Add => {
            let a = rng.below(16);
            let b = rng.below(16);
            let c = a + b;
            let wrong = if c == 0 { 1 } else { c - 1 };
            Qa {
                q: vec![v.number(a), vocab::PLUS, v.number(b), vocab::EQUALS],
                ans: vec![v.number(c)],
                distractors: vec![vec![v.number(wrong)]],
            }
        }
        QType::Add3 => {
            let a = rng.below(10);
            let b = rng.below(10);
            let c = rng.below(10);
            let s = a + b + c;
            Qa {
                q: vec![
                    v.number(a), vocab::PLUS, v.number(b), vocab::PLUS, v.number(c), vocab::EQUALS,
                ],
                ans: vec![v.number(s)],
                distractors: vec![vec![v.number((s + 1) % 32)], vec![v.number((s + 2) % 32)],
                                  vec![v.number((s + 30) % 32)]],
            }
        }
        QType::Mul => {
            let a = rng.below(6);
            let b = rng.below(6);
            let p = a * b;
            Qa {
                q: vec![v.number(a), vocab::TIMES, v.number(b), vocab::EQUALS],
                ans: vec![v.number(p)],
                distractors: vec![vec![v.number((p + 1) % 32)], vec![v.number((p + 2) % 32)],
                                  vec![v.number((p + 31) % 32)]],
            }
        }
        QType::SeqCont => {
            let k = rng.range(1, 4);
            let n0 = rng.below(32 - 5 * k);
            let q: Vec<i32> = (0..4).map(|i| v.number(n0 + i * k)).collect();
            let correct = n0 + 4 * k;
            let mut ds = vec![];
            for delta in [1usize, 2, 3] {
                let wrong = (correct + delta) % 32;
                ds.push(vec![v.number(wrong)]);
            }
            Qa { q, ans: vec![v.number(correct)], distractors: ds }
        }
        QType::NumSum => {
            let e1 = rng.below(ne);
            let e2 = rng.below(ne);
            let correct = w.number(e1) + w.number(e2);
            let mut ds = vec![];
            for delta in [1usize, 2, 3] {
                ds.push(vec![v.number((correct + delta) % 32)]);
            }
            Qa {
                q: vec![
                    vocab::NUMBER, vocab::OF, v.entity(e1), vocab::PLUS,
                    vocab::NUMBER, vocab::OF, v.entity(e2), vocab::EQUALS,
                ],
                ans: vec![v.number(correct)],
                distractors: ds,
            }
        }
        QType::RepeatInstr => {
            let k = rng.range(1, 5);
            Qa {
                q: vec![vocab::REPEAT, v.number(k), vocab::YES],
                ans: vec![vocab::YES; k],
                distractors: vec![],
            }
        }
        QType::ContextHop => {
            // context states a (possibly world-contradicting) friendship and
            // an attribute of that friend; the answer must come from context.
            let f = rng.below(4);
            let e = rng.below(ne);
            let ctx_friend = rng.below(ne);
            let ctx_val = rng.below(ATTR_VALS_PER_FAMILY);
            let mut q = vec![
                vocab::FRIEND, vocab::OF, v.entity(e), vocab::IS, v.entity(ctx_friend), vocab::SEP,
                v.entity(ctx_friend), vocab::HAS, Vocab::attr_type(f), v.attr_val(f, ctx_val), vocab::SEP,
            ];
            q.extend_from_slice(&[Vocab::attr_type(f), vocab::OF, vocab::FRIEND, vocab::OF, v.entity(e)]);
            Qa {
                q,
                ans: vec![v.attr_val(f, ctx_val)],
                distractors: distinct_vals(rng, ctx_val, 3)
                    .into_iter()
                    .map(|x| vec![v.attr_val(f, x)])
                    .collect(),
            }
        }
        QType::Mixed => {
            let qt = *rng.choice(&[QType::Attr(4), QType::Add, QType::Mul, QType::SeqCont]);
            gen_qa(w, rng, qt)
        }
    }
}

fn distinct_vals(rng: &mut Rng, correct: usize, n: usize) -> Vec<usize> {
    let mut out = vec![];
    while out.len() < n {
        let d = rng.below(ATTR_VALS_PER_FAMILY);
        if d != correct && !out.contains(&d) {
            out.push(d);
        }
    }
    out
}

/// Assemble the prompt: `chat` adds the instruct Q/A template (the paper's
/// `--apply_chat_template` analog); base models get declarative shots.
fn format_prompt(chat: bool, shots: &[(Vec<i32>, Vec<i32>)], q: &[i32]) -> Vec<i32> {
    let mut p = vec![vocab::BOS];
    for (sq, sa) in shots {
        if chat {
            p.push(vocab::Q);
            p.extend_from_slice(sq);
            p.push(vocab::A);
            p.extend_from_slice(sa);
            p.push(vocab::SEP);
        } else {
            p.extend_from_slice(sq);
            p.extend_from_slice(sa);
            p.push(vocab::SEP);
        }
    }
    if chat {
        p.push(vocab::Q);
        p.extend_from_slice(q);
        p.push(vocab::A);
    } else {
        p.extend_from_slice(q);
    }
    p
}

impl TaskDef {
    /// Generate the task's items deterministically.
    pub fn items(&self, world: &World, chat: bool, seed: u64) -> Vec<EvalItem> {
        let mut rng = Rng::new(seed ^ fxhash(self.name));
        (0..self.n_items)
            .map(|_| {
                let shots: Vec<(Vec<i32>, Vec<i32>)> = (0..self.fewshot)
                    .map(|_| {
                        let qa = gen_qa(world, &mut rng, self.qtype);
                        (qa.q, qa.ans)
                    })
                    .collect();
                let qa = gen_qa(world, &mut rng, self.qtype);
                let prompt = format_prompt(chat, &shots, &qa.q);
                match self.kind {
                    TaskKind::MultipleChoice => {
                        let mut choices = vec![qa.ans.clone()];
                        choices.extend(qa.distractors.iter().cloned());
                        // shuffle so the gold answer isn't always index 0
                        let mut idx: Vec<usize> = (0..choices.len()).collect();
                        rng.shuffle(&mut idx);
                        let correct = idx.iter().position(|&i| i == 0).unwrap();
                        let choices = idx.into_iter().map(|i| choices[i].clone()).collect();
                        EvalItem { prompt, choices, correct, answer: qa.ans }
                    }
                    TaskKind::Generate => EvalItem {
                        prompt,
                        choices: vec![],
                        correct: 0,
                        answer: qa.ans,
                    },
                }
            })
            .collect()
    }
}

fn fxhash(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

/// The full task registry — 8 CSR + 6 OLLMv1 + 6 OLLMv2 analogs, mirroring
/// the paper's Tables 5/6/7 structure.
pub fn registry(n_items: usize) -> Vec<TaskDef> {
    use Suite::*;
    use TaskKind::*;
    let t = |name, suite, fewshot, kind, qtype| TaskDef { name, suite, fewshot, kind, n_items, qtype };
    vec![
        // ---- CSR analogs (zero-shot, Table 5) ----
        t("arc_e*", Csr, 0, MultipleChoice, QType::Attr(0)),
        t("arc_c*", Csr, 0, MultipleChoice, QType::TwoHop),
        t("boolq*", Csr, 0, MultipleChoice, QType::BoolAttr),
        t("piqa*", Csr, 0, MultipleChoice, QType::Add),
        t("siqa*", Csr, 0, MultipleChoice, QType::Friend),
        t("hellaswag*", Csr, 0, MultipleChoice, QType::SeqCont),
        t("obqa*", Csr, 0, MultipleChoice, QType::Attr(2)),
        t("winogrande*", Csr, 0, MultipleChoice, QType::Attr(3)),
        // ---- OLLMv1 analogs (few-shot, Table 6) ----
        t("v1_arc_c*", OllmV1, 2, MultipleChoice, QType::TwoHop),
        t("v1_hellaswag*", OllmV1, 2, MultipleChoice, QType::SeqCont),
        t("v1_mmlu*", OllmV1, 2, MultipleChoice, QType::Mixed),
        t("v1_truthfulqa*", OllmV1, 2, MultipleChoice, QType::Truth),
        t("v1_winogrande*", OllmV1, 2, MultipleChoice, QType::Attr(3)),
        t("v1_gsm8k*", OllmV1, 2, Generate, QType::Add3),
        // ---- OLLMv2 analogs (hardest, Table 7) ----
        t("v2_bbh*", OllmV2, 2, MultipleChoice, QType::ThreeHop),
        t("v2_gpqa*", OllmV2, 2, MultipleChoice, QType::NumSum),
        t("v2_ifeval*", OllmV2, 1, Generate, QType::RepeatInstr),
        t("v2_math*", OllmV2, 2, Generate, QType::Mul),
        t("v2_mmlupro*", OllmV2, 2, MultipleChoice, QType::Mixed),
        t("v2_musr*", OllmV2, 1, MultipleChoice, QType::ContextHop),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> World {
        World::generate(Vocab::new(256), 21)
    }

    #[test]
    fn registry_has_paper_structure() {
        let r = registry(16);
        assert_eq!(r.iter().filter(|t| t.suite == Suite::Csr).count(), 8);
        assert_eq!(r.iter().filter(|t| t.suite == Suite::OllmV1).count(), 6);
        assert_eq!(r.iter().filter(|t| t.suite == Suite::OllmV2).count(), 6);
        let names: Vec<_> = r.iter().map(|t| t.name).collect();
        let mut dedup = names.clone();
        dedup.dedup();
        assert_eq!(names.len(), dedup.len());
    }

    #[test]
    fn items_deterministic() {
        let w = setup();
        let task = &registry(8)[1];
        let a = task.items(&w, true, 5);
        let b = task.items(&w, true, 5);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.correct, y.correct);
        }
    }

    #[test]
    fn mc_items_have_valid_correct_index() {
        let w = setup();
        for task in registry(12) {
            if task.kind != TaskKind::MultipleChoice {
                continue;
            }
            for item in task.items(&w, false, 1) {
                assert!(item.correct < item.choices.len(), "{}", task.name);
                assert_eq!(item.choices[item.correct], item.answer, "{}", task.name);
                assert!(item.choices.len() >= 2);
            }
        }
    }

    #[test]
    fn gold_position_shuffled() {
        let w = setup();
        let task = &registry(64)[0];
        let items = task.items(&w, false, 3);
        let positions: std::collections::HashSet<usize> =
            items.iter().map(|i| i.correct).collect();
        assert!(positions.len() > 1, "gold answer must not always sit at one index");
    }

    #[test]
    fn chat_template_adds_markers() {
        let w = setup();
        let task = &registry(4)[0];
        let chat = task.items(&w, true, 2);
        let base = task.items(&w, false, 2);
        assert!(chat[0].prompt.contains(&vocab::Q));
        assert!(chat[0].prompt.ends_with(&[vocab::A]));
        assert!(!base[0].prompt.contains(&vocab::Q));
    }

    #[test]
    fn fewshot_prompts_longer() {
        let w = setup();
        let r = registry(4);
        let zero = r[1].items(&w, true, 1); // arc_c*, 0-shot
        let few = r[8].items(&w, true, 1); // v1_arc_c*, 2-shot
        assert!(few[0].prompt.len() > zero[0].prompt.len());
    }

    #[test]
    fn generation_answers_correct_arithmetic() {
        let w = setup();
        let task = registry(32).into_iter().find(|t| t.name == "v1_gsm8k*").unwrap();
        for item in task.items(&w, true, 7) {
            // question tail: a PLUS b PLUS c EQUALS ; answer = a+b+c
            let p = &item.prompt;
            let eq_pos = p.iter().rposition(|&t| t == vocab::EQUALS).unwrap();
            let a = p[eq_pos - 5] - vocab::NUM_BASE;
            let b = p[eq_pos - 3] - vocab::NUM_BASE;
            let c = p[eq_pos - 1] - vocab::NUM_BASE;
            assert_eq!(item.answer, vec![vocab::NUM_BASE + a + b + c]);
        }
    }

    #[test]
    fn context_hop_answer_comes_from_context() {
        let w = setup();
        let task = registry(16).into_iter().find(|t| t.name == "v2_musr*").unwrap();
        for item in task.items(&w, false, 9) {
            // the stated attribute value inside the context equals the gold
            let p = &item.prompt;
            let ans = item.answer[0];
            assert!(p.contains(&ans), "context must state the answer");
        }
    }

    #[test]
    fn repeat_instruction_lengths() {
        let w = setup();
        let task = registry(32).into_iter().find(|t| t.name == "v2_ifeval*").unwrap();
        for item in task.items(&w, true, 11) {
            assert!(!item.answer.is_empty() && item.answer.len() <= 4);
            assert!(item.answer.iter().all(|&t| t == vocab::YES));
        }
    }
}
