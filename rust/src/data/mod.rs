//! SynthLang: the synthetic language substrate standing in for the paper's
//! datasets (DCLM pre-train corpus, SFT instruct mixtures) and for the
//! worlds the benchmark suites query. See DESIGN.md §2 for the substitution
//! rationale.
//!
//! Design: a deterministic entity-attribute *world* (who has which color /
//! size / shape / place / number, and who is whose friend) plus closed-form
//! arithmetic and sequence patterns. The pre-training corpus states world
//! facts and patterns as declarative token sentences; SFT datasets wrap the
//! same knowledge in Q/A chat format; the eval suites (CSR / OLLMv1 /
//! OLLMv2 analogs) probe it at increasing compositional depth. Accuracy is
//! therefore meaningful: a model can only score well by actually modeling
//! the data, and quantization damage shows up exactly like it does on real
//! benchmarks (harder, more compositional suites degrade first).

pub mod batcher;
pub mod corpus;
pub mod sft;
pub mod tasks;
pub mod vocab;
pub mod world;

pub use batcher::{Batcher, DataMix};
pub use corpus::CorpusGen;
pub use sft::{SftGen, SftStyle};
pub use tasks::{EvalItem, Suite, TaskDef, TaskKind};
pub use vocab::Vocab;
pub use world::World;
