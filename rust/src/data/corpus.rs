//! DCLM-analog pre-training corpus: declarative sentences stating world
//! facts, arithmetic identities, and sequence patterns, packed into
//! fixed-length documents.

use crate::data::vocab::{self, Vocab};
use crate::data::world::World;
use crate::util::Rng;

/// Streaming corpus generator.
pub struct CorpusGen<'w> {
    pub world: &'w World,
    rng: Rng,
    /// fraction of pure-filler sentences (lexical noise)
    pub noise: f32,
}

impl<'w> CorpusGen<'w> {
    pub fn new(world: &'w World, seed: u64) -> Self {
        CorpusGen { world, rng: Rng::new(seed ^ 0x434f5250), noise: 0.1 }
    }

    /// One declarative sentence (without separator).
    pub fn sentence(&mut self) -> Vec<i32> {
        let w = self.world;
        let v = &w.vocab;
        if self.rng.uniform() < self.noise {
            let n = self.rng.range(3, 7);
            return (0..n).map(|_| v.filler(self.rng.below(32))).collect();
        }
        match self.rng.below(6) {
            // attribute fact: E has <type> <value>
            0 => {
                let e = self.rng.below(w.n_entities());
                let f = self.rng.below(4);
                vec![v.entity(e), vocab::HAS, Vocab::attr_type(f), v.attr_val(f, w.attr(e, f))]
            }
            // friendship: friend of E is E2
            1 => {
                let e = self.rng.below(w.n_entities());
                vec![vocab::FRIEND, vocab::OF, v.entity(e), vocab::IS, v.entity(w.friend(e))]
            }
            // number fact: E has number n
            2 => {
                let e = self.rng.below(w.n_entities());
                vec![v.entity(e), vocab::HAS, vocab::NUMBER, v.number(w.number(e))]
            }
            // addition: a plus b equals c  (c < 32 by construction)
            3 => {
                let a = self.rng.below(16);
                let b = self.rng.below(16);
                vec![v.number(a), vocab::PLUS, v.number(b), vocab::EQUALS, v.number(a + b)]
            }
            // small multiplication: a times b equals c
            4 => {
                let a = self.rng.below(6);
                let b = self.rng.below(6);
                vec![v.number(a), vocab::TIMES, v.number(b), vocab::EQUALS, v.number(a * b)]
            }
            // arithmetic progression: n, n+k, n+2k, n+3k, n+4k
            _ => {
                let k = self.rng.range(1, 4);
                let n0 = self.rng.below(32 - 4 * k);
                (0..5).map(|i| v.number(n0 + i * k)).collect()
            }
        }
    }

    /// A packed document of exactly `seq_len` tokens: BOS then sentences
    /// joined by SEP, truncated at the boundary (no padding — every token
    /// carries signal, like packed pre-training data).
    pub fn document(&mut self, seq_len: usize) -> Vec<i32> {
        let mut doc = vec![vocab::BOS];
        while doc.len() < seq_len {
            let s = self.sentence();
            doc.extend_from_slice(&s);
            doc.push(vocab::SEP);
        }
        doc.truncate(seq_len);
        doc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::vocab::NUM_BASE;

    fn setup() -> World {
        World::generate(Vocab::new(256), 11)
    }

    #[test]
    fn sentences_non_empty_in_vocab() {
        let w = setup();
        let mut g = CorpusGen::new(&w, 0);
        for _ in 0..500 {
            let s = g.sentence();
            assert!(!s.is_empty());
            assert!(s.iter().all(|&t| (0..256).contains(&t)), "{s:?}");
        }
    }

    #[test]
    fn documents_exact_length_start_bos() {
        let w = setup();
        let mut g = CorpusGen::new(&w, 1);
        for _ in 0..20 {
            let d = g.document(64);
            assert_eq!(d.len(), 64);
            assert_eq!(d[0], vocab::BOS);
            assert!(!d.contains(&vocab::PAD));
        }
    }

    #[test]
    fn arithmetic_sentences_are_correct() {
        let w = setup();
        let mut g = CorpusGen::new(&w, 2);
        let mut checked = 0;
        for _ in 0..2000 {
            let s = g.sentence();
            if s.len() == 5 && s[1] == vocab::PLUS && s[3] == vocab::EQUALS {
                let (a, b, c) = (s[0] - NUM_BASE, s[2] - NUM_BASE, s[4] - NUM_BASE);
                assert_eq!(a + b, c);
                checked += 1;
            }
        }
        assert!(checked > 50);
    }

    #[test]
    fn facts_match_world() {
        let w = setup();
        let mut g = CorpusGen::new(&w, 3);
        let v = &w.vocab;
        let mut checked = 0;
        for _ in 0..2000 {
            let s = g.sentence();
            if s.len() == 5 && s[0] == vocab::FRIEND {
                let e = (s[2] - vocab::ENTITY_BASE) as usize;
                assert_eq!(s[4], v.entity(w.friend(e)));
                checked += 1;
            }
        }
        assert!(checked > 100);
    }

    #[test]
    fn deterministic_stream() {
        let w = setup();
        let d1: Vec<_> = {
            let mut g = CorpusGen::new(&w, 9);
            (0..5).map(|_| g.document(32)).collect()
        };
        let d2: Vec<_> = {
            let mut g = CorpusGen::new(&w, 9);
            (0..5).map(|_| g.document(32)).collect()
        };
        assert_eq!(d1, d2);
    }
}
