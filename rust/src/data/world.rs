//! The entity-attribute world: the ground truth every dataset and benchmark
//! is generated from. Deterministic given a seed.

use crate::data::vocab::{Vocab, ATTR_VALS_PER_FAMILY, NUM_COUNT};
use crate::util::Rng;

/// One entity's attributes (indices into the per-family value sets).
#[derive(Clone, Debug)]
pub struct Entity {
    /// color, size, shape, place — value index per family
    pub attrs: [usize; 4],
    /// index of the friend entity
    pub friend: usize,
    /// a number in 0..NUM_COUNT/2 (kept small so sums stay in range)
    pub number: usize,
}

/// The full world.
#[derive(Clone, Debug)]
pub struct World {
    pub vocab: Vocab,
    pub entities: Vec<Entity>,
}

impl World {
    pub fn generate(vocab: Vocab, seed: u64) -> World {
        let mut rng = Rng::new(seed ^ 0x5157_4f52_4c44); // "QWORLD"
        let n = vocab.n_entities();
        let entities = (0..n)
            .map(|i| {
                let mut friend = rng.below(n);
                if friend == i {
                    friend = (friend + 1) % n;
                }
                Entity {
                    attrs: [
                        rng.below(ATTR_VALS_PER_FAMILY),
                        rng.below(ATTR_VALS_PER_FAMILY),
                        rng.below(ATTR_VALS_PER_FAMILY),
                        rng.below(ATTR_VALS_PER_FAMILY),
                    ],
                    friend,
                    number: rng.below(NUM_COUNT / 2),
                }
            })
            .collect();
        World { vocab, entities }
    }

    pub fn n_entities(&self) -> usize {
        self.entities.len()
    }

    /// Attribute value index of entity `e` in family `f`.
    pub fn attr(&self, e: usize, f: usize) -> usize {
        self.entities[e].attrs[f]
    }

    pub fn friend(&self, e: usize) -> usize {
        self.entities[e].friend
    }

    pub fn number(&self, e: usize) -> usize {
        self.entities[e].number
    }

    /// k-hop friend chain.
    pub fn friend_hop(&self, e: usize, hops: usize) -> usize {
        let mut cur = e;
        for _ in 0..hops {
            cur = self.friend(cur);
        }
        cur
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let a = World::generate(Vocab::new(256), 7);
        let b = World::generate(Vocab::new(256), 7);
        for (x, y) in a.entities.iter().zip(&b.entities) {
            assert_eq!(x.attrs, y.attrs);
            assert_eq!(x.friend, y.friend);
        }
    }

    #[test]
    fn seeds_differ() {
        let a = World::generate(Vocab::new(256), 1);
        let b = World::generate(Vocab::new(256), 2);
        assert!(a.entities.iter().zip(&b.entities).any(|(x, y)| x.attrs != y.attrs));
    }

    #[test]
    fn no_self_friends() {
        let w = World::generate(Vocab::new(256), 3);
        for (i, e) in w.entities.iter().enumerate() {
            assert_ne!(e.friend, i);
        }
    }

    #[test]
    fn numbers_small_enough_for_sums() {
        let w = World::generate(Vocab::new(256), 4);
        for e in &w.entities {
            assert!(e.number < NUM_COUNT / 2);
        }
    }

    #[test]
    fn friend_hops_compose() {
        let w = World::generate(Vocab::new(256), 5);
        let e = 3;
        assert_eq!(w.friend_hop(e, 2), w.friend(w.friend(e)));
    }
}
