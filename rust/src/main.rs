//! `silq` — the coordinator CLI.
//!
//! Subcommands (clap is unavailable offline; parsing is hand-rolled):
//!   silq info                          # artifacts + configs
//!   silq pretrain|sft|qat [--set k=v]  # pipeline stages
//!   silq eval --ckpt path --prec p     # evaluate a checkpoint
//!   silq exp <table1|...|fig3>         # regenerate a paper table/figure
//!   silq e2e                           # full end-to-end demo (small model)
//!   silq serve                         # continuous-batching load run

use anyhow::{bail, Context, Result};
use std::sync::Arc;

use silq::config::TrainCfg;
use silq::coordinator::{run_experiment, BackendKind, Pipeline, PipelineCfg};
use silq::data::{vocab, DataMix, SftStyle, Vocab, World};
use silq::evalharness::Evaluator;
use silq::forward::HostForward;
use silq::hostmodel::{self, CacheStore, HostCfg};
use silq::metrics::RunLog;
use silq::model::ParamStore;
use silq::runtime::Engine;
use silq::serve::{
    AdmissionQueue, ArtifactBackend, DecodeBackend, GenRequest, HostBackend, Scheduler, ServeStats,
};
use silq::train::init_model;
use silq::util::Timer;

struct Args {
    cmd: String,
    flags: Vec<(String, String)>,
}

fn parse_args() -> Args {
    parse_argv(std::env::args().skip(1).collect())
}

fn parse_argv(argv: Vec<String>) -> Args {
    let cmd = argv.first().cloned().unwrap_or_else(|| "help".into());
    let mut flags = vec![];
    let mut i = 1;
    while i < argv.len() {
        if let Some(name) = argv[i].strip_prefix("--") {
            if let Some((k, v)) = name.split_once('=') {
                // `--flag=value`: the unambiguous form — use it for values
                // that start with `--` or look like another flag
                if k == "set" {
                    if let Some((sk, sv)) = v.split_once('=') {
                        flags.push((sk.into(), sv.into()));
                    }
                } else {
                    flags.push((k.into(), v.into()));
                }
                i += 1;
            } else if name == "set" && i + 1 < argv.len() {
                if let Some((k, v)) = argv[i + 1].split_once('=') {
                    flags.push((k.into(), v.into()));
                }
                i += 2;
            } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.push((name.into(), argv[i + 1].clone()));
                i += 2;
            } else {
                flags.push((name.into(), "1".into()));
                i += 1;
            }
        } else {
            flags.push(("_pos".into(), argv[i].clone()));
            i += 1;
        }
    }
    Args { cmd, flags }
}

impl Args {
    fn get(&self, key: &str) -> Option<&str> {
        self.flags.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    fn pos(&self) -> Option<&str> {
        self.get("_pos")
    }

    fn pipeline_cfg(&self) -> Result<PipelineCfg> {
        let mut c = PipelineCfg::default();
        if let Some(m) = self.get("model") {
            c.model = m.into();
        }
        for (k, v) in &self.flags {
            match k.as_str() {
                "pretrain_steps" => c.pretrain_steps = v.parse().unwrap_or(c.pretrain_steps),
                "sft_steps" => c.sft_steps = v.parse().unwrap_or(c.sft_steps),
                "qat_steps" => c.qat_steps = v.parse().unwrap_or(c.qat_steps),
                "eval_items" => c.eval_items = v.parse().unwrap_or(c.eval_items),
                "seed" => c.seed = v.parse().unwrap_or(c.seed),
                "world_seed" => c.world_seed = v.parse().unwrap_or(c.world_seed),
                // a mistyped backend must fail loudly, not silently run a
                // different compute path than the user asked for
                "backend" => c.backend = BackendKind::parse(v)?,
                _ => {}
            }
        }
        Ok(c)
    }

    fn train_cfg(&self) -> TrainCfg {
        let mut t = TrainCfg::default();
        for (k, v) in &self.flags {
            t.set(k, v);
        }
        t
    }
}

fn main() -> Result<()> {
    let args = parse_args();
    let art_dir = args.get("artifacts").unwrap_or("artifacts").to_string();

    match args.cmd.as_str() {
        "help" | "--help" | "-h" => {
            println!(
                "silq — SiLQ reproduction coordinator\n\
                 usage: silq <cmd> [flags]\n\
                 cmds:  info | pretrain | sft | qat | eval | exp <id> | e2e | serve\n\
                 flags: --model tiny|small  --prec a8d-c8-w4|...  --ckpt path\n\
                        --set key=value (training hyper-params)\n\
                        --qat_steps N --pretrain_steps N --sft_steps N --eval_items N\n\
                        --backend artifact|host (eval/qat/serve; host needs no\n\
                        compiled artifacts and decodes incrementally over the\n\
                        quantized KV pool)\n\
                 serve: --requests N --batch B --max_new M --queue_cap C --producers P\n\
                        --cache int8|f32 (host backend)\n\
                 note:  `--flag value` and `--flag=value` are equivalent; use\n\
                        `--flag=value` when the value itself starts with `--`"
            );
            Ok(())
        }
        "info" => {
            let eng = Engine::new(&art_dir)?;
            println!("platform: {}", eng.platform());
            println!("models:");
            for m in eng.manifest.models.values() {
                println!(
                    "  {}: vocab={} d={} L={} H={} ff={} S={} (pallas={})",
                    m.name, m.vocab, m.d_model, m.n_layers, m.n_heads, m.d_ff, m.seq_len, m.use_pallas
                );
            }
            println!("precisions: {:?}", eng.manifest.precs.keys().collect::<Vec<_>>());
            println!("artifacts:  {}", eng.manifest.artifacts.len());
            Ok(())
        }
        "pretrain" => {
            let eng = Engine::new(&art_dir)?;
            let p = Pipeline::new(&eng, args.pipeline_cfg()?)?;
            let mut log = RunLog::new("runs/pretrain");
            let params = p.base_model(&mut log)?;
            println!("base model ready ({} params)", params.numel());
            Ok(())
        }
        "sft" => {
            let eng = Engine::new(&art_dir)?;
            let p = Pipeline::new(&eng, args.pipeline_cfg()?)?;
            let mut log = RunLog::new("runs/sft");
            let style = match args.get("style").unwrap_or("tulu") {
                "original" => SftStyle::Original,
                _ => SftStyle::TuluSynth,
            };
            let params = p.instruct_model(style, "instruct", &mut log)?;
            println!("instruct model ready ({} params)", params.numel());
            Ok(())
        }
        "qat" => {
            let eng = Engine::new(&art_dir)?;
            let p = Pipeline::new(&eng, args.pipeline_cfg()?)?;
            let mut log = RunLog::new("runs/qat");
            let prec = args.get("prec").unwrap_or("a8d-c8-w4").to_string();
            let fp16 = p.instruct_model(SftStyle::TuluSynth, "instruct", &mut log)?;
            let stats = p.calib_stats(&fp16, 4)?;
            let tcfg = args.train_cfg();
            let act_calib = tcfg.act_calib.clone();
            let wgt_calib = tcfg.wgt_calib.clone();
            let mut qs = p.calibrated_quant_store(&prec, &fp16, &stats, &act_calib, &wgt_calib)?;
            let stats_t = p.qat(
                &prec, &mut qs, &fp16,
                DataMix::Instruct { style: SftStyle::TuluSynth, dclm_ratio: tcfg.dclm_ratio },
                tcfg, &mut log, None,
            )?;
            println!(
                "QAT done: {:.2} steps/s, final loss {:.4}",
                stats_t.steps_per_sec(), stats_t.final_loss
            );
            let out = args.get("out").unwrap_or("runs/qat/model.ckpt").to_string();
            qs.save(&out)?;
            let r = p.eval(&prec, &qs, true)?;
            println!("eval: {}", r.summary());
            Ok(())
        }
        "eval" => {
            // the host backend is fully artifact-free: no engine, no
            // manifest, no PJRT — built-in config mirrors describe the model
            if args.pipeline_cfg()?.backend == BackendKind::Host {
                return host_eval_cmd(&args);
            }
            let eng = Engine::new(&art_dir)?;
            let p = Pipeline::new(&eng, args.pipeline_cfg()?)?;
            let prec = args.get("prec").unwrap_or("fp16").to_string();
            let ckpt = args.get("ckpt").context("--ckpt required")?;
            // spec comes from the manifest, not eng.module(): loading a
            // checkpoint must not pay a PJRT compile of the fwd artifact
            let spec = eng.manifest.artifact(&format!("{}_{prec}_fwd", p.cfg.model))?.clone();
            let params = silq::model::ParamStore::load(&spec, ckpt)?;
            let chat = args.get("chat").map(|v| v == "1").unwrap_or(true);
            let r = p.eval(&prec, &params, chat)?;
            println!("{}", r.summary());
            for (name, suite, acc) in &r.per_task {
                println!("  {:<16} {:8} {:.2}", name, suite.label(), 100.0 * acc);
            }
            Ok(())
        }
        "serve" => {
            let eng = Engine::new(&art_dir)?;
            serve_cmd(&eng, &args)
        }
        "exp" => {
            let id = args.pos().context("exp needs an id: table1..table4, fig1..fig3")?;
            let eng = Engine::new(&art_dir)?;
            run_experiment(&eng, id, args.pipeline_cfg()?)
        }
        "e2e" => {
            // delegated to the example so `cargo run --example qat_e2e` and
            // `silq e2e` share one code path
            let eng = Engine::new(&art_dir)?;
            silq::coordinator::experiments::run_experiment(&eng, "fig2", args.pipeline_cfg()?)?;
            println!("(full e2e lives in examples/qat_e2e.rs — `cargo run --release --example qat_e2e`)");
            Ok(())
        }
        other => bail!("unknown command {other}; try `silq help`"),
    }
}

/// `silq eval --backend host`: score a checkpoint through the host
/// transformer — no compiled artifacts, no manifest, no PJRT. The model
/// and precision come from the built-in mirrors of
/// `python/compile/configs.py`; quantized precisions keep the K/V cache in
/// the deployment INT8 representation and decode incrementally.
fn host_eval_cmd(args: &Args) -> Result<()> {
    let model = args.get("model").unwrap_or("tiny");
    // same default precision as the artifact eval path, so flipping only
    // --backend never changes what is evaluated
    let prec = args.get("prec").unwrap_or("fp16");
    let mc = hostmodel::builtin_model(model)
        .with_context(|| format!("unknown model {model} (host backend knows tiny|small|tiny-pallas)"))?;
    let pc = hostmodel::builtin_prec(prec)
        .with_context(|| format!("unknown precision {prec}"))?;
    let hc = HostCfg::from_cfgs(&mc, &pc)?;
    let spec = hostmodel::host_param_spec(&hc);
    let params = match args.get("ckpt") {
        Some(path) => {
            println!("loading checkpoint {path}");
            ParamStore::load(&spec, path)?
        }
        None => {
            let seed = args.get("seed").and_then(|s| s.parse().ok()).unwrap_or(0);
            println!("no --ckpt given; evaluating a fresh random-init model (scores ~ chance)");
            hostmodel::host_test_params(&hc, seed)
        }
    };
    let store = hostmodel::cache_store_for(&pc);
    let fwd = HostForward::new(hc, mc.fwd_batch, &params, store)?;
    let chat = args.get("chat").map(|v| v == "1").unwrap_or(true);
    let n_items: usize = args.get("eval_items").unwrap_or("40").parse()?;
    let world_seed: u64 = args.get("world_seed").unwrap_or("7").parse()?;
    let world = World::generate(Vocab::new(mc.vocab), world_seed);
    let mut ev = Evaluator::new(fwd, chat, n_items);
    let r = ev.eval_all(&world, world_seed ^ silq::evalharness::EVAL_SEED_SALT)?;
    println!("backend=host model={model} prec={prec} (artifact-free)");
    println!("{}", r.summary());
    for (name, suite, acc) in &r.per_task {
        println!("  {:<16} {:8} {:.2}", name, suite.label(), 100.0 * acc);
    }
    Ok(())
}

/// `silq serve`: self-driving load run — producer threads push synthetic
/// chat requests through the bounded admission queue while the
/// continuous-batching scheduler drains it (there is no network stack in
/// this offline environment; the load generator stands in for clients).
fn serve_cmd(eng: &Engine, args: &Args) -> Result<()> {
    let model = args.get("model").unwrap_or("tiny").to_string();
    let prec = args.get("prec").unwrap_or("a8d-c8-w4").to_string();
    let backend_kind = args.get("backend").unwrap_or("artifact").to_string();
    let n_requests: usize = args.get("requests").unwrap_or("64").parse()?;
    let batch: usize = args.get("batch").unwrap_or("8").parse()?;
    let max_new: usize = args.get("max_new").unwrap_or("8").parse()?;
    let queue_cap: usize = args.get("queue_cap").unwrap_or("16").parse()?;
    let producers: usize = args.get("producers").unwrap_or("2").parse::<usize>()?.max(1);

    let mc = eng.manifest.model(&model)?.clone();
    let art = format!("{model}_{prec}_fwd");
    // spec comes from the manifest, not eng.module(): the host backend must
    // not pay (or depend on) a PJRT compile of the fwd artifact
    let spec = eng.manifest.artifact(&art)?.clone();

    // trained checkpoint if given, else a freshly calibrated model (noise
    // answers, but the latency/throughput trajectory is what we measure)
    let params: ParamStore = match args.get("ckpt") {
        Some(path) => {
            println!("loading checkpoint {path}");
            ParamStore::load(&spec, path)?
        }
        None if prec == "fp16" => {
            // init straight from the manifest spec — no PJRT compile needed
            let mut rng = silq::util::Rng::new(0);
            ParamStore::init(&spec, &mc, &mut rng)
        }
        None => {
            println!("no checkpoint given; calibrating a fresh (untrained) model");
            let p = Pipeline::new(
                eng,
                PipelineCfg { model: model.clone(), eval_items: 4, ..Default::default() },
            )?;
            let fp16 = init_model(eng, &format!("{model}_fp16_fwd"), 0)?;
            let cstats = p.calib_stats(&fp16, 2)?;
            p.calibrated_quant_store(&prec, &fp16, &cstats, "quantile", "mse")?
        }
    };

    // synthetic chat traffic: questions about the world's entities
    let world = World::generate(Vocab::new(mc.vocab), 7);
    let v = world.vocab.clone();
    let prompts: Vec<Vec<i32>> = (0..n_requests)
        .map(|i| {
            vec![
                vocab::BOS, vocab::Q,
                Vocab::attr_type(i % 4), vocab::OF, v.entity(i * 3 % world.n_entities()),
                vocab::A,
            ]
        })
        .collect();

    println!(
        "serving {n_requests} requests: backend={backend_kind} prec={prec} \
         batch={batch} max_new={max_new} queue_cap={queue_cap} producers={producers}"
    );

    let queue = Arc::new(AdmissionQueue::new(queue_cap));
    let mut producer_handles = vec![];
    for p in 0..producers {
        let q = queue.clone();
        let mine: Vec<(u64, Vec<i32>)> = prompts
            .iter()
            .enumerate()
            .filter(|(i, _)| i % producers == p)
            .map(|(i, pr)| (i as u64, pr.clone()))
            .collect();
        producer_handles.push(std::thread::spawn(move || -> Result<()> {
            for (id, prompt) in mine {
                q.submit(GenRequest::new(id, prompt, max_new))?;
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            Ok(())
        }));
    }
    // close the queue once every producer has drained its share
    {
        let q = queue.clone();
        std::thread::spawn(move || {
            for h in producer_handles {
                let _ = h.join();
            }
            q.close();
        });
    }

    let t = Timer::start();
    let (results, stats) = match backend_kind.as_str() {
        "artifact" => {
            let b = ArtifactBackend::new(eng, &art, &params)?;
            let lanes = batch.min(b.lanes());
            let mut stats = ServeStats::new(lanes);
            let mut sched = Scheduler::new(b, lanes)?;
            let results = sched.run(&queue, &mut stats)?;
            (results, stats)
        }
        "host" => {
            let pc = eng.manifest.prec(&prec)?.clone();
            // integer storage only exists for quantized precisions; fp16
            // serving degrades to the f32 cache
            let store = match (pc.quantized, args.get("cache").unwrap_or("int8")) {
                (false, _) | (_, "f32") => CacheStore::F32,
                _ => CacheStore::Int8,
            };
            let b = HostBackend::new(HostCfg::from_cfgs(&mc, &pc)?, batch, &params, store)?;
            let mut stats = ServeStats::new(batch);
            let mut sched = Scheduler::new(b, batch)?;
            let results = sched.run(&queue, &mut stats)?;
            (results, stats)
        }
        other => bail!("unknown serve backend {other} (artifact|host)"),
    };
    let wall = t.secs();

    for r in results.iter().take(4) {
        println!(
            "  [{}] {:<40} -> {}",
            r.id,
            v.describe_seq(&r.tokens[..r.prompt_len]),
            v.describe_seq(r.generated())
        );
    }
    if results.len() > 4 {
        println!("  ... and {} more", results.len() - 4);
    }
    println!("{}", stats.report());
    println!("wall time {wall:.2}s");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::parse_argv;

    fn args_of(v: &[&str]) -> Vec<(String, String)> {
        parse_argv(v.iter().map(|s| s.to_string()).collect()).flags
    }

    #[test]
    fn space_and_equals_forms_agree() {
        assert_eq!(args_of(&["x", "--prec", "fp16"]), args_of(&["x", "--prec=fp16"]));
    }

    #[test]
    fn equals_form_admits_flag_like_values() {
        // the space form degrades to a boolean; `=` is the escape hatch
        assert_eq!(args_of(&["x", "--note", "--fast"]),
                   vec![("note".to_string(), "1".to_string()), ("fast".to_string(), "1".to_string())]);
        assert_eq!(args_of(&["x", "--note=--fast"]),
                   vec![("note".to_string(), "--fast".to_string())]);
    }

    #[test]
    fn set_works_in_both_forms() {
        assert_eq!(args_of(&["x", "--set", "kd_ratio=0.5"]), args_of(&["x", "--set=kd_ratio=0.5"]));
        assert_eq!(args_of(&["x", "--set", "kd_ratio=0.5"]),
                   vec![("kd_ratio".to_string(), "0.5".to_string())]);
    }

    #[test]
    fn trailing_flag_is_boolean() {
        assert_eq!(args_of(&["x", "--chat"]), vec![("chat".to_string(), "1".to_string())]);
    }
}
