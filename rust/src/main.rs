//! `silq` — the coordinator CLI.
//!
//! Subcommands (clap is unavailable offline; parsing is hand-rolled):
//!   silq info                          # artifacts + configs
//!   silq prec [list|<spec>]            # precision presets / spec inspector
//!   silq pretrain|sft|qat [--set k=v]  # pipeline stages
//!   silq eval --ckpt path --prec p     # evaluate a checkpoint
//!   silq exp <table1|...|fig3>         # regenerate a paper table/figure
//!   silq e2e                           # full end-to-end demo (small model)
//!   silq serve                         # continuous-batching load run
//!   silq serve --listen ADDR           # HTTP front-end (streaming SSE)
//!   silq bench-serve                   # wire-level TTFT/throughput bench
//!
//! `--prec` accepts one currency everywhere: a manifest precision name
//! (`a8d-c8-w4`), a policy preset (`w4a8kv8-base`) or an inline spec
//! string (`w4a8kv8:statacts`) — see `silq prec list` and README
//! §Precision policies. Inline specs need no manifest entry and run on
//! the host backend.

use anyhow::{anyhow, bail, ensure, Context, Result};
use std::sync::Arc;

use silq::config::{Manifest, ModelCfg, TrainCfg};
use silq::coordinator::{run_experiment, BackendKind, Pipeline, PipelineCfg};
use silq::data::{vocab, DataMix, SftStyle, Vocab, World};
use silq::evalharness::Evaluator;
use silq::forward::HostForward;
use silq::hostmodel::{self, CacheStore, HostCfg, KvLayout};
use silq::kernels::pool;
use silq::kernels::simd;
use silq::metrics::{percentile, RunLog, Table};
use silq::model::ParamStore;
use silq::net::{client as netclient, install_sigint_drain, Server, ServerCfg};
use silq::obs;
use silq::policy::{QuantPolicy, PRESETS};
use silq::runtime::Engine;
use silq::serve::{
    AdmissionQueue, ArtifactBackend, DecodeBackend, GenRequest, HostBackend, Scheduler, ServeStats,
};
use silq::train::init_model;
use silq::util::Timer;

struct Args {
    cmd: String,
    flags: Vec<(String, String)>,
}

fn parse_args() -> Args {
    parse_argv(std::env::args().skip(1).collect())
}

fn parse_argv(argv: Vec<String>) -> Args {
    let cmd = argv.first().cloned().unwrap_or_else(|| "help".into());
    let mut flags = vec![];
    let mut i = 1;
    while i < argv.len() {
        if let Some(name) = argv[i].strip_prefix("--") {
            if let Some((k, v)) = name.split_once('=') {
                // `--flag=value`: the unambiguous form — use it for values
                // that start with `--` or look like another flag. `--set`
                // overrides stay as ("set", "key=value") pairs so bad
                // values can be rejected with the key named.
                flags.push((k.into(), v.into()));
                i += 1;
            } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.push((name.into(), argv[i + 1].clone()));
                i += 2;
            } else {
                flags.push((name.into(), "1".into()));
                i += 1;
            }
        } else {
            flags.push(("_pos".into(), argv[i].clone()));
            i += 1;
        }
    }
    Args { cmd, flags }
}

/// Parse a numeric flag value, naming the flag in the error instead of
/// silently keeping a default.
fn parse_flag<T: std::str::FromStr>(key: &str, value: &str) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    value.parse().map_err(|e| anyhow!("--{key} {value}: {e}"))
}

/// Keys `--set` may target besides the training hyper-parameters
/// (consumed by [`Args::pipeline_cfg`]).
const PIPELINE_KEYS: &[&str] = &[
    "model", "backend", "pretrain_steps", "sft_steps", "qat_steps", "eval_items", "seed",
    "world_seed",
];

impl Args {
    fn get(&self, key: &str) -> Option<&str> {
        self.flags.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// Flags with `--set key=value` entries expanded into (key, value)
    /// pairs (a malformed `--set` is a hard error).
    fn overrides(&self) -> Result<Vec<(&str, &str)>> {
        let mut out = Vec::with_capacity(self.flags.len());
        for (k, v) in &self.flags {
            if k == "set" {
                let (sk, sv) = v
                    .split_once('=')
                    .with_context(|| format!("--set needs key=value, got {v:?}"))?;
                out.push((sk, sv));
            } else {
                out.push((k.as_str(), v.as_str()));
            }
        }
        Ok(out)
    }

    fn get_num<T: std::str::FromStr>(&self, key: &str, default: &str) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        parse_flag(key, self.get(key).unwrap_or(default))
    }

    fn pos(&self) -> Option<&str> {
        self.get("_pos")
    }

    fn pipeline_cfg(&self) -> Result<PipelineCfg> {
        let mut c = PipelineCfg::default();
        // `--set key=value` and `--key value` are interchangeable here,
        // as they are for the training keys
        for (k, v) in self.overrides()? {
            match k {
                "model" => c.model = v.into(),
                "pretrain_steps" => c.pretrain_steps = parse_flag(k, v)?,
                "sft_steps" => c.sft_steps = parse_flag(k, v)?,
                "qat_steps" => c.qat_steps = parse_flag(k, v)?,
                "eval_items" => c.eval_items = parse_flag(k, v)?,
                "seed" => c.seed = parse_flag(k, v)?,
                "world_seed" => c.world_seed = parse_flag(k, v)?,
                // a mistyped backend must fail loudly, not silently run a
                // different compute path than the user asked for
                "backend" => c.backend = BackendKind::parse(v)?,
                _ => {}
            }
        }
        Ok(c)
    }

    fn train_cfg(&self) -> Result<TrainCfg> {
        let mut t = TrainCfg::default();
        for (k, v) in &self.flags {
            if k == "set" {
                let (sk, sv) = v
                    .split_once('=')
                    .with_context(|| format!("--set needs key=value, got {v:?}"))?;
                // an explicit --set must land somewhere: a training key
                // (applied here) or a pipeline key (applied by
                // pipeline_cfg); anything else is a typo
                ensure!(
                    t.set(sk, sv)? || PIPELINE_KEYS.contains(&sk),
                    "--set {sk}: unknown key"
                );
            } else {
                // direct flags double as overrides when they name a
                // training key; a bad value for a known key is still a
                // hard error (TrainCfg::set names the key)
                t.set(k, v)?;
            }
        }
        Ok(t)
    }
}

/// Resolve a `--prec` string into a typed policy: a manifest precision
/// (when a manifest is at hand), a preset name, a legacy name, or an
/// inline spec.
fn resolve_policy(prec: &str, manifest: Option<&Manifest>) -> Result<QuantPolicy> {
    if let Some(pc) = manifest.and_then(|m| m.precs.get(prec)) {
        return pc.policy();
    }
    QuantPolicy::resolve(prec).with_context(|| {
        format!("--prec {prec}: not a manifest precision, preset or spec (try `silq prec list`)")
    })
}

fn main() -> Result<()> {
    let args = parse_args();
    let art_dir = args.get("artifacts").unwrap_or("artifacts").to_string();

    match args.cmd.as_str() {
        "help" | "--help" | "-h" => {
            println!(
                "silq — SiLQ reproduction coordinator\n\
                 usage: silq <cmd> [flags]\n\
                 cmds:  info | prec [list|<spec>] | pretrain | sft | qat | eval\n\
                 \x20      | exp <id> | e2e | serve | bench-serve\n\
                 flags: --model tiny|small\n\
                 \x20      --prec <manifest name | preset | spec>  e.g. a8d-c8-w4,\n\
                 \x20        w4a8kv8, w4a8kv8:statacts, fp16 (see `silq prec list`)\n\
                 \x20      --ckpt path\n\
                 \x20      --set key=value (training hyper-params; bad values are errors)\n\
                 \x20      --qat_steps N --pretrain_steps N --sft_steps N --eval_items N\n\
                 \x20      --backend artifact|host (eval/qat/serve; host needs no\n\
                 \x20      compiled artifacts and decodes incrementally over the\n\
                 \x20      quantized KV pool; on eval/serve an inline --prec spec\n\
                 \x20      selects host automatically — qat trains through compiled\n\
                 \x20      graphs, so it takes manifest precision names only)\n\
                 serve: --requests N --batch B --max_new M --queue_cap C --producers P\n\
                 \x20      --cache int8|f32 (host backend)\n\
                 \x20      --kv slab|paged (host backend; paged = fixed-size pages,\n\
                 \x20      lazy binding, copy-on-write prompt-prefix sharing, LRU\n\
                 \x20      reclaim — token-identical to slab) --page-size N\n\
                 \x20      (positions per page, default 16)\n\
                 \x20      --tokens-out FILE (load run: id-sorted generated-token\n\
                 \x20      lines, for the paged-vs-slab identity diff)\n\
                 \x20      --listen ADDR (HTTP front-end instead of the load run; host\n\
                 \x20      backend only; port 0 binds an ephemeral port; drain with\n\
                 \x20      POST /shutdown or ^C) --max_conns N (handler cap)\n\
                 \x20      --header_timeout_ms N (slowloris guard: a connection that\n\
                 \x20      takes longer than N ms to deliver its request is answered\n\
                 \x20      408; default 5000)\n\
                 bench-serve: wire-level bench over real sockets —\n\
                 \x20      --clients 1,4,8 --per_client N --mode closed|open --rate R\n\
                 \x20      [--addr host:port] (default: self-host on 127.0.0.1:0)\n\
                 \x20      --out FILE (default BENCH_serve.json, rows appended); open\n\
                 \x20      mode honors 429/503 Retry-After backoff hints\n\
                 exec:  --threads N (eval/qat/serve; kernel worker-pool width —\n\
                 \x20      default $SILQ_THREADS, else all cores; 1 = serial) and\n\
                 \x20      --kernel scalar|simd (dot micro-kernel dispatch; default\n\
                 \x20      simd). Both are bit-exact: thread count and kernel choice\n\
                 \x20      never change any result, only throughput\n\
                 faults: --faults SPEC (or $SILQ_FAULTS) arms deterministic fault\n\
                 \x20      injection for resilience tests. SPEC is entries joined by\n\
                 \x20      commas: site@nth[+period][:ms] or seed=N, with sites\n\
                 \x20      kv (KV-pool alloc fails) | lat:ms (kernel-shard latency)\n\
                 \x20      | torn (torn stream write) | stall:ms (client stalls\n\
                 \x20      mid-request) | full (admission queue reports full).\n\
                 \x20      e.g. --faults kv@3,lat@5+10:40,full@2 — 3rd KV alloc\n\
                 \x20      fails, every 10th shard call from the 5th sleeps 40ms,\n\
                 \x20      2nd submit is refused. Unset = disarmed, zero cost\n\
                 obs:   --trace out.trace.json (Chrome trace_event JSON — load in\n\
                 \x20      ui.perfetto.dev; serve + eval) and, serve only,\n\
                 \x20      --metrics-out metrics.json (per-step time series; see\n\
                 \x20      README §Observability for the schema)\n\
                 note:  `--flag value` and `--flag=value` are equivalent; use\n\
                 \x20      `--flag=value` when the value itself starts with `--`"
            );
            Ok(())
        }
        "info" => {
            let eng = Engine::new(&art_dir)?;
            println!("platform: {}", eng.platform());
            println!("models:");
            for m in eng.manifest.models.values() {
                println!(
                    "  {}: vocab={} d={} L={} H={} ff={} S={} (pallas={})",
                    m.name, m.vocab, m.d_model, m.n_layers, m.n_heads, m.d_ff, m.seq_len, m.use_pallas
                );
            }
            println!("precisions:");
            for pc in eng.manifest.precs.values() {
                println!("  {:<16} spec {}", pc.name, pc.policy()?);
            }
            println!("artifacts:  {}", eng.manifest.artifacts.len());
            Ok(())
        }
        "prec" => prec_cmd(&args),
        "pretrain" => {
            let eng = Engine::new(&art_dir)?;
            let p = Pipeline::new(&eng, args.pipeline_cfg()?)?;
            let mut log = RunLog::new("runs/pretrain");
            let params = p.base_model(&mut log)?;
            println!("base model ready ({} params)", params.numel());
            Ok(())
        }
        "sft" => {
            let eng = Engine::new(&art_dir)?;
            let p = Pipeline::new(&eng, args.pipeline_cfg()?)?;
            let mut log = RunLog::new("runs/sft");
            let style = match args.get("style").unwrap_or("tulu") {
                "original" => SftStyle::Original,
                _ => SftStyle::TuluSynth,
            };
            let params = p.instruct_model(style, "instruct", &mut log)?;
            println!("instruct model ready ({} params)", params.numel());
            Ok(())
        }
        "qat" => {
            configure_execution(&args)?;
            let eng = Engine::new(&art_dir)?;
            let p = Pipeline::new(&eng, args.pipeline_cfg()?)?;
            let mut log = RunLog::new("runs/qat");
            let prec = args.get("prec").unwrap_or("a8d-c8-w4").to_string();
            let fp16 = p.instruct_model(SftStyle::TuluSynth, "instruct", &mut log)?;
            let stats = p.calib_stats(&fp16, 4)?;
            let tcfg = args.train_cfg()?;
            let mut qs = p.calibrated_quant_store_with(
                &prec, &fp16, &stats, tcfg.act_calib, tcfg.wgt_calib,
            )?;
            let stats_t = p.qat(
                &prec, &mut qs, &fp16,
                DataMix::Instruct { style: SftStyle::TuluSynth, dclm_ratio: tcfg.dclm_ratio },
                tcfg, &mut log, None,
            )?;
            println!(
                "QAT done: {:.2} steps/s, final loss {:.4}",
                stats_t.steps_per_sec(), stats_t.final_loss
            );
            let out = args.get("out").unwrap_or("runs/qat/model.ckpt").to_string();
            qs.save(&out)?;
            let r = p.eval(&prec, &qs, true)?;
            println!("eval: {}", r.summary());
            Ok(())
        }
        "eval" => {
            configure_execution(&args)?;
            // the host backend is fully artifact-free: no engine, no
            // PJRT — built-in config mirrors describe the model. Explicit
            // --backend host selects it; so does a --prec the built
            // manifest doesn't know (inline specs, bare checkout)
            let prec = args.get("prec").unwrap_or("fp16").to_string();
            let cfg = args.pipeline_cfg()?;
            let manifest_has_prec = Manifest::load(&art_dir)
                .map(|m| m.precs.contains_key(&prec))
                .unwrap_or(false);
            let auto_host = args.get("backend").is_none() && !manifest_has_prec;
            if cfg.backend == BackendKind::Host || auto_host {
                if auto_host {
                    println!(
                        "--prec {prec} is not a built manifest precision; evaluating \
                         on the artifact-free host backend"
                    );
                }
                return host_eval_cmd(&args, &art_dir);
            }
            let eng = Engine::new(&art_dir)?;
            let p = Pipeline::new(&eng, cfg)?;
            if eng.manifest.prec(&prec).is_err() {
                bail!(
                    "--prec {prec} is not a manifest precision (the artifact backend \
                     needs a compiled graph per precision); inline policy specs run \
                     artifact-free with --backend host"
                );
            }
            let ckpt = args.get("ckpt").context("--ckpt required")?;
            // spec comes from the manifest, not eng.module(): loading a
            // checkpoint must not pay a PJRT compile of the fwd artifact
            let spec = eng.manifest.artifact(&format!("{}_{prec}_fwd", p.cfg.model))?.clone();
            let params = silq::model::ParamStore::load(&spec, ckpt)?;
            let chat = args.get("chat").map(|v| v == "1").unwrap_or(true);
            let r = p.eval(&prec, &params, chat)?;
            println!("{}", r.summary());
            for (name, suite, acc) in &r.per_task {
                println!("  {:<16} {:8} {:.2}", name, suite.label(), 100.0 * acc);
            }
            Ok(())
        }
        "serve" => serve_cmd(&args, &art_dir),
        "bench-serve" => bench_serve_cmd(&args, &art_dir),
        "exp" => {
            let id = args.pos().context("exp needs an id: table1..table4, fig1..fig3")?;
            let eng = Engine::new(&art_dir)?;
            run_experiment(&eng, id, args.pipeline_cfg()?)
        }
        "e2e" => {
            // delegated to the example so `cargo run --example qat_e2e` and
            // `silq e2e` share one code path
            let eng = Engine::new(&art_dir)?;
            silq::coordinator::experiments::run_experiment(&eng, "fig2", args.pipeline_cfg()?)?;
            println!("(full e2e lives in examples/qat_e2e.rs — `cargo run --release --example qat_e2e`)");
            Ok(())
        }
        other => bail!("unknown command {other}; try `silq help`"),
    }
}

/// Apply the execution-layer flags shared by eval/qat/serve: `--threads`
/// (default: `SILQ_THREADS`, else every available core) sizes the
/// persistent kernel worker pool, `--kernel scalar|simd` picks the dot
/// micro-kernel. Every setting is bit-exact — results never depend on
/// either choice — so this only moves throughput.
fn configure_execution(args: &Args) -> Result<()> {
    let threads = match args.get("threads") {
        Some(_) => args.get_num::<usize>("threads", "1")?.max(1),
        None => pool::env_threads().unwrap_or_else(|| {
            std::thread::available_parallelism().map_or(1, |n| n.get())
        }),
    };
    pool::configure(threads);
    if let Some(k) = args.get("kernel") {
        simd::set_kernel(simd::KernelChoice::parse(k)?);
    }
    // deterministic fault injection: `--faults SPEC` wins over the
    // `SILQ_FAULTS` env var; unset means fully disarmed (one relaxed
    // load per site check).
    let faults = args
        .get("faults")
        .map(str::to_string)
        .or_else(|| std::env::var("SILQ_FAULTS").ok().filter(|s| !s.is_empty()));
    if let Some(spec) = faults {
        silq::faults::configure(&spec).map_err(|e| anyhow::anyhow!("--faults {spec}: {e}"))?;
        eprintln!("faults armed: {spec}");
    }
    Ok(())
}

/// `silq prec list` / `silq prec <spec>`: the policy inspector — prints
/// the preset table, or parses any precision string and pretty-prints the
/// resulting policy.
fn prec_cmd(args: &Args) -> Result<()> {
    match args.pos() {
        None | Some("list") => {
            println!("{:<14} {:<20} {:<14} note", "preset", "spec", "manifest prec");
            for p in PRESETS {
                println!(
                    "{:<14} {:<20} {:<14} {}",
                    p.name,
                    p.spec,
                    p.manifest_prec.unwrap_or("-"),
                    p.note
                );
            }
            println!(
                "\nany inline spec works too: w<bits>a<bits>kv<bits>[:mods] with mods\n\
                 statacts|dynacts, h<bits>, q<bits>, rot, acal=quantile|max, wcal=mse|lsq\n\
                 (`silq prec <spec>` pretty-prints one)"
            );
        }
        Some(spec) => {
            let p = QuantPolicy::resolve(spec)?;
            println!("{spec} -> {p}");
            print!("{}", p.describe());
            println!(
                "  serve cache store: {:?}",
                CacheStore::for_policy(&p)
            );
        }
    }
    Ok(())
}

/// `silq eval --backend host`: score a checkpoint through the host
/// transformer — no compiled artifacts, no PJRT. The model comes from the
/// built-in mirrors of `python/compile/configs.py`; the precision is any
/// policy string (manifest name, preset or inline spec — a manifest on
/// disk is consulted when present, but never required). Quantized
/// policies keep the K/V cache in the deployment INT8 representation and
/// decode incrementally.
fn host_eval_cmd(args: &Args, art_dir: &str) -> Result<()> {
    let model = args.get("model").unwrap_or("tiny");
    let trace_path = args.get("trace").map(str::to_string);
    if trace_path.is_some() {
        obs::enable_tracing(1 << 18);
    }
    let build_t = Timer::start();
    // same default precision as the artifact eval path, so flipping only
    // --backend never changes what is evaluated
    let prec = args.get("prec").unwrap_or("fp16");
    let mc = hostmodel::builtin_model(model)
        .with_context(|| format!("unknown model {model} (host backend knows tiny|small|tiny-pallas)"))?;
    let manifest = Manifest::load(art_dir).ok();
    let policy = resolve_policy(prec, manifest.as_ref())?;
    let hc = HostCfg::from_policy(&mc, &policy)?;
    let spec = hostmodel::host_param_spec(&hc);
    let params = match args.get("ckpt") {
        Some(path) => {
            println!("loading checkpoint {path}");
            ParamStore::load(&spec, path)?
        }
        None => {
            let seed = args.get_num("seed", "0")?;
            println!("no --ckpt given; evaluating a fresh random-init model (scores ~ chance)");
            hostmodel::host_test_params(&hc, seed)
        }
    };
    let store = CacheStore::for_policy(&hc.policy);
    let fwd = HostForward::new(hc.clone(), mc.fwd_batch, &params, store)?;
    let chat = args.get("chat").map(|v| v == "1").unwrap_or(true);
    let n_items: usize = args.get_num("eval_items", "40")?;
    let world_seed: u64 = args.get_num("world_seed", "7")?;
    let world = World::generate(Vocab::new(mc.vocab), world_seed);
    let mut ev = Evaluator::new(fwd, chat, n_items);
    let build_secs = build_t.secs();
    let eval_t = Timer::start();
    let r = ev.eval_all(&world, world_seed ^ silq::evalharness::EVAL_SEED_SALT)?;
    let eval_secs = eval_t.secs();
    println!(
        "backend=host model={model} prec={prec} policy={} threads={} kernel={} (artifact-free)",
        hc.policy,
        pool::active_threads(),
        simd::active_name()
    );
    println!("{}", r.summary());
    for (name, suite, acc) in &r.per_task {
        println!("  {:<16} {:8} {:.2}", name, suite.label(), 100.0 * acc);
    }
    let wall = (build_secs + eval_secs).max(1e-9);
    let mut t = Table::new(&["phase", "secs", "% wall"]);
    t.row(&["build+load".into(), format!("{build_secs:.3}"), format!("{:.1}", 100.0 * build_secs / wall)]);
    t.row(&["eval".into(), format!("{eval_secs:.3}"), format!("{:.1}", 100.0 * eval_secs / wall)]);
    println!("phase breakdown:\n{}", t.render());
    if let Some(p) = &trace_path {
        obs::export::write_chrome_trace(p).with_context(|| format!("writing --trace {p}"))?;
        println!("(chrome trace -> {p}; load in ui.perfetto.dev or chrome://tracing)");
    }
    Ok(())
}

/// `silq serve`: self-driving load run — producer threads push synthetic
/// chat requests through the bounded admission queue while the
/// continuous-batching scheduler drains it (the load generator stands in
/// for clients; `--listen ADDR` swaps it for the real HTTP front-end,
/// [`serve_http_cmd`]).
///
/// Backend choice: `--backend` wins; otherwise the compiled artifact is
/// used when the manifest knows `--prec`, and the artifact-free host
/// backend otherwise (inline specs, bare checkouts).
fn serve_cmd(args: &Args, art_dir: &str) -> Result<()> {
    if args.get("listen").is_some() {
        return serve_http_cmd(args, art_dir);
    }
    configure_execution(args)?;
    let model = args.get("model").unwrap_or("tiny").to_string();
    let prec = args.get("prec").unwrap_or("a8d-c8-w4").to_string();
    let n_requests: usize = args.get_num("requests", "64")?;
    let batch: usize = args.get_num("batch", "8")?;
    let max_new: usize = args.get_num("max_new", "8")?;
    let queue_cap: usize = args.get_num("queue_cap", "16")?;
    let producers: usize = args.get_num::<usize>("producers", "2")?.max(1);
    let trace_path = args.get("trace").map(str::to_string);
    let metrics_path = args.get("metrics-out").map(str::to_string);
    if trace_path.is_some() {
        // ring sized for the whole run: per-token hostmodel spans dominate
        // (prefill + decode per request), plus per-step and lifecycle spans
        obs::enable_tracing(n_requests * (max_new + 16) * 4 + 4096);
    } else if metrics_path.is_some() {
        obs::set_enabled(true);
    }

    let manifest = Manifest::load(art_dir).ok();
    let backend_kind = match args.get("backend") {
        Some(b) => b.to_string(),
        None if manifest.as_ref().map(|m| m.precs.contains_key(&prec)).unwrap_or(false) => {
            "artifact".into()
        }
        None => {
            println!(
                "--prec {prec} is not a built manifest precision; serving on the \
                 artifact-free host backend"
            );
            "host".into()
        }
    };
    let policy = resolve_policy(&prec, manifest.as_ref())?;

    // model shape: manifest entry when built, built-in mirror otherwise
    let mc = manifest
        .as_ref()
        .and_then(|m| m.models.get(&model).cloned())
        .or_else(|| hostmodel::builtin_model(&model))
        .with_context(|| format!("unknown model {model}"))?;

    // synthetic chat traffic: questions about the world's entities
    let world = World::generate(Vocab::new(mc.vocab), 7);
    let v = world.vocab.clone();
    let prompts: Vec<Vec<i32>> = (0..n_requests)
        .map(|i| {
            vec![
                vocab::BOS, vocab::Q,
                Vocab::attr_type(i % 4), vocab::OF, v.entity(i * 3 % world.n_entities()),
                vocab::A,
            ]
        })
        .collect();

    println!(
        "serving {n_requests} requests: backend={backend_kind} prec={prec} policy={policy} \
         batch={batch} max_new={max_new} queue_cap={queue_cap} producers={producers} \
         threads={} kernel={}",
        pool::active_threads(),
        simd::active_name()
    );

    let queue = Arc::new(AdmissionQueue::new(queue_cap));
    let mut producer_handles = vec![];
    for p in 0..producers {
        let q = queue.clone();
        let mine: Vec<(u64, Vec<i32>)> = prompts
            .iter()
            .enumerate()
            .filter(|(i, _)| i % producers == p)
            .map(|(i, pr)| (i as u64, pr.clone()))
            .collect();
        producer_handles.push(std::thread::spawn(move || -> Result<()> {
            for (id, prompt) in mine {
                q.submit(GenRequest::new(id, prompt, max_new))?;
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            Ok(())
        }));
    }
    // close the queue once every producer has drained its share
    {
        let q = queue.clone();
        std::thread::spawn(move || {
            for h in producer_handles {
                let _ = h.join();
            }
            q.close();
        });
    }

    let t = Timer::start();
    let (results, stats) = match backend_kind.as_str() {
        "artifact" => {
            let eng = Engine::new(art_dir)?;
            ensure!(
                eng.manifest.precs.contains_key(&prec),
                "--prec {prec} is not a manifest precision (the artifact backend needs a \
                 compiled graph); inline policy specs serve with --backend host"
            );
            let art = format!("{model}_{prec}_fwd");
            // spec comes from the manifest, not eng.module(): loading a
            // checkpoint must not pay a PJRT compile of the fwd artifact
            let spec = eng.manifest.artifact(&art)?.clone();
            // trained checkpoint if given, else a freshly calibrated model
            // (noise answers, but the latency/throughput trajectory is what
            // we measure)
            let params: ParamStore = match args.get("ckpt") {
                Some(path) => {
                    println!("loading checkpoint {path}");
                    ParamStore::load(&spec, path)?
                }
                None if !policy.quantized => {
                    // init straight from the manifest spec — no PJRT compile
                    let mut rng = silq::util::Rng::new(0);
                    ParamStore::init(&spec, &mc, &mut rng)
                }
                None => {
                    println!("no checkpoint given; calibrating a fresh (untrained) model");
                    let p = Pipeline::new(
                        &eng,
                        PipelineCfg { model: model.clone(), eval_items: 4, ..Default::default() },
                    )?;
                    let fp16 = init_model(&eng, &format!("{model}_fp16_fwd"), 0)?;
                    let cstats = p.calib_stats(&fp16, 2)?;
                    p.calibrated_quant_store(&prec, &fp16, &cstats)?
                }
            };
            let b = ArtifactBackend::new(&eng, &art, &params)?;
            let lanes = batch.min(b.lanes());
            let mut stats = ServeStats::new(lanes);
            let mut sched = Scheduler::new(b, lanes)?;
            let results = sched.run(&queue, &mut stats)?;
            (results, stats)
        }
        "host" => {
            let b = build_host_backend(args, &mc, &policy, batch)?;
            let mut stats = ServeStats::new(batch);
            let mut sched = Scheduler::new(b, batch)?;
            let results = sched.run(&queue, &mut stats)?;
            (results, stats)
        }
        other => bail!("unknown serve backend {other} (artifact|host)"),
    };
    let wall = t.secs();

    for r in results.iter().take(4) {
        println!(
            "  [{}] {:<40} -> {}",
            r.id,
            v.describe_seq(&r.tokens[..r.prompt_len]),
            v.describe_seq(r.generated())
        );
    }
    if results.len() > 4 {
        println!("  ... and {} more", results.len() - 4);
    }
    println!("{}", stats.report());
    println!("phase breakdown:\n{}", stats.breakdown());
    println!("wall time {wall:.2}s");
    if let Some(p) = &metrics_path {
        std::fs::write(p, stats.metrics_json())
            .with_context(|| format!("writing --metrics-out {p}"))?;
        println!("(per-step metrics -> {p})");
    }
    if let Some(p) = &trace_path {
        obs::export::write_chrome_trace(p).with_context(|| format!("writing --trace {p}"))?;
        println!("(chrome trace -> {p}; load in ui.perfetto.dev or chrome://tracing)");
    }
    if let Some(p) = args.get("tokens-out") {
        // one line per request, id-sorted: the paged-vs-slab identity
        // smoke in check.sh diffs two of these files byte for byte
        let mut rows: Vec<(u64, String)> = results
            .iter()
            .map(|r| {
                let toks: Vec<String> = r.generated().iter().map(|t| t.to_string()).collect();
                (r.id, toks.join(" "))
            })
            .collect();
        rows.sort_by_key(|(id, _)| *id);
        let mut out = String::new();
        for (id, toks) in rows {
            out.push_str(&format!("{id}: {toks}\n"));
        }
        std::fs::write(p, out).with_context(|| format!("writing --tokens-out {p}"))?;
        println!("(token streams -> {p})");
    }
    Ok(())
}

/// Build the artifact-free host serving backend shared by the load run,
/// the HTTP front-end and the wire bench: model shape + policy ->
/// `HostCfg`, params from `--ckpt` or a seeded random init, cache store
/// from `--cache` (with the fp16 degradation rule).
fn build_host_backend(
    args: &Args,
    mc: &ModelCfg,
    policy: &QuantPolicy,
    lanes: usize,
) -> Result<HostBackend> {
    let hc = HostCfg::from_policy(mc, policy)?;
    let spec = hostmodel::host_param_spec(&hc);
    let params = match args.get("ckpt") {
        Some(path) => {
            println!("loading checkpoint {path}");
            ParamStore::load(&spec, path)?
        }
        None => {
            let seed = args.get_num("seed", "0")?;
            println!(
                "no --ckpt given; serving a fresh random-init model (noise \
                 answers; the latency/throughput trajectory is the measurement)"
            );
            hostmodel::host_test_params(&hc, seed)
        }
    };
    // --cache folds into the policy-derived store; unknown values
    // are rejected with the accepted set named
    let store = match args.get("cache") {
        None => CacheStore::for_policy(policy),
        Some(c) => {
            let c = CacheStore::parse(c)?;
            if c == CacheStore::Int8 && !policy.quantized {
                // integer storage only exists for quantized
                // policies; fp16 serving degrades to the f32 cache
                println!("fp16 policy has no integer cache; serving with the f32 cache");
                CacheStore::F32
            } else {
                c
            }
        }
    };
    // --kv selects the pool geometry: the contiguous slab (default) or the
    // paged allocator with copy-on-write prefix sharing; --page-size tunes
    // positions per page (paged only)
    let layout = match args.get("kv") {
        None => KvLayout::Slab,
        Some(k) => match KvLayout::parse(k)? {
            KvLayout::Paged { page_size, total_pages, sharing } => KvLayout::Paged {
                page_size: args.get_num("page-size", &page_size.to_string())?,
                total_pages,
                sharing,
            },
            slab => slab,
        },
    };
    if layout != KvLayout::Slab {
        println!("kv cache: paged layout ({layout:?})");
    }
    HostBackend::new_with_layout(hc, lanes, &params, store, layout)
}

/// `silq serve --listen ADDR`: the HTTP front-end. Host backend only (the
/// artifact backend holds PJRT state that cannot cross to the scheduler
/// worker thread). Serves until drained — `POST /shutdown` or SIGINT —
/// then proves clean teardown: every lane free, zero KV bytes resident.
fn serve_http_cmd(args: &Args, art_dir: &str) -> Result<()> {
    configure_execution(args)?;
    if args.get("backend").is_some_and(|b| b != "host") {
        bail!(
            "--listen serves on the host backend only (the artifact backend cannot \
             move to the scheduler worker thread)"
        );
    }
    let listen = args.get("listen").unwrap_or("127.0.0.1:8090").to_string();
    let model = args.get("model").unwrap_or("tiny").to_string();
    let prec = args.get("prec").unwrap_or("w4a8kv8").to_string();
    let lanes: usize = args.get_num::<usize>("batch", "4")?.max(1);
    let queue_cap: usize = args.get_num("queue_cap", "16")?;
    let max_conns: usize = args.get_num::<usize>("max_conns", "32")?.max(1);
    let default_max_new: usize = args.get_num("max_new", "16")?;
    let header_timeout_ms: u64 = args.get_num::<u64>("header_timeout_ms", "5000")?.max(1);
    let trace_path = args.get("trace").map(str::to_string);
    let metrics_path = args.get("metrics-out").map(str::to_string);
    if trace_path.is_some() {
        obs::enable_tracing(1 << 18);
    } else {
        // GET /metrics reads the live counter registry; keep it on
        obs::set_enabled(true);
    }

    let manifest = Manifest::load(art_dir).ok();
    let policy = resolve_policy(&prec, manifest.as_ref())?;
    let mc = manifest
        .as_ref()
        .and_then(|m| m.models.get(&model).cloned())
        .or_else(|| hostmodel::builtin_model(&model))
        .with_context(|| format!("unknown model {model}"))?;
    let backend = build_host_backend(args, &mc, &policy, lanes)?;

    let server = Server::bind(ServerCfg {
        addr: listen,
        lanes,
        queue_cap,
        max_conns,
        default_max_new,
        header_timeout_ms,
    })?;
    install_sigint_drain();
    let addr = server.local_addr();
    println!(
        "listening on {addr} (prec={prec} policy={policy} lanes={lanes} \
         queue_cap={queue_cap} max_conns={max_conns} threads={} kernel={})",
        pool::active_threads(),
        simd::active_name()
    );
    println!(
        "endpoints: POST /v1/completions | GET /healthz | GET /metrics | POST /shutdown \
         (graceful drain; ^C does the same)"
    );
    // the check.sh smoke tails this output for the bound address; it must
    // be on disk before the accept loop starts blocking
    {
        use std::io::Write as _;
        let _ = std::io::stdout().flush();
    }

    let t = Timer::start();
    let ((results, stats, backend), net) = server.run(backend)?;
    let wall = t.secs();

    println!("{}", stats.report());
    println!("phase breakdown:\n{}", stats.breakdown());
    println!(
        "wire: {} connections, {} requests ({} streaming, {} disconnects, {} x 429, \
         {} x 503 shed, {} guard rejects) in {wall:.2}s",
        net.connections, net.requests, net.streams, net.disconnects, net.rejected_429,
        net.shed_503, net.guard_rejects
    );
    if let Some(p) = &metrics_path {
        std::fs::write(p, stats.metrics_json())
            .with_context(|| format!("writing --metrics-out {p}"))?;
        println!("(per-step metrics -> {p})");
    }
    if let Some(p) = &trace_path {
        obs::export::write_chrome_trace(p).with_context(|| format!("writing --trace {p}"))?;
        println!("(chrome trace -> {p}; load in ui.perfetto.dev or chrome://tracing)");
    }
    ensure!(backend.all_slots_free(), "drain left a KV slot allocated");
    ensure!(backend.all_pages_free(), "drain left a KV page resident");
    ensure!(backend.kv_bytes() == 0, "drain left KV bytes resident");
    println!("drained clean ({} results)", results.len());
    Ok(())
}

/// `silq bench-serve`: wire-level serving bench over real sockets. For
/// each client count B, drive the HTTP front-end with B streaming
/// clients — closed loop (each client fires its next request when the
/// previous finishes) or open loop (requests launch at `--rate` per
/// second regardless of completions; queue-full 429s take the server's
/// `Retry-After` hint for a bounded backoff-and-retry, then count as
/// drops, not failures). Rows append to `--out` with client-measured TTFT p50/p95,
/// wire throughput, and threads/kernel provenance.
fn bench_serve_cmd(args: &Args, art_dir: &str) -> Result<()> {
    configure_execution(args)?;
    let mode = args.get("mode").unwrap_or("closed").to_string();
    ensure!(mode == "closed" || mode == "open", "--mode {mode}: closed|open");
    let clients: Vec<usize> = args
        .get("clients")
        .unwrap_or("1,4,8")
        .split(',')
        .map(|s| parse_flag("clients", s.trim()))
        .collect::<Result<_>>()?;
    ensure!(!clients.is_empty() && clients.iter().all(|&b| b > 0), "--clients needs counts >= 1");
    let per_client: usize = args.get_num::<usize>("per_client", "8")?.max(1);
    let max_tokens: usize = args.get_num::<usize>("max_new", "16")?.max(1);
    let rate: f64 = args.get_num("rate", "32")?;
    let out_path = args.get("out").unwrap_or("BENCH_serve.json").to_string();
    let prec = args.get("prec").unwrap_or("w4a8kv8").to_string();
    let model = args.get("model").unwrap_or("tiny").to_string();

    let manifest = Manifest::load(art_dir).ok();
    let policy = resolve_policy(&prec, manifest.as_ref())?;
    let mc = manifest
        .as_ref()
        .and_then(|m| m.models.get(&model).cloned())
        .or_else(|| hostmodel::builtin_model(&model))
        .with_context(|| format!("unknown model {model}"))?;

    // same synthetic chat traffic as the load run, generated client-side
    let world = World::generate(Vocab::new(mc.vocab), 7);
    let n_entities = world.n_entities();
    let v = world.vocab.clone();
    let prompt = move |i: usize| -> Vec<i32> {
        vec![
            vocab::BOS, vocab::Q,
            Vocab::attr_type(i % 4), vocab::OF, v.entity(i * 3 % n_entities),
            vocab::A,
        ]
    };

    // target: --addr for a server already running, else self-host on an
    // ephemeral port and drain it after the sweep
    let (addr, hosted) = match args.get("addr") {
        Some(a) => (a.to_string(), None),
        None => {
            obs::set_enabled(true);
            let lanes: usize = args.get_num::<usize>("batch", "8")?.max(1);
            let backend = build_host_backend(args, &mc, &policy, lanes)?;
            let server = Server::bind(ServerCfg {
                addr: "127.0.0.1:0".into(),
                lanes,
                queue_cap: args.get_num("queue_cap", "32")?,
                max_conns: 64,
                default_max_new: max_tokens,
                header_timeout_ms: 5000,
            })?;
            let flag = server.shutdown_flag();
            let addr = server.local_addr().to_string();
            println!("self-hosted server on {addr} (lanes={lanes})");
            let worker = std::thread::spawn(move || server.run(backend));
            (addr, Some((flag, worker)))
        }
    };

    println!(
        "bench-serve: mode={mode} clients={clients:?} per_client={per_client} \
         max_tokens={max_tokens} prec={prec} threads={} kernel={}",
        pool::active_threads(),
        simd::active_name()
    );
    let mut rows = Vec::new();
    for &b in &clients {
        let t = Timer::start();
        // (client-measured ttft_ms, tokens streamed); NaN ttft = dropped
        let outcomes: Vec<(f64, usize)> = if mode == "closed" {
            let mut hs = Vec::new();
            for c in 0..b {
                let addr = addr.clone();
                let prompt = prompt.clone();
                hs.push(std::thread::spawn(move || -> Result<Vec<(f64, usize)>> {
                    let mut out = Vec::with_capacity(per_client);
                    for k in 0..per_client {
                        let i = c * per_client + k;
                        let body = netclient::completion_body(
                            i as u64, &prompt(i), max_tokens, true, true,
                        );
                        let o = netclient::complete_streaming(&addr, &body, None)?;
                        out.push(if o.status == 200 {
                            (o.ttft_ms, o.tokens.len())
                        } else {
                            (f64::NAN, 0)
                        });
                    }
                    Ok(out)
                }));
            }
            let mut all = Vec::new();
            for h in hs {
                all.extend(h.join().map_err(|_| anyhow!("bench client panicked"))??);
            }
            all
        } else {
            let gap = std::time::Duration::from_secs_f64(1.0 / rate.max(1e-3));
            let mut hs = Vec::new();
            for i in 0..b * per_client {
                let addr = addr.clone();
                let prompt = prompt.clone();
                hs.push(std::thread::spawn(move || -> Result<(f64, usize)> {
                    let body = netclient::completion_body(
                        i as u64, &prompt(i), max_tokens, true, true,
                    );
                    // honor the server's backoff hint: a 429/503 with a
                    // retry_after_ms estimate gets a bounded number of
                    // waited retries before counting as a drop
                    let mut o = netclient::complete_streaming(&addr, &body, None)?;
                    for _ in 0..3 {
                        let Some(ms) = o.retry_after_ms else { break };
                        if o.status != 429 && o.status != 503 {
                            break;
                        }
                        std::thread::sleep(std::time::Duration::from_millis(ms.min(2000)));
                        o = netclient::complete_streaming(&addr, &body, None)?;
                    }
                    Ok(if o.status == 200 { (o.ttft_ms, o.tokens.len()) } else { (f64::NAN, 0) })
                }));
                std::thread::sleep(gap);
            }
            let mut all = Vec::new();
            for h in hs {
                all.push(h.join().map_err(|_| anyhow!("bench client panicked"))??);
            }
            all
        };
        let wall = t.secs().max(1e-9);
        let ttfts: Vec<f64> = outcomes.iter().map(|o| o.0).filter(|t| t.is_finite()).collect();
        let completed = ttfts.len();
        let dropped = outcomes.len() - completed;
        let tokens: usize = outcomes.iter().map(|o| o.1).sum();
        let tok_per_s = tokens as f64 / wall;
        let (p50, p95) = if ttfts.is_empty() {
            (0.0, 0.0)
        } else {
            (percentile(&ttfts, 50.0), percentile(&ttfts, 95.0))
        };
        println!(
            "  B={b:<3} completed {completed}/{} ttft p50 {p50:.2}ms p95 {p95:.2}ms \
             {tok_per_s:.1} tok/s ({wall:.2}s)",
            outcomes.len()
        );
        rows.push(format!(
            "  {{\"label\": \"wire {mode}-loop B={b}\", \"backend\": \"host+http\", \
             \"policy\": \"{prec}\", \"threads\": {}, \"kernel\": \"{}\", \
             \"clients\": {b}, \"mode\": \"{mode}\", \"completed\": {completed}, \
             \"dropped\": {dropped}, \"tok_per_s\": {tok_per_s:.2}, \
             \"wire_ttft_ms_p50\": {p50:.3}, \"wire_ttft_ms_p95\": {p95:.3}, \
             \"wall_secs\": {wall:.3}}}",
            pool::active_threads(),
            simd::active_name(),
        ));
    }

    if let Some((flag, worker)) = hosted {
        flag.store(true, std::sync::atomic::Ordering::SeqCst);
        let ((results, _stats, backend), net) =
            worker.join().map_err(|_| anyhow!("server worker panicked"))??;
        ensure!(backend.all_slots_free(), "bench drain left a KV slot allocated");
        println!(
            "server drained clean: {} results, {} connections, {} x 429",
            results.len(), net.connections, net.rejected_429
        );
    }
    append_bench_rows(&out_path, &rows)?;
    println!("({} rows -> {out_path})", rows.len());
    Ok(())
}

/// Append rows to a JSON-array bench file, preserving existing rows —
/// the same splice `BENCH.json` gets from the kernel bench. A missing or
/// empty file starts a fresh array.
fn append_bench_rows(path: &str, rows: &[String]) -> Result<()> {
    let joined = rows.join(",\n");
    let text = match std::fs::read_to_string(path) {
        Ok(existing) => {
            let head = existing.trim_end().trim_end_matches(']').trim_end().to_string();
            if head.trim() == "[" || head.trim().is_empty() {
                format!("[\n{joined}\n]\n")
            } else {
                format!("{},\n{joined}\n]\n", head.trim_end_matches(','))
            }
        }
        Err(_) => format!("[\n{joined}\n]\n"),
    };
    std::fs::write(path, text).with_context(|| format!("writing {path}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::parse_argv;

    fn args_of(v: &[&str]) -> Vec<(String, String)> {
        parse_argv(v.iter().map(|s| s.to_string()).collect()).flags
    }

    #[test]
    fn space_and_equals_forms_agree() {
        assert_eq!(args_of(&["x", "--prec", "fp16"]), args_of(&["x", "--prec=fp16"]));
    }

    #[test]
    fn equals_form_admits_flag_like_values() {
        // the space form degrades to a boolean; `=` is the escape hatch
        assert_eq!(args_of(&["x", "--note", "--fast"]),
                   vec![("note".to_string(), "1".to_string()), ("fast".to_string(), "1".to_string())]);
        assert_eq!(args_of(&["x", "--note=--fast"]),
                   vec![("note".to_string(), "--fast".to_string())]);
    }

    #[test]
    fn set_works_in_both_forms() {
        assert_eq!(args_of(&["x", "--set", "kd_ratio=0.5"]), args_of(&["x", "--set=kd_ratio=0.5"]));
        // --set entries stay unflattened so bad values can be rejected
        // with the key named
        assert_eq!(args_of(&["x", "--set", "kd_ratio=0.5"]),
                   vec![("set".to_string(), "kd_ratio=0.5".to_string())]);
    }

    #[test]
    fn train_cfg_applies_and_rejects_set_overrides() {
        let args = parse_argv(vec!["qat".into(), "--set".into(), "kd_ratio=0.5".into()]);
        assert_eq!(args.train_cfg().unwrap().kd_ratio, 0.5);
        // a bad value for a known key is a hard error naming the key
        let args = parse_argv(vec!["qat".into(), "--set".into(), "steps=notanumber".into()]);
        let e = args.train_cfg().unwrap_err().to_string();
        assert!(e.contains("steps"), "{e}");
        // an unknown --set key is a hard error
        let args = parse_argv(vec!["qat".into(), "--set".into(), "typo_key=1".into()]);
        assert!(args.train_cfg().is_err());
        // non-set flags that don't name training keys pass through
        let args = parse_argv(vec!["qat".into(), "--prec".into(), "fp16".into()]);
        assert!(args.train_cfg().is_ok());
    }

    #[test]
    fn set_reaches_pipeline_keys_too() {
        // the pre-policy behavior `--set qat_steps=200` must keep working
        let args = parse_argv(vec!["exp".into(), "--set".into(), "qat_steps=200".into()]);
        assert_eq!(args.pipeline_cfg().unwrap().qat_steps, 200);
        // train_cfg tolerates pipeline-only keys under --set...
        assert!(args.train_cfg().is_ok());
        // ...but a bad value still fails loudly where the key is consumed
        let args = parse_argv(vec!["exp".into(), "--set".into(), "qat_steps=abc".into()]);
        assert!(args.pipeline_cfg().is_err());
    }

    #[test]
    fn trailing_flag_is_boolean() {
        assert_eq!(args_of(&["x", "--chat"]), vec![("chat".to_string(), "1".to_string())]);
    }
}
