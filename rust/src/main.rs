//! `silq` — the coordinator CLI.
//!
//! Subcommands (clap is unavailable offline; parsing is hand-rolled):
//!   silq info                          # artifacts + configs
//!   silq pretrain|sft|qat [--set k=v]  # pipeline stages
//!   silq eval --ckpt path --prec p     # evaluate a checkpoint
//!   silq exp <table1|...|fig3>         # regenerate a paper table/figure
//!   silq e2e                           # full end-to-end demo (small model)

use anyhow::{bail, Context, Result};

use silq::config::TrainCfg;
use silq::coordinator::{run_experiment, Pipeline, PipelineCfg};
use silq::data::{DataMix, SftStyle};
use silq::metrics::RunLog;
use silq::runtime::Engine;

struct Args {
    cmd: String,
    flags: Vec<(String, String)>,
}

fn parse_args() -> Args {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().cloned().unwrap_or_else(|| "help".into());
    let mut flags = vec![];
    let mut i = 1;
    while i < argv.len() {
        if let Some(name) = argv[i].strip_prefix("--") {
            if name == "set" && i + 1 < argv.len() {
                if let Some((k, v)) = argv[i + 1].split_once('=') {
                    flags.push((k.into(), v.into()));
                }
                i += 2;
            } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.push((name.into(), argv[i + 1].clone()));
                i += 2;
            } else {
                flags.push((name.into(), "1".into()));
                i += 1;
            }
        } else {
            flags.push(("_pos".into(), argv[i].clone()));
            i += 1;
        }
    }
    Args { cmd, flags }
}

impl Args {
    fn get(&self, key: &str) -> Option<&str> {
        self.flags.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    fn pos(&self) -> Option<&str> {
        self.get("_pos")
    }

    fn pipeline_cfg(&self) -> PipelineCfg {
        let mut c = PipelineCfg::default();
        if let Some(m) = self.get("model") {
            c.model = m.into();
        }
        for (k, v) in &self.flags {
            match k.as_str() {
                "pretrain_steps" => c.pretrain_steps = v.parse().unwrap_or(c.pretrain_steps),
                "sft_steps" => c.sft_steps = v.parse().unwrap_or(c.sft_steps),
                "qat_steps" => c.qat_steps = v.parse().unwrap_or(c.qat_steps),
                "eval_items" => c.eval_items = v.parse().unwrap_or(c.eval_items),
                "seed" => c.seed = v.parse().unwrap_or(c.seed),
                "world_seed" => c.world_seed = v.parse().unwrap_or(c.world_seed),
                _ => {}
            }
        }
        c
    }

    fn train_cfg(&self) -> TrainCfg {
        let mut t = TrainCfg::default();
        for (k, v) in &self.flags {
            t.set(k, v);
        }
        t
    }
}

fn main() -> Result<()> {
    let args = parse_args();
    let art_dir = args.get("artifacts").unwrap_or("artifacts").to_string();

    match args.cmd.as_str() {
        "help" | "--help" | "-h" => {
            println!(
                "silq — SiLQ reproduction coordinator\n\
                 usage: silq <cmd> [flags]\n\
                 cmds:  info | pretrain | sft | qat | eval | exp <id> | e2e\n\
                 flags: --model tiny|small  --prec a8d-c8-w4|...  --ckpt path\n\
                        --set key=value (training hyper-params)\n\
                        --qat_steps N --pretrain_steps N --sft_steps N --eval_items N"
            );
            Ok(())
        }
        "info" => {
            let eng = Engine::new(&art_dir)?;
            println!("platform: {}", eng.platform());
            println!("models:");
            for m in eng.manifest.models.values() {
                println!(
                    "  {}: vocab={} d={} L={} H={} ff={} S={} (pallas={})",
                    m.name, m.vocab, m.d_model, m.n_layers, m.n_heads, m.d_ff, m.seq_len, m.use_pallas
                );
            }
            println!("precisions: {:?}", eng.manifest.precs.keys().collect::<Vec<_>>());
            println!("artifacts:  {}", eng.manifest.artifacts.len());
            Ok(())
        }
        "pretrain" => {
            let eng = Engine::new(&art_dir)?;
            let p = Pipeline::new(&eng, args.pipeline_cfg())?;
            let mut log = RunLog::new("runs/pretrain");
            let params = p.base_model(&mut log)?;
            println!("base model ready ({} params)", params.numel());
            Ok(())
        }
        "sft" => {
            let eng = Engine::new(&art_dir)?;
            let p = Pipeline::new(&eng, args.pipeline_cfg())?;
            let mut log = RunLog::new("runs/sft");
            let style = match args.get("style").unwrap_or("tulu") {
                "original" => SftStyle::Original,
                _ => SftStyle::TuluSynth,
            };
            let params = p.instruct_model(style, "instruct", &mut log)?;
            println!("instruct model ready ({} params)", params.numel());
            Ok(())
        }
        "qat" => {
            let eng = Engine::new(&art_dir)?;
            let p = Pipeline::new(&eng, args.pipeline_cfg())?;
            let mut log = RunLog::new("runs/qat");
            let prec = args.get("prec").unwrap_or("a8d-c8-w4").to_string();
            let fp16 = p.instruct_model(SftStyle::TuluSynth, "instruct", &mut log)?;
            let stats = p.calib_stats(&fp16, 4)?;
            let tcfg = args.train_cfg();
            let act_calib = tcfg.act_calib.clone();
            let wgt_calib = tcfg.wgt_calib.clone();
            let mut qs = p.calibrated_quant_store(&prec, &fp16, &stats, &act_calib, &wgt_calib)?;
            let stats_t = p.qat(
                &prec, &mut qs, &fp16,
                DataMix::Instruct { style: SftStyle::TuluSynth, dclm_ratio: tcfg.dclm_ratio },
                tcfg, &mut log, None,
            )?;
            println!(
                "QAT done: {:.2} steps/s, final loss {:.4}",
                stats_t.steps_per_sec(), stats_t.final_loss
            );
            let out = args.get("out").unwrap_or("runs/qat/model.ckpt").to_string();
            qs.save(&out)?;
            let r = p.eval(&prec, &qs, true)?;
            println!("eval: {}", r.summary());
            Ok(())
        }
        "eval" => {
            let eng = Engine::new(&art_dir)?;
            let p = Pipeline::new(&eng, args.pipeline_cfg())?;
            let prec = args.get("prec").unwrap_or("fp16").to_string();
            let ckpt = args.get("ckpt").context("--ckpt required")?;
            let spec = eng
                .module(&format!("{}_{prec}_fwd", p.cfg.model))?
                .spec
                .clone();
            let params = silq::model::ParamStore::load(&spec, ckpt)?;
            let chat = args.get("chat").map(|v| v == "1").unwrap_or(true);
            let r = p.eval(&prec, &params, chat)?;
            println!("{}", r.summary());
            for (name, suite, acc) in &r.per_task {
                println!("  {:<16} {:8} {:.2}", name, suite.label(), 100.0 * acc);
            }
            Ok(())
        }
        "exp" => {
            let id = args.pos().context("exp needs an id: table1..table4, fig1..fig3")?;
            let eng = Engine::new(&art_dir)?;
            run_experiment(&eng, id, args.pipeline_cfg())
        }
        "e2e" => {
            // delegated to the example so `cargo run --example qat_e2e` and
            // `silq e2e` share one code path
            let eng = Engine::new(&art_dir)?;
            silq::coordinator::experiments::run_experiment(&eng, "fig2", args.pipeline_cfg())?;
            println!("(full e2e lives in examples/qat_e2e.rs — `cargo run --release --example qat_e2e`)");
            Ok(())
        }
        other => bail!("unknown command {other}; try `silq help`"),
    }
}
