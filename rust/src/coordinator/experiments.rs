//! One runner per paper table/figure (DESIGN.md §4). Every runner prints
//! the regenerated rows and appends them to `runs/experiments_out.md` so
//! EXPERIMENTS.md can quote them verbatim.

use anyhow::Result;
use std::io::Write;

use crate::data::{DataMix, SftStyle, Suite};
use crate::evalharness::EvalReport;
use crate::metrics::{pct, RunLog, Table};
use crate::policy::CalibMethod;
use crate::runtime::Engine;
use crate::train::llm_qat;
use crate::util::Timer;

use super::pipeline::{Pipeline, PipelineCfg};

fn emit(section: &str, body: &str) -> Result<()> {
    println!("\n=== {section} ===\n{body}");
    std::fs::create_dir_all("runs")?;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open("runs/experiments_out.md")?;
    writeln!(f, "\n## {section}\n\n```\n{body}```")?;
    Ok(())
}

fn report_cells(r: &EvalReport) -> Vec<String> {
    vec![
        pct(r.suite_avg(Suite::Csr)),
        pct(r.suite_avg(Suite::OllmV1)),
        pct(r.suite_avg(Suite::OllmV2)),
    ]
}

/// Dispatch by experiment id.
pub fn run_experiment(engine: &Engine, id: &str, cfg: PipelineCfg) -> Result<()> {
    match id {
        "table1" => table1(engine, cfg),
        "fig1" => fig1(engine, cfg),
        "table2" => table2(engine, cfg),
        "table3" => table3(engine, cfg),
        "table4" => table4(engine, cfg),
        "fig2" => fig2(engine, cfg),
        "fig3" => fig3(engine, cfg),
        other => anyhow::bail!("unknown experiment {other} (table1..4, fig1..3)"),
    }
}

/// Table 1 (+5/6/7): SiLQ vs PTQ baselines across precisions, base+instruct.
fn table1(engine: &Engine, cfg: PipelineCfg) -> Result<()> {
    let p = Pipeline::new(engine, cfg)?;
    let mut log = RunLog::new("runs/table1");
    let mut t = Table::new(&["model", "bits", "method", "CSR", "OLLMv1", "OLLMv2"]);
    let mut per_task_dump = String::new();

    for (mtag, chat) in [("base", false), ("instruct", true)] {
        let fp16 = if chat {
            p.instruct_model(SftStyle::TuluSynth, "instruct", &mut log)?
        } else {
            p.base_model(&mut log)?
        };
        let stats = p.calib_stats(&fp16, 4)?;
        let rb = p.eval("fp16", &fp16, chat)?;
        t.row(&[mtag.into(), "16-16-16".into(), "Baseline".into(), report_cells(&rb)[0].clone(), report_cells(&rb)[1].clone(), report_cells(&rb)[2].clone()]);
        per_task_dump += &format!("{mtag} fp16: {:?}\n", rb.per_task);

        // precision grid: dynamic 8-8-4, static 8-8-4, dynamic 8-4-4
        let precs: Vec<&str> = if chat {
            vec!["a8d-c8-w4", "a8s-c8-w4", "a8d-c4-w4"]
        } else {
            vec!["a8d-c8-w4"]
        };
        for prec in precs {
            for method in ["smoothquant", "spinquant", "silq"] {
                let report = if method == "silq" {
                    let mut qs = p.calibrated_quant_store(prec, &fp16, &stats)?;
                    let mut tcfg = p.qat_cfg(p.cfg.qat_steps);
                    tcfg.seed = p.cfg.seed;
                    let mix = if chat {
                        DataMix::Instruct { style: SftStyle::TuluSynth, dclm_ratio: 0.25 }
                    } else {
                        DataMix::Corpus
                    };
                    p.qat(prec, &mut qs, &fp16, mix, tcfg, &mut log, None)?;
                    p.eval(prec, &qs, chat)?
                } else {
                    let qs = p.ptq_baseline(method, prec, &fp16, &stats)?;
                    p.eval(prec, &qs, chat)?
                };
                let c = report_cells(&report);
                t.row(&[mtag.into(), prec.into(), method.into(), c[0].clone(), c[1].clone(), c[2].clone()]);
                per_task_dump += &format!("{mtag} {prec} {method}: {:?}\n", report.per_task);
            }
        }
    }
    emit("Table 1 — SiLQ vs PTQ (suite averages)", &t.render())?;
    emit("Tables 5/6/7 — per-task accuracies", &per_task_dump)
}

/// Figure 1: accuracy (relative to fp16) vs QAT steps, SpinQuant dashed.
fn fig1(engine: &Engine, cfg: PipelineCfg) -> Result<()> {
    let p = Pipeline::new(engine, cfg)?;
    let mut log = RunLog::new("runs/fig1");
    let fp16 = p.instruct_model(SftStyle::TuluSynth, "instruct", &mut log)?;
    let stats = p.calib_stats(&fp16, 4)?;
    let prec = "a8d-c8-w4";
    let base = p.eval("fp16", &fp16, true)?;

    let spin = p.ptq_baseline("spinquant", prec, &fp16, &stats)?;
    let rs = p.eval(prec, &spin, true)?;

    let mut t = Table::new(&["qat_steps", "CSR rel", "OLLMv1 rel", "OLLMv2 rel"]);
    let rel = |r: &EvalReport, s: Suite| {
        let b = base.suite_avg(s).max(1e-6);
        format!("{:.3}", r.suite_avg(s) / b)
    };
    t.row(&[
        "spinquant (PTQ, dashed)".into(),
        rel(&rs, Suite::Csr),
        rel(&rs, Suite::OllmV1),
        rel(&rs, Suite::OllmV2),
    ]);

    // one long QAT run, evaluated at checkpoints (like the paper's curve)
    let steps_grid = [p.cfg.qat_steps / 8, p.cfg.qat_steps / 4, p.cfg.qat_steps / 2, p.cfg.qat_steps];
    let mut qs = p.calibrated_quant_store(prec, &fp16, &stats)?;
    let mut tcfg = p.qat_cfg(p.cfg.qat_steps);
    tcfg.eval_every = (p.cfg.qat_steps / 8).max(1);
    let mut rows: Vec<(usize, EvalReport)> = vec![];
    {
        let mut hook = |step: usize, params: &crate::model::ParamStore| {
            if steps_grid.contains(&step) {
                if let Ok(r) = p.eval(prec, params, true) {
                    rows.push((step, r));
                }
            }
        };
        p.qat(prec, &mut qs, &fp16, DataMix::Instruct { style: SftStyle::TuluSynth, dclm_ratio: 0.25 }, tcfg, &mut log, Some(&mut hook))?;
    }
    for (step, r) in &rows {
        t.row(&[
            format!("silq @{step}"),
            rel(r, Suite::Csr),
            rel(r, Suite::OllmV1),
            rel(r, Suite::OllmV2),
        ]);
    }
    emit("Figure 1 — accuracy vs QAT duration (relative to fp16)", &t.render())
}

/// Table 2: SiLQ on open data vs LLM-QAT on self-generated data.
fn table2(engine: &Engine, cfg: PipelineCfg) -> Result<()> {
    let p = Pipeline::new(engine, cfg)?;
    let mut log = RunLog::new("runs/table2");
    let fp16 = p.base_model(&mut log)?; // LLM-QAT targets base models
    let stats = p.calib_stats(&fp16, 4)?;
    let prec = "a8d-c8-w4";
    let rb = p.eval("fp16", &fp16, false)?;

    let mut t = Table::new(&["method", "secs", "samples", "CSR", "OLLMv1", "OLLMv2"]);
    let c = report_cells(&rb);
    t.row(&["Baseline".into(), "-".into(), "-".into(), c[0].clone(), c[1].clone(), c[2].clone()]);

    let n_samples = p.cfg.qat_steps * 4; // matched sample count
    let mc = engine.manifest.model(&p.cfg.model)?.clone();
    let steps = n_samples / mc.train_batch;

    // LLM-QAT: generate from the model, then QAT on the fixed set (the
    // generation backend follows PipelineCfg::backend — host runs it
    // incrementally over the KV pool, artifact-free)
    let gen_t = Timer::start();
    let mut gen_backend = p.forward("fp16", &fp16)?;
    let (docs, gen_secs) = llm_qat::self_generate(
        &mut gen_backend, n_samples, mc.seq_len - 1, 3, 1.0, p.cfg.seed,
    )?;
    drop(gen_backend);
    let mut qs = p.calibrated_quant_store(prec, &fp16, &stats)?;
    let tcfg = p.qat_cfg(steps);
    let st = p.qat(prec, &mut qs, &fp16, DataMix::Fixed(docs), tcfg.clone(), &mut log, None)?;
    let r_llmqat = p.eval(prec, &qs, false)?;
    let c = report_cells(&r_llmqat);
    t.row(&[
        "LLM-QAT (self-gen)".into(),
        format!("{:.1}", gen_t.secs()),
        format!("{n_samples}"),
        c[0].clone(), c[1].clone(), c[2].clone(),
    ]);
    log.note(&format!("llm-qat: gen {gen_secs:.1}s train {:.1}s", st.total_secs));

    // SiLQ on the open corpus, same samples
    let silq_t = Timer::start();
    let mut qs2 = p.calibrated_quant_store(prec, &fp16, &stats)?;
    p.qat(prec, &mut qs2, &fp16, DataMix::Corpus, tcfg, &mut log, None)?;
    let r_silq = p.eval(prec, &qs2, false)?;
    let c = report_cells(&r_silq);
    t.row(&[
        "SiLQ (open data)".into(),
        format!("{:.1}", silq_t.secs()),
        format!("{n_samples}"),
        c[0].clone(), c[1].clone(), c[2].clone(),
    ]);

    // SiLQ given the baseline's *total* wall-clock (gen time converted to
    // extra training steps) — the paper's last row
    let tcfg2 = p.qat_cfg(steps * 3);
    let mut qs3 = p.calibrated_quant_store(prec, &fp16, &stats)?;
    let st3 = p.qat(prec, &mut qs3, &fp16, DataMix::Corpus, tcfg2, &mut log, None)?;
    let r3 = p.eval(prec, &qs3, false)?;
    let c = report_cells(&r3);
    t.row(&[
        "SiLQ (matched time)".into(),
        format!("{:.1}", st3.total_secs),
        format!("{}", steps * 3 * mc.train_batch),
        c[0].clone(), c[1].clone(), c[2].clone(),
    ]);
    emit("Table 2 — SiLQ vs LLM-QAT", &t.render())
}

/// Table 3: original vs open (Tulu-like) SFT data for QAT.
fn table3(engine: &Engine, cfg: PipelineCfg) -> Result<()> {
    let p = Pipeline::new(engine, cfg)?;
    let mut log = RunLog::new("runs/table3");
    let prec = "a8d-c8-w4";
    let mut t = Table::new(&["sft data", "CSR", "OLLMv1", "OLLMv2"]);

    // the "original" instruct model was tuned on the narrow mixture
    let fp16 = p.instruct_model(SftStyle::Original, "instruct-orig", &mut log)?;
    let stats = p.calib_stats(&fp16, 4)?;
    for (tag, style) in [("Original", SftStyle::Original), ("Tulu3-synth", SftStyle::TuluSynth)] {
        let mut qs = p.calibrated_quant_store(prec, &fp16, &stats)?;
        let tcfg = p.qat_cfg(p.cfg.qat_steps);
        p.qat(prec, &mut qs, &fp16, DataMix::Instruct { style, dclm_ratio: 0.25 }, tcfg, &mut log, None)?;
        let r = p.eval(prec, &qs, true)?;
        let c = report_cells(&r);
        t.row(&[tag.into(), c[0].clone(), c[1].clone(), c[2].clone()]);
    }
    emit("Table 3 — SFT dataset substitution", &t.render())
}

/// Table 4: ablations around the default configuration.
fn table4(engine: &Engine, cfg: PipelineCfg) -> Result<()> {
    let p = Pipeline::new(engine, cfg)?;
    let mut log = RunLog::new("runs/table4");
    let fp16 = p.instruct_model(SftStyle::TuluSynth, "instruct", &mut log)?;
    let stats = p.calib_stats(&fp16, 4)?;

    struct Abl {
        name: &'static str,
        kd_ratio: f32,
        kd_temp: f32,
        dclm: f32,
        act_lrx: f32,
        act_calib: CalibMethod,
        wgt_calib: CalibMethod,
        prec: &'static str,
    }
    let b = Abl { name: "baseline", kd_ratio: 1.0, kd_temp: 1.0, dclm: 0.25, act_lrx: 50.0, act_calib: CalibMethod::Quantile, wgt_calib: CalibMethod::Mse, prec: "a8s-c8-w4" };
    let abls = vec![
        Abl { name: "kd_ratio=0 (pure NTP)", kd_ratio: 0.0, ..cfgcopy(&b) },
        Abl { name: "kd_ratio=0.5", kd_ratio: 0.5, ..cfgcopy(&b) },
        Abl { name: "kd_temp=0.5", kd_temp: 0.5, ..cfgcopy(&b) },
        Abl { name: "kd_temp=2.0", kd_temp: 2.0, ..cfgcopy(&b) },
        Abl { name: "dclm=0.0", dclm: 0.0, ..cfgcopy(&b) },
        Abl { name: "dclm=0.5", dclm: 0.5, ..cfgcopy(&b) },
        Abl { name: "act_lrx=1", act_lrx: 1.0, ..cfgcopy(&b) },
        Abl { name: "act_calib=max", act_calib: CalibMethod::Max, ..cfgcopy(&b) },
        Abl { name: "wgt_calib=lsq", wgt_calib: CalibMethod::Lsq, ..cfgcopy(&b) },
        Abl { name: "online_rot=yes", prec: "a8d-c8-w4-rot", ..cfgcopy(&b) },
    ];
    fn cfgcopy(b: &Abl) -> Abl {
        Abl { name: b.name, kd_ratio: b.kd_ratio, kd_temp: b.kd_temp, dclm: b.dclm, act_lrx: b.act_lrx, act_calib: b.act_calib, wgt_calib: b.wgt_calib, prec: b.prec }
    }

    let mut t = Table::new(&["config", "OLLMv1", "OLLMv2"]);
    let run_one = |a: &Abl, log: &mut RunLog| -> Result<(f32, f32)> {
        let mut qs = p.calibrated_quant_store_with(a.prec, &fp16, &stats, a.act_calib, a.wgt_calib)?;
        let mut tcfg = p.qat_cfg(p.cfg.qat_steps);
        tcfg.kd_ratio = a.kd_ratio;
        tcfg.kd_temp = a.kd_temp;
        tcfg.act_lrx = a.act_lrx;
        p.qat(a.prec, &mut qs, &fp16, DataMix::Instruct { style: SftStyle::TuluSynth, dclm_ratio: a.dclm }, tcfg, log, None)?;
        let r = p.eval(a.prec, &qs, true)?;
        Ok((r.suite_avg(Suite::OllmV1), r.suite_avg(Suite::OllmV2)))
    };

    let (v1b, v2b) = run_one(&b, &mut log)?;
    t.row(&[b.name.into(), pct(v1b), pct(v2b)]);
    for a in &abls {
        let (v1, v2) = run_one(a, &mut log)?;
        t.row(&[
            a.name.into(),
            format!("{} ({:+.2})", pct(v1), 100.0 * (v1 - v1b)),
            format!("{} ({:+.2})", pct(v2), 100.0 * (v2 - v2b)),
        ]);
    }
    emit("Table 4 — ablations (OLLMv1/v2)", &t.render())
}

/// Figure 2: textual rendering of the precision placement.
fn fig2(engine: &Engine, cfg: PipelineCfg) -> Result<()> {
    let mut out = String::new();
    for prec in ["a8d-c8-w4", "a8s-c8-w4", "a8d-c4-w4"] {
        let pc = engine.manifest.prec(prec)?;
        let spec = pc.policy()?;
        let d = if pc.act_dynamic { "dynamic/token" } else { "static/tensor (LSQ)" };
        out += &format!(
            "[{prec}] (spec {spec})\n  embedding            : fp16\n  linear inputs (acts) : INT{} {d}\n  query / softmax-out  : INT{} / unquantized-in-training\n  KV cache             : INT{}\n  linear weights       : INT{} per-output-channel (LSQ)\n  head (in/weights)    : INT{}\n  online Hadamard      : {}\n\n",
            pc.act_bits, pc.query_bits, pc.cache_bits, pc.weight_bits, pc.head_bits,
            if pc.online_rot { "yes" } else { "no" },
        );
    }
    let _ = cfg;
    emit("Figure 2 — transformer block precision placement", &out)
}

/// Figure 3: rotational vs non-rotational weight change, SiLQ vs SpinQuant.
fn fig3(engine: &Engine, cfg: PipelineCfg) -> Result<()> {
    let p = Pipeline::new(engine, cfg)?;
    let mut log = RunLog::new("runs/fig3");
    let fp16 = p.instruct_model(SftStyle::TuluSynth, "instruct", &mut log)?;
    let stats = p.calib_stats(&fp16, 4)?;
    let mc = engine.manifest.model(&p.cfg.model)?.clone();
    let prec = "a8d-c8-w4";

    // SpinQuant: baseline A is the norm-folded fp16 weights (paper §3.4)
    let mut folded = crate::train::quantize_store(engine, &format!("{}_{prec}_fwd", p.cfg.model), &fp16)?;
    crate::ptq::fold_norms(&mut folded, &mc)?;
    let spin = p.ptq_baseline("spinquant", prec, &fp16, &stats)?;
    let spin_split = crate::analysis::analyze_rotation(&folded, &spin, &mc)?;

    // SiLQ QAT
    let mut qs = p.calibrated_quant_store(prec, &fp16, &stats)?;
    let before = qs.clone();
    let tcfg = p.qat_cfg(p.cfg.qat_steps);
    p.qat(prec, &mut qs, &fp16, DataMix::Instruct { style: SftStyle::TuluSynth, dclm_ratio: 0.25 }, tcfg, &mut log, None)?;
    let silq_split = crate::analysis::analyze_rotation(&before, &qs, &mc)?;

    let mut t = Table::new(&["layer", "spin rot", "spin non-rot", "silq rot", "silq non-rot"]);
    for wn in ["wq", "wk", "wv", "wo", "wg", "wu", "wd"] {
        let s = &spin_split[wn];
        let q = &silq_split[wn];
        t.row(&[
            wn.into(),
            format!("{:.3}", s.rotational),
            format!("{:.3}", s.non_rotational),
            format!("{:.3}", q.rotational),
            format!("{:.3}", q.non_rotational),
        ]);
    }
    let body = format!(
        "{}\nrotation-explained fraction: spinquant {:.1}%  silq {:.1}%\n",
        t.render(),
        100.0 * crate::analysis::rotation_fraction(&spin_split),
        100.0 * crate::analysis::rotation_fraction(&silq_split),
    );
    emit("Figure 3 — Procrustes rotation analysis", &body)
}
