//! Experiment coordinator: the end-to-end SiLQ pipeline plus one runner per
//! paper table/figure (see DESIGN.md §4 for the index).

pub mod experiments;
pub mod pipeline;

pub use experiments::run_experiment;
pub use pipeline::{BackendKind, Pipeline, PipelineCfg};
