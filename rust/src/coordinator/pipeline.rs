//! The full SiLQ pipeline over one model size: pretrain -> SFT -> calibrate
//! -> QAT (or a PTQ baseline) -> evaluate. Checkpoints are cached under
//! `runs/` so experiment runners share the expensive fp16 phases.

use anyhow::Result;

use crate::config::TrainCfg;
use crate::data::{DataMix, SftStyle, Vocab, World};
use crate::evalharness::{EvalReport, Evaluator};
use crate::forward::{ArtifactForward, ForwardBackend, HostForward};
use crate::hostmodel::{CacheStore, HostCfg};
use crate::metrics::RunLog;
use crate::model::ParamStore;
use crate::policy::{CalibMethod, QuantPolicy};
use crate::ptq;
use crate::runtime::Engine;
use crate::train::calibrate::{calibrate_act_steps, calibrate_weight_steps, collect_stats, CalibStats};
use crate::train::{init_model, quantize_store, Trainer, TrainStats};

/// Which [`ForwardBackend`] the pipeline's logits-consuming workloads
/// (eval scoring, generation, LLM-QAT self-generation) run behind.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendKind {
    /// The compiled `*_fwd` artifact on PJRT (full-sequence recompute).
    #[default]
    Artifact,
    /// The artifact-free host transformer with incremental KV decode.
    Host,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "artifact" => Ok(BackendKind::Artifact),
            "host" => Ok(BackendKind::Host),
            other => anyhow::bail!("unknown backend {other} (artifact|host)"),
        }
    }
}

/// Scaled-down defaults for the tiny experiment grid.
#[derive(Clone, Debug)]
pub struct PipelineCfg {
    pub model: String,
    pub pretrain_steps: usize,
    pub sft_steps: usize,
    pub qat_steps: usize,
    pub eval_items: usize,
    pub seed: u64,
    /// world seed shared by data and eval
    pub world_seed: u64,
    /// forward backend for eval / generation workloads
    pub backend: BackendKind,
}

impl Default for PipelineCfg {
    fn default() -> Self {
        PipelineCfg {
            model: "tiny".into(),
            pretrain_steps: 500,
            sft_steps: 250,
            qat_steps: 250,
            eval_items: 40,
            seed: 0,
            world_seed: 7,
            backend: BackendKind::Artifact,
        }
    }
}

pub struct Pipeline<'e> {
    pub engine: &'e Engine,
    pub cfg: PipelineCfg,
    pub world: World,
}

impl<'e> Pipeline<'e> {
    pub fn new(engine: &'e Engine, cfg: PipelineCfg) -> Result<Self> {
        let mc = engine.manifest.model(&cfg.model)?;
        let world = World::generate(Vocab::new(mc.vocab), cfg.world_seed);
        Ok(Pipeline { engine, cfg, world })
    }

    fn art(&self, prec: &str, mode: &str) -> String {
        format!("{}_{prec}_{mode}", self.cfg.model)
    }

    fn ckpt(&self, tag: &str) -> std::path::PathBuf {
        std::path::PathBuf::from(format!(
            "runs/{}_s{}_{}.ckpt",
            self.cfg.model, self.cfg.seed, tag
        ))
    }

    /// QAT hyper-parameters: like train_cfg but with the much smaller LR
    /// QAT needs relative to pretraining (paper: 5e-6 QAT vs ~1e-4 scale
    /// pretrain LRs; same ~20x ratio here).
    pub fn qat_cfg(&self, steps: usize) -> TrainCfg {
        let mut t = self.train_cfg(steps);
        t.base_lr = 3e-4;
        t
    }

    fn train_cfg(&self, steps: usize) -> TrainCfg {
        let mut t = TrainCfg::default();
        t.steps = steps;
        t.ref_steps = 500;
        t.seed = self.cfg.seed;
        t
    }

    /// fp16 base model: pretrained on the corpus (cached).
    pub fn base_model(&self, log: &mut RunLog) -> Result<ParamStore> {
        let fwd = self.art("fp16", "fwd");
        let spec = self.engine.module(&fwd)?.spec.clone();
        let path = self.ckpt("base");
        if path.exists() {
            log.note(&format!("[pipeline] cached base model {path:?}"));
            return ParamStore::load(&spec, &path);
        }
        log.note(&format!("[pipeline] pretraining base ({} steps)...", self.cfg.pretrain_steps));
        let mut params = init_model(self.engine, &fwd, self.cfg.seed ^ 0x1717)?;
        let mut tcfg = self.train_cfg(self.cfg.pretrain_steps);
        tcfg.kd_ratio = 0.0;
        let trainer = Trainer::new(self.engine, &self.art("fp16", "train"), None, tcfg)?;
        let stats = trainer.run(&mut params, &self.world, DataMix::Corpus, log, None)?;
        log.note(&format!(
            "[pipeline] pretrain done: loss {:.4}, {:.2} steps/s",
            stats.final_loss,
            stats.steps_per_sec()
        ));
        params.save(&path)?;
        Ok(params)
    }

    /// fp16 instruct model: base + SFT on the given mixture (cached by tag).
    pub fn instruct_model(
        &self,
        style: SftStyle,
        tag: &str,
        log: &mut RunLog,
    ) -> Result<ParamStore> {
        let fwd = self.art("fp16", "fwd");
        let spec = self.engine.module(&fwd)?.spec.clone();
        let path = self.ckpt(tag);
        if path.exists() {
            log.note(&format!("[pipeline] cached instruct model {path:?}"));
            return ParamStore::load(&spec, &path);
        }
        let mut params = self.base_model(log)?;
        log.note(&format!("[pipeline] SFT {tag} ({} steps)...", self.cfg.sft_steps));
        let mut tcfg = self.train_cfg(self.cfg.sft_steps);
        tcfg.kd_ratio = 0.0;
        let trainer = Trainer::new(self.engine, &self.art("fp16", "train"), None, tcfg)?;
        let stats = trainer.run(
            &mut params,
            &self.world,
            DataMix::Instruct { style, dclm_ratio: 0.25 },
            log,
            None,
        )?;
        log.note(&format!("[pipeline] SFT done: loss {:.4}", stats.final_loss));
        params.save(&path)?;
        Ok(params)
    }

    /// Calibration statistics from the fp16 model (cached per fp16 params
    /// instance is overkill; recomputed each call, it is cheap).
    pub fn calib_stats(&self, fp16: &ParamStore, batches: usize) -> Result<CalibStats> {
        collect_stats(
            self.engine,
            &self.art("fp16", "calib"),
            fp16,
            &self.world,
            batches,
            self.cfg.seed ^ 0xCAFE,
        )
    }

    /// Build + calibrate a quantized store from fp16 weights (SiLQ init)
    /// with the manifest precision's default calibrations.
    pub fn calibrated_quant_store(
        &self,
        prec: &str,
        fp16: &ParamStore,
        stats: &CalibStats,
    ) -> Result<ParamStore> {
        let policy = self.engine.manifest.prec(prec)?.policy()?;
        self.calibrated_store_for_policy(prec, fp16, stats, &policy)
    }

    /// Like [`Pipeline::calibrated_quant_store`] but with explicit
    /// calibration overrides (the Table-4 ablation knobs).
    pub fn calibrated_quant_store_with(
        &self,
        prec: &str,
        fp16: &ParamStore,
        stats: &CalibStats,
        act_calib: CalibMethod,
        wgt_calib: CalibMethod,
    ) -> Result<ParamStore> {
        let policy = self
            .engine
            .manifest
            .prec(prec)?
            .policy()?
            .with_act_calib(act_calib)
            .with_weight_calib(wgt_calib);
        policy.validate()?;
        self.calibrated_store_for_policy(prec, fp16, stats, &policy)
    }

    fn calibrated_store_for_policy(
        &self,
        prec: &str,
        fp16: &ParamStore,
        stats: &CalibStats,
        policy: &QuantPolicy,
    ) -> Result<ParamStore> {
        let mut qs = quantize_store(self.engine, &self.art(prec, "fwd"), fp16)?;
        calibrate_act_steps(&mut qs, policy, stats)?;
        calibrate_weight_steps(&mut qs, policy)?;
        Ok(qs)
    }

    /// SiLQ QAT: KD from the fp16 teacher, LSQ step refinement, end-to-end.
    /// Returns train stats; `qs` is updated in place.
    #[allow(clippy::too_many_arguments)]
    pub fn qat(
        &self,
        prec: &str,
        qs: &mut ParamStore,
        teacher: &ParamStore,
        mix: DataMix,
        tcfg: TrainCfg,
        log: &mut RunLog,
        eval_hook: Option<&mut dyn FnMut(usize, &ParamStore)>,
    ) -> Result<TrainStats> {
        let trainer = Trainer::new(
            self.engine,
            &self.art(prec, "train"),
            Some((&self.art("fp16", "fwd"), teacher.clone())),
            tcfg,
        )?;
        trainer.run(qs, &self.world, mix, log, eval_hook)
    }

    /// Bind `params` to the forward backend selected by
    /// `PipelineCfg::backend` — the compiled artifact, or the artifact-free
    /// host transformer (quantized policies keep their KV cache in the
    /// deployment INT8 representation, via `CacheStore::for_policy`).
    pub fn forward(&self, prec: &str, params: &ParamStore) -> Result<Box<dyn ForwardBackend>> {
        let policy = self.engine.manifest.prec(prec)?.policy()?;
        // the host forward has no online-rotation implementation; rot
        // precisions (Table 4 ablation) stay on the compiled graph rather
        // than aborting a half-finished experiment at eval time
        if self.cfg.backend == BackendKind::Artifact || policy.online_rot {
            return Ok(Box::new(ArtifactForward::new(
                self.engine,
                &self.art(prec, "fwd"),
                params,
            )?));
        }
        let mc = self.engine.manifest.model(&self.cfg.model)?.clone();
        let hc = HostCfg::from_policy(&mc, &policy)?;
        let store = CacheStore::for_policy(&policy);
        Ok(Box::new(HostForward::new(hc, mc.fwd_batch, params, store)?))
    }

    /// Evaluate a param store under a precision config.
    pub fn eval(
        &self,
        prec: &str,
        params: &ParamStore,
        chat: bool,
    ) -> Result<EvalReport> {
        let mut ev = Evaluator::new(self.forward(prec, params)?, chat, self.cfg.eval_items);
        ev.eval_all(&self.world, self.cfg.world_seed ^ crate::evalharness::EVAL_SEED_SALT)
    }

    /// PTQ baselines sharing the same artifacts.
    pub fn ptq_baseline(
        &self,
        method: &str,
        prec: &str,
        fp16: &ParamStore,
        stats: &CalibStats,
    ) -> Result<ParamStore> {
        let policy = self.engine.manifest.prec(prec)?.policy()?;
        let mc = self.engine.manifest.model(&self.cfg.model)?.clone();
        let mut qs = quantize_store(self.engine, &self.art(prec, "fwd"), fp16)?;
        calibrate_act_steps(&mut qs, &policy, stats)?;
        match method {
            "rtn" => ptq::rtn(&mut qs, &policy)?,
            "smoothquant" => ptq::smoothquant(&mut qs, &mc, &policy, stats, 0.4)?,
            "gptq" => ptq::gptq(&mut qs, &mc, &policy, stats)?,
            "spinquant" => ptq::spinquant(&mut qs, &mc, &policy, stats, 3, self.cfg.seed)?,
            other => anyhow::bail!("unknown ptq method {other}"),
        }
        // weight changes (smoothquant/rotation) shift activation ranges:
        // re-calibrating statics on the fp16 stats is the faithful analog of
        // each method's own calibration pass.
        Ok(qs)
    }
}
