//! `forward` — the one abstraction every logits-consuming workload runs
//! behind.
//!
//! [`ForwardBackend`] packages the two forward shapes the system needs:
//! batched full-sequence logits (continuation log-likelihood scoring) and
//! an incremental decode session (greedy generation, LLM-QAT hybrid
//! sampling). Two implementations:
//!
//! * [`ArtifactForward`] — the compiled `*_fwd` artifact on PJRT. Batched
//!   calls are one graph execution; incremental steps recompute the full
//!   sequence each time (the graph is stateless), which is the O(n²)
//!   behavior the host backend exists to beat.
//! * [`HostForward`] — the [`HostModel`] host transformer: batched calls
//!   run `forward_seq` per row; incremental steps advance every active
//!   [`KvPool`] session by one token through **one cross-lane batched
//!   forward** (one fused `i8` GEMM per weight matrix across all rows —
//!   O(n) total per row, and the weights stream once per GEMM block per
//!   step instead of once per row). Needs no artifacts at all.
//!
//! [`decode_with`]/[`decode_greedy`] drive an incremental session with
//! early exit: the loop stops as soon as every row has its budget or hit
//! the context window, instead of always burning `max_new` steps.

use anyhow::{ensure, Context, Result};
use std::sync::Arc;

use crate::evalharness::decode::{argmax, argmax_rows, pack_rows};
use crate::hostmodel::{
    check_tokens, AdmitErr, BatchLane, CacheStore, HostCfg, HostModel, KvLayout, KvPool, PageLedger,
};
use crate::kernels::{BatchScratch, DecodeScratch};
use crate::model::ParamStore;
use crate::obs;
use crate::runtime::{build_inputs, literal_i32, to_f32_vec, Engine, Module};

/// Batched logits + incremental decode over one bound model instance
/// (parameters are fixed at construction).
pub trait ForwardBackend {
    /// Rows one batched call (or decode session) serves.
    fn batch(&self) -> usize;
    /// Model context window.
    fn seq_len(&self) -> usize;
    fn vocab(&self) -> usize;

    /// Full-sequence logits for up to [`ForwardBackend::batch`] rows,
    /// packed `[batch, seq_len, vocab]` row-major — the compiled fwd
    /// artifact's layout. Values at positions past a row's length (or for
    /// missing rows) are unspecified; callers index only real positions.
    fn batch_logits(&mut self, rows: &[&[i32]]) -> Result<Vec<f32>>;

    /// Open an incremental decode session over `rows` (prefill: every
    /// prompt token but the last is folded into the backend's cache).
    /// Rows must be non-empty and shorter than the context window.
    fn begin_decode(&mut self, rows: &[&[i32]]) -> Result<()>;

    /// Advance the session one position: `rows[r]` is row r's full token
    /// prefix — its last token not yet folded into the cache — or `None`
    /// for a finished row. Returns next-token logits per active row.
    fn step_logits(&mut self, rows: &[Option<&[i32]>]) -> Result<Vec<Option<Vec<f32>>>>;

    /// Advance the session one position for every active row and return
    /// the greedy next token per row — semantically [`step_logits`]
    /// followed by argmax, without materializing per-row logits vectors.
    /// The serve hot path; the host backend overrides this to run one
    /// **cross-lane batched** forward (one fused GEMM per weight matrix
    /// across all live rows) instead of B sequential steps.
    ///
    /// [`step_logits`]: ForwardBackend::step_logits
    fn step_greedy(&mut self, rows: &[Option<&[i32]>]) -> Result<Vec<Option<i32>>> {
        Ok(self
            .step_logits(rows)?
            .into_iter()
            .map(|l| l.map(|lg| argmax(&lg) as i32))
            .collect())
    }

    /// Close the decode session, releasing any cache resources.
    fn end_decode(&mut self);
}

impl<'a> ForwardBackend for Box<dyn ForwardBackend + 'a> {
    fn batch(&self) -> usize {
        (**self).batch()
    }
    fn seq_len(&self) -> usize {
        (**self).seq_len()
    }
    fn vocab(&self) -> usize {
        (**self).vocab()
    }
    fn batch_logits(&mut self, rows: &[&[i32]]) -> Result<Vec<f32>> {
        (**self).batch_logits(rows)
    }
    fn begin_decode(&mut self, rows: &[&[i32]]) -> Result<()> {
        (**self).begin_decode(rows)
    }
    fn step_logits(&mut self, rows: &[Option<&[i32]>]) -> Result<Vec<Option<Vec<f32>>>> {
        (**self).step_logits(rows)
    }
    fn step_greedy(&mut self, rows: &[Option<&[i32]>]) -> Result<Vec<Option<i32>>> {
        (**self).step_greedy(rows)
    }
    fn end_decode(&mut self) {
        (**self).end_decode()
    }
}

// ---------------------------------------------------------------------------
// decode driver
// ---------------------------------------------------------------------------

/// Incremental decode driver: prefill once, then one step per new token,
/// `pick(row, step, logits) -> token` choosing each next token. Rows that
/// are empty or already fill the context window generate nothing. Returns
/// the generated tokens per row (prompt excluded).
///
/// Early exit: the loop ends as soon as every row has `max_new` tokens or
/// hit `seq_len`, so a chunk of short rows never pays for its budget.
pub fn decode_with<B, F>(
    backend: &mut B,
    prompts: &[&[i32]],
    max_new: usize,
    mut pick: F,
) -> Result<Vec<Vec<i32>>>
where
    B: ForwardBackend + ?Sized,
    F: FnMut(usize, usize, &[f32]) -> i32,
{
    ensure!(prompts.len() <= backend.batch(), "more rows than the backend batch");
    let s = backend.seq_len();
    let mut out: Vec<Vec<i32>> = vec![vec![]; prompts.len()];
    // rows that can decode at all; index mapping back to the caller's order
    let viable: Vec<usize> = (0..prompts.len())
        .filter(|&r| !prompts[r].is_empty() && prompts[r].len() < s)
        .collect();
    if viable.is_empty() || max_new == 0 {
        return Ok(out);
    }
    let sub: Vec<&[i32]> = viable.iter().map(|&r| prompts[r]).collect();
    backend.begin_decode(&sub)?;

    let mut rows: Vec<Vec<i32>> = sub.iter().map(|p| p.to_vec()).collect();
    let mut done = vec![false; rows.len()];
    let stepped = (|| -> Result<()> {
        for step in 0..max_new {
            if done.iter().all(|&d| d) {
                break; // every row finished early
            }
            let views: Vec<Option<&[i32]>> = rows
                .iter()
                .zip(&done)
                .map(|(r, &d)| if d { None } else { Some(r.as_slice()) })
                .collect();
            let logits = backend.step_logits(&views)?;
            ensure!(logits.len() == rows.len(), "backend returned a short step");
            for (r, lg) in logits.into_iter().enumerate() {
                let Some(lg) = lg else { continue };
                let tok = pick(viable[r], step, &lg);
                rows[r].push(tok);
                out[viable[r]].push(tok);
                if out[viable[r]].len() >= max_new || rows[r].len() >= s {
                    done[r] = true;
                }
            }
        }
        Ok(())
    })();
    backend.end_decode();
    stepped?;
    Ok(out)
}

/// Greedy (argmax) decode through [`decode_with`] — the eval-harness and
/// serve sampling rule.
pub fn decode_greedy<B: ForwardBackend + ?Sized>(
    backend: &mut B,
    prompts: &[&[i32]],
    max_new: usize,
) -> Result<Vec<Vec<i32>>> {
    decode_with(backend, prompts, max_new, |_, _, lg| argmax(lg) as i32)
}

// ---------------------------------------------------------------------------
// ArtifactForward — the compiled PJRT graph
// ---------------------------------------------------------------------------

/// Forward through a compiled `*_fwd` artifact. Parameter literals are
/// built once; only the token literal changes per call. Incremental steps
/// recompute the full sequence (the graph holds no external cache).
pub struct ArtifactForward {
    module: Arc<Module>,
    inputs: Vec<xla::Literal>,
    tok_idx: usize,
    batch: usize,
    seq: usize,
    vocab: usize,
}

impl ArtifactForward {
    pub fn new(engine: &Engine, artifact: &str, params: &ParamStore) -> Result<ArtifactForward> {
        let module = engine.module(artifact)?;
        let spec = module.spec.clone();
        let mc = engine.manifest.model(&spec.model)?;
        let (batch, seq, vocab) = (mc.fwd_batch, mc.seq_len, mc.vocab);
        let tok_idx = spec.input_index("tokens")?;
        let zeros = vec![0i32; batch * seq];
        let inputs = build_inputs(
            &spec,
            params,
            &[("tokens", literal_i32(&spec.inputs[tok_idx].dims, &zeros)?)],
        )?;
        Ok(ArtifactForward { module, inputs, tok_idx, batch, seq, vocab })
    }

    /// One graph execution over packed rows; full `[batch, seq, vocab]`
    /// logits out.
    fn run_packed(&mut self, rows: &[&[i32]]) -> Result<Vec<f32>> {
        let tokens = pack_rows(rows, self.batch, self.seq);
        let tok_spec = &self.module.spec.inputs[self.tok_idx];
        self.inputs[self.tok_idx] = literal_i32(&tok_spec.dims, &tokens)?;
        let out = self.module.run(&self.inputs)?;
        to_f32_vec(&out[0])
    }
}

impl ForwardBackend for ArtifactForward {
    fn batch(&self) -> usize {
        self.batch
    }

    fn seq_len(&self) -> usize {
        self.seq
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn batch_logits(&mut self, rows: &[&[i32]]) -> Result<Vec<f32>> {
        ensure!(rows.len() <= self.batch, "more rows than the artifact batch");
        self.run_packed(rows)
    }

    fn begin_decode(&mut self, rows: &[&[i32]]) -> Result<()> {
        // stateless graph: the prefix is recomputed every step
        ensure!(rows.len() <= self.batch, "more rows than the artifact batch");
        for row in rows {
            ensure!(!row.is_empty() && row.len() < self.seq, "bad decode row length");
            check_tokens(row, self.vocab)?;
        }
        Ok(())
    }

    fn step_logits(&mut self, rows: &[Option<&[i32]>]) -> Result<Vec<Option<Vec<f32>>>> {
        ensure!(rows.len() <= self.batch, "more rows than the artifact batch");
        let packed: Vec<&[i32]> = rows.iter().map(|r| r.unwrap_or(&[])).collect();
        let logits = self.run_packed(&packed)?;
        let mut out = Vec::with_capacity(rows.len());
        for (r, row) in rows.iter().enumerate() {
            out.push(match row {
                Some(toks) if !toks.is_empty() && toks.len() < self.seq => {
                    let base = (r * self.seq + toks.len() - 1) * self.vocab;
                    Some(logits[base..base + self.vocab].to_vec())
                }
                _ => None,
            });
        }
        Ok(out)
    }

    fn end_decode(&mut self) {}
}

// ---------------------------------------------------------------------------
// HostForward — the host transformer over a KvPool
// ---------------------------------------------------------------------------

/// Forward through the [`HostModel`] host transformer: batched calls run
/// the full-sequence forward per row; incremental sessions keep the K/V
/// cache resident in a quantized [`KvPool`] and advance one token per
/// step. Runs with no artifacts built.
///
/// Decode steps through the trait surface (`step_logits` / `step_greedy`)
/// gather every active row into **one cross-lane batched forward**
/// ([`HostModel::forward_tokens_batch`]): the rows' activation vectors
/// stack into one fused blocked GEMM per weight matrix, so at batch width
/// B each matrix streams once per `GEMM_BLOCK` rows per step instead of B
/// times — bit-identical per row to the per-lane
/// [`HostForward::step_row_greedy`] path (exact `i32` accumulation), which
/// remains the sequential reference. All intermediates live in persistent scratches
/// ([`DecodeScratch`] for prefill/per-row steps, [`BatchScratch`] for the
/// batched step), so the steady-state decode loop performs no heap
/// allocation inside the forward.
///
/// Execution width and micro-kernel choice live one level down: the GEMMs
/// shard by output channel (and the batched step's integer attention by
/// lane) across the persistent [`crate::kernels::pool`] worker pool, and
/// the inner `i8` dot products run through the runtime-dispatched
/// [`crate::kernels::simd`] kernel. Both are bit-exact — every identity in
/// this module holds at any `--threads` / `--kernel` setting.
pub struct HostForward {
    model: HostModel,
    pool: KvPool,
    n_rows: usize,
    slot_of_row: Vec<Option<usize>>,
    /// tokens already folded into the cache, per row
    processed: Vec<usize>,
    /// every per-row decode intermediate, reused across steps and rows
    scratch: DecodeScratch,
    /// every batched-step intermediate, sized once for `n_rows` lanes
    batch_scratch: BatchScratch,
    /// gathered lanes of the current batched step (persistent so the
    /// steady-state gather allocates nothing)
    lane_buf: Vec<BatchLane>,
    /// caller row index of each gathered lane
    lane_rows: Vec<usize>,
}

impl HostForward {
    pub fn new(
        cfg: HostCfg,
        n_rows: usize,
        params: &ParamStore,
        store: CacheStore,
    ) -> Result<HostForward> {
        Self::from_model(HostModel::new(cfg, params)?, n_rows, store)
    }

    /// [`HostForward::new`] with an explicit KV cache layout.
    pub fn new_with_layout(
        cfg: HostCfg,
        n_rows: usize,
        params: &ParamStore,
        store: CacheStore,
        layout: KvLayout,
    ) -> Result<HostForward> {
        Self::from_model_with_layout(HostModel::new(cfg, params)?, n_rows, store, layout)
    }

    /// Wrap an already-built model (e.g. a [`HostModel::new_reference`]
    /// build for the f32-baseline benches) in a decode frontend.
    pub fn from_model(model: HostModel, n_rows: usize, store: CacheStore) -> Result<HostForward> {
        Self::from_model_with_layout(model, n_rows, store, KvLayout::Slab)
    }

    /// [`HostForward::from_model`] with an explicit cache layout — the
    /// paged pool is selected here (`--kv paged` upstream) and everything
    /// downstream is layout-oblivious.
    pub fn from_model_with_layout(
        model: HostModel,
        n_rows: usize,
        store: CacheStore,
        layout: KvLayout,
    ) -> Result<HostForward> {
        ensure!(n_rows >= 1, "need at least one row");
        let pool = model.make_pool_with(n_rows, store, layout)?;
        let scratch = DecodeScratch::for_cfg(&model.cfg);
        let batch_scratch = BatchScratch::for_cfg(&model.cfg, n_rows);
        Ok(HostForward {
            model,
            pool,
            n_rows,
            slot_of_row: vec![None; n_rows],
            processed: vec![0; n_rows],
            scratch,
            batch_scratch,
            lane_buf: Vec::with_capacity(n_rows),
            lane_rows: Vec::with_capacity(n_rows),
        })
    }

    pub fn model(&self) -> &HostModel {
        &self.model
    }

    /// Resident KV bytes — bytes of pages actually bound to live
    /// sessions, in deployment format. Under the slab layout a session
    /// binds its pages up front, so this still climbs per-slot; under the
    /// paged layout it tracks true occupancy (shared prefix pages are
    /// counted once).
    pub fn kv_bytes(&self) -> usize {
        self.pool.resident_bytes()
    }

    /// Physical pages currently bound to live sessions.
    pub fn kv_pages(&self) -> usize {
        self.pool.pages_in_use()
    }

    /// Lifetime page-flow counters of the underlying pool.
    pub fn kv_ledger(&self) -> PageLedger {
        self.pool.ledger()
    }

    /// Bind row `row` to a cache slot and prefill everything but the last
    /// prompt token; the first step folds that one in and emits the first
    /// generated token.
    pub fn admit_row(&mut self, row: usize, prompt: &[i32]) -> Result<()> {
        ensure!(row < self.n_rows, "row {row} out of range");
        ensure!(self.slot_of_row[row].is_none(), "row {row} already occupied");
        ensure!(
            !prompt.is_empty() && prompt.len() < self.model.cfg.seq_len,
            "bad prompt length"
        );
        // validate the WHOLE prompt here — a bad final token must be a
        // per-request rejection, not an error out of the first step
        check_tokens(prompt, self.model.cfg.vocab)?;
        let _span = obs::span("prefill", "serve", row as u32 + 1, prompt.len() as u64);
        // keep the typed cause in the chain: serve admission downcasts to
        // `AdmitErr` to distinguish pages-exhausted from slot-exhausted
        let (slot, shared_pos) = self
            .pool
            .alloc_with_prompt(prompt)
            .map_err(|e: AdmitErr| anyhow::Error::new(e).context("KV pool exhausted"))?;
        self.slot_of_row[row] = Some(slot);
        // positions < shared_pos are already resident in sealed pages this
        // session attached to — prefill only the unshared tail
        for (pos, &tok) in prompt[..prompt.len() - 1].iter().enumerate().skip(shared_pos) {
            let stepped = self
                .model
                .forward_token_into(&mut self.pool, slot, tok, pos, false, &mut self.scratch);
            if let Err(e) = stepped {
                self.evict_row(row);
                return Err(e);
            }
        }
        self.processed[row] = prompt.len() - 1;
        Ok(())
    }

    /// Release row `row`'s cache slot (idempotent).
    pub fn evict_row(&mut self, row: usize) {
        if let Some(slot) = self.slot_of_row[row].take() {
            self.pool.free(slot);
        }
        self.processed[row] = 0;
    }

    /// Advance row `row` by one position: fold `toks`'s last token into the
    /// cache and return the next-token logits (borrowed from the scratch —
    /// valid until the next step).
    pub fn step_row_borrowed(&mut self, row: usize, toks: &[i32]) -> Result<&[f32]> {
        let slot = self.slot_of_row[row].context("row has no cache slot")?;
        let pos = self.processed[row];
        ensure!(
            pos + 1 == toks.len(),
            "row {row}: cache holds {pos} tokens, row has {}",
            toks.len()
        );
        let logits = self
            .model
            .forward_token_into(&mut self.pool, slot, toks[pos], pos, true, &mut self.scratch)?
            .expect("logits requested");
        self.processed[row] = pos + 1;
        Ok(logits)
    }

    /// Advance row `row` one position and pick the greedy token — the
    /// per-lane sequential path: no logits vector is materialized, the
    /// argmax reads the scratch directly. Since the cross-lane batching
    /// PR the serve hot loop runs [`ForwardBackend::step_greedy`] (one
    /// fused forward across all rows) instead; this remains the
    /// bit-identical sequential reference it is measured against.
    pub fn step_row_greedy(&mut self, row: usize, toks: &[i32]) -> Result<i32> {
        Ok(argmax(self.step_row_borrowed(row, toks)?) as i32)
    }

    /// Whether every cache slot is back in the pool — the shutdown
    /// invariant the serve soak test pins.
    pub fn all_slots_free(&self) -> bool {
        self.pool.all_slots_free()
    }

    /// [`HostForward::all_slots_free`] generalized to the paged pool: no
    /// slot bound, no page resident, no commitment outstanding, every
    /// physical page accounted for on the free list or the LRU.
    pub fn all_pages_free(&self) -> bool {
        self.pool.all_pages_free()
    }

    /// Gather every active row into one [`HostModel::forward_tokens_batch`]
    /// call. After return, gathered lane `i` (caller row `lane_rows[i]`)
    /// has its logits at `batch_scratch.logits[i*vocab..]`. Rows that are
    /// `None`, empty, or already fill the context window are skipped (they
    /// stay `None` in the callers' outputs, matching `step_logits`'
    /// historical semantics); mismatched prefixes are hard errors.
    fn step_rows_batched(&mut self, rows: &[Option<&[i32]>]) -> Result<()> {
        ensure!(rows.len() <= self.n_rows, "more rows than the backend batch");
        let seq = self.model.cfg.seq_len;
        self.lane_buf.clear();
        self.lane_rows.clear();
        for (r, row) in rows.iter().enumerate() {
            let Some(toks) = row else { continue };
            if toks.is_empty() || toks.len() >= seq {
                continue;
            }
            let slot = self.slot_of_row[r].context("row has no cache slot")?;
            let pos = self.processed[r];
            ensure!(
                pos + 1 == toks.len(),
                "row {r}: cache holds {pos} tokens, row has {}",
                toks.len()
            );
            self.lane_buf.push(BatchLane { slot, tok: toks[pos], pos });
            self.lane_rows.push(r);
        }
        if self.lane_buf.is_empty() {
            return Ok(());
        }
        self.model.forward_tokens_batch(
            &mut self.pool,
            &self.lane_buf,
            true,
            &mut self.batch_scratch,
        )?;
        for &r in &self.lane_rows {
            self.processed[r] += 1;
        }
        Ok(())
    }
}

impl ForwardBackend for HostForward {
    fn batch(&self) -> usize {
        self.n_rows
    }

    fn seq_len(&self) -> usize {
        self.model.cfg.seq_len
    }

    fn vocab(&self) -> usize {
        self.model.cfg.vocab
    }

    fn batch_logits(&mut self, rows: &[&[i32]]) -> Result<Vec<f32>> {
        ensure!(rows.len() <= self.n_rows, "more rows than the backend batch");
        let (s, v) = (self.model.cfg.seq_len, self.model.cfg.vocab);
        let mut logits = vec![0f32; self.n_rows * s * v];
        for (r, row) in rows.iter().enumerate() {
            if row.is_empty() {
                continue;
            }
            let lg = self.model.forward_seq(row)?;
            logits[r * s * v..r * s * v + lg.len()].copy_from_slice(&lg);
        }
        Ok(logits)
    }

    fn begin_decode(&mut self, rows: &[&[i32]]) -> Result<()> {
        ensure!(rows.len() <= self.n_rows, "more rows than the backend batch");
        for (r, row) in rows.iter().enumerate() {
            if let Err(e) = self.admit_row(r, row) {
                // leave no slots bound on a failed session open
                self.end_decode();
                return Err(e);
            }
        }
        Ok(())
    }

    fn step_logits(&mut self, rows: &[Option<&[i32]>]) -> Result<Vec<Option<Vec<f32>>>> {
        self.step_rows_batched(rows)?;
        let v = self.model.cfg.vocab;
        let mut out = vec![None; rows.len()];
        for (i, &r) in self.lane_rows.iter().enumerate() {
            out[r] = Some(self.batch_scratch.logits[i * v..(i + 1) * v].to_vec());
        }
        Ok(out)
    }

    fn step_greedy(&mut self, rows: &[Option<&[i32]>]) -> Result<Vec<Option<i32>>> {
        // one fused forward across every live row; the greedy picks read
        // the stacked scratch logits directly — no per-row vectors
        self.step_rows_batched(rows)?;
        let v = self.model.cfg.vocab;
        let b = self.lane_rows.len();
        let picks = argmax_rows(&self.batch_scratch.logits[..b * v], v);
        let mut out = vec![None; rows.len()];
        for (&r, &p) in self.lane_rows.iter().zip(&picks) {
            out[r] = Some(p as i32);
        }
        Ok(out)
    }

    fn end_decode(&mut self) {
        for r in 0..self.n_rows {
            self.evict_row(r);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostmodel::{host_test_params, tiny_host_cfg};

    fn host_fwd(rows: usize, seed: u64) -> HostForward {
        let cfg = tiny_host_cfg(true, true);
        let params = host_test_params(&cfg, seed);
        HostForward::new(cfg, rows, &params, CacheStore::Int8).unwrap()
    }

    #[test]
    fn decode_greedy_matches_manual_loop() {
        let cfg = tiny_host_cfg(true, true);
        let params = host_test_params(&cfg, 3);
        let mut fwd = HostForward::new(cfg.clone(), 2, &params, CacheStore::F32).unwrap();
        let prompt = [1i32, 3, 22, 10];
        let gen = decode_greedy(&mut fwd, &[&prompt], 4).unwrap();
        assert_eq!(gen.len(), 1);
        assert_eq!(gen[0].len(), 4);

        // reference: full-sequence recompute per token
        let model = HostModel::new(cfg.clone(), &params).unwrap();
        let mut row = prompt.to_vec();
        for _ in 0..4 {
            let lg = model.forward_seq(&row).unwrap();
            let last = &lg[(row.len() - 1) * cfg.vocab..row.len() * cfg.vocab];
            row.push(argmax(last) as i32);
        }
        assert_eq!(&row[prompt.len()..], &gen[0][..]);
    }

    #[test]
    fn decode_early_exits_at_the_window() {
        let mut fwd = host_fwd(1, 7);
        let s = fwd.seq_len();
        let prompt: Vec<i32> = (0..s as i32 - 2).map(|i| 1 + i % 200).collect();
        // budget far beyond the window: only 2 tokens fit
        let gen = decode_greedy(&mut fwd, &[&prompt], 100).unwrap();
        assert_eq!(gen[0].len(), 2);
        // the session must be fully released — a second decode succeeds
        let gen2 = decode_greedy(&mut fwd, &[&[1i32, 2][..]], 3).unwrap();
        assert_eq!(gen2[0].len(), 3);
    }

    #[test]
    fn decode_skips_unviable_rows() {
        let mut fwd = host_fwd(3, 9);
        let s = fwd.seq_len();
        let full: Vec<i32> = (0..s as i32).map(|i| 1 + i % 200).collect();
        let prompts: Vec<&[i32]> = vec![&[], &[1, 3, 4], &full[..]];
        let gen = decode_greedy(&mut fwd, &prompts, 2).unwrap();
        assert!(gen[0].is_empty());
        assert_eq!(gen[1].len(), 2);
        assert!(gen[2].is_empty());
    }

    #[test]
    fn decode_with_passes_row_and_step() {
        let mut fwd = host_fwd(2, 11);
        let mut seen: Vec<(usize, usize)> = vec![];
        let prompts: Vec<&[i32]> = vec![&[1, 3], &[1, 4]];
        let gen = decode_with(&mut fwd, &prompts, 2, |row, step, lg| {
            seen.push((row, step));
            argmax(lg) as i32
        })
        .unwrap();
        assert_eq!(gen.iter().map(|g| g.len()).sum::<usize>(), 4);
        assert_eq!(seen, vec![(0, 0), (1, 0), (0, 1), (1, 1)]);
    }

    #[test]
    fn begin_decode_rejects_bad_rows_cleanly() {
        let mut fwd = host_fwd(2, 13);
        let prompts: Vec<&[i32]> = vec![&[1, 3], &[9999]];
        assert!(fwd.begin_decode(&prompts).is_err());
        // nothing left bound: a fresh session over both rows works
        let ok: Vec<&[i32]> = vec![&[1, 3], &[1, 4]];
        assert!(fwd.begin_decode(&ok).is_ok());
        fwd.end_decode();
    }

    #[test]
    fn batch_logits_layout_matches_artifact_shape() {
        let mut fwd = host_fwd(2, 17);
        let (s, v) = (fwd.seq_len(), fwd.vocab());
        let rows: Vec<&[i32]> = vec![&[1, 3, 4], &[1, 5]];
        let logits = fwd.batch_logits(&rows).unwrap();
        assert_eq!(logits.len(), 2 * s * v);
        // row 1's position-0 logits sit at the second row stride
        let model_lg = fwd.model().forward_seq(&[1, 5]).unwrap();
        assert_eq!(&logits[s * v..s * v + 2 * v], &model_lg[..2 * v]);
    }
}
