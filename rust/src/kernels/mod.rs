//! `kernels` — integer decode kernels for the host forward.
//!
//! SiLQ's deployment claim is that the quantized model adds *no extra
//! operations*, so an integer accelerator runs it strictly faster. Before
//! this module the host path simulated quantization in f32: weights were
//! fake-quantized but stored as 4-byte floats, and every decode step
//! dequantized the whole cached prefix into fresh f32 buffers. These
//! kernels make the claim real on the host:
//!
//! * [`QLinear`] — a linear weight folded to `i8` integers + one f32 step
//!   per output channel (the `quant::pack` representation), with a fused
//!   [`QLinear::gemv`] (one activation row) and a blocked
//!   [`QLinear::gemm`] (many rows, one pass over the weights). Both
//!   accumulate `i8×i8` products in `i32` — *exact* integer arithmetic —
//!   and apply `scale_x · scale_w[c]` once per output channel, so GEMV and
//!   GEMM are bit-identical by construction.
//! * [`attend_i8`] — causal attention computed directly over the `i8` K/V
//!   rows of the [`crate::hostmodel::KvPool`] slab: `q·k` in `i32`, then
//!   softmax·V accumulated over the `i8` V rows. The per-token
//!   `O(pos·d)` dequantize-and-copy of the old read path disappears.
//! * [`DecodeScratch`] — every intermediate of one decode step, sized once
//!   per model, so steady-state `forward_token` performs no heap
//!   allocation (pinned by `tests/kernels_zero_alloc.rs`).
//! * The integer/f32 *twins* ([`quant_rows_i8`] vs
//!   [`crate::quant::dynamic_quant_rows`], [`qint`] vs
//!   `quant::fake_quant_scalar`) share the step rules bit-for-bit: a
//!   fake-quantized value is exactly `q · s` for the integer `q` these
//!   kernels store, which is the pack/unpack losslessness invariant the
//!   repo pins in `proptests.rs`.
//!
//! Why integer accumulation is exact: an `i8×i8` product is at most
//! `2^14`, and the hot-path contraction lengths (`d_model`, `d_ff`,
//! `d_head` times the quantization ranges) keep the running sum far below
//! `2^31`, so the `i32` accumulator never rounds — eligibility is checked
//! against exactly this bound in `HostModel::new`. The only f32 rounding
//! left is the single per-channel descale multiply, which is why the
//! integer path tracks the f32 fake-quant reference to ~1e-5 relative
//! (and greedy decode is token-identical on the builtin models) without
//! being bit-equal to it.
//!
//! Exactness is also what makes the kernels **parallel and vectorized for
//! free**: because every output channel's contraction is exact `i32`
//! arithmetic, sharding channels across the persistent worker [`pool`]
//! and running the inner loops through the runtime-dispatched SIMD
//! [`simd::DotKernel`] cannot change a single bit — `gemv`/`gemm_into`
//! fan out by output-channel range ([`pool::shard_range`]: disjoint,
//! deterministic) whenever `pool::configure` raised the thread count and
//! the call clears [`pool::MIN_WORK_PER_SHARD`], and every identity pin
//! (int≡reference, batched≡sequential, parallel≡scalar) holds bit-exact
//! at any thread count and under either kernel. The f32 reductions the
//! module does *not* own (softmax·V accumulation inside [`attend_i8`],
//! residual adds) are order-dependent, so they never cross a shard
//! boundary: attention parallelism happens one level up, per lane, in
//! `HostModel::forward_tokens_batch`.
//!
//! Observability contract: each kernel call adds its *whole* cost to the
//! [`obs`] counters **once at entry** (`i8_macs = n·in·out` for a GEMM,
//! `kv_bytes_read = 2·len·dim` for an attend) — never per element, never
//! per shard — so counter totals are exact closed-form functions of the
//! work submitted, independent of thread count, zero-skips, and SIMD
//! width, and the disabled cost stays one relaxed load + branch per call.

pub mod pool;
pub mod scratch;
pub mod simd;

pub use scratch::{BatchScratch, DecodeScratch};

use crate::obs;
use crate::quant::{qbounds, round_half_even, EPS};

// ---------------------------------------------------------------------------
// quantization primitives (integer twins of quant::fake_quant_*)
// ---------------------------------------------------------------------------

/// The integer half of `quant::fake_quant_scalar`: clamp, round half to
/// even, keep the integer. The step `s` must already be floored at
/// [`EPS`] (see `QuantRule::floored` — the floor is hoisted out of the
/// per-element inner loops).
#[inline]
pub fn qint(x: f32, s: f32, bits: u32) -> i32 {
    let (qn, qp) = qbounds(bits);
    round_half_even((x / s).clamp(qn as f32, qp as f32)) as i32
}

/// Dynamic per-sub-row step: `max|x| / q_p`, floored at [`EPS`] (the 'd'
/// mode rule shared by activations, queries and the KV cache).
#[inline]
pub fn dyn_step(row: &[f32], qp: i64) -> f32 {
    let maxabs = row.iter().fold(0f32, |a, &b| a.max(b.abs()));
    (maxabs / qp as f32).max(EPS)
}

/// One quantization loop for every integer width: dynamic per-group steps
/// when `step` is `None`, one static (pre-floored) step otherwise. Both
/// public row quantizers delegate here so the step rule can never drift
/// between the activation (`i8`) and query (`i32`) paths.
fn quant_rows_impl<T: Copy>(
    x: &[f32],
    sub: usize,
    bits: u32,
    step: Option<f32>,
    q: &mut [T],
    scales: &mut [f32],
    to: impl Fn(i32) -> T,
) {
    debug_assert_eq!(x.len() % sub, 0);
    debug_assert_eq!(q.len(), x.len());
    debug_assert_eq!(scales.len(), x.len() / sub);
    let (_, qp) = qbounds(bits);
    for (g, (xg, qg)) in x.chunks(sub).zip(q.chunks_mut(sub)).enumerate() {
        let s = match step {
            Some(s) => s,
            None => dyn_step(xg, qp),
        };
        scales[g] = s;
        for (qv, &xv) in qg.iter_mut().zip(xg) {
            *qv = to(qint(xv, s, bits));
        }
    }
}

/// Quantize one activation row to `i8` over `sub`-sized groups.
/// `scales[g]` receives group g's step, so `q[i] as f32 * scales[i / sub]`
/// reproduces the fake-quant value bit-exactly.
pub fn quant_rows_i8(
    x: &[f32],
    sub: usize,
    bits: u32,
    step: Option<f32>,
    q: &mut [i8],
    scales: &mut [f32],
) {
    quant_rows_impl(x, sub, bits, step, q, scales, |v| v as i8);
}

/// [`quant_rows_i8`] widened to `i32` values — the query row, which the
/// paper keeps at 16 bits, does not fit an `i8`.
pub fn quant_rows_i32(
    x: &[f32],
    sub: usize,
    bits: u32,
    step: Option<f32>,
    q: &mut [i32],
    scales: &mut [f32],
) {
    quant_rows_impl(x, sub, bits, step, q, scales, |v| v);
}

// ---------------------------------------------------------------------------
// packed linear weights + fused GEMV / GEMM
// ---------------------------------------------------------------------------

/// The **maximum** activation rows processed per accumulator block in
/// [`QLinear::gemm`] / [`QLinear::gemm_into`] — public so scratch buffers
/// can size their accumulators (`GEMM_BLOCK · out_dim`) for the largest
/// block the kernel will ever pick. The block size actually used is a
/// tunable selected per call shape by [`gemm_block_for`]; because the
/// `i32` contraction is exact, **every** block size produces bit-identical
/// output (pinned by `gemm_all_block_sizes_are_bit_identical`), so the
/// choice is purely a locality trade-off: a larger block amortizes each
/// streamed weight row over more activation rows, a smaller one keeps the
/// accumulator window hot in L1.
pub const GEMM_BLOCK: usize = 8;

/// Block size [`QLinear::gemm_into`] uses for an `n`-row call: the largest
/// power of two `≤ min(n, GEMM_BLOCK)`. Never larger than `n` (a partial
/// final block would waste accumulator traffic) and never larger than
/// [`GEMM_BLOCK`] (the scratch sizing contract). Deterministic in `n`
/// alone so a given call shape always takes the same path.
pub fn gemm_block_for(n: usize) -> usize {
    let cap = n.clamp(1, GEMM_BLOCK);
    // largest power of two <= cap
    1 << (usize::BITS - 1 - cap.leading_zeros())
}

/// A linear weight folded to integers at model construction: row-major
/// `[in_dim, out_dim]` `i8` values (matching the f32 matrices' `x @ W`
/// layout) plus one pre-floored f32 step per output channel — the
/// `quant::pack::PackedTensor` representation, shaped for the decode hot
/// loop. A 4-bit weight matrix holds the same integers an accelerator
/// would bit-pack; the host keeps one byte per value, still quartering
/// the f32 path's weight traffic.
pub struct QLinear {
    /// contraction (input) dimension
    pub in_dim: usize,
    /// output channels
    pub out_dim: usize,
    /// row-major `[in_dim, out_dim]` quantized values
    pub q: Vec<i8>,
    /// per-output-channel steps, pre-floored at [`EPS`]
    pub scales: Vec<f32>,
}

impl QLinear {
    /// Fold a raw row-major `[in_dim, out_dim]` f32 matrix with per-output
    /// -channel steps into the packed representation. Produces exactly the
    /// integers `quant::pack::PackedTensor::pack` would (same clamp and
    /// round-half-even), so dequantizing reproduces the fake-quant matrix
    /// bit-for-bit.
    pub fn pack(w: &[f32], out_dim: usize, steps: &[f32], bits: u32) -> QLinear {
        assert!(bits <= 8, "QLinear packs <=8-bit weights");
        assert_eq!(steps.len(), out_dim);
        assert_eq!(w.len() % out_dim, 0);
        let scales: Vec<f32> = steps.iter().map(|&s| s.max(EPS)).collect();
        let mut q = Vec::with_capacity(w.len());
        for row in w.chunks(out_dim) {
            for (&x, &s) in row.iter().zip(&scales) {
                q.push(qint(x, s, bits) as i8);
            }
        }
        QLinear { in_dim: w.len() / out_dim, out_dim, q, scales }
    }

    /// Fused quantized GEMV: `out[o] = (Σ_i xq[i]·q[i,o]) · (sx·scales[o])`.
    /// The contraction is exact `i32` arithmetic; `acc` is caller-provided
    /// scratch (`>= out_dim`) so the decode loop never allocates. The
    /// output channels are sharded across the worker [`pool`] when it is
    /// configured and the call clears the work floor — each shard owns a
    /// disjoint channel range, and every channel's sum is exact integer
    /// math fully contained in one shard, so the result is bit-identical
    /// at any thread count (and under either [`simd`] kernel).
    pub fn gemv(&self, xq: &[i8], sx: f32, acc: &mut [i32], out: &mut [f32]) {
        debug_assert_eq!(xq.len(), self.in_dim);
        debug_assert_eq!(out.len(), self.out_dim);
        obs::add(obs::Counter::GemvCalls, 1);
        obs::add(obs::Counter::I8Macs, (self.in_dim * self.out_dim) as u64);
        let od = self.out_dim;
        let acc = &mut acc[..od]; // bounds-check the scratch before raw windows
        let kern = simd::active();
        let shards = pool::shard_count(self.in_dim * od, od);
        let accp = pool::SendPtr(acc.as_mut_ptr());
        let outp = pool::SendPtr(out.as_mut_ptr());
        pool::run(shards, &|s| {
            let (c0, c1) = pool::shard_range(od, shards, s);
            // SAFETY: shard_range windows are disjoint per shard and the
            // pool joins every shard before `run` returns, so these are
            // non-overlapping borrows that end inside this call.
            let acc = unsafe { std::slice::from_raw_parts_mut(accp.0.add(c0), c1 - c0) };
            let out = unsafe { std::slice::from_raw_parts_mut(outp.0.add(c0), c1 - c0) };
            self.gemv_cols(xq, sx, kern, c0, c1, acc, out);
        });
    }

    /// One GEMV shard: output channels `[c0, c1)`. `acc`/`out` are that
    /// window's slices. The serial call is the single shard `[0, od)`.
    fn gemv_cols(
        &self,
        xq: &[i8],
        sx: f32,
        kern: &dyn simd::DotKernel,
        c0: usize,
        c1: usize,
        acc: &mut [i32],
        out: &mut [f32],
    ) {
        let od = self.out_dim;
        acc.fill(0);
        for (i, &a) in xq.iter().enumerate() {
            if a == 0 {
                continue; // a zero activation contributes exactly nothing
            }
            kern.axpy_i8(a as i32, &self.q[i * od + c0..i * od + c1], acc);
        }
        for ((y, &s), &sw) in out.iter_mut().zip(acc.iter()).zip(&self.scales[c0..c1]) {
            *y = s as f32 * (sx * sw);
        }
    }

    /// Blocked multi-row GEMM: `sxs.len()` activation rows (`xq` row-major
    /// `[n, in_dim]`, one scale per row) through one pass over the weight
    /// matrix, [`gemm_block_for`]`(n)` rows at a time — prefill/scoring
    /// (and, since the cross-lane batching PR, every batched decode step)
    /// stops paying n independent weight streams. Bit-identical to
    /// [`QLinear::gemv`] per row (the `i32` contraction is exact, so
    /// blocking cannot change it; the descale expression is the same).
    /// Allocates its own accumulator; hot loops use
    /// [`QLinear::gemm_into`] instead.
    pub fn gemm(&self, xq: &[i8], sxs: &[f32], out: &mut [f32]) {
        let mut acc = vec![0i32; GEMM_BLOCK.min(sxs.len().max(1)) * self.out_dim];
        self.gemm_into(xq, sxs, &mut acc, out);
    }

    /// [`QLinear::gemm`] with a caller-provided `i32` accumulator
    /// (`>= min(n, GEMM_BLOCK) · out_dim`) — the multi-row decode entry:
    /// B stacked activation rows through one pass over the weights with no
    /// heap allocation, so the cross-lane batched decode step stays as
    /// zero-alloc as the single-lane GEMV path. Like [`QLinear::gemv`],
    /// the output channels are sharded across the worker [`pool`]; each
    /// shard streams its channel window of the weights for all rows, so
    /// parallel output is bit-identical to serial at any thread count.
    pub fn gemm_into(&self, xq: &[i8], sxs: &[f32], acc: &mut [i32], out: &mut [f32]) {
        self.gemm_into_blocked(xq, sxs, acc, out, gemm_block_for(sxs.len()));
    }

    /// [`QLinear::gemm_into`] at an explicit block size `1..=GEMM_BLOCK`
    /// (the accumulator must hold `block · out_dim`). Exposed so the block
    /// tunable can be swept — all block sizes produce bit-identical output
    /// (exact `i32` accumulation), which the kernel test suite pins.
    pub fn gemm_into_blocked(
        &self,
        xq: &[i8],
        sxs: &[f32],
        acc: &mut [i32],
        out: &mut [f32],
        block: usize,
    ) {
        let n = sxs.len();
        let od = self.out_dim;
        obs::add(obs::Counter::GemmCalls, 1);
        obs::add(obs::Counter::I8Macs, (n * self.in_dim * od) as u64);
        debug_assert_eq!(xq.len(), n * self.in_dim);
        debug_assert_eq!(out.len(), n * od);
        assert!((1..=GEMM_BLOCK).contains(&block), "block size {block} out of range");
        if n == 0 {
            return;
        }
        let block = block.min(n);
        let acc = &mut acc[..block * od]; // bounds-check before raw windows
        let kern = simd::active();
        let shards = pool::shard_count(n * self.in_dim * od, od);
        let accp = pool::SendPtr(acc.as_mut_ptr());
        let outp = pool::SendPtr(out.as_mut_ptr());
        pool::run(shards, &|s| {
            let (c0, c1) = pool::shard_range(od, shards, s);
            // SAFETY: shard s owns channels [c0, c1) — its accumulator
            // window `acc[c0·block, c1·block)` and its per-row output
            // windows `out[r·od+c0, r·od+c1)` are disjoint across shards,
            // and the pool joins every shard before `run` returns.
            let acc = unsafe {
                std::slice::from_raw_parts_mut(accp.0.add(c0 * block), (c1 - c0) * block)
            };
            self.gemm_cols(xq, sxs, kern, block, c0, c1, acc, outp.0);
        });
    }

    /// One GEMM shard: output channels `[c0, c1)` of every activation row,
    /// `block` rows per accumulator pass. `acc` is this shard's private
    /// `[block · (c1-c0)]` window; `out` is the raw base of the full
    /// `[n, out_dim]` output (each row's `[c0, c1)` window is written).
    #[allow(clippy::too_many_arguments)]
    fn gemm_cols(
        &self,
        xq: &[i8],
        sxs: &[f32],
        kern: &dyn simd::DotKernel,
        block: usize,
        c0: usize,
        c1: usize,
        acc: &mut [i32],
        out: *mut f32,
    ) {
        let n = sxs.len();
        let od = self.out_dim;
        let w = c1 - c0;
        let mut r = 0;
        while r < n {
            let b = (n - r).min(block);
            let accb = &mut acc[..b * w];
            accb.fill(0);
            for i in 0..self.in_dim {
                let row = &self.q[i * od + c0..i * od + c1];
                for (br, accr) in accb.chunks_mut(w).enumerate() {
                    let a = xq[(r + br) * self.in_dim + i] as i32;
                    if a == 0 {
                        continue;
                    }
                    kern.axpy_i8(a, row, accr);
                }
            }
            for (br, accr) in accb.chunks(w).enumerate() {
                let sx = sxs[r + br];
                // SAFETY: this shard's disjoint column window of row r+br.
                let o = unsafe {
                    std::slice::from_raw_parts_mut(out.add((r + br) * od + c0), w)
                };
                for ((y, &s), &sw) in o.iter_mut().zip(accr).zip(&self.scales[c0..c1]) {
                    *y = s as f32 * (sx * sw);
                }
            }
            r += b;
        }
    }

    /// Packed storage footprint in bytes (bit-packed values + scales),
    /// matching `PackedTensor::storage_bytes` accounting at `bits`.
    pub fn storage_bytes(&self, bits: u32) -> usize {
        (self.q.len() * bits as usize + 7) / 8 + self.scales.len() * 4
    }
}

/// One model weight in whichever representation the policy earned:
/// packed integers on the deployment path, (fake-quantized) f32 on the
/// reference/fallback path.
pub enum Linear {
    /// row-major `[in, out]` f32 weights — unquantized, >8-bit, or the
    /// explicit f32 reference build
    F32 {
        /// the weight matrix (fake-quantized when the policy asks)
        w: Vec<f32>,
        /// output channels
        out_dim: usize,
    },
    /// packed integers + per-output-channel scales
    Int8(QLinear),
}

/// One activation row prepared for a [`Linear`]'s representation.
#[derive(Clone, Copy)]
pub enum ActRow<'a> {
    /// (fake-quantized) f32 row for [`Linear::F32`]
    F32(&'a [f32]),
    /// quantized `i8` row + its step for [`Linear::Int8`]
    I8 {
        /// quantized values
        q: &'a [i8],
        /// the row's step
        scale: f32,
    },
}

impl Linear {
    /// Output channels of this weight.
    pub fn out_dim(&self) -> usize {
        match self {
            Linear::F32 { out_dim, .. } => *out_dim,
            Linear::Int8(ql) => ql.out_dim,
        }
    }

    /// Resident host bytes of this representation: one byte per packed
    /// value + 4-byte scales, or 4 bytes per f32 — the "quarter the weight
    /// traffic" accounting the bench harness reports.
    pub fn resident_bytes(&self) -> usize {
        match self {
            Linear::F32 { w, .. } => w.len() * 4,
            Linear::Int8(ql) => ql.q.len() + ql.scales.len() * 4,
        }
    }

    /// One activation row through this weight into `out`. The caller
    /// prepares `act` in the matching representation (the model decides
    /// once per site); `acc` is `i32` scratch for the packed path.
    pub fn forward(&self, act: ActRow<'_>, acc: &mut [i32], out: &mut [f32]) {
        match (self, act) {
            (Linear::F32 { w, out_dim }, ActRow::F32(x)) => {
                debug_assert_eq!(out.len(), *out_dim);
                matvec_into(x, w, out);
            }
            (Linear::Int8(ql), ActRow::I8 { q, scale }) => ql.gemv(q, scale, acc, out),
            _ => unreachable!("activation representation does not match the weight"),
        }
    }
}

/// f32 matvec `out[o] = Σ_i x[i]·w[i·out_dim+o]` into a caller buffer —
/// the reference-path twin of [`QLinear::gemv`] (same zero-skip, same
/// accumulation order as the pre-kernels `matvec`).
pub fn matvec_into(x: &[f32], w: &[f32], out: &mut [f32]) {
    let od = out.len();
    debug_assert_eq!(x.len() * od, w.len());
    out.fill(0.0);
    for (i, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let row = &w[i * od..(i + 1) * od];
        for (o, &wv) in out.iter_mut().zip(row) {
            *o += xv * wv;
        }
    }
}

// ---------------------------------------------------------------------------
// attention kernels
// ---------------------------------------------------------------------------

/// Zero-copy causal attention for one query position directly over `i8`
/// K/V rows (`len` positions, `[len·dim]` head-major — the `KvPool` slab
/// layout, or `forward_seq`'s own quantized rows).
///
/// Per head `h` and position `j`: `q·k` is an exact `i32` contraction of
/// the quantized query (`qq`, step `q_scales[h]`) against the `i8` K row,
/// descaled once: `score = acc · (q_scale·k_scale) / sqrt(d_head)`. After
/// the softmax, the context accumulates `p_j·v_scale` against the raw
/// `i8` V row. `scale_stride` selects the K/V step layout: `rows` (=
/// heads) for per-(position, head) dynamic steps, `0` for per-head steps
/// constant across positions (the static per-layer rule).
///
/// The q·k dot runs through the dispatched [`simd`] kernel (exact), but
/// the call itself never shards internally: the softmax·V accumulation is
/// **f32 and order-dependent**, so splitting it would change bits.
/// Attention parallelism lives one level up — the batched forward fans
/// whole lanes (one `attend_i8` each) across the [`pool`].
pub fn attend_i8(
    qq: &[i32],
    q_scales: &[f32],
    k: &[i8],
    v: &[i8],
    k_scales: &[f32],
    v_scales: &[f32],
    scale_stride: usize,
    heads: usize,
    dim: usize,
    len: usize,
    scores: &mut [f32],
    ctx: &mut [f32],
) {
    debug_assert!(k.len() >= len * dim && v.len() >= len * dim);
    let run = KvRun { k, v, k_scales, v_scales, len };
    attend_i8_runs(
        qq,
        q_scales,
        std::iter::once(run),
        scale_stride,
        heads,
        dim,
        len,
        scores,
        ctx,
    );
}

/// One contiguous stretch of quantized K/V rows — a whole slab, or one
/// page of the paged [`crate::hostmodel::KvPool`]. `k`/`v` hold `len`
/// positions (`[len·dim]` row-major); `k_scales`/`v_scales` hold that
/// run's per-(position, head) dynamic write steps (`[len·rows]`), or the
/// per-head static steps shared by every run when `scale_stride` is 0.
#[derive(Clone, Copy)]
pub struct KvRun<'a> {
    /// `i8` K rows of this run, `[len * dim]`
    pub k: &'a [i8],
    /// `i8` V rows of this run, `[len * dim]`
    pub v: &'a [i8],
    /// K write steps for this run (layout per `scale_stride`)
    pub k_scales: &'a [f32],
    /// V write steps for this run (layout per `scale_stride`)
    pub v_scales: &'a [f32],
    /// positions in this run
    pub len: usize,
}

/// [`attend_i8`] over a sequence of contiguous K/V runs — the paged-pool
/// entry point. The runs are walked **in position order** twice (the
/// iterator must be `Clone`): one pass scores every position, the softmax
/// normalizes over the full score window, and a second pass accumulates
/// the context. Per position the math is exactly [`attend_i8`]'s — the
/// position loop is merely split at page boundaries, and neither the
/// score of a position nor the f32 softmax·V accumulation order depends
/// on where those splits fall, so paged ≡ contiguous bit-for-bit (the
/// kernels unit test pins it against random splits). `len` must equal the
/// run lengths' sum; the byte/call counters are charged here once, in
/// closed form, exactly as the contiguous path always has.
#[allow(clippy::too_many_arguments)]
pub fn attend_i8_runs<'a, I>(
    qq: &[i32],
    q_scales: &[f32],
    runs: I,
    scale_stride: usize,
    heads: usize,
    dim: usize,
    len: usize,
    scores: &mut [f32],
    ctx: &mut [f32],
) where
    I: Iterator<Item = KvRun<'a>> + Clone,
{
    debug_assert_eq!(qq.len(), dim);
    debug_assert_eq!(ctx.len(), dim);
    obs::add(obs::Counter::AttendI8Calls, 1);
    obs::add(obs::Counter::KvBytesRead, 2 * (len * dim) as u64);
    let kern = simd::active();
    let dh = dim / heads;
    let inv = 1.0 / (dh as f32).sqrt();
    let scores = &mut scores[..len];
    ctx.fill(0.0);
    for h in 0..heads {
        let off = h * dh;
        let qh = &qq[off..off + dh];
        let sq = q_scales[h];
        let mut j0 = 0usize;
        for run in runs.clone() {
            debug_assert!(run.k.len() >= run.len * dim && run.v.len() >= run.len * dim);
            for (j, sc) in scores[j0..j0 + run.len].iter_mut().enumerate() {
                let kh = &run.k[j * dim + off..j * dim + off + dh];
                // exact i32 q·k (quantized queries fit i16 — the policy
                // caps query bits at 16 — so the SIMD narrowing is
                // lossless)
                let acc = kern.dot_q_i8(qh, kh);
                *sc = acc as f32 * (sq * run.k_scales[j * scale_stride + h]) * inv;
            }
            j0 += run.len;
        }
        debug_assert_eq!(j0, len, "run lengths must sum to len");
        softmax_inplace(scores);
        let ch = &mut ctx[off..off + dh];
        let mut j0 = 0usize;
        for run in runs.clone() {
            for (j, &p) in scores[j0..j0 + run.len].iter().enumerate() {
                let w = p * run.v_scales[j * scale_stride + h];
                let vh = &run.v[j * dim + off..j * dim + off + dh];
                for (cv, &vv) in ch.iter_mut().zip(vh) {
                    *cv += w * vv as f32;
                }
            }
            j0 += run.len;
        }
    }
}

/// f32 causal attention into caller buffers — the reference/fallback twin
/// of [`attend_i8`], bit-identical to the pre-kernels `HostModel::attend`
/// (same per-head loop and accumulation order).
pub fn attend_f32(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    heads: usize,
    dim: usize,
    len: usize,
    scores: &mut [f32],
    ctx: &mut [f32],
) {
    debug_assert_eq!(q.len(), dim);
    debug_assert_eq!(ctx.len(), dim);
    debug_assert!(k.len() >= len * dim && v.len() >= len * dim);
    let dh = dim / heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let scores = &mut scores[..len];
    ctx.fill(0.0);
    for h in 0..heads {
        let off = h * dh;
        let qh = &q[off..off + dh];
        for (j, sc) in scores.iter_mut().enumerate() {
            let kh = &k[j * dim + off..j * dim + off + dh];
            *sc = qh.iter().zip(kh).map(|(a, b)| a * b).sum::<f32>() * scale;
        }
        softmax_inplace(scores);
        let ch = &mut ctx[off..off + dh];
        for (j, &p) in scores.iter().enumerate() {
            let vh = &v[j * dim + off..j * dim + off + dh];
            for (cv, &vv) in ch.iter_mut().zip(vh) {
                *cv += p * vv;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// shared elementwise math
// ---------------------------------------------------------------------------

/// In-place softmax. The max fold seeds with `f32::NEG_INFINITY` — the
/// identity element of `max` — so fully masked score rows (everything at
/// or below `f32::MIN`) still normalize instead of exploding.
pub fn softmax_inplace(xs: &mut [f32]) {
    let m = xs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0f32;
    for v in xs.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    for v in xs.iter_mut() {
        *v /= sum;
    }
}

/// RMSNorm into a caller buffer (model.py uses EPS=1e-6 inside rmsnorm;
/// the quant EPS is 1e-9).
pub fn rmsnorm_into(x: &[f32], g: &[f32], out: &mut [f32]) {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let r = 1.0 / (ms + 1e-6).sqrt();
    for ((o, &v), &gv) in out.iter_mut().zip(x).zip(g) {
        *o = v * gv * r;
    }
}

/// SiLU gate activation.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{dynamic_quant_rows, fake_quant_per_channel, fake_quant_scalar};
    use crate::util::Rng;

    #[test]
    fn quant_rows_i8_is_the_integer_twin_of_dynamic_quant_rows() {
        let mut rng = Rng::new(1);
        for sub in [4usize, 8, 16] {
            let x = rng.normal_vec(32, 0.7);
            let mut q = vec![0i8; 32];
            let mut s = vec![0f32; 32 / sub];
            quant_rows_i8(&x, sub, 8, None, &mut q, &mut s);
            let mut fq = x.clone();
            dynamic_quant_rows(&mut fq, sub, 8);
            for (i, &qv) in q.iter().enumerate() {
                assert_eq!(qv as f32 * s[i / sub], fq[i], "sub {sub} idx {i}");
            }
        }
    }

    #[test]
    fn quant_rows_static_matches_fake_quant_scalar() {
        let mut rng = Rng::new(2);
        let x = rng.normal_vec(24, 1.2);
        let step = 0.021f32;
        let mut q = vec![0i32; 24];
        let mut s = vec![0f32; 1];
        quant_rows_i32(&x, 24, 16, Some(step), &mut q, &mut s);
        assert_eq!(s[0], step);
        for (&qv, &xv) in q.iter().zip(&x) {
            assert_eq!(qv as f32 * step, fake_quant_scalar(xv, step, 16));
        }
    }

    #[test]
    fn qlinear_pack_dequants_to_fake_quant() {
        let mut rng = Rng::new(3);
        let (din, dout) = (16usize, 8usize);
        let w = rng.normal_vec(din * dout, 0.2);
        let steps: Vec<f32> = (0..dout).map(|_| rng.uniform() * 0.05 + 1e-3).collect();
        let ql = QLinear::pack(&w, dout, &steps, 4);
        let mut fq = w.clone();
        fake_quant_per_channel(&mut fq, dout, &steps, 4);
        for (i, &qv) in ql.q.iter().enumerate() {
            assert_eq!(qv as f32 * ql.scales[i % dout], fq[i]);
        }
        assert!(ql.storage_bytes(4) < din * dout * 4);
    }

    #[test]
    fn gemv_matches_f32_matvec_of_dequant_closely() {
        let mut rng = Rng::new(4);
        let (din, dout) = (32usize, 12usize);
        let w = rng.normal_vec(din * dout, 0.2);
        let steps: Vec<f32> = (0..dout).map(|_| rng.uniform() * 0.05 + 1e-3).collect();
        let ql = QLinear::pack(&w, dout, &steps, 4);
        let x = rng.normal_vec(din, 1.0);
        let mut xq = vec![0i8; din];
        let mut sx = vec![0f32; 1];
        quant_rows_i8(&x, din, 8, None, &mut xq, &mut sx);
        let mut acc = vec![0i32; dout];
        let mut out = vec![0f32; dout];
        ql.gemv(&xq, sx[0], &mut acc, &mut out);
        // f32 reference over the dequantized operands
        let mut fq = w.clone();
        fake_quant_per_channel(&mut fq, dout, &steps, 4);
        let xf: Vec<f32> = xq.iter().map(|&q| q as f32 * sx[0]).collect();
        let mut want = vec![0f32; dout];
        matvec_into(&xf, &fq, &mut want);
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-4 * a.abs().max(b.abs()).max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn gemm_is_bit_identical_to_gemv_per_row() {
        let mut rng = Rng::new(5);
        let (din, dout, n) = (24usize, 16usize, 7usize);
        let w = rng.normal_vec(din * dout, 0.3);
        let steps: Vec<f32> = (0..dout).map(|_| rng.uniform() * 0.05 + 1e-3).collect();
        let ql = QLinear::pack(&w, dout, &steps, 8);
        let mut xq = vec![0i8; n * din];
        for q in xq.iter_mut() {
            *q = (rng.below(255) as i32 - 127) as i8;
        }
        let sxs: Vec<f32> = (0..n).map(|_| rng.uniform() * 0.1 + 1e-3).collect();
        let mut out = vec![0f32; n * dout];
        ql.gemm(&xq, &sxs, &mut out);
        let mut acc = vec![0i32; dout];
        let mut row = vec![0f32; dout];
        for r in 0..n {
            ql.gemv(&xq[r * din..(r + 1) * din], sxs[r], &mut acc, &mut row);
            assert_eq!(&out[r * dout..(r + 1) * dout], &row[..], "row {r}");
        }
        // the caller-scratch entry is the same kernel (the batched decode
        // path rides on this)
        let mut acc2 = vec![0i32; GEMM_BLOCK * dout];
        let mut out2 = vec![0f32; n * dout];
        ql.gemm_into(&xq, &sxs, &mut acc2, &mut out2);
        assert_eq!(out, out2);
    }

    #[test]
    fn attend_i8_tracks_attend_f32_on_dequantized_rows() {
        let mut rng = Rng::new(6);
        let (heads, dim, len) = (2usize, 8usize, 5usize);
        let q = rng.normal_vec(dim, 1.0);
        let mut qq = vec![0i32; dim];
        let mut qs = vec![0f32; heads];
        quant_rows_i32(&q, dim / heads, 16, None, &mut qq, &mut qs);
        // dynamic per-(pos, head) K/V
        let mut k = vec![0i8; len * dim];
        let mut v = vec![0i8; len * dim];
        let mut ksc = vec![0f32; len * heads];
        let mut vsc = vec![0f32; len * heads];
        for j in 0..len {
            let kr = rng.normal_vec(dim, 0.5);
            let vr = rng.normal_vec(dim, 0.5);
            let (ks, vs) = (j * heads, (j + 1) * heads);
            quant_rows_i8(&kr, dim / heads, 8, None, &mut k[j * dim..(j + 1) * dim], &mut ksc[ks..vs]);
            quant_rows_i8(&vr, dim / heads, 8, None, &mut v[j * dim..(j + 1) * dim], &mut vsc[ks..vs]);
        }
        let mut scores = vec![0f32; len];
        let mut ctx = vec![0f32; dim];
        attend_i8(&qq, &qs, &k, &v, &ksc, &vsc, heads, heads, dim, len, &mut scores, &mut ctx);
        // f32 reference over the dequantized rows
        let dh = dim / heads;
        let qf: Vec<f32> = qq.iter().enumerate().map(|(i, &x)| x as f32 * qs[i / dh]).collect();
        let deq = |q: &[i8], sc: &[f32]| -> Vec<f32> {
            q.iter()
                .enumerate()
                .map(|(i, &x)| x as f32 * sc[(i / dim) * heads + (i % dim) / dh])
                .collect()
        };
        let (kf, vf) = (deq(&k, &ksc), deq(&v, &vsc));
        let mut scores2 = vec![0f32; len];
        let mut want = vec![0f32; dim];
        attend_f32(&qf, &kf, &vf, heads, dim, len, &mut scores2, &mut want);
        for (a, b) in ctx.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-4 * a.abs().max(b.abs()).max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn attend_i8_runs_is_bit_identical_at_any_split() {
        // the paged pool splits the position loop at page boundaries; every
        // split of the same rows must reproduce the contiguous call exactly
        let mut rng = Rng::new(9);
        let (heads, dim, len) = (2usize, 8usize, 7usize);
        let q = rng.normal_vec(dim, 1.0);
        let mut qq = vec![0i32; dim];
        let mut qs = vec![0f32; heads];
        quant_rows_i32(&q, dim / heads, 16, None, &mut qq, &mut qs);
        let mut k = vec![0i8; len * dim];
        let mut v = vec![0i8; len * dim];
        let mut ksc = vec![0f32; len * heads];
        let mut vsc = vec![0f32; len * heads];
        for j in 0..len {
            let kr = rng.normal_vec(dim, 0.5);
            let vr = rng.normal_vec(dim, 0.5);
            let (a, b) = (j * heads, (j + 1) * heads);
            quant_rows_i8(&kr, dim / heads, 8, None, &mut k[j * dim..(j + 1) * dim], &mut ksc[a..b]);
            quant_rows_i8(&vr, dim / heads, 8, None, &mut v[j * dim..(j + 1) * dim], &mut vsc[a..b]);
        }
        let mut scores = vec![0f32; len];
        let mut want = vec![0f32; dim];
        attend_i8(&qq, &qs, &k, &v, &ksc, &vsc, heads, heads, dim, len, &mut scores, &mut want);
        for page in [1usize, 2, 3, 4, len] {
            let runs = (0..len.div_ceil(page)).map(|p| {
                let (j0, j1) = (p * page, ((p + 1) * page).min(len));
                KvRun {
                    k: &k[j0 * dim..j1 * dim],
                    v: &v[j0 * dim..j1 * dim],
                    k_scales: &ksc[j0 * heads..j1 * heads],
                    v_scales: &vsc[j0 * heads..j1 * heads],
                    len: j1 - j0,
                }
            });
            let mut s2 = vec![0f32; len];
            let mut ctx = vec![0f32; dim];
            attend_i8_runs(&qq, &qs, runs, heads, heads, dim, len, &mut s2, &mut ctx);
            assert_eq!(ctx, want, "page size {page} changed bits");
            assert_eq!(s2, scores, "page size {page} changed the last head's scores");
        }
    }

    #[test]
    fn softmax_handles_uniform_and_extreme_rows() {
        let mut xs = vec![3.0f32, 3.0, 3.0];
        softmax_inplace(&mut xs);
        for v in &xs {
            assert!((v - 1.0 / 3.0).abs() < 1e-6);
        }
        // deeply negative scores (masked-out extensions) still normalize
        let mut lo = vec![f32::MIN, f32::MIN];
        softmax_inplace(&mut lo);
        assert!((lo[0] - 0.5).abs() < 1e-6 && (lo[1] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn matvec_into_matches_manual() {
        let x = [1.0f32, 0.0, 2.0];
        let w = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]; // [3, 2]
        let mut out = [0f32; 2];
        matvec_into(&x, &w, &mut out);
        assert_eq!(out, [1.0 + 10.0, 2.0 + 12.0]);
    }

    fn random_qlinear(rng: &mut Rng, din: usize, dout: usize, bits: u32) -> QLinear {
        let w = rng.normal_vec(din * dout, 0.3);
        let steps: Vec<f32> = (0..dout).map(|_| rng.uniform() * 0.05 + 1e-3).collect();
        QLinear::pack(&w, dout, &steps, bits)
    }

    fn random_act_rows(rng: &mut Rng, n: usize, din: usize) -> (Vec<i8>, Vec<f32>) {
        let mut xq = vec![0i8; n * din];
        for q in xq.iter_mut() {
            // include zeros so the zero-skip path is exercised
            *q = (rng.below(257) as i32 - 128).clamp(-127, 127) as i8;
        }
        let sxs: Vec<f32> = (0..n).map(|_| rng.uniform() * 0.1 + 1e-3).collect();
        (xq, sxs)
    }

    #[test]
    fn gemm_all_block_sizes_are_bit_identical() {
        let mut rng = Rng::new(7);
        let (din, dout) = (24usize, 20usize);
        let ql = random_qlinear(&mut rng, din, dout, 8);
        for n in [1usize, 2, 5, 7, 8, 11] {
            let (xq, sxs) = random_act_rows(&mut rng, n, din);
            let mut want = vec![0f32; n * dout];
            ql.gemm_into_blocked(&xq, &sxs, &mut vec![0i32; dout], &mut want, 1);
            for block in [2usize, 3, 4, GEMM_BLOCK] {
                let mut acc = vec![0i32; block * dout];
                let mut out = vec![0f32; n * dout];
                ql.gemm_into_blocked(&xq, &sxs, &mut acc, &mut out, block);
                assert_eq!(want, out, "n={n} block={block}");
            }
        }
    }

    #[test]
    fn gemm_block_for_is_bounded_and_deterministic() {
        for n in 1..=32 {
            let b = gemm_block_for(n);
            assert!(b >= 1 && b <= GEMM_BLOCK && b <= n, "n={n} -> {b}");
            assert!(b.is_power_of_two());
            assert_eq!(b, gemm_block_for(n), "deterministic in n");
        }
        assert_eq!(gemm_block_for(0), 1);
        assert_eq!(gemm_block_for(usize::MAX), GEMM_BLOCK);
    }

    #[test]
    fn sharded_gemv_and_gemm_match_serial_at_any_thread_count() {
        // the pool is process-global: serialize against its unit tests
        let _g = pool::test_guard();
        let mut rng = Rng::new(8);
        // big enough to clear the pool's per-shard work floor
        let (din, dout, n) = (96usize, 768usize, 5usize);
        let ql = random_qlinear(&mut rng, din, dout, 4);
        let (xq, sxs) = random_act_rows(&mut rng, n, din);
        // serial reference (library default: pool off)
        pool::shutdown();
        let mut acc = vec![0i32; GEMM_BLOCK * dout];
        let mut gv_want = vec![0f32; dout];
        ql.gemv(&xq[..din], sxs[0], &mut acc, &mut gv_want);
        let mut gm_want = vec![0f32; n * dout];
        ql.gemm_into(&xq, &sxs, &mut acc, &mut gm_want);
        for threads in [2usize, 4, 7] {
            pool::configure(threads);
            assert!(
                pool::shard_count(n * din * dout, dout) > 1,
                "test shape must actually fan out at {threads} threads"
            );
            let mut gv = vec![0f32; dout];
            ql.gemv(&xq[..din], sxs[0], &mut acc, &mut gv);
            assert_eq!(gv_want, gv, "gemv threads={threads}");
            let mut gm = vec![0f32; n * dout];
            ql.gemm_into(&xq, &sxs, &mut acc, &mut gm);
            assert_eq!(gm_want, gm, "gemm threads={threads}");
        }
        pool::shutdown();
    }
}
