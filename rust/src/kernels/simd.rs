//! `simd` — runtime-dispatched SIMD i8 dot-product micro-kernels.
//!
//! The integer GEMV/GEMM inner loop is an `i8×i8→i32` multiply-accumulate
//! over contiguous output channels, and the int8 attention score loop is
//! an `i32(≤i16)×i8→i32` dot over one head's K row. Both are exact integer
//! arithmetic, so a vectorized implementation that widens every product to
//! `i32` before adding produces **bit-identical** accumulators to the
//! scalar loop — integer addition is associative, unlike the f32 math this
//! module never touches.
//!
//! Dispatch is a process-global kernel choice ([`set_kernel`], `--kernel
//! scalar|simd` on the CLI): hot kernels load the active implementation
//! once per call ([`active`], one relaxed atomic load) and run every inner
//! loop through it. The SIMD implementation is selected per target at
//! compile time — SSE2 on `x86_64` and NEON on `aarch64` are baseline
//! target features, so no CPUID probing is needed — and falls back to the
//! scalar loops on other architectures.
//!
//! Exactness arguments, per micro-kernel:
//! * [`DotKernel::axpy_i8`]: `|a·w| ≤ 127·127 < 2^15`, so the 16-bit lane
//!   products (`_mm_mullo_epi16` / `vmull_s16`) never wrap; they are then
//!   sign-extended to `i32` and added — the same additions the scalar loop
//!   performs, in a different order, on exact integers.
//! * [`DotKernel::dot_q_i8`]: callers quantize the query to at most 16
//!   bits (the policy grammar caps `q<bits>` at 16), so narrowing the
//!   `i32` query lanes to `i16` (`_mm_packs_epi32` / `vmovn_s32`) is
//!   lossless and the widening multiply-accumulate is exact.

use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::{bail, Result};

/// A dot-product implementation the integer kernels dispatch through.
/// Every implementation must produce **bit-identical** results to
/// [`ScalarKernel`] — the contractions are exact `i32` arithmetic, so this
/// is an implementable contract, and `prop_parallel_gemm_matches_scalar`
/// pins it.
pub trait DotKernel: Sync {
    /// Stable name for reports and bench JSON (`scalar`, `simd-sse2`, ...).
    fn name(&self) -> &'static str;

    /// `acc[j] += a · row[j]` over one contiguous output-channel window.
    /// `a` is an `i8`-range activation (the caller already skipped zeros).
    fn axpy_i8(&self, a: i32, row: &[i8], acc: &mut [i32]);

    /// `Σ_j q[j] · k[j]` in exact `i32` — the attention score contraction.
    /// Contract: every `q[j]` fits an `i16` (query bits are capped at 16
    /// by the policy grammar), so 16-bit lane narrowing is lossless.
    fn dot_q_i8(&self, q: &[i32], k: &[i8]) -> i32;
}

/// The reference scalar loops — exactly the pre-SIMD kernel inner loops.
pub struct ScalarKernel;

impl DotKernel for ScalarKernel {
    fn name(&self) -> &'static str {
        "scalar"
    }

    #[inline]
    fn axpy_i8(&self, a: i32, row: &[i8], acc: &mut [i32]) {
        debug_assert_eq!(row.len(), acc.len());
        for (s, &w) in acc.iter_mut().zip(row) {
            *s += a * w as i32;
        }
    }

    #[inline]
    fn dot_q_i8(&self, q: &[i32], k: &[i8]) -> i32 {
        debug_assert_eq!(q.len(), k.len());
        q.iter().zip(k).map(|(&a, &b)| a * b as i32).sum()
    }
}

/// The vectorized implementation for this target (SSE2 on `x86_64`, NEON
/// on `aarch64`, scalar elsewhere).
pub struct SimdKernel;

impl DotKernel for SimdKernel {
    fn name(&self) -> &'static str {
        if cfg!(target_arch = "x86_64") {
            "simd-sse2"
        } else if cfg!(target_arch = "aarch64") {
            "simd-neon"
        } else {
            "scalar"
        }
    }

    #[inline]
    fn axpy_i8(&self, a: i32, row: &[i8], acc: &mut [i32]) {
        debug_assert_eq!(row.len(), acc.len());
        arch::axpy_i8(a, row, acc);
    }

    #[inline]
    fn dot_q_i8(&self, q: &[i32], k: &[i8]) -> i32 {
        debug_assert_eq!(q.len(), k.len());
        debug_assert!(
            q.iter().all(|&x| (i16::MIN as i32..=i16::MAX as i32).contains(&x)),
            "dot_q_i8 contract: query values must fit i16 (query bits <= 16)"
        );
        arch::dot_q_i8(q, k)
    }
}

// ---------------------------------------------------------------------------
// dispatch
// ---------------------------------------------------------------------------

static SCALAR: ScalarKernel = ScalarKernel;
static SIMD: SimdKernel = SimdKernel;

/// Active kernel index; SIMD (index 1) is the default — it is bit-exact
/// with scalar, so there is no correctness reason to opt in.
static ACTIVE: AtomicUsize = AtomicUsize::new(1);

/// A user-selectable kernel family (`--kernel scalar|simd`).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum KernelChoice {
    /// the reference scalar loops
    Scalar,
    /// the vectorized loops for this target (scalar fallback elsewhere)
    Simd,
}

impl KernelChoice {
    /// Parse a `--kernel` value, naming the accepted set on failure.
    pub fn parse(s: &str) -> Result<KernelChoice> {
        match s {
            "scalar" => Ok(KernelChoice::Scalar),
            "simd" => Ok(KernelChoice::Simd),
            other => bail!("unknown kernel {other:?} (scalar|simd)"),
        }
    }
}

/// Select the process-global dot kernel (normally once, at startup /
/// model build; safe at any time — every choice is bit-identical).
pub fn set_kernel(c: KernelChoice) {
    ACTIVE.store(
        match c {
            KernelChoice::Scalar => 0,
            KernelChoice::Simd => 1,
        },
        Ordering::Relaxed,
    );
}

/// The active kernel — hot paths load this once per kernel call (one
/// relaxed atomic load) and run every inner loop through it.
#[inline]
pub fn active() -> &'static dyn DotKernel {
    match ACTIVE.load(Ordering::Relaxed) {
        0 => &SCALAR,
        _ => &SIMD,
    }
}

/// Name of the dispatched implementation (bench JSON, serve banner).
pub fn active_name() -> &'static str {
    active().name()
}

// ---------------------------------------------------------------------------
// x86_64: SSE2 (baseline — no runtime detection needed)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod arch {
    use std::arch::x86_64::*;

    #[inline]
    pub fn axpy_i8(a: i32, row: &[i8], acc: &mut [i32]) {
        // SAFETY: SSE2 is a baseline x86_64 target feature; all loads and
        // stores below stay inside `row`/`acc` bounds.
        unsafe {
            let n = row.len();
            let va = _mm_set1_epi16(a as i16);
            let zero = _mm_setzero_si128();
            let mut j = 0;
            while j + 16 <= n {
                let w = _mm_loadu_si128(row.as_ptr().add(j) as *const __m128i);
                // sign-extend 16×i8 → 2×8×i16 (SSE2 has no cvtepi8)
                let neg = _mm_cmpgt_epi8(zero, w);
                let w_lo = _mm_unpacklo_epi8(w, neg);
                let w_hi = _mm_unpackhi_epi8(w, neg);
                // |a·w| ≤ 127·127 < 2^15 — the 16-bit products are exact
                let p_lo = _mm_mullo_epi16(w_lo, va);
                let p_hi = _mm_mullo_epi16(w_hi, va);
                for (off, p) in [(0usize, p_lo), (8usize, p_hi)] {
                    // sign-extend i16 → i32: interleave-with-self then
                    // arithmetic-shift the 32-bit lanes right by 16
                    let e_lo = _mm_srai_epi32(_mm_unpacklo_epi16(p, p), 16);
                    let e_hi = _mm_srai_epi32(_mm_unpackhi_epi16(p, p), 16);
                    let a0 = acc.as_mut_ptr().add(j + off) as *mut __m128i;
                    _mm_storeu_si128(a0, _mm_add_epi32(_mm_loadu_si128(a0), e_lo));
                    let a1 = acc.as_mut_ptr().add(j + off + 4) as *mut __m128i;
                    _mm_storeu_si128(a1, _mm_add_epi32(_mm_loadu_si128(a1), e_hi));
                }
                j += 16;
            }
            for jj in j..n {
                *acc.get_unchecked_mut(jj) += a * *row.get_unchecked(jj) as i32;
            }
        }
    }

    #[inline]
    pub fn dot_q_i8(q: &[i32], k: &[i8]) -> i32 {
        // SAFETY: SSE2 baseline; loads stay inside `q`/`k` bounds. The
        // caller guarantees every q value fits i16, so the saturating
        // `_mm_packs_epi32` narrowing is exact.
        unsafe {
            let n = q.len();
            let zero = _mm_setzero_si128();
            let mut accv = zero;
            let mut j = 0;
            while j + 8 <= n {
                let q0 = _mm_loadu_si128(q.as_ptr().add(j) as *const __m128i);
                let q1 = _mm_loadu_si128(q.as_ptr().add(j + 4) as *const __m128i);
                let qv = _mm_packs_epi32(q0, q1);
                let kb = _mm_loadl_epi64(k.as_ptr().add(j) as *const __m128i);
                let kv = _mm_unpacklo_epi8(kb, _mm_cmpgt_epi8(zero, kb));
                // madd: exact i16×i16 products, adjacent pairs summed in i32
                accv = _mm_add_epi32(accv, _mm_madd_epi16(qv, kv));
                j += 8;
            }
            let mut lanes = [0i32; 4];
            _mm_storeu_si128(lanes.as_mut_ptr() as *mut __m128i, accv);
            let mut acc = lanes[0] + lanes[1] + lanes[2] + lanes[3];
            for jj in j..n {
                acc += *q.get_unchecked(jj) * *k.get_unchecked(jj) as i32;
            }
            acc
        }
    }
}

// ---------------------------------------------------------------------------
// aarch64: NEON (baseline — no runtime detection needed)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod arch {
    use std::arch::aarch64::*;

    #[inline]
    pub fn axpy_i8(a: i32, row: &[i8], acc: &mut [i32]) {
        // SAFETY: NEON is a baseline aarch64 target feature; all loads and
        // stores below stay inside `row`/`acc` bounds.
        unsafe {
            let n = row.len();
            let va = vdup_n_s16(a as i16);
            let mut j = 0;
            while j + 8 <= n {
                let w16 = vmovl_s8(vld1_s8(row.as_ptr().add(j)));
                // widening multiply: exact i32 products of i16 lanes
                let p_lo = vmull_s16(vget_low_s16(w16), va);
                let p_hi = vmull_s16(vget_high_s16(w16), va);
                let a0 = vld1q_s32(acc.as_ptr().add(j));
                vst1q_s32(acc.as_mut_ptr().add(j), vaddq_s32(a0, p_lo));
                let a1 = vld1q_s32(acc.as_ptr().add(j + 4));
                vst1q_s32(acc.as_mut_ptr().add(j + 4), vaddq_s32(a1, p_hi));
                j += 8;
            }
            for jj in j..n {
                *acc.get_unchecked_mut(jj) += a * *row.get_unchecked(jj) as i32;
            }
        }
    }

    #[inline]
    pub fn dot_q_i8(q: &[i32], k: &[i8]) -> i32 {
        // SAFETY: NEON baseline; loads stay inside `q`/`k` bounds. The
        // caller guarantees every q value fits i16, so the truncating
        // `vmovn_s32` narrowing is exact.
        unsafe {
            let n = q.len();
            let mut accv = vdupq_n_s32(0);
            let mut j = 0;
            while j + 8 <= n {
                let q0 = vmovn_s32(vld1q_s32(q.as_ptr().add(j)));
                let q1 = vmovn_s32(vld1q_s32(q.as_ptr().add(j + 4)));
                let qv = vcombine_s16(q0, q1);
                let k16 = vmovl_s8(vld1_s8(k.as_ptr().add(j)));
                accv = vmlal_s16(accv, vget_low_s16(qv), vget_low_s16(k16));
                accv = vmlal_s16(accv, vget_high_s16(qv), vget_high_s16(k16));
                j += 8;
            }
            let mut acc = vaddvq_s32(accv);
            for jj in j..n {
                acc += *q.get_unchecked(jj) * *k.get_unchecked(jj) as i32;
            }
            acc
        }
    }
}

// ---------------------------------------------------------------------------
// other targets: the scalar loops under the simd name
// ---------------------------------------------------------------------------

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
mod arch {
    #[inline]
    pub fn axpy_i8(a: i32, row: &[i8], acc: &mut [i32]) {
        for (s, &w) in acc.iter_mut().zip(row) {
            *s += a * w as i32;
        }
    }

    #[inline]
    pub fn dot_q_i8(q: &[i32], k: &[i8]) -> i32 {
        q.iter().zip(k).map(|(&a, &b)| a * b as i32).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn simd_axpy_is_bit_identical_to_scalar() {
        let mut rng = Rng::new(91);
        // lengths straddling every vector-width remainder, plus extremes
        for n in [0usize, 1, 3, 7, 8, 15, 16, 17, 31, 33, 64, 100] {
            for &a in &[1i32, -1, 127, -128, 7, -23] {
                let row: Vec<i8> =
                    (0..n).map(|_| (rng.below(256) as i32 - 128) as i8).collect();
                let mut acc_s: Vec<i32> =
                    (0..n).map(|_| rng.below(1 << 20) as i32 - (1 << 19)).collect();
                let mut acc_v = acc_s.clone();
                ScalarKernel.axpy_i8(a, &row, &mut acc_s);
                SimdKernel.axpy_i8(a, &row, &mut acc_v);
                assert_eq!(acc_s, acc_v, "n={n} a={a}");
            }
        }
    }

    #[test]
    fn simd_dot_is_bit_identical_to_scalar() {
        let mut rng = Rng::new(92);
        for n in [0usize, 1, 5, 8, 9, 16, 24, 31, 40] {
            let q: Vec<i32> = (0..n)
                .map(|i| match i % 5 {
                    // exercise the full i16 envelope the narrowing must keep
                    0 => i16::MAX as i32,
                    1 => i16::MIN as i32,
                    _ => rng.below(1 << 16) as i32 - (1 << 15),
                })
                .collect();
            let k: Vec<i8> = (0..n).map(|_| (rng.below(256) as i32 - 128) as i8).collect();
            assert_eq!(
                ScalarKernel.dot_q_i8(&q, &k),
                SimdKernel.dot_q_i8(&q, &k),
                "n={n}"
            );
        }
    }

    #[test]
    fn kernel_choice_parses_and_dispatches() {
        assert_eq!(KernelChoice::parse("scalar").unwrap(), KernelChoice::Scalar);
        assert_eq!(KernelChoice::parse("simd").unwrap(), KernelChoice::Simd);
        assert!(KernelChoice::parse("avx512").is_err());
        // selection is process-global; restore the default afterwards so
        // sibling tests see the shipped configuration
        set_kernel(KernelChoice::Scalar);
        assert_eq!(active_name(), "scalar");
        set_kernel(KernelChoice::Simd);
        assert_eq!(active_name(), SimdKernel.name());
    }
}
