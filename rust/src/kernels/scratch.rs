//! `DecodeScratch` — the zero-alloc working set of one decode step.
//!
//! Every intermediate of `HostModel::forward_token_into` (normed rows,
//! attention inputs, quantized rows and their steps, scores, the f32
//! fallback dequant buffers, the logits) lives here, sized once from the
//! model config. A serve lane or an eval decode session carries one and
//! reuses it every step, so the steady-state decode loop performs **no
//! heap allocation** — `tests/kernels_zero_alloc.rs` pins this with a
//! counting global allocator.

use crate::hostmodel::HostCfg;

/// Pre-sized buffers for one incremental decode step. Buffers are sized
/// for the *largest* site they serve (e.g. `xq` covers both `d_model` and
/// `d_ff` rows), so one scratch serves every layer and the head.
pub struct DecodeScratch {
    /// residual stream `[d_model]`
    pub x: Vec<f32>,
    /// normed row `[d_model]` (reused for `h2` and the final `hf`)
    pub hnorm: Vec<f32>,
    /// attention query row `[d_model]`
    pub q: Vec<f32>,
    /// attention key row `[d_model]`
    pub k: Vec<f32>,
    /// attention value row `[d_model]`
    pub v: Vec<f32>,
    /// attention context `[d_model]`
    pub ctx: Vec<f32>,
    /// projection output row `[d_model]` (`wo` and `wd` results)
    pub o: Vec<f32>,
    /// FFN gate row `[d_ff]` (reused for the gated product `a`)
    pub g: Vec<f32>,
    /// FFN up row `[d_ff]`
    pub u: Vec<f32>,
    /// quantized activation row `[max(d_model, d_ff)]`
    pub xq: Vec<i8>,
    /// activation row steps (one per quant group; `[n_heads]` covers all)
    pub xs: Vec<f32>,
    /// quantized query row `[d_model]` (i32: the query is 16-bit)
    pub qq: Vec<i32>,
    /// per-head query steps `[n_heads]`
    pub qs: Vec<f32>,
    /// integer GEMV accumulator `[max(d_model, d_ff, vocab)]`
    pub acc: Vec<i32>,
    /// attention scores `[seq_len]`
    pub scores: Vec<f32>,
    /// f32 K dequant buffer `[seq_len · d_model]` (fallback path only)
    pub kc: Vec<f32>,
    /// f32 V dequant buffer `[seq_len · d_model]` (fallback path only)
    pub vc: Vec<f32>,
    /// next-token logits `[vocab]`
    pub logits: Vec<f32>,
}

impl DecodeScratch {
    /// Size every buffer for `cfg` (the only allocations the decode path
    /// ever makes).
    pub fn for_cfg(cfg: &HostCfg) -> DecodeScratch {
        let (d, f, v) = (cfg.d_model, cfg.d_ff, cfg.vocab);
        let wide = d.max(f);
        DecodeScratch {
            x: vec![0.0; d],
            hnorm: vec![0.0; d],
            q: vec![0.0; d],
            k: vec![0.0; d],
            v: vec![0.0; d],
            ctx: vec![0.0; d],
            o: vec![0.0; d],
            g: vec![0.0; f],
            u: vec![0.0; f],
            xq: vec![0; wide],
            xs: vec![0.0; cfg.n_heads.max(1)],
            qq: vec![0; d],
            qs: vec![0.0; cfg.n_heads.max(1)],
            acc: vec![0; wide.max(v)],
            scores: vec![0.0; cfg.seq_len],
            kc: vec![0.0; cfg.seq_len * d],
            vc: vec![0.0; cfg.seq_len * d],
            logits: vec![0.0; v],
        }
    }

    /// Assert this scratch fits `cfg` (a scratch built for a different
    /// model is a programming error, caught before any buffer indexing).
    pub fn check(&self, cfg: &HostCfg) {
        let (d, f, v) = (cfg.d_model, cfg.d_ff, cfg.vocab);
        assert!(
            self.x.len() >= d
                && self.g.len() >= f
                && self.xq.len() >= d.max(f)
                && self.acc.len() >= d.max(f).max(v)
                && self.qs.len() >= cfg.n_heads
                && self.scores.len() >= cfg.seq_len
                && self.kc.len() >= cfg.seq_len * d
                && self.logits.len() >= v,
            "DecodeScratch was sized for a different model"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostmodel::tiny_host_cfg;

    #[test]
    fn scratch_fits_its_own_cfg() {
        let cfg = tiny_host_cfg(true, true);
        let s = DecodeScratch::for_cfg(&cfg);
        s.check(&cfg);
        assert_eq!(s.logits.len(), cfg.vocab);
        assert_eq!(s.kc.len(), cfg.seq_len * cfg.d_model);
    }

    #[test]
    #[should_panic(expected = "different model")]
    fn scratch_rejects_a_bigger_model() {
        let cfg = tiny_host_cfg(true, true);
        let mut big = cfg.clone();
        big.d_model *= 2;
        big.d_ff *= 2;
        DecodeScratch::for_cfg(&cfg).check(&big);
    }
}
