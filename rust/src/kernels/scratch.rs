//! `DecodeScratch` / `BatchScratch` — the zero-alloc working sets of one
//! decode step.
//!
//! Every intermediate of `HostModel::forward_token_into` (normed rows,
//! attention inputs, quantized rows and their steps, scores, the f32
//! fallback dequant buffers, the logits) lives in a [`DecodeScratch`],
//! sized once from the model config. A serve lane or an eval decode
//! session carries one and reuses it every step, so the steady-state
//! decode loop performs **no heap allocation** —
//! `tests/kernels_zero_alloc.rs` pins this with a counting global
//! allocator.
//!
//! [`BatchScratch`] is the cross-lane twin: the same buffers widened to
//! `rows` stacked lanes, feeding `HostModel::forward_tokens_batch` (one
//! fused GEMM per weight matrix across every live serve lane). Attention
//! stays per lane, but each lane owns its **own** score row (`scores` is
//! `[rows · seq_len]`) so the integer attention phase can fan whole lanes
//! across the worker pool; only the f32-fallback dequant buffers keep a
//! single lane's shape (that path runs sequentially — its accumulation
//! order must match the per-lane reference exactly).

use crate::hostmodel::HostCfg;
use crate::kernels::GEMM_BLOCK;

/// Pre-sized buffers for one incremental decode step. Buffers are sized
/// for the *largest* site they serve (e.g. `xq` covers both `d_model` and
/// `d_ff` rows), so one scratch serves every layer and the head.
pub struct DecodeScratch {
    /// residual stream `[d_model]`
    pub x: Vec<f32>,
    /// normed row `[d_model]` (reused for `h2` and the final `hf`)
    pub hnorm: Vec<f32>,
    /// attention query row `[d_model]`
    pub q: Vec<f32>,
    /// attention key row `[d_model]`
    pub k: Vec<f32>,
    /// attention value row `[d_model]`
    pub v: Vec<f32>,
    /// attention context `[d_model]`
    pub ctx: Vec<f32>,
    /// projection output row `[d_model]` (`wo` and `wd` results)
    pub o: Vec<f32>,
    /// FFN gate row `[d_ff]` (reused for the gated product `a`)
    pub g: Vec<f32>,
    /// FFN up row `[d_ff]`
    pub u: Vec<f32>,
    /// quantized activation row `[max(d_model, d_ff)]`
    pub xq: Vec<i8>,
    /// activation row steps (one per quant group; `[n_heads]` covers all)
    pub xs: Vec<f32>,
    /// quantized query row `[d_model]` (i32: the query is 16-bit)
    pub qq: Vec<i32>,
    /// per-head query steps `[n_heads]`
    pub qs: Vec<f32>,
    /// integer GEMV accumulator `[max(d_model, d_ff, vocab)]`
    pub acc: Vec<i32>,
    /// attention scores `[seq_len]`
    pub scores: Vec<f32>,
    /// f32 K dequant buffer `[seq_len · d_model]` (fallback path only)
    pub kc: Vec<f32>,
    /// f32 V dequant buffer `[seq_len · d_model]` (fallback path only)
    pub vc: Vec<f32>,
    /// next-token logits `[vocab]`
    pub logits: Vec<f32>,
}

impl DecodeScratch {
    /// Size every buffer for `cfg` (the only allocations the decode path
    /// ever makes).
    pub fn for_cfg(cfg: &HostCfg) -> DecodeScratch {
        let (d, f, v) = (cfg.d_model, cfg.d_ff, cfg.vocab);
        let wide = d.max(f);
        DecodeScratch {
            x: vec![0.0; d],
            hnorm: vec![0.0; d],
            q: vec![0.0; d],
            k: vec![0.0; d],
            v: vec![0.0; d],
            ctx: vec![0.0; d],
            o: vec![0.0; d],
            g: vec![0.0; f],
            u: vec![0.0; f],
            xq: vec![0; wide],
            xs: vec![0.0; cfg.n_heads.max(1)],
            qq: vec![0; d],
            qs: vec![0.0; cfg.n_heads.max(1)],
            acc: vec![0; wide.max(v)],
            scores: vec![0.0; cfg.seq_len],
            kc: vec![0.0; cfg.seq_len * d],
            vc: vec![0.0; cfg.seq_len * d],
            logits: vec![0.0; v],
        }
    }

    /// Assert this scratch fits `cfg` (a scratch built for a different
    /// model is a programming error, caught before any buffer indexing).
    pub fn check(&self, cfg: &HostCfg) {
        let (d, f, v) = (cfg.d_model, cfg.d_ff, cfg.vocab);
        assert!(
            self.x.len() >= d
                && self.g.len() >= f
                && self.xq.len() >= d.max(f)
                && self.acc.len() >= d.max(f).max(v)
                && self.qs.len() >= cfg.n_heads
                && self.scores.len() >= cfg.seq_len
                && self.kc.len() >= cfg.seq_len * d
                && self.logits.len() >= v,
            "DecodeScratch was sized for a different model"
        );
    }
}

/// Pre-sized buffers for one **cross-lane batched** decode step: up to
/// `rows` lanes advance together, each intermediate stacked row-major
/// `[rows, dim]`. The linear layers run one fused GEMM per matrix over
/// the stack; attention runs per lane (each lane owns its own KV slab)
/// with a private score row per lane so lanes can run in parallel; only
/// the f32-fallback `kc`/`vc` dequant buffers are single-lane (that path
/// stays sequential).
pub struct BatchScratch {
    /// lanes this scratch was sized for
    pub rows: usize,
    /// residual stream `[rows * d_model]`
    pub x: Vec<f32>,
    /// normed rows `[rows * d_model]`
    pub hnorm: Vec<f32>,
    /// query rows `[rows * d_model]`
    pub q: Vec<f32>,
    /// key rows `[rows * d_model]`
    pub k: Vec<f32>,
    /// value rows `[rows * d_model]`
    pub v: Vec<f32>,
    /// attention contexts `[rows * d_model]`
    pub ctx: Vec<f32>,
    /// projection outputs `[rows * d_model]`
    pub o: Vec<f32>,
    /// FFN gate rows `[rows * d_ff]` (reused for the gated product)
    pub g: Vec<f32>,
    /// FFN up rows `[rows * d_ff]`
    pub u: Vec<f32>,
    /// quantized activation rows `[rows * max(d_model, d_ff)]`
    pub xq: Vec<i8>,
    /// one activation step per lane row `[rows]`
    pub sx: Vec<f32>,
    /// quantized query rows `[rows * d_model]` (i32: the query is 16-bit)
    pub qq: Vec<i32>,
    /// per-(lane, head) query steps `[rows * n_heads]`
    pub qs: Vec<f32>,
    /// blocked-GEMM accumulator `[GEMM_BLOCK * max(d_model, d_ff, vocab)]`
    pub acc: Vec<i32>,
    /// attention scores `[rows * seq_len]` — one private row per lane so
    /// the attention phase can shard by lane
    pub scores: Vec<f32>,
    /// f32 K dequant buffer `[seq_len · d_model]` (fallback path, per lane)
    pub kc: Vec<f32>,
    /// f32 V dequant buffer `[seq_len · d_model]` (fallback path, per lane)
    pub vc: Vec<f32>,
    /// next-token logits `[rows * vocab]`
    pub logits: Vec<f32>,
}

impl BatchScratch {
    /// Size every buffer for up to `rows` lanes of `cfg` (the only
    /// allocations the batched decode path ever makes).
    pub fn for_cfg(cfg: &HostCfg, rows: usize) -> BatchScratch {
        let (d, f, v) = (cfg.d_model, cfg.d_ff, cfg.vocab);
        let wide = d.max(f);
        let rows = rows.max(1);
        BatchScratch {
            rows,
            x: vec![0.0; rows * d],
            hnorm: vec![0.0; rows * d],
            q: vec![0.0; rows * d],
            k: vec![0.0; rows * d],
            v: vec![0.0; rows * d],
            ctx: vec![0.0; rows * d],
            o: vec![0.0; rows * d],
            g: vec![0.0; rows * f],
            u: vec![0.0; rows * f],
            xq: vec![0; rows * wide],
            sx: vec![0.0; rows],
            qq: vec![0; rows * d],
            qs: vec![0.0; rows * cfg.n_heads.max(1)],
            acc: vec![0; GEMM_BLOCK * wide.max(v)],
            scores: vec![0.0; rows * cfg.seq_len],
            kc: vec![0.0; cfg.seq_len * d],
            vc: vec![0.0; cfg.seq_len * d],
            logits: vec![0.0; rows * v],
        }
    }

    /// Assert this scratch holds `b` lanes of `cfg` (a scratch sized for a
    /// different model, or stepped with more lanes than it was built for,
    /// is a programming error caught before any buffer indexing).
    pub fn check(&self, cfg: &HostCfg, b: usize) {
        let (d, f, v) = (cfg.d_model, cfg.d_ff, cfg.vocab);
        assert!(
            b <= self.rows
                && self.x.len() >= b * d
                && self.g.len() >= b * f
                && self.xq.len() >= b * d.max(f)
                && self.acc.len() >= GEMM_BLOCK * d.max(f).max(v)
                && self.qs.len() >= b * cfg.n_heads
                && self.scores.len() >= b * cfg.seq_len
                && self.kc.len() >= cfg.seq_len * d
                && self.logits.len() >= b * v,
            "BatchScratch was sized for a different model or fewer lanes"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostmodel::tiny_host_cfg;

    #[test]
    fn scratch_fits_its_own_cfg() {
        let cfg = tiny_host_cfg(true, true);
        let s = DecodeScratch::for_cfg(&cfg);
        s.check(&cfg);
        assert_eq!(s.logits.len(), cfg.vocab);
        assert_eq!(s.kc.len(), cfg.seq_len * cfg.d_model);
    }

    #[test]
    #[should_panic(expected = "different model")]
    fn scratch_rejects_a_bigger_model() {
        let cfg = tiny_host_cfg(true, true);
        let mut big = cfg.clone();
        big.d_model *= 2;
        big.d_ff *= 2;
        DecodeScratch::for_cfg(&cfg).check(&big);
    }

    #[test]
    fn batch_scratch_fits_its_lane_count() {
        let cfg = tiny_host_cfg(true, true);
        let s = BatchScratch::for_cfg(&cfg, 4);
        s.check(&cfg, 4);
        s.check(&cfg, 1);
        assert_eq!(s.logits.len(), 4 * cfg.vocab);
        assert_eq!(s.sx.len(), 4);
        assert_eq!(s.scores.len(), 4 * cfg.seq_len, "one score row per lane");
        assert!(s.acc.len() >= GEMM_BLOCK * cfg.vocab);
    }

    #[test]
    #[should_panic(expected = "fewer lanes")]
    fn batch_scratch_rejects_more_lanes_than_sized() {
        let cfg = tiny_host_cfg(true, true);
        BatchScratch::for_cfg(&cfg, 2).check(&cfg, 3);
    }
}
