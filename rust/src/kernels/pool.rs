//! `pool` — a persistent worker pool for sharded integer kernels.
//!
//! Workers are spawned **once** ([`configure`]) and reused by every kernel
//! call; the hot path never spawns a thread. A job is a `Fn(usize)` shard
//! closure: [`run`]`(shards, job)` publishes it, the caller and every
//! worker claim shard indices from a shared atomic cursor, and `run`
//! returns only when all shards finished **and every worker is quiescent
//! again** (the per-worker ack protocol below), so the borrowed closure
//! never outlives the call.
//!
//! ### Determinism
//! Sharding never changes results: shards own **disjoint** output ranges
//! (output channels for GEMV/GEMM, lanes for the batched forward) and the
//! accumulation inside one output channel is exact `i32` arithmetic fully
//! contained in one shard. Which thread runs a shard is scheduling, not
//! math — the identity pins (int≡reference, batched≡sequential,
//! parallel≡scalar) hold bit-exact at any thread count.
//!
//! ### Steady-state allocation
//! Publishing a job is lock + atomics + park/unpark — no allocation — so
//! the `kernels_zero_alloc` pins hold with the pool active. Spawning and
//! the one-time warm-up job happen inside [`configure`], outside any
//! measured window.
//!
//! ### Concurrency protocol
//! One job runs at a time (the global pool mutex is held for the whole
//! call — concurrent `run`s serialize). Publication: store the erased
//! closure pointer and shard/cursor state, then bump `generation`
//! (Release) and unpark. Workers sleep on `generation` (spin-then-park),
//! and on a new value: read the closure under the job lock, claim shards
//! until the cursor runs out, then store the generation into their `ack`
//! slot (Release) and go back to waiting — a worker only ever touches the
//! cursor **between observing a new generation and acking it**, and the
//! caller only mutates job state while no `run` is in flight, so a
//! straggler can never claim into the next job's cursor. The caller claims
//! shards too, then waits for every ack; acks (Acquire) also publish the
//! workers' shard writes back to the caller.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::obs;

/// Minimum i8 MACs (or comparable work units) per shard — below this,
/// fan-out overhead beats the win and the call stays serial.
pub const MIN_WORK_PER_SHARD: usize = 16 * 1024;

/// Spins before a worker parks (jobs arrive back-to-back during decode,
/// so the common wake is a spin hit, not a futex round-trip).
const SPIN_LIMIT: u32 = 1 << 14;

thread_local! {
    /// Set while this thread executes pool shards. Nested [`run`] calls
    /// from inside a shard go serial inline — no re-entry on the pool
    /// mutex, no deadlock. Const-init so the first check in a zero-alloc
    /// window doesn't lazily allocate TLS.
    static IN_POOL_JOB: Cell<bool> = const { Cell::new(false) };
}

/// Erased shard closure; only dereferenced while the owning [`run`] call
/// blocks on completion, which keeps the borrow alive.
struct JobPtr(*const (dyn Fn(usize) + Sync));
unsafe impl Send for JobPtr {}

struct Shared {
    /// Bumped once per published job (workers sleep on this).
    generation: AtomicU64,
    /// Shard count of the current job.
    shards: AtomicUsize,
    /// Next shard index to claim.
    next: AtomicUsize,
    /// The current job; `None` between jobs.
    job: Mutex<Option<JobPtr>>,
    /// One worker ack slot per worker: the last generation it finished.
    acks: Vec<AtomicU64>,
    /// A shard panicked; re-raised on the caller after quiescence.
    panicked: AtomicBool,
    /// Workers exit on the next wake.
    shutdown: AtomicBool,
}

struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

/// Configured thread count (1 = serial). Read lock-free on the hot path.
static ACTIVE: AtomicUsize = AtomicUsize::new(1);

/// The pool itself; the mutex doubles as the one-job-at-a-time lock.
static POOL: Mutex<Option<WorkerPool>> = Mutex::new(None);

fn lock_pool() -> std::sync::MutexGuard<'static, Option<WorkerPool>> {
    // A panicking job poisons this mutex by design (the panic is re-raised
    // inside `run`); the state it guards stays consistent, so keep going.
    POOL.lock().unwrap_or_else(|e| e.into_inner())
}

/// Claim and execute shards of the current job until the cursor runs out.
fn claim_shards(shared: &Shared, job: *const (dyn Fn(usize) + Sync)) {
    let total = shared.shards.load(Ordering::Acquire);
    loop {
        let i = shared.next.fetch_add(1, Ordering::Relaxed);
        if i >= total {
            break;
        }
        // Isolate each shard so one panicking shard can't unwind through
        // a worker (or past the caller while workers still run).
        if catch_unwind(AssertUnwindSafe(|| unsafe { (*job)(i) })).is_err() {
            shared.panicked.store(true, Ordering::Release);
        }
    }
}

fn worker_loop(shared: Arc<Shared>, me: usize) {
    // Workers only ever run shard bodies — a nested `run` from inside a
    // shard must go serial on this thread.
    IN_POOL_JOB.with(|f| f.set(true));
    let mut seen = 0u64;
    loop {
        // Wait for a new generation: spin briefly, then park.
        let mut spins = 0u32;
        let gen = loop {
            let g = shared.generation.load(Ordering::Acquire);
            if g != seen {
                break g;
            }
            if shared.shutdown.load(Ordering::Acquire) {
                return;
            }
            if spins < SPIN_LIMIT {
                spins += 1;
                std::hint::spin_loop();
            } else {
                std::thread::park();
            }
        };
        seen = gen;
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        let job = shared
            .job
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .as_ref()
            .map(|p| p.0);
        if let Some(job) = job {
            claim_shards(&shared, job);
        }
        // Ack even when the job was already gone: the caller waits for
        // every worker to reach this line before reusing the cursor.
        shared.acks[me].store(gen, Ordering::Release);
    }
}

impl WorkerPool {
    fn spawn(workers: usize) -> WorkerPool {
        let shared = Arc::new(Shared {
            generation: AtomicU64::new(0),
            shards: AtomicUsize::new(0),
            next: AtomicUsize::new(0),
            job: Mutex::new(None),
            acks: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            panicked: AtomicBool::new(false),
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..workers)
            .map(|me| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("silq-pool-{me}"))
                    .spawn(move || worker_loop(shared, me))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, handles }
    }

    /// Publish `job`, participate, and block until all shards ran and
    /// every worker acked. Caller must hold the `POOL` lock.
    fn run(&self, shards: usize, job: &(dyn Fn(usize) + Sync)) {
        let s = &*self.shared;
        // Erase the borrow: the pointer is only dereferenced before the
        // ack wait below completes, while `job` is still live.
        let ptr: *const (dyn Fn(usize) + Sync) = job;
        *s.job.lock().unwrap_or_else(|e| e.into_inner()) = Some(JobPtr(ptr));
        s.shards.store(shards, Ordering::Relaxed);
        s.next.store(0, Ordering::Relaxed);
        let gen = s.generation.load(Ordering::Relaxed) + 1;
        s.generation.store(gen, Ordering::Release);
        for h in &self.handles {
            h.thread().unpark();
        }
        // The caller is a full participant.
        IN_POOL_JOB.with(|f| f.set(true));
        claim_shards(s, ptr);
        IN_POOL_JOB.with(|f| f.set(false));
        // Quiescence barrier: every worker back in its wait loop. Workers
        // that raced past the claim cursor still ack, and all shard writes
        // are published by these Acquire loads.
        for ack in &s.acks {
            let mut spins = 0u32;
            while ack.load(Ordering::Acquire) != gen {
                spins += 1;
                if spins < SPIN_LIMIT {
                    std::hint::spin_loop();
                } else {
                    std::thread::yield_now();
                }
            }
        }
        *s.job.lock().unwrap_or_else(|e| e.into_inner()) = None;
        if s.panicked.swap(false, Ordering::AcqRel) {
            panic!("worker pool: a kernel shard panicked");
        }
    }

    fn shutdown_and_join(self) {
        self.shared.shutdown.store(true, Ordering::Release);
        // Bump generation so spinning workers notice without a park wake.
        self.shared.generation.fetch_add(1, Ordering::Release);
        for h in &self.handles {
            h.thread().unpark();
        }
        for h in self.handles {
            let _ = h.join();
        }
    }
}

/// Set the execution width: `threads` total participants (the caller
/// counts as one, so `threads - 1` workers are kept). `1` (the library
/// default) is pure serial — no pool, no atomics beyond one load per
/// kernel call. Re-configuring with the same count is a no-op; changing
/// it joins the old workers and spawns fresh ones, then runs a warm-up
/// job so lazy thread state is faulted in before any measured
/// (zero-alloc) window.
pub fn configure(threads: usize) {
    let threads = threads.max(1);
    let mut guard = lock_pool();
    let current = ACTIVE.load(Ordering::Relaxed);
    if current == threads {
        return;
    }
    if let Some(pool) = guard.take() {
        pool.shutdown_and_join();
    }
    if threads > 1 {
        let pool = WorkerPool::spawn(threads - 1);
        pool.run(threads * 2, &|_shard| {});
        *guard = Some(pool);
    }
    ACTIVE.store(threads, Ordering::Relaxed);
}

/// Join all workers and return to serial execution ([`configure`]`(1)`).
pub fn shutdown() {
    configure(1);
}

/// Configured execution width (1 = serial).
pub fn active_threads() -> usize {
    ACTIVE.load(Ordering::Relaxed)
}

/// Live worker threads (0 when serial — `active_threads() - 1` otherwise).
pub fn worker_count() -> usize {
    lock_pool().as_ref().map_or(0, |p| p.handles.len())
}

/// `SILQ_THREADS` from the environment, if set to a positive integer.
pub fn env_threads() -> Option<usize> {
    std::env::var("SILQ_THREADS")
        .ok()?
        .trim()
        .parse::<usize>()
        .ok()
        .filter(|&n| n >= 1)
}

/// How many shards to cut `units` independent output units (channels,
/// lanes) into, given `work` total MACs: never more than the configured
/// threads, never more than the units, and never so many that a shard
/// falls under [`MIN_WORK_PER_SHARD`].
pub fn shard_count(work: usize, units: usize) -> usize {
    let t = active_threads();
    if t <= 1 || units <= 1 {
        return 1;
    }
    t.min(work / MIN_WORK_PER_SHARD).min(units).max(1)
}

/// Shard `s` of `shards` over `[0, n)`: the half-open range
/// `[s·n/shards, (s+1)·n/shards)` — contiguous, disjoint, exhaustive, and
/// a pure function of `(n, shards, s)` so partitioning is deterministic.
pub fn shard_range(n: usize, shards: usize, s: usize) -> (usize, usize) {
    (n * s / shards, n * (s + 1) / shards)
}

/// Run `job(0..shards)` across the pool. Serial inline when the pool is
/// off, the job is single-shard, or we're already inside a shard (nested
/// calls must not re-enter the pool lock). Counts one `pool_jobs` /
/// `shards` `pool_shards` per actually-fanned-out job and wraps it in a
/// `pool_job` span.
pub fn run(shards: usize, job: &(dyn Fn(usize) + Sync)) {
    // fault hook (`lat@N:MS`): a planned hit stalls this job before it
    // runs — serial fast path included, so the step watchdog sees the
    // same stall at any SILQ_THREADS. One relaxed load when disarmed.
    if crate::faults::should_inject(crate::faults::Site::Shard) {
        std::thread::sleep(std::time::Duration::from_millis(crate::faults::latency_ms(
            crate::faults::Site::Shard,
        )));
    }
    if shards <= 1 || active_threads() <= 1 || IN_POOL_JOB.with(|f| f.get()) {
        for i in 0..shards {
            job(i);
        }
        return;
    }
    let guard = lock_pool();
    let Some(pool) = guard.as_ref() else {
        // configured serial between our fast-path check and the lock
        drop(guard);
        for i in 0..shards {
            job(i);
        }
        return;
    };
    obs::add(obs::Counter::PoolJobs, 1);
    obs::add(obs::Counter::PoolShards, shards as u64);
    let _span = obs::span("pool_job", "kernels", 0, shards as u64);
    pool.run(shards, job);
}

/// A raw pointer that crosses the shard boundary. Safety contract: every
/// shard derives **disjoint** slices from it (disjointness comes from
/// [`shard_range`]), and the pool's ack barrier keeps all derived
/// references inside the `run` call's lifetime.
pub(crate) struct SendPtr<T>(pub *mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Serializes in-crate tests that reconfigure the global pool (the
/// configuration is process-wide; results are bit-identical at any width,
/// but tests asserting on `active_threads`/`shard_count` need a stable
/// configuration while they run).
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Restore the library-default serial configuration on drop so these
    /// global-state tests don't leak a pool into sibling tests (kernels
    /// stay bit-identical either way; this is about tidiness, not
    /// correctness).
    struct SerialAfter;
    impl Drop for SerialAfter {
        fn drop(&mut self) {
            shutdown();
        }
    }

    #[test]
    fn sharded_fill_covers_every_index_once_at_any_width() {
        let _g = test_guard();
        let _restore = SerialAfter;
        for threads in [1usize, 2, 4, 7] {
            configure(threads);
            let n = 1013; // prime: ragged shard boundaries
            let mut hits = vec![0u32; n];
            let shards = threads.min(n);
            let p = SendPtr(hits.as_mut_ptr());
            run(shards, &|s| {
                let (lo, hi) = shard_range(n, shards, s);
                let mine =
                    unsafe { std::slice::from_raw_parts_mut(p.0.add(lo), hi - lo) };
                for (k, h) in mine.iter_mut().enumerate() {
                    *h += (lo + k) as u32 + 1;
                }
            });
            for (i, &h) in hits.iter().enumerate() {
                assert_eq!(h, i as u32 + 1, "threads={threads} index {i}");
            }
        }
    }

    #[test]
    fn shard_range_partitions_exactly() {
        for n in [0usize, 1, 5, 64, 1013] {
            for shards in [1usize, 2, 3, 7, 16] {
                let mut prev = 0;
                for s in 0..shards {
                    let (lo, hi) = shard_range(n, shards, s);
                    assert_eq!(lo, prev);
                    assert!(hi >= lo);
                    prev = hi;
                }
                assert_eq!(prev, n);
            }
        }
    }

    #[test]
    fn nested_run_goes_serial_inline() {
        let _g = test_guard();
        let _restore = SerialAfter;
        configure(4);
        let flags: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        run(4, &|s| {
            // a kernel called from inside a shard fans out serially
            run(2, &|_inner| {
                flags[s].fetch_add(1, Ordering::Relaxed);
            });
        });
        for f in &flags {
            assert_eq!(f.load(Ordering::Relaxed), 2);
        }
    }

    #[test]
    fn panicking_shard_panics_caller_and_pool_survives() {
        let _g = test_guard();
        let _restore = SerialAfter;
        configure(4);
        let r = std::panic::catch_unwind(|| {
            run(4, &|s| {
                if s == 2 {
                    panic!("boom");
                }
            });
        });
        assert!(r.is_err(), "shard panic must reach the caller");
        // the pool still works after a panicked job
        let total = AtomicUsize::new(0);
        run(8, &|_s| {
            total.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn configure_and_shutdown_manage_workers() {
        let _g = test_guard();
        let _restore = SerialAfter;
        configure(4);
        assert_eq!(active_threads(), 4);
        assert_eq!(worker_count(), 3);
        configure(4); // no-op
        assert_eq!(worker_count(), 3);
        shutdown();
        assert_eq!(active_threads(), 1);
        assert_eq!(worker_count(), 0);
    }

    #[test]
    fn shard_count_respects_floor_and_units() {
        let _g = test_guard();
        let _restore = SerialAfter;
        configure(4);
        // tiny work stays serial
        assert_eq!(shard_count(100, 64), 1);
        // plentiful work uses every thread
        assert_eq!(shard_count(MIN_WORK_PER_SHARD * 64, 64), 4);
        // never more shards than independent units
        assert_eq!(shard_count(MIN_WORK_PER_SHARD * 64, 2), 2);
        shutdown();
        assert_eq!(shard_count(MIN_WORK_PER_SHARD * 64, 64), 1);
    }
}
