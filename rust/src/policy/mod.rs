//! `policy` — the typed quantization-policy API.
//!
//! SiLQ's central claim is that **one** simple recipe (which tensors are
//! quantized, to how many bits, with which step rule) covers weights,
//! activations and cache across model variants. This module makes that
//! recipe a first-class value instead of a spray of loose `bits: u32`
//! parameters, calib strings and ad-hoc CLI matches:
//!
//! * [`TensorPolicy`] — one tensor class's scheme: bit width,
//!   [`Granularity`] (per-tensor / per-channel / per-token),
//!   [`QuantMode`] (static calibrated steps vs dynamic per-write steps)
//!   and [`CalibMethod`] (how static steps are initialized).
//! * [`QuantPolicy`] — the five slots the paper's Figure 2 places
//!   (`weights`, `acts`, `cache`, `head`, `query`) plus the
//!   online-rotation ablation flag.
//!
//! A [`QuantPolicy`] round-trips through a compact **spec string**
//! (`Display`/`FromStr`):
//!
//! ```text
//! spec := "fp16" | core [":" mod ("," mod)*]
//! core := "w" BITS "a" BITS "kv" BITS          (weights / acts / KV cache)
//! mod  := "statacts" | "dynacts"               (activation step mode)
//!       | "h" BITS                             (head bits, default 8)
//!       | "q" BITS                             (query bits, default 16)
//!       | "rot"                                (online-rotation ablation)
//!       | "acal=" ("quantile" | "max")         (activation calibration)
//!       | "wcal=" ("mse" | "lsq")              (weight calibration)
//! ```
//!
//! `w4a8kv8` is the paper's main recipe; `w4a8kv8:statacts` its
//! base-model variant; `fp16` the unquantized baseline. [`PRESETS`] names
//! the ablation-table configurations and maps them onto the manifest
//! precision names (`a8d-c8-w4`, ...), which [`QuantPolicy::resolve`]
//! also parses directly — so every entry point (`--prec` on
//! `eval`/`qat`/`serve`, `silq prec`, the manifest, the hostmodel
//! builtins) speaks one currency.
//!
//! Conversions: [`QuantPolicy::from_prec`] / [`QuantPolicy::to_prec`]
//! bridge to the manifest's [`PrecCfg`] losslessly (the manifest carries
//! no calibration choice, so calib defaults survive one direction only).

use anyhow::{anyhow, bail, ensure, Context, Result};
use std::fmt;
use std::str::FromStr;

use crate::config::PrecCfg;

/// Step-size granularity of one tensor class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    /// One step per tensor (per layer) — static activation sites.
    PerTensor,
    /// One step per output channel — weights and the head.
    PerChannel,
    /// One step per token row (per head sub-row for cache/query) computed
    /// at run time — the dynamic ('d') activation mode.
    PerToken,
}

impl fmt::Display for Granularity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Granularity::PerTensor => "per-tensor",
            Granularity::PerChannel => "per-channel",
            Granularity::PerToken => "per-token",
        })
    }
}

/// When step sizes are decided.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantMode {
    /// Steps are calibrated offline (and learned during QAT).
    Static,
    /// Steps are recomputed from each value row at run time.
    Dynamic,
}

impl fmt::Display for QuantMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            QuantMode::Static => "static",
            QuantMode::Dynamic => "dynamic",
        })
    }
}

/// How static steps are initialized from calibration statistics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CalibMethod {
    /// Percentile rule for activations (paper section 3.1).
    Quantile,
    /// Plain max-abs for activations (Table 4 ablation).
    Max,
    /// Convex-MSE search for weights (paper Eq. 2).
    Mse,
    /// LSQ-paper initialization for weights (Table 4 ablation).
    Lsq,
}

impl CalibMethod {
    /// Parse an activation-side calibration name.
    pub fn parse_act(s: &str) -> Result<CalibMethod> {
        match s {
            "quantile" => Ok(CalibMethod::Quantile),
            "max" => Ok(CalibMethod::Max),
            other => bail!("unknown activation calibration {other:?} (quantile|max)"),
        }
    }

    /// Parse a weight-side calibration name.
    pub fn parse_weight(s: &str) -> Result<CalibMethod> {
        match s {
            "mse" => Ok(CalibMethod::Mse),
            "lsq" => Ok(CalibMethod::Lsq),
            other => bail!("unknown weight calibration {other:?} (mse|lsq)"),
        }
    }
}

impl fmt::Display for CalibMethod {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            CalibMethod::Quantile => "quantile",
            CalibMethod::Max => "max",
            CalibMethod::Mse => "mse",
            CalibMethod::Lsq => "lsq",
        })
    }
}

/// The quantization scheme of one tensor class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TensorPolicy {
    pub bits: u32,
    pub granularity: Granularity,
    pub mode: QuantMode,
    pub calib: CalibMethod,
}

impl TensorPolicy {
    /// A weight-class slot: per-output-channel static steps.
    pub const fn weight(bits: u32, calib: CalibMethod) -> TensorPolicy {
        TensorPolicy { bits, granularity: Granularity::PerChannel, mode: QuantMode::Static, calib }
    }

    /// An activation-class slot; granularity follows the mode (dynamic
    /// steps are per token row, static steps are per tensor).
    pub const fn act(bits: u32, mode: QuantMode, calib: CalibMethod) -> TensorPolicy {
        let granularity = match mode {
            QuantMode::Dynamic => Granularity::PerToken,
            QuantMode::Static => Granularity::PerTensor,
        };
        TensorPolicy { bits, granularity, mode, calib }
    }
}

/// The full precision policy: one [`TensorPolicy`] per Figure-2 slot.
///
/// `quantized == false` is the fp16 baseline; the slots then keep their
/// default values so conversion with the manifest's [`PrecCfg`] (which
/// carries default bit fields even for fp16) stays lossless.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct QuantPolicy {
    pub quantized: bool,
    /// Linear-layer weights (per-output-channel).
    pub weights: TensorPolicy,
    /// Activations feeding every linear / matmul.
    pub acts: TensorPolicy,
    /// K/V cache rows (quantize-on-write in the pool).
    pub cache: TensorPolicy,
    /// Final head: input activation and weights share this width.
    pub head: TensorPolicy,
    /// Attention query rows (INT16 in the paper).
    pub query: TensorPolicy,
    /// QuaRot-style online Hadamard ablation (artifact backend only).
    pub online_rot: bool,
}

impl QuantPolicy {
    /// The unquantized baseline.
    pub fn fp16() -> QuantPolicy {
        QuantPolicy { quantized: false, ..QuantPolicy::integer(4, 8, 8) }
    }

    /// A canonical integer policy: `weight_bits` per-channel weights,
    /// dynamic per-token `act_bits` activations, `cache_bits` KV cache,
    /// 8-bit head, 16-bit query, default calibrations.
    pub fn integer(weight_bits: u32, act_bits: u32, cache_bits: u32) -> QuantPolicy {
        QuantPolicy {
            quantized: true,
            weights: TensorPolicy::weight(weight_bits, CalibMethod::Mse),
            acts: TensorPolicy::act(act_bits, QuantMode::Dynamic, CalibMethod::Quantile),
            cache: TensorPolicy::act(cache_bits, QuantMode::Dynamic, CalibMethod::Quantile),
            head: TensorPolicy::weight(8, CalibMethod::Mse),
            query: TensorPolicy::act(16, QuantMode::Dynamic, CalibMethod::Quantile),
            online_rot: false,
        }
    }

    /// The paper's main recipe (W4A8KV8, dynamic per-token acts).
    pub fn w4a8kv8() -> QuantPolicy {
        QuantPolicy::integer(4, 8, 8)
    }

    /// Switch the runtime-quantized slots (acts, cache, query) to static
    /// calibrated per-tensor steps — the base-model ('s') recipe.
    pub fn with_static_acts(mut self) -> QuantPolicy {
        for slot in [&mut self.acts, &mut self.cache, &mut self.query] {
            slot.mode = QuantMode::Static;
            slot.granularity = Granularity::PerTensor;
        }
        self
    }

    /// Switch the runtime-quantized slots to dynamic per-token steps.
    pub fn with_dynamic_acts(mut self) -> QuantPolicy {
        for slot in [&mut self.acts, &mut self.cache, &mut self.query] {
            slot.mode = QuantMode::Dynamic;
            slot.granularity = Granularity::PerToken;
        }
        self
    }

    /// Set the activation-side calibration (acts, cache and query share
    /// one trained step-parameter family, so they calibrate together).
    pub fn with_act_calib(mut self, calib: CalibMethod) -> QuantPolicy {
        for slot in [&mut self.acts, &mut self.cache, &mut self.query] {
            slot.calib = calib;
        }
        self
    }

    /// Set the weight-side calibration (weights and head share it).
    pub fn with_weight_calib(mut self, calib: CalibMethod) -> QuantPolicy {
        self.weights.calib = calib;
        self.head.calib = calib;
        self
    }

    /// Check the policy against the hardware envelope the codebase
    /// implements (the paper's deployment constraints).
    pub fn validate(&self) -> Result<()> {
        if !self.quantized {
            ensure!(!self.online_rot, "the fp16 baseline has no online rotation");
            return Ok(());
        }
        let range = |name: &str, bits: u32, lo: u32, hi: u32| -> Result<()> {
            ensure!(
                (lo..=hi).contains(&bits),
                "{name} bits must be {lo}..={hi}, got {bits}"
            );
            Ok(())
        };
        range("weight", self.weights.bits, 2, 16)?;
        range("act", self.acts.bits, 2, 16)?;
        // KvPool stores cache integers in i8 slabs
        range("cache", self.cache.bits, 2, 8)?;
        range("head", self.head.bits, 2, 16)?;
        range("query", self.query.bits, 2, 16)?;
        for (name, slot) in [("weights", &self.weights), ("head", &self.head)] {
            ensure!(
                slot.granularity == Granularity::PerChannel && slot.mode == QuantMode::Static,
                "{name} must be static per-output-channel (hardware constraint)"
            );
            ensure!(
                matches!(slot.calib, CalibMethod::Mse | CalibMethod::Lsq),
                "{name} calibration must be mse|lsq"
            );
        }
        for (name, slot) in [("acts", &self.acts), ("cache", &self.cache), ("query", &self.query)] {
            let want = match slot.mode {
                QuantMode::Dynamic => Granularity::PerToken,
                QuantMode::Static => Granularity::PerTensor,
            };
            ensure!(
                slot.granularity == want,
                "{name}: {} granularity must be {want}",
                slot.mode
            );
            ensure!(
                matches!(slot.calib, CalibMethod::Quantile | CalibMethod::Max),
                "{name} calibration must be quantile|max"
            );
        }
        // one trained step-parameter set (sa_*/sc_*) covers all three
        // runtime slots, so their modes and calibrations must agree — this
        // also keeps the spec string an unambiguous encoding
        ensure!(
            self.cache.mode == self.acts.mode && self.query.mode == self.acts.mode,
            "cache/query step mode must match the activation mode"
        );
        ensure!(
            self.cache.calib == self.acts.calib && self.query.calib == self.acts.calib,
            "cache/query calibration must match the activation calibration"
        );
        ensure!(
            self.head.calib == self.weights.calib,
            "head calibration must match the weight calibration"
        );
        Ok(())
    }

    /// Lift a manifest precision into a typed policy. The manifest carries
    /// no calibration choice, so calib fields take their defaults.
    pub fn from_prec(pc: &PrecCfg) -> Result<QuantPolicy> {
        let mode = if pc.act_dynamic { QuantMode::Dynamic } else { QuantMode::Static };
        let p = QuantPolicy {
            quantized: pc.quantized,
            weights: TensorPolicy::weight(pc.weight_bits, CalibMethod::Mse),
            acts: TensorPolicy::act(pc.act_bits, mode, CalibMethod::Quantile),
            cache: TensorPolicy::act(pc.cache_bits, mode, CalibMethod::Quantile),
            head: TensorPolicy::weight(pc.head_bits, CalibMethod::Mse),
            query: TensorPolicy::act(pc.query_bits, mode, CalibMethod::Quantile),
            online_rot: pc.online_rot,
        };
        if p.quantized {
            p.validate().with_context(|| format!("precision {}", pc.name))?;
        }
        Ok(p)
    }

    /// Lower the policy back to manifest form under `name`. Fails when the
    /// policy uses a shape `PrecCfg` cannot carry; the calibration choice
    /// is dropped (the manifest does not record it).
    pub fn to_prec(&self, name: &str) -> Result<PrecCfg> {
        ensure!(
            self.cache.mode == self.acts.mode && self.query.mode == self.acts.mode,
            "PrecCfg has a single act_dynamic switch; cache/query mode must match acts"
        );
        Ok(PrecCfg {
            name: name.to_string(),
            quantized: self.quantized,
            act_bits: self.acts.bits,
            act_dynamic: self.acts.mode == QuantMode::Dynamic,
            cache_bits: self.cache.bits,
            weight_bits: self.weights.bits,
            head_bits: self.head.bits,
            query_bits: self.query.bits,
            online_rot: self.online_rot,
        })
    }

    /// Resolve any user-facing precision string: a preset name
    /// (`w4a8kv8-base`), a manifest-style legacy name (`a8d-c4-w4`), or an
    /// inline spec string (`w4a8kv8:statacts,h6`).
    pub fn resolve(s: &str) -> Result<QuantPolicy> {
        if let Some(p) = QuantPolicy::preset(s) {
            return Ok(p);
        }
        if let Some(p) = QuantPolicy::from_legacy_name(s) {
            return Ok(p);
        }
        s.parse()
    }

    /// Look up a named preset (see [`PRESETS`]).
    pub fn preset(name: &str) -> Option<QuantPolicy> {
        let p = PRESETS.iter().find(|p| p.name == name)?;
        Some(p.spec.parse().expect("preset specs are canonical"))
    }

    /// Parse the legacy manifest naming scheme `a<A><d|s>-c<C>-w<W>[-rot]`
    /// (plus `fp16`, which [`QuantPolicy::preset`] already covers).
    fn from_legacy_name(s: &str) -> Option<QuantPolicy> {
        let (s, rot) = match s.strip_suffix("-rot") {
            Some(rest) => (rest, true),
            None => (s, false),
        };
        let mut it = s.split('-');
        let (a, c, w) = (it.next()?, it.next()?, it.next()?);
        if it.next().is_some() {
            return None;
        }
        let a = a.strip_prefix('a')?;
        // match the trailing mode byte first: d/s are ASCII, so the slice
        // below is always on a char boundary (split_at would panic on a
        // multi-byte final char)
        let dynamic = match a.as_bytes().last()? {
            b'd' => true,
            b's' => false,
            _ => return None,
        };
        let abits: u32 = a[..a.len() - 1].parse().ok()?;
        let cbits: u32 = c.strip_prefix('c')?.parse().ok()?;
        let wbits: u32 = w.strip_prefix('w')?.parse().ok()?;
        let mut p = QuantPolicy::integer(wbits, abits, cbits);
        if !dynamic {
            p = p.with_static_acts();
        }
        p.online_rot = rot;
        p.validate().ok()?;
        Some(p)
    }

    /// Multi-line human rendering for `silq prec`.
    pub fn describe(&self) -> String {
        if !self.quantized {
            return "fp16: unquantized baseline (f32 host math, f32 KV cache)\n".into();
        }
        let slot = |name: &str, t: &TensorPolicy| {
            format!(
                "  {name:<8} INT{:<2} {:<12} {:<8} calib={}\n",
                t.bits,
                t.granularity.to_string(),
                t.mode.to_string(),
                t.calib
            )
        };
        let mut out = String::new();
        out += &slot("weights", &self.weights);
        out += &slot("acts", &self.acts);
        out += &slot("cache", &self.cache);
        out += &slot("head", &self.head);
        out += &slot("query", &self.query);
        out += &format!(
            "  online rotation: {}\n",
            if self.online_rot { "yes (artifact backend only)" } else { "no" }
        );
        out
    }
}

impl fmt::Display for QuantPolicy {
    /// The canonical spec string; `FromStr` inverts it exactly.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if !self.quantized {
            return f.write_str("fp16");
        }
        write!(f, "w{}a{}kv{}", self.weights.bits, self.acts.bits, self.cache.bits)?;
        let mut mods: Vec<String> = vec![];
        if self.acts.mode == QuantMode::Static {
            mods.push("statacts".into());
        }
        if self.head.bits != 8 {
            mods.push(format!("h{}", self.head.bits));
        }
        if self.query.bits != 16 {
            mods.push(format!("q{}", self.query.bits));
        }
        if self.online_rot {
            mods.push("rot".into());
        }
        if self.acts.calib == CalibMethod::Max {
            mods.push("acal=max".into());
        }
        if self.weights.calib == CalibMethod::Lsq {
            mods.push("wcal=lsq".into());
        }
        if !mods.is_empty() {
            write!(f, ":{}", mods.join(","))?;
        }
        Ok(())
    }
}

/// Take a leading decimal number off `s`.
fn take_num(s: &str) -> Result<(u32, &str)> {
    let end = s.find(|c: char| !c.is_ascii_digit()).unwrap_or(s.len());
    ensure!(end > 0, "expected a number at {s:?}");
    Ok((s[..end].parse().map_err(|e| anyhow!("bad number in {s:?}: {e}"))?, &s[end..]))
}

/// Parse the `w<W>a<A>kv<KV>` core.
fn parse_core(core: &str) -> Result<(u32, u32, u32)> {
    let rest = core.strip_prefix('w').context("spec core must start with w<bits>")?;
    let (w, rest) = take_num(rest)?;
    let rest = rest.strip_prefix('a').context("expected a<bits> after the weight width")?;
    let (a, rest) = take_num(rest)?;
    let rest = rest.strip_prefix("kv").context("expected kv<bits> after the act width")?;
    let (kv, rest) = take_num(rest)?;
    ensure!(rest.is_empty(), "trailing garbage {rest:?} in spec core");
    Ok((w, a, kv))
}

impl FromStr for QuantPolicy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<QuantPolicy> {
        let s = s.trim();
        ensure!(!s.is_empty(), "empty precision spec");
        let (core, mods) = match s.split_once(':') {
            Some((c, m)) => {
                ensure!(!m.is_empty(), "empty modifier list after ':' in {s:?}");
                (c, m)
            }
            None => (s, ""),
        };
        let mut p = if core == "fp16" {
            ensure!(mods.is_empty(), "fp16 takes no modifiers");
            QuantPolicy::fp16()
        } else {
            let (w, a, kv) = parse_core(core)
                .with_context(|| format!("bad precision spec {s:?} (grammar: w4a8kv8[:mods] | fp16)"))?;
            QuantPolicy::integer(w, a, kv)
        };
        for m in mods.split(',').filter(|m| !m.is_empty()) {
            if let Some(v) = m.strip_prefix("acal=") {
                p = p.with_act_calib(CalibMethod::parse_act(v)?);
            } else if let Some(v) = m.strip_prefix("wcal=") {
                p = p.with_weight_calib(CalibMethod::parse_weight(v)?);
            } else if m == "dynacts" {
                p = p.with_dynamic_acts();
            } else if m == "statacts" || m == "staticacts" {
                p = p.with_static_acts();
            } else if m == "rot" {
                p.online_rot = true;
            } else if let Some(v) = m.strip_prefix('h') {
                p.head.bits = take_num(v).and_then(|(b, rest)| {
                    ensure!(rest.is_empty(), "trailing garbage in h modifier");
                    Ok(b)
                })?;
            } else if let Some(v) = m.strip_prefix('q') {
                p.query.bits = take_num(v).and_then(|(b, rest)| {
                    ensure!(rest.is_empty(), "trailing garbage in q modifier");
                    Ok(b)
                })?;
            } else {
                bail!(
                    "unknown policy modifier {m:?} \
                     (dynacts|statacts|h<bits>|q<bits>|rot|acal=quantile|max|wcal=mse|lsq)"
                );
            }
        }
        p.validate()?;
        Ok(p)
    }
}

/// One named preset in the paper's ablation table.
pub struct PolicyPreset {
    pub name: &'static str,
    /// canonical spec string (parses via `QuantPolicy`'s `FromStr`)
    pub spec: &'static str,
    /// equivalent artifact-manifest precision name, when one exists
    pub manifest_prec: Option<&'static str>,
    pub note: &'static str,
}

/// The preset table `silq prec list` prints, mirroring the paper's
/// ablations (Table 4) plus the serving baselines.
pub const PRESETS: &[PolicyPreset] = &[
    PolicyPreset {
        name: "fp16",
        spec: "fp16",
        manifest_prec: Some("fp16"),
        note: "unquantized deployment baseline",
    },
    PolicyPreset {
        name: "w4a8kv8",
        spec: "w4a8kv8",
        manifest_prec: Some("a8d-c8-w4"),
        note: "paper main recipe: INT4 weights, dynamic per-token INT8 acts, INT8 KV (instruct)",
    },
    PolicyPreset {
        name: "w4a8kv8-base",
        spec: "w4a8kv8:statacts",
        manifest_prec: Some("a8s-c8-w4"),
        note: "static per-tensor activation steps (base-model recipe, LSQ-trained)",
    },
    PolicyPreset {
        name: "w4a8kv4",
        spec: "w4a8kv4",
        manifest_prec: Some("a8d-c4-w4"),
        note: "4-bit KV-cache ablation",
    },
    PolicyPreset {
        name: "w4a8kv8-rot",
        spec: "w4a8kv8:rot",
        manifest_prec: Some("a8d-c8-w4-rot"),
        note: "online-rotation ablation (artifact backend only)",
    },
    PolicyPreset {
        name: "w8a8kv8",
        spec: "w8a8kv8",
        manifest_prec: None,
        note: "8-bit weights everywhere — accuracy headroom check",
    },
    PolicyPreset {
        name: "kv8-only",
        spec: "w16a16kv8:h16",
        manifest_prec: None,
        note: "cache-only quantization: near-fp 16-bit weights/acts, INT8 KV",
    },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_core_round_trips() {
        for s in ["fp16", "w4a8kv8", "w4a8kv4", "w8a8kv8", "w2a4kv2"] {
            let p: QuantPolicy = s.parse().unwrap();
            assert_eq!(p.to_string(), s, "canonical spec must round-trip");
        }
    }

    #[test]
    fn modifiers_round_trip_in_canonical_order() {
        let p: QuantPolicy = "w4a8kv8:statacts,h6,q8,rot,acal=max,wcal=lsq".parse().unwrap();
        assert_eq!(p.acts.mode, QuantMode::Static);
        assert_eq!(p.head.bits, 6);
        assert_eq!(p.query.bits, 8);
        assert!(p.online_rot);
        assert_eq!(p.acts.calib, CalibMethod::Max);
        assert_eq!(p.weights.calib, CalibMethod::Lsq);
        let s = p.to_string();
        assert_eq!(s.parse::<QuantPolicy>().unwrap(), p);
        // non-canonical order parses to the same policy
        let q: QuantPolicy = "w4a8kv8:wcal=lsq,rot,acal=max,q8,h6,statacts".parse().unwrap();
        assert_eq!(q, p);
    }

    #[test]
    fn dynacts_is_the_default() {
        let a: QuantPolicy = "w4a8kv8".parse().unwrap();
        let b: QuantPolicy = "w4a8kv8:dynacts".parse().unwrap();
        assert_eq!(a, b);
        assert_eq!(a.acts.granularity, Granularity::PerToken);
    }

    #[test]
    fn bad_specs_are_rejected() {
        for s in [
            "", "w4", "w4a8", "w4a8kv", "a8w4kv8", "w4a8kv8:", "w4a8kv8:turbo",
            "w4a8kv99", "w1a8kv8", "fp16:rot", "w4a8kv8x", "w4a8kv8:h",
        ] {
            assert!(s.parse::<QuantPolicy>().is_err(), "{s:?} must not parse");
        }
    }

    #[test]
    fn legacy_manifest_names_resolve() {
        let p = QuantPolicy::resolve("a8d-c8-w4").unwrap();
        assert_eq!(p, "w4a8kv8".parse().unwrap());
        let p = QuantPolicy::resolve("a8s-c8-w4").unwrap();
        assert_eq!(p, "w4a8kv8:statacts".parse().unwrap());
        let p = QuantPolicy::resolve("a8d-c4-w4").unwrap();
        assert_eq!(p.cache.bits, 4);
        let p = QuantPolicy::resolve("a8d-c8-w4-rot").unwrap();
        assert!(p.online_rot);
        assert!(QuantPolicy::resolve("int1").is_err());
        assert!(QuantPolicy::resolve("a8x-c8-w4").is_err());
        // malformed multi-byte input must error, not panic on a byte slice
        assert!(QuantPolicy::resolve("a8µ-c8-w4").is_err());
        assert!(QuantPolicy::resolve("aµd-c8-w4").is_err());
    }

    #[test]
    fn presets_parse_and_match_manifest_names() {
        for preset in PRESETS {
            let p = QuantPolicy::preset(preset.name).unwrap();
            p.validate().unwrap();
            if let Some(legacy) = preset.manifest_prec {
                assert_eq!(
                    p,
                    QuantPolicy::resolve(legacy).unwrap(),
                    "preset {} must equal manifest precision {legacy}",
                    preset.name
                );
            }
        }
        assert!(QuantPolicy::preset("nope").is_none());
    }

    #[test]
    fn prec_cfg_round_trip_is_lossless() {
        let pc = PrecCfg {
            name: "a8s-c8-w4".into(),
            quantized: true,
            act_bits: 8,
            act_dynamic: false,
            cache_bits: 8,
            weight_bits: 4,
            head_bits: 8,
            query_bits: 16,
            online_rot: false,
        };
        let p = QuantPolicy::from_prec(&pc).unwrap();
        let back = p.to_prec(&pc.name).unwrap();
        assert_eq!(format!("{pc:?}"), format!("{back:?}"));
    }

    #[test]
    fn validation_catches_inconsistent_modes() {
        let mut p = QuantPolicy::w4a8kv8();
        p.cache.mode = QuantMode::Static;
        p.cache.granularity = Granularity::PerTensor;
        assert!(p.validate().is_err());
        let mut p = QuantPolicy::w4a8kv8();
        p.weights.calib = CalibMethod::Quantile;
        assert!(p.validate().is_err());
    }
}
