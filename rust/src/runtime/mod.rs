//! PJRT runtime: load AOT-compiled HLO text artifacts and execute them.
//!
//! This is the only module that touches the `xla` crate. Python never runs
//! at request time — the Rust binary loads `artifacts/*.hlo.txt` (produced
//! once by `make artifacts`), compiles each on the PJRT CPU client, and
//! executes with `Literal` inputs built from the [`crate::model::ParamStore`].

use anyhow::{ensure, Context, Result};
use std::collections::HashMap;
use std::sync::Mutex;

use crate::config::{ArtifactSpec, Manifest};
use crate::model::ParamStore;

/// Owns the PJRT client and a cache of compiled executables.
///
/// The cache maps artifact name -> a per-entry cell so that concurrent
/// `module()` calls for the *same* artifact compile it exactly once (the
/// first caller holds the entry's lock through compilation) while calls for
/// *different* artifacts compile in parallel.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    modules: Mutex<HashMap<String, std::sync::Arc<Mutex<Option<std::sync::Arc<Module>>>>>>,
}

/// One compiled artifact.
pub struct Module {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

// xla::PjRtLoadedExecutable wraps raw pointers without Send/Sync markers;
// the engine serializes access through the modules mutex and the CPU client
// is thread-safe, so sharing across threads is sound for our usage.
unsafe impl Send for Module {}
unsafe impl Sync for Module {}

impl Engine {
    /// Create a CPU engine over an artifact directory.
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, manifest, modules: Mutex::new(HashMap::new()) })
    }

    /// Load (or fetch cached) compiled module by artifact name.
    pub fn module(&self, name: &str) -> Result<std::sync::Arc<Module>> {
        // reserve (or find) this artifact's cell under the map lock, then
        // compile under the cell's own lock — a second thread racing on the
        // same name blocks on the cell instead of compiling a duplicate
        let cell = self
            .modules
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| std::sync::Arc::new(Mutex::new(None)))
            .clone();
        // a panic mid-compile poisons the cell but leaves the slot None —
        // recover the lock so the next caller retries instead of panicking
        let mut slot = cell.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(m) = slot.as_ref() {
            return Ok(m.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.manifest.hlo_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        let m = std::sync::Arc::new(Module { spec, exe });
        // on failure the slot stays None, so a later caller retries cleanly
        *slot = Some(m.clone());
        Ok(m)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

impl Module {
    /// Execute with literal inputs; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        ensure!(
            inputs.len() == self.spec.inputs.len(),
            "artifact {}: expected {} inputs, got {}",
            self.spec.name,
            self.spec.inputs.len(),
            inputs.len()
        );
        let out = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = out[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        ensure!(
            parts.len() == self.spec.outputs.len(),
            "artifact {}: expected {} outputs, got {}",
            self.spec.name,
            self.spec.outputs.len(),
            parts.len()
        );
        Ok(parts)
    }

    /// Execute with device-resident buffers (hot path: the caller keeps
    /// params on device between steps). Returns one tuple buffer; use
    /// [`Module::run`] semantics via `tuple_to_literals` to decompose.
    pub fn run_buffers(&self, inputs: &[xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        let out = self.exe.execute_b::<xla::PjRtBuffer>(inputs)?;
        Ok(out.into_iter().next().unwrap())
    }
}

/// Build an f32 literal of the given logical dims.
pub fn literal_f32(dims: &[usize], data: &[f32]) -> Result<xla::Literal> {
    ensure!(dims.iter().product::<usize>().max(1) == data.len(), "literal_f32 shape mismatch");
    let lit = xla::Literal::vec1(data);
    if dims.is_empty() {
        // 0-d scalar
        Ok(lit.reshape(&[])?)
    } else {
        Ok(lit.reshape(&dims.iter().map(|&d| d as i64).collect::<Vec<_>>())?)
    }
}

/// Build an i32 literal of the given logical dims.
pub fn literal_i32(dims: &[usize], data: &[i32]) -> Result<xla::Literal> {
    ensure!(dims.iter().product::<usize>().max(1) == data.len(), "literal_i32 shape mismatch");
    let lit = xla::Literal::vec1(data);
    if dims.is_empty() {
        Ok(lit.reshape(&[])?)
    } else {
        Ok(lit.reshape(&dims.iter().map(|&d| d as i64).collect::<Vec<_>>())?)
    }
}

/// Scalar f32 literal.
pub fn literal_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract an f32 vector from a literal.
pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract the single f32 of a scalar literal.
pub fn to_f32_scalar(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

/// Build the literal list for a params-prefixed artifact call: params first
/// (in spec order), then the extra inputs provided by name.
pub fn build_inputs(
    spec: &ArtifactSpec,
    params: &ParamStore,
    extras: &[(&str, xla::Literal)],
) -> Result<Vec<xla::Literal>> {
    let mut out: Vec<Option<xla::Literal>> = Vec::with_capacity(spec.inputs.len());
    for t in &spec.inputs {
        if let Some(pname) = t.name.strip_prefix("params.") {
            out.push(Some(literal_f32(&t.dims, params.get(pname)?)?));
        } else {
            out.push(None);
        }
    }
    for (name, lit) in extras {
        let idx = spec.input_index(name)?;
        out[idx] = Some(lit.clone_literal()?);
    }
    let mut lits = Vec::with_capacity(out.len());
    for (i, o) in out.into_iter().enumerate() {
        lits.push(o.ok_or_else(|| {
            anyhow::anyhow!("missing input {} for {}", spec.inputs[i].name, spec.name)
        })?);
    }
    Ok(lits)
}

/// Clone helper (Literal lacks Clone; round-trip through vec1/reshape).
pub trait LiteralClone {
    fn clone_literal(&self) -> Result<xla::Literal>;
}

impl LiteralClone for xla::Literal {
    fn clone_literal(&self) -> Result<xla::Literal> {
        let shape = self.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match self.ty()? {
            xla::ElementType::F32 => literal_f32(&dims, &self.to_vec::<f32>()?),
            xla::ElementType::S32 => literal_i32(&dims, &self.to_vec::<i32>()?),
            other => anyhow::bail!("clone_literal: unsupported type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let lit = literal_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(to_f32_vec(&lit).unwrap(), vec![1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn literal_scalar_shape() {
        let lit = literal_f32(&[], &[5.0]).unwrap();
        assert_eq!(to_f32_scalar(&lit).unwrap(), 5.0);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(literal_f32(&[2, 2], &[1.0]).is_err());
        assert!(literal_i32(&[3], &[1, 2]).is_err());
    }

    #[test]
    fn clone_literal_roundtrip() {
        let lit = literal_i32(&[4], &[9, 8, 7, 6]).unwrap();
        let c = lit.clone_literal().unwrap();
        assert_eq!(c.to_vec::<i32>().unwrap(), vec![9, 8, 7, 6]);
    }
}
