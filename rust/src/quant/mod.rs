//! Host-side quantization math — the Rust mirror of `python/compile/quant.py`
//! and `kernels/ref.py` (cross-checked against Python-generated fixtures in
//! `rust/tests/quant_integration.rs`).
//!
//! Used by the PTQ baselines (RTN / SmoothQuant / GPTQ / SpinQuant-analog),
//! by QAT step-size calibration, and by the integer packing that a real
//! deployment would ship to the accelerator.

pub mod calib;
pub mod pack;

pub use calib::{
    act_step_max, act_step_percentile, percentile_for_bits, weight_step_lsq_init, weight_step_mse,
};

pub const EPS: f32 = 1e-9;

/// Signed symmetric integer bounds at a precision.
pub fn qbounds(bits: u32) -> (i64, i64) {
    (-(1i64 << (bits - 1)), (1i64 << (bits - 1)) - 1)
}

/// Paper Eq. 1: `round(clip(x/s, b_l, b_u)) * s` (round half to even, like
/// jnp.round, so fixtures match bit-for-bit).
pub fn fake_quant_scalar(x: f32, s: f32, bits: u32) -> f32 {
    let (qn, qp) = qbounds(bits);
    let s = s.max(EPS);
    let v = (x / s).clamp(qn as f32, qp as f32);
    round_half_even(v) * s
}

/// Round half to even (banker's rounding) — matches numpy/jnp semantics.
pub fn round_half_even(v: f32) -> f32 {
    let r = v.round(); // round half away from zero
    if (v - v.trunc()).abs() == 0.5 {
        // tie: pick the even neighbor
        let lower = v.floor();
        let upper = v.ceil();
        if (lower as i64) % 2 == 0 {
            lower
        } else {
            upper
        }
    } else {
        r
    }
}

/// [`fake_quant_scalar`] for a step already floored at [`EPS`] — hoists
/// the per-element floor out of inner loops (bit-identical results, since
/// `s.max(EPS)` is idempotent). Callers guarantee `s >= EPS` (see
/// `QuantRule::floored` and the hoisted loops below).
#[inline]
pub fn fake_quant_prefloored(x: f32, s: f32, bits: u32) -> f32 {
    let (qn, qp) = qbounds(bits);
    round_half_even((x / s).clamp(qn as f32, qp as f32)) * s
}

/// Fake-quantize a slice in place with one step (floored once, not per
/// element).
pub fn fake_quant(xs: &mut [f32], s: f32, bits: u32) {
    let s = s.max(EPS);
    for x in xs.iter_mut() {
        *x = fake_quant_prefloored(*x, s, bits);
    }
}

/// Per-token (row) dynamic symmetric quantization of a row-major [rows, cols]
/// matrix, as the 'd' activation mode does at runtime. The per-row step is
/// floored at [`EPS`] once; the inner loop uses the prefloored form.
pub fn dynamic_quant_rows(xs: &mut [f32], cols: usize, bits: u32) {
    let (_, qp) = qbounds(bits);
    for row in xs.chunks_mut(cols) {
        let maxabs = row.iter().fold(0f32, |a, &b| a.max(b.abs()));
        let s = (maxabs / qp as f32).max(EPS);
        for x in row.iter_mut() {
            *x = fake_quant_prefloored(*x, s, bits);
        }
    }
}

/// Per-output-channel fake quantization of a row-major [rows, cols] weight
/// matrix; `sw[c]` is the step of column c.
pub fn fake_quant_per_channel(w: &mut [f32], cols: usize, sw: &[f32], bits: u32) {
    assert_eq!(sw.len(), cols);
    for row in w.chunks_mut(cols) {
        for (x, &s) in row.iter_mut().zip(sw) {
            *x = fake_quant_scalar(*x, s, bits);
        }
    }
}

/// Mean squared quantization error of quantizing `w` with step `s`.
pub fn quant_mse(w: &[f32], s: f32, bits: u32) -> f64 {
    let mut acc = 0f64;
    for &x in w {
        let d = (fake_quant_scalar(x, s, bits) - x) as f64;
        acc += d * d;
    }
    acc / w.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds() {
        assert_eq!(qbounds(4), (-8, 7));
        assert_eq!(qbounds(8), (-128, 127));
        assert_eq!(qbounds(16), (-32768, 32767));
    }

    #[test]
    fn fake_quant_basics() {
        // s=0.5, 4-bit: clip range [-4, 3.5]
        assert_eq!(fake_quant_scalar(10.0, 0.5, 4), 3.5);
        assert_eq!(fake_quant_scalar(-10.0, 0.5, 4), -4.0);
        assert_eq!(fake_quant_scalar(0.26, 0.5, 4), 0.5);
        assert_eq!(fake_quant_scalar(0.0, 0.5, 4), 0.0);
    }

    #[test]
    fn round_half_even_matches_numpy() {
        assert_eq!(round_half_even(0.5), 0.0);
        assert_eq!(round_half_even(1.5), 2.0);
        assert_eq!(round_half_even(2.5), 2.0);
        assert_eq!(round_half_even(-0.5), 0.0);
        assert_eq!(round_half_even(-1.5), -2.0);
        assert_eq!(round_half_even(0.4999), 0.0);
        assert_eq!(round_half_even(3.7), 4.0);
    }

    #[test]
    fn dynamic_rows_bound_error() {
        let mut x = vec![1.0, -2.0, 3.0, 0.5, 0.25, -0.125];
        let orig = x.clone();
        dynamic_quant_rows(&mut x, 3, 8);
        for (a, b) in x.iter().zip(&orig) {
            let rowmax: f32 = 3.0; // both rows max-abs <= 3
            assert!((a - b).abs() <= rowmax / 127.0 / 2.0 + 1e-6);
        }
    }

    #[test]
    fn per_channel_uses_own_step() {
        let mut w = vec![0.3, 0.3, 0.3, 0.3];
        fake_quant_per_channel(&mut w, 2, &[0.1, 0.2], 4);
        assert!((w[0] - 0.3).abs() < 1e-6); // 0.3/0.1=3 exact
        assert!((w[1] - 0.4).abs() < 1e-6); // round(1.5)=2 (half-even), 2*0.2=0.4
    }

    #[test]
    fn prefloored_matches_fake_quant_for_floored_steps() {
        for &x in &[0.26f32, -3.4, 0.0, 17.0, -0.49] {
            for &s in &[EPS, 0.1, 0.5, 2.0] {
                for bits in [2u32, 4, 8] {
                    assert_eq!(fake_quant_prefloored(x, s, bits), fake_quant_scalar(x, s, bits));
                }
            }
        }
    }

    #[test]
    fn mse_zero_for_grid_values() {
        let w: Vec<f32> = (-8..8).map(|i| i as f32 * 0.25).collect();
        assert!(quant_mse(&w, 0.25, 8) < 1e-12);
    }
}
