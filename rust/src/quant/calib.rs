//! Step-size calibration: the paper's percentile rule for activations and
//! the novel convex-MSE approximation (Eq. 2) for weights, plus the LSQ-paper
//! initialization used as the Table 4 ablation baseline.

use super::EPS;
use crate::quant::qbounds;

/// Paper section 3.1: percentile per precision — 99.91 / 99.99 / 99.995 for
/// 4- / 8- / 16-bit activations.
pub fn percentile_for_bits(bits: u32) -> f64 {
    match bits {
        b if b <= 4 => 99.91,
        b if b <= 8 => 99.99,
        _ => 99.995,
    }
}

/// Linear-interpolated percentile of |x| (numpy semantics), then divided by
/// q_p to produce a step size.
pub fn act_step_percentile(xs: &[f32], bits: u32, percentile: f64) -> f32 {
    let (_, qp) = qbounds(bits);
    let mut a: Vec<f32> = xs.iter().map(|v| v.abs()).collect();
    a.sort_by(|p, q| p.partial_cmp(q).unwrap());
    let q = percentile_interp(&a, percentile);
    (q / qp as f32).max(EPS)
}

/// numpy-style linear interpolation percentile on a sorted slice.
pub fn percentile_interp(sorted: &[f32], percentile: f64) -> f32 {
    assert!(!sorted.is_empty());
    let rank = percentile / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = (rank - lo as f64) as f32;
    sorted[lo] * (1.0 - frac) + sorted[hi.min(sorted.len() - 1)] * frac
}

/// Max (absmax) calibration — the weak baseline in the Table 4 ablation.
pub fn act_step_max(xs: &[f32], bits: u32) -> f32 {
    let (_, qp) = qbounds(bits);
    let m = xs.iter().fold(0f32, |a, &b| a.max(b.abs()));
    (m / qp as f32).max(EPS)
}

/// Paper Eq. 2 objective: eps(s) = sum_i max(s^2/12, H(|w_i|-sb)(|w_i|-sb)^2).
fn mse_objective(aw: &[f32], s: f64, b: f64) -> f64 {
    let floor = s * s / 12.0;
    let mut acc = 0f64;
    for &w in aw {
        let over = (w as f64 - s * b).max(0.0);
        acc += floor.max(over * over);
    }
    acc
}

/// The paper's novel convex-MSE weight-step calibration (Eq. 2), solved by
/// ternary search (the objective is convex in s).
pub fn weight_step_mse(w: &[f32], bits: u32) -> f32 {
    let b = (1i64 << (bits - 1)) as f64 - 0.5;
    let aw: Vec<f32> = w.iter().map(|v| v.abs()).collect();
    let maxw = aw.iter().fold(0f32, |a, &v| a.max(v)) as f64;
    let (mut lo, mut hi) = (EPS as f64, maxw / b + EPS as f64);
    for _ in 0..60 {
        let m1 = lo + (hi - lo) / 3.0;
        let m2 = hi - (hi - lo) / 3.0;
        if mse_objective(&aw, m1, b) > mse_objective(&aw, m2, b) {
            lo = m1;
        } else {
            hi = m2;
        }
    }
    (((lo + hi) / 2.0) as f32).max(EPS)
}

/// Per-output-channel convex-MSE steps for a row-major [rows, cols] matrix.
pub fn weight_step_mse_per_channel(w: &[f32], cols: usize, bits: u32) -> Vec<f32> {
    let rows = w.len() / cols;
    (0..cols)
        .map(|c| {
            let col: Vec<f32> = (0..rows).map(|r| w[r * cols + c]).collect();
            weight_step_mse(&col, bits)
        })
        .collect()
}

/// LSQ-paper initialization: s = 2 * mean|w| / sqrt(q_p).
pub fn weight_step_lsq_init(w: &[f32], bits: u32) -> f32 {
    let (_, qp) = qbounds(bits);
    let mean: f32 = w.iter().map(|v| v.abs()).sum::<f32>() / w.len() as f32;
    2.0 * mean / (qp as f32).sqrt() + EPS
}

/// Per-output-channel LSQ init.
pub fn weight_step_lsq_per_channel(w: &[f32], cols: usize, bits: u32) -> Vec<f32> {
    let rows = w.len() / cols;
    (0..cols)
        .map(|c| {
            let col: Vec<f32> = (0..rows).map(|r| w[r * cols + c]).collect();
            weight_step_lsq_init(&col, bits)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quant_mse;
    use crate::util::Rng;

    #[test]
    fn percentile_rule() {
        assert_eq!(percentile_for_bits(4), 99.91);
        assert_eq!(percentile_for_bits(8), 99.99);
        assert_eq!(percentile_for_bits(16), 99.995);
    }

    #[test]
    fn percentile_interp_simple() {
        let v = [0.0, 1.0, 2.0, 3.0];
        assert_eq!(percentile_interp(&v, 0.0), 0.0);
        assert_eq!(percentile_interp(&v, 100.0), 3.0);
        assert_eq!(percentile_interp(&v, 50.0), 1.5);
    }

    #[test]
    fn percentile_below_max_with_outliers() {
        let mut rng = Rng::new(0);
        let mut xs = rng.normal_vec(100_000, 1.0);
        xs[0] = 1000.0; // giant outlier
        let sp = act_step_percentile(&xs, 8, 99.99);
        let sm = act_step_max(&xs, 8);
        assert!(sp < sm / 10.0, "percentile must ignore the outlier: {sp} vs {sm}");
    }

    #[test]
    fn mse_step_beats_max_step_on_heavy_tails() {
        let mut rng = Rng::new(1);
        // cubed normals: heavy tails, the regime Eq. 2 is built for
        let w: Vec<f32> = rng.normal_vec(4096, 1.0).iter().map(|x| x * x * x * 0.05).collect();
        let s_mse = weight_step_mse(&w, 4);
        let s_max = act_step_max(&w, 4);
        assert!(s_mse < s_max, "MSE step must clip the tail: {s_mse} vs {s_max}");
        assert!(quant_mse(&w, s_mse, 4) < quant_mse(&w, s_max, 4));
    }

    #[test]
    fn mse_step_minimizes_eq2_objective() {
        // the property the method *does* guarantee: s* minimizes Eq. 2
        let mut rng = Rng::new(6);
        let w = rng.normal_vec(2048, 0.3);
        let aw: Vec<f32> = w.iter().map(|v| v.abs()).collect();
        let b = 7.5f64;
        let s = weight_step_mse(&w, 4) as f64;
        let at = |sv: f64| mse_objective(&aw, sv, b);
        assert!(at(s) <= at(s * 0.9) + 1e-9);
        assert!(at(s) <= at(s * 1.1) + 1e-9);
    }

    #[test]
    fn mse_step_near_bruteforce() {
        let mut rng = Rng::new(2);
        let w = rng.normal_vec(512, 1.0);
        let s = weight_step_mse(&w, 4);
        // brute force over a dense grid
        let b = 7.5f32;
        let maxw = w.iter().fold(0f32, |a, &v| a.max(v.abs()));
        let mut best = (f64::MAX, 0f32);
        for i in 1..4000 {
            let sv = maxw / b * i as f32 / 4000.0;
            let e = mse_objective(&w.iter().map(|v| v.abs()).collect::<Vec<_>>(), sv as f64, b as f64);
            if e < best.0 {
                best = (e, sv);
            }
        }
        assert!((s - best.1).abs() / best.1 < 0.02, "{s} vs {}", best.1);
    }

    #[test]
    fn per_channel_steps_independent() {
        // col 0 small values, col 1 big values -> steps differ ~10x
        let w: Vec<f32> = (0..64).flat_map(|i| [0.01 * (i as f32 % 7.0 - 3.0), 0.1 * (i as f32 % 7.0 - 3.0)]).collect();
        let s = weight_step_mse_per_channel(&w, 2, 4);
        assert!(s[1] > s[0] * 5.0);
    }

    #[test]
    fn lsq_init_formula() {
        let w = vec![1.0f32; 100];
        let s = weight_step_lsq_init(&w, 4);
        assert!((s - 2.0 / (7f32).sqrt()).abs() < 1e-4);
    }
}
