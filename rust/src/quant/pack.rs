//! Integer packing: convert fake-quantized f32 tensors to the integer +
//! scale representation a deployment target (NorthPole-like) stores.
//!
//! During QAT everything is f32 "fake quant"; at export time weights are
//! divided by their step and stored as packed signed integers. This module
//! exercises that path and verifies it is lossless w.r.t. the fake-quant
//! values (the invariant the paper relies on for deployability).

use anyhow::{bail, Result};

use super::{qbounds, round_half_even, EPS};

/// A per-channel-quantized integer tensor.
#[derive(Clone, Debug)]
pub struct PackedTensor {
    pub bits: u32,
    pub rows: usize,
    pub cols: usize,
    /// row-major quantized values (i8 covers up to 8-bit)
    pub q: Vec<i8>,
    /// one step per column (output channel)
    pub scales: Vec<f32>,
}

impl PackedTensor {
    /// Quantize a row-major [rows, cols] f32 matrix with per-column steps.
    pub fn pack(w: &[f32], cols: usize, scales: &[f32], bits: u32) -> Result<PackedTensor> {
        if bits > 8 {
            bail!("pack supports <=8 bits (16-bit tensors stay fp16 on chip)");
        }
        if scales.len() != cols || w.len() % cols != 0 {
            bail!("pack: shape mismatch");
        }
        let (qn, qp) = qbounds(bits);
        let rows = w.len() / cols;
        let mut q = Vec::with_capacity(w.len());
        for row in w.chunks(cols) {
            for (x, &s) in row.iter().zip(scales) {
                let v = (x / s.max(EPS)).clamp(qn as f32, qp as f32);
                q.push(round_half_even(v) as i8);
            }
        }
        Ok(PackedTensor { bits, rows, cols, q, scales: scales.to_vec() })
    }

    /// Dequantize back to f32 (must reproduce the fake-quant tensor exactly).
    pub fn dequant(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.q.len());
        for row in self.q.chunks(self.cols) {
            for (qv, &s) in row.iter().zip(&self.scales) {
                out.push(*qv as f32 * s.max(EPS));
            }
        }
        out
    }

    /// Bit-packed storage size in bytes (4-bit packs two values per byte).
    pub fn storage_bytes(&self) -> usize {
        (self.q.len() * self.bits as usize + 7) / 8 + self.scales.len() * 4
    }

    /// Integer matmul against an integer activation row (reference semantics
    /// for the accelerator's vector-matrix unit): returns f32 accumulators.
    pub fn int_matvec(&self, act_q: &[i8], act_scale: f32) -> Vec<f32> {
        assert_eq!(act_q.len(), self.rows);
        let mut out = vec![0f32; self.cols];
        for (r, &a) in act_q.iter().enumerate() {
            let a = a as i32;
            let base = r * self.cols;
            for c in 0..self.cols {
                out[c] += (a * self.q[base + c] as i32) as f32;
            }
        }
        for c in 0..self.cols {
            out[c] *= act_scale * self.scales[c];
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{fake_quant_per_channel, round_half_even};
    use crate::util::Rng;

    #[test]
    fn pack_dequant_matches_fake_quant() {
        let mut rng = Rng::new(3);
        let w = rng.normal_vec(64 * 16, 0.1);
        let scales: Vec<f32> = (0..16).map(|i| 0.01 + 0.002 * i as f32).collect();
        let packed = PackedTensor::pack(&w, 16, &scales, 4).unwrap();
        let mut fq = w.clone();
        fake_quant_per_channel(&mut fq, 16, &scales, 4);
        let deq = packed.dequant();
        for (a, b) in deq.iter().zip(&fq) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn values_in_bit_range() {
        let mut rng = Rng::new(4);
        let w = rng.normal_vec(256, 10.0);
        let packed = PackedTensor::pack(&w, 8, &vec![0.01; 8], 4).unwrap();
        assert!(packed.q.iter().all(|&q| (-8..=7).contains(&q)));
    }

    #[test]
    fn storage_is_packed() {
        let w = vec![0.0f32; 128];
        let p4 = PackedTensor::pack(&w, 8, &vec![0.1; 8], 4).unwrap();
        let p8 = PackedTensor::pack(&w, 8, &vec![0.1; 8], 8).unwrap();
        assert_eq!(p4.storage_bytes(), 64 + 32);
        assert_eq!(p8.storage_bytes(), 128 + 32);
    }

    #[test]
    fn int_matvec_matches_float_matmul_of_dequant() {
        let mut rng = Rng::new(5);
        let w = rng.normal_vec(8 * 4, 0.2);
        let scales = vec![0.05, 0.04, 0.03, 0.02];
        let packed = PackedTensor::pack(&w, 4, &scales, 4).unwrap();
        // quantized activation
        let act: Vec<f32> = rng.normal_vec(8, 1.0);
        let a_scale = 0.03f32;
        let act_q: Vec<i8> = act.iter().map(|&x| round_half_even((x / a_scale).clamp(-128.0, 127.0)) as i8).collect();
        let got = packed.int_matvec(&act_q, a_scale);
        let deq = packed.dequant();
        for c in 0..4 {
            let want: f32 = (0..8).map(|r| (act_q[r] as f32 * a_scale) * deq[r * 4 + c]).sum();
            assert!((got[c] - want).abs() < 1e-4, "{} vs {}", got[c], want);
        }
    }

    #[test]
    fn rejects_16bit_and_bad_shapes() {
        assert!(PackedTensor::pack(&[0.0; 4], 2, &[0.1, 0.1], 16).is_err());
        assert!(PackedTensor::pack(&[0.0; 5], 2, &[0.1, 0.1], 4).is_err());
    }
}
