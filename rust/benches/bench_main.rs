//! Benchmark harness (criterion is unavailable offline; hand-rolled timing
//! with warmup + repetitions). One section per paper table/figure measuring
//! the compute that regenerates it, plus the §Perf hot-path microbenches.
//!
//! Run: `cargo bench --offline` (results also land in bench_output.txt via
//! the Makefile). `cargo bench --offline -- --quick` (`make bench-quick`)
//! runs only the sections that regenerate the machine-readable perf
//! trajectory (BENCH_serve.json + BENCH_hostmodel.json) — the CI smoke.

use silq::config::Manifest;
use silq::data::vocab::Vocab;
use silq::data::{Batcher, DataMix, World};
use silq::kernels::{pool, simd, DecodeScratch};
use silq::linalg::{hadamard, Mat};
use silq::model::ParamStore;
use silq::ptq::gptq::gptq_quantize_family;
use silq::quant;
use silq::runtime::{build_inputs, literal_i32, Engine};
use silq::evalharness::decode::argmax;
use silq::forward::{decode_greedy, HostForward};
use silq::hostmodel::{builtin_model, host_test_params, HostModel, KvLayout, KvPool};
use silq::serve::{serve_inline, ArtifactBackend, CacheStore, GenRequest, HostBackend, HostCfg};
use silq::util::timer::{bench_ms, BenchMs};
use silq::util::{Rng, Timer};

fn section(name: &str) {
    println!("\n== {name} ==");
}

fn report(name: &str, ms: f64, extra: &str) {
    println!("{name:<44} {ms:>10.3} ms  {extra}");
}

/// Report a min/mean measurement. The JSON trajectories use the min
/// (noise-robust: jitter only pushes samples up); the mean rides along
/// here so the console shows the spread.
fn report_bench(name: &str, b: BenchMs, extra: &str) {
    println!("{name:<44} {:>10.3} ms min ({:.3} mean)  {extra}", b.min_ms, b.mean_ms);
}

/// One serve measurement as a JSON object (serde is unavailable offline;
/// the fields are flat scalars so hand-rolled formatting is safe).
fn bench_serve_entry(
    label: &str,
    backend: &str,
    policy: &str,
    stats: &silq::serve::ServeStats,
) -> String {
    // ttft_mean_ms is 0 (never NaN) on runs with no first token, so the
    // value is always a valid JSON number
    format!(
        "  {{\"label\": \"{label}\", \"backend\": \"{backend}\", \"policy\": \"{policy}\", \
         \"threads\": {}, \"kernel\": \"{}\", \
         \"tok_per_s\": {:.2}, \"ttft_ms_mean\": {:.3}, \"wall_secs\": {:.4}, \
         \"completed\": {}, \"occupancy\": {:.3}}}",
        pool::active_threads(),
        simd::active_name(),
        stats.tokens_per_sec(),
        stats.ttft_mean_ms(),
        stats.wall_secs,
        stats.completed,
        stats.batch_occupancy(),
    )
}

/// Machine-readable serve perf trajectory: benches run from `rust/`, so
/// the JSON lands next to bench_output.txt at the repo root.
fn write_bench_serve_json(entries: &[String]) {
    let body = format!("[\n{}\n]\n", entries.join(",\n"));
    match std::fs::write("../BENCH_serve.json", &body) {
        Ok(()) => println!("(serve metrics -> BENCH_serve.json)"),
        Err(e) => eprintln!("warning: could not write ../BENCH_serve.json: {e}"),
    }
}

/// Prefill `prompt` into a fresh slot, then decode `steps` tokens through
/// the scratch-reusing incremental forward; returns min/mean ms per
/// decoded token over `reps` repetitions (after one warmup rep).
fn decode_ms_per_tok(
    model: &HostModel,
    pool: &mut KvPool,
    prompt: &[i32],
    steps: usize,
    reps: usize,
) -> BenchMs {
    let mut scratch = DecodeScratch::for_cfg(&model.cfg);
    let mut min_ms = f64::INFINITY;
    let mut total_ms = 0.0;
    for rep in 0..reps + 1 {
        let slot = pool.alloc().expect("pool slot");
        let mut tok = 0i32;
        for (pos, &t) in prompt.iter().enumerate() {
            let lg = model
                .forward_token_into(pool, slot, t, pos, true, &mut scratch)
                .expect("prefill")
                .expect("logits");
            tok = argmax(lg) as i32;
        }
        let t0 = Timer::start();
        for i in 0..steps {
            let lg = model
                .forward_token_into(pool, slot, tok, prompt.len() + i, true, &mut scratch)
                .expect("decode")
                .expect("logits");
            tok = argmax(lg) as i32;
        }
        if rep > 0 {
            let rep_ms = t0.millis() / steps as f64;
            min_ms = min_ms.min(rep_ms);
            total_ms += rep_ms;
        }
        pool.free(slot);
    }
    BenchMs { min_ms, mean_ms: total_ms / reps as f64 }
}

/// Integer-kernel vs f32-reference hostmodel benches on one builtin model;
/// returns the JSON entry for BENCH_hostmodel.json.
fn bench_hostmodel_entry(model_name: &str, policy: &str, seed: u64) -> String {
    let mc = builtin_model(model_name).expect("builtin model");
    let cfg = HostCfg::from_policy(&mc, &policy.parse().expect("policy")).expect("host cfg");
    let params = host_test_params(&cfg, seed);
    let int_model = HostModel::new(cfg.clone(), &params).expect("model");
    let ref_model = HostModel::new_reference(cfg.clone(), &params).expect("reference");
    assert!(int_model.integer_path(), "{model_name}/{policy} must run the integer kernels");

    // prefill / scoring: batched forward_seq over a half-window prompt
    let plen = cfg.seq_len / 2;
    let prompt: Vec<i32> = (0..plen as i32).map(|i| 1 + (i * 13) % (cfg.vocab as i32 - 1)).collect();
    let ms_prefill_int = bench_ms(1, 3, || {
        let _ = int_model.forward_seq(&prompt).expect("fwd");
    });
    let ms_prefill_ref = bench_ms(1, 3, || {
        let _ = ref_model.forward_seq(&prompt).expect("fwd");
    });
    // the JSON trajectory rates/ratios use the min iteration (noise-robust)
    let prefill_tok_s = plen as f64 / ms_prefill_int.min_ms * 1e3;
    let prefill_tok_s_ref = plen as f64 / ms_prefill_ref.min_ms * 1e3;

    // decode: steady-state forward_token over the deployment Int8 pool —
    // the reference pays the dequantize-and-copy read path on the same
    // resident representation (the pre-kernels behavior)
    let steps = (cfg.seq_len - plen - 1).min(32);
    let mut int_pool = int_model.make_pool(1, CacheStore::Int8).expect("pool");
    let mut ref_pool = ref_model.make_pool(1, CacheStore::Int8).expect("pool");
    let ms_tok_int = decode_ms_per_tok(&int_model, &mut int_pool, &prompt, steps, 3);
    let ms_tok_ref = decode_ms_per_tok(&ref_model, &mut ref_pool, &prompt, steps, 3);
    let decode_tok_s = 1e3 / ms_tok_int.min_ms;
    let decode_tok_s_ref = 1e3 / ms_tok_ref.min_ms;
    let speedup = ms_tok_ref.min_ms / ms_tok_int.min_ms.max(1e-9);

    // bytes the attention read path touches per decoded token, mid-decode
    let kv_len = plen + steps / 2;
    let kv_bytes_int = int_pool.read_bytes_per_token(kv_len);
    let kv_bytes_f32 = cfg.n_layers * 2 * kv_len * cfg.d_model * 4;
    report_bench(
        &format!("decode {model_name} {policy} integer kernels"),
        ms_tok_int,
        &format!("({decode_tok_s:.0} tok/s)"),
    );
    report_bench(
        &format!("decode {model_name} {policy} f32 reference"),
        ms_tok_ref,
        &format!("({decode_tok_s_ref:.0} tok/s, int is {speedup:.1}x faster)"),
    );
    report_bench(
        &format!("prefill {model_name} {policy} integer GEMM"),
        ms_prefill_int,
        &format!("({prefill_tok_s:.0} tok/s vs {prefill_tok_s_ref:.0} f32)"),
    );
    format!(
        "  {{\"model\": \"{model_name}\", \"policy\": \"{policy}\", \
         \"threads\": {}, \"kernel\": \"{}\", \
         \"prefill_tok_s\": {prefill_tok_s:.2}, \"prefill_tok_s_ref\": {prefill_tok_s_ref:.2}, \
         \"decode_tok_s\": {decode_tok_s:.2}, \"decode_tok_s_ref\": {decode_tok_s_ref:.2}, \
         \"decode_speedup\": {speedup:.3}, \
         \"kv_read_bytes_per_token\": {kv_bytes_int}, \
         \"kv_read_bytes_per_token_f32\": {kv_bytes_f32}, \
         \"weight_bytes\": {}, \"weight_bytes_ref\": {}}}",
        pool::active_threads(),
        simd::active_name(),
        int_model.weight_bytes(),
        ref_model.weight_bytes(),
    )
}

/// Decode tok/s vs worker-pool width on the builtin `small` model — the
/// thread-scaling table. Same model, same tokens, bit-identical output at
/// every width (the kernels shard exact `i32` contractions by output
/// channel); the only thing that moves is throughput.
fn thread_scaling_entries(base_threads: usize) -> Vec<String> {
    let mc = builtin_model("small").expect("builtin model");
    let cfg = HostCfg::from_policy(&mc, &"w4a8kv8".parse().expect("policy")).expect("host cfg");
    let params = host_test_params(&cfg, 33);
    let model = HostModel::new(cfg.clone(), &params).expect("model");
    let plen = cfg.seq_len / 2;
    let prompt: Vec<i32> =
        (0..plen as i32).map(|i| 1 + (i * 13) % (cfg.vocab as i32 - 1)).collect();
    let steps = (cfg.seq_len - plen - 1).min(32);
    let mut kv = model.make_pool(1, CacheStore::Int8).expect("pool");
    let mut out = vec![];
    let mut tok_s_1t = 0.0f64;
    for t in [1usize, 2, 4, 8] {
        pool::configure(t);
        let ms = decode_ms_per_tok(&model, &mut kv, &prompt, steps, 3);
        let tok_s = 1e3 / ms.min_ms;
        if t == 1 {
            tok_s_1t = tok_s;
        }
        let scaling = tok_s / tok_s_1t.max(1e-9);
        report_bench(
            &format!("decode small w4a8kv8, {t} thread(s)"),
            ms,
            &format!("({tok_s:.0} tok/s, {scaling:.2}x vs 1t, kernel {})", simd::active_name()),
        );
        out.push(format!(
            "  {{\"model\": \"small\", \"policy\": \"w4a8kv8\", \"section\": \"thread_scaling\", \
             \"threads\": {t}, \"kernel\": \"{}\", \"decode_tok_s\": {tok_s:.2}, \
             \"scaling_vs_1t\": {scaling:.3}}}",
            simd::active_name(),
        ));
    }
    pool::configure(base_threads);
    out
}

/// Serve throughput through the host backend (quantized KV pool), int8 vs
/// f32 store — the always-runnable serve trajectory entries.
fn serve_host_entries() -> Vec<String> {
    let mut serve_json: Vec<String> = vec![];
    let cfg = HostCfg {
        vocab: 256, d_model: 64, n_layers: 2, n_heads: 4, d_ff: 128, seq_len: 48,
        policy: "w4a8kv8".parse().expect("policy spec"), rope_theta: 10000.0,
    };
    let params = host_test_params(&cfg, 9);
    for (label, store) in
        [("serve 32 reqs x8 tok, int8 kv pool", CacheStore::Int8),
         ("serve 32 reqs x8 tok, f32 kv cache", CacheStore::F32)]
    {
        let reqs: Vec<GenRequest> = (0..32)
            .map(|i| GenRequest::new(i, vec![1, 3, 22 + (i % 4) as i32, 10, 4], 8).ignore_eos())
            .collect();
        let backend = HostBackend::new(cfg.clone(), 8, &params, store).expect("backend");
        let t = Timer::start();
        let (results, stats) = serve_inline(backend, 8, reqs).expect("serve run");
        let ms = t.millis();
        report(label, ms, &format!(
            "({:.0} tok/s, occ {:.0}%, {} reqs)",
            stats.tokens_per_sec(), 100.0 * stats.batch_occupancy(), results.len()
        ));
        serve_json.push(bench_serve_entry(label, "host", "w4a8kv8", &stats));
    }
    serve_json
}

/// Cross-lane batched vs per-lane sequential serve decode on the builtin
/// `small` model at batch widths B ∈ {1, 4, 8} — the PR-5 throughput
/// figure. One scheduler step is one fused GEMM per weight matrix across
/// all live lanes (`HostBackend::new`) against B independent GEMV passes
/// (`HostBackend::new_sequential`); the two decode token-identically (the
/// batched≡sequential identity suite pins it), so the ratio is pure
/// batching — each weight matrix streams once per GEMM block per step
/// instead of once per lane.
fn batched_decode_entries() -> Vec<String> {
    let mc = builtin_model("small").expect("builtin model");
    let cfg = HostCfg::from_policy(&mc, &"w4a8kv8".parse().expect("policy")).expect("host cfg");
    let params = host_test_params(&cfg, 41);
    // short prompts, long budgets: both backends pay the same sequential
    // per-token prefill at admission, so keeping it ~1/8 of the run stops
    // it diluting the decode-phase ratio the JSON reports
    let mk_reqs = |n: usize| -> Vec<GenRequest> {
        (0..n)
            .map(|i| {
                let prompt: Vec<i32> =
                    (0..4usize).map(|p| 1 + ((i * 29 + p * 13) % (cfg.vocab - 1)) as i32).collect();
                GenRequest::new(i as u64, prompt, 24).ignore_eos()
            })
            .collect()
    };
    let mut out = vec![];
    for b in [1usize, 4, 8] {
        let n_req = 2 * b;
        let seq_backend = HostBackend::new_sequential(cfg.clone(), b, &params, CacheStore::Int8)
            .expect("backend");
        let (_, st_seq) = serve_inline(seq_backend, b, mk_reqs(n_req)).expect("serve run");
        let bat_backend =
            HostBackend::new(cfg.clone(), b, &params, CacheStore::Int8).expect("backend");
        let (_, st_bat) = serve_inline(bat_backend, b, mk_reqs(n_req)).expect("serve run");
        let speedup = st_bat.tokens_per_sec() / st_seq.tokens_per_sec().max(1e-9);
        report(
            &format!("serve decode small w4a8kv8, B={b} batched"),
            st_bat.wall_secs * 1e3,
            &format!(
                "({:.0} tok/s vs {:.0} sequential, {speedup:.2}x)",
                st_bat.tokens_per_sec(),
                st_seq.tokens_per_sec()
            ),
        );
        out.push(format!(
            "  {{\"label\": \"batched decode small w4a8kv8 B={b}\", \"backend\": \"host\", \
             \"policy\": \"w4a8kv8\", \"batch\": {b}, \"threads\": {}, \"kernel\": \"{}\", \
             \"tok_per_s\": {:.2}, \
             \"tok_per_s_sequential\": {:.2}, \"batched_speedup\": {speedup:.3}, \
             \"completed\": {}}}",
            pool::active_threads(),
            simd::active_name(),
            st_bat.tokens_per_sec(),
            st_seq.tokens_per_sec(),
            st_bat.completed,
        ));
    }
    out
}

/// Slab-vs-paged serve rows with page-occupancy and sharing provenance:
/// the same request mix (half the prompts open with a two-page shared
/// system prefix) through both KV layouts. The layouts decode
/// token-identically (pinned by the proptest suite), so these rows track
/// only the paged walk's overhead plus the occupancy / sharing-ratio
/// trajectory the paged allocator is for.
fn paged_serve_entries() -> Vec<String> {
    let mc = builtin_model("small").expect("builtin model");
    let cfg = HostCfg::from_policy(&mc, &"w4a8kv8".parse().expect("policy")).expect("host cfg");
    let params = host_test_params(&cfg, 41);
    let (lanes, ps) = (4usize, 8usize);
    let prefix: Vec<i32> =
        (0..(2 * ps) as i32).map(|p| 1 + (p * 17) % (cfg.vocab as i32 - 1)).collect();
    let mk_reqs = || -> Vec<GenRequest> {
        (0..2 * lanes)
            .map(|i| {
                let mut prompt = if i % 2 == 0 { prefix.clone() } else { Vec::new() };
                prompt
                    .extend((0..4usize).map(|p| 1 + ((i * 29 + p * 13) % (cfg.vocab - 1)) as i32));
                GenRequest::new(i as u64, prompt, 8).ignore_eos()
            })
            .collect()
    };
    let mut out = vec![];
    for (kv, layout) in [
        ("slab", KvLayout::Slab),
        ("paged", KvLayout::Paged { page_size: ps, total_pages: None, sharing: true }),
    ] {
        let backend =
            HostBackend::new_with_layout(cfg.clone(), lanes, &params, CacheStore::Int8, layout)
                .expect("backend");
        let (_, st) = serve_inline(backend, lanes, mk_reqs()).expect("serve run");
        report(
            &format!("serve decode small w4a8kv8, kv={kv}"),
            st.wall_secs * 1e3,
            &format!(
                "({:.0} tok/s, {} pages peak, sharing {:.2})",
                st.tokens_per_sec(),
                st.kv_pages_peak,
                st.kv_sharing_ratio()
            ),
        );
        out.push(format!(
            "  {{\"label\": \"paged kv serve small w4a8kv8 kv={kv}\", \"backend\": \"host\", \
             \"policy\": \"w4a8kv8\", \"kv\": \"{kv}\", \"page_size\": {}, \"threads\": {}, \
             \"kernel\": \"{}\", \"tok_per_s\": {:.2}, \"kv_pages_peak\": {}, \
             \"kv_sharing_ratio\": {:.4}, \"completed\": {}, \"occupancy\": {:.3}}}",
            if kv == "paged" { ps } else { cfg.seq_len },
            pool::active_threads(),
            simd::active_name(),
            st.tokens_per_sec(),
            st.kv_pages_peak,
            st.kv_sharing_ratio(),
            st.completed,
            st.batch_occupancy(),
        ));
    }
    out
}

/// Batched serve decode at B=8 across worker-pool widths {1, 2, 4, 8}:
/// the fused cross-lane step shards its GEMMs by output channel and its
/// int8 attention by lane, so one scheduler step itself scales with the
/// pool — token-identical at every width.
fn batched_decode_thread_entries(base_threads: usize) -> Vec<String> {
    let mc = builtin_model("small").expect("builtin model");
    let cfg = HostCfg::from_policy(&mc, &"w4a8kv8".parse().expect("policy")).expect("host cfg");
    let params = host_test_params(&cfg, 41);
    let b = 8usize;
    let mk_reqs = || -> Vec<GenRequest> {
        (0..2 * b)
            .map(|i| {
                let prompt: Vec<i32> =
                    (0..4usize).map(|p| 1 + ((i * 29 + p * 13) % (cfg.vocab - 1)) as i32).collect();
                GenRequest::new(i as u64, prompt, 24).ignore_eos()
            })
            .collect()
    };
    let mut out = vec![];
    let mut tok_s_1t = 0.0f64;
    for t in [1usize, 2, 4, 8] {
        pool::configure(t);
        let backend =
            HostBackend::new(cfg.clone(), b, &params, CacheStore::Int8).expect("backend");
        let (_, st) = serve_inline(backend, b, mk_reqs()).expect("serve run");
        let tok_s = st.tokens_per_sec();
        if t == 1 {
            tok_s_1t = tok_s;
        }
        let speedup = tok_s / tok_s_1t.max(1e-9);
        report(
            &format!("serve decode small w4a8kv8, B={b}, {t} thread(s)"),
            st.wall_secs * 1e3,
            &format!("({tok_s:.0} tok/s, {speedup:.2}x vs 1t)"),
        );
        out.push(format!(
            "  {{\"label\": \"batched decode small w4a8kv8 B={b} threads={t}\", \
             \"backend\": \"host\", \"policy\": \"w4a8kv8\", \"batch\": {b}, \"threads\": {t}, \
             \"kernel\": \"{}\", \"tok_per_s\": {tok_s:.2}, \"scaling_vs_1t\": {speedup:.3}, \
             \"completed\": {}}}",
            simd::active_name(),
            st.completed,
        ));
    }
    pool::configure(base_threads);
    out
}

/// The `--quick` serve pass: host-backend + batched-decode + thread-
/// scaling entries, straight to JSON.
fn quick_serve_section(base_threads: usize) {
    section("serve throughput (host backend, quantized KV pool)");
    let mut entries = serve_host_entries();
    section("cross-lane batched decode (one fused GEMM per matrix per step)");
    entries.extend(batched_decode_entries());
    section("paged KV serve (page occupancy + prefix sharing)");
    entries.extend(paged_serve_entries());
    section("batched decode vs worker-pool width (B=8)");
    entries.extend(batched_decode_thread_entries(base_threads));
    write_bench_serve_json(&entries);
}

/// Machine-readable hostmodel perf trajectory, next to BENCH_serve.json.
fn write_bench_hostmodel_json(entries: &[String]) {
    let body = format!("[\n{}\n]\n", entries.join(",\n"));
    match std::fs::write("../BENCH_hostmodel.json", &body) {
        Ok(()) => println!("(hostmodel metrics -> BENCH_hostmodel.json)"),
        Err(e) => eprintln!("warning: could not write ../BENCH_hostmodel.json: {e}"),
    }
}

fn main() {
    // --quick (make bench-quick): only the JSON-writing trajectory
    // sections, so CI can regenerate BENCH_*.json in seconds
    let quick = std::env::args().any(|a| a == "--quick");
    // worker-pool width: $SILQ_THREADS, else every core. The scaling
    // sections sweep widths explicitly and restore this afterwards, so
    // every JSON entry's recorded "threads" is what actually ran it.
    let base_threads = pool::env_threads()
        .unwrap_or_else(|| std::thread::available_parallelism().map_or(1, |n| n.get()));
    pool::configure(base_threads);
    println!(
        "silq bench harness (warmup+avg wall-clock; CPU PJRT{}; threads={} kernel={})",
        if quick { "; --quick" } else { "" },
        pool::active_threads(),
        simd::active_name(),
    );

    // ---------------- integer decode kernels (BENCH_hostmodel.json) ------
    // the deployment claim measured: packed-i8 GEMV/GEMM + zero-copy int8
    // attention vs the f32 fake-quant reference on the same params
    section("integer decode kernels (hostmodel hot loop)");
    let mut hostmodel_json: Vec<String> = vec![];
    hostmodel_json.push(bench_hostmodel_entry("small", "w4a8kv8", 33));
    hostmodel_json.push(bench_hostmodel_entry("tiny", "w4a8kv8", 35));
    if !quick {
        hostmodel_json.push(bench_hostmodel_entry("small", "w4a8kv8:statacts", 37));
    }
    section("decode vs worker-pool width (small, w4a8kv8)");
    hostmodel_json.extend(thread_scaling_entries(base_threads));
    write_bench_hostmodel_json(&hostmodel_json);

    if quick {
        quick_serve_section(base_threads);
        println!("\nbench harness done (--quick)");
        return;
    }

    // ---------------- host-side quantization (L3 substrate) --------------
    section("quant substrate (feeds every PTQ table)");
    let mut rng = Rng::new(0);
    let w: Vec<f32> = rng.normal_vec(256 * 256, 0.1);
    report_bench("weight_step_mse_per_channel 256x256 int4", bench_ms(2, 10, || {
        let _ = quant::calib::weight_step_mse_per_channel(&w, 256, 4);
    }), "(paper Eq. 2, ternary search)");
    let steps = quant::calib::weight_step_mse_per_channel(&w, 256, 4);
    report_bench("fake_quant_per_channel 256x256 int4", bench_ms(2, 50, || {
        let mut c = w.clone();
        quant::fake_quant_per_channel(&mut c, 256, &steps, 4);
    }), "");
    let mut x = rng.normal_vec(1024 * 256, 1.0);
    report_bench("dynamic_quant_rows 1024x256 int8", bench_ms(2, 50, || {
        let mut c = x.clone();
        quant::dynamic_quant_rows(&mut c, 256, 8);
    }), "(A8d runtime path)");
    x.truncate(0);

    // ---------------- GPTQ / rotations (Table 1 baselines) ---------------
    section("PTQ kernels (Table 1 baselines)");
    let k = 128;
    let gram = {
        let mut g = Mat::zeros(k, k);
        let mut r2 = Rng::new(1);
        for _ in 0..256 {
            let v = r2.normal_vec(k, 1.0);
            for i in 0..k {
                for j in 0..k {
                    g.data[i * k + j] += v[i] * v[j];
                }
            }
        }
        g
    };
    let wk: Vec<f32> = rng.normal_vec(k * 128, 0.1);
    let sk = quant::calib::weight_step_mse_per_channel(&wk, 128, 4);
    report_bench("gptq_quantize_family 128x128 int4", bench_ms(1, 5, || {
        let mut c = wk.clone();
        let _ = gptq_quantize_family(&mut c, k, 128, &gram, &sk, 4);
    }), "(Cholesky + OBS updates)");
    report_bench("hadamard(128) construction", bench_ms(2, 50, || {
        let _ = hadamard(128);
    }), "(SpinQuant rotation)");
    let a = Mat::from_vec(128, 128, rng.normal_vec(128 * 128, 1.0));
    let b = Mat::from_vec(128, 128, rng.normal_vec(128 * 128, 1.0));
    report_bench("procrustes rotation_decomposition 128x128", bench_ms(1, 3, || {
        let _ = silq::linalg::rotation_decomposition(&a, &b);
    }), "(Figure 3, Jacobi SVD)");

    // ---------------- data pipeline (L3 hot loop input) -------------------
    section("data pipeline");
    let world = World::generate(Vocab::new(256), 7);
    let mut batcher = Batcher::new(&world, DataMix::Corpus, 16, 64, 0);
    report_bench("corpus batch 16x64", bench_ms(10, 200, || {
        let _ = batcher.next_batch();
    }), "(must be << exec time)");

    // ---------------- serve throughput (host backend) ---------------------
    // continuous-batching engine over the host incremental decoder; no
    // artifacts needed, so this section always runs. Each run also lands in
    // BENCH_serve.json (repo root) so the perf trajectory is machine-
    // readable across PRs.
    section("serve throughput (host backend, quantized KV pool)");
    let mut serve_json = serve_host_entries();

    // cross-lane batched decode: the PR-5 lever, batched vs sequential at
    // several batch widths (also part of --quick; lands in BENCH_serve.json)
    section("cross-lane batched decode (one fused GEMM per matrix per step)");
    serve_json.extend(batched_decode_entries());

    // paged KV layout vs the slab, same mix: occupancy + sharing rows
    section("paged KV serve (page occupancy + prefix sharing)");
    serve_json.extend(paged_serve_entries());

    // one fused step scales with the worker pool too: B=8, widths 1..8
    section("batched decode vs worker-pool width (B=8)");
    serve_json.extend(batched_decode_thread_entries(base_threads));

    // ------- eval-style greedy decode: incremental vs full recompute ------
    // the ISSUE-2 win, measured: host incremental decode does O(1) work per
    // new token over the KV pool, while the old eval loop (and the
    // stateless artifact graph) recomputes the whole prefix every step —
    // O(n) per token, O(n^2) per generation. The ratio should grow with
    // prompt length.
    section("eval greedy decode (host): incremental KV vs full-sequence recompute");
    {
        let cfg = HostCfg {
            vocab: 256, d_model: 64, n_layers: 2, n_heads: 4, d_ff: 128, seq_len: 96,
            policy: "w4a8kv8".parse().expect("policy spec"), rope_theta: 10000.0,
        };
        let params = host_test_params(&cfg, 21);
        let model = HostModel::new(cfg.clone(), &params).expect("model");
        let mut fwd = HostForward::new(cfg.clone(), 1, &params, CacheStore::Int8).expect("fwd");
        let max_new = 16usize;
        for plen in [8usize, 32, 64] {
            let prompt: Vec<i32> = (0..plen as i32).map(|i| 1 + i % 250).collect();
            let ms_inc = bench_ms(1, 5, || {
                let out = decode_greedy(&mut fwd, &[&prompt], max_new).expect("decode");
                assert_eq!(out[0].len(), max_new);
            });
            let ms_full = bench_ms(1, 5, || {
                // the pre-ISSUE-2 eval loop: full forward per emitted token
                let mut row = prompt.clone();
                for _ in 0..max_new {
                    let lg = model.forward_seq(&row).expect("fwd");
                    let last = &lg[(row.len() - 1) * cfg.vocab..row.len() * cfg.vocab];
                    row.push(argmax(last) as i32);
                }
            });
            report_bench(&format!("greedy {max_new} tok, prompt {plen:>2}, incremental"), ms_inc, "");
            report_bench(
                &format!("greedy {max_new} tok, prompt {plen:>2}, full recompute"),
                ms_full,
                &format!("({:.1}x slower)", ms_full.min_ms / ms_inc.min_ms.max(1e-9)),
            );
        }
    }

    // ---------------- PJRT execution (every experiment) ------------------
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        write_bench_serve_json(&serve_json);
        println!("\nartifacts not built; skipping PJRT benches (run `make artifacts`)");
        return;
    }
    let engine = Engine::new("artifacts").expect("engine");
    let _ = Manifest::load("artifacts").unwrap();
    section("PJRT execution (Tables 1-4, Figure 1)");
    for art in ["tiny_fp16_fwd", "tiny_a8d-c8-w4_fwd", "tiny_a8s-c8-w4_fwd", "tiny-pallas_a8d-c8-w4_fwd"] {
        let m = engine.module(art).expect("module");
        let mc = engine.manifest.model(&m.spec.model).unwrap().clone();
        let mut r3 = Rng::new(3);
        let ps = ParamStore::init(&m.spec, &mc, &mut r3);
        let tok_spec = m.spec.inputs[m.spec.input_index("tokens").unwrap()].clone();
        let tokens: Vec<i32> = (0..tok_spec.numel()).map(|i| 1 + (i as i32 % 250)).collect();
        let inputs = build_inputs(&m.spec, &ps, &[("tokens", literal_i32(&tok_spec.dims, &tokens).unwrap())]).unwrap();
        let toks_per = tok_spec.numel() as f64;
        let ms = bench_ms(2, 10, || {
            let _ = m.run(&inputs).unwrap();
        });
        report_bench(&format!("fwd {art}"), ms, &format!("({:.0} tok/s)", toks_per / ms.min_ms * 1e3));
    }

    // serve throughput through the compiled graph (continuous batching,
    // full-sequence recompute per step)
    section("serve throughput (artifact backend)");
    {
        let art = "tiny_a8d-c8-w4_fwd";
        let m = engine.module(art).expect("module");
        let mc = engine.manifest.model(&m.spec.model).unwrap().clone();
        let mut r6 = Rng::new(11);
        let params = ParamStore::init(&m.spec, &mc, &mut r6);
        let reqs: Vec<GenRequest> = (0..16)
            .map(|i| GenRequest::new(i, vec![1, 3, 22 + (i % 4) as i32, 10, 4], 4).ignore_eos())
            .collect();
        let backend = ArtifactBackend::new(&engine, art, &params).expect("backend");
        let t = Timer::start();
        let (results, stats) = serve_inline(backend, 8, reqs).expect("serve run");
        let ms = t.millis();
        report("serve 16 reqs x4 tok via PJRT fwd", ms, &format!(
            "({:.0} tok/s, occ {:.0}%, {} reqs)",
            stats.tokens_per_sec(), 100.0 * stats.batch_occupancy(), results.len()
        ));
        serve_json.push(bench_serve_entry(
            "serve 16 reqs x4 tok via PJRT fwd", "artifact", "w4a8kv8", &stats,
        ));
    }

    // train step (the QAT hot path — Table 1/2/3/4 inner loop)
    for art in ["tiny_fp16_train", "tiny_a8s-c8-w4_train"] {
        let m = engine.module(art).expect("module");
        let mc = engine.manifest.model(&m.spec.model).unwrap().clone();
        let spec = m.spec.clone();
        let mut r4 = Rng::new(4);
        let ps = ParamStore::init(&m.spec, &mc, &mut r4);
        let n = ps.names.len();
        let mut inputs = vec![];
        for (i, t) in spec.inputs.iter().enumerate() {
            if i < n {
                inputs.push(silq::runtime::literal_f32(&t.dims, &ps.values[i]).unwrap());
            } else if i < 3 * n {
                inputs.push(silq::runtime::literal_f32(&t.dims, &vec![0.0; t.numel()]).unwrap());
            } else if t.name == "tokens" {
                let toks: Vec<i32> = (0..t.numel()).map(|i| 1 + (i as i32 % 250)).collect();
                inputs.push(literal_i32(&t.dims, &toks).unwrap());
            } else if t.name == "teacher_logits" {
                inputs.push(silq::runtime::literal_f32(&t.dims, &vec![0.0; t.numel()]).unwrap());
            } else {
                inputs.push(silq::runtime::literal_scalar(1.0));
            }
        }
        let batch_tokens = mc.train_batch * mc.seq_len;
        let ms = bench_ms(1, 5, || {
            let _ = m.run(&inputs).unwrap();
        });
        report_bench(
            &format!("train_step {art}"),
            ms,
            &format!("({:.0} tok/s)", batch_tokens as f64 / ms.min_ms * 1e3),
        );
    }

    write_bench_serve_json(&serve_json);
    println!("\nbench harness done");
}
