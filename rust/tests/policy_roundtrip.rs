//! Integration: the typed `QuantPolicy` API against the manifest contract.
//!
//! Everything here runs in a bare checkout (no compiled artifacts): the
//! fixture below mirrors the `prec` lines `python -m compile.aot` writes
//! for `python/compile/configs.py::PRECISIONS`, which is the full set of
//! precisions the Python side can emit.

use std::path::PathBuf;

use silq::config::Manifest;
use silq::hostmodel::{builtin_model, builtin_prec, HostCfg};
use silq::policy::{CalibMethod, QuantMode, QuantPolicy, PRESETS};

/// The `prec` lines of a real manifest (mirroring configs.py PRECISIONS).
const FIXTURE_PRECS: &str = "\
# silq artifact manifest v1 (precision fixture)
prec fp16 quantized=0 act_bits=8 act_dynamic=1 cache_bits=8 weight_bits=4 head_bits=8 query_bits=16 online_rot=0
prec a8d-c8-w4 quantized=1 act_bits=8 act_dynamic=1 cache_bits=8 weight_bits=4 head_bits=8 query_bits=16 online_rot=0
prec a8s-c8-w4 quantized=1 act_bits=8 act_dynamic=0 cache_bits=8 weight_bits=4 head_bits=8 query_bits=16 online_rot=0
prec a8d-c4-w4 quantized=1 act_bits=8 act_dynamic=1 cache_bits=4 weight_bits=4 head_bits=8 query_bits=16 online_rot=0
prec a8d-c8-w4-rot quantized=1 act_bits=8 act_dynamic=1 cache_bits=8 weight_bits=4 head_bits=8 query_bits=16 online_rot=1
";

#[test]
fn every_fixture_prec_converts_to_policy_and_back_without_loss() {
    let m = Manifest::parse(FIXTURE_PRECS, PathBuf::new()).unwrap();
    assert_eq!(m.precs.len(), 5, "fixture must cover all configs.py precisions");
    for pc in m.precs.values() {
        let policy = pc.policy().unwrap_or_else(|e| panic!("{}: {e}", pc.name));
        let back = policy.to_prec(&pc.name).unwrap();
        // PrecCfg derives no PartialEq; the Debug rendering covers every
        // field, so identical renderings mean identical configs
        assert_eq!(
            format!("{pc:?}"),
            format!("{back:?}"),
            "{}: policy round trip must be lossless",
            pc.name
        );
        // the legacy name resolves to the same policy through the grammar
        assert_eq!(
            QuantPolicy::resolve(&pc.name).unwrap(),
            policy,
            "{}: name resolution must agree with the manifest entry",
            pc.name
        );
    }
}

#[test]
fn fixture_precs_agree_with_builtin_mirrors() {
    let m = Manifest::parse(FIXTURE_PRECS, PathBuf::new()).unwrap();
    for pc in m.precs.values() {
        let builtin = builtin_prec(&pc.name)
            .unwrap_or_else(|| panic!("{} must have a builtin mirror", pc.name));
        assert_eq!(format!("{pc:?}"), format!("{builtin:?}"), "{} mirror drifted", pc.name);
    }
}

#[test]
fn presets_cover_the_fixture_and_extend_it() {
    let m = Manifest::parse(FIXTURE_PRECS, PathBuf::new()).unwrap();
    // every manifest-mapped preset matches its manifest entry
    for preset in PRESETS {
        let policy = QuantPolicy::preset(preset.name).unwrap();
        if let Some(name) = preset.manifest_prec {
            let pc = &m.precs[name];
            assert_eq!(pc.policy().unwrap(), policy, "preset {} vs {name}", preset.name);
        }
    }
    // and at least one preset goes beyond what the manifest can name
    assert!(PRESETS.iter().any(|p| p.manifest_prec.is_none()));
}

#[test]
fn inline_specs_build_host_configs_without_any_manifest() {
    let mc = builtin_model("tiny").unwrap();
    for spec in ["fp16", "w4a8kv8", "w4a8kv8:statacts", "w4a8kv4", "w8a8kv8:q8,acal=max"] {
        let policy = QuantPolicy::resolve(spec).unwrap();
        let hc = HostCfg::from_policy(&mc, &policy).unwrap_or_else(|e| panic!("{spec}: {e}"));
        assert_eq!(hc.policy, policy);
    }
    // the rotation ablation stays artifact-only
    let rot = QuantPolicy::resolve("w4a8kv8:rot").unwrap();
    assert!(HostCfg::from_policy(&mc, &rot).is_err());
}

#[test]
fn calibration_survives_spec_round_trip_but_not_prec_cfg() {
    // calib choices are policy-level: the spec string keeps them, the
    // manifest form (which never carried them) drops them by design
    let p: QuantPolicy = "w4a8kv8:acal=max,wcal=lsq".parse().unwrap();
    assert_eq!(p.to_string().parse::<QuantPolicy>().unwrap(), p);
    let back = p.to_prec("x").unwrap().policy().unwrap();
    assert_eq!(back.acts.calib, CalibMethod::Quantile);
    assert_eq!(back.weights.calib, CalibMethod::Mse);
    assert_eq!(back.acts.bits, p.acts.bits);
    assert_eq!(back.acts.mode, QuantMode::Dynamic);
}
